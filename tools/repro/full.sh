#!/usr/bin/env bash
# Full reproduction run: the complete scenario matrix through the real
# CompressionSession engine (train → capture → prune → emit families),
# not just the engine-free kick-tires subset.
#
# This is NOT deterministic across machines the way kick-tires is —
# measured latency tables depend on the host — so its report is an
# artifact to read, not a golden to diff. Expect minutes, not seconds.
#
# Usage: tools/repro/full.sh [OUT_DIR] [SEED]
# See DESIGN.md §11 for the matrix axes and report schema.
set -euo pipefail
cd "$(dirname "$0")/../.."

out="${1:-runs/repro-full}"
seed="${2:-7}"

cargo run --release --locked --manifest-path rust/Cargo.toml -- \
  repro --seed "$seed" --out "$out" --precomputed tools/repro/precomputed

python3 tools/repro/render_report.py "$out/repro_report.json" --check-md "$out/REPORT.md"

echo "Done! full reproduction report at $out/REPORT.md"
