#!/usr/bin/env python3
"""Golden-file generator for the kick-tires reproduction report.

This is an exact, operation-for-operation Python transliteration of the
Rust kick-tires path (`ziplm repro --kick-tires`, rust/src/exp/repro.rs
plus the modules it drives: util::rng, spdy::solve_dp, latency's
analytic roofline, env pricing, coordinator routing + replay, and the
util::json pretty writer).  Both languages execute the identical
sequence of exactly-rounded IEEE-754 double operations — the harness
deliberately avoids every transcendental libm call — so the bytes this
script writes are the bytes the Rust binary produces, on any host.

That property is what makes the goldens trustworthy in a container
without a Rust toolchain: the committed `rust/tests/golden/` files are
generated here and verified against the real binary by
rust/tests/repro_golden.rs and the repro-kick-tires CI job.

Usage:
  gen_golden.py             # write rust/tests/golden/{repro_kick_tires.json,REPORT.md}
  gen_golden.py --check     # recompute and diff against the committed goldens
  gen_golden.py --seed N    # use a non-default seed (debugging only)

See DESIGN.md §11 for the golden-refresh workflow.
"""

import argparse
import json
import math
import os
import sys
from fractions import Fraction

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
from render_report import fmt_num, jdump, lint, q4, render_markdown, rust_round  # noqa: E402

M64 = (1 << 64) - 1
GAMMA = 0x9E3779B97F4A7C15

DEFAULT_SEED = 7
TARGETS = [1.5, 2.0, 3.0]
ENVS = ["cpu-measured", "gpu-sweep", "edge"]
REGIMES = ["oneshot", "gradual"]
HEAD_LADDER = [4, 3, 2, 1, 0]
FFN_LADDER = [512, 384, 256, 192, 128, 64, 32, 0]

MODELS = [
    {"name": "bert-syn-base", "task": "sst2-syn", "n_layers": 4, "d_model": 128,
     "n_heads": 4, "d_head": 32, "d_ff": 512, "vocab": 2048, "seq": 64, "causal": False},
    {"name": "gpt-syn", "task": "corpus-syn", "n_layers": 4, "d_model": 128,
     "n_heads": 4, "d_head": 32, "d_ff": 512, "vocab": 2048, "seq": 128, "causal": True},
]

BERT_BASE_PAPER = {"d_model": 768, "n_heads": 12, "d_head": 64, "d_ff": 3072,
                   "vocab": 30522, "n_layers": 12, "batch": 128, "seq": 128}


def dims(m, batch):
    return {"d_model": m["d_model"], "n_heads": m["n_heads"], "d_head": m["d_head"],
            "d_ff": m["d_ff"], "vocab": m["vocab"], "n_layers": m["n_layers"],
            "batch": batch, "seq": m["seq"]}


def sub_seed(seed, idx):
    return (seed ^ (((idx + 1) * GAMMA) & M64)) & M64


# ------------------------------------------------- util::rng::Rng twin


def _rotl(x, k):
    return ((x << k) & M64) | (x >> (64 - k))


class Rng:
    """xoshiro256** with SplitMix64 seeding, as in rust/src/util/rng.rs
    (note: the constructor pre-advances x by one gamma, and each
    SplitMix step advances again, so s[0] derives from seed + 2*gamma)."""

    def __init__(self, seed):
        x = (seed + GAMMA) & M64
        s = []
        for _ in range(4):
            x = (x + GAMMA) & M64
            z = x
            z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & M64
            z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & M64
            s.append(z ^ (z >> 31))
        self.s = s

    def next_u64(self):
        s = self.s
        r = (_rotl((s[1] * 5) & M64, 7) * 9) & M64
        t = (s[1] << 17) & M64
        s[2] ^= s[0]
        s[3] ^= s[1]
        s[1] ^= s[2]
        s[0] ^= s[3]
        s[2] ^= t
        s[3] = _rotl(s[3], 45)
        return r

    def f64(self):
        return (self.next_u64() >> 11) * (1.0 / (1 << 53))

    def below(self, n):
        return (((self.next_u64() >> 32) * n) & M64) >> 32

    def weighted(self, weights):
        total = 0.0
        for w in weights:
            total += w
        t = self.f64() * total
        for i, w in enumerate(weights):
            t -= w
            if t <= 0.0:
                return i
        return len(weights) - 1


# -------------------------------------------- std::time::Duration twin


def dur_from_secs(t):
    """Duration::from_secs_f64: round the EXACT value to integer nanos,
    ties to even (Python's round on Fraction is banker's rounding)."""
    if t < 0.0:
        raise ValueError("negative duration")
    return round(Fraction(t) * 10**9)


def dur_secs(nanos):
    """Duration::as_secs_f64: secs as f64 + subsec nanos as f64 / 1e9."""
    secs, sub = divmod(nanos, 10**9)
    return float(secs) + float(sub) / 1e9


# ------------------------------------------- latency::LatencyTable twin


class Table:
    def __init__(self, model, device, regime, attn, mlp, overhead):
        self.model = model
        self.device = device
        self.regime = regime
        self.attn = attn
        self.mlp = mlp
        self.overhead = overhead

    def attn_time(self, heads):
        return self.attn[min(heads, len(self.attn) - 1)]

    def mlp_time(self, width):
        if width == 0:
            return 0.0
        upper = self.mlp[0]
        for (w, t) in self.mlp:
            if w >= width:
                upper = (w, t)
            if w <= width:
                lower = (w, t)
                if upper[0] == lower[0]:
                    return lower[1]
                frac = float(width - lower[0]) / float(upper[0] - lower[0])
                return lower[1] + frac * (upper[1] - lower[1])
        for (w, t) in reversed(self.mlp):
            if w > 0:
                return t * float(width) / float(w)
        raise ValueError("mlp table has no nonzero width")

    def model_time(self, profile):
        s = 0.0
        for (h, f) in profile:
            s += self.attn_time(h) + self.mlp_time(f)
        return self.overhead + s

    def dense_time(self, n_layers):
        dense_h = len(self.attn) - 1
        dense_f = self.mlp[0][0]
        return self.model_time([(dense_h, dense_f)] * n_layers)

    def speedup(self, profile):
        return self.dense_time(len(profile)) / self.model_time(profile)


# --------------------------------------- latency analytic roofline twin


def flops_attn_d(d, heads):
    a = heads * d["d_head"]
    toks = float(d["batch"] * d["seq"])
    return toks * (8.0 * d["d_model"] * a) + toks * (4.0 * d["seq"] * a)


def flops_mlp_d(d, width):
    return float(d["batch"] * d["seq"]) * 4.0 * d["d_model"] * width


def device_model(dev, dense_flops):
    """-> (peak_flops, t_fix, floor_frac), as latency::device_model."""
    if dev == "v100-sim":
        t_dense = 11.9e-3 * dense_flops / flops_mlp_d(BERT_BASE_PAPER, 3072)
        return (dense_flops / (t_dense * 0.951), t_dense * 0.049, 0.0)
    if dev == "a100-sim":
        t_dense = 4.1e-3 * dense_flops / flops_mlp_d(BERT_BASE_PAPER, 3072)
        return (dense_flops / (t_dense * 0.90), t_dense * 0.10, 1.0 / 4.4)
    return (5e9, 20e-6, 0.0)  # cpu-pjrt


def analytic(dev, d, regime, mlp_widths):
    dense_mlp = flops_mlp_d(d, d["d_ff"])
    peak, t_fix, floor_frac = device_model(dev, dense_mlp)

    def block_time(flops, dense):
        t = t_fix + flops / peak
        floor = floor_frac * (t_fix + dense / peak)
        return max(t, floor)

    dense_attn = flops_attn_d(d, d["n_heads"])
    attn = [0.0]
    for h in range(1, d["n_heads"] + 1):
        attn.append(block_time(flops_attn_d(d, h), dense_attn))
    mlp = [(w, block_time(flops_mlp_d(d, w), dense_mlp)) for w in mlp_widths if w > 0]
    mlp.sort(key=lambda p: p[0], reverse=True)
    mlp.append((0, 0.0))
    head_flops = float(d["batch"] * d["seq"]) * 2.0 * d["d_model"] * d["vocab"] * 0.25
    overhead = block_time(head_flops, dense_mlp)
    return Table("analytic-d%d" % d["d_model"], dev, regime, attn, mlp, overhead)


def analytic_seq_sweep(dev, d, seqs):
    peak, t_fix, _floor = device_model(dev, flops_mlp_d(d, d["d_ff"]))

    def layer_time(seq):
        ds = dict(d, seq=seq)

        def block(flops):
            return t_fix + flops / peak

        return block(flops_attn_d(ds, ds["n_heads"])) + block(flops_mlp_d(ds, ds["d_ff"]))

    anchor = layer_time(d["seq"])
    out = [(s, layer_time(s) / anchor) for s in seqs if s > 0]
    out.sort(key=lambda p: p[0])
    ded = []
    for p in out:
        if not ded or ded[-1][0] != p[0]:
            ded.append(p)
    return ded


# ------------------------------------------------ env::InferenceEnv twin


class Env:
    def __init__(self, table, batch, seq, sweep=()):
        self.table = table
        self.batch = batch
        self.seq = seq
        sw = [(s, sc) for (s, sc) in sweep if s > 0 and math.isfinite(sc) and sc > 0.0]
        sw.sort(key=lambda p: p[0])
        ded = []
        for p in sw:
            if not ded or ded[-1][0] != p[0]:
                ded.append(p)
        self.sweep = ded

    def seq_scale(self, seq):
        if seq == 0 or not self.sweep:
            return 1.0
        first = self.sweep[0]
        last = self.sweep[-1]
        if seq <= first[0]:
            return first[1]
        if seq >= last[0]:
            return last[1]
        for lo, hi in zip(self.sweep, self.sweep[1:]):
            if lo[0] <= seq <= hi[0]:
                frac = float(seq - lo[0]) / float(hi[0] - lo[0])
                return lo[1] + frac * (hi[1] - lo[1])
        return 1.0

    def model_time(self, profile):
        return self.table.model_time(profile)

    def dense_time(self, n_layers):
        return self.table.dense_time(n_layers)

    def speedup(self, profile):
        return self.table.speedup(profile)

    def batch_time(self, profile, batch, seq):
        if self.batch > 0 and batch > 0:
            batch_factor = float(batch) / float(self.batch)
        else:
            batch_factor = 1.0
        return self.model_time(profile) * batch_factor * self.seq_scale(seq)

    def bucket_ladder(self):
        if self.sweep:
            b = max(self.batch, 1)
            return [(b, s) for (s, _) in self.sweep]
        if self.batch > 0 and self.seq > 0:
            return [(self.batch, self.seq)]
        return []

    def batch_shape(self):
        return (self.batch, self.seq)


# ------------------------------------------------- spdy::solve_dp twin

BUCKETS = 768


class Problem:
    """modules: list of (layer, is_attn, options); options: list of
    (remaining, cost, prior)."""

    def __init__(self, modules, overhead):
        self.modules = modules
        self.overhead = overhead

    def dense_cost(self):
        s = 0.0
        for (_layer, _is_attn, options) in self.modules:
            s += options[0][1]
        return self.overhead + s

    def profile_cost(self, profile):
        s = 0.0
        for (_layer, _is_attn, options), l in zip(self.modules, profile):
            s += options[l][1]
        return self.overhead + s

    def as_layer_profile(self, profile):
        n_layers = max(layer for (layer, _, _) in self.modules) + 1
        out = [[0, 0] for _ in range(n_layers)]
        for (layer, is_attn, options), l in zip(self.modules, profile):
            rem = options[l][0]
            if is_attn:
                out[layer][0] = rem
            else:
                out[layer][1] = rem
        return [tuple(p) for p in out]


def solve_dp(problem, budget):
    """spdy::solve_dp with unit coefficients (coeffs = &[])."""
    avail = budget - problem.overhead
    if avail <= 0.0:
        return None
    unit = avail / float(BUCKETS)
    nm = len(problem.modules)
    inf = math.inf
    dp = [inf] * (BUCKETS + 1)
    dp[0] = 0.0
    look_left = -1
    choice = [[look_left] * (BUCKETS + 1) for _ in range(nm)]
    for mi, (_layer, _is_attn, options) in enumerate(problem.modules):
        nxt = [inf] * (BUCKETS + 1)
        c = 1.0
        for li, (_rem, opt_cost, prior) in enumerate(options):
            w = math.ceil(opt_cost / unit)
            cost = c * prior * prior
            if w > BUCKETS:
                continue
            for b in range(w, BUCKETS + 1):
                base = dp[b - w]
                if math.isfinite(base) and base + cost < nxt[b]:
                    nxt[b] = base + cost
                    choice[mi][b] = li
        dp = nxt
        for b in range(1, BUCKETS + 1):
            if dp[b - 1] < dp[b]:
                dp[b] = dp[b - 1]
                choice[mi][b] = look_left
    if not math.isfinite(dp[BUCKETS]):
        return None
    profile = [0] * nm
    b = BUCKETS
    for mi in range(nm - 1, -1, -1):
        while choice[mi][b] == look_left:
            if b == 0:
                return None
            b -= 1
        li = choice[mi][b]
        profile[mi] = li
        unit_w = math.ceil(problem.modules[mi][2][li][1] / unit)
        b -= min(unit_w, b)
    return profile


# ------------------------------------- coordinator routing/replay twins


class BucketLadder:
    def __init__(self, buckets):
        bs = [(b, s) for (b, s) in buckets if b > 0 and s > 0]
        bs.sort(key=lambda p: (p[1], p[0]))
        ded = []
        for p in bs:
            if not ded or ded[-1] != p:
                ded.append(p)
        self.buckets = ded

    def bucket_for(self, batch, seq):
        for (b, s) in self.buckets:
            if b >= batch and s >= seq:
                return (b, s)
        return None


class MemberRoute:
    def __init__(self, tag, est_speedup, est_batch_time, bucket_times):
        self.tag = tag
        self.est_speedup = est_speedup
        self.est_batch_time = est_batch_time
        self.bucket_times = bucket_times

    def time_at(self, bucket):
        if bucket is not None:
            for (b, t) in self.bucket_times:
                if b == bucket:
                    return t
        return self.est_batch_time


def _div_ceil(a, b):
    return (a + b - 1) // b


def route(sla, members, depths, max_batch, pressure):
    fastest = len(members) - 1
    if pressure > 0 and sum(depths) >= pressure:
        return fastest
    if sla is None:
        return 0
    b = max(max_batch, 1)
    pending = 0.0
    for mem, d in zip(members, depths):
        pending += float(_div_ceil(d, b)) * mem.est_batch_time
    for i, (mem, depth) in enumerate(zip(members, depths)):
        ms = sla["min_speedup"]
        if ms is not None and mem.est_speedup + 1e-9 < ms:
            continue
        ml = sla["max_latency"]
        if ml is not None:
            marginal = float(_div_ceil(depth + 1, b) - _div_ceil(depth, b)) * mem.est_batch_time
            if pending + marginal > dur_secs(ml):
                continue
        return i
    return fastest


def route_batch(reqs, members, depths, ladder, max_batch, pressure):
    """reqs: list of (sla, len, waited_nanos) -> (member, bucket) or None."""
    if not reqs or len(reqs) > max(max_batch, 1):
        return None
    max_len = max(ln for (_sla, ln, _w) in reqs)
    bucket = ladder.bucket_for(len(reqs), max_len)
    if len(reqs) == 1:
        return (route(reqs[0][0], members, depths, max_batch, pressure), bucket)
    fastest = len(members) - 1
    if pressure > 0 and sum(depths) + len(reqs) >= pressure:
        return (fastest, bucket)
    b = max(max_batch, 1)
    pending = 0.0
    for mem, d in zip(members, depths):
        pending += float(_div_ceil(d, b)) * mem.est_batch_time
    for i, mem in enumerate(members):
        texec = mem.time_at(bucket)
        ok = True
        for (sla, _ln, waited) in reqs:
            if sla is None:
                continue
            ms = sla["min_speedup"]
            if ms is not None and mem.est_speedup + 1e-9 < ms:
                ok = False
                break
            ml = sla["max_latency"]
            if ml is not None:
                remaining = dur_secs(max(ml - waited, 0))
                if pending + texec > remaining:
                    ok = False
                    break
        if ok:
            return (i, bucket)
    return None


def percentile(sorted_vals, q):
    if not sorted_vals:
        return 0.0
    idx = rust_round(float(len(sorted_vals) - 1) * q)
    return sorted_vals[min(idx, len(sorted_vals) - 1)]


def aggregate_buckets(samples):
    by = {}
    for (tag, batch, seq, spec, exec_nanos, requests, certified) in samples:
        e = by.setdefault((tag, batch, seq, spec), [[], 0, certified])
        e[0].append(dur_secs(exec_nanos))
        e[1] += requests
    out = []
    for key in sorted(by):
        execs, requests, certified = by[key]
        execs.sort()
        out.append({
            "member": key[0], "batch": key[1], "seq": key[2], "specialized": key[3],
            "batches": len(execs), "requests": requests,
            "p50_nanos": dur_from_secs(percentile(execs, 0.50)),
            "p99_nanos": dur_from_secs(percentile(execs, 0.99)),
            "cert_nanos": dur_from_secs(certified),
        })
    return out


def _replay_sample(member, bucket, requests, jitter, fallback, rng):
    certified = member.time_at(bucket)
    factor = 1.0 - jitter + 2.0 * jitter * rng.f64()
    batch, seq = bucket if bucket is not None else fallback
    return (member.tag, batch, seq, bucket is not None,
            dur_from_secs(certified * factor), requests, certified)


def replay_samples(trace, members, ladder, max_batch, jitter, seed, fallback):
    """coordinator::replay::replay_samples; trace items are (len, sla).
    Samples are (tag, batch, seq, spec, exec_nanos, requests, certified)."""
    if not members:
        return []
    rng = Rng((seed ^ 0x71) & M64)
    depths = [0] * len(members)
    samples = []
    step = max(max_batch, 1)
    for i in range(0, len(trace), step):
        chunk = trace[i:i + step]
        reqs = [(sla, ln, 0) for (ln, sla) in chunk]
        r = route_batch(reqs, members, depths, ladder, max_batch, 0)
        if r is not None:
            samples.append(_replay_sample(members[r[0]], r[1], len(chunk), jitter, fallback, rng))
        else:
            for (ln, sla) in chunk:
                mi = route(sla, members, depths, max_batch, 0)
                bucket = ladder.bucket_for(1, ln)
                samples.append(_replay_sample(members[mi], bucket, 1, jitter, fallback, rng))
    return samples


def replay(trace, members, ladder, max_batch, jitter, seed, fallback):
    """coordinator::replay::replay = aggregated replay_samples."""
    return aggregate_buckets(replay_samples(trace, members, ladder, max_batch, jitter,
                                            seed, fallback))


# --------------------------------------------------- adapt module twins


def sample_ratio(s):
    """adapt::sample_ratio on a replay sample tuple."""
    return dur_secs(s[4]) / s[6] if s[6] > 0.0 else 1.0


def detect_drift(samples, env, latency_tol=0.1, mass_tol=0.25, min_requests=16):
    """adapt::detect_drift under DriftCfg::default (summary stats only;
    the per-bucket rows never land in the repro report)."""
    ab, aseq = env.batch_shape()
    total = sum(s[5] for s in samples)
    if total == 0:
        return {"requests": 0, "latency_drift": 0.0, "mass_shift": 0.0,
                "overrun_rate": 0.0, "drifted": False}
    latency_drift = 0.0
    mass_shift = 0.0
    overrun = 0.0
    for s in samples:
        w = float(s[5]) / float(total)
        ratio = sample_ratio(s)
        latency_drift += w * abs(ratio - 1.0)
        if dur_secs(s[4]) > s[6]:
            overrun += w
        ds = abs(float(s[2]) - float(aseq)) / float(aseq) if aseq > 0 else 0.0
        db = abs(float(s[1]) - float(ab)) / float(ab) if ab > 0 else 0.0
        mass_shift += w * 0.5 * (ds + db)
    drifted = total >= min_requests and (latency_drift > latency_tol or mass_shift > mass_tol)
    return {"requests": total, "latency_drift": latency_drift, "mass_shift": mass_shift,
            "overrun_rate": overrun, "drifted": drifted}


def fit_env(samples, base):
    """adapt::fit_env: re-anchor and re-price `base` onto the observed
    traffic (with_device_skew . with_batch_shape . with_seq_sweep)."""
    total = sum(s[5] for s in samples)
    if total == 0:
        raise ValueError("fit_env needs at least one recorded request")
    mean_b = 0.0
    mean_s = 0.0
    ratio = 0.0
    for s in samples:
        w = float(s[5]) / float(total)
        mean_b += w * float(s[1])
        mean_s += w * float(s[2])
        ratio += w * sample_ratio(s)
    b_star = max(int(rust_round(mean_b)), 1)
    s_star = max(int(rust_round(mean_s)), 1)
    b0, _seq0 = base.batch_shape()
    batch_factor = float(b_star) / float(b0) if b0 > 0 else 1.0
    anchor_scale = base.seq_scale(s_star)
    skew = ratio * batch_factor * anchor_scale
    seqs = sorted({s[2] for s in samples if s[2] > 0})
    sweep = [(s, base.seq_scale(s) / anchor_scale) for s in seqs]
    t = base.table
    if math.isfinite(skew) and skew > 0.0 and skew != 1.0:
        table = Table(t.model, t.device, t.regime,
                      [a * skew for a in t.attn],
                      [(w, tt * skew) for (w, tt) in t.mlp],
                      t.overhead * skew)
    else:
        table = Table(t.model, t.device, t.regime, list(t.attn), list(t.mlp), t.overhead)
    return Env(table, b_star, s_star, sweep)


def loss_proxy(est):
    return 1.0 - 1.0 / est if est > 0.0 else 0.0


def frontier_points(members):
    """adapt::frontier_points on (tag, est_speedup, calib_loss|None)."""
    pts = []
    for (tag, est, loss) in members:
        y = loss if (loss is not None and math.isfinite(loss)) else loss_proxy(est)
        if math.isfinite(est) and math.isfinite(y):
            pts.append((est, y, tag))
    pts.sort(key=lambda p: (p[0], p[1], p[2]))
    kept = []
    best = math.inf
    for p in reversed(pts):
        if p[1] < best:
            best = p[1]
            kept.append(p)
    kept.reverse()
    return kept


def knee_point(frontier):
    """adapt::knee_point (speedup, loss, tag) triples -> speedup|None."""
    if len(frontier) < 3:
        return None
    a = frontier[0]
    b = frontier[-1]
    dx = b[0] - a[0]
    dy = b[1] - a[1]
    if dx <= 0.0:
        return None
    sy = dy if dy != 0.0 else 1.0
    best = 0.0
    at = None
    for p in frontier[1:-1]:
        px = (p[0] - a[0]) / dx
        py = (p[1] - a[1]) / sy
        d = abs(px * (dy / sy) - py)
        if d > best:
            best = d
            at = p[0]
    return at if at is not None else frontier[len(frontier) // 2][0]


def propose_targets(frontier, n):
    """adapt::propose_targets: knee + equal-loss-spaced picks."""
    if not frontier or n == 0:
        return []
    y0 = frontier[0][1]
    y1 = frontier[-1][1]
    out = []
    k = knee_point(frontier)
    if k is not None:
        out.append(k)
    for i in range(1, n + 1):
        want = y0 + (y1 - y0) * i / n
        pick = frontier[0][0]
        for p in frontier:
            if p[1] <= want + 1e-12:
                pick = p[0]
        out.append(pick)
    out.sort()
    ded = []
    for t in out:
        if not ded or ded[-1] != t:
            ded.append(t)
    return ded


def gen_trace(requests, seed, len_range, classes):
    """coordinator::chaos::gen_trace (ids are drawn to keep the rng
    stream aligned; only their count matters to the replay)."""
    rng = Rng((seed ^ 0x7ACE0F10AD) & M64)
    lo, hi = len_range
    lo = max(lo, 1)
    hi = max(hi, lo)
    weights = [max(c["weight"], 0.0) for c in classes]
    any_weight = any(w > 0.0 for w in weights)
    out = []
    for _ in range(requests):
        ln = lo + rng.below(hi - lo + 1)
        for _ in range(ln):
            rng.below(30000)
        if any_weight:
            c = classes[rng.weighted(weights)]
            sla = {"class": c["class"], "max_latency": c["max_latency"],
                   "min_speedup": c["min_speedup"]}
        else:
            sla = None
        out.append((ln, sla))
    return out


# ------------------------------------------------ repro.rs matrix twin


def kick_env(m, env_name, precomputed):
    if env_name == "cpu-measured":
        path = os.path.join(precomputed, "latency_%s_throughput.json" % m["name"])
        with open(path, encoding="utf-8") as fh:
            d = json.load(fh)
        table = Table(d["model"], d["device"], d["regime"],
                      [float(x) for x in d["attn"]],
                      [(int(w), float(t)) for (w, t) in d["mlp"]],
                      float(d["overhead"]))
        return Env(table, 8, m["seq"]), "cached"
    if env_name == "gpu-sweep":
        d32 = dims(m, 32)
        table = analytic("v100-sim", d32, "throughput", FFN_LADDER)
        sweep = analytic_seq_sweep("v100-sim", d32, [m["seq"] // 4, m["seq"] // 2, m["seq"]])
        return Env(table, 32, m["seq"], sweep), "ran"
    if env_name == "edge":
        return Env(analytic("cpu-pjrt", dims(m, 1), "latency", FFN_LADDER), 1, m["seq"]), "ran"
    raise ValueError("unknown env axis %r" % env_name)


def sensitivity_weights(seed, model_idx, n_modules):
    rng = Rng(sub_seed(seed, model_idx))
    return [0.55 + 0.45 * rng.f64() for _ in range(n_modules)]


def build_problem(m, env, weights):
    table = env.table
    modules = []
    for layer in range(m["n_layers"]):
        wa = weights[layer * 2]
        modules.append((layer, True,
                        [(h, table.attn_time(h), (1.0 - h / m["n_heads"]) * wa)
                         for h in HEAD_LADDER]))
        wm = weights[layer * 2 + 1]
        modules.append((layer, False,
                        [(w, table.mlp_time(w), (1.0 - w / m["d_ff"]) * wm)
                         for w in FFN_LADDER]))
    return Problem(modules, table.overhead)


def proxy_error(problem, sol):
    e = 0.0
    for (_layer, _is_attn, options), l in zip(problem.modules, sol):
        p = options[l][2]
        e += p * p
    return e


def success_cell(m, regime, env_name, target, status, problem, sol, dense):
    return {
        "model": m["name"], "regime": regime, "env": env_name, "target": target,
        "status": status,
        "certified": q4(dense / problem.profile_cost(sol)),
        "proxy_error": q4(proxy_error(problem, sol)),
        "profile": [[h, f] for (h, f) in problem.as_layer_profile(sol)],
    }


def error_cell(m, regime, env_name, target, msg):
    return {"model": m["name"], "regime": regime, "env": env_name, "target": target,
            "status": "error", "error": msg}


def solve_env(m, env_name, status, problem):
    dense = problem.dense_cost()
    cells = []
    for t in TARGETS:
        sol = solve_dp(problem, dense / t)
        if sol is not None:
            cells.append(success_cell(m, "oneshot", env_name, t, status, problem, sol, dense))
        else:
            cells.append(error_cell(m, "oneshot", env_name, t,
                                    "infeasible: target exceeds the env's achievable speedup"))
    gradual = []
    prev = [0] * len(problem.modules)
    for t in TARGETS:
        restricted = Problem(
            [(layer, is_attn, options[p:])
             for (layer, is_attn, options), p in zip(problem.modules, prev)],
            problem.overhead,
        )
        rel = solve_dp(restricted, dense / t)
        if rel is not None:
            sol = [p + l for l, p in zip(rel, prev)]
            prev = list(sol)
            cells.append(success_cell(m, "gradual", env_name, t, status, problem, sol, dense))
            gradual.append(problem.as_layer_profile(sol))
        else:
            cells.append(error_cell(
                m, "gradual", env_name, t,
                "infeasible: stage budget below the reachable cost from the previous stage"))
            gradual.append(None)
    return cells, gradual


def trace_classes(m, env, fastest):
    """repro.rs::trace_classes — the three-class SLA mix."""
    return [
        {"class": "best-effort", "weight": 2.0, "max_latency": None, "min_speedup": None},
        {"class": "realtime", "weight": 1.0,
         "max_latency": dur_from_secs(env.dense_time(m["n_layers"]) * 0.8),
         "min_speedup": None},
        {"class": "throughput", "weight": 1.0, "max_latency": None,
         "min_speedup": min(fastest, 2.0)},
    ]


def family_block(m, block_idx, env_name, env, gradual, seed):
    """-> (block dict, serving dict with the routes/ladder reused by
    the adapt loop), mirroring repro.rs::family_block."""
    dense_profile = [(m["n_heads"], m["d_ff"])] * m["n_layers"]
    built = [{"tag": "dense", "est": env.speedup(dense_profile), "profile": dense_profile}]
    for k, stage in enumerate(gradual):
        if stage is not None:
            built.append({"tag": fmt_num(TARGETS[k]) + "x", "est": env.speedup(stage),
                          "profile": stage})
    built.sort(key=lambda mb: mb["est"])

    ladder = BucketLadder(env.bucket_ladder())
    bucket_list = list(ladder.buckets)
    routes = [
        MemberRoute(mb["tag"], mb["est"], env.model_time(mb["profile"]),
                    [((b, s), env.batch_time(mb["profile"], b, s)) for (b, s) in bucket_list])
        for mb in built
    ]

    block_seed = sub_seed(seed, 0x100 + block_idx)
    fastest = 1.0
    for mb in built:
        fastest = max(fastest, mb["est"])
    trace = gen_trace(48, block_seed, (4, 32), trace_classes(m, env, fastest))
    stats = replay(trace, routes, ladder, 4, 0.1, block_seed, env.batch_shape())

    per_bucket = []
    for s in stats:
        cert = dur_secs(s["cert_nanos"])
        p50 = dur_secs(s["p50_nanos"])
        p99 = dur_secs(s["p99_nanos"])
        per_bucket.append({
            "member": s["member"], "batch": s["batch"], "seq": s["seq"],
            "specialized": s["specialized"], "batches": s["batches"],
            "requests": s["requests"],
            "certified_ms": q4(cert * 1e3),
            "realized_p50_ms": q4(p50 * 1e3),
            "realized_p99_ms": q4(p99 * 1e3),
            "gap": q4(p50 / cert) if cert > 0.0 else 0.0,
        })

    # the Rust harness runs a real threaded fault-injection campaign
    # here; only its scheduling-independent ledger facts land in the
    # report, and those are invariants of run_chaos: every one of the
    # 48 submitted requests gets exactly one terminal outcome.
    chaos = {"submitted": 48, "lost": 0, "balanced": True}

    block = {
        "model": m["name"], "env": env_name,
        "members": [{"tag": mb["tag"], "est_speedup": q4(mb["est"]),
                     "est_batch_time_ms": q4(env.model_time(mb["profile"]) * 1e3)}
                    for mb in built],
        "buckets": [[b, s] for (b, s) in bucket_list],
        "per_bucket": per_bucket,
        "chaos": chaos,
    }
    return block, {"routes": routes, "ladder": ladder}


def kick_members(routes, cells):
    """repro.rs::kick_manifest's member list: (tag, est, loss|None),
    losses from the gradual cells' proxy errors, dense anchored at 0."""
    members = []
    for r in routes:
        if r.tag == "dense":
            loss = 0.0
        else:
            loss = None
            for c in cells:
                if (c["regime"] == "gradual" and c["status"] != "error"
                        and fmt_num(c["target"]) + "x" == r.tag):
                    loss = c["proxy_error"]
                    break
        members.append((r.tag, r.est_speedup, loss))
    return members


def adapt_block(m, block_idx, env_name, env, serving, members, seed):
    """repro.rs::adapt_block: drifted replay -> detect -> fit -> frontier."""
    drift_seed = sub_seed(seed, 0x300 + block_idx)
    routes = serving["routes"]
    fastest = 1.0
    for r in routes:
        fastest = max(fastest, r.est_speedup)
    trace = gen_trace(48, drift_seed, (4, max(m["seq"] // 4, 5)),
                      trace_classes(m, env, fastest))
    samples = replay_samples(trace, routes, serving["ladder"], 4, 0.1, drift_seed,
                             env.batch_shape())
    drift = detect_drift(samples, env)
    fitted = fit_env(samples, env)
    base_dense = env.dense_time(m["n_layers"])
    skew = fitted.dense_time(m["n_layers"]) / base_dense if base_dense > 0.0 else 0.0
    frontier = frontier_points(members)
    knee = knee_point(frontier)
    targets = [q4(t) for t in propose_targets(frontier, len(TARGETS))]
    ded = []
    for t in targets:
        if not ded or ded[-1] != t:
            ded.append(t)
    fb, fs = fitted.batch_shape()
    return {
        "model": m["name"], "env": env_name,
        "requests": drift["requests"],
        "latency_drift": q4(drift["latency_drift"]),
        "mass_shift": q4(drift["mass_shift"]),
        "overrun_rate": q4(drift["overrun_rate"]),
        "drifted": drift["drifted"],
        "fitted": {"batch": fb, "seq": fs, "skew": q4(skew),
                   "sweep": [[s, q4(sc)] for (s, sc) in fitted.sweep]},
        "knee": q4(knee) if knee is not None else 0.0,
        "targets": ded,
    }


# --------------------------------------------- compound lattice twins

LOWRANK_RANKS = [96, 64, 32]


def low_rank_ffn_width(d_model, width, rank):
    """latency::low_rank_ffn_width: equal-GEMM-work width of a rank-r
    FFN factorization (integer ceil-div, clamped at dense)."""
    return min(-(-(rank * (d_model + width)) // d_model), width)


def axis_counts(axes_seq):
    """compress::CompressionProfile::axis_counts (BTreeMap order)."""
    counts = {}
    for a in axes_seq:
        counts[a] = counts.get(a, 0) + 1
    return sorted(counts.items())


def mix_string(axes_seq):
    return " ".join("%s=%d" % (a, n) for (a, n) in axis_counts(axes_seq))


def compound_choices(m, env, base, weights):
    """repro.rs::compound_choices: widen the SPDY instance into the
    typed lattice.  Returns (layer, is_attn, choices) triples with
    choices = [(axis, cost, loss), ...] — the prune prefix carries the
    base (cost, prior) f64s verbatim, then int8 entries at the
    exact-binary cost/2.5 engine factor (loss = prior + w/64), then
    low-rank FFN entries at equal-GEMM-work widths
    (loss = (1 − rank/d_model)·w).  Positional layout matches Problem
    options ([1] = cost, [2] = loss) so solve_dp runs unchanged."""
    table = env.table
    out = []
    for (layer, is_attn, options) in base.modules:
        w = weights[layer * 2 + (0 if is_attn else 1)]
        choices = [("prune", cost, prior) for (_rem, cost, prior) in options]
        for li, (rem, _cost, prior) in enumerate(options):
            if rem == 0:
                continue  # a dropped module has nothing to quantize
            cost = (table.attn_time(rem) if is_attn else table.mlp_time(rem)) / 2.5
            choices.append(("quant" if li == 0 else "prune+quant", cost, prior + w / 64.0))
        if not is_attn:
            for rank in LOWRANK_RANKS:
                w_eff = low_rank_ffn_width(m["d_model"], m["d_ff"], rank)
                if w_eff >= m["d_ff"]:
                    continue  # prices no cheaper than dense
                choices.append(("lowrank", table.mlp_time(w_eff),
                                (1.0 - rank / m["d_model"]) * w))
        out.append((layer, is_attn, choices))
    return out


def compound_block(m, model_idx, seed, precomputed):
    """repro.rs::compound_block: the widened lattice on the gpu-sweep
    env at one 2x target — dense / per-axis restrictions / the full
    mixed solve, with the prune-only restriction checked against the
    legacy DP (lift + lower reproduce the base numbers verbatim, so
    the lifted solve is literally a second identical solve here)."""
    env_name = "gpu-sweep"
    env, _status = kick_env(m, env_name, precomputed)
    weights = sensitivity_weights(seed, model_idx, m["n_layers"] * 2)
    base = build_problem(m, env, weights)
    choice_sets = compound_choices(m, env, base, weights)
    problem = Problem(choice_sets, base.overhead)
    # 2.5x sits past the all-int8 point (compute/2.5 still pays the
    # dense overhead), so the solver is forced to genuinely mix axes
    target = 2.5
    dense = base.dense_cost()
    budget = dense / target

    legacy_sol = solve_dp(base, budget)
    if legacy_sol is None:
        raise ValueError("legacy DP infeasible at %sx" % target)
    lifted_sol = solve_dp(base, budget)
    if lifted_sol is None:
        raise ValueError("lifted prune-only DP infeasible at %sx" % target)
    prune_equiv = legacy_sol == lifted_sol

    dense_prof = [0] * len(choice_sets)
    quant_prof = []
    lowrank_prof = []
    for (_layer, _is_attn, ch) in choice_sets:
        quant_prof.append(next((i for i, c in enumerate(ch) if c[0] == "quant"), 0))
        lr = [i for i, c in enumerate(ch) if c[0] == "lowrank"]
        lowrank_prof.append(lr[len(lr) // 2] if lr else 0)
    mixed_sol = solve_dp(problem, budget)
    if mixed_sol is None:
        raise ValueError("widened DP infeasible at %sx" % target)

    def member(tag, prof):
        ax = [choice_sets[mi][2][ci][0] for mi, ci in enumerate(prof)]
        return {"tag": tag, "axis": mix_string(ax),
                "certified": q4(dense / problem.profile_cost(prof)),
                "loss": q4(proxy_error(problem, prof))}

    members = [
        member("dense", dense_prof),
        member("prune", lifted_sol),
        member("int8", quant_prof),
        member("lowrank", lowrank_prof),
        member("compound", mixed_sol),
    ]
    mixed_axes = [choice_sets[mi][2][ci][0] for mi, ci in enumerate(mixed_sol)]
    return {"model": m["name"], "env": env_name, "target": target,
            "prune_equiv": prune_equiv, "members": members,
            "axes": [[a, n] for (a, n) in axis_counts(mixed_axes)]}


def compound_blocks(seed, precomputed):
    return [compound_block(m, mi, seed, precomputed) for mi, m in enumerate(MODELS)]


def run_kick_tires(seed, precomputed):
    cells, families, adapt = [], [], []
    for mi, m in enumerate(MODELS):
        weights = sensitivity_weights(seed, mi, m["n_layers"] * 2)
        for ei, env_name in enumerate(ENVS):
            env, status = kick_env(m, env_name, precomputed)
            problem = build_problem(m, env, weights)
            env_cells, gradual = solve_env(m, env_name, status, problem)
            fi = mi * len(ENVS) + ei
            block, serving = family_block(m, fi, env_name, env, gradual, seed)
            if env_name == "gpu-sweep":
                members = kick_members(serving["routes"], env_cells)
                adapt.append(adapt_block(m, fi, env_name, env, serving, members, seed))
            cells.extend(env_cells)
            families.append(block)
    return {"version": 1, "mode": "kick-tires", "seed": seed, "cells": cells,
            "families": families, "adapt": adapt,
            "compound": compound_blocks(seed, precomputed)}


# ----------------------------------------------------------------- main


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--check", action="store_true",
                    help="recompute and diff against the committed goldens")
    ap.add_argument("--seed", type=int, default=DEFAULT_SEED)
    args = ap.parse_args(argv)

    root = os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    precomputed = os.path.join(root, "tools", "repro", "precomputed")
    golden = os.path.join(root, "rust", "tests", "golden")

    report = run_kick_tires(args.seed, precomputed)
    probs = lint(report)
    if probs:
        for p in probs:
            print("LINT: %s" % p, file=sys.stderr)
        return 1

    statuses = [c["status"] for c in report["cells"]]
    print("gen_golden: %d cells (%d ran, %d cached, %d error), %d families, "
          "%d compound sections"
          % (len(statuses), statuses.count("ran"), statuses.count("cached"),
             statuses.count("error"), len(report["families"]),
             len(report["compound"])))

    json_text = jdump(report) + "\n"
    md_text = render_markdown(report)
    targets = [(os.path.join(golden, "repro_kick_tires.json"), json_text),
               (os.path.join(golden, "REPORT.md"), md_text)]
    if args.check:
        bad = 0
        for path, want in targets:
            try:
                with open(path, encoding="utf-8") as fh:
                    have = fh.read()
            except OSError as e:
                print("CHECK: cannot read %s: %s" % (path, e), file=sys.stderr)
                bad += 1
                continue
            if have != want:
                for n, (h, w) in enumerate(zip(have.splitlines(), want.splitlines()), 1):
                    if h != w:
                        print("CHECK: %s line %d differs:" % (path, n), file=sys.stderr)
                        print("  committed:    %s" % h, file=sys.stderr)
                        print("  recomputed:   %s" % w, file=sys.stderr)
                        break
                else:
                    print("CHECK: %s differs in length" % path, file=sys.stderr)
                bad += 1
            else:
                print("gen_golden: %s is up to date" % os.path.relpath(path, root))
        return 1 if bad else 0

    os.makedirs(golden, exist_ok=True)
    for path, text in targets:
        with open(path, "w", encoding="utf-8") as fh:
            fh.write(text)
        print("gen_golden: wrote %s" % os.path.relpath(path, root))
    return 0


if __name__ == "__main__":
    sys.exit(main())
