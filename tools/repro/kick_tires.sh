#!/usr/bin/env bash
# Engine-free kick-tires gate: run the reproduction matrix subset and
# fail if its report drifts from the committed goldens by a single byte.
#
# Three independent checks, strongest first:
#   1. gen_golden.py --check — the Python transliteration still
#      reproduces the committed rust/tests/golden/ files (catches a
#      golden edited by hand, or a stale golden after a harness change).
#   2. `ziplm repro --kick-tires` — the real binary over the same
#      matrix, byte-diffed against the same goldens (catches Rust-side
#      drift: the whole point of the gate).
#   3. render_report.py lint + --check-md — schema totality (every
#      matrix cell present exactly once, never silently dropped) and an
#      independent re-render of REPORT.md from the JSON.
#
# No engine, no network, no GPU: every cell is either computed from the
# analytic roofline or loaded from tools/repro/precomputed (`cached`).
# See DESIGN.md §11.
set -euo pipefail
cd "$(dirname "$0")/../.."

out="${1:-runs/repro-kick-tires}"

echo "== [1/3] transliteration self-check =="
python3 tools/repro/gen_golden.py --check

echo "== [2/3] ziplm repro --kick-tires =="
cargo run --release --locked --manifest-path rust/Cargo.toml -- \
  repro --kick-tires --out "$out" --precomputed tools/repro/precomputed
diff -u rust/tests/golden/repro_kick_tires.json "$out/repro_report.json"
diff -u rust/tests/golden/REPORT.md "$out/REPORT.md"
echo "binary output matches committed goldens byte-for-byte"

echo "== [3/3] report lint + independent re-render =="
python3 tools/repro/render_report.py "$out/repro_report.json" --check-md "$out/REPORT.md"

echo "Done! kick-tires report verified against goldens ($out/REPORT.md)"
