#!/usr/bin/env python3
"""Doc link/anchor checker for the ziplm repo (CI: `doc-links` step).

Stdlib-only, in the spirit of rust/benches/mirror/check_regression.py:
a small, dependency-free gate that keeps prose and code honest.

Checks, over README.md / DESIGN.md / ROADMAP.md / CHANGES.md and the
rustdoc comments under rust/src + examples:

1. every relative markdown link `[text](path)` resolves to a file or
   directory in the repo (absolute URLs are skipped);
2. every `#anchor` used in a markdown link matches a real heading of
   the target document (GitHub slugification);
3. every `DESIGN.md §N` / standalone `§N` section reference — in the
   markdown AND in rustdoc comments — names a section DESIGN.md
   actually has, so doc comments can't cite sections that were never
   written (or got renumbered away).

Extra markdown files (e.g. generated reports like
rust/tests/golden/REPORT.md) can be passed as argv paths; they get the
same link/anchor/§N checks as the core set.

Exit code 0 = clean, 1 = problems (each printed as `file: problem`).
"""

import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
MARKDOWN = ["README.md", "DESIGN.md", "ROADMAP.md", "CHANGES.md", "PAPER.md"]
RUST_DIRS = [REPO / "rust" / "src", REPO / "examples"]

LINK_RE = re.compile(r"\[[^\]^\[]*\]\(([^)\s]+)\)")
SECTION_REF_RE = re.compile(r"§(\d+)")
HEADING_RE = re.compile(r"^(#{1,6})\s+(.*)$")


def github_slug(heading: str) -> str:
    """GitHub's markdown heading → anchor slug (close enough for ours)."""
    slug = heading.strip().lower()
    slug = re.sub(r"[^\w\s§\-]", "", slug, flags=re.UNICODE)
    slug = slug.replace("§", "")
    slug = re.sub(r"\s+", "-", slug.strip())
    return slug


def headings_of(path: Path) -> set:
    slugs = set()
    in_code = False
    for line in path.read_text(encoding="utf-8").splitlines():
        if line.strip().startswith("```"):
            in_code = not in_code
            continue
        if in_code:
            continue
        m = HEADING_RE.match(line)
        if m:
            slugs.add(github_slug(m.group(2)))
    return slugs


def design_sections() -> set:
    """The §N numbers DESIGN.md actually defines (## §N … headings)."""
    out = set()
    for line in (REPO / "DESIGN.md").read_text(encoding="utf-8").splitlines():
        m = re.match(r"^##\s+§(\d+)\b", line)
        if m:
            out.add(int(m.group(1)))
    return out


def strip_code(md_text: str) -> str:
    """Drop fenced code blocks and inline code spans before scanning."""
    out, in_code = [], False
    for line in md_text.splitlines():
        if line.strip().startswith("```"):
            in_code = not in_code
            continue
        if not in_code:
            out.append(re.sub(r"`[^`]*`", "", line))
    return "\n".join(out)


def check_markdown(problems: list, extra: list) -> None:
    sections = design_sections()
    # core files may legitimately be absent (fresh checkout); an extra
    # path was requested explicitly, so a missing one is a failure
    paths = [(REPO / name, False) for name in MARKDOWN]
    paths += [(Path(e).resolve(), True) for e in extra]
    for path, required in paths:
        name = str(path.relative_to(REPO)) if path.is_relative_to(REPO) else str(path)
        if not path.exists():
            if required:
                problems.append(f"{name}: file does not exist")
            continue
        text = strip_code(path.read_text(encoding="utf-8"))
        for target in LINK_RE.findall(text):
            if re.match(r"^[a-z][a-z0-9+.-]*:", target):  # http:, mailto:, …
                continue
            frag = None
            if "#" in target:
                target, frag = target.split("#", 1)
            if target:
                dest = (path.parent / target).resolve()
                if not dest.exists():
                    problems.append(f"{name}: broken link target `{target}`")
                    continue
            else:
                dest = path
            if frag is not None and dest.suffix == ".md" and dest.is_file():
                if github_slug(frag) not in headings_of(dest):
                    problems.append(f"{name}: broken anchor `#{frag}` into {dest.name}")
        for n in SECTION_REF_RE.findall(text):
            if int(n) not in sections:
                problems.append(f"{name}: references §{n}, which DESIGN.md does not define")


def check_rustdoc(problems: list) -> None:
    sections = design_sections()
    for root in RUST_DIRS:
        for path in sorted(root.rglob("*.rs")):
            rel = path.relative_to(REPO)
            for i, line in enumerate(path.read_text(encoding="utf-8").splitlines(), 1):
                stripped = line.strip()
                if not (stripped.startswith("//!") or stripped.startswith("///")
                        or stripped.startswith("//")):
                    continue
                for n in SECTION_REF_RE.findall(stripped):
                    if int(n) not in sections:
                        problems.append(
                            f"{rel}:{i}: cites §{n}, which DESIGN.md does not define"
                        )


def main() -> int:
    problems: list = []
    check_markdown(problems, sys.argv[1:])
    check_rustdoc(problems)
    if problems:
        for p in problems:
            print(f"FAIL {p}")
        print(f"{len(problems)} doc link/anchor problem(s)")
        return 1
    print("doc links OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
