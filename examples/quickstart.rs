//! Quickstart: load the AOT artifacts, train a small dense model for a
//! few steps, one-shot prune it to 2x with ZipLM, and evaluate.
//!
//!   make artifacts && cargo run --release --example quickstart

use anyhow::Result;
use ziplm::data;
use ziplm::eval::evaluate;
use ziplm::latency;
use ziplm::models::ModelState;
use ziplm::pruner::{self, PruneCfg};
use ziplm::runtime::Engine;
use ziplm::train::{TrainCfg, Trainer};

fn main() -> Result<()> {
    let engine = Engine::open(std::path::Path::new("artifacts"))?;
    let (model, task) = ("bert-syn-base", "sst2-syn");
    let minfo = engine.manifest.model(model).clone();
    let tinfo = engine.manifest.task(model, task).clone();
    println!("model {model}: {} layers, d={}, {} heads, ffn={}, {} params",
        minfo.n_layers, minfo.d_model, minfo.n_heads, minfo.d_ff, tinfo.n_params);

    // 1. data + a briefly-trained dense model
    let ds = data::load_sized(&minfo, task, 256, 128);
    let mut state = ModelState::init(&minfo, task, &tinfo, 0);
    let mut trainer = Trainer::new(&engine, tinfo.n_params, None);
    let cfg = TrainCfg { lr: 1e-3, epochs: 2.0, lambdas: [1.0, 0.0, 0.0], ..Default::default() };
    let loss = trainer.train(&mut state, &ds, &cfg)?;
    let dense = evaluate(&engine, &state, &ds, "dev")?;
    println!("dense: train_loss={loss:.3} dev_acc={:.3}", dense.metric);

    // 2. measure the latency table on this machine (the paper's App. E)
    let table = latency::measure_cpu(&engine, model, "throughput", 10)?;
    println!("dense model latency estimate: {:.2} ms", table.dense_time(minfo.n_layers) * 1e3);

    // 3. one-shot ZipLM prune to 2x
    let mut pruned = state.clone();
    let pcfg = PruneCfg { calib_samples: 64, spdy: pruner::SpdyCfgLite { iters: 20, seed: 7 }, ..Default::default() };
    let report = pruner::prune_to_target(
        &engine, &mut pruned, &ds, &table, table.dense_time(minfo.n_layers), 2.0, &pcfg)?;
    let ev = evaluate(&engine, &pruned, &ds, "dev")?;
    println!(
        "ziplm 2x one-shot: est_speedup={:.2}x acc {:.3} -> {:.3}, per-layer (heads, ffn) = {:?}",
        report.est_speedup, dense.metric, ev.metric, report.layer_profile
    );
    pruned.save(std::path::Path::new("runs/quickstart_2x.zlm"))?;
    println!("saved runs/quickstart_2x.zlm — try: ziplm serve --ckpt runs/quickstart_2x.zlm");
    Ok(())
}
