//! Quickstart: load the AOT artifacts, train a small dense model for a
//! few steps, one-shot prune it to 2x with ZipLM, and evaluate.
//!
//!   make artifacts && cargo run --release --example quickstart

use anyhow::Result;
use ziplm::data;
use ziplm::env::{CostModel, InferenceEnv};
use ziplm::eval::evaluate;
use ziplm::latency;
use ziplm::models::ModelState;
use ziplm::pruner::{PruneCfg, SpdyCfgLite};
use ziplm::runtime::Engine;
use ziplm::session::CompressionSession;
use ziplm::train::{TrainCfg, Trainer};

fn main() -> Result<()> {
    let engine = Engine::open(std::path::Path::new("artifacts"))?;
    let (model, task) = ("bert-syn-base", "sst2-syn");
    let minfo = engine.manifest.model(model).clone();
    let tinfo = engine.manifest.task(model, task).clone();
    println!("model {model}: {} layers, d={}, {} heads, ffn={}, {} params",
        minfo.n_layers, minfo.d_model, minfo.n_heads, minfo.d_ff, tinfo.n_params);

    // 1. data + a briefly-trained dense model
    let ds = data::load_sized(&minfo, task, 256, 128);
    let mut state = ModelState::init(&minfo, task, &tinfo, 0);
    let mut trainer = Trainer::new(&engine, tinfo.n_params, None);
    let cfg = TrainCfg { lr: 1e-3, epochs: 2.0, lambdas: [1.0, 0.0, 0.0], ..Default::default() };
    let loss = trainer.train(&mut state, &ds, &cfg)?;
    let dense = evaluate(&engine, &state, &ds, "dev")?;
    println!("dense: train_loss={loss:.3} dev_acc={:.3}", dense.metric);

    // 2. measure the environment on this machine (the paper's App. E):
    //    a latency table wrapped in the typed InferenceEnv every
    //    downstream consumer shares
    let env = InferenceEnv::measured(latency::measure_cpu(&engine, model, "throughput", 10)?)?;
    println!("dense model latency estimate: {:.2} ms", env.dense_time(minfo.n_layers) * 1e3);

    // 3. one-shot ZipLM prune to 2x through a CompressionSession
    let mut pruned = state.clone();
    let pcfg = PruneCfg { calib_samples: 64, spdy: SpdyCfgLite { iters: 20, seed: 7 }, ..Default::default() };
    let report = CompressionSession::for_model(&engine, model, task)
        .with_env(env)
        .with_prune_cfg(pcfg)
        .open()?
        .oneshot(&mut pruned, &ds, 2.0)?;
    let ev = evaluate(&engine, &pruned, &ds, "dev")?;
    println!(
        "ziplm 2x one-shot: est_speedup={:.2}x acc {:.3} -> {:.3}, per-layer (heads, ffn) = {:?}",
        report.est_speedup, dense.metric, ev.metric, report.layer_profile
    );
    pruned.save(std::path::Path::new("runs/quickstart_2x.zlm"))?;
    println!("saved runs/quickstart_2x.zlm — try: ziplm serve --ckpt runs/quickstart_2x.zlm");
    Ok(())
}
