//! End-to-end validation driver (DESIGN.md: "End-to-end validation").
//!
//! Proves all three layers compose on a real small workload:
//!   1. trains the dense teacher for a few hundred fused PJRT train
//!      steps on the synthetic SQuAD analogue, logging the loss curve;
//!   2. runs the full ZipLM gradual pipeline (Hessians → Pallas-kernel
//!      scoring → SPDY → fine-tune with token distillation) for a
//!      family of speedup targets;
//!   3. serves batched requests from the pruned model through the
//!      coordinator and reports latency/throughput;
//!   4. prints the accuracy-vs-speedup family (the paper's headline).
//!
//!   cargo run --release --example e2e_pipeline

#![allow(clippy::disallowed_methods)] // test/bench/example code: unwrap-on-failure is fine

use anyhow::Result;
use ziplm::coordinator::{self, ServerCfg};
use ziplm::data;
use ziplm::env::{CostModel, InferenceEnv};
use ziplm::eval::evaluate;
use ziplm::latency;
use ziplm::models::ModelState;
use ziplm::pruner::{PruneCfg, SpdyCfgLite};
use ziplm::runtime::Engine;
use ziplm::session::{stdout_progress, CompressionSession};
use ziplm::train::{TrainCfg, Trainer};

fn main() -> Result<()> {
    let t0 = std::time::Instant::now();
    let engine = Engine::open(std::path::Path::new("artifacts"))?;
    let (model, task) = ("bert-syn-base", "squad-syn");
    let minfo = engine.manifest.model(model).clone();
    let tinfo = engine.manifest.task(model, task).clone();
    let ds = data::load_sized(&minfo, task, 1024, 256);

    // ---- 1. teacher training with loss curve
    println!("== [1/4] training dense teacher ({} params) ==", tinfo.n_params);
    let mut teacher = ModelState::init(&minfo, task, &tinfo, 0);
    let mut trainer = Trainer::new(&engine, tinfo.n_params, None);
    let cfg = TrainCfg { lr: 1e-3, epochs: 4.0, lambdas: [1.0, 0.0, 0.0], log_every: 32, ..Default::default() };
    std::env::set_var("ZIPLM_LOG", "info");
    let loss = trainer.train(&mut teacher, &ds, &cfg)?;
    let dense = evaluate(&engine, &teacher, &ds, "dev")?;
    println!("teacher: final_train_loss={loss:.4}  dev EM={:.4}", dense.metric);

    // ---- 2. inference environment + gradual ZipLM family
    println!("== [2/4] measuring the inference environment ==");
    let env = InferenceEnv::measured(latency::measure_cpu(&engine, model, "throughput", 15)?)?;
    println!("dense latency {:.2} ms (overhead {:.2} ms)",
        env.dense_time(minfo.n_layers) * 1e3, env.overhead() * 1e3);

    println!("== [3/4] ZipLM gradual pruning 2x/3x/4x with token distillation ==");
    let targets = [2.0, 3.0, 4.0];
    let pcfg = PruneCfg { calib_samples: 128, spdy: SpdyCfgLite { iters: 60, seed: 7 }, ..Default::default() };
    let tcfg = TrainCfg { lr: 5e-4, epochs: 1.0, lambdas: [1.0, 0.5, 0.5], ..Default::default() };
    let stages = CompressionSession::for_model(&engine, model, task)
        .with_env(env)
        .with_targets(&targets)
        .with_prune_cfg(pcfg)
        .with_train_cfg(tcfg)
        .with_teacher(teacher.params.clone())
        .on_progress(stdout_progress())
        .open()?
        .run(teacher.clone(), &ds)?;
    println!("\n  speedup |   EM    | per-layer (heads, ffn)");
    println!("  --------+---------+------------------------");
    println!("    1.0x  |  {:.4} | dense", dense.metric);
    for s in &stages {
        let ev = evaluate(&engine, &s.state, &ds, "dev")?;
        println!("    {:.1}x  |  {:.4} | {:?}", s.report.target, ev.metric, s.state.masks.summary());
    }
    let fastest = stages.last().unwrap().state.clone();
    fastest.save(std::path::Path::new("runs/e2e_final.zlm"))?;

    // ---- 3. serve batched requests from the pruned model
    println!("== [4/4] serving 64 requests through the coordinator ==");
    let handle = coordinator::start(
        ServerCfg {
            artifacts: std::path::PathBuf::from("artifacts"),
            max_batch: 16,
            max_wait: std::time::Duration::from_millis(2),
        },
        fastest,
    );
    let t1 = std::time::Instant::now();
    let mut lat = Vec::new();
    for ex in ds.dev.iter().take(64) {
        lat.push(handle.infer(ex.ids.clone())?.latency.as_secs_f64());
    }
    let wall = t1.elapsed().as_secs_f64();
    lat.sort_by(|a, b| a.total_cmp(b));
    let stats = handle.shutdown()?;
    println!(
        "served 64 reqs in {wall:.2}s ({} batches): {:.1} req/s, p50 {:.1} ms",
        stats.batches, 64.0 / wall, lat[32] * 1e3
    );
    println!("\nE2E COMPLETE in {:.0}s — all three layers composed.", t0.elapsed().as_secs_f64());
    Ok(())
}
