//! Compound compression for CPU edge deployment (paper §5 + App. A):
//! ZipLM structural pruning → 80% unstructured magnitude → INT8, with
//! accuracy after each stage and DeepSparse-sim speedups.
//!
//!   cargo run --release --example edge_compound

use anyhow::Result;
use ziplm::data;
use ziplm::env::InferenceEnv;
use ziplm::eval::evaluate;
use ziplm::latency;
use ziplm::models::ModelState;
use ziplm::pruner::{PruneCfg, SpdyCfgLite};
use ziplm::quant::{self, CpuEngineModel};
use ziplm::runtime::Engine;
use ziplm::session::CompressionSession;
use ziplm::train::{TrainCfg, Trainer};

fn main() -> Result<()> {
    let engine = Engine::open(std::path::Path::new("artifacts"))?;
    let (model, task) = ("bert-syn-base", "sst2-syn");
    let minfo = engine.manifest.model(model).clone();
    let tinfo = engine.manifest.task(model, task).clone();
    let ds = data::load_sized(&minfo, task, 512, 128);

    let mut st = ModelState::init(&minfo, task, &tinfo, 0);
    let mut tr = Trainer::new(&engine, tinfo.n_params, None);
    tr.train(&mut st, &ds, &TrainCfg { lr: 1e-3, epochs: 3.0, lambdas: [1.0, 0.0, 0.0], ..Default::default() })?;
    let acc0 = evaluate(&engine, &st, &ds, "dev")?.metric;
    println!("stage 0 dense:            acc={acc0:.4}");

    // stage 1: ZipLM structured 2x
    let env = InferenceEnv::measured(latency::measure_cpu(&engine, model, "throughput", 10)?)?;
    let pcfg = PruneCfg { calib_samples: 64, spdy: SpdyCfgLite { iters: 30, seed: 7 }, ..Default::default() };
    CompressionSession::for_model(&engine, model, task)
        .with_env(env)
        .with_prune_cfg(pcfg)
        .open()?
        .oneshot(&mut st, &ds, 2.0)?;
    let mut tr2 = Trainer::new(&engine, tinfo.n_params, None);
    tr2.train(&mut st, &ds, &TrainCfg { lr: 5e-4, epochs: 1.0, lambdas: [1.0, 0.0, 0.0], ..Default::default() })?;
    let acc1 = evaluate(&engine, &st, &ds, "dev")?.metric;
    println!("stage 1 ziplm 2x:         acc={acc1:.4}");

    // stage 2: 80% unstructured magnitude on the survivors
    let s = quant::unstructured_magnitude(&mut st, &tinfo, 0.8)?;
    let acc2 = evaluate(&engine, &st, &ds, "dev")?.metric;
    println!("stage 2 +80% unstructured: acc={acc2:.4} (achieved sparsity {s:.2})");

    // stage 3: INT8 quantization
    let err = quant::int8_quantize(&mut st, &tinfo)?;
    let acc3 = evaluate(&engine, &st, &ds, "dev")?.metric;
    println!("stage 3 +INT8:             acc={acc3:.4} (mean |quant err| {err:.2e})");

    let eng = CpuEngineModel::default();
    let flops = 1e9;
    println!("\nDeepSparse-sim single-core speedups vs dense f32:");
    println!("  ziplm 2x              : {:.1}x", eng.speedup(flops, st.masks.density(), 0.0, false));
    println!("  + 80% unstructured    : {:.1}x", eng.speedup(flops, st.masks.density(), 0.8, false));
    println!("  + INT8 (full pipeline): {:.1}x", eng.speedup(flops, st.masks.density(), 0.8, true));
    Ok(())
}
