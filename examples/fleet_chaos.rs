//! Fault-tolerant fleet serving, end to end and engine-free
//! (DESIGN.md §10): a supervised 3-worker fleet serves a ZipLM model
//! family while a seeded fault plan crashes workers, fails compiles,
//! and poisons latency samples — and every submitted request still
//! terminates in exactly one of Replied / Shed / Abandoned.
//!
//! Everything is deterministic given the two seeds below, which is why
//! CI runs this binary as its chaos smoke job:
//!
//! ```sh
//! cargo run --example fleet_chaos
//! ```

use std::time::Duration;

use anyhow::{anyhow, Result};
use ziplm::coordinator::chaos::{self, TraceCfg, TraceClass};
use ziplm::coordinator::family::BucketLadder;
use ziplm::coordinator::fleet::{FleetCfg, FleetMember, RetryPolicy};
use ziplm::env::{CostModel, InferenceEnv, Regime};
use ziplm::latency::{ArchDims, Device};
use ziplm::runtime::{FaultPlan, FaultRates};

fn main() -> Result<()> {
    // --- the serving environment: the paper's analytic V100 roofline
    // at BERT-base dims, with a small seq-bucket ladder ---------------
    let dims = ArchDims::bert_base_paper();
    let env = InferenceEnv::analytic(Device::V100Sim, &dims, Regime::Throughput, &[3072, 302, 33]);
    let (dense_h, dense_f) = env.dense_profile();
    let n_layers = dims.n_layers;

    // --- a synthetic certified family: dense + two pruned members ----
    let members = vec![
        FleetMember { tag: "dense".into(), profile: vec![(dense_h, dense_f); n_layers] },
        FleetMember { tag: "2x".into(), profile: vec![(dense_h / 2, 302); n_layers] },
        FleetMember { tag: "4x".into(), profile: vec![(dense_h / 4, 33); n_layers] },
    ];

    // --- fleet topology: 3 simulated devices with latency skew -------
    let cfg = FleetCfg {
        workers: 3,
        skews: vec![1.0, 1.3, 0.85],
        max_batch: 8,
        max_wait: Duration::from_micros(300),
        queue_cap: 64,
        retry: RetryPolicy { max_retries: 3, base: Duration::from_micros(200), factor: 2.0 },
        quarantine_after: 8,
        restart_delay: Duration::from_micros(500),
        buckets: BucketLadder::new(env.bucket_ladder()),
        time_scale: 0.0,
    };

    // --- deterministic chaos: both seeds fixed, so every run of this
    // binary sees the same crashes and the same outcomes --------------
    let plan = FaultPlan::seeded(
        0xC0FFEE,
        FaultRates {
            crash: 0.08,
            compile_fail: 0.15,
            slowdown: 0.1,
            slowdown_factor: 3.0,
            nan_latency: 0.02,
        },
    );
    let trace = TraceCfg {
        requests: 200,
        seed: 7,
        arrival_gap: Duration::from_micros(40),
        len_range: (4, 48),
        classes: vec![
            TraceClass::best_effort(2.0),
            TraceClass {
                class: "realtime".into(),
                weight: 1.0,
                max_latency: Some(Duration::from_secs_f64(env.dense_time(n_layers) * 0.8)),
                min_speedup: None,
            },
            TraceClass {
                class: "throughput".into(),
                weight: 1.0,
                max_latency: None,
                min_speedup: Some(2.0),
            },
        ],
    };

    println!("chaos campaign: 3 workers, 200 requests, seeded faults\n");
    let report = chaos::run_chaos(cfg.clone(), members.clone(), &env, plan, &trace)?;
    print!("{}", chaos::render_report(&report));

    // --- the contract this example exists to demonstrate -------------
    if !report.balanced() {
        return Err(anyhow!(
            "INVARIANT VIOLATED: {} of {} requests have no terminal outcome",
            report.lost,
            report.submitted
        ));
    }
    println!("\nno-lost-request invariant holds: every request Replied, Shed, or Abandoned.");

    // --- control: the same trace with faults off. It must be balanced
    // with zero crashes and zero retries; admission may still shed a
    // realtime request under transient backlog (that is admission
    // control working, not a fault), so shed is reported, not banned.
    let control = chaos::run_chaos(cfg, members, &env, FaultPlan::none(), &trace)?;
    if !control.balanced() || control.stats.crashes != 0 || control.retried_replies != 0 {
        return Err(anyhow!(
            "fault-free control degraded: {} of {} replied, {} crashes, {} retried",
            control.replied,
            control.submitted,
            control.stats.crashes,
            control.retried_replies
        ));
    }
    println!(
        "fault-free control: {} / {} replied ({} admission-shed), 0 crashes, 0 retries — \
         crashes and retries above are all injected.",
        control.replied, control.submitted, control.shed
    );
    Ok(())
}
