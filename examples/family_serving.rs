//! Family serving: produce a ZipLM model family with gradual pruning,
//! then serve the whole family behind ONE SLA-aware coordinator at the
//! shape buckets it was certified under (DESIGN.md §6 and §9).
//!
//!   make artifacts && cargo run --release --example family_serving
//!
//! The run: (1) quick-train a dense teacher, (2) gradual-prune it to
//! two speedup targets — one run, a whole certified family (paper
//! §3.2, App. F), (3) record the family manifest, including the
//! shape-bucket ladder certification priced, (4) start the family
//! coordinator and fire a mixed workload of best-effort,
//! latency-bound, and min-speedup requests at it — compatible requests
//! coalesce ACROSS SLA classes into one shaped batch, and each
//! (member, bucket) pair lazily warms a shape-specialized executable
//! (generic fallback while cold), (5) print per-class p50/p99 latency
//! and SLA-hit rate, then the §9 deliverable: REALIZED per-bucket
//! execution latency next to the CERTIFIED estimate, plus the
//! compile-cache counters (one build for the shared masked graph, one
//! per warmed (member, bucket) specialization).

use std::path::Path;
use std::time::Duration;

use anyhow::Result;
use ziplm::coordinator::family as famserve;
use ziplm::data;
use ziplm::env::{CostModel, InferenceEnv};
use ziplm::eval::evaluate;
use ziplm::exp;
use ziplm::latency;
use ziplm::models::ModelState;
use ziplm::pruner::{PruneCfg, SpdyCfgLite};
use ziplm::runtime::Engine;
use ziplm::session::CompressionSession;
use ziplm::train::{TrainCfg, Trainer};

fn main() -> Result<()> {
    let engine = Engine::open(Path::new("artifacts"))?;
    let (model, task) = ("bert-syn-base", "sst2-syn");
    let minfo = engine.manifest.model(model).clone();
    let tinfo = engine.manifest.task(model, task).clone();

    // 1. data + a briefly-trained dense teacher
    let ds = data::load_sized(&minfo, task, 256, 128);
    let mut teacher = ModelState::init(&minfo, task, &tinfo, 0);
    let mut trainer = Trainer::new(&engine, tinfo.n_params, None);
    let tcfg = TrainCfg { lr: 1e-3, epochs: 2.0, lambdas: [1.0, 0.0, 0.0], ..Default::default() };
    trainer.train(&mut teacher, &ds, &tcfg)?;
    let dense_ev = evaluate(&engine, &teacher, &ds, "dev")?;
    println!("dense teacher: dev acc {:.3}", dense_ev.metric);

    // 2. inference environment: ONE value prices the SPDY search AND
    //    the router's admission estimates — they cannot diverge. The
    //    measured block artifacts' static shape anchors the serving
    //    bucket ladder the manifest will record.
    let (eb, es) = latency::regime_shape(&engine, model, "throughput")?;
    let env = InferenceEnv::measured(latency::measure_cpu(&engine, model, "throughput", 10)?)?
        .with_batch_shape(eb, es);
    let dense_ms = env.dense_time(minfo.n_layers) * 1e3;
    println!("dense batched fwd estimate: {dense_ms:.2} ms");
    println!("serving bucket ladder: {:?}", env.bucket_ladder());

    // 3. gradual prune → a 3-member family (dense + 1.5x + 3x)
    let targets = [1.5, 3.0];
    let pcfg = PruneCfg {
        calib_samples: 64,
        spdy: SpdyCfgLite { iters: 20, seed: 7 },
        ..Default::default()
    };
    let ft = TrainCfg { lr: 5e-4, epochs: 0.5, lambdas: [1.0, 0.5, 0.5], ..Default::default() };
    let sess = CompressionSession::for_model(&engine, model, task)
        .with_env(env.clone())
        .with_targets(&targets)
        .with_prune_cfg(pcfg)
        .with_train_cfg(ft)
        .with_teacher(teacher.params.clone())
        .open()?;
    let stages = sess.run(teacher.clone(), &ds)?;
    for s in &stages {
        let ev = evaluate(&engine, &s.state, &ds, "dev")?;
        println!(
            "  member {:>4.1}x: est={:.2}x dev acc {:.3}",
            s.report.target, s.report.est_speedup, ev.metric
        );
    }

    // 4. record the family manifest (what `ziplm serve-family` loads);
    //    it embeds BOTH the certification env and the bucket ladder
    let fam_dir = Path::new("runs").join(format!("family_{model}_{task}"));
    let fam = sess.emit_family(&teacher, &stages, &fam_dir)?;
    assert_eq!(fam.buckets, env.bucket_ladder(), "manifest records the certified ladder");
    let members: Vec<(String, ModelState)> = fam
        .load_states(&fam_dir)?
        .into_iter()
        .map(|(m, st)| (m.tag, st))
        .collect();
    drop(sess);
    drop(engine); // the coordinator worker owns its own engine

    // 5. serve the family: one front end, per-member queues, SLA
    //    routing, cross-SLA coalescing, and lazy shape-specialized
    //    executables at the manifest's buckets (generic fallback while
    //    a (member, bucket) pair is still cold — the batch that
    //    triggers a warm-up never pays the compile)
    let handle = famserve::start(
        famserve::FamilyCfg {
            artifacts: "artifacts".into(),
            max_batch: 8,
            max_wait: Duration::from_millis(2),
            pressure: 64,
            buckets: famserve::BucketLadder::new(fam.buckets.clone()),
            specialized: None,
        },
        members,
        &env,
    )?;
    // mixed workload, all submitted up front so the queues see pressure:
    // best-effort (no SLA) / interactive (latency bound under one dense
    // fwd, must spill to a pruned member) / cheap (min 1.5x speedup)
    let bound = Duration::from_secs_f64(env.dense_time(minfo.n_layers) * 0.8);
    let rows = exp::mixed_workload(&handle, &ds, 96, bound, 1.5)?;
    let stats = handle.shutdown()?;

    println!(
        "\nper-class serving report ({} requests, {} batches, {} coalesced):",
        stats.requests, stats.batches, stats.coalesced_batches
    );
    for r in famserve::summarize(&rows) {
        println!(
            "  [{:<12}] n={:<4} p50={:>7.1}ms  p99={:>7.1}ms  sla-hit={:>4.0}%",
            r.class,
            r.n,
            r.p50.as_secs_f64() * 1e3,
            r.p99.as_secs_f64() * 1e3,
            r.hit_rate * 100.0
        );
        for bk in &r.per_bucket {
            println!(
                "      bucket {}x{}: n={:<3} p50={:>7.1}ms p99={:>7.1}ms",
                bk.batch,
                bk.seq,
                bk.n,
                bk.p50.as_secs_f64() * 1e3,
                bk.p99.as_secs_f64() * 1e3
            );
        }
    }
    // realized vs certified: the certify-vs-realize gap, per bucket
    println!("\nrealized vs certified (worker-side execution time):");
    for bkt in &stats.per_bucket {
        println!(
            "  {:>6} @ {}x{}{}: batches={:<3} realized p50={:>6.1}ms certified={:>6.1}ms",
            bkt.member,
            bkt.batch,
            bkt.seq,
            if bkt.specialized { " (specialized)" } else { " (generic)" },
            bkt.batches,
            bkt.realized_p50.as_secs_f64() * 1e3,
            bkt.certified.as_secs_f64() * 1e3
        );
    }
    println!("per-member requests: {:?}", stats.per_member);
    println!(
        "compiled executables: {} build(s), {} cache hit(s) — one for the shared masked graph \
         plus one per warmed (member, bucket) specialization",
        stats.cache_builds, stats.cache_hits
    );
    assert!(stats.cache_builds >= 1, "the shared graph must compile");
    // generic graph: ONE build however many members; specializations
    // add at most one build per (member, bucket) cell that warmed up
    let spec_cells = stats
        .per_bucket
        .iter()
        .filter(|r| r.specialized)
        .map(|r| (r.member.clone(), r.batch, r.seq))
        .collect::<std::collections::HashSet<_>>()
        .len();
    assert!(
        stats.cache_builds <= 1 + stats.per_member.len() * fam.buckets.len().max(spec_cells),
        "unexpected compile count: {} builds",
        stats.cache_builds
    );
    Ok(())
}
