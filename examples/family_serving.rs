//! Family serving: produce a ZipLM model family with gradual pruning,
//! then serve the whole family behind ONE SLA-aware coordinator.
//!
//!   make artifacts && cargo run --release --example family_serving
//!
//! The run: (1) quick-train a dense teacher, (2) gradual-prune it to
//! two speedup targets — one run, a whole certified family (paper
//! §3.2, App. F), (3) record the family manifest, (4) start the family
//! coordinator and fire a mixed workload of best-effort,
//! latency-bound, and min-speedup requests at it, (5) print per-class
//! p50/p99 latency, SLA-hit rate, and the compile-cache counters that
//! show every shared graph was compiled exactly once.

use std::path::Path;
use std::time::Duration;

use anyhow::Result;
use ziplm::coordinator::family as famserve;
use ziplm::data;
use ziplm::env::{CostModel, InferenceEnv};
use ziplm::eval::evaluate;
use ziplm::exp;
use ziplm::latency;
use ziplm::models::ModelState;
use ziplm::pruner::{PruneCfg, SpdyCfgLite};
use ziplm::runtime::Engine;
use ziplm::session::CompressionSession;
use ziplm::train::{TrainCfg, Trainer};

fn main() -> Result<()> {
    let engine = Engine::open(Path::new("artifacts"))?;
    let (model, task) = ("bert-syn-base", "sst2-syn");
    let minfo = engine.manifest.model(model).clone();
    let tinfo = engine.manifest.task(model, task).clone();

    // 1. data + a briefly-trained dense teacher
    let ds = data::load_sized(&minfo, task, 256, 128);
    let mut teacher = ModelState::init(&minfo, task, &tinfo, 0);
    let mut trainer = Trainer::new(&engine, tinfo.n_params, None);
    let tcfg = TrainCfg { lr: 1e-3, epochs: 2.0, lambdas: [1.0, 0.0, 0.0], ..Default::default() };
    trainer.train(&mut teacher, &ds, &tcfg)?;
    let dense_ev = evaluate(&engine, &teacher, &ds, "dev")?;
    println!("dense teacher: dev acc {:.3}", dense_ev.metric);

    // 2. inference environment: ONE value prices the SPDY search AND
    //    the router's admission estimates — they cannot diverge
    let env = InferenceEnv::measured(latency::measure_cpu(&engine, model, "throughput", 10)?)?;
    let dense_ms = env.dense_time(minfo.n_layers) * 1e3;
    println!("dense batched fwd estimate: {dense_ms:.2} ms");

    // 3. gradual prune → a 3-member family (dense + 1.5x + 3x)
    let targets = [1.5, 3.0];
    let pcfg = PruneCfg {
        calib_samples: 64,
        spdy: SpdyCfgLite { iters: 20, seed: 7 },
        ..Default::default()
    };
    let ft = TrainCfg { lr: 5e-4, epochs: 0.5, lambdas: [1.0, 0.5, 0.5], ..Default::default() };
    let sess = CompressionSession::for_model(&engine, model, task)
        .with_env(env.clone())
        .with_targets(&targets)
        .with_prune_cfg(pcfg)
        .with_train_cfg(ft)
        .with_teacher(teacher.params.clone())
        .open()?;
    let stages = sess.run(teacher.clone(), &ds)?;
    for s in &stages {
        let ev = evaluate(&engine, &s.state, &ds, "dev")?;
        println!(
            "  member {:>4.1}x: est={:.2}x dev acc {:.3}",
            s.report.target, s.report.est_speedup, ev.metric
        );
    }

    // 4. record the family manifest (what `ziplm serve-family` loads)
    let fam_dir = Path::new("runs").join(format!("family_{model}_{task}"));
    let fam = sess.emit_family(&teacher, &stages, &fam_dir)?;
    let members: Vec<(String, ModelState)> = fam
        .load_states(&fam_dir)?
        .into_iter()
        .map(|(m, st)| (m.tag, st))
        .collect();
    drop(sess);
    drop(engine); // the coordinator worker owns its own engine

    // 5. serve the family: one front end, per-member queues, SLA routing
    let handle = famserve::start(
        famserve::FamilyCfg {
            artifacts: "artifacts".into(),
            max_batch: 8,
            max_wait: Duration::from_millis(2),
            pressure: 64,
        },
        members,
        &env,
    )?;
    // mixed workload, all submitted up front so the queues see pressure:
    // best-effort (no SLA) / interactive (latency bound under one dense
    // fwd, must spill to a pruned member) / cheap (min 1.5x speedup)
    let bound = Duration::from_secs_f64(env.dense_time(minfo.n_layers) * 0.8);
    let rows = exp::mixed_workload(&handle, &ds, 96, bound, 1.5)?;
    let stats = handle.shutdown()?;

    println!(
        "\nper-class serving report ({} requests, {} batches):",
        stats.requests, stats.batches
    );
    for r in famserve::summarize(&rows) {
        println!(
            "  [{:<12}] n={:<4} p50={:>7.1}ms  p99={:>7.1}ms  sla-hit={:>4.0}%",
            r.class,
            r.n,
            r.p50.as_secs_f64() * 1e3,
            r.p99.as_secs_f64() * 1e3,
            r.hit_rate * 100.0
        );
    }
    println!("per-member requests: {:?}", stats.per_member);
    println!(
        "compiled executables: {} build(s), {} cache hit(s) — one compile for the whole family",
        stats.cache_builds, stats.cache_hits
    );
    assert!(stats.cache_builds <= 1, "family members must share the compiled graph");
    Ok(())
}
