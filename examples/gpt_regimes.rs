//! GPT depth-vs-width demo (paper §4.2 "On the Importance of
//! Inference-Awareness"): prune the SAME decoder for the same target
//! under the throughput regime (big batches) and the latency regime
//! (single short prompts) and print how differently ZipLM shapes the
//! architecture — width shrinks in the former, depth in the latter.
//!
//!   cargo run --release --example gpt_regimes

#![allow(clippy::disallowed_methods)] // test/bench/example code: unwrap-on-failure is fine

use anyhow::Result;
use ziplm::data;
use ziplm::env::InferenceEnv;
use ziplm::eval::evaluate;
use ziplm::latency;
use ziplm::models::ModelState;
use ziplm::pruner::{PruneCfg, SpdyCfgLite};
use ziplm::runtime::Engine;
use ziplm::session::CompressionSession;
use ziplm::train::{TrainCfg, Trainer};

fn main() -> Result<()> {
    let engine = Engine::open(std::path::Path::new("artifacts"))?;
    let (model, task) = ("gpt-syn", "corpus-syn");
    let minfo = engine.manifest.model(model).clone();
    let tinfo = engine.manifest.task(model, task).clone();
    let ds = data::load_sized(&minfo, task, 512, 128);

    println!("== training dense GPT teacher ==");
    let mut teacher = ModelState::init(&minfo, task, &tinfo, 0);
    let mut trainer = Trainer::new(&engine, tinfo.n_params, None);
    trainer.train(&mut teacher, &ds,
        &TrainCfg { lr: 1e-3, epochs: 2.0, lambdas: [1.0, 0.0, 0.0], ..Default::default() })?;
    let dense_ppl = evaluate(&engine, &teacher, &ds, "test")?.perplexity.unwrap();
    println!("dense zero-shot PPL = {dense_ppl:.2}");

    let target = 2.0;
    for regime in ["throughput", "latency"] {
        // one env per regime: the ONLY thing that changes between runs
        let env = InferenceEnv::measured(latency::measure_cpu(&engine, model, regime, 10)?)?;
        let mut st = teacher.clone();
        let pcfg = PruneCfg { calib_samples: 64, spdy: SpdyCfgLite { iters: 40, seed: 7 }, ..Default::default() };
        CompressionSession::for_model(&engine, model, task)
            .with_env(env)
            .with_prune_cfg(pcfg)
            .open()?
            .oneshot(&mut st, &ds, target)?;
        // brief recovery (no KD for GPT, paper App. I)
        let mut tr = Trainer::new(&engine, tinfo.n_params, None);
        tr.train(&mut st, &ds, &TrainCfg { lr: 5e-4, epochs: 0.5, lambdas: [1.0, 0.0, 0.0], ..Default::default() })?;
        let ppl = evaluate(&engine, &st, &ds, "test")?.perplexity.unwrap();
        let anatomy = st.masks.summary();
        let dropped = anatomy.iter().filter(|&&(h, f)| h == 0 && f == 0).count();
        let mean_ffn: f64 = anatomy.iter().map(|&(_, f)| f as f64).sum::<f64>() / anatomy.len() as f64;
        println!(
            "\n[{regime}] {target}x: PPL {dense_ppl:.2} -> {ppl:.2}\n  per-layer (heads, ffn): {anatomy:?}\n  -> {dropped} modules fully dropped, mean ffn width {mean_ffn:.0}/{}",
            minfo.d_ff
        );
    }
    println!("\nExpected shape (paper Table 1): throughput regime keeps depth and\nshrinks width; latency regime keeps width and drops whole modules.");
    Ok(())
}
