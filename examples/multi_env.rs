//! Multi-env sessions: ONE Hessian capture → N inference environments
//! → N certified families (paper §3.2: matching desired speedups "in
//! any given inference environment"; DESIGN.md §8).
//!
//!   make artifacts && cargo run --release --example multi_env
//!
//! The run: (1) quick-train a dense teacher, (2) describe TWO
//! environments — this machine's measured CPU table and an analytic
//! V100 roofline at the same architecture dims, (3) open ONE
//! checkpointed `CompressionSession` and call `emit_families`: capture
//! and database build happen once, each env's SPDY solve fans out on
//! the global pool, and each env gets its own `family.json` embedding
//! the env it was certified against, (4) prove the headline property
//! with store counters — a fresh session pinned to the GPU env resumes
//! capture, databases AND solve from the shared directory with ZERO
//! recomputation, (5) serve the CPU family with the env *loaded from
//! its manifest* (no re-measuring) behind the SLA-aware coordinator.

#![allow(clippy::disallowed_methods)] // test/bench/example code: unwrap-on-failure is fine

use std::path::Path;
use std::time::Duration;

use anyhow::Result;
use ziplm::coordinator::family as famserve;
use ziplm::data;
use ziplm::env::{CostModel, InferenceEnv, Regime};
use ziplm::latency;
use ziplm::models::family::FamilyManifest;
use ziplm::models::ModelState;
use ziplm::pruner::{PruneCfg, SpdyCfgLite};
use ziplm::runtime::Engine;
use ziplm::session::{env_slug, CompressionSession};
use ziplm::train::{TrainCfg, Trainer};

fn main() -> Result<()> {
    let engine = Engine::open(Path::new("artifacts"))?;
    let (model, task) = ("bert-syn-base", "sst2-syn");
    let minfo = engine.manifest.model(model).clone();
    let tinfo = engine.manifest.task(model, task).clone();

    // 1. data + a briefly-trained dense teacher
    let ds = data::load_sized(&minfo, task, 256, 128);
    let mut teacher = ModelState::init(&minfo, task, &tinfo, 0);
    let mut trainer = Trainer::new(&engine, tinfo.n_params, None);
    let tcfg = TrainCfg { lr: 1e-3, epochs: 2.0, lambdas: [1.0, 0.0, 0.0], ..Default::default() };
    trainer.train(&mut teacher, &ds, &tcfg)?;

    // 2. two inference environments, one real and one analytic (the
    //    same constructor the `multienv` experiment driver uses). The
    //    measured env anchors its serving bucket at the block
    //    artifacts' shape; the analytic env carries a full seq sweep,
    //    so its family records a multi-bucket ladder (DESIGN.md §9)
    let (eb, es) = latency::regime_shape(&engine, model, "throughput")?;
    let env_cpu = InferenceEnv::measured(latency::measure_cpu(&engine, model, "throughput", 10)?)?
        .with_batch_shape(eb, es);
    let env_gpu = ziplm::exp::analytic_gpu_env(&minfo, Regime::Throughput);
    println!("env A: {} (buckets {:?})", env_cpu.describe(), env_cpu.bucket_ladder());
    println!("env B: {} (buckets {:?})", env_gpu.describe(), env_gpu.bucket_ladder());

    // 3. ONE session, ONE capture, N families
    let targets = [1.5, 3.0];
    let pcfg = PruneCfg {
        calib_samples: 64,
        spdy: SpdyCfgLite { iters: 20, seed: 7 },
        ..Default::default()
    };
    let sdir = Path::new("runs").join(format!("session_multienv_{model}_{task}"));
    let _ = std::fs::remove_dir_all(&sdir); // fresh demo run
    let base = Path::new("runs").join(format!("families_{model}_{task}"));
    let sess = CompressionSession::for_model(&engine, model, task)
        .with_env(env_cpu.clone())
        .with_targets(&targets)
        .with_prune_cfg(pcfg.clone())
        .checkpoint_to(&sdir)
        .open()?;
    let envs = [env_cpu.clone(), env_gpu.clone()];
    let fams = sess.emit_families(&teacher, &ds, &envs, &base)?;
    assert!(fams.len() >= 2, "expected one family per env");
    let (computed, loaded) = sess.counters();
    println!("\none capture, {} families ({computed} computed, {loaded} loaded):", fams.len());
    for (env, fam) in envs.iter().zip(&fams) {
        assert!(fam.env.is_some(), "manifest must embed its certification env");
        println!("  {} →", env.describe());
        for m in &fam.members {
            let (tag, t, est) = (&m.tag, m.target, m.est_speedup);
            println!("    {tag:>6}: target {t:>4.1}x, certified {est:>5.2}x");
        }
    }

    // 4. the proof: a fresh session pinned to the SECOND env resumes
    //    capture + databases + its solve with zero recomputation
    let sess2 = CompressionSession::for_model(&engine, model, task)
        .with_env(env_gpu.clone())
        .with_targets(&targets)
        .with_prune_cfg(pcfg)
        .checkpoint_to(&sdir)
        .open()?;
    let solved = sess2.capture(&teacher, &ds)?.build_dbs()?.solve(&ds, targets[0])?;
    let (c2, l2) = sess2.counters();
    println!("\ngpu-env resume: {c2} computed / {l2} loaded (profile {:?})", solved.profile);
    assert_eq!(c2, 0, "second env must recompute NOTHING — no Hessians, no databases");
    drop(solved);
    drop(sess2);
    drop(sess);

    // 5. serve the CPU family with the env loaded from its manifest —
    //    admission is priced by the certification env, not a fresh
    //    measurement
    let cpu_dir = base.join(env_slug(&env_cpu));
    let fam = FamilyManifest::load(&cpu_dir.join("family.json"))?;
    let served_env = fam.env.clone().expect("embedded env");
    assert_eq!(served_env, env_cpu, "loaded env must equal the certification env");
    let members: Vec<(String, ModelState)> =
        fam.load_states(&cpu_dir)?.into_iter().map(|(m, st)| (m.tag, st)).collect();
    drop(engine); // the coordinator worker owns its own engine
    let handle = famserve::start(
        famserve::FamilyCfg {
            artifacts: "artifacts".into(),
            max_batch: 8,
            max_wait: Duration::from_millis(2),
            pressure: 64,
            buckets: famserve::BucketLadder::new(fam.buckets.clone()),
            specialized: None,
        },
        members,
        &served_env,
    )?;
    let bound = Duration::from_secs_f64(served_env.dense_time(minfo.n_layers) * 0.8);
    let rows = ziplm::exp::mixed_workload(&handle, &ds, 48, bound, 1.5)?;
    let stats = handle.shutdown()?;
    let (reqs, batches) = (stats.requests, stats.batches);
    println!("\nserved {reqs} requests / {batches} batches against the manifest env:");
    for r in famserve::summarize(&rows) {
        println!(
            "  [{:<12}] n={:<3} p50={:>6.1}ms p99={:>6.1}ms sla-hit={:>4.0}%",
            r.class,
            r.n,
            r.p50.as_secs_f64() * 1e3,
            r.p99.as_secs_f64() * 1e3,
            r.hit_rate * 100.0
        );
    }
    Ok(())
}
