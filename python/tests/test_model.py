"""L2 model-level invariants.

The load-bearing test is mask/materialize equivalence: a masked model
(the gradual-pruning workhorse) must agree with the shape-materialized
model (the deployment export) to float tolerance for ANY pruning
configuration — that is what makes speedups measured on specialized
artifacts valid for masked checkpoints.
"""

import functools

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from compile import model as M
from compile.configs import MODELS, TASKS, n_params, param_layout, layout_offsets
from compile.specialized import specialized_fwd, specialized_layout

CFG = MODELS["bert-syn-base"]
GPT = MODELS["gpt-syn"]


def rand_params(cfg, task, seed=0, scale=0.05):
    rng = np.random.default_rng(seed)
    flat = (rng.normal(size=n_params(cfg, task)) * scale).astype(np.float32)
    # make layernorm gains 1 (not 0-centered noise)
    offs = layout_offsets(param_layout(cfg, task))
    for name, (off, shape) in offs.items():
        if name.endswith("_g"):
            n = int(np.prod(shape))
            flat[off:off + n] = 1.0
    return flat


def gather_specialized(flat, cfg, task, heads_keep, inter_keep):
    """Extract surviving rows/cols of a masked checkpoint into the
    specialized packed layout (mirrors rust models/export.rs)."""
    offs = layout_offsets(param_layout(cfg, task))
    full = {}
    for name, (off, shape) in offs.items():
        n = int(np.prod(shape))
        full[name] = flat[off:off + n].reshape(shape)
    heads = [len(h) for h in heads_keep]
    inters = [len(f) for f in inter_keep]
    slayout = specialized_layout(cfg, task, heads, inters)
    out = []
    for name, shape in slayout:
        if name.startswith("layer"):
            l = int(name.split(".")[0][5:])
            key = name.split(".")[1]
            hk = np.array(heads_keep[l], np.int64)
            fk = np.array(inter_keep[l], np.int64)
            cols_a = (hk[:, None] * cfg.d_head + np.arange(cfg.d_head)[None]).reshape(-1) \
                if len(hk) else np.zeros(0, np.int64)
            t = full[name]
            if key in ("wq", "wk", "wv"):
                t = t[:, cols_a]
            elif key in ("bq", "bk", "bv"):
                t = t[cols_a]
            elif key == "wo":
                t = t[cols_a, :]
            elif key == "w1":
                t = t[:, fk]
            elif key == "b1":
                t = t[fk]
            elif key == "w2":
                t = t[fk, :]
            out.append(np.asarray(t, np.float32).reshape(-1))
        else:
            out.append(full[name].reshape(-1))
    return np.concatenate(out), heads, inters


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2**16))
def test_masked_equals_specialized_bert(seed):
    task = TASKS["sst2-syn"]
    rng = np.random.default_rng(seed)
    flat = rand_params(CFG, task, seed)
    # random pruning config (keep at least 1 head / 1 col in some layers)
    heads_keep, inter_keep = [], []
    for l in range(CFG.n_layers):
        nh = int(rng.integers(0, CFG.n_heads + 1))
        hk = sorted(rng.choice(CFG.n_heads, nh, replace=False).tolist())
        nf = int(rng.integers(0, CFG.d_ff // 8)) * 4
        fk = sorted(rng.choice(CFG.d_ff, nf, replace=False).tolist())
        heads_keep.append(hk)
        inter_keep.append(fk)
    hm = np.zeros((CFG.n_layers, CFG.n_heads), np.float32)
    fm = np.zeros((CFG.n_layers, CFG.d_ff), np.float32)
    for l in range(CFG.n_layers):
        hm[l, heads_keep[l]] = 1.0
        fm[l, inter_keep[l]] = 1.0
    # masked checkpoint must have pruned weights zeroed for equivalence
    offs = layout_offsets(param_layout(CFG, task))
    ids = rng.integers(0, CFG.vocab, (4, CFG.seq_len)).astype(np.int32)
    masked_logits = np.asarray(M.fwd(jnp.array(flat), jnp.array(ids),
                                     jnp.array(hm), jnp.array(fm),
                                     cfg=CFG, task=task)[0])
    sflat, heads, inters = gather_specialized(flat, CFG, task, heads_keep, inter_keep)
    sfn, _ = specialized_fwd(CFG, task, heads, inters)
    spec_logits = np.asarray(sfn(jnp.array(sflat), jnp.array(ids))[0])
    np.testing.assert_allclose(masked_logits, spec_logits, rtol=1e-3, atol=1e-4)


def test_masked_equals_specialized_gpt():
    task = TASKS["corpus-syn"]
    rng = np.random.default_rng(7)
    flat = rand_params(GPT, task, 7)
    heads_keep = [[0, 2], [1], list(range(GPT.n_heads)), []]
    inter_keep = [sorted(rng.choice(GPT.d_ff, 100, replace=False).tolist()),
                  [], list(range(GPT.d_ff)), [3, 500]]
    hm = np.zeros((GPT.n_layers, GPT.n_heads), np.float32)
    fm = np.zeros((GPT.n_layers, GPT.d_ff), np.float32)
    for l in range(GPT.n_layers):
        hm[l, heads_keep[l]] = 1.0
        fm[l, inter_keep[l]] = 1.0
    ids = rng.integers(0, GPT.vocab, (2, GPT.seq_len)).astype(np.int32)
    masked = np.asarray(M.fwd(jnp.array(flat), jnp.array(ids), jnp.array(hm),
                              jnp.array(fm), cfg=GPT, task=task)[0])
    sflat, heads, inters = gather_specialized(flat, GPT, task, heads_keep, inter_keep)
    sfn, _ = specialized_fwd(GPT, task, heads, inters)
    spec = np.asarray(sfn(jnp.array(sflat), jnp.array(ids))[0])
    np.testing.assert_allclose(masked, spec, rtol=2e-3, atol=2e-3)


def test_module_drop_is_exact():
    """All-zero mask row == module absent (bias gated too)."""
    task = TASKS["sst2-syn"]
    flat = rand_params(CFG, task, 1)
    rng = np.random.default_rng(1)
    ids = rng.integers(0, CFG.vocab, (2, CFG.seq_len)).astype(np.int32)
    hm = np.ones((CFG.n_layers, CFG.n_heads), np.float32)
    fm = np.ones((CFG.n_layers, CFG.d_ff), np.float32)
    hm[1, :] = 0.0
    base = np.asarray(M.fwd(jnp.array(flat), jnp.array(ids), jnp.array(hm),
                            jnp.array(fm), cfg=CFG, task=task)[0])
    # perturb the dropped layer's attention weights: output must not change
    flat2 = flat.copy()
    offs = layout_offsets(param_layout(CFG, task))
    for key in ("wq", "wk", "wv", "wo", "bo", "bq", "bk", "bv"):
        off, shape = offs[f"layer1.{key}"]
        n = int(np.prod(shape))
        flat2[off:off + n] += 123.0
    pert = np.asarray(M.fwd(jnp.array(flat2), jnp.array(ids), jnp.array(hm),
                            jnp.array(fm), cfg=CFG, task=task)[0])
    np.testing.assert_allclose(base, pert, rtol=1e-5, atol=1e-6)


def test_train_step_overfits_tiny_batch():
    """A few steps of the fused train_step must drive task loss down."""
    task = TASKS["sst2-syn"]
    flat = rand_params(CFG, task, 3)
    m = np.zeros_like(flat); v = np.zeros_like(flat)
    rng = np.random.default_rng(3)
    from compile.configs import TRAIN_BATCH
    ids = rng.integers(0, CFG.vocab, (TRAIN_BATCH, CFG.seq_len)).astype(np.int32)
    labels = rng.integers(0, 2, (TRAIN_BATCH,)).astype(np.int32)
    hm = np.ones((CFG.n_layers, CFG.n_heads), np.float32)
    fm = np.ones((CFG.n_layers, CFG.d_ff), np.float32)
    tl = np.zeros((TRAIN_BATCH, 2), np.float32)
    th = np.zeros((CFG.n_layers, TRAIN_BATCH, CFG.seq_len, CFG.d_model), np.float32)
    pm = np.ones((TRAIN_BATCH, CFG.seq_len), np.float32)
    lam = np.array([1.0, 0.0, 0.0], np.float32)
    step = jax.jit(functools.partial(M.train_step, cfg=CFG, task=task))
    first = None
    for t in range(1, 51):
        flat, m, v, lt, _, _ = step(flat, m, v, float(t), 1e-3, ids, labels,
                                    hm, fm, tl, th, pm, lam, 0.0)
        if first is None:
            first = float(lt)
    assert float(lt) < min(0.05, first * 0.1), (first, float(lt))


def test_calib_capture_hessians_are_psd_and_match_manual():
    task = TASKS["sst2-syn"]
    flat = rand_params(CFG, task, 4)
    rng = np.random.default_rng(4)
    from compile.configs import CALIB_BATCH
    ids = rng.integers(0, CFG.vocab, (CALIB_BATCH, CFG.seq_len)).astype(np.int32)
    hm = np.ones((CFG.n_layers, CFG.n_heads), np.float32)
    fm = np.ones((CFG.n_layers, CFG.d_ff), np.float32)
    ha, hf = M.calib_capture(jnp.array(flat), jnp.array(ids), jnp.array(hm),
                             jnp.array(fm), cfg=CFG, task=task)
    ha, hf = np.asarray(ha), np.asarray(hf)
    assert ha.shape == (CFG.n_layers, CFG.d_attn, CFG.d_attn)
    assert hf.shape == (CFG.n_layers, CFG.d_ff, CFG.d_ff)
    for l in range(CFG.n_layers):
        np.testing.assert_allclose(ha[l], ha[l].T, rtol=1e-4, atol=1e-3)
        ev = np.linalg.eigvalsh(ha[l].astype(np.float64))
        assert ev.min() > -1e-2, ev.min()


def test_eval_loss_matches_manual_ce():
    task = TASKS["mnli-syn"]
    flat = rand_params(CFG, task, 5)
    rng = np.random.default_rng(5)
    from compile.configs import EVAL_BATCH
    ids = rng.integers(0, CFG.vocab, (EVAL_BATCH, CFG.seq_len)).astype(np.int32)
    labels = rng.integers(0, 3, (EVAL_BATCH,)).astype(np.int32)
    hm = np.ones((CFG.n_layers, CFG.n_heads), np.float32)
    fm = np.ones((CFG.n_layers, CFG.d_ff), np.float32)
    loss = float(M.eval_loss(jnp.array(flat), jnp.array(ids), jnp.array(labels),
                             jnp.array(hm), jnp.array(fm), cfg=CFG, task=task)[0])
    logits = np.asarray(M.fwd(jnp.array(flat), jnp.array(ids[:32]), jnp.array(hm),
                              jnp.array(fm), cfg=CFG, task=task)[0])
    lse = np.log(np.exp(logits).sum(-1))
    manual = float(np.mean(lse - logits[np.arange(len(labels)), labels]))
    assert abs(loss - manual) < 1e-3
