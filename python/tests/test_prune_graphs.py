"""Pruning graphs (Algorithm 1) vs numpy oracle, plus OBS invariants."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile import prune_graphs as PG
from compile.configs import MODELS
from compile.kernels import ref as R

CFG = MODELS["bert-syn-base"]


def _spd(rng, n, scale=0.5):
    a = rng.normal(size=(n, n)).astype(np.float32)
    return a @ a.T + scale * n * np.eye(n, dtype=np.float32)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**16), g=st.sampled_from([1, 8, 32]))
def test_update_structure_matches_numpy(seed, g):
    rng = np.random.default_rng(seed)
    n_s = 4
    d_row, d_col = 24, n_s * g
    w = rng.normal(size=(d_row, d_col)).astype(np.float32)
    hinv = _spd(rng, d_col)
    idx = int(rng.integers(0, n_s))
    w2, h2 = PG.update_structure(jnp.array(w), jnp.array(hinv),
                                 jnp.int32(idx), g=g)
    w_ref, h_ref = R.ref_obs_full_step(w, hinv, idx, g)
    np.testing.assert_allclose(np.asarray(w2), w_ref, rtol=1e-3, atol=1e-3)
    # scrubbed rows/cols: compare only surviving block
    keep = np.ones(d_col, bool)
    keep[idx * g:(idx + 1) * g] = False
    np.testing.assert_allclose(np.asarray(h2)[np.ix_(keep, keep)],
                               h_ref[np.ix_(keep, keep)], rtol=1e-3, atol=1e-3)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**16))
def test_score_structures_matches_ref(seed):
    rng = np.random.default_rng(seed)
    g, n_s, d_row = 8, 6, 16
    w = rng.normal(size=(d_row, n_s * g)).astype(np.float32)
    hinv = _spd(rng, n_s * g)
    active = np.ones(n_s, np.float32)
    active[2] = 0.0
    (scores,) = PG.score_structures(jnp.array(w), jnp.array(hinv),
                                    jnp.array(active), g=g)
    scores = np.asarray(scores)
    for j in range(n_s):
        if active[j] == 0:
            assert scores[j] >= PG.BIG / 2
            continue
        s = slice(j * g, (j + 1) * g)
        binv = np.linalg.inv(hinv[s, s])
        want = np.einsum("rg,gh,rh->", w[:, s], binv, w[:, s])
        np.testing.assert_allclose(scores[j], want, rtol=2e-3, atol=2e-3)


def test_update_fc_multi_equals_sequential_singles():
    """The fused while-loop graph must reproduce n sequential
    argmin+update steps exactly (same order, same weights)."""
    rng = np.random.default_rng(11)
    d_row, f = 12, 24
    w = rng.normal(size=(d_row, f)).astype(np.float32)
    hinv = _spd(rng, f)
    active = np.ones(f, np.float32)
    n = 6
    w2, h2, act2, order = PG.update_fc_multi(jnp.array(w), jnp.array(hinv),
                                             jnp.array(active), jnp.int32(n))
    # sequential numpy mirror
    wm, hm = w.copy().astype(np.float64), hinv.copy().astype(np.float64)
    act = active.copy()
    seq_order = []
    for _ in range(n):
        diag = np.diagonal(hm).copy()
        sc = (wm ** 2).sum(0) / np.where(act > 0, diag, 1.0)
        sc[act == 0] = np.inf
        j = int(np.argmin(sc))
        seq_order.append(j)
        p = hm[:, j] / hm[j, j]
        wm = wm - np.outer(wm[:, j], p)
        hm = hm - np.outer(hm[:, j], p)
        wm[:, j] = 0
        hm[j, :] = 0; hm[:, j] = 0; hm[j, j] = 1
        act[j] = 0
    assert list(np.asarray(order)[:n]) == seq_order
    np.testing.assert_allclose(np.asarray(w2), wm.astype(np.float32),
                               rtol=5e-3, atol=5e-3)
    assert int(np.asarray(act2).sum()) == f - n


def test_obs_removes_linearly_redundant_column_first():
    """Paper Sec. 3.1: a structure that is a linear combination of others
    is maximally redundant — OBS must score it lowest and reconstruct
    the layer output exactly after removal."""
    rng = np.random.default_rng(5)
    n, d_row, nsamp = 8, 6, 400
    x = rng.normal(size=(n, nsamp)).astype(np.float32)
    x[3] = 0.5 * x[1] - 0.25 * x[6]  # feature 3 linearly dependent
    w = rng.normal(size=(d_row, n)).astype(np.float32)
    h = 2.0 * x @ x.T + 1e-4 * np.eye(n, dtype=np.float32)
    hinv = np.linalg.inv(h).astype(np.float32)
    active = np.ones(n, np.float32)
    (scores,) = PG.score_structures(jnp.array(w), jnp.array(hinv),
                                    jnp.array(active), g=1)
    j = int(np.argmin(np.asarray(scores)))
    assert j == 3, np.asarray(scores)
    w2, _ = PG.update_structure(jnp.array(w), jnp.array(hinv), jnp.int32(3), g=1)
    y0, y1 = w @ x, np.asarray(w2) @ x
    np.testing.assert_allclose(y1, y0, rtol=1e-2, atol=1e-2)


def test_one_at_a_time_beats_joint_removal_on_correlated_pair():
    """The paper's motivating example: two mutually-redundant structures
    must NOT both be removed. After removing one and updating, the
    other's score increases."""
    rng = np.random.default_rng(8)
    n, d_row, nsamp = 6, 5, 300
    x = rng.normal(size=(n, nsamp)).astype(np.float32)
    x[2] = x[4] + 0.01 * rng.normal(size=nsamp).astype(np.float32)
    w = rng.normal(size=(d_row, n)).astype(np.float32)
    h = 2.0 * x @ x.T + 1e-3 * np.eye(n, dtype=np.float32)
    hinv = np.linalg.inv(h).astype(np.float32)
    active = np.ones(n, np.float32)
    (s0,) = PG.score_structures(jnp.array(w), jnp.array(hinv), jnp.array(active), g=1)
    s0 = np.asarray(s0)
    j = int(np.argmin(s0))
    assert j in (2, 4)
    other = 4 if j == 2 else 2
    w2, h2 = PG.update_structure(jnp.array(w), jnp.array(hinv), jnp.int32(j), g=1)
    active[j] = 0.0
    (s1,) = PG.score_structures(w2, h2, jnp.array(active), g=1)
    assert float(np.asarray(s1)[other]) > 10.0 * float(s0[other])
