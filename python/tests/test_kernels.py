"""L1 Pallas kernels vs pure-jnp/numpy oracles (the core correctness signal).

hypothesis sweeps shapes; every kernel must match ref.py to tight
tolerances. These tests run in interpret mode — the same lowering the
AOT artifacts use — so passing here pins the numerics of the artifacts
the Rust coordinator executes.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels import linalg as KL
from compile.kernels import ref as R
from compile.kernels.mha import mha, _mha_pallas
from compile.kernels.obs_score import obs_scores
from compile.kernels.rankg_update import rankg_update


def _rng(seed):
    return np.random.default_rng(seed)


def _spd(rng, n, scale=1.0):
    a = rng.normal(size=(n, n)).astype(np.float32)
    return a @ a.T + scale * n * np.eye(n, dtype=np.float32)


# ---------------------------------------------------------------- obs_score

@settings(max_examples=20, deadline=None)
@given(
    d_row=st.sampled_from([8, 33, 64, 128]),
    n_s=st.sampled_from([1, 4, 7, 16]),
    g=st.sampled_from([1, 4, 32]),
    seed=st.integers(0, 2**16),
)
def test_obs_scores_matches_ref(d_row, n_s, g, seed):
    rng = _rng(seed)
    w = rng.normal(size=(d_row, n_s, g)).astype(np.float32)
    b = np.stack([_spd(rng, g) for _ in range(n_s)])
    got = np.asarray(obs_scores(jnp.array(w), jnp.array(b), row_tile=32))
    want = np.asarray(R.ref_obs_scores(w, b))
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_obs_scores_zero_weights_zero_score():
    w = np.zeros((64, 4, 8), np.float32)
    b = np.stack([np.eye(8, dtype=np.float32)] * 4)
    got = np.asarray(obs_scores(jnp.array(w), jnp.array(b)))
    np.testing.assert_allclose(got, 0.0)


def test_obs_scores_row_padding_invariant():
    """Scores must not depend on the row-tile padding."""
    rng = _rng(0)
    w = rng.normal(size=(50, 3, 4)).astype(np.float32)  # 50 % 64 != 0
    b = np.stack([_spd(rng, 4) for _ in range(3)])
    a = np.asarray(obs_scores(jnp.array(w), jnp.array(b), row_tile=64))
    c = np.asarray(obs_scores(jnp.array(w), jnp.array(b), row_tile=25))
    np.testing.assert_allclose(a, c, rtol=1e-4, atol=1e-5)


# ------------------------------------------------------------- rankg_update

@settings(max_examples=20, deadline=None)
@given(
    m=st.sampled_from([16, 63, 128]),
    n=st.sampled_from([8, 96]),
    g=st.sampled_from([1, 8, 32]),
    seed=st.integers(0, 2**16),
)
def test_rankg_update_matches_ref(m, n, g, seed):
    rng = _rng(seed)
    a = rng.normal(size=(m, n)).astype(np.float32)
    c = rng.normal(size=(m, g)).astype(np.float32)
    p = rng.normal(size=(g, n)).astype(np.float32)
    got = np.asarray(rankg_update(jnp.array(a), jnp.array(c), jnp.array(p), row_tile=32))
    np.testing.assert_allclose(got, R.ref_rankg_update(a, c, p), rtol=2e-4, atol=2e-4)


def test_rankg_update_zero_c_is_identity():
    rng = _rng(1)
    a = rng.normal(size=(40, 16)).astype(np.float32)
    got = np.asarray(rankg_update(jnp.array(a), jnp.zeros((40, 4), jnp.float32),
                                  jnp.ones((4, 16), jnp.float32)))
    np.testing.assert_allclose(got, a)


# ---------------------------------------------------------------------- mha

@settings(max_examples=15, deadline=None)
@given(
    b=st.sampled_from([1, 3]),
    h=st.sampled_from([1, 4]),
    s=st.sampled_from([4, 16, 33]),
    dh=st.sampled_from([8, 32]),
    causal=st.booleans(),
    seed=st.integers(0, 2**16),
)
def test_mha_matches_ref(b, h, s, dh, causal, seed):
    rng = _rng(seed)
    q = rng.normal(size=(b, h, s, dh)).astype(np.float32)
    k = rng.normal(size=(b, h, s, dh)).astype(np.float32)
    v = rng.normal(size=(b, h, s, dh)).astype(np.float32)
    hm = (rng.random(h) > 0.3).astype(np.float32)
    got = np.asarray(_mha_pallas(jnp.array(q), jnp.array(k), jnp.array(v),
                                 jnp.array(hm), causal))
    want = np.asarray(R.ref_mha(q, k, v, hm, causal))
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_mha_masked_head_exact_zero():
    rng = _rng(2)
    q = rng.normal(size=(2, 3, 8, 8)).astype(np.float32)
    hm = np.array([1, 0, 1], np.float32)
    out = np.asarray(_mha_pallas(jnp.array(q), jnp.array(q), jnp.array(q),
                                 jnp.array(hm), False))
    assert np.all(out[:, 1] == 0.0)


def test_mha_custom_vjp_matches_numeric():
    """Hand-derived backward vs finite differences."""
    import jax
    rng = _rng(3)
    q = rng.normal(size=(1, 2, 6, 4)).astype(np.float32)
    k = rng.normal(size=(1, 2, 6, 4)).astype(np.float32)
    v = rng.normal(size=(1, 2, 6, 4)).astype(np.float32)
    hm = np.array([1.0, 1.0], np.float32)

    def f(q_, k_, v_):
        return jnp.sum(jnp.sin(mha(q_, k_, v_, jnp.array(hm), True)))

    g = jax.grad(f, argnums=(0, 1, 2))(jnp.array(q), jnp.array(k), jnp.array(v))
    eps = 1e-3
    for argi, arr in enumerate([q, k, v]):
        idx = (0, 1, 2, 1)
        pert = arr.copy(); pert[idx] += eps
        args = [q, k, v]; args[argi] = pert
        fp = float(f(*map(jnp.array, args)))
        pert2 = arr.copy(); pert2[idx] -= eps
        args[argi] = pert2
        fm = float(f(*map(jnp.array, args)))
        num = (fp - fm) / (2 * eps)
        assert abs(num - float(np.asarray(g[argi])[idx])) < 5e-2


# -------------------------------------------------------------- linalg (HLO)

@settings(max_examples=15, deadline=None)
@given(n=st.sampled_from([1, 2, 8, 17, 48]), seed=st.integers(0, 2**16))
def test_gauss_jordan_inverse(n, seed):
    a = _spd(_rng(seed), n)
    got = np.asarray(KL.gauss_jordan_inverse(jnp.array(a)))
    np.testing.assert_allclose(got @ a, np.eye(n), rtol=0, atol=5e-3)


@settings(max_examples=10, deadline=None)
@given(m=st.sampled_from([1, 3, 9]), n=st.sampled_from([1, 4, 16]),
       seed=st.integers(0, 2**16))
def test_batched_gauss_jordan_inverse(m, n, seed):
    rng = _rng(seed)
    a = np.stack([_spd(rng, n) for _ in range(m)])
    got = np.asarray(KL.batched_gauss_jordan_inverse(jnp.array(a)))
    for i in range(m):
        np.testing.assert_allclose(got[i] @ a[i], np.eye(n), rtol=0, atol=5e-3)


@settings(max_examples=10, deadline=None)
@given(n=st.sampled_from([2, 8, 24]), seed=st.integers(0, 2**16))
def test_cholesky_inverse_cross_check(n, seed):
    a = _spd(_rng(seed), n)
    gj = np.asarray(KL.gauss_jordan_inverse(jnp.array(a)))
    ch = np.asarray(KL.cholesky_inverse(jnp.array(a)))
    np.testing.assert_allclose(gj, ch, rtol=1e-2, atol=1e-3)


# --------------------------------------------------- composed OBS step check

@settings(max_examples=10, deadline=None)
@given(d_row=st.sampled_from([8, 32]), n_s=st.sampled_from([4, 8]),
       g=st.sampled_from([1, 4]), seed=st.integers(0, 2**16))
def test_composed_obs_step_vs_numpy(d_row, n_s, g, seed):
    """pallas score->select->pallas update == ref_obs_full_step."""
    rng = _rng(seed)
    d_col = n_s * g
    w = rng.normal(size=(d_row, d_col)).astype(np.float32)
    hinv = _spd(rng, d_col, scale=0.5)
    # score all, select argmin, update
    wg = w.reshape(d_row, n_s, g)
    blocks = np.stack([hinv[i * g:(i + 1) * g, i * g:(i + 1) * g] for i in range(n_s)])
    binv = np.stack([np.linalg.inv(b) for b in blocks])
    scores = np.asarray(obs_scores(jnp.array(wg), jnp.array(binv.astype(np.float32))))
    j = int(np.argmin(scores))
    s = slice(j * g, (j + 1) * g)
    p = binv[j] @ hinv[s, :]
    w2 = np.array(rankg_update(jnp.array(w), jnp.array(w[:, s]), jnp.array(p.astype(np.float32))))
    w2[:, s] = 0.0
    w_ref, _ = R.ref_obs_full_step(w, hinv, j, g)
    np.testing.assert_allclose(w2, w_ref, rtol=1e-3, atol=1e-3)
