"""Manifest / artifact consistency (runs against a prebuilt artifacts/)."""

import json
import os

import pytest

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")

pytestmark = pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "manifest.json")),
    reason="artifacts not built (run `make artifacts`)",
)


def _manifest():
    with open(os.path.join(ART, "manifest.json")) as f:
        return json.load(f)


def test_all_artifact_files_exist():
    man = _manifest()
    for name, a in man["artifacts"].items():
        path = os.path.join(ART, a["file"])
        assert os.path.exists(path), name
        assert os.path.getsize(path) > 100, name


def test_no_custom_calls_anywhere():
    man = _manifest()
    for name, a in man["artifacts"].items():
        with open(os.path.join(ART, a["file"])) as f:
            txt = f.read()
        assert "custom-call" not in txt, f"{name} contains a custom-call"


def test_layout_offsets_are_contiguous():
    man = _manifest()
    for mname, m in man["models"].items():
        for tname, t in m["tasks"].items():
            cur = 0
            for entry in t["layout"]:
                assert entry["offset"] == cur, (mname, tname, entry["name"])
                n = 1
                for s in entry["shape"]:
                    n *= s
                cur += n
            assert cur == t["n_params"]


def test_train_step_signature_shapes():
    man = _manifest()
    a = man["artifacts"]["bert-syn-base__sst2-syn__train_step"]
    P = man["models"]["bert-syn-base"]["tasks"]["sst2-syn"]["n_params"]
    assert a["inputs"][0]["shape"] == [P]
    assert a["outputs"][0]["shape"] == [P]
    assert len(a["outputs"]) == 6


def test_ladders_monotone():
    man = _manifest()
    for m in man["models"].values():
        lad = m["ffn_ladder"]
        assert lad[0] == m["d_ff"] and lad[-1] == 0
        assert all(a > b for a, b in zip(lad, lad[1:]))
