"""Model / task configurations and the packed-parameter layout.

This module is the single source of truth for:
  * the synthetic model family (bert-syn-base, bert-syn-large, gpt-syn),
  * the flat f32 parameter packing (name, shape, offset) shared with the
    Rust coordinator via artifacts/manifest.json,
  * the FFN shrink ladder (0.9^i steps, Sec. 3.2 of the paper).

Everything downstream (model.py, prune_graphs.py, aot.py, the Rust side)
derives shapes from here; nothing is duplicated by hand.
"""

from dataclasses import dataclass, field
from typing import Dict, List, Tuple


@dataclass(frozen=True)
class ModelConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    d_head: int
    d_ff: int
    vocab: int
    seq_len: int
    causal: bool  # False => BERT-style post-LN encoder; True => GPT pre-LN decoder

    @property
    def d_attn(self) -> int:
        return self.n_heads * self.d_head


# Scaled for a single-core CPU testbed (see DESIGN.md §3): the pruning
# algorithm and every trade-off the paper measures are shape phenomena.
BERT_SYN_BASE = ModelConfig("bert-syn-base", 4, 128, 4, 32, 512, 2048, 64, False)
BERT_SYN_LARGE = ModelConfig("bert-syn-large", 8, 192, 6, 32, 768, 2048, 64, False)
GPT_SYN = ModelConfig("gpt-syn", 4, 128, 4, 32, 512, 2048, 128, True)

MODELS: Dict[str, ModelConfig] = {
    m.name: m for m in (BERT_SYN_BASE, BERT_SYN_LARGE, GPT_SYN)
}


@dataclass(frozen=True)
class TaskConfig:
    name: str
    kind: str       # "cls" | "span" | "lm"
    n_classes: int  # used by "cls" only


TASKS: Dict[str, TaskConfig] = {
    "sst2-syn": TaskConfig("sst2-syn", "cls", 2),
    "qnli-syn": TaskConfig("qnli-syn", "cls", 2),
    "mnli-syn": TaskConfig("mnli-syn", "cls", 3),
    "qqp-syn": TaskConfig("qqp-syn", "cls", 2),
    "squad-syn": TaskConfig("squad-syn", "span", 0),
    "corpus-syn": TaskConfig("corpus-syn", "lm", 0),
}

# Batch sizes baked into the lowered graphs (XLA is shape-static).
TRAIN_BATCH = 16
EVAL_BATCH = 32
CALIB_BATCH = 16


def param_layout(cfg: ModelConfig, task: TaskConfig) -> List[Tuple[str, Tuple[int, ...]]]:
    """Ordered (name, shape) list; packing offset = cumulative product sum."""
    d, f, V, S = cfg.d_model, cfg.d_ff, cfg.vocab, cfg.seq_len
    out: List[Tuple[str, Tuple[int, ...]]] = [
        ("tok_emb", (V, d)),
        ("pos_emb", (S, d)),
    ]
    if not cfg.causal:
        out += [("emb_ln_g", (d,)), ("emb_ln_b", (d,))]
    for l in range(cfg.n_layers):
        p = f"layer{l}."
        out += [
            (p + "wq", (d, cfg.d_attn)), (p + "bq", (cfg.d_attn,)),
            (p + "wk", (d, cfg.d_attn)), (p + "bk", (cfg.d_attn,)),
            (p + "wv", (d, cfg.d_attn)), (p + "bv", (cfg.d_attn,)),
            (p + "wo", (cfg.d_attn, d)), (p + "bo", (d,)),
            (p + "ln1_g", (d,)), (p + "ln1_b", (d,)),
            (p + "w1", (d, f)), (p + "b1", (f,)),
            (p + "w2", (f, d)), (p + "b2", (d,)),
            (p + "ln2_g", (d,)), (p + "ln2_b", (d,)),
        ]
    if task.kind == "cls":
        out += [("cls_w", (d, task.n_classes)), ("cls_b", (task.n_classes,))]
    elif task.kind == "span":
        out += [("span_w", (d,)), ("span_b", (1,))]
    else:  # lm: tied embeddings + final layer norm
        out += [("lnf_g", (d,)), ("lnf_b", (d,))]
    return out


def layout_offsets(layout) -> Dict[str, Tuple[int, Tuple[int, ...]]]:
    offs, cur = {}, 0
    for name, shape in layout:
        n = 1
        for s in shape:
            n *= s
        offs[name] = (cur, shape)
        cur += n
    return offs


def n_params(cfg: ModelConfig, task: TaskConfig) -> int:
    total = 0
    for _, shape in param_layout(cfg, task):
        n = 1
        for s in shape:
            n *= s
        total += n
    return total


def ffn_ladder(d_ff: int) -> List[int]:
    """FFN shrink ladder: d_ff * 0.9^i, deduplicated, down to <1% then 0.

    Mirrors the paper's latency-table granularity (Sec. 3.2 / App. E):
    relative steps of 10% until ~99% sparsity, plus full removal.
    """
    out, i = [], 0
    while True:
        v = int(round(d_ff * (0.9 ** i)))
        if v < max(1, d_ff // 100):
            break
        if not out or v < out[-1]:
            out.append(v)
        i += 1
    out.append(0)
    return out


def head_ladder(n_heads: int) -> List[int]:
    """Remaining-head counts from dense to fully dropped."""
    return list(range(n_heads, -1, -1))
