"""L2 graphs implementing the ZipLM pruning step (paper Algorithm 1).

Each graph is lowered per model architecture and executed from the Rust
pruner. The split of responsibilities mirrors the paper exactly:

  * `score_*`  — Eq. 2 saliencies for ALL candidate structures at once
                 (L1 Pallas kernel `obs_scores` on the hot path);
  * `update_*` — Eqs. 3-4 for the structure the coordinator selected
                 (selection lives in Rust: that is where
                 inference-awareness enters — the coordinator is free to
                 pick by pure saliency, by loss-per-latency, or to
                 snapshot database levels);
  * `update_fc_multi` — a while-loop fused variant that performs `n`
                 one-at-a-time FC-column removals per dispatch (the FC2
                 ladder removes ~10% of columns between database levels,
                 so per-step PJRT round-trips would dominate; see
                 EXPERIMENTS.md §Perf).

Conventions: W is in "paper orientation" [d_row, d_col] with structures
as groups of g consecutive COLUMNS (attention: g = d_head over the
out-projection's input dim; FC2: g = 1 over the intermediate dim);
Hinv = (2 X X^T + λI)^{-1} is supplied by the Rust side (native
Cholesky); `active` marks structures still present.
"""

import jax
import jax.numpy as jnp

from .configs import ModelConfig
from .kernels.linalg import batched_gauss_jordan_inverse
from .kernels.obs_score import obs_scores
from .kernels.rankg_update import rankg_update

BIG = 1e30  # score assigned to already-pruned structures


# --------------------------------------------------------------------------
# scoring (Eq. 2)
# --------------------------------------------------------------------------

def _grouped(w: jnp.ndarray, g: int) -> jnp.ndarray:
    d_row, d_col = w.shape
    return w.reshape(d_row, d_col // g, g)


def _diag_blocks(hinv: jnp.ndarray, g: int) -> jnp.ndarray:
    n = hinv.shape[0] // g
    hr = hinv.reshape(n, g, n, g)
    idx = jnp.arange(n)
    return hr[idx, :, idx, :]  # [n, g, g]


def score_structures(w, hinv, active, *, g: int):
    """Saliency for every g-column structure; pruned ones get BIG.

    w: [d_row, n*g], hinv: [n*g, n*g], active: [n] (1 = present).
    """
    n = w.shape[1] // g
    blocks = _diag_blocks(hinv, g)
    eye = jnp.eye(g, dtype=w.dtype)
    safe = jnp.where(active[:, None, None] > 0, blocks, eye)
    binv = batched_gauss_jordan_inverse(safe)
    scores = obs_scores(_grouped(w, g), binv)  # L1 Pallas kernel
    return (jnp.where(active > 0, scores, BIG),)


# --------------------------------------------------------------------------
# update (Eqs. 3-4)
# --------------------------------------------------------------------------

def _zero_structure_cols(w, idx, g):
    col = jnp.arange(w.shape[1]) // g == idx
    return jnp.where(col[None, :], 0.0, w)


def _scrub_hinv(hinv, idx, g):
    """Zero rows/cols of the removed structure, put 1 on its diagonal.

    Algebraically they are already ~0 after the downdate (Eq. 4); the
    scrub removes float dust so later block inversions stay benign.
    """
    e = (jnp.arange(hinv.shape[0]) // g == idx).astype(hinv.dtype)
    keep = (1.0 - e)[:, None] * (1.0 - e)[None, :]
    return hinv * keep + jnp.diag(e)


def update_structure(w, hinv, idx, *, g: int):
    """Remove structure `idx`: apply delta_S to W and downdate Hinv.

    w: [d_row, n*g], hinv: [n*g, n*g], idx: int32 scalar.
    Returns (w', hinv').
    """
    d_col = w.shape[1]
    start = idx * g
    block = jax.lax.dynamic_slice(hinv, (start, start), (g, g))
    binv = batched_gauss_jordan_inverse(block[None])[0]
    rows = jax.lax.dynamic_slice(hinv, (start, jnp.int32(0)), (g, d_col))
    p = binv @ rows                                             # [g, d_col]
    wc = jax.lax.dynamic_slice(w, (jnp.int32(0), start), (w.shape[0], g))
    hc = jax.lax.dynamic_slice(hinv, (jnp.int32(0), start), (d_col, g))
    w2 = rankg_update(w, wc, p)        # L1 Pallas kernel (Eq. 3)
    h2 = rankg_update(hinv, hc, p)     # L1 Pallas kernel (Eq. 4)
    w2 = _zero_structure_cols(w2, idx, g)
    h2 = _scrub_hinv(h2, idx, g)
    return w2, h2


# --------------------------------------------------------------------------
# fused multi-step FC pruning (g = 1), selection by pure saliency
# --------------------------------------------------------------------------

def update_fc_multi(w, hinv, active, n):
    """Run `n` one-at-a-time FC-column removals inside one executable.

    Selection inside the loop follows Algorithm 1 exactly (argmin of
    Eq. 2 with g=1: score_j = sum_i w_ij^2 / hinv_jj). Returns
    (w', hinv', active', order) where order[k] is the k-th removed
    column (-1 padding).
    """
    f = w.shape[1]

    def cond(carry):
        _, _, _, _, i = carry
        return i < n

    def body(carry):
        w_, h_, act, order, i = carry
        diag = jnp.diagonal(h_)
        scores = jnp.sum(jnp.square(w_), axis=0) / jnp.where(act > 0, diag, 1.0)
        scores = jnp.where(act > 0, scores, BIG)
        j = jnp.argmin(scores).astype(jnp.int32)
        pj = h_[:, j] / h_[j, j]          # [f]; Hinv is symmetric: col == row
        w2 = w_ - jnp.outer(w_[:, j], pj)  # rank-1 Eq. 3
        h2 = h_ - jnp.outer(h_[:, j], pj)  # rank-1 Eq. 4
        e = (jnp.arange(f) == j).astype(w_.dtype)
        w2 = w2 * (1.0 - e)[None, :]
        h2 = h2 * ((1.0 - e)[:, None] * (1.0 - e)[None, :]) + jnp.diag(e)
        act2 = act * (1.0 - e)
        order2 = order.at[i].set(j)
        return (w2, h2, act2, order2, i + 1)

    order0 = jnp.full((f,), -1, jnp.int32)
    w2, h2, act2, order, _ = jax.lax.while_loop(
        cond, body, (w, hinv, active, order0, jnp.int32(0))
    )
    return w2, h2, act2, order


# --------------------------------------------------------------------------
# graph factories used by aot.py
# --------------------------------------------------------------------------

def make_score_attn(cfg: ModelConfig):
    def f(w, hinv, active):
        return score_structures(w, hinv, active, g=cfg.d_head)
    return f


def make_update_attn(cfg: ModelConfig):
    def f(w, hinv, idx):
        return update_structure(w, hinv, idx, g=cfg.d_head)
    return f


def make_score_fc(cfg: ModelConfig):
    def f(w, hinv, active):
        return score_structures(w, hinv, active, g=1)
    return f


def make_update_fc(cfg: ModelConfig):
    def f(w, hinv, idx):
        return update_structure(w, hinv, idx, g=1)
    return f
