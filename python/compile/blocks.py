"""Standalone transformer sub-blocks at MATERIALIZED sizes.

These are what the latency table is built from (paper Sec. 3.2 /
App. E): "we record the time to run an attention block, including all
overheads, with 0..N_heads-1 heads pruned, and similarly for the
fully-connected block with the intermediate dimension shrunk by 0.9^i".

Each graph is a real residual sub-block (projections + residual + LN),
lowered at the exact pruned width, so the Rust latency/measure.rs
harness times the same artifact kind the deployed model is built from.
Two batch regimes are emitted per size: "throughput" (the model-native
batch) and "latency" (batch 1, short prompt) — the distinction that
drives the paper's Table 1 depth-vs-width finding.
"""

import math

import jax
import jax.numpy as jnp

from .configs import ModelConfig
from .model import gelu_tanh, layer_norm


def attn_block_fn(cfg: ModelConfig, n_heads: int):
    """Materialized attention block with `n_heads` heads remaining."""
    dh = cfg.d_head

    def f(x, wq, bq, wk, bk, wv, bv, wo, bo, ln_g, ln_b):
        b_, s_, d = x.shape

        def split(t):
            return t.reshape(b_, s_, n_heads, dh).transpose(0, 2, 1, 3)

        q, k, v = split(x @ wq + bq), split(x @ wk + bk), split(x @ wv + bv)
        s = jnp.einsum("bhid,bhjd->bhij", q, k) / math.sqrt(dh)
        if cfg.causal:
            msk = jnp.tril(jnp.ones((s_, s_), bool))
            s = jnp.where(msk[None, None], s, -1e30)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bhij,bhjd->bhid", p, v)
        o = o.transpose(0, 2, 1, 3).reshape(b_, s_, n_heads * dh)
        return (layer_norm(x + (o @ wo + bo), ln_g, ln_b),)

    return f


def mlp_block_fn(cfg: ModelConfig, inter: int):
    """Materialized FFN block with intermediate width `inter`."""

    def f(x, w1, b1, w2, b2, ln_g, ln_b):
        a = gelu_tanh(x @ w1 + b1)
        return (layer_norm(x + (a @ w2 + b2), ln_g, ln_b),)

    return f


def attn_block_specs(cfg: ModelConfig, n_heads: int, batch: int, seq: int):
    d, a = cfg.d_model, n_heads * cfg.d_head
    f32 = jnp.float32
    return [
        jax.ShapeDtypeStruct((batch, seq, d), f32),
        jax.ShapeDtypeStruct((d, a), f32), jax.ShapeDtypeStruct((a,), f32),
        jax.ShapeDtypeStruct((d, a), f32), jax.ShapeDtypeStruct((a,), f32),
        jax.ShapeDtypeStruct((d, a), f32), jax.ShapeDtypeStruct((a,), f32),
        jax.ShapeDtypeStruct((a, d), f32), jax.ShapeDtypeStruct((d,), f32),
        jax.ShapeDtypeStruct((d,), f32), jax.ShapeDtypeStruct((d,), f32),
    ]


def mlp_block_specs(cfg: ModelConfig, inter: int, batch: int, seq: int):
    d = cfg.d_model
    f32 = jnp.float32
    return [
        jax.ShapeDtypeStruct((batch, seq, d), f32),
        jax.ShapeDtypeStruct((d, inter), f32), jax.ShapeDtypeStruct((inter,), f32),
        jax.ShapeDtypeStruct((inter, d), f32), jax.ShapeDtypeStruct((d,), f32),
        jax.ShapeDtypeStruct((d,), f32), jax.ShapeDtypeStruct((d,), f32),
    ]
