"""AOT compiler: lowers every graph to HLO TEXT + writes the manifest.

This is the ONLY entry point that runs Python; afterwards the Rust
coordinator is self-contained. Interchange is HLO *text*, not
serialized HloModuleProto: jax >= 0.5 emits protos with 64-bit
instruction ids which xla_extension 0.5.1 rejects; the text parser
reassigns ids (see /opt/xla-example/README.md).

Usage:
    python -m compile.aot --out ../artifacts              # full build
    python -m compile.aot --specialize spec.json --out d  # deployed model

The manifest (artifacts/manifest.json) tells Rust everything: model
configs, packed-parameter layouts, ladders, and per-artifact I/O
signatures, so shapes are never duplicated by hand on the Rust side.
"""

import argparse
import functools
import hashlib
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import blocks as BL
from . import model as M
from . import prune_graphs as PG
from .configs import (CALIB_BATCH, EVAL_BATCH, MODELS, TASKS, TRAIN_BATCH,
                      ffn_ladder, head_ladder, layout_offsets, n_params,
                      param_layout)
from .specialized import specialized_fwd

F32, I32 = jnp.float32, jnp.int32

# (model, task) pairs we train/prune — mirrors the paper's eval matrix.
PAIRS = [
    ("bert-syn-base", "sst2-syn"),
    ("bert-syn-base", "qnli-syn"),
    ("bert-syn-base", "mnli-syn"),
    ("bert-syn-base", "qqp-syn"),
    ("bert-syn-base", "squad-syn"),
    ("bert-syn-large", "squad-syn"),
    ("gpt-syn", "corpus-syn"),
]

# latency-table batch regimes (paper Sec. 4: throughput vs latency pruning)
REGIMES = {"throughput": (16, None), "latency": (1, 16)}  # None -> model seq


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(*shape, dt=F32):
    return jax.ShapeDtypeStruct(shape, dt)


def _sig(avals):
    out = []
    for a in avals:
        out.append({"shape": list(a.shape), "dtype": "i32" if a.dtype == jnp.int32 else "f32"})
    return out


class Emitter:
    def __init__(self, out_dir: str):
        self.out = out_dir
        self.artifacts = {}
        os.makedirs(out_dir, exist_ok=True)

    def emit(self, name: str, fn, in_specs, meta=None):
        t0 = time.time()
        lowered = jax.jit(fn).lower(*in_specs)
        txt = to_hlo_text(lowered)
        assert "custom-call" not in txt, f"{name}: custom-call leaked into HLO"
        fname = f"{name}.hlo.txt"
        with open(os.path.join(self.out, fname), "w") as f:
            f.write(txt)
        out_avals = jax.eval_shape(fn, *in_specs)
        if not isinstance(out_avals, (tuple, list)):
            out_avals = (out_avals,)
        self.artifacts[name] = {
            "file": fname,
            "inputs": _sig(in_specs),
            "outputs": _sig(out_avals),
            **(meta or {}),
        }
        print(f"  {name:56s} {len(txt)//1024:5d} KiB  {time.time()-t0:5.1f}s", flush=True)


def emit_pair(em: Emitter, model_name: str, task_name: str):
    cfg, task = MODELS[model_name], TASKS[task_name]
    P = n_params(cfg, task)
    L, H, F, SQ, D, V = cfg.n_layers, cfg.n_heads, cfg.d_ff, cfg.seq_len, cfg.d_model, cfg.vocab
    pre = f"{model_name}__{task_name}"

    if task.kind == "cls":
        lab_e, lab_t = spec(EVAL_BATCH, dt=I32), spec(TRAIN_BATCH, dt=I32)
        logits_t = spec(TRAIN_BATCH, task.n_classes)
    elif task.kind == "span":
        lab_e, lab_t = spec(EVAL_BATCH, dt=I32), spec(TRAIN_BATCH, dt=I32)
        logits_t = spec(TRAIN_BATCH, SQ)
    else:
        lab_e, lab_t = spec(EVAL_BATCH, SQ, dt=I32), spec(TRAIN_BATCH, SQ, dt=I32)
        logits_t = spec(TRAIN_BATCH, SQ, V)

    em.emit(f"{pre}__fwd", functools.partial(M.fwd, cfg=cfg, task=task),
            [spec(P), spec(EVAL_BATCH, SQ, dt=I32), spec(L, H), spec(L, F)])
    em.emit(f"{pre}__eval_loss", functools.partial(M.eval_loss, cfg=cfg, task=task),
            [spec(P), spec(EVAL_BATCH, SQ, dt=I32), lab_e, spec(L, H), spec(L, F)])
    em.emit(f"{pre}__teacher_fwd", functools.partial(M.teacher_fwd, cfg=cfg, task=task),
            [spec(P), spec(TRAIN_BATCH, SQ, dt=I32)])
    em.emit(f"{pre}__train_step", functools.partial(M.train_step, cfg=cfg, task=task),
            [spec(P), spec(P), spec(P), spec(), spec(),
             spec(TRAIN_BATCH, SQ, dt=I32), lab_t, spec(L, H), spec(L, F),
             logits_t, spec(L, TRAIN_BATCH, SQ, D), spec(TRAIN_BATCH, SQ),
             spec(3), spec()])
    em.emit(f"{pre}__train_step_nokd", functools.partial(M.train_step_nokd, cfg=cfg, task=task),
            [spec(P), spec(P), spec(P), spec(), spec(),
             spec(TRAIN_BATCH, SQ, dt=I32), lab_t, spec(L, H), spec(L, F), spec()])
    em.emit(f"{pre}__calib", functools.partial(M.calib_capture, cfg=cfg, task=task),
            [spec(P), spec(CALIB_BATCH, SQ, dt=I32), spec(L, H), spec(L, F)])


def emit_prune(em: Emitter, model_name: str):
    cfg = MODELS[model_name]
    A, F, D = cfg.d_attn, cfg.d_ff, cfg.d_model
    pre = model_name
    em.emit(f"{pre}__score_attn", PG.make_score_attn(cfg),
            [spec(D, A), spec(A, A), spec(cfg.n_heads)])
    em.emit(f"{pre}__update_attn", PG.make_update_attn(cfg),
            [spec(D, A), spec(A, A), spec(dt=I32)])
    em.emit(f"{pre}__score_fc", PG.make_score_fc(cfg),
            [spec(D, F), spec(F, F), spec(F)])
    em.emit(f"{pre}__update_fc", PG.make_update_fc(cfg),
            [spec(D, F), spec(F, F), spec(dt=I32)])
    em.emit(f"{pre}__update_fc_multi", PG.update_fc_multi,
            [spec(D, F), spec(F, F), spec(F), spec(dt=I32)])


def measured_ladder(d_ff: int):
    """Subset of the FFN ladder that gets real on-device measurements;
    the Rust latency table linearly interpolates between them."""
    lad = [x for x in ffn_ladder(d_ff) if x > 0]
    return sorted(set(lad[::3] + [lad[0], lad[-1]]), reverse=True)


def emit_blocks(em: Emitter, model_name: str):
    cfg = MODELS[model_name]
    for regime, (b, s) in REGIMES.items():
        s_ = s or cfg.seq_len
        for h in range(1, cfg.n_heads + 1):
            em.emit(f"{model_name}__block_attn_h{h}__{regime}",
                    BL.attn_block_fn(cfg, h), BL.attn_block_specs(cfg, h, b, s_),
                    meta={"kind": "block_attn", "heads": h, "regime": regime,
                          "batch": b, "seq": s_})
        for f in measured_ladder(cfg.d_ff):
            em.emit(f"{model_name}__block_mlp_f{f}__{regime}",
                    BL.mlp_block_fn(cfg, f), BL.mlp_block_specs(cfg, f, b, s_),
                    meta={"kind": "block_mlp", "inter": f, "regime": regime,
                          "batch": b, "seq": s_})


def build_manifest(em: Emitter):
    models = {}
    for name, cfg in MODELS.items():
        tasks = {}
        for tname, task in TASKS.items():
            if (name, tname) not in PAIRS:
                continue
            layout = param_layout(cfg, task)
            offs = layout_offsets(layout)
            tasks[tname] = {
                "n_params": n_params(cfg, task),
                "kind": task.kind,
                "n_classes": task.n_classes,
                "layout": [
                    {"name": n, "shape": list(shape), "offset": offs[n][0]}
                    for n, shape in layout
                ],
            }
        models[name] = {
            "n_layers": cfg.n_layers, "d_model": cfg.d_model,
            "n_heads": cfg.n_heads, "d_head": cfg.d_head, "d_ff": cfg.d_ff,
            "vocab": cfg.vocab, "seq_len": cfg.seq_len, "causal": cfg.causal,
            "tasks": tasks,
            "ffn_ladder": ffn_ladder(cfg.d_ff),
            "head_ladder": head_ladder(cfg.n_heads),
            "measured_ffn": measured_ladder(cfg.d_ff),
        }
    return {
        "version": 1,
        "batch": {"train": TRAIN_BATCH, "eval": EVAL_BATCH, "calib": CALIB_BATCH},
        "models": models,
        "artifacts": em.artifacts,
    }


def specialize(spec_path: str, out_dir: str):
    """Emit a shape-materialized pruned model (deployment export)."""
    with open(spec_path) as f:
        sp = json.load(f)
    cfg, task = MODELS[sp["model"]], TASKS[sp["task"]]
    heads, inters = sp["heads"], sp["inters"]
    batch = sp.get("batch", 1)
    seq = sp.get("seq", cfg.seq_len)
    name = sp.get("name", "specialized")
    em = Emitter(out_dir)
    fn, layout = specialized_fwd(cfg, task, heads, inters)
    total = 0
    for _, shape in layout:
        n = 1
        for s_ in shape:
            n *= s_
        total += n
    em.emit(name, fn, [spec(total), spec(batch, seq, dt=I32)],
            meta={"kind": "specialized", "model": sp["model"], "task": sp["task"],
                  "heads": heads, "inters": inters, "batch": batch, "seq": seq})
    offs = layout_offsets(layout)
    man = {
        "n_params": total,
        "layout": [{"name": n, "shape": list(shape), "offset": offs[n][0]}
                   for n, shape in layout],
        "artifacts": em.artifacts,
    }
    with open(os.path.join(out_dir, f"{name}.manifest.json"), "w") as f:
        json.dump(man, f, indent=1)
    print(f"specialized -> {out_dir}/{name}.hlo.txt")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--specialize", default=None, help="spec JSON for deployed export")
    ap.add_argument("--only", default=None, help="comma list: pairs,prune,blocks")
    args = ap.parse_args()

    if args.specialize:
        specialize(args.specialize, args.out)
        return

    only = set(args.only.split(",")) if args.only else {"pairs", "prune", "blocks"}
    em = Emitter(args.out)
    t0 = time.time()
    if "pairs" in only:
        for m, t in PAIRS:
            print(f"[pair] {m} / {t}", flush=True)
            emit_pair(em, m, t)
    if "prune" in only:
        for m in MODELS:
            print(f"[prune] {m}", flush=True)
            emit_prune(em, m)
    if "blocks" in only:
        for m in MODELS:
            print(f"[blocks] {m}", flush=True)
            emit_blocks(em, m)
    man = build_manifest(em)
    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(man, f, indent=1)
    print(f"wrote {len(em.artifacts)} artifacts + manifest in {time.time()-t0:.0f}s")


if __name__ == "__main__":
    main()
