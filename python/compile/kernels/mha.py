"""Pallas kernel: fused head-masked multi-head attention core.

Computes, per (batch, head) grid step,

    out[b, h] = head_mask[h] * softmax(q[b,h] @ k[b,h]^T / sqrt(dh) + causal) @ v[b,h]

i.e. the paper's structural head masking is fused into the attention
core itself: a pruned head produces exact zeros, so the out-projection
input matches a materialized (column-removed) model bit-for-bit.

TPU mapping: the grid iterates (B * n_heads); each step holds one
head's q, k, v ([S, dh] each), the [S, S] score matrix and the output
in VMEM (S <= 128, dh = 32 here -> < 1 MiB); both matmuls are MXU
work. Softmax is computed with the usual max-subtraction for
stability. interpret=True; oracle in kernels/ref.py.
"""

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _mha_kernel(q_ref, k_ref, v_ref, mask_ref, out_ref, *, causal: bool, scale: float):
    q = q_ref[0, 0]  # [S, dh]
    k = k_ref[0, 0]
    v = v_ref[0, 0]
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale  # [S, S]
    if causal:
        seq = q.shape[0]
        i = jax.lax.broadcasted_iota(jnp.int32, (seq, seq), 0)
        j = jax.lax.broadcasted_iota(jnp.int32, (seq, seq), 1)
        s = jnp.where(j > i, -1e30, s)
    s = s - jnp.max(s, axis=-1, keepdims=True)
    e = jnp.exp(s)
    p = e / jnp.sum(e, axis=-1, keepdims=True)
    o = jnp.dot(p, v, preferred_element_type=jnp.float32)  # [S, dh]
    out_ref[0, 0] = o * mask_ref[0]


def _mha_pallas(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, head_mask: jnp.ndarray,
                causal: bool) -> jnp.ndarray:
    """q, k, v: [B, H, S, dh]; head_mask: [H] -> out [B, H, S, dh]."""
    b, h, s, dh = q.shape
    scale = 1.0 / math.sqrt(dh)
    kern = functools.partial(_mha_kernel, causal=causal, scale=scale)
    return pl.pallas_call(
        kern,
        grid=(b, h),
        in_specs=[
            pl.BlockSpec((1, 1, s, dh), lambda bi, hi: (bi, hi, 0, 0)),
            pl.BlockSpec((1, 1, s, dh), lambda bi, hi: (bi, hi, 0, 0)),
            pl.BlockSpec((1, 1, s, dh), lambda bi, hi: (bi, hi, 0, 0)),
            pl.BlockSpec((1,), lambda bi, hi: (hi,)),
        ],
        out_specs=pl.BlockSpec((1, 1, s, dh), lambda bi, hi: (bi, hi, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, s, dh), jnp.float32),
        interpret=True,
    )(q, k, v, head_mask)


# ---------------------------------------------------------------------------
# custom VJP: Pallas has no reverse-mode rule, so the backward pass is the
# hand-derived attention gradient in plain jnp (recompute-probabilities
# flavour — no residual besides the inputs). The forward stays on the L1
# kernel, so train_step's fwd and fwd-only graphs execute the exact same
# kernel path.
# ---------------------------------------------------------------------------

def _probs(q, k, causal):
    dh = q.shape[-1]
    s = jnp.einsum("bhid,bhjd->bhij", q, k) / math.sqrt(dh)
    if causal:
        seq = q.shape[2]
        msk = jnp.tril(jnp.ones((seq, seq), bool))
        s = jnp.where(msk[None, None], s, -1e30)
    return jax.nn.softmax(s, axis=-1)


@functools.partial(jax.custom_vjp, nondiff_argnums=(4,))
def mha(q, k, v, head_mask, causal: bool):
    return _mha_pallas(q, k, v, head_mask, causal)


def _mha_fwd(q, k, v, head_mask, causal):
    return _mha_pallas(q, k, v, head_mask, causal), (q, k, v, head_mask)


def _mha_bwd(causal, res, dout):
    q, k, v, head_mask, = res
    dh = q.shape[-1]
    p = _probs(q, k, causal)                                   # [B,H,S,S]
    dm = dout * head_mask[None, :, None, None]                 # mask folds in
    dv = jnp.einsum("bhij,bhid->bhjd", p, dm)
    dp = jnp.einsum("bhid,bhjd->bhij", dm, v)
    ds = p * (dp - jnp.sum(dp * p, axis=-1, keepdims=True))
    scale = 1.0 / math.sqrt(dh)
    dq = jnp.einsum("bhij,bhjd->bhid", ds, k) * scale
    dk = jnp.einsum("bhij,bhid->bhjd", ds, q) * scale
    dmask = jnp.einsum("bhij,bhjd,bhid->h", p, v, dout)
    return dq, dk, dv, dmask


mha.defvjp(_mha_fwd, _mha_bwd)
