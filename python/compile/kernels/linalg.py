"""Pure-HLO dense linear algebra used inside lowered graphs.

jax 0.8 lowers `jnp.linalg.*` to LAPACK FFI custom-calls that the
xla_extension 0.5.1 CPU runtime (used by the Rust `xla` crate) does not
register, so none of those may appear in any lowered module. These
routines use only elementwise ops, matmuls and `lax` loops, which lower
to plain HLO and round-trip through the HLO-text interchange.

All matrices here are symmetric positive definite (damped Hessians /
their inverses), so Gauss-Jordan without pivoting is numerically safe.
"""

import jax
import jax.numpy as jnp


def gauss_jordan_inverse(a: jnp.ndarray) -> jnp.ndarray:
    """Inverse of an SPD matrix via Gauss-Jordan, plain-HLO only.

    a: [n, n] float32. Returns [n, n].
    """
    n = a.shape[-1]
    # Standard augmented [A | I] elimination.
    aug0 = jnp.concatenate([a, jnp.eye(n, dtype=a.dtype)], axis=1)

    def step(k, aug):
        pivot = aug[k, k]
        row = aug[k] / pivot
        factors = aug[:, k].at[k].set(0.0)
        aug = aug - factors[:, None] * row[None, :]
        aug = aug.at[k].set(row)
        return aug

    aug = jax.lax.fori_loop(0, n, step, aug0)
    return aug[:, n:]


def batched_gauss_jordan_inverse(a: jnp.ndarray) -> jnp.ndarray:
    """Batched SPD inverse. a: [m, n, n] -> [m, n, n], plain-HLO only."""
    m, n, _ = a.shape
    aug0 = jnp.concatenate(
        [a, jnp.broadcast_to(jnp.eye(n, dtype=a.dtype), (m, n, n))], axis=2
    )

    def step(k, aug):
        pivot = aug[:, k, k]  # [m]
        row = aug[:, k, :] / pivot[:, None]  # [m, 2n]
        factors = aug[:, :, k]  # [m, n]
        factors = factors.at[:, k].set(0.0)
        aug = aug - factors[:, :, None] * row[:, None, :]
        aug = aug.at[:, k, :].set(row)
        return aug

    aug = jax.lax.fori_loop(0, n, step, aug0)
    return aug[:, :, n:]


def cholesky_inverse(a: jnp.ndarray) -> jnp.ndarray:
    """SPD inverse via unblocked Cholesky + two triangular solves.

    Kept as an alternative path (same plain-HLO constraint); used by
    tests to cross-check gauss_jordan_inverse.
    """
    n = a.shape[-1]

    def chol_step(j, l):
        # l holds the partial Cholesky factor (lower), built column by column:
        # l[i, j] = (a[i, j] - sum_{k<j} l[i, k] l[j, k]) / l[j, j]
        lj = jax.lax.dynamic_slice_in_dim(l, j, 1, axis=0)[0]  # row j
        mask = jnp.arange(n) < j
        ljm = jnp.where(mask, lj, 0.0)
        col = a[:, j] - l @ ljm
        diag = jnp.sqrt(col[j])
        newcol = jnp.where(jnp.arange(n) > j, col / diag, 0.0)
        newcol = newcol.at[j].set(diag)
        return l.at[:, j].set(newcol)

    l = jax.lax.fori_loop(0, n, chol_step, jnp.zeros_like(a))

    # Invert L by row-by-row forward substitution on the identity block:
    # x_i = (e_i - sum_{k<i} L[i,k] x_k) / L[i,i]
    def fs_step(i, x):
        li = l[i]
        mask = jnp.arange(n) < i
        lim = jnp.where(mask, li, 0.0)
        xi = (jnp.eye(n, dtype=a.dtype)[i] - lim @ x) / l[i, i]
        return x.at[i].set(xi)

    linv = jax.lax.fori_loop(0, n, fs_step, jnp.zeros_like(a))
    return linv.T @ linv
