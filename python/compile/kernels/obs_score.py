"""Pallas kernel: structured-OBS saliency scores (paper Eq. 2).

For every candidate structure S_j (a group of g consecutive columns of
the weight matrix W), compute

    score_j = sum_i  W[i, S_j]  @  Binv_j  @  W[i, S_j]^T

where Binv_j = ((H^{-1})_{S_j, S_j})^{-1} is the g x g inverse-Hessian
block inverse, precomputed by the surrounding L2 graph (see
prune_graphs.py) with the plain-HLO batched Gauss-Jordan.

This is the pruning hot-spot: it touches all of W for every pruning
step. TPU mapping (see DESIGN.md / EXPERIMENTS.md SPerf):

  * grid over row-tiles of W: each step streams a [TR, n_s*g] tile of W
    HBM->VMEM while Binv ([n_s, g, g]) and the score accumulator
    ([n_s]) stay VMEM-resident across the whole grid;
  * the quadratic form is evaluated as (Wt @ Binv_j) * Wt summed over
    rows -- batched g x g matmuls that map onto the MXU when g = d_head
    (>= 32); accumulation is f32;
  * VMEM footprint = TR*d_col + n_s*g*g + n_s floats, with TR chosen
    so the total stays far below ~16 MiB.

Kernels are lowered with interpret=True (CPU PJRT cannot execute Mosaic
custom-calls); correctness is pinned against kernels/ref.py by pytest +
hypothesis.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _obs_score_kernel(w_ref, binv_ref, out_ref):
    """One grid step: accumulate scores for a row-tile of W.

    w_ref:    [TR, n_s, g]  row-tile of W, columns grouped by structure
    binv_ref: [n_s, g, g]   per-structure inverse blocks (resident)
    out_ref:  [n_s]         score accumulator (revisited across grid)
    """

    @pl.when(pl.program_id(0) == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    w = w_ref[...]  # [TR, n_s, g]
    binv = binv_ref[...]  # [n_s, g, g]
    # t[r, j, :] = W[r, S_j] @ Binv_j   -> einsum over the g dimension
    t = jnp.einsum("rjg,jgh->rjh", w, binv, preferred_element_type=jnp.float32)
    # score_j += sum_r <t[r, j], w[r, j]>
    out_ref[...] += jnp.sum(t * w, axis=(0, 2))


def obs_scores(w_grouped: jnp.ndarray, binv: jnp.ndarray, row_tile: int = 64) -> jnp.ndarray:
    """Scores for all structures. w_grouped: [d_row, n_s, g], binv: [n_s, g, g]."""
    d_row, n_s, g = w_grouped.shape
    if d_row % row_tile != 0:
        # pad rows with zeros; zero rows contribute zero to every score
        pad = row_tile - d_row % row_tile
        w_grouped = jnp.pad(w_grouped, ((0, pad), (0, 0), (0, 0)))
        d_row = d_row + pad
    grid = (d_row // row_tile,)
    return pl.pallas_call(
        _obs_score_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((row_tile, n_s, g), lambda r: (r, 0, 0)),
            pl.BlockSpec((n_s, g, g), lambda r: (0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((n_s,), lambda r: (0,)),
        out_shape=jax.ShapeDtypeStruct((n_s,), jnp.float32),
        interpret=True,
    )(w_grouped, binv)
