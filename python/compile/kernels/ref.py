"""Pure-jnp oracles for every Pallas kernel — the correctness ground truth.

pytest (python/tests/test_kernels.py) asserts allclose between each
kernel and its oracle, with hypothesis sweeping shapes; the Rust side
additionally cross-checks its native mirrors against values produced
through the full HLO round-trip.
"""

import math

import jax
import jax.numpy as jnp
import numpy as np


def ref_obs_scores(w_grouped, binv):
    """score_j = sum_i W[i, S_j] Binv_j W[i, S_j]^T.

    w_grouped: [d_row, n_s, g], binv: [n_s, g, g] -> [n_s]
    """
    return jnp.einsum("rjg,jgh,rjh->j", w_grouped, binv, w_grouped)


def ref_rankg_update(a, c, p):
    """A - C @ P."""
    return a - c @ p


def ref_mha(q, k, v, head_mask, causal):
    """[B, H, S, dh] fused attention reference."""
    dh = q.shape[-1]
    s = jnp.einsum("bhid,bhjd->bhij", q, k) / math.sqrt(dh)
    if causal:
        seq = q.shape[2]
        msk = jnp.tril(jnp.ones((seq, seq), bool))
        s = jnp.where(msk[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhij,bhjd->bhid", p, v)
    return o * head_mask[None, :, None, None]


def ref_inverse(a):
    """numpy inverse (allowed in tests — never in lowered graphs)."""
    return np.linalg.inv(np.asarray(a))


def ref_obs_full_step(w, hinv, idx, g):
    """One complete structured-OBS removal in numpy: returns (w', hinv').

    w: [d_row, d_col] (paper orientation: structures are column groups),
    hinv: [d_col, d_col], idx: structure index, g: structure size.
    Mirrors Algorithm 1's inner loop exactly; used to pin both the
    Pallas kernels (composed) and the Rust-native mirror.
    """
    w = np.asarray(w, dtype=np.float64)
    hinv = np.asarray(hinv, dtype=np.float64)
    s = slice(idx * g, (idx + 1) * g)
    binv = np.linalg.inv(hinv[s, s])
    p = binv @ hinv[s, :]  # [g, d_col]
    w_new = w - w[:, s] @ p
    hinv_new = hinv - hinv[:, s] @ p
    w_new[:, s] = 0.0
    return w_new.astype(np.float32), hinv_new.astype(np.float32)
