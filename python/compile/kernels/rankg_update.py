"""Pallas kernel: fused rank-g OBS update (paper Eqs. 3-4).

After structure S (g columns) is selected, the remaining weights and
the inverse Hessian are updated:

    W'    = W    - W[:, S]    @ Binv @ Hinv[S, :]      (Eq. 3, delta_S)
    Hinv' = Hinv - Hinv[:, S] @ Binv @ Hinv[S, :]      (Eq. 4, one step
                                                        of block Gaussian
                                                        elimination)

Both share the g x d_col factor P = Binv @ Hinv[S, :], which the L2
graph precomputes once; the kernel then applies the rank-g update to
row-tiles of the target matrix:

    out_tile = A_tile - C_tile @ P

where (A, C) is (W, W[:, S]) or (Hinv, Hinv[:, S]). TPU mapping: grid
over row-tiles; P ([g, d_col]) stays VMEM-resident, each grid step
streams one [TR, d_col] tile plus its [TR, g] slab; the update is a
[TR, g] x [g, d_col] MXU matmul. VMEM = TR*d_col*2 + TR*g + g*d_col
floats.

Extraction of the S-indexed slabs and re-zeroing of pruned columns are
dynamic-slice ops in the surrounding graph (static shapes inside the
kernel). interpret=True; oracle in kernels/ref.py.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rankg_update_kernel(a_ref, c_ref, p_ref, out_ref):
    """out_tile = a_tile - c_tile @ p   (all f32).

    a_ref: [TR, d_col], c_ref: [TR, g], p_ref: [g, d_col], out_ref: [TR, d_col]
    """
    out_ref[...] = a_ref[...] - jnp.dot(
        c_ref[...], p_ref[...], preferred_element_type=jnp.float32
    )


def rankg_update(a: jnp.ndarray, c: jnp.ndarray, p: jnp.ndarray, row_tile: int = 64) -> jnp.ndarray:
    """Apply A - C @ P with row-tiling. a: [m, n], c: [m, g], p: [g, n]."""
    m, n = a.shape
    g = c.shape[1]
    pad = (-m) % row_tile
    if pad:
        a = jnp.pad(a, ((0, pad), (0, 0)))
        c = jnp.pad(c, ((0, pad), (0, 0)))
    grid = ((m + pad) // row_tile,)
    out = pl.pallas_call(
        _rankg_update_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((row_tile, n), lambda r: (r, 0)),
            pl.BlockSpec((row_tile, g), lambda r: (r, 0)),
            pl.BlockSpec((g, n), lambda r: (0, 0)),
        ],
        out_specs=pl.BlockSpec((row_tile, n), lambda r: (r, 0)),
        out_shape=jax.ShapeDtypeStruct((m + pad, n), jnp.float32),
        interpret=True,
    )(a, c, p)
    return out[:m] if pad else out
