"""Shape-materialized (deployed) model: the final export path.

During gradual pruning the Rust coordinator works on MASKED models (one
executable, masks as inputs). For deployment and for measuring
*achieved* speedup (paper Table 8), the pruned configuration is
re-lowered here with every weight matrix at its real pruned size and
fully-dropped modules removed from the graph — exactly the paper's
"model can be reshaped to new dimensions" property of structured
pruning.

`aot.py --specialize spec.json` drives this; the spec carries per-layer
remaining head counts and intermediate widths. The emitted manifest
section gives Rust the packed layout so it can gather surviving
rows/columns out of a masked checkpoint.
"""

import math
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from .configs import ModelConfig, TaskConfig, layout_offsets
from .model import gelu_tanh, layer_norm, logits_fn


def specialized_layout(cfg: ModelConfig, task: TaskConfig,
                       heads: List[int], inters: List[int]):
    """(name, shape) list for a materialized pruned model."""
    d, V, S = cfg.d_model, cfg.vocab, cfg.seq_len
    out: List[Tuple[str, Tuple[int, ...]]] = [("tok_emb", (V, d)), ("pos_emb", (S, d))]
    if not cfg.causal:
        out += [("emb_ln_g", (d,)), ("emb_ln_b", (d,))]
    for l in range(cfg.n_layers):
        p = f"layer{l}."
        a = heads[l] * cfg.d_head
        if heads[l] > 0:
            out += [
                (p + "wq", (d, a)), (p + "bq", (a,)),
                (p + "wk", (d, a)), (p + "bk", (a,)),
                (p + "wv", (d, a)), (p + "bv", (a,)),
                (p + "wo", (a, d)), (p + "bo", (d,)),
            ]
        out += [(p + "ln1_g", (d,)), (p + "ln1_b", (d,))]
        f = inters[l]
        if f > 0:
            out += [(p + "w1", (d, f)), (p + "b1", (f,)),
                    (p + "w2", (f, d)), (p + "b2", (d,))]
        out += [(p + "ln2_g", (d,)), (p + "ln2_b", (d,))]
    if task.kind == "cls":
        out += [("cls_w", (d, task.n_classes)), ("cls_b", (task.n_classes,))]
    elif task.kind == "span":
        out += [("span_w", (d,)), ("span_b", (1,))]
    else:
        out += [("lnf_g", (d,)), ("lnf_b", (d,))]
    return out


def specialized_fwd(cfg: ModelConfig, task: TaskConfig,
                    heads: List[int], inters: List[int]):
    """Forward with per-layer materialized widths; dropped modules elided."""
    layout = specialized_layout(cfg, task, heads, inters)
    offs = layout_offsets(layout)

    def f(flat, ids):
        p = {}
        for name, (off, shape) in offs.items():
            n = 1
            for s in shape:
                n *= s
            p[name] = jax.lax.dynamic_slice_in_dim(flat, off, n).reshape(shape)
        b_, s_ = ids.shape
        x = p["tok_emb"][ids] + p["pos_emb"][None, :s_, :]
        if not cfg.causal:
            x = layer_norm(x, p["emb_ln_g"], p["emb_ln_b"])
        for l in range(cfg.n_layers):
            pre = f"layer{l}."
            h, dh, fl = heads[l], cfg.d_head, inters[l]

            def attn(xin):
                def split(t):
                    return t.reshape(b_, s_, h, dh).transpose(0, 2, 1, 3)
                q = split(xin @ p[pre + "wq"] + p[pre + "bq"])
                k = split(xin @ p[pre + "wk"] + p[pre + "bk"])
                v = split(xin @ p[pre + "wv"] + p[pre + "bv"])
                s = jnp.einsum("bhid,bhjd->bhij", q, k) / math.sqrt(dh)
                if cfg.causal:
                    msk = jnp.tril(jnp.ones((s_, s_), bool))
                    s = jnp.where(msk[None, None], s, -1e30)
                pr = jax.nn.softmax(s, axis=-1)
                o = jnp.einsum("bhij,bhjd->bhid", pr, v)
                o = o.transpose(0, 2, 1, 3).reshape(b_, s_, h * dh)
                return o @ p[pre + "wo"] + p[pre + "bo"]

            def ffn(xin):
                a = gelu_tanh(xin @ p[pre + "w1"] + p[pre + "b1"])
                return a @ p[pre + "w2"] + p[pre + "b2"]

            if cfg.causal:
                if h > 0:
                    x = x + attn(layer_norm(x, p[pre + "ln1_g"], p[pre + "ln1_b"]))
                if fl > 0:
                    x = x + ffn(layer_norm(x, p[pre + "ln2_g"], p[pre + "ln2_b"]))
            else:
                a_out = attn(x) if h > 0 else 0.0
                x = layer_norm(x + a_out, p[pre + "ln1_g"], p[pre + "ln1_b"])
                f_out = ffn(x) if fl > 0 else 0.0
                x = layer_norm(x + f_out, p[pre + "ln2_g"], p[pre + "ln2_b"])
        if cfg.causal:
            x = layer_norm(x, p["lnf_g"], p["lnf_b"])
        return (logits_fn(x, p, cfg, task),)

    return f, layout
