"""L2: masked transformer (BERT-style encoder / GPT-style decoder) in JAX.

Everything here is lowered ONCE by aot.py to HLO text and executed from
the Rust coordinator via PJRT; Python never runs on the request path.

Key design points (see DESIGN.md §2):

* **Packed parameters** — all weights live in one flat f32 vector whose
  layout comes from configs.param_layout; unpacking is static slicing,
  so jax.grad differentiates straight through it and the Rust side
  moves exactly three big literals (params, adam-m, adam-v) per step.
* **Structural masks as runtime inputs** — head_mask [L, H] and
  ffn_mask [L, F] make one executable serve every sparsity
  configuration during gradual pruning; a module whose mask is all-zero
  contributes exactly nothing (bias gated too), matching a materialized
  removal bit-for-bit.
* **Plain-HLO only** — tanh-GELU, no linalg custom-calls, no RNG, no
  sort (argmax/sampling happen in Rust).
* The attention core is the L1 Pallas kernel (kernels/mha.py).
"""

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from .configs import ModelConfig, TaskConfig, layout_offsets, param_layout
from .kernels.mha import mha


# --------------------------------------------------------------------------
# parameter unpacking
# --------------------------------------------------------------------------

def unpack_params(flat: jnp.ndarray, cfg: ModelConfig, task: TaskConfig) -> Dict[str, jnp.ndarray]:
    offs = layout_offsets(param_layout(cfg, task))
    out = {}
    for name, (off, shape) in offs.items():
        n = 1
        for s in shape:
            n *= s
        out[name] = jax.lax.dynamic_slice_in_dim(flat, off, n).reshape(shape)
    return out


def layer_norm(x: jnp.ndarray, g: jnp.ndarray, b: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * g + b


def gelu_tanh(x: jnp.ndarray) -> jnp.ndarray:
    """tanh-approximate GELU (erf lowers to a custom-call; tanh does not)."""
    return 0.5 * x * (1.0 + jnp.tanh(0.7978845608028654 * (x + 0.044715 * x * x * x)))


# --------------------------------------------------------------------------
# transformer blocks
# --------------------------------------------------------------------------

def attention_block(x: jnp.ndarray, p: Dict[str, jnp.ndarray], l: int,
                    head_mask_l: jnp.ndarray, cfg: ModelConfig):
    """Head-masked MHA sub-block (residual/LN handled by the caller).

    Returns (out-projection result, concatenated masked head outputs).
    The output is gated to exact zero when every head is pruned (module
    drop, Sec. 3.1 "removing entire residual parts").
    """
    pre = f"layer{l}."
    b_, s_ = x.shape[0], x.shape[1]
    h, dh = cfg.n_heads, cfg.d_head

    def split(t):
        return t.reshape(b_, s_, h, dh).transpose(0, 2, 1, 3)  # [B, H, S, dh]

    q = split(x @ p[pre + "wq"] + p[pre + "bq"])
    k = split(x @ p[pre + "wk"] + p[pre + "bk"])
    v = split(x @ p[pre + "wv"] + p[pre + "bv"])
    o = mha(q, k, v, head_mask_l, cfg.causal)  # L1 Pallas kernel
    o = o.transpose(0, 2, 1, 3).reshape(b_, s_, h * dh)  # concat heads
    active = jnp.max(head_mask_l)
    return (o @ p[pre + "wo"] + p[pre + "bo"]) * active, o


def ffn_block(x: jnp.ndarray, p: Dict[str, jnp.ndarray], l: int,
              ffn_mask_l: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    pre = f"layer{l}."
    a = gelu_tanh(x @ p[pre + "w1"] + p[pre + "b1"]) * ffn_mask_l
    active = jnp.max(ffn_mask_l)
    return (a @ p[pre + "w2"] + p[pre + "b2"]) * active, a


def encode(flat_params: jnp.ndarray, ids: jnp.ndarray,
           head_mask: jnp.ndarray, ffn_mask: jnp.ndarray,
           cfg: ModelConfig, task: TaskConfig,
           collect: bool = False):
    """Run the masked transformer trunk.

    Returns (final hidden [B, S, d], per-layer hiddens [L, B, S, d],
    calibration activations (attn-concat list, ffn-act list), params).
    """
    p = unpack_params(flat_params, cfg, task)
    b_, s_ = ids.shape
    x = p["tok_emb"][ids] + p["pos_emb"][None, :s_, :]
    if not cfg.causal:
        x = layer_norm(x, p["emb_ln_g"], p["emb_ln_b"])
    hiddens = []
    calib_attn, calib_ffn = [], []
    for l in range(cfg.n_layers):
        pre = f"layer{l}."
        if cfg.causal:  # pre-LN (GPT-2 style)
            a, concat = attention_block(layer_norm(x, p[pre + "ln1_g"], p[pre + "ln1_b"]),
                                        p, l, head_mask[l], cfg)
            x = x + a
            f, act = ffn_block(layer_norm(x, p[pre + "ln2_g"], p[pre + "ln2_b"]),
                               p, l, ffn_mask[l])
            x = x + f
        else:  # post-LN (BERT style)
            a, concat = attention_block(x, p, l, head_mask[l], cfg)
            x = layer_norm(x + a, p[pre + "ln1_g"], p[pre + "ln1_b"])
            f, act = ffn_block(x, p, l, ffn_mask[l])
            x = layer_norm(x + f, p[pre + "ln2_g"], p[pre + "ln2_b"])
        hiddens.append(x)
        if collect:
            calib_attn.append(concat)
            calib_ffn.append(act)
    if cfg.causal:
        x = layer_norm(x, p["lnf_g"], p["lnf_b"])
    hs = jnp.stack(hiddens)
    return x, hs, (calib_attn, calib_ffn), p


def logits_fn(x: jnp.ndarray, p: Dict[str, jnp.ndarray],
              cfg: ModelConfig, task: TaskConfig) -> jnp.ndarray:
    if task.kind == "cls":
        return x[:, 0, :] @ p["cls_w"] + p["cls_b"]          # [B, C]
    if task.kind == "span":
        return x @ p["span_w"] + p["span_b"]                  # [B, S]
    return x @ p["tok_emb"].T                                 # [B, S, V] (tied)


# --------------------------------------------------------------------------
# exported graphs
# --------------------------------------------------------------------------

def fwd(flat_params, ids, head_mask, ffn_mask, *, cfg: ModelConfig, task: TaskConfig):
    """Inference forward: logits only (argmax/sampling done in Rust)."""
    x, _, _, p = encode(flat_params, ids, head_mask, ffn_mask, cfg, task)
    return (logits_fn(x, p, cfg, task),)


def teacher_fwd(flat_params, ids, *, cfg: ModelConfig, task: TaskConfig):
    """Dense-teacher forward: logits + all per-layer hiddens (distill targets)."""
    hm = jnp.ones((cfg.n_layers, cfg.n_heads), jnp.float32)
    fm = jnp.ones((cfg.n_layers, cfg.d_ff), jnp.float32)
    x, hs, _, p = encode(flat_params, ids, hm, fm, cfg, task)
    return logits_fn(x, p, cfg, task), hs


def _task_loss(logits, labels, task: TaskConfig):
    if task.kind in ("cls", "span"):
        lse = jax.nn.logsumexp(logits, axis=-1)
        picked = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
        return jnp.mean(lse - picked)
    # lm: next-token cross-entropy
    lg = logits[:, :-1, :]
    tg = labels[:, 1:]
    lse = jax.nn.logsumexp(lg, axis=-1)
    picked = jnp.take_along_axis(lg, tg[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - picked)


def _kd_losses(logits, t_logits, hs, t_hs, pad_mask, task: TaskConfig):
    """KL(teacher || student) on logits + token-level hidden L2 (Eqs. 5-6)."""
    t_logp = jax.nn.log_softmax(t_logits, axis=-1)
    s_logp = jax.nn.log_softmax(logits, axis=-1)
    kl = jnp.mean(jnp.sum(jnp.exp(t_logp) * (t_logp - s_logp), axis=-1))
    # Eq. 6: squared distance between token vectors for each non-padded
    # token, averaged over tokens and over layers.
    diff = jnp.sum(jnp.square(hs - t_hs), axis=-1)            # [L, B, S]
    w = pad_mask[None, :, :]
    token = jnp.sum(diff * w) / (hs.shape[0] * jnp.maximum(jnp.sum(pad_mask), 1.0))
    return kl, token


def train_step(flat_params, m, v, t, lr, ids, labels, head_mask, ffn_mask,
               t_logits, t_hs, pad_mask, lambdas, wd,
               *, cfg: ModelConfig, task: TaskConfig):
    """One fused fwd+bwd+AdamW step (a single HLO executable).

    Inputs (runtime literals fed by the Rust trainer):
      flat_params/m/v [P]    packed parameters and Adam moments
      t []                   step count (float, bias correction)
      lr []                  learning rate (schedule computed in Rust)
      ids [B, S] int32       token ids
      labels [B] or [B, S]   task labels (lm: = ids)
      head_mask [L, H], ffn_mask [L, F]
      t_logits, t_hs         teacher outputs (ignored when lambdas[1:] = 0)
      pad_mask [B, S]        1 for non-padding tokens (Eq. 6's P-set)
      lambdas [3]            (task, logit-KL, token-distill) weights (Eq. 5)
      wd []                  decoupled weight decay
    Returns (params', m', v', task_loss, kl_loss, token_loss).
    """

    def loss_fn(fp):
        x, hs, _, p = encode(fp, ids, head_mask, ffn_mask, cfg, task)
        logits = logits_fn(x, p, cfg, task)
        lt = _task_loss(logits, labels, task)
        kl, token = _kd_losses(logits, t_logits, hs, t_hs, pad_mask, task)
        total = lambdas[0] * lt + lambdas[1] * kl + lambdas[2] * token
        return total, (lt, kl, token)

    (_, (lt, kl, token)), g = jax.value_and_grad(loss_fn, has_aux=True)(flat_params)
    b1, b2, eps = 0.9, 0.999, 1e-8
    m2 = b1 * m + (1 - b1) * g
    v2 = b2 * v + (1 - b2) * jnp.square(g)
    mh = m2 / (1 - b1 ** t)
    vh = v2 / (1 - b2 ** t)
    upd = mh / (jnp.sqrt(vh) + eps) + wd * flat_params
    fp2 = flat_params - lr * upd
    return fp2, m2, v2, lt, kl, token


def train_step_nokd(flat_params, m, v, t, lr, ids, labels, head_mask, ffn_mask,
                    wd, *, cfg: ModelConfig, task: TaskConfig):
    """train_step with distillation structurally elided (λ = (1,0,0)).

    Used for GPT pruning (paper App. I disables KD there) and the
    distillation ablation (Table 5); a separate graph guarantees the
    teacher terms are absent from the HLO, not just multiplied by zero.
    """

    def loss_fn(fp):
        x, _, _, p = encode(fp, ids, head_mask, ffn_mask, cfg, task)
        return _task_loss(logits_fn(x, p, cfg, task), labels, task)

    lt, g = jax.value_and_grad(loss_fn)(flat_params)
    b1, b2, eps = 0.9, 0.999, 1e-8
    m2 = b1 * m + (1 - b1) * g
    v2 = b2 * v + (1 - b2) * jnp.square(g)
    mh = m2 / (1 - b1 ** t)
    vh = v2 / (1 - b2 ** t)
    fp2 = flat_params - lr * (mh / (jnp.sqrt(vh) + eps) + wd * flat_params)
    return fp2, m2, v2, lt


def eval_loss(flat_params, ids, labels, head_mask, ffn_mask,
              *, cfg: ModelConfig, task: TaskConfig):
    """Mean task loss on one batch (SPDY candidate scoring & perplexity)."""
    x, _, _, p = encode(flat_params, ids, head_mask, ffn_mask, cfg, task)
    return (_task_loss(logits_fn(x, p, cfg, task), labels, task),)


def calib_capture(flat_params, ids, head_mask, ffn_mask, *, cfg: ModelConfig, task: TaskConfig):
    """Per-layer Hessian contributions for the ZipLM pruner (Sec. 3.1).

    Returns (H_attn [L, d_attn, d_attn], H_ffn [L, F, F]) where H = X X^T
    over this batch: X are the inputs of the attention out-projection
    (concatenated masked head outputs) and of FC2 (masked activations).
    The Rust coordinator accumulates batches and adds the dampening.
    """
    _, _, (cal_a, cal_f), _ = encode(flat_params, ids, head_mask, ffn_mask,
                                     cfg, task, collect=True)
    h_attn = jnp.stack([jnp.einsum("bsi,bsj->ij", a, a) for a in cal_a])
    h_ffn = jnp.stack([jnp.einsum("bsi,bsj->ij", f, f) for f in cal_f])
    return h_attn, h_ffn
