//! Hot-path micro-benchmarks (custom harness; criterion unavailable).
//! Covers the L3 hot loops + PJRT dispatch overhead — the numbers
//! EXPERIMENTS.md §Perf cites.
//!
//!   cargo bench --bench bench_hotpath
//!
//! Every `obs::*`/`linalg::*` fast-path entry has a `*_ref` sibling
//! driving the retained reference implementation, so one run produces
//! the before/after pair. Results are also written machine-readably to
//! `BENCH_hotpath.json` at the repo root (flat `name → ns/iter`
//! median; see util::bench::JsonReport) for cross-PR tracking.

#![allow(clippy::disallowed_methods)] // test/bench/example code: unwrap-on-failure is fine

use std::path::Path;

use ziplm::kernel::{with_level, Level};
use ziplm::runtime::{lit_f32_shaped, lit_scalar_i32, Engine};
use ziplm::spdy::{self, LevelOpt, ModuleLevels, SpdyProblem};
use ziplm::tensor::{linalg, Tensor};
use ziplm::util::bench::{header, Bench, JsonReport};
use ziplm::util::prop::gen;
use ziplm::util::rng::Rng;
use ziplm::util::threadpool::with_thread_budget;
use ziplm::ziplm::{NativeBackend, ObsOps};

fn main() {
    println!("{}", header());
    let b = Bench::default();
    let bq = Bench::quick();
    let mut rep = JsonReport::new();
    let mut rng = Rng::new(0);

    // Baseline keys are pinned to the Scalar dispatch level and a
    // thread budget of 1 so they stay comparable with the committed
    // single-threaded scalar C-mirror numbers; the ` simd`/
    // `native_simd` siblings run at the detected level (DESIGN.md
    // §14). Bits are identical either way — only throughput moves.
    let lvl = Level::detect();
    rep.note("dispatch", &format!("detected level {lvl:?}"));

    // native GEMM + transpose (coordinator-side math)
    let a = Tensor::from_vec(&[256, 256], gen::vec_f32(&mut rng, 256 * 256, 1.0));
    let c = Tensor::from_vec(&[256, 256], gen::vec_f32(&mut rng, 256 * 256, 1.0));
    rep.record(b.run("tensor::matmul 256x256x256", || {
        with_level(Level::Scalar, || with_thread_budget(1, || a.matmul(&c)))
    }));
    let t512 = Tensor::from_vec(&[512, 512], gen::vec_f32(&mut rng, 512 * 512, 1.0));
    rep.record(b.run("tensor::transpose2 512x512", || t512.transpose2()));

    // SPD inverse (per-layer Hessian inversion, d_ff=512 realistic):
    // fast (column-sparsity + symmetry) vs reference (two full solves)
    let h512 = Tensor::from_vec(&[512, 512], gen::spd(&mut rng, 512, 0.3));
    rep.record(bq.run_n("linalg::spd_inverse 512", 5, || {
        with_level(Level::Scalar, || with_thread_budget(1, || linalg::spd_inverse(&h512).unwrap()))
    }));
    rep.record(bq.run_n("linalg::spd_inverse_ref 512", 3, || linalg::spd_inverse_ref(&h512).unwrap()));

    // native OBS score + update at model scale (d=128, F=512)
    let w = Tensor::from_vec(&[128, 512], gen::vec_f32(&mut rng, 128 * 512, 1.0));
    let hinv = linalg::spd_inverse(&h512).unwrap();
    let act = vec![1.0f32; 512];
    let mut nb = NativeBackend::new(1);
    rep.record(bq.run_n("obs::scores native fc(128x512)", 10, || {
        with_level(Level::Scalar, || nb.scores(&w, &hinv, &act).unwrap())
    }));
    rep.record(bq.run_n("obs::scores native_ref fc(128x512)", 3, || {
        nb.scores_ref(&w, &hinv, &act).unwrap()
    }));
    rep.record(bq.run_n("obs::update native fc(128x512)", 10, || {
        with_level(Level::Scalar, || nb.update(&w, &hinv, 3).unwrap())
    }));
    rep.record(bq.run_n("obs::update native_ref fc(128x512)", 10, || {
        nb.update_ref(&w, &hinv, 3).unwrap()
    }));

    // fused multi-step pruning: 45 one-at-a-time removals (the ladder
    // step the database build actually takes), in-place vs clone-based
    rep.record(bq.run_n("obs::multi_update native fc(128x512) n=45", 5, || {
        with_level(Level::Scalar, || nb.multi_update(&w, &hinv, &act, 45).unwrap())
    }));
    rep.record(bq.run_n("obs::multi_update native_ref fc(128x512) n=45", 2, || {
        nb.multi_update_ref(&w, &hinv, &act, 45).unwrap()
    }));

    // deep removal ladder (460 of 512): the alive-set compact passes
    // only engage once fewer than half the columns survive
    rep.record(bq.run_n("obs::multi_update native fc(128x512) deep n=460", 3, || {
        with_level(Level::Scalar, || nb.multi_update(&w, &hinv, &act, 460).unwrap())
    }));

    // SIMD siblings at the detected dispatch level (omitted when only
    // the scalar fallback is compiled in, e.g. --features no-simd, so
    // the keys never carry scalar numbers under a simd name)
    if lvl != Level::Scalar {
        rep.record(b.run("tensor::matmul 256x256x256 simd", || {
            with_level(lvl, || with_thread_budget(1, || a.matmul(&c)))
        }));
        rep.record(bq.run_n("linalg::spd_inverse 512 simd", 5, || {
            with_level(lvl, || with_thread_budget(1, || linalg::spd_inverse(&h512).unwrap()))
        }));
        rep.record(bq.run_n("obs::scores native_simd fc(128x512)", 10, || {
            with_level(lvl, || nb.scores(&w, &hinv, &act).unwrap())
        }));
        rep.record(bq.run_n("obs::update native_simd fc(128x512)", 10, || {
            with_level(lvl, || nb.update(&w, &hinv, 3).unwrap())
        }));
        rep.record(bq.run_n("obs::multi_update native_simd fc(128x512) n=45", 5, || {
            with_level(lvl, || nb.multi_update(&w, &hinv, &act, 45).unwrap())
        }));
        rep.record(bq.run_n("obs::multi_update native_simd fc(128x512) deep n=460", 3, || {
            with_level(lvl, || nb.multi_update(&w, &hinv, &act, 460).unwrap())
        }));
    }

    // grouped scoring (attention heads): batched block path, g=64
    let wg = Tensor::from_vec(&[128, 512], gen::vec_f32(&mut rng, 128 * 512, 1.0));
    let actg = vec![1.0f32; 8];
    let mut nbg = NativeBackend::new(64);
    rep.record(bq.run_n("obs::scores native attn(g=64, 8 heads)", 10, || {
        nbg.scores(&wg, &hinv, &actg).unwrap()
    }));
    rep.record(bq.run_n("obs::scores native_ref attn(g=64, 8 heads)", 5, || {
        nbg.scores_ref(&wg, &hinv, &actg).unwrap()
    }));

    // SPDY DP solve (8 modules x 43 levels)
    let problem = SpdyProblem {
        modules: (0..8)
            .map(|i| ModuleLevels {
                layer: i / 2,
                is_attn: i % 2 == 0,
                options: (0..43)
                    .map(|k| LevelOpt {
                        remaining: 43 - k,
                        cost: (43 - k) as f64 * 1e-4,
                        prior: k as f64 / 43.0,
                    })
                    .collect(),
            })
            .collect(),
        overhead: 1e-3,
    };
    let coeffs = vec![1.0; 8];
    rep.record(b.run("spdy::solve_dp 8mod x 43lvl", || spdy::solve_dp(&problem, &coeffs, 0.02)));

    // PJRT paths (skipped without artifacts)
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        let engine = Engine::open(&dir).unwrap();
        let model = "bert-syn-base";
        let minfo = engine.manifest.model(model).clone();
        // HLO OBS score dispatch (the pruning hot loop's unit of work)
        let w_l = lit_f32_shaped(&[minfo.d_model, minfo.d_ff], &w.data).unwrap();
        let h_l = lit_f32_shaped(&[minfo.d_ff, minfo.d_ff], &hinv.data).unwrap();
        let a_l = lit_f32_shaped(&[minfo.d_ff], &act).unwrap();
        let exe = engine.executable(&format!("{model}__score_fc")).unwrap();
        rep.record(bq.run_n("pjrt dispatch score_fc", 20, || {
            Engine::run_exe(&exe, &[w_l.clone(), h_l.clone(), a_l.clone()]).unwrap()
        }));
        // multi-step fused FC pruning vs equivalent single steps
        let exe_multi = engine.executable(&format!("{model}__update_fc_multi")).unwrap();
        let n_l = lit_scalar_i32(45).unwrap();
        rep.record(bq.run_n("pjrt update_fc_multi n=45", 8, || {
            Engine::run_exe(&exe_multi, &[w_l.clone(), h_l.clone(), a_l.clone(), n_l.clone()])
                .unwrap()
        }));
        let exe_single = engine.executable(&format!("{model}__update_fc")).unwrap();
        let idx = lit_scalar_i32(3).unwrap();
        rep.record(bq.run_n("pjrt update_fc single", 20, || {
            Engine::run_exe(&exe_single, &[w_l.clone(), h_l.clone(), idx.clone()]).unwrap()
        }));
        // fwd inference dispatch (serving hot path)
        let task = "sst2-syn";
        let tinfo = engine.manifest.task(model, task).clone();
        let st = ziplm::models::ModelState::init(&minfo, task, &tinfo, 0);
        let p_l = lit_f32_shaped(&[tinfo.n_params], &st.params).unwrap();
        let ids = vec![1i32; engine.manifest.batch_eval * minfo.seq_len];
        let i_l = ziplm::runtime::lit_i32(&[engine.manifest.batch_eval, minfo.seq_len], &ids).unwrap();
        let hm = lit_f32_shaped(&[minfo.n_layers, minfo.n_heads], &st.masks.head).unwrap();
        let fm = lit_f32_shaped(&[minfo.n_layers, minfo.d_ff], &st.masks.ffn).unwrap();
        let exe_fwd = engine.executable(&format!("{model}__{task}__fwd")).unwrap();
        rep.record(bq.run_n("pjrt fwd batch=32 (serving)", 10, || {
            Engine::run_exe(&exe_fwd, &[p_l.clone(), i_l.clone(), hm.clone(), fm.clone()]).unwrap()
        }));
    } else {
        println!("(pjrt benches skipped: artifacts/ not built)");
        rep.note("pjrt", "skipped: artifacts/ not built");
    }

    let out = Path::new(env!("CARGO_MANIFEST_DIR")).join("..").join("BENCH_hotpath.json");
    match rep.write(&out) {
        Ok(()) => println!("wrote {}", out.display()),
        Err(e) => eprintln!("could not write {}: {e}", out.display()),
    }
}
