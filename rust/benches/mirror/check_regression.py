#!/usr/bin/env python3
"""Regenerate BENCH_hotpath.json from the C mirror's output and gate CI
on kernel regressions.

Two subcommands:

  parse <mirror_stdout>... -o <out.json> [--notes TEXT]
      Read the `BENCH <key> | min <ns> | median <ns> | n <N>` lines the
      mirror prints, and write a BENCH_hotpath.json-shaped file (keys =
      benchmark ids, values = min-of-N ns/iter, plus a _meta provenance
      record — schema in README.md next to this script). Multiple
      input files (separate mirror runs) are min-merged per key: on
      shared runners a co-tenant burst can cover one whole run, so CI
      runs the mirror several times and takes the quietest window.

  compare <new.json> <baseline.json> [--threshold 0.15]
      For every fast/ref kernel pair, compute the speedup ratio
      (ref_ns / fast_ns) in both files and FAIL (exit 1) when the new
      speedup has dropped by more than the threshold relative to the
      baseline's. Ratios, not absolute ns: CI runners and the
      committed baseline's box differ in clock, but a kernel whose
      *relative* win over its retained reference collapses has
      regressed no matter the machine.

Stdlib only (the CI job runs it on a bare runner).
"""
import argparse
import json
import sys

# (fast entry, reference entry) pairs gated by `compare`. Extra keys in
# either file are ignored, per the BENCH_hotpath.json schema.
# Pairs whose BASELINE speedup is under MIN_GATED_SPEEDUP are reported
# but not gated: a ~1.2x margin (e.g. the grouped attn scores) is
# inside shared-runner noise, so a 15% floor on it would fail CI on
# machine weather rather than code. A real de-optimization of the
# big-margin kernels (2x-15x) collapses their ratios far past 15%.
MIN_GATED_SPEEDUP = 1.5
PAIRS = [
    ("tensor::matmul 256x256x256", "tensor::matmul 256x256x256 seed_ref"),
    ("linalg::spd_inverse 512", "linalg::spd_inverse_ref 512"),
    ("obs::scores native fc(128x512)", "obs::scores native_ref fc(128x512)"),
    ("obs::scores native attn(g=64, 8 heads)", "obs::scores native_ref attn(g=64, 8 heads)"),
    ("obs::update native fc(128x512)", "obs::update native_ref fc(128x512)"),
    ("obs::multi_update native fc(128x512) n=45", "obs::multi_update native_ref fc(128x512) n=45"),
    # PR-10 per-SIMD-variant pairs: each vectorized kernel is gated
    # against ITS OWN scalar twin, so a dispatch-layer regression can't
    # hide behind the (much larger) fast-vs-seed-ref margin above.
    ("tensor::matmul 256x256x256 simd", "tensor::matmul 256x256x256"),
    ("linalg::spd_inverse 512 simd", "linalg::spd_inverse 512"),
    ("obs::scores native_simd fc(128x512)", "obs::scores native fc(128x512)"),
    ("obs::update native_simd fc(128x512)", "obs::update native fc(128x512)"),
    ("obs::multi_update native_simd fc(128x512) n=45", "obs::multi_update native fc(128x512) n=45"),
    # alive-set hybrid vs the PR-4 always-dense passes on the deep
    # ladder, where the O(n_alive^2) late steps actually show up
    ("obs::multi_update native fc(128x512) deep n=460", "obs::multi_update native_prev fc(128x512) deep n=460"),
    ("obs::multi_update native_simd fc(128x512) deep n=460", "obs::multi_update native_prev fc(128x512) deep n=460"),
]


def parse_mirror(path):
    out = {}
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line.startswith("BENCH "):
                continue
            fields = [p.strip() for p in line[len("BENCH "):].split("|")]
            if len(fields) < 2 or not fields[1].startswith("min "):
                raise SystemExit(f"unparseable BENCH line: {line!r}")
            out[fields[0]] = int(float(fields[1][len("min "):]))
    if not out:
        raise SystemExit(f"no BENCH lines found in {path}")
    return out


def cmd_parse(args):
    vals = {}
    for path in args.mirror_stdout:
        for key, v in parse_mirror(path).items():
            vals[key] = min(v, vals.get(key, v))
    doc = {
        "_meta": {
            "unit": "ns/iter (min of N)",
            "harness": "C mirror of rust/benches/bench_hotpath.rs (gcc -O2, single-thread)",
            "notes": args.notes,
        }
    }
    doc.update(vals)
    with open(args.out, "w") as f:
        json.dump(doc, f, indent=2)
        f.write("\n")
    print(f"wrote {args.out} ({len(vals)} benchmarks)")
    return 0


def speedups(doc):
    out = {}
    for fast, ref in PAIRS:
        if fast in doc and ref in doc and doc[fast] > 0:
            out[fast] = doc[ref] / doc[fast]
    return out


def cmd_compare(args):
    new = json.load(open(args.new))
    base = json.load(open(args.baseline))
    new_s, base_s = speedups(new), speedups(base)
    failures = []
    print(f"{'kernel':<46} {'baseline':>9} {'new':>9}  verdict")
    for fast, _ref in PAIRS:
        if fast not in base_s:
            print(f"{fast:<46} {'-':>9} {'-':>9}  skipped (not in baseline)")
            continue
        if base_s[fast] < MIN_GATED_SPEEDUP:
            got = f"{new_s[fast]:>8.2f}x" if fast in new_s else f"{'-':>9}"
            print(f"{fast:<46} {base_s[fast]:>8.2f}x {got}  "
                  f"informational (margin < {MIN_GATED_SPEEDUP}x gate floor)")
            continue
        if fast not in new_s:
            # "simd" entries are emitted only when the mirror's binary
            # detects AVX2 at runtime; on a runner without it (or a
            # future non-x86 one) their absence is environment, not a
            # regression — the scalar pairs above still gate.
            if "simd" in fast:
                print(f"{fast:<46} {base_s[fast]:>8.2f}x {'-':>9}  "
                      f"informational (simd entry absent on this runner)")
                continue
            failures.append(f"{fast}: missing from new results")
            print(f"{fast:<46} {base_s[fast]:>8.2f}x {'-':>9}  MISSING")
            continue
        floor = base_s[fast] * (1.0 - args.threshold)
        ok = new_s[fast] >= floor
        print(f"{fast:<46} {base_s[fast]:>8.2f}x {new_s[fast]:>8.2f}x  "
              f"{'ok' if ok else f'REGRESSED (floor {floor:.2f}x)'}")
        if not ok:
            failures.append(
                f"{fast}: speedup {new_s[fast]:.2f}x < floor {floor:.2f}x "
                f"(baseline {base_s[fast]:.2f}x, threshold {args.threshold:.0%})")
    if failures:
        print("\nFAIL:", file=sys.stderr)
        for f in failures:
            print(f"  {f}", file=sys.stderr)
        return 1
    print("\nall kernel speedups within threshold")
    return 0


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    sub = ap.add_subparsers(dest="cmd", required=True)
    p = sub.add_parser("parse", help="mirror stdout(s) -> BENCH_hotpath.json shape")
    p.add_argument("mirror_stdout", nargs="+")
    p.add_argument("-o", "--out", required=True)
    p.add_argument("--notes", default="regenerated from bench_mirror.c output")
    p.set_defaults(fn=cmd_parse)
    c = sub.add_parser("compare", help="gate on fast-vs-ref speedup regressions")
    c.add_argument("new")
    c.add_argument("baseline")
    c.add_argument("--threshold", type=float, default=0.15,
                   help="max allowed fractional speedup drop (default 0.15)")
    c.set_defaults(fn=cmd_compare)
    args = ap.parse_args()
    sys.exit(args.fn(args))


if __name__ == "__main__":
    main()
