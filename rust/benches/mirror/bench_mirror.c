/* C mirror of rust/benches/bench_hotpath.rs OBS/linalg entries.
 * Reproduces the seed ("ref") and fast implementations' loop structure
 * and heap-allocation behavior 1:1, compiled with gcc -O2 (baseline
 * x86-64, no fast-math) as a proxy for rustc -O in a container without
 * a Rust toolchain. Single-threaded, matching the Rust OBS paths. */
#define _POSIX_C_SOURCE 199309L
#include <stdio.h>
#include <stdlib.h>
#include <string.h>
#include <math.h>
#include <time.h>
#if defined(__x86_64__)
#include <immintrin.h>
#define HAVE_SIMD_MIRROR 1
#endif

static double now_ns(void) {
    struct timespec ts;
    clock_gettime(CLOCK_MONOTONIC, &ts);
    return ts.tv_sec * 1e9 + ts.tv_nsec;
}

static unsigned long long rstate = 0x243F6A8885A308D3ull;
static float frand(void) { /* xorshift normal-ish via sum of uniforms */
    rstate ^= rstate << 13; rstate ^= rstate >> 7; rstate ^= rstate << 17;
    double u = (double)(rstate >> 11) / 9007199254740992.0;
    rstate ^= rstate << 13; rstate ^= rstate >> 7; rstate ^= rstate << 17;
    double v = (double)(rstate >> 11) / 9007199254740992.0;
    return (float)((u + v) - 1.0);
}

static volatile float SINK;

/* ---------------------------------------------------------------- spd */
static void make_spd(float *h, int n, float damp) {
    float *a = malloc(sizeof(float) * n * n);
    for (int i = 0; i < n * n; i++) a[i] = frand();
    for (int i = 0; i < n; i++)
        for (int j = 0; j < n; j++) {
            float s = 0;
            for (int k = 0; k < n; k++) s += a[i * n + k] * a[j * n + k];
            h[i * n + j] = s;
        }
    for (int i = 0; i < n; i++) h[i * n + i] += damp * n;
    free(a);
}

/* seed cholesky: element-wise at2 access (same flops; gcc sees same deps) */
static int cholesky(const float *a, float *l, int n) {
    memset(l, 0, sizeof(float) * n * n);
    for (int j = 0; j < n; j++) {
        float d = a[j * n + j];
        for (int k = 0; k < j; k++) d -= l[j * n + k] * l[j * n + k];
        if (d <= 0) return -1;
        d = sqrtf(d);
        l[j * n + j] = d;
        for (int i = j + 1; i < n; i++) {
            float s = a[i * n + j];
            for (int k = 0; k < j; k++) s -= l[i * n + k] * l[j * n + k];
            l[i * n + j] = s / d;
        }
    }
    return 0;
}

/* ref spd_inverse: full forward+backward solve per unit vector */
static void spd_inverse_ref(const float *a, float *inv, int n) {
    float *l = malloc(sizeof(float) * n * n);
    float *e = calloc(n, sizeof(float));
    float *y = malloc(sizeof(float) * n);
    float *x = malloc(sizeof(float) * n);
    cholesky(a, l, n);
    for (int j = 0; j < n; j++) {
        e[j] = 1.0f;
        for (int i = 0; i < n; i++) {
            float s = e[i];
            for (int k = 0; k < i; k++) s -= l[i * n + k] * y[k];
            y[i] = s / l[i * n + i];
        }
        for (int i = n - 1; i >= 0; i--) {
            float s = y[i];
            for (int k = i + 1; k < n; k++) s -= l[k * n + i] * x[k];
            x[i] = s / l[i * n + i];
        }
        for (int i = 0; i < n; i++) inv[i * n + j] = x[i];
        e[j] = 0.0f;
    }
    free(l); free(e); free(y); free(x);
}

/* fast spd_inverse: start fwd at j, stop bwd at j, mirror symmetric */
static void spd_inverse_fast(const float *a, float *inv, int n) {
    float *l = malloc(sizeof(float) * n * n);
    float *lt = malloc(sizeof(float) * n * n);
    float *y = malloc(sizeof(float) * n);
    float *x = malloc(sizeof(float) * n);
    cholesky(a, l, n);
    for (int i = 0; i < n; i++)
        for (int j = 0; j < n; j++) lt[j * n + i] = l[i * n + j];
    for (int j = 0; j < n; j++) {
        y[j] = 1.0f / l[j * n + j];
        for (int i = j + 1; i < n; i++) {
            float s = 0;
            const float *li = &l[i * n + j];
            for (int t = 0; t < i - j; t++) s += li[t] * y[j + t];
            y[i] = -s / l[i * n + i];
        }
        for (int i = n - 1; i >= j; i--) {
            float s = y[i];
            const float *row = &lt[i * n + i + 1];
            for (int t = 0; t < n - i - 1; t++) s -= row[t] * x[i + 1 + t];
            x[i] = s / l[i * n + i];
        }
        for (int i = j; i < n; i++) { inv[i * n + j] = x[i]; inv[j * n + i] = x[i]; }
    }
    free(l); free(lt); free(y); free(x);
}

/* ------------------------------------------------------------- matmul */
/* seed kernel: i-k-j with zero skip */
static void matmul_old(const float *a, const float *b, float *c, int m, int k, int n) {
    memset(c, 0, sizeof(float) * m * n);
    for (int i = 0; i < m; i++) {
        float *crow = &c[i * n];
        for (int kk = 0; kk < k; kk++) {
            float aik = a[i * k + kk];
            if (aik == 0.0f) continue;
            const float *brow = &b[kk * n];
            for (int j = 0; j < n; j++) crow[j] += aik * brow[j];
        }
    }
}

/* new kernel: KC/NC tiles + quad-row inner */
static void matmul_new(const float *a, const float *b, float *c, int m, int k, int n) {
    const int KC = 64, NC = 256;
    memset(c, 0, sizeof(float) * m * n);
    for (int jb = 0; jb < n; jb += NC) {
        int jend = jb + NC < n ? jb + NC : n;
        int jl = jend - jb;
        for (int kb = 0; kb < k; kb += KC) {
            int kend = kb + KC < k ? kb + KC : k;
            int kc = kend - kb, kq = kc - kc % 4;
            for (int i = 0; i < m; i++) {
                const float *arow = &a[i * k + kb];
                float *crow = &c[i * n + jb];
                int kk = 0;
                for (; kk < kq; kk += 4) {
                    float a0 = arow[kk], a1 = arow[kk + 1], a2 = arow[kk + 2], a3 = arow[kk + 3];
                    if (a0 != 0.0f || a1 != 0.0f || a2 != 0.0f || a3 != 0.0f) {
                        int r = kb + kk;
                        const float *b0 = &b[r * n + jb], *b1 = &b[(r + 1) * n + jb];
                        const float *b2 = &b[(r + 2) * n + jb], *b3 = &b[(r + 3) * n + jb];
                        for (int j = 0; j < jl; j++)
                            crow[j] += a0 * b0[j] + a1 * b1[j] + a2 * b2[j] + a3 * b3[j];
                    }
                }
                for (; kk < kc; kk++) {
                    float aik = arow[kk];
                    if (aik == 0.0f) continue;
                    const float *brow = &b[(kb + kk) * n + jb];
                    for (int j = 0; j < jl; j++) crow[j] += aik * brow[j];
                }
            }
        }
    }
}

/* ------------------------------------------------------- gj inverse */
static int gj_inverse_flat(float *m, float *inv, int n) {
    for (int k = 0; k < n; k++) {
        int p = k;
        for (int i = k + 1; i < n; i++)
            if (fabsf(m[i * n + k]) > fabsf(m[p * n + k])) p = i;
        if (fabsf(m[p * n + k]) < 1e-20f) return -1;
        if (p != k)
            for (int j = 0; j < n; j++) {
                float t = m[k * n + j]; m[k * n + j] = m[p * n + j]; m[p * n + j] = t;
                t = inv[k * n + j]; inv[k * n + j] = inv[p * n + j]; inv[p * n + j] = t;
            }
        float piv = m[k * n + k];
        for (int j = 0; j < n; j++) { m[k * n + j] /= piv; inv[k * n + j] /= piv; }
        for (int i = 0; i < n; i++) {
            if (i == k) continue;
            float f = m[i * n + k];
            if (f == 0.0f) continue;
            for (int j = 0; j < n; j++) {
                m[i * n + j] -= f * m[k * n + j];
                inv[i * n + j] -= f * inv[k * n + j];
            }
        }
    }
    return 0;
}

/* block_inv of seed: gather_rows → gather_cols → gj_inverse, with the
 * same temporary allocations the Rust Tensor path makes */
static float *block_inv_ref(const float *hinv, int d, int j, int g) {
    float *rows = malloc(sizeof(float) * g * d);          /* gather_rows */
    for (int r = 0; r < g; r++) memcpy(&rows[r * d], &hinv[(j * g + r) * d], sizeof(float) * d);
    float *block = malloc(sizeof(float) * g * g);         /* gather_cols */
    for (int r = 0; r < g; r++)
        for (int c = 0; c < g; c++) block[r * g + c] = rows[r * d + j * g + c];
    float *mcopy = malloc(sizeof(float) * g * g);         /* gj clone */
    memcpy(mcopy, block, sizeof(float) * g * g);
    float *inv = calloc(g * g, sizeof(float));            /* eye */
    for (int t = 0; t < g; t++) inv[t * g + t] = 1.0f;
    gj_inverse_flat(mcopy, inv, g);
    free(rows); free(block); free(mcopy);
    return inv;
}

/* ------------------------------------------------------- scores paths */
static void scores_ref(const float *w, const float *hinv, const float *act,
                       int d_row, int d, int g, float *out) {
    int nst = d / g;
    for (int j = 0; j < nst; j++) {
        out[j] = 1e30f;
        if (act[j] <= 0.0f) continue;
        float *binv = block_inv_ref(hinv, d, j, g);
        double s = 0.0;
        for (int i = 0; i < d_row; i++) {
            const float *wi = &w[i * d + j * g];
            float *bw = malloc(sizeof(float) * g);        /* matvec alloc */
            for (int r = 0; r < g; r++) {
                float t = 0;
                for (int c = 0; c < g; c++) t += binv[r * g + c] * wi[c];
                bw[r] = t;
            }
            for (int r = 0; r < g; r++) s += (double)wi[r] * (double)bw[r];
            free(bw);
        }
        out[j] = (float)s;
        free(binv);
    }
}

static void scores_fast_g1(const float *w, const float *hinv, const float *act,
                           int d_row, int d, float *out, double *colsq) {
    for (int j = 0; j < d; j++) colsq[j] = 0.0;
    for (int i = 0; i < d_row; i++) {
        const float *row = &w[i * d];
        for (int j = 0; j < d; j++) colsq[j] += (double)row[j] * (double)row[j];
    }
    for (int j = 0; j < d; j++)
        out[j] = act[j] > 0.0f ? (float)(colsq[j] / (double)hinv[j * d + j]) : 1e30f;
}

static void scores_fast_grouped(const float *w, const float *hinv, const float *act,
                                int d_row, int d, int g, float *out) {
    int nst = d / g;
    /* batched gather of diagonal blocks */
    float *blocks = calloc(nst * g * g, sizeof(float));
    for (int r = 0; r < d; r++) {
        int j = r / g;
        if (act[j] <= 0.0f) continue;
        memcpy(&blocks[j * g * g + (r - j * g) * g], &hinv[r * d + j * g], sizeof(float) * g);
    }
    float *scratch = malloc(sizeof(float) * g * g);
    float *ident = malloc(sizeof(float) * g * g);
    for (int j = 0; j < nst; j++) {
        if (act[j] <= 0.0f) continue;
        memcpy(scratch, &blocks[j * g * g], sizeof(float) * g * g);
        memset(ident, 0, sizeof(float) * g * g);
        for (int t = 0; t < g; t++) ident[t * g + t] = 1.0f;
        gj_inverse_flat(scratch, ident, g);
        memcpy(&blocks[j * g * g], ident, sizeof(float) * g * g);
    }
    for (int j = 0; j < nst; j++) {
        out[j] = 1e30f;
        if (act[j] <= 0.0f) continue;
        const float *b = &blocks[j * g * g];
        double s = 0.0;
        for (int i = 0; i < d_row; i++) {
            const float *wseg = &w[i * d + j * g];
            for (int r = 0; r < g; r++) {
                float t = 0;
                for (int c = 0; c < g; c++) t += b[r * g + c] * wseg[c];
                s += (double)wseg[r] * (double)t;
            }
        }
        out[j] = (float)s;
    }
    free(blocks); free(scratch); free(ident);
}

/* ------------------------------------------------------- update paths */
static int argmin_f(const float *s, int n) {
    int best = 0;
    for (int i = 0; i < n; i++) if (s[i] < s[best]) best = i;
    return best;
}

/* seed update (g=1): clones + gathers + dense matmuls (same allocs) */
static void update_ref_g1(const float *w, const float *hinv, int idx,
                          int d_row, int d, float **w2out, float **h2out) {
    float *binv = block_inv_ref(hinv, d, idx, 1);
    float *rows = malloc(sizeof(float) * d);              /* gather_rows */
    memcpy(rows, &hinv[idx * d], sizeof(float) * d);
    float *p = malloc(sizeof(float) * d);                 /* binv.matmul */
    for (int j = 0; j < d; j++) p[j] = binv[0] * rows[j];
    float *wc = malloc(sizeof(float) * d_row);            /* gather_cols W */
    for (int i = 0; i < d_row; i++) wc[i] = w[i * d + idx];
    float *hc = malloc(sizeof(float) * d);                /* gather_cols H */
    for (int i = 0; i < d; i++) hc[i] = hinv[i * d + idx];
    float *w2 = malloc(sizeof(float) * d_row * d);        /* clone W */
    memcpy(w2, w, sizeof(float) * d_row * d);
    float *dw = calloc(d_row * d, sizeof(float));         /* matmul out */
    for (int i = 0; i < d_row; i++) {
        float aik = wc[i];
        if (aik != 0.0f)
            for (int j = 0; j < d; j++) dw[i * d + j] = aik * p[j];
    }
    for (int i = 0; i < d_row * d; i++) w2[i] -= dw[i];
    float *h2 = malloc(sizeof(float) * d * d);            /* clone H */
    memcpy(h2, hinv, sizeof(float) * d * d);
    float *dh = calloc(d * d, sizeof(float));
    for (int i = 0; i < d; i++) {
        float aik = hc[i];
        if (aik != 0.0f)
            for (int j = 0; j < d; j++) dh[i * d + j] = aik * p[j];
    }
    for (int i = 0; i < d * d; i++) h2[i] -= dh[i];
    for (int i = 0; i < d_row; i++) w2[i * d + idx] = 0.0f;
    for (int k = 0; k < d; k++) { h2[idx * d + k] = 0.0f; h2[k * d + idx] = 0.0f; }
    h2[idx * d + idx] = 1.0f;
    free(binv); free(rows); free(p); free(wc); free(hc); free(dw); free(dh);
    *w2out = w2; *h2out = h2;
}

/* seed multi_update: scores_ref + clone-based update per step */
static void multi_update_ref(const float *w0, const float *h0, const float *act0,
                             int d_row, int d, int nrm) {
    float *w = malloc(sizeof(float) * d_row * d);
    float *h = malloc(sizeof(float) * d * d);
    float *act = malloc(sizeof(float) * d);
    float *sc = malloc(sizeof(float) * d);
    memcpy(w, w0, sizeof(float) * d_row * d);
    memcpy(h, h0, sizeof(float) * d * d);
    memcpy(act, act0, sizeof(float) * d);
    for (int s = 0; s < nrm; s++) {
        scores_ref(w, h, act, d_row, d, 1, sc);
        int j = argmin_f(sc, d);
        float *w2, *h2;
        update_ref_g1(w, h, j, d_row, d, &w2, &h2);
        free(w); free(h);
        w = w2; h = h2;
        act[j] = 0.0f;
    }
    SINK = w[0] + h[0];
    free(w); free(h); free(act); free(sc);
}

/* fast single update (g=1): clone once + in-place rank-1 downdate */
static void update_fast_g1(const float *w0, const float *h0, int idx, int d_row, int d,
                           float *out_w, float *out_h) {
    float *w = malloc(sizeof(float) * d_row * d);
    float *h = malloc(sizeof(float) * d * d);
    memcpy(w, w0, sizeof(float) * d_row * d);
    memcpy(h, h0, sizeof(float) * d * d);
    float *p = malloc(sizeof(float) * d);
    float *cbuf = malloc(sizeof(float) * d);
    float binv = 1.0f / h[idx * d + idx];
    for (int k = 0; k < d; k++) p[k] = binv * h[idx * d + k];
    for (int i = 0; i < d_row; i++) {
        float *row = &w[i * d];
        float wij = row[idx];
        if (wij != 0.0f)
            for (int k = 0; k < d; k++) row[k] -= wij * p[k];
        row[idx] = 0.0f;
    }
    for (int r = 0; r < d; r++) cbuf[r] = h[r * d + idx];
    for (int r = 0; r < d; r++) {
        float c = cbuf[r];
        if (c == 0.0f) continue;
        float *hrow = &h[r * d];
        for (int k = 0; k < d; k++) hrow[k] -= c * p[k];
    }
    for (int k = 0; k < d; k++) { h[idx * d + k] = 0.0f; h[k * d + idx] = 0.0f; }
    h[idx * d + idx] = 1.0f;
    SINK = w[1] + h[1];
    if (out_w) memcpy(out_w, w, sizeof(float) * d_row * d);
    if (out_h) memcpy(out_h, h, sizeof(float) * d * d);
    free(w); free(h); free(p); free(cbuf);
}

/* PR-10 fast multi_update: incremental colsq + the alive-set hybrid.
 * While more than half the columns are alive the dense per-step passes
 * (identical to fast_incr above) win on stride-1 bandwidth; once
 * n_alive*2 < d every pass walks only the compacted alive-index list,
 * turning the O(d^2) Hinv downdate into O(n_alive^2). Mirrors
 * NativeBackend::multi_update's compact/dense split 1:1. */
static void multi_update_alive(const float *w0, const float *h0, const float *act0,
                               int d_row, int d, int nrm, float *out_w, float *out_h) {
    float *w = malloc(sizeof(float) * d_row * d);
    float *h = malloc(sizeof(float) * d * d);
    float *act = malloc(sizeof(float) * d);
    memcpy(w, w0, sizeof(float) * d_row * d);
    memcpy(h, h0, sizeof(float) * d * d);
    memcpy(act, act0, sizeof(float) * d);
    int *alive = malloc(sizeof(int) * d);
    int n_alive = 0;
    for (int j = 0; j < d; j++) if (act[j] > 0.0f) alive[n_alive++] = j;
    double *colsq = malloc(sizeof(double) * d);
    float *p = malloc(sizeof(float) * d);
    float *cbuf = malloc(sizeof(float) * d);
    for (int j = 0; j < d; j++) colsq[j] = 0.0;
    for (int i = 0; i < d_row; i++) {
        const float *row = &w[i * d];
        for (int j = 0; j < d; j++) colsq[j] += (double)row[j] * (double)row[j];
    }
    for (int s = 0; s < nrm; s++) {
        int best = alive[0];
        float best_s = INFINITY;
        for (int t = 0; t < n_alive; t++) {
            int j = alive[t];
            double cs = colsq[j] > 0.0 ? colsq[j] : 0.0;
            float sc = (float)(cs / (double)h[j * d + j]);
            if (sc < best_s) { best_s = sc; best = j; }
        }
        int j = best;
        float hjj_inv = 1.0f / h[j * d + j];
        if (n_alive * 2 < d) {
            /* compact passes: p gathered at alive positions only */
            for (int t = 0; t < n_alive; t++) p[t] = h[j * d + alive[t]] * hjj_inv;
            for (int i = 0; i < d_row; i++) {
                float *row = &w[i * d];
                float wij = row[j];
                if (wij != 0.0f) {
                    for (int t = 0; t < n_alive; t++) {
                        int c = alive[t];
                        double old = (double)row[c];
                        row[c] -= wij * p[t];
                        colsq[c] += (double)row[c] * (double)row[c] - old * old;
                    }
                }
                row[j] = 0.0f;
            }
            colsq[j] = 0.0;
            for (int t = 0; t < n_alive; t++) {
                int r = alive[t];
                float c = h[r * d + j];
                if (c == 0.0f) continue;
                float *hrow = &h[r * d];
                for (int tt = 0; tt < n_alive; tt++) hrow[alive[tt]] -= c * p[tt];
            }
            for (int t = 0; t < n_alive; t++) { h[j * d + alive[t]] = 0.0f; h[alive[t] * d + j] = 0.0f; }
            h[j * d + j] = 1.0f;
        } else {
            for (int k = 0; k < d; k++) p[k] = h[j * d + k] * hjj_inv;
            for (int i = 0; i < d_row; i++) {
                float *row = &w[i * d];
                float wij = row[j];
                if (wij != 0.0f) {
                    for (int k = 0; k < d; k++) {
                        double old = (double)row[k];
                        row[k] -= wij * p[k];
                        colsq[k] += (double)row[k] * (double)row[k] - old * old;
                    }
                }
                row[j] = 0.0f;
            }
            colsq[j] = 0.0;
            for (int r = 0; r < d; r++) cbuf[r] = h[r * d + j];
            for (int r = 0; r < d; r++) {
                float c = cbuf[r];
                if (c == 0.0f) continue;
                float *hrow = &h[r * d];
                for (int k = 0; k < d; k++) hrow[k] -= c * p[k];
            }
            for (int k = 0; k < d; k++) { h[j * d + k] = 0.0f; h[k * d + j] = 0.0f; }
            h[j * d + j] = 1.0f;
        }
        act[j] = 0.0f;
        for (int t = 0; t < n_alive; t++)
            if (alive[t] == j) { memmove(&alive[t], &alive[t + 1], sizeof(int) * (n_alive - t - 1)); n_alive--; break; }
    }
    SINK = w[0] + h[0];
    if (out_w) memcpy(out_w, w, sizeof(float) * d_row * d);
    if (out_h) memcpy(out_h, h, sizeof(float) * d * d);
    free(w); free(h); free(act); free(alive); free(colsq); free(p); free(cbuf);
}

/* PR-4 fast multi_update: colsq computed ONCE and maintained
 * incrementally inside the W axpy pass (mirrors the current Rust
 * NativeBackend::multi_update loop structure 1:1) */
static void multi_update_fast_incr(const float *w0, const float *h0, const float *act0,
                                   int d_row, int d, int nrm) {
    float *w = malloc(sizeof(float) * d_row * d);
    float *h = malloc(sizeof(float) * d * d);
    float *act = malloc(sizeof(float) * d);
    memcpy(w, w0, sizeof(float) * d_row * d);
    memcpy(h, h0, sizeof(float) * d * d);
    memcpy(act, act0, sizeof(float) * d);
    int *alive = malloc(sizeof(int) * d);
    int n_alive = 0;
    for (int j = 0; j < d; j++) if (act[j] > 0.0f) alive[n_alive++] = j;
    double *colsq = malloc(sizeof(double) * d);
    float *p = malloc(sizeof(float) * d);
    float *cbuf = malloc(sizeof(float) * d);
    for (int j = 0; j < d; j++) colsq[j] = 0.0;
    for (int i = 0; i < d_row; i++) {
        const float *row = &w[i * d];
        for (int j = 0; j < d; j++) colsq[j] += (double)row[j] * (double)row[j];
    }
    for (int s = 0; s < nrm; s++) {
        int best = alive[0];
        float best_s = INFINITY;
        for (int t = 0; t < n_alive; t++) {
            int j = alive[t];
            double cs = colsq[j] > 0.0 ? colsq[j] : 0.0;
            float sc = (float)(cs / (double)h[j * d + j]);
            if (sc < best_s) { best_s = sc; best = j; }
        }
        int j = best;
        float hjj_inv = 1.0f / h[j * d + j];
        for (int k = 0; k < d; k++) p[k] = h[j * d + k] * hjj_inv;
        for (int i = 0; i < d_row; i++) {
            float *row = &w[i * d];
            float wij = row[j];
            if (wij != 0.0f) {
                for (int k = 0; k < d; k++) {
                    double old = (double)row[k];
                    row[k] -= wij * p[k];
                    colsq[k] += (double)row[k] * (double)row[k] - old * old;
                }
            }
            row[j] = 0.0f;
        }
        colsq[j] = 0.0;
        for (int r = 0; r < d; r++) cbuf[r] = h[r * d + j];
        for (int r = 0; r < d; r++) {
            float c = cbuf[r];
            if (c == 0.0f) continue;
            float *hrow = &h[r * d];
            for (int k = 0; k < d; k++) hrow[k] -= c * p[k];
        }
        for (int k = 0; k < d; k++) { h[j * d + k] = 0.0f; h[k * d + j] = 0.0f; }
        h[j * d + j] = 1.0f;
        act[j] = 0.0f;
        for (int t = 0; t < n_alive; t++)
            if (alive[t] == j) { memmove(&alive[t], &alive[t + 1], sizeof(int) * (n_alive - t - 1)); n_alive--; break; }
    }
    SINK = w[0] + h[0];
    free(w); free(h); free(act); free(alive); free(colsq); free(p); free(cbuf);
}

/* ----------------------------------------------------- simd variants */
/* Mirrors of kernel/x86.rs's AVX2 fast paths (packed mul+add/sub, no
 * FMA, XOR negate, per-128-lane f32->f64 widening — the exact idioms
 * the Rust dispatch layer uses to stay bit-identical to scalar).
 * Compiled for AVX2 via function-level target attributes so the
 * baseline -O2 scalar codegen of everything above is undisturbed;
 * main() only runs them behind __builtin_cpu_supports("avx2"). */
#ifdef HAVE_SIMD_MIRROR

__attribute__((target("avx2")))
static void axpy_avx2(float *dst, float a, const float *x, int n) {
    __m256 va = _mm256_set1_ps(a);
    int j = 0;
    for (; j + 8 <= n; j += 8)
        _mm256_storeu_ps(dst + j,
                         _mm256_add_ps(_mm256_loadu_ps(dst + j),
                                       _mm256_mul_ps(va, _mm256_loadu_ps(x + j))));
    for (; j < n; j++) dst[j] += a * x[j];
}

__attribute__((target("avx2")))
static void axpy_minus_avx2(float *dst, float a, const float *x, int n) {
    __m256 va = _mm256_set1_ps(a);
    int j = 0;
    for (; j + 8 <= n; j += 8)
        _mm256_storeu_ps(dst + j,
                         _mm256_sub_ps(_mm256_loadu_ps(dst + j),
                                       _mm256_mul_ps(va, _mm256_loadu_ps(x + j))));
    for (; j < n; j++) dst[j] -= a * x[j];
}

__attribute__((target("avx2")))
static void quad_axpy_avx2(float *dst, const float a[4], const float *b0, const float *b1,
                           const float *b2, const float *b3, int n) {
    __m256 a0 = _mm256_set1_ps(a[0]), a1 = _mm256_set1_ps(a[1]);
    __m256 a2 = _mm256_set1_ps(a[2]), a3 = _mm256_set1_ps(a[3]);
    int j = 0;
    for (; j + 8 <= n; j += 8) {
        __m256 t = _mm256_add_ps(
            _mm256_add_ps(_mm256_add_ps(_mm256_mul_ps(a0, _mm256_loadu_ps(b0 + j)),
                                        _mm256_mul_ps(a1, _mm256_loadu_ps(b1 + j))),
                          _mm256_mul_ps(a2, _mm256_loadu_ps(b2 + j))),
            _mm256_mul_ps(a3, _mm256_loadu_ps(b3 + j)));
        _mm256_storeu_ps(dst + j, _mm256_add_ps(_mm256_loadu_ps(dst + j), t));
    }
    for (; j < n; j++)
        dst[j] += a[0] * b0[j] + a[1] * b1[j] + a[2] * b2[j] + a[3] * b3[j];
}

__attribute__((target("avx2")))
static void colsq_accum_avx2(double *colsq, const float *row, int n) {
    int j = 0;
    for (; j + 8 <= n; j += 8) {
        __m256 v = _mm256_loadu_ps(row + j);
        __m256d lo = _mm256_cvtps_pd(_mm256_castps256_ps128(v));
        __m256d hi = _mm256_cvtps_pd(_mm256_extractf128_ps(v, 1));
        _mm256_storeu_pd(colsq + j, _mm256_add_pd(_mm256_loadu_pd(colsq + j),
                                                  _mm256_mul_pd(lo, lo)));
        _mm256_storeu_pd(colsq + j + 4, _mm256_add_pd(_mm256_loadu_pd(colsq + j + 4),
                                                      _mm256_mul_pd(hi, hi)));
    }
    for (; j < n; j++) colsq[j] += (double)row[j] * (double)row[j];
}

__attribute__((target("avx2")))
static void axpy_minus_colsq_avx2(float *dst, float a, const float *x, double *colsq, int n) {
    __m256 va = _mm256_set1_ps(a);
    int j = 0;
    for (; j + 8 <= n; j += 8) {
        __m256 old = _mm256_loadu_ps(dst + j);
        __m256 nw = _mm256_sub_ps(old, _mm256_mul_ps(va, _mm256_loadu_ps(x + j)));
        _mm256_storeu_ps(dst + j, nw);
        __m256d olo = _mm256_cvtps_pd(_mm256_castps256_ps128(old));
        __m256d ohi = _mm256_cvtps_pd(_mm256_extractf128_ps(old, 1));
        __m256d nlo = _mm256_cvtps_pd(_mm256_castps256_ps128(nw));
        __m256d nhi = _mm256_cvtps_pd(_mm256_extractf128_ps(nw, 1));
        _mm256_storeu_pd(colsq + j,
                         _mm256_add_pd(_mm256_loadu_pd(colsq + j),
                                       _mm256_sub_pd(_mm256_mul_pd(nlo, nlo),
                                                     _mm256_mul_pd(olo, olo))));
        _mm256_storeu_pd(colsq + j + 4,
                         _mm256_add_pd(_mm256_loadu_pd(colsq + j + 4),
                                       _mm256_sub_pd(_mm256_mul_pd(nhi, nhi),
                                                     _mm256_mul_pd(ohi, ohi))));
    }
    for (; j < n; j++) {
        double old = (double)dst[j];
        dst[j] -= a * x[j];
        colsq[j] += (double)dst[j] * (double)dst[j] - old * old;
    }
}

/* matmul: same KC/NC tiling + quad-row skip as matmul_new, inner loops
 * through the AVX2 primitives */
__attribute__((target("avx2")))
static void matmul_simd(const float *a, const float *b, float *c, int m, int k, int n) {
    const int KC = 64, NC = 256;
    memset(c, 0, sizeof(float) * m * n);
    for (int jb = 0; jb < n; jb += NC) {
        int jend = jb + NC < n ? jb + NC : n;
        int jl = jend - jb;
        for (int kb = 0; kb < k; kb += KC) {
            int kend = kb + KC < k ? kb + KC : k;
            int kc = kend - kb, kq = kc - kc % 4;
            for (int i = 0; i < m; i++) {
                const float *arow = &a[i * k + kb];
                float *crow = &c[i * n + jb];
                int kk = 0;
                for (; kk < kq; kk += 4) {
                    float aq[4] = { arow[kk], arow[kk + 1], arow[kk + 2], arow[kk + 3] };
                    if (aq[0] != 0.0f || aq[1] != 0.0f || aq[2] != 0.0f || aq[3] != 0.0f) {
                        int r = kb + kk;
                        quad_axpy_avx2(crow, aq, &b[r * n + jb], &b[(r + 1) * n + jb],
                                       &b[(r + 2) * n + jb], &b[(r + 3) * n + jb], jl);
                    }
                }
                for (; kk < kc; kk++) {
                    float aik = arow[kk];
                    if (aik != 0.0f) axpy_avx2(crow, aik, &b[(kb + kk) * n + jb], jl);
                }
            }
        }
    }
}

/* lane-block spd_inverse: 8 unit columns j0..j0+7 share one forward +
 * backward triangular sweep, one __m256 per row (linalg.rs lane path) */
__attribute__((target("avx2")))
static void spd_inverse_simd(const float *a, float *inv, int n) {
    float *l = malloc(sizeof(float) * n * n);
    float *lt = malloc(sizeof(float) * n * n);
    float *y = malloc(sizeof(float) * n * 8);
    float *x = malloc(sizeof(float) * n * 8);
    cholesky(a, l, n);
    for (int i = 0; i < n; i++)
        for (int j = 0; j < n; j++) lt[j * n + i] = l[i * n + j];
    for (int j0 = 0; j0 < n; j0 += 8) {
        int lanes = n - j0 < 8 ? n - j0 : 8;
        for (int i = j0; i < n; i++) {
            __m256 acc = _mm256_setzero_ps();
            for (int k = j0; k < i; k++)
                acc = _mm256_add_ps(acc, _mm256_mul_ps(_mm256_set1_ps(l[i * n + k]),
                                                       _mm256_loadu_ps(y + k * 8)));
            __m256 neg = _mm256_xor_ps(acc, _mm256_set1_ps(-0.0f));
            _mm256_storeu_ps(y + i * 8, _mm256_div_ps(neg, _mm256_set1_ps(l[i * n + i])));
            if (i - j0 < 8) y[i * 8 + (i - j0)] = 1.0f / l[i * n + i];
        }
        for (int i = n - 1; i >= j0; i--) {
            __m256 s = _mm256_loadu_ps(y + i * 8);
            for (int k = i + 1; k < n; k++)
                s = _mm256_sub_ps(s, _mm256_mul_ps(_mm256_set1_ps(lt[i * n + k]),
                                                   _mm256_loadu_ps(x + k * 8)));
            _mm256_storeu_ps(x + i * 8, _mm256_div_ps(s, _mm256_set1_ps(l[i * n + i])));
        }
        for (int t = 0; t < lanes; t++) {
            int j = j0 + t;
            for (int i = j; i < n; i++) { inv[i * n + j] = x[i * 8 + t]; inv[j * n + i] = x[i * 8 + t]; }
        }
    }
    free(l); free(lt); free(y); free(x);
}

__attribute__((target("avx2")))
static void scores_simd_g1(const float *w, const float *hinv, const float *act,
                           int d_row, int d, float *out, double *colsq) {
    for (int j = 0; j < d; j++) colsq[j] = 0.0;
    for (int i = 0; i < d_row; i++) colsq_accum_avx2(colsq, &w[i * d], d);
    for (int j = 0; j < d; j++)
        out[j] = act[j] > 0.0f ? (float)(colsq[j] / (double)hinv[j * d + j]) : 1e30f;
}

__attribute__((target("avx2")))
static void update_simd_g1(const float *w0, const float *h0, int idx, int d_row, int d,
                           float *out_w, float *out_h) {
    float *w = malloc(sizeof(float) * d_row * d);
    float *h = malloc(sizeof(float) * d * d);
    memcpy(w, w0, sizeof(float) * d_row * d);
    memcpy(h, h0, sizeof(float) * d * d);
    float *p = malloc(sizeof(float) * d);
    float *cbuf = malloc(sizeof(float) * d);
    float binv = 1.0f / h[idx * d + idx];
    for (int k = 0; k < d; k++) p[k] = binv * h[idx * d + k];
    for (int i = 0; i < d_row; i++) {
        float *row = &w[i * d];
        float wij = row[idx];
        if (wij != 0.0f) axpy_minus_avx2(row, wij, p, d);
        row[idx] = 0.0f;
    }
    for (int r = 0; r < d; r++) cbuf[r] = h[r * d + idx];
    for (int r = 0; r < d; r++) {
        float c = cbuf[r];
        if (c == 0.0f) continue;
        axpy_minus_avx2(&h[r * d], c, p, d);
    }
    for (int k = 0; k < d; k++) { h[idx * d + k] = 0.0f; h[k * d + idx] = 0.0f; }
    h[idx * d + idx] = 1.0f;
    SINK = w[1] + h[1];
    if (out_w) memcpy(out_w, w, sizeof(float) * d_row * d);
    if (out_h) memcpy(out_h, h, sizeof(float) * d * d);
    free(w); free(h); free(p); free(cbuf);
}

/* alive-hybrid multi_update with the dense block routed through the
 * AVX2 primitives (the compact block is index-gather work and stays
 * scalar, exactly as in the Rust dispatch layer) */
__attribute__((target("avx2")))
static void multi_update_alive_simd(const float *w0, const float *h0, const float *act0,
                                    int d_row, int d, int nrm, float *out_w, float *out_h) {
    float *w = malloc(sizeof(float) * d_row * d);
    float *h = malloc(sizeof(float) * d * d);
    float *act = malloc(sizeof(float) * d);
    memcpy(w, w0, sizeof(float) * d_row * d);
    memcpy(h, h0, sizeof(float) * d * d);
    memcpy(act, act0, sizeof(float) * d);
    int *alive = malloc(sizeof(int) * d);
    int n_alive = 0;
    for (int j = 0; j < d; j++) if (act[j] > 0.0f) alive[n_alive++] = j;
    double *colsq = malloc(sizeof(double) * d);
    float *p = malloc(sizeof(float) * d);
    float *cbuf = malloc(sizeof(float) * d);
    for (int j = 0; j < d; j++) colsq[j] = 0.0;
    for (int i = 0; i < d_row; i++) colsq_accum_avx2(colsq, &w[i * d], d);
    for (int s = 0; s < nrm; s++) {
        int best = alive[0];
        float best_s = INFINITY;
        for (int t = 0; t < n_alive; t++) {
            int j = alive[t];
            double cs = colsq[j] > 0.0 ? colsq[j] : 0.0;
            float sc = (float)(cs / (double)h[j * d + j]);
            if (sc < best_s) { best_s = sc; best = j; }
        }
        int j = best;
        float hjj_inv = 1.0f / h[j * d + j];
        if (n_alive * 2 < d) {
            for (int t = 0; t < n_alive; t++) p[t] = h[j * d + alive[t]] * hjj_inv;
            for (int i = 0; i < d_row; i++) {
                float *row = &w[i * d];
                float wij = row[j];
                if (wij != 0.0f) {
                    for (int t = 0; t < n_alive; t++) {
                        int c = alive[t];
                        double old = (double)row[c];
                        row[c] -= wij * p[t];
                        colsq[c] += (double)row[c] * (double)row[c] - old * old;
                    }
                }
                row[j] = 0.0f;
            }
            colsq[j] = 0.0;
            for (int t = 0; t < n_alive; t++) {
                int r = alive[t];
                float c = h[r * d + j];
                if (c == 0.0f) continue;
                float *hrow = &h[r * d];
                for (int tt = 0; tt < n_alive; tt++) hrow[alive[tt]] -= c * p[tt];
            }
            for (int t = 0; t < n_alive; t++) { h[j * d + alive[t]] = 0.0f; h[alive[t] * d + j] = 0.0f; }
            h[j * d + j] = 1.0f;
        } else {
            for (int k = 0; k < d; k++) p[k] = h[j * d + k] * hjj_inv;
            for (int i = 0; i < d_row; i++) {
                float *row = &w[i * d];
                float wij = row[j];
                if (wij != 0.0f) axpy_minus_colsq_avx2(row, wij, p, colsq, d);
                row[j] = 0.0f;
            }
            colsq[j] = 0.0;
            for (int r = 0; r < d; r++) cbuf[r] = h[r * d + j];
            for (int r = 0; r < d; r++) {
                float c = cbuf[r];
                if (c == 0.0f) continue;
                axpy_minus_avx2(&h[r * d], c, p, d);
            }
            for (int k = 0; k < d; k++) { h[j * d + k] = 0.0f; h[k * d + j] = 0.0f; }
            h[j * d + j] = 1.0f;
        }
        act[j] = 0.0f;
        for (int t = 0; t < n_alive; t++)
            if (alive[t] == j) { memmove(&alive[t], &alive[t + 1], sizeof(int) * (n_alive - t - 1)); n_alive--; break; }
    }
    SINK = w[0] + h[0];
    if (out_w) memcpy(out_w, w, sizeof(float) * d_row * d);
    if (out_h) memcpy(out_h, h, sizeof(float) * d * d);
    free(w); free(h); free(act); free(alive); free(colsq); free(p); free(cbuf);
}

#endif /* HAVE_SIMD_MIRROR */

#ifdef HAVE_SIMD_MIRROR
/* -------------------------------------------------------- selfcheck
 * `./bench_mirror --selfcheck`: differential BIT-IDENTITY check of
 * every AVX2 variant against its scalar twin, over remainder-heavy
 * shapes (the same sweep the Rust wall in tests/kernel_equiv.rs
 * runs). CI runs this before timing anything, so the mirror's SIMD
 * numbers are only ever produced by code proven bit-equal to the
 * scalar baseline it is compared against. */
static int bits_differ(const char *what, const float *a, const float *b, size_t n) {
    for (size_t i = 0; i < n; i++) {
        unsigned ua, ub;
        memcpy(&ua, &a[i], 4);
        memcpy(&ub, &b[i], 4);
        if (ua != ub) {
            printf("SELFCHECK FAIL %s: first diff at %zu (0x%08x vs 0x%08x)\n", what, i, ua, ub);
            return 1;
        }
    }
    return 0;
}

static int selfcheck(void) {
    int fails = 0;
    char what[96];

    /* tiled GEMM vs AVX2 tiles, incl. the quad-skip zero path */
    static const int MS[][3] = {{1, 1, 1}, {3, 5, 7}, {9, 17, 23}, {33, 12, 65}, {64, 70, 66}};
    for (size_t t = 0; t < sizeof(MS) / sizeof(MS[0]); t++) {
        int m = MS[t][0], k = MS[t][1], n = MS[t][2];
        float *a = malloc(sizeof(float) * m * k), *b = malloc(sizeof(float) * k * n);
        float *c0 = malloc(sizeof(float) * m * n), *c1 = malloc(sizeof(float) * m * n);
        for (int i = 0; i < m * k; i++) a[i] = (i % 7 == 0) ? 0.0f : frand();
        for (int i = 0; i < k * n; i++) b[i] = frand();
        matmul_new(a, b, c0, m, k, n);
        matmul_simd(a, b, c1, m, k, n);
        snprintf(what, sizeof(what), "matmul %dx%dx%d", m, k, n);
        fails += bits_differ(what, c0, c1, (size_t)m * n);
        free(a); free(b); free(c0); free(c1);
    }

    /* lane-block spd_inverse at every remainder dim class */
    static const int NS[] = {1, 2, 3, 4, 5, 6, 7, 8, 9, 12, 15, 16, 17, 25, 33, 40, 114};
    for (size_t t = 0; t < sizeof(NS) / sizeof(NS[0]); t++) {
        int n = NS[t];
        float *h = malloc(sizeof(float) * n * n);
        float *i0 = malloc(sizeof(float) * n * n), *i1 = malloc(sizeof(float) * n * n);
        make_spd(h, n, 0.4f);
        spd_inverse_fast(h, i0, n);
        spd_inverse_simd(h, i1, n);
        snprintf(what, sizeof(what), "spd_inverse %d", n);
        fails += bits_differ(what, i0, i1, (size_t)n * n);
        free(h); free(i0); free(i1);
    }

    /* scores / update / multi_update on one remainder-width problem
     * with dead columns (d=100: 100%8 lanes, every 7th column dead) */
    {
        const int dr = 13, d = 100;
        float *h = malloc(sizeof(float) * d * d), *hi = malloc(sizeof(float) * d * d);
        float *wv = malloc(sizeof(float) * dr * d), *a = malloc(sizeof(float) * d);
        make_spd(h, d, 0.4f);
        spd_inverse_fast(h, hi, d);
        for (int i = 0; i < dr * d; i++) wv[i] = frand();
        for (int j = 0; j < d; j++) a[j] = (j % 7 == 3) ? 0.0f : 1.0f;

        float *s0 = malloc(sizeof(float) * d), *s1 = malloc(sizeof(float) * d);
        double *cq = malloc(sizeof(double) * d);
        scores_fast_g1(wv, hi, a, dr, d, s0, cq);
        scores_simd_g1(wv, hi, a, dr, d, s1, cq);
        fails += bits_differ("scores g=1 d=100", s0, s1, d);

        float *w0 = malloc(sizeof(float) * dr * d), *h0 = malloc(sizeof(float) * d * d);
        float *w1 = malloc(sizeof(float) * dr * d), *h1 = malloc(sizeof(float) * d * d);
        update_fast_g1(wv, hi, 4, dr, d, w0, h0);
        update_simd_g1(wv, hi, 4, dr, d, w1, h1);
        fails += bits_differ("update g=1 W", w0, w1, (size_t)dr * d);
        fails += bits_differ("update g=1 Hinv", h0, h1, (size_t)d * d);

        /* shallow stays dense; deep crosses into the compact passes */
        static const int NRM[] = {6, 78};
        for (size_t t = 0; t < sizeof(NRM) / sizeof(NRM[0]); t++) {
            multi_update_alive(wv, hi, a, dr, d, NRM[t], w0, h0);
            multi_update_alive_simd(wv, hi, a, dr, d, NRM[t], w1, h1);
            snprintf(what, sizeof(what), "multi_update n=%d W", NRM[t]);
            fails += bits_differ(what, w0, w1, (size_t)dr * d);
            snprintf(what, sizeof(what), "multi_update n=%d Hinv", NRM[t]);
            fails += bits_differ(what, h0, h1, (size_t)d * d);
        }
        free(h); free(hi); free(wv); free(a); free(s0); free(s1); free(cq);
        free(w0); free(h0); free(w1); free(h1);
    }

    if (fails == 0)
        printf("SELFCHECK ok: every avx2 variant bit-identical to its scalar twin\n");
    return fails;
}
#endif /* HAVE_SIMD_MIRROR */

/* ----------------------------------------------------------- harness */
static int cmp_d(const void *a, const void *b) {
    double x = *(const double *)a, y = *(const double *)b;
    return (x > y) - (x < y);
}

/* Machine-readable output: `BENCH <json key> | min <ns> | median <ns>
 * | n <N>`. The key must match BENCH_hotpath.json exactly —
 * check_regression.py parses these lines to regenerate the file. */
#define TIME(name, iters, stmt) do { \
    double samples[64]; \
    int nn = (iters) < 64 ? (iters) : 64; \
    { stmt; } /* warmup */ \
    for (int it = 0; it < nn; it++) { \
        double t0 = now_ns(); \
        { stmt; } \
        samples[it] = now_ns() - t0; \
    } \
    qsort(samples, nn, sizeof(double), cmp_d); \
    printf("BENCH %s | min %.0f | median %.0f | n %d\n", name, samples[0], samples[nn / 2], nn); \
} while (0)

int main(int argc, char **argv) {
    if (argc > 1 && strcmp(argv[1], "--selfcheck") == 0) {
#ifdef HAVE_SIMD_MIRROR
        if (__builtin_cpu_supports("avx2")) return selfcheck() == 0 ? 0 : 1;
        printf("SELFCHECK skipped: cpu lacks avx2\n");
        return 0;
#else
        printf("SELFCHECK skipped: non-x86 build\n");
        return 0;
#endif
    }
    const int D = 512, DR = 128;
    float *h512 = malloc(sizeof(float) * D * D);
    make_spd(h512, D, 0.3f * D > 1 ? 0.3f : 0.3f); /* damp*n applied inside */
    float *hinv = malloc(sizeof(float) * D * D);
    spd_inverse_fast(h512, hinv, D);
    float *w = malloc(sizeof(float) * DR * D);
    for (int i = 0; i < DR * D; i++) w[i] = frand();
    float *act = malloc(sizeof(float) * D);
    for (int i = 0; i < D; i++) act[i] = 1.0f;
    float *out = malloc(sizeof(float) * D);
    double *colsq = malloc(sizeof(double) * D);

    /* matmul 256 */
    int M = 256;
    float *ma = malloc(sizeof(float) * M * M), *mb = malloc(sizeof(float) * M * M), *mc = malloc(sizeof(float) * M * M);
    for (int i = 0; i < M * M; i++) { ma[i] = frand(); mb[i] = frand(); }
    TIME("tensor::matmul 256x256x256 seed_ref", 30, { matmul_old(ma, mb, mc, M, M, M); SINK = mc[7]; });
    TIME("tensor::matmul 256x256x256", 30, { matmul_new(ma, mb, mc, M, M, M); SINK = mc[7]; });

    /* spd_inverse 512 */
    float *inv = malloc(sizeof(float) * D * D);
    TIME("linalg::spd_inverse_ref 512", 12, { spd_inverse_ref(h512, inv, D); SINK = inv[3]; });
    TIME("linalg::spd_inverse 512", 12, { spd_inverse_fast(h512, inv, D); SINK = inv[3]; });

    /* scores fc 128x512 g=1 */
    TIME("obs::scores native_ref fc(128x512)", 30, { scores_ref(w, hinv, act, DR, D, 1, out); SINK = out[5]; });
    TIME("obs::scores native fc(128x512)", 60, { scores_fast_g1(w, hinv, act, DR, D, out, colsq); SINK = out[5]; });

    /* scores attn g=64, 8 heads */
    float act8[8]; for (int i = 0; i < 8; i++) act8[i] = 1.0f;
    float out8[8];
    TIME("obs::scores native_ref attn(g=64, 8 heads)", 30, { scores_ref(w, hinv, act8, DR, D, 64, out8); SINK = out8[3]; });
    TIME("obs::scores native attn(g=64, 8 heads)", 30, { scores_fast_grouped(w, hinv, act8, DR, D, 64, out8); SINK = out8[3]; });

    /* single update g=1 */
    { float *w2, *h2;
      TIME("obs::update native_ref fc(128x512)", 40, { update_ref_g1(w, hinv, 3, DR, D, &w2, &h2); SINK = w2[9] + h2[9]; free(w2); free(h2); }); }
    TIME("obs::update native fc(128x512)", 40, { update_fast_g1(w, hinv, 3, DR, D, NULL, NULL); });

    /* multi_update n=45: ref (clone per step) vs PR-4 fast
     * (incremental colsq, always-dense passes — now the "prev" entry)
     * vs PR-10 alive-set hybrid (the current NativeBackend path) */
    TIME("obs::multi_update native_ref fc(128x512) n=45", 12, { multi_update_ref(w, hinv, act, DR, D, 45); });
    TIME("obs::multi_update native_prev fc(128x512) n=45", 20, { multi_update_fast_incr(w, hinv, act, DR, D, 45); });
    TIME("obs::multi_update native fc(128x512) n=45", 20, { multi_update_alive(w, hinv, act, DR, D, 45, NULL, NULL); });

    /* deep removal ladder (460 of 512 structures): the alive-set
     * hybrid's O(n_alive^2) late steps vs always-dense O(d^2). The
     * ref (clone + fresh scores per step) is omitted — at this depth
     * it measures minutes, not a ratio. */
    TIME("obs::multi_update native_prev fc(128x512) deep n=460", 8, { multi_update_fast_incr(w, hinv, act, DR, D, 460); });
    TIME("obs::multi_update native fc(128x512) deep n=460", 8, { multi_update_alive(w, hinv, act, DR, D, 460, NULL, NULL); });

#ifdef HAVE_SIMD_MIRROR
    if (__builtin_cpu_supports("avx2")) {
        /* per-variant SIMD entries, keyed "simd"/"native_simd": the
         * check_regression.py gate treats them as informational when
         * absent (non-x86 runner or the no-simd feature leg) */
        TIME("tensor::matmul 256x256x256 simd", 30, { matmul_simd(ma, mb, mc, M, M, M); SINK = mc[7]; });
        TIME("linalg::spd_inverse 512 simd", 12, { spd_inverse_simd(h512, inv, D); SINK = inv[3]; });
        TIME("obs::scores native_simd fc(128x512)", 60, { scores_simd_g1(w, hinv, act, DR, D, out, colsq); SINK = out[5]; });
        TIME("obs::update native_simd fc(128x512)", 40, { update_simd_g1(w, hinv, 3, DR, D, NULL, NULL); });
        TIME("obs::multi_update native_simd fc(128x512) n=45", 20, { multi_update_alive_simd(w, hinv, act, DR, D, 45, NULL, NULL); });
        TIME("obs::multi_update native_simd fc(128x512) deep n=460", 8, { multi_update_alive_simd(w, hinv, act, DR, D, 460, NULL, NULL); });
    } else {
        printf("(simd benches skipped: cpu lacks avx2)\n");
    }
#else
    printf("(simd benches skipped: non-x86 build)\n");
#endif

    return 0;
}
