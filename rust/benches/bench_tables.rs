//! Paper-table benches: CI-sized versions of every experiment driver
//! (the full versions run via `ziplm experiment <id>` and are recorded
//! in EXPERIMENTS.md). Each bench prints the same row shape the paper
//! reports.
//!
//!   cargo bench --bench bench_tables

#![allow(clippy::disallowed_methods)] // test/bench/example code: unwrap-on-failure is fine

use std::path::Path;

use ziplm::exp::{self, ExpCtx};

fn main() {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        println!("bench_tables skipped: artifacts/ not built (run `make artifacts`)");
        return;
    }
    let ctx = ExpCtx::new(&dir, true).expect("ctx"); // fast mode
    // fast, deterministic subset: the measurement/analytic tables (no
    // training). Training-heavy experiments (fig2/3/4/5, table1/2/4/5/8)
    // run via `ziplm experiment <id>` — see EXPERIMENTS.md.
    for id in ["table3", "table7"] {
        println!("=== bench {id} (fast) ===");
        let t0 = std::time::Instant::now();
        if let Err(e) = exp::run(&ctx, id) {
            println!("{id} failed: {e:#}");
        }
        println!("=== {id} done in {:.1}s ===\n", t0.elapsed().as_secs_f64());
    }
}
