//! Shared integration-test support (cargo compiles `tests/*.rs` as
//! separate crates; both the pipeline and session suites include this
//! via `mod support;` so the synthetic environment they drive is ONE
//! definition, not a drifting copy).
#![allow(dead_code)] // each test crate uses a subset

use std::path::Path;

use ziplm::env::InferenceEnv;
use ziplm::latency::LatencyTable;
use ziplm::runtime::Engine;

/// Open the artifact-backed engine, or `None` (skip the test) when
/// `artifacts/` has not been built in this checkout.
pub fn engine() -> Option<Engine> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts/ not built");
        return None;
    }
    Some(Engine::open(&dir).expect("engine"))
}

/// Synthetic environment so tests do not depend on measurement noise:
/// linear attention ladder, affine FFN pricing over the model's
/// manifest ladder, fixed overhead.
pub fn toy_env(engine: &Engine, model: &str) -> InferenceEnv {
    let info = engine.manifest.model(model);
    let attn: Vec<f64> = (0..=info.n_heads).map(|h| h as f64 * 1.0e-3).collect();
    let mut mlp: Vec<(usize, f64)> = info
        .ffn_ladder
        .iter()
        .map(|&w| (w, w as f64 * 1.6e-5 + if w > 0 { 5e-4 } else { 0.0 }))
        .collect();
    mlp.sort_by(|a, b| b.0.cmp(&a.0));
    InferenceEnv::measured(LatencyTable {
        model: model.into(),
        device: "toy".into(),
        regime: "throughput".into(),
        attn,
        mlp,
        overhead: 1e-3,
    })
    .unwrap()
}
