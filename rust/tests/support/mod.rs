//! Shared integration-test support (cargo compiles `tests/*.rs` as
//! separate crates; the pipeline, session, runtime, and fleet suites
//! include this via `mod support;` so the synthetic environments and
//! configs they drive are ONE definition each, not drifting copies).
#![allow(dead_code)] // each test crate uses a subset

use std::path::{Path, PathBuf};
use std::time::Duration;

use ziplm::coordinator::family::BucketLadder;
use ziplm::coordinator::fleet::{FleetCfg, FleetMember, RetryPolicy};
use ziplm::env::InferenceEnv;
use ziplm::latency::LatencyTable;
use ziplm::pruner::{PruneCfg, SpdyCfgLite};
use ziplm::runtime::Engine;
use ziplm::train::TrainCfg;

/// Open the artifact-backed engine, or `None` (skip the test) when
/// `artifacts/` has not been built in this checkout.
pub fn engine() -> Option<Engine> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts/ not built");
        return None;
    }
    Some(Engine::open(&dir).expect("engine"))
}

/// Synthetic environment so tests do not depend on measurement noise:
/// linear attention ladder, affine FFN pricing over the model's
/// manifest ladder, fixed overhead.
pub fn toy_env(engine: &Engine, model: &str) -> InferenceEnv {
    let info = engine.manifest.model(model);
    let attn: Vec<f64> = (0..=info.n_heads).map(|h| h as f64 * 1.0e-3).collect();
    let mut mlp: Vec<(usize, f64)> = info
        .ffn_ladder
        .iter()
        .map(|&w| (w, w as f64 * 1.6e-5 + if w > 0 { 5e-4 } else { 0.0 }))
        .collect();
    mlp.sort_by(|a, b| b.0.cmp(&a.0));
    InferenceEnv::measured(LatencyTable {
        model: model.into(),
        device: "toy".into(),
        regime: "throughput".into(),
        attn,
        mlp,
        overhead: 1e-3,
    })
    .unwrap()
}

/// A second, differently-priced environment derived from `env`: same
/// ladder shape, uniformly different block times — enough to change
/// SPDY's cost trade-offs without breaking table monotonicity.
pub fn other_env(env: &InferenceEnv) -> InferenceEnv {
    let mut t = env.table().clone();
    for v in t.attn.iter_mut() {
        *v *= 3.0;
    }
    t.overhead *= 0.25;
    t.device = "toy-b".into();
    InferenceEnv::measured(t).unwrap()
}

/// Fresh per-test scratch directory under the OS temp dir; any
/// leftover from a previous (crashed) run is removed first.
pub fn temp_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("ziplm_itest_{tag}"));
    let _ = std::fs::remove_dir_all(&d);
    d
}

/// Small-but-real pruning config for integration runs: enough calib
/// samples and SPDY iterations to exercise the whole path, fast enough
/// for CI.
pub fn cfg() -> PruneCfg {
    PruneCfg { calib_samples: 16, spdy: SpdyCfgLite { iters: 4, seed: 5 }, ..Default::default() }
}

/// Quarter-epoch distillation config matching `cfg()` above.
pub fn tcfg() -> TrainCfg {
    TrainCfg {
        lr: 5e-4,
        epochs: 0.25,
        lambdas: [1.0, 0.0, 0.0],
        weight_decay: 0.0,
        seed: 0,
        log_every: 0,
    }
}

/// Engine-free measured environment for the fleet/chaos suites:
/// hand-written table, batch shape (8, 64), three-point seq sweep.
pub fn fleet_env() -> InferenceEnv {
    let table = LatencyTable {
        model: "m".into(),
        device: "sim".into(),
        regime: "throughput".into(),
        attn: vec![0.0, 1.0e-3, 1.8e-3, 2.5e-3, 3.1e-3],
        mlp: vec![(512, 8e-3), (256, 4.2e-3), (64, 1.5e-3), (0, 0.0)],
        overhead: 1e-3,
    };
    InferenceEnv::measured(table)
        .unwrap()
        .with_batch_shape(8, 64)
        .with_seq_sweep(vec![(16, 0.4), (32, 0.7), (64, 1.0)])
}

/// Three-member speedup ladder served by the simulated fleet.
pub fn fleet_members() -> Vec<FleetMember> {
    vec![
        FleetMember { tag: "dense".into(), profile: vec![(4, 512); 2] },
        FleetMember { tag: "2x".into(), profile: vec![(2, 256); 2] },
        FleetMember { tag: "4x".into(), profile: vec![(1, 64); 2] },
    ]
}

/// Fleet config shared by the chaos acceptance tests: tight timings
/// (time_scale 0.0) so campaigns run in milliseconds.
pub fn fleet_cfg(workers: usize) -> FleetCfg {
    FleetCfg {
        workers,
        skews: vec![1.0, 1.2, 0.9],
        max_batch: 4,
        max_wait: Duration::from_micros(200),
        queue_cap: 64,
        retry: RetryPolicy { max_retries: 3, base: Duration::from_micros(150), factor: 2.0 },
        quarantine_after: 50,
        restart_delay: Duration::from_micros(400),
        buckets: BucketLadder::new(fleet_env().bucket_ladder()),
        time_scale: 0.0,
    }
}
