//! Fleet serving acceptance tests (DESIGN.md §10) — fully engine-free.
//!
//! The contract under test, per ISSUE acceptance:
//!   1. under arbitrary seeded FaultPlans, every submitted request
//!      terminates in exactly one of {Replied, Shed, Abandoned};
//!   2. no panic crosses a worker boundary (a poison-pill batch panics
//!      the simulated backend; the caller must see `Abandoned`, not a
//!      propagated panic);
//!   3. a crashed worker's in-flight work is re-dispatched or
//!      abandoned, never silently dropped (that IS property 1 plus the
//!      crash counters);
//!   4. after a supervisor restart, the re-warmed cache shard's
//!      `builds()` equals the distinct (member, bucket) executables it
//!      re-served.

#![allow(clippy::disallowed_methods)]

mod support;

use std::collections::HashSet;
use std::time::Duration;

use support::{fleet_cfg as cfg, fleet_env as env, fleet_members as members};
use ziplm::coordinator::chaos::{gen_trace, run_chaos, TraceCfg, TraceClass};
use ziplm::coordinator::family::Sla;
use ziplm::coordinator::fleet::{
    self, admit, sim_logits, Outcome, RetryPolicy, ShedReason, WorkerView, SIM_WIDTH,
};
use ziplm::env::CostModel;
use ziplm::runtime::{FaultPlan, FaultRates};
use ziplm::util::prop::Prop;
use ziplm::util::rng::Rng;

// ------------------------------------------------------------------
// 1. exactly-one-outcome under arbitrary seeded fault plans
// ------------------------------------------------------------------

#[test]
fn every_request_terminates_exactly_once_under_arbitrary_faults() {
    let env = env();
    Prop::new(6).check_msg(
        "fleet-exactly-one-outcome",
        |rng: &mut Rng| {
            (
                rng.next_u64(),
                rng.f64() * 0.3,        // crash
                rng.f64() * 0.4,        // compile_fail
                rng.f64() * 0.5,        // slowdown
                1.0 + rng.f64() * 4.0,  // slowdown_factor
                rng.f64() * 0.1,        // nan_latency
            )
        },
        |&(seed, crash, compile_fail, slowdown, slowdown_factor, nan_latency)| {
            let rates =
                FaultRates { crash, compile_fail, slowdown, slowdown_factor, nan_latency };
            let trace = TraceCfg {
                requests: 48,
                seed: seed ^ 0x51,
                arrival_gap: Duration::ZERO,
                len_range: (4, 64),
                classes: vec![
                    TraceClass::best_effort(2.0),
                    TraceClass {
                        class: "rt".into(),
                        weight: 1.0,
                        max_latency: Some(Duration::from_millis(40)),
                        min_speedup: None,
                    },
                ],
            };
            let report = run_chaos(
                cfg(3),
                members(),
                &env,
                FaultPlan::seeded(seed, rates),
                &trace,
            )
            .map_err(|e| e.to_string())?;
            if !report.balanced() {
                return Err(format!(
                    "unbalanced: submitted {} replied {} shed {} abandoned {} lost {}",
                    report.submitted, report.replied, report.shed, report.abandoned, report.lost
                ));
            }
            // the fleet's own ledger must agree with the client's view
            if report.stats.replied != report.replied
                || report.stats.shed != report.shed
                || report.stats.abandoned != report.abandoned
            {
                return Err(format!(
                    "ledger mismatch: stats ({}, {}, {}) vs client ({}, {}, {})",
                    report.stats.replied,
                    report.stats.shed,
                    report.stats.abandoned,
                    report.replied,
                    report.shed,
                    report.abandoned
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn crash_free_plans_reply_to_every_request() {
    // slowdowns and NaN latency samples degrade but never lose work
    let rates = FaultRates {
        crash: 0.0,
        compile_fail: 0.0,
        slowdown: 0.4,
        slowdown_factor: 5.0,
        nan_latency: 0.5,
    };
    let trace = TraceCfg {
        requests: 64,
        seed: 9,
        arrival_gap: Duration::ZERO,
        len_range: (4, 64),
        classes: Vec::new(), // best-effort only: nothing can shed on SLA
    };
    let report =
        run_chaos(cfg(2), members(), &env(), FaultPlan::seeded(3, rates), &trace).unwrap();
    assert!(report.balanced());
    assert_eq!(report.replied, report.submitted, "no crash → nothing may be dropped");
    assert_eq!(report.stats.crashes, 0);
    assert!(report.stats.nan_samples > 0, "nan rate 0.5 over 16+ batches must fire");
}

// ------------------------------------------------------------------
// 2. a real panic stays inside the worker boundary
// ------------------------------------------------------------------

#[test]
fn worker_panic_never_crosses_the_boundary() {
    let fleet = fleet::start(cfg(2), members(), &env(), FaultPlan::none()).unwrap();
    // the poison pill panics the simulated backend on every attempt;
    // retries exhaust and the caller sees Abandoned — never a panic
    let poisoned = fleet.submit(vec![1, i32::MIN, 3], None).unwrap();
    let out = poisoned.recv_timeout(Duration::from_secs(30)).unwrap();
    match out {
        Outcome::Abandoned { attempts, .. } => {
            assert!(attempts >= 1, "the pill was dispatched at least once")
        }
        other => panic!("poison pill must end Abandoned, got {other:?}"),
    }
    // the fleet survives and keeps serving normal traffic
    let ok = fleet.infer(vec![5, 6, 7], None).unwrap();
    match ok {
        Outcome::Replied(r) => assert_eq!(r.logits, sim_logits(&r.member, &[5, 6, 7], SIM_WIDTH)),
        other => panic!("fleet must still serve after a panic, got {other:?}"),
    }
    let stats = fleet.shutdown().unwrap();
    assert!(stats.crashes >= 1, "each panic counts as a crash");
    assert_eq!(stats.accounted(), stats.submitted);
}

// ------------------------------------------------------------------
// 3. crashed in-flight work is re-dispatched (retried replies exist)
// ------------------------------------------------------------------

#[test]
fn crashed_inflight_work_is_redispatched_not_dropped() {
    // moderate crash rate: plenty of crashes, but retries usually land
    let rates = FaultRates { crash: 0.3, ..FaultRates::default() };
    let trace = TraceCfg {
        requests: 96,
        seed: 21,
        arrival_gap: Duration::from_micros(30),
        len_range: (4, 48),
        classes: Vec::new(),
    };
    let report =
        run_chaos(cfg(3), members(), &env(), FaultPlan::seeded(77, rates), &trace).unwrap();
    assert!(report.balanced());
    assert!(report.stats.crashes > 0, "crash rate 0.3 must crash someone");
    assert!(
        report.retried_replies > 0,
        "some replies must have survived a crash via re-dispatch (retries {})",
        report.stats.retries
    );
    assert!(report.replied > 0);
    // abandoned requests are allowed (retry exhaustion) but every one
    // of them is accounted — that is exactly `balanced()` above
}

// ------------------------------------------------------------------
// 4. restart re-warms the shard: builds() == distinct pairs re-served
// ------------------------------------------------------------------

#[test]
fn rewarmed_shard_builds_equal_distinct_served_pairs() {
    let env = env();
    let anchor = env.batch_shape();
    let rates = FaultRates { crash: 0.25, compile_fail: 0.1, ..FaultRates::default() };
    let fleet = fleet::start(cfg(3), members(), &env, FaultPlan::seeded(41, rates)).unwrap();
    let mut rng = Rng::new(0xF1EE7);
    let mut rxs = Vec::new();
    for _ in 0..150 {
        let len = 4 + rng.below(60);
        let ids: Vec<i32> = (0..len).map(|_| rng.below(1000) as i32).collect();
        rxs.push(fleet.submit(ids, None).unwrap());
    }
    // collect the replies: which executable key did each one exercise?
    // specialized replies used (member, bucket); generic ones used the
    // member's anchor graph. builds() counts only successful compiles,
    // so per (worker, incarnation) the distinct key set IS the build
    // count of the shard serving that incarnation.
    // (worker, incarnation) → distinct executable keys its replies used
    type ServedKeys = std::collections::HashMap<(usize, u32), HashSet<(String, (usize, usize), bool)>>;
    let mut keys_by_worker_inc: ServedKeys = Default::default();
    for rx in rxs {
        if let Outcome::Replied(r) = rx.recv_timeout(Duration::from_secs(30)).unwrap() {
            let key = if r.specialized {
                (r.member.clone(), r.bucket, true)
            } else {
                (r.member.clone(), anchor, false)
            };
            keys_by_worker_inc.entry((r.worker, r.incarnation)).or_default().insert(key);
        }
    }
    let stats = fleet.shutdown().unwrap();
    assert_eq!(stats.accounted(), stats.submitted);
    assert!(stats.restarts > 0, "crash rate 0.25 over ~40 batches must restart someone");
    for w in &stats.per_worker {
        let served_keys = keys_by_worker_inc
            .get(&(w.worker, w.incarnation))
            .map(|s| s.len())
            .unwrap_or(0);
        assert_eq!(
            w.builds, served_keys,
            "worker {} incarnation {}: shard builds {} != distinct (member, bucket) pairs {}",
            w.worker, w.incarnation, w.builds, served_keys
        );
    }
}

// ------------------------------------------------------------------
// admission policy + backoff properties (pure, no threads)
// ------------------------------------------------------------------

#[test]
fn admit_never_picks_a_dead_or_full_worker() {
    let envv = env();
    let mems = members();
    let mut order: Vec<usize> = (0..mems.len()).collect();
    let base: Vec<f64> = mems.iter().map(|m| envv.speedup(&m.profile)).collect();
    order.sort_by(|&a, &b| base[a].total_cmp(&base[b]));
    let routes: Vec<ziplm::coordinator::family::MemberRoute> = order
        .iter()
        .map(|&i| ziplm::coordinator::family::MemberRoute {
            tag: mems[i].tag.clone(),
            est_speedup: envv.speedup(&mems[i].profile),
            est_batch_time: envv.model_time(&mems[i].profile),
            bucket_times: Vec::new(),
        })
        .collect();
    Prop::new(200).check(
        "admit-respects-liveness-and-capacity",
        |rng: &mut Rng| {
            let n = 1 + rng.below(4);
            let views: Vec<(bool, usize, f64)> = (0..n)
                .map(|_| (rng.below(4) > 0, rng.below(10), rng.f64() * 0.2))
                .collect();
            let sla = if rng.below(2) == 0 {
                None
            } else {
                Some((rng.below(80) as u64 + 5, rng.f64() * 3.0))
            };
            (views, sla)
        },
        |(views, sla)| {
            let wv: Vec<WorkerView> = views
                .iter()
                .map(|&(alive, depth, queued_time)| WorkerView {
                    alive,
                    depth,
                    queue_cap: 8,
                    queued_time,
                    routes: &routes,
                })
                .collect();
            let sla_v = sla.map(|(ms, min_s)| Sla {
                class: "p".into(),
                max_latency: Some(Duration::from_millis(ms)),
                min_speedup: Some(min_s),
            });
            match admit(sla_v.as_ref(), &wv) {
                Ok((w, m)) => {
                    let v = &wv[w];
                    // never a dead or full worker, always a real member
                    v.alive
                        && v.depth < v.queue_cap
                        && m < routes.len()
                        // and the member satisfies the SLA bounds
                        && sla_v.as_ref().is_none_or(|s| {
                            s.min_speedup
                                .is_none_or(|ms| routes[m].est_speedup + 1e-9 >= ms)
                                && s.max_latency.is_none_or(|ml| {
                                    v.queued_time + routes[m].est_batch_time
                                        <= ml.as_secs_f64()
                                })
                        })
                }
                Err(ShedReason::NoCapacity) => !wv.iter().any(|v| v.alive),
                Err(ShedReason::QueueFull) => {
                    wv.iter().any(|v| v.alive)
                        && wv.iter().all(|v| !v.alive || v.depth >= v.queue_cap)
                }
                Err(ShedReason::DeadlineUnmeetable) => {
                    wv.iter().any(|v| v.alive && v.depth < v.queue_cap)
                }
            }
        },
    );
}

#[test]
fn backoff_is_monotone_and_bounded() {
    Prop::new(100).check(
        "retry-backoff-monotone-bounded",
        |rng: &mut Rng| {
            (
                1 + rng.below(50) as u64, // base ms
                1.0 + rng.f64() * 3.0,    // factor
                1 + rng.below(30) as u32, // attempt
            )
        },
        |&(base_ms, factor, attempt)| {
            let r = RetryPolicy {
                max_retries: 5,
                base: Duration::from_millis(base_ms),
                factor,
            };
            let cur = r.backoff(attempt);
            let next = r.backoff(attempt + 1);
            next >= cur && next <= Duration::from_secs(1) && cur >= Duration::ZERO
        },
    );
}

// ------------------------------------------------------------------
// trace generator + reply-integrity cross-checks
// ------------------------------------------------------------------

#[test]
fn replies_are_genuine_member_outputs_under_faults() {
    // under compile failures and slowdowns, whatever DOES reply must
    // carry the claimed member's deterministic logits — re-dispatch
    // may change which member serves, never fabricate an answer
    let rates = FaultRates {
        crash: 0.15,
        compile_fail: 0.3,
        slowdown: 0.2,
        slowdown_factor: 2.0,
        nan_latency: 0.0,
    };
    let fleet = fleet::start(cfg(2), members(), &env(), FaultPlan::seeded(99, rates)).unwrap();
    let mut pending = Vec::new();
    for i in 0..60i32 {
        let ids = vec![i, i + 1, i + 2];
        pending.push((ids.clone(), fleet.submit(ids, None).unwrap()));
    }
    let mut replied = 0;
    for (ids, rx) in pending {
        match rx.recv_timeout(Duration::from_secs(30)).unwrap() {
            Outcome::Replied(r) => {
                replied += 1;
                assert_eq!(
                    r.logits,
                    sim_logits(&r.member, &ids, SIM_WIDTH),
                    "reply logits must be member `{}`'s genuine output",
                    r.member
                );
            }
            // Abandoned (retry exhaustion) and Shed (both workers may be
            // transiently down mid-restart → NoCapacity) are legitimate
            // terminal outcomes here; integrity only binds replies.
            Outcome::Abandoned { .. } | Outcome::Shed(_) => {}
        }
    }
    assert!(replied > 0);
    let stats = fleet.shutdown().unwrap();
    assert_eq!(stats.accounted(), stats.submitted);
}

#[test]
fn trace_replay_is_bit_identical_and_weighted() {
    let tcfg = TraceCfg {
        requests: 400,
        seed: 1234,
        arrival_gap: Duration::ZERO,
        len_range: (1, 8),
        classes: vec![
            TraceClass::best_effort(3.0),
            TraceClass {
                class: "rt".into(),
                weight: 1.0,
                max_latency: Some(Duration::from_millis(5)),
                min_speedup: None,
            },
        ],
    };
    let a = gen_trace(&tcfg);
    let b = gen_trace(&tcfg);
    assert_eq!(a.len(), b.len());
    let mut rt = 0usize;
    for (x, y) in a.iter().zip(&b) {
        assert_eq!(x.ids, y.ids);
        let (cx, cy) = (
            x.sla.as_ref().map(|s| s.class.as_str()),
            y.sla.as_ref().map(|s| s.class.as_str()),
        );
        assert_eq!(cx, cy);
        if cx == Some("rt") {
            rt += 1;
        }
    }
    // 1-in-4 weight → roughly a quarter of 400 (generous tolerance)
    assert!((40..=180).contains(&rt), "rt class drew {rt} of 400");
}
