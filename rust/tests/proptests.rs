//! Property tests on coordinator invariants (hand-rolled harness —
//! proptest is unavailable offline; see util::prop).

#![allow(clippy::disallowed_methods)] // test/bench/example code: unwrap-on-failure is fine

use std::path::Path;
use std::time::Duration;

use ziplm::adapt::{detect_drift, fit_env, DriftCfg, DriftReport};
use ziplm::compress::{
    Choice, ChoiceProblem, ChoiceSet, CompressionProfile, LayerChoice, ModuleChoice, QuantScheme,
};
use ziplm::coordinator::family::{
    route, route_batch, BatchReq, BucketLadder, BucketSample, MemberRoute, Sla,
};
use ziplm::env::InferenceEnv;
use ziplm::exp::repro::{
    matrix_keys, scenario_cells, AdaptBlock, BucketRow, CellStatus, ChaosSummary, CompoundBlock,
    CompoundMember, FamilyBlock, MemberSummary, ReproReport, ScenarioCell,
};
use ziplm::latency::LatencyTable;
use ziplm::models::family::{FamilyManifest, FamilyMember};
use ziplm::runtime::ArtifactKey;
use ziplm::session::store::{env_fingerprint, StageStore};
use ziplm::session::{solve_fingerprint, solve_key};
use ziplm::spdy::{self, LevelOpt, ModuleLevels, SpdyProblem};
use ziplm::util::json::Json;
use ziplm::tensor::{linalg, Tensor};
use ziplm::util::prop::{gen, Prop};
use ziplm::util::rng::Rng;
use ziplm::ziplm::{argmin, relative_error, NativeBackend, ObsOps};

fn random_problem(rng: &mut Rng) -> SpdyProblem {
    let n_layers = 1 + rng.below(4);
    let mut modules = Vec::new();
    for l in 0..n_layers {
        for is_attn in [true, false] {
            let n_levels = 2 + rng.below(5);
            let dense_cost = 1.0 + rng.f64() * 9.0;
            let mut options = Vec::new();
            for k in 0..n_levels {
                let frac = 1.0 - k as f64 / (n_levels - 1) as f64;
                options.push(LevelOpt {
                    remaining: (frac * 8.0) as usize,
                    cost: dense_cost * frac,
                    prior: 1.0 - frac,
                });
            }
            modules.push(ModuleLevels { layer: l, is_attn, options });
        }
    }
    SpdyProblem { modules, overhead: rng.f64() }
}

#[test]
fn prop_spdy_dp_always_respects_budget() {
    Prop::new(60).check_msg(
        "dp ≤ budget",
        |r| {
            let p = random_problem(r);
            let dense = p.dense_cost();
            let budget = p.overhead + (dense - p.overhead) * (0.2 + 0.8 * r.f64());
            (p, budget)
        },
        |(p, budget)| {
            let coeffs = vec![1.0; p.modules.len()];
            match spdy::solve_dp(p, &coeffs, *budget) {
                Some(prof) => {
                    let c = p.profile_cost(&prof);
                    if c <= *budget + 1e-9 {
                        Ok(())
                    } else {
                        Err(format!("cost {c} > budget {budget}"))
                    }
                }
                None => {
                    // must only fail when even the min config misses budget
                    if p.min_cost() > *budget {
                        Ok(())
                    } else {
                        Err("dp failed though feasible".into())
                    }
                }
            }
        },
    );
}

#[test]
fn prop_spdy_monotone_budget_monotone_error() {
    // more budget → no worse total prior error
    Prop::new(30).check_msg(
        "budget monotone",
        |r| random_problem(r),
        |p| {
            let coeffs = vec![1.0; p.modules.len()];
            let lo = p.min_cost() * 1.2 + p.overhead;
            let hi = p.dense_cost();
            let err = |budget: f64| -> Option<f64> {
                spdy::solve_dp(p, &coeffs, budget).map(|prof| {
                    prof.iter()
                        .zip(&p.modules)
                        .map(|(&l, m)| m.options[l].prior.powi(2))
                        .sum()
                })
            };
            match (err(lo), err(hi)) {
                (Some(e_lo), Some(e_hi)) => {
                    if e_hi <= e_lo + 1e-9 {
                        Ok(())
                    } else {
                        Err(format!("e_hi {e_hi} > e_lo {e_lo}"))
                    }
                }
                _ => Ok(()),
            }
        },
    );
}

/// Enumerate every level assignment of a (small) SPDY problem.
fn all_profiles(p: &SpdyProblem) -> Vec<Vec<usize>> {
    let mut out = vec![vec![]];
    for m in &p.modules {
        let mut next = Vec::with_capacity(out.len() * m.options.len());
        for prefix in &out {
            for li in 0..m.options.len() {
                let mut v = prefix.clone();
                v.push(li);
                next.push(v);
            }
        }
        out = next;
    }
    out
}

fn spdy_objective(p: &SpdyProblem, coeffs: &[f64], profile: &[usize]) -> f64 {
    profile
        .iter()
        .zip(&p.modules)
        .enumerate()
        .map(|(mi, (&li, m))| coeffs[mi] * m.options[li].prior * m.options[li].prior)
        .sum()
}

#[test]
fn prop_spdy_dp_matches_bruteforce_on_small_instances() {
    // Exhaustive cross-check of the knapsack DP (≤4 modules × ≤4
    // levels): the DP must (a) return a profile whose REAL cost meets
    // the budget, and (b) be loss-optimal among all profiles that are
    // feasible under the DP's own ceil-to-bucket weight rounding —
    // i.e. the DP+backtracking is exact in bucket space. Any profile
    // with real cost ≤ budget − nm·unit is bucket-feasible, so the DP
    // is also within one bucket per module of the unrounded optimum.
    const BUCKETS: f64 = 768.0;
    Prop::new(50).check_msg(
        "dp == bucket-space brute force",
        |r| {
            let nm = 1 + r.below(4);
            let mut modules = Vec::new();
            for l in 0..nm {
                let n_levels = 2 + r.below(3); // 2..=4
                let dense_cost = 0.5 + r.f64() * 9.5;
                let mut options = Vec::new();
                for k in 0..n_levels {
                    let frac = 1.0 - k as f64 / (n_levels - 1) as f64;
                    options.push(LevelOpt {
                        remaining: (frac * 8.0) as usize,
                        // not proportional on purpose: random per-level cost
                        cost: dense_cost * frac * (0.5 + r.f64()),
                        prior: (1.0 - frac) * (0.5 + r.f64()),
                    });
                }
                options[0].cost = dense_cost;
                options[0].prior = 0.0;
                modules.push(ModuleLevels { layer: l, is_attn: l % 2 == 0, options });
            }
            let p = SpdyProblem { modules, overhead: r.f64() };
            let budget = p.overhead + (p.dense_cost() - p.overhead) * (0.1 + 0.9 * r.f64());
            let coeffs: Vec<f64> = (0..nm).map(|_| 0.1 + 2.0 * r.f64()).collect();
            (p, coeffs, budget)
        },
        |(p, coeffs, budget)| {
            let unit = (budget - p.overhead) / BUCKETS;
            // brute force with the DP's own weight rounding
            let mut best: Option<(f64, Vec<usize>)> = None;
            for prof in all_profiles(p) {
                let w: f64 = prof
                    .iter()
                    .zip(&p.modules)
                    .map(|(&li, m)| (m.options[li].cost / unit).ceil())
                    .sum();
                if w > BUCKETS {
                    continue;
                }
                let obj = spdy_objective(p, coeffs, &prof);
                if best.as_ref().is_none_or(|(b, _)| obj < *b) {
                    best = Some((obj, prof));
                }
            }
            match (spdy::solve_dp(p, coeffs, *budget), best) {
                (None, None) => Ok(()),
                (None, Some((_, prof))) => {
                    Err(format!("dp returned None though {prof:?} is bucket-feasible"))
                }
                (Some(prof), None) => Err(format!("dp returned {prof:?} on infeasible instance")),
                (Some(prof), Some((best_obj, best_prof))) => {
                    let real = p.profile_cost(&prof);
                    if real > *budget + 1e-9 {
                        return Err(format!("dp profile {prof:?} cost {real} > budget {budget}"));
                    }
                    let obj = spdy_objective(p, coeffs, &prof);
                    let tol = 1e-9 * best_obj.abs().max(1.0);
                    if obj > best_obj + tol {
                        return Err(format!(
                            "dp {prof:?} obj {obj} vs brute {best_prof:?} obj {best_obj}"
                        ));
                    }
                    Ok(())
                }
            }
        },
    );
}

#[test]
fn prop_choice_dp_prune_only_bit_identical_to_legacy() {
    // Satellite 3 / tentpole acceptance: lifting a legacy prune-only
    // problem into the choice lattice and solving the widened DP must
    // reproduce the legacy DP bit-identically — same Option, same
    // indices, and the lowered numbers the DP reads are the SAME f64s.
    Prop::new(60).check_msg(
        "prune-only lattice ≡ legacy DP",
        |r| {
            let p = random_problem(r);
            let dense = p.dense_cost();
            let budget = p.overhead + (dense - p.overhead) * (0.1 + 0.9 * r.f64());
            let coeffs: Vec<f64> = (0..p.modules.len()).map(|_| 0.1 + 2.0 * r.f64()).collect();
            (p, coeffs, budget)
        },
        |(p, coeffs, budget)| {
            let lifted = ChoiceProblem::from_spdy(p);
            let lowered = lifted.lower();
            for (a, b) in p.modules.iter().zip(&lowered.modules) {
                for (oa, ob) in a.options.iter().zip(&b.options) {
                    if oa.cost.to_bits() != ob.cost.to_bits()
                        || oa.prior.to_bits() != ob.prior.to_bits()
                        || oa.remaining != ob.remaining
                    {
                        return Err("lift/lower mutated a LevelOpt".into());
                    }
                }
            }
            if lifted.solve_dp(coeffs, *budget) != spdy::solve_dp(p, coeffs, *budget) {
                return Err("widened DP diverged from legacy DP on prune-only input".into());
            }
            let typed = lifted.profile_choices(&vec![0; p.modules.len()]);
            if !typed.is_prune_only() {
                return Err("lifted profile must report prune-only".into());
            }
            Ok(())
        },
    );
}

/// A random mixed-axis lattice: a prune ladder per module plus quant
/// (dense-shape, cheaper, small loss) and — on FFN modules — low-rank
/// entries, with costs/losses deliberately NOT proportional so the DP
/// has real trade-offs to rank.
fn random_choice_problem(r: &mut Rng) -> ChoiceProblem {
    let nm = 1 + r.below(4);
    let mut modules = Vec::new();
    for l in 0..nm {
        let is_attn = l % 2 == 0;
        let n_levels = 2 + r.below(2); // 2..=3 prune levels
        let dense_cost = 0.5 + r.f64() * 9.5;
        let mut choices = Vec::new();
        for k in 0..n_levels {
            let frac = 1.0 - k as f64 / (n_levels - 1) as f64;
            choices.push(Choice {
                choice: LayerChoice::Prune { remaining: (frac * 8.0) as usize },
                cost: if k == 0 { dense_cost } else { dense_cost * frac * (0.5 + r.f64()) },
                loss: if k == 0 { 0.0 } else { (1.0 - frac) * (0.5 + r.f64()) },
            });
        }
        choices.push(Choice {
            choice: LayerChoice::Quant { scheme: QuantScheme::Int8 },
            cost: dense_cost * (0.3 + 0.2 * r.f64()),
            loss: 0.05 + 0.2 * r.f64(),
        });
        if r.below(2) == 0 {
            choices.push(Choice {
                choice: LayerChoice::PruneQuant {
                    remaining: 4,
                    scheme: QuantScheme::Int8,
                },
                cost: dense_cost * (0.15 + 0.15 * r.f64()),
                loss: 0.3 + 0.5 * r.f64(),
            });
        }
        if !is_attn {
            choices.push(Choice {
                choice: LayerChoice::LowRank { rank: 1 + r.below(8) },
                cost: dense_cost * (0.2 + 0.5 * r.f64()),
                loss: 0.1 + 0.6 * r.f64(),
            });
        }
        modules.push(ChoiceSet { layer: l, is_attn, choices });
    }
    ChoiceProblem { modules, overhead: r.f64() }
}

#[test]
fn prop_choice_dp_matches_bruteforce_on_mixed_instances() {
    // Satellite 3: the widened DP must stay bucket-space exact on
    // mixed prune × quant × low-rank instances (≤4 modules × ≤5
    // choices), exactly like the legacy prop above — the lattice adds
    // axes, not approximation. Also: the typed view of the solution
    // must agree with the raw indices module-by-module.
    const BUCKETS: f64 = 768.0;
    Prop::new(50).check_msg(
        "mixed-lattice dp == bucket-space brute force",
        |r| {
            let p = random_choice_problem(r);
            let budget = p.overhead + (p.dense_cost() - p.overhead) * (0.1 + 0.9 * r.f64());
            let coeffs: Vec<f64> = (0..p.modules.len()).map(|_| 0.1 + 2.0 * r.f64()).collect();
            (p, coeffs, budget)
        },
        |(p, coeffs, budget)| {
            let lowered = p.lower();
            let unit = (budget - p.overhead) / BUCKETS;
            let mut best: Option<(f64, Vec<usize>)> = None;
            for prof in all_profiles(&lowered) {
                let w: f64 = prof
                    .iter()
                    .zip(&lowered.modules)
                    .map(|(&ci, m)| (m.options[ci].cost / unit).ceil())
                    .sum();
                if w > BUCKETS {
                    continue;
                }
                let obj = spdy_objective(&lowered, coeffs, &prof);
                if best.as_ref().is_none_or(|(b, _)| obj < *b) {
                    best = Some((obj, prof));
                }
            }
            match (p.solve_dp(coeffs, *budget), best) {
                (None, None) => Ok(()),
                (None, Some((_, prof))) => {
                    Err(format!("dp returned None though {prof:?} is bucket-feasible"))
                }
                (Some(prof), None) => Err(format!("dp returned {prof:?} on infeasible instance")),
                (Some(prof), Some((best_obj, best_prof))) => {
                    let real = p.profile_cost(&prof);
                    if real > *budget + 1e-9 {
                        return Err(format!("dp profile {prof:?} cost {real} > budget {budget}"));
                    }
                    let obj = spdy_objective(&lowered, coeffs, &prof);
                    let tol = 1e-9 * best_obj.abs().max(1.0);
                    if obj > best_obj + tol {
                        return Err(format!(
                            "dp {prof:?} obj {obj} vs brute {best_prof:?} obj {best_obj}"
                        ));
                    }
                    let typed = p.profile_choices(&prof);
                    for ((mc, set), &ci) in typed.modules.iter().zip(&p.modules).zip(&prof) {
                        if mc.choice != set.choices[ci].choice {
                            return Err("typed view disagrees with raw choice index".into());
                        }
                    }
                    Ok(())
                }
            }
        },
    );
}

#[test]
fn spdy_dp_exact_on_handpicked_instance() {
    // Costs far larger than one bucket, budget strictly between the
    // interesting combinations: rounding cannot matter, so the DP must
    // hit the true (unrounded) optimum found by brute force.
    let mk = |costs: [f64; 3], priors: [f64; 3]| ModuleLevels {
        layer: 0,
        is_attn: false,
        options: (0..3)
            .map(|i| LevelOpt { remaining: 2 - i, cost: costs[i], prior: priors[i] })
            .collect(),
    };
    let p = SpdyProblem {
        modules: vec![
            mk([10.0, 6.0, 0.0], [0.0, 0.2, 1.0]),
            mk([10.0, 5.0, 0.0], [0.0, 0.6, 1.0]),
            mk([8.0, 4.0, 0.0], [0.0, 0.3, 1.0]),
        ],
        overhead: 2.0,
    };
    let coeffs = vec![1.0, 1.0, 1.0];
    for budget in [30.1, 26.1, 22.1, 18.1, 14.1, 10.1] {
        let dp = spdy::solve_dp(&p, &coeffs, budget).expect("feasible");
        assert!(p.profile_cost(&dp) <= budget + 1e-9);
        let (mut best_obj, mut best_prof) = (f64::INFINITY, vec![]);
        for prof in all_profiles(&p) {
            if p.profile_cost(&prof) <= budget {
                let obj = spdy_objective(&p, &coeffs, &prof);
                if obj < best_obj {
                    best_obj = obj;
                    best_prof = prof;
                }
            }
        }
        let obj = spdy_objective(&p, &coeffs, &dp);
        assert!(
            (obj - best_obj).abs() <= 1e-9,
            "budget {budget}: dp {dp:?} obj {obj} vs brute {best_prof:?} obj {best_obj}"
        );
    }
}

#[test]
fn prop_obs_update_exactness_on_redundant_column() {
    // If column j is an exact linear combination of the others in the
    // data, removing it with the OBS update preserves outputs ~exactly.
    Prop::new(20).check_msg(
        "obs exact on redundancy",
        |r| {
            let n = 4 + r.below(6);
            let d_row = 3 + r.below(5);
            let nsamp = 20 * n;
            let mut x = vec![0f32; n * nsamp];
            for v in x.iter_mut() {
                *v = r.normal_f32(1.0);
            }
            // make row `dep` of X a combination of two others
            let dep = r.below(n);
            let (a, b) = ((dep + 1) % n, (dep + 2) % n);
            let (ca, cb) = (r.normal_f32(0.7), r.normal_f32(0.7));
            for s in 0..nsamp {
                x[dep * nsamp + s] = ca * x[a * nsamp + s] + cb * x[b * nsamp + s];
            }
            let w = gen::vec_f32(r, d_row * n, 1.0);
            (n, d_row, nsamp, x, w, dep)
        },
        |(n, d_row, nsamp, x, w, dep)| {
            let xt = Tensor::from_vec(&[*n, *nsamp], x.clone());
            let mut h = xt.matmul(&xt.transpose2());
            h.scale(2.0);
            h.add_diag(1e-4 * *n as f32);
            let hinv = linalg::spd_inverse(&h).map_err(|e| e)?;
            let w = Tensor::from_vec(&[*d_row, *n], w.clone());
            let mut ops = NativeBackend::new(1);
            let scores = ops.scores(&w, &hinv, &vec![1.0; *n]).map_err(|e| e.to_string())?;
            // the redundant column must be near-free to remove: tiny
            // relative to the typical column (another column may tie by
            // chance when its weights are tiny, so exact-argmin is too
            // strong a property)
            let max = scores.iter().cloned().fold(0f32, f32::max);
            if scores[*dep] > 0.05 * max {
                return Err(format!("redundant col {dep} not cheap: {scores:?}"));
            }
            let _ = argmin(&scores);
            let (w2, _) = ops.update(&w, &hinv, *dep).map_err(|e| e.to_string())?;
            let rel = relative_error(&w, &w2, &h);
            if rel < 0.05 {
                Ok(())
            } else {
                Err(format!("rel err {rel}"))
            }
        },
    );
}

#[test]
fn prop_obs_scores_nonnegative_and_masked_big() {
    Prop::new(30).check_msg(
        "scores ≥ 0, masked = BIG",
        |r| {
            let n = 3 + r.below(8);
            let d_row = 2 + r.below(6);
            let w = gen::vec_f32(r, d_row * n, 1.0);
            let h = gen::spd(r, n, 0.4);
            let dead = r.below(n);
            (n, d_row, w, h, dead)
        },
        |(n, d_row, w, h, dead)| {
            let h = Tensor::from_vec(&[*n, *n], h.clone());
            let hinv = linalg::spd_inverse(&h).map_err(|e| e)?;
            let w = Tensor::from_vec(&[*d_row, *n], w.clone());
            let mut act = vec![1.0f32; *n];
            act[*dead] = 0.0;
            let mut ops = NativeBackend::new(1);
            let s = ops.scores(&w, &hinv, &act).map_err(|e| e.to_string())?;
            if s[*dead] < 1e29 {
                return Err("dead structure not BIG".into());
            }
            for (i, &v) in s.iter().enumerate() {
                if i != *dead && v < -1e-3 {
                    return Err(format!("negative score {v} at {i}"));
                }
            }
            Ok(())
        },
    );
}

/// Random structured-OBS problem: W [d_row, n·g], SPD Hessian inverse,
/// and a random (non-empty) active mask over the n structures.
fn random_obs_problem(r: &mut Rng, g: usize) -> (Tensor, Tensor, Vec<f32>) {
    let n = 3 + r.below(6);
    let d_row = 2 + r.below(8);
    let d_col = n * g;
    let w = Tensor::from_vec(&[d_row, d_col], gen::vec_f32(r, d_row * d_col, 1.0));
    let h = Tensor::from_vec(&[d_col, d_col], gen::spd(r, d_col, 0.4));
    let hinv = linalg::spd_inverse(&h).unwrap();
    let mut active = vec![1.0f32; n];
    for j in 0..n {
        if r.f64() < 0.2 {
            active[j] = 0.0;
        }
    }
    if !active.iter().any(|&a| a > 0.0) {
        active[r.below(n)] = 1.0;
    }
    (w, hinv, active)
}

fn rel_close(a: f32, b: f32, tol: f32) -> bool {
    (a - b).abs() <= tol * a.abs().max(b.abs()).max(1.0)
}

#[test]
fn prop_fast_scores_match_reference_g1_and_g8() {
    // Tentpole equivalence: the closed-form (g=1) and batched-block
    // (g>1) score paths must agree with the retained reference
    // implementation within 1e-4 on random SPD problems.
    for &g in &[1usize, 8] {
        Prop::new(40).check_msg(
            "fast scores == reference scores",
            |r| random_obs_problem(r, g),
            |(w, hinv, active)| {
                let mut ops = NativeBackend::new(g);
                let fast = ops.scores(w, hinv, active).map_err(|e| e.to_string())?;
                let slow = ops.scores_ref(w, hinv, active).map_err(|e| e.to_string())?;
                for (j, (&f, &s)) in fast.iter().zip(&slow).enumerate() {
                    if active[j] <= 0.0 {
                        if f < 1e29 || s < 1e29 {
                            return Err(format!("g={g} j={j}: inactive not BIG ({f} vs {s})"));
                        }
                    } else if !rel_close(f, s, 1e-4) {
                        return Err(format!("g={g} j={j}: fast {f} vs ref {s}"));
                    }
                }
                Ok(())
            },
        );
    }
}

#[test]
fn prop_parallel_g8_score_sweep_matches_reference_wide() {
    // The g>1 score sweep fans the per-structure quadratic forms out
    // across the thread pool in disjoint chunks of the output, gated
    // on per-chunk work (~64k flops). These instances are sized so
    // the gate opens (d_row·g² ≥ 6k flops/structure, 16..24
    // structures → chunking engages on multi-core runners); on a
    // 1-core box the sweep degenerates to the inline loop — both must
    // match the reference path exactly.
    let g = 8;
    Prop::new(10).check_msg(
        "threaded g>1 scores == reference scores",
        |r| {
            let n = 16 + r.below(9); // 16..=24 structures
            let d_row = 96 + r.below(33); // ≥ 96 rows: above the work gate
            let d_col = n * g;
            let w = Tensor::from_vec(&[d_row, d_col], gen::vec_f32(r, d_row * d_col, 1.0));
            let h = Tensor::from_vec(&[d_col, d_col], gen::spd(r, d_col, 0.4));
            let hinv = linalg::spd_inverse(&h).unwrap();
            let mut active = vec![1.0f32; n];
            for j in 0..n {
                if r.f64() < 0.25 {
                    active[j] = 0.0;
                }
            }
            active[r.below(n)] = 1.0;
            (w, hinv, active)
        },
        |(w, hinv, active)| {
            let mut ops = NativeBackend::new(g);
            let fast = ops.scores(w, hinv, active).map_err(|e| e.to_string())?;
            let slow = ops.scores_ref(w, hinv, active).map_err(|e| e.to_string())?;
            for (j, (&f, &s)) in fast.iter().zip(&slow).enumerate() {
                if active[j] <= 0.0 {
                    if f < 1e29 || s < 1e29 {
                        return Err(format!("j={j}: inactive not BIG ({f} vs {s})"));
                    }
                } else if !rel_close(f, s, 1e-4) {
                    return Err(format!("j={j}: fast {f} vs ref {s}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_inplace_update_matches_clone_based_g1_and_g8() {
    // In-place rank-g downdate == clone+gather+matmul reference.
    for &g in &[1usize, 8] {
        Prop::new(30).check_msg(
            "in-place update == clone-based update",
            |r| {
                let (w, hinv, active) = random_obs_problem(r, g);
                let n = active.len();
                let alive: Vec<usize> =
                    (0..n).filter(|&j| active[j] > 0.0).collect();
                let idx = alive[r.below(alive.len())];
                (w, hinv, idx)
            },
            |(w, hinv, idx)| {
                let mut ops = NativeBackend::new(g);
                let (wf, hf) = ops.update(w, hinv, *idx).map_err(|e| e.to_string())?;
                let (wr, hr) = ops.update_ref(w, hinv, *idx).map_err(|e| e.to_string())?;
                let dw = wf.max_abs_diff(&wr);
                let dh = hf.max_abs_diff(&hr);
                if dw > 1e-4 || dh > 1e-4 {
                    return Err(format!("g={g} idx={idx}: dW {dw} dH {dh}"));
                }
                Ok(())
            },
        );
    }
}

#[test]
fn prop_inplace_multi_update_matches_reference() {
    // Fused in-place multi-step removal == reference clone-based loop:
    // same removal order, same W'/Hinv' within 1e-4, same active mask.
    Prop::new(25).check_msg(
        "in-place multi_update == reference multi_update",
        |r| {
            let (w, hinv, active) = random_obs_problem(r, 1);
            let alive = active.iter().filter(|&&a| a > 0.0).count();
            let n_remove = 1 + r.below(alive);
            (w, hinv, active, n_remove)
        },
        |(w, hinv, active, n_remove)| {
            let mut ops = NativeBackend::new(1);
            let (wf, hf, af, of) =
                ops.multi_update(w, hinv, active, *n_remove).map_err(|e| e.to_string())?;
            let (wr, hr, ar, or) =
                ops.multi_update_ref(w, hinv, active, *n_remove).map_err(|e| e.to_string())?;
            // The two paths round scores slightly differently, so an
            // f32-ulp near-tie may legitimately flip a removal choice;
            // the outputs decide. A materially different order produces
            // materially different W'/Hinv' and fails the checks below.
            if of != or {
                let mut sf = of.clone();
                let mut sr = or.clone();
                sf.sort_unstable();
                sr.sort_unstable();
                if sf != sr {
                    return Err(format!("removed sets differ: {of:?} vs {or:?}"));
                }
            }
            if af != ar {
                return Err("active mask mismatch".into());
            }
            let dw = wf.max_abs_diff(&wr);
            let dh = hf.max_abs_diff(&hr);
            if dw > 1e-4 || dh > 1e-4 {
                return Err(format!("dW {dw} dH {dh}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_multi_update_incremental_colsq_deep_removals() {
    // PR-4 satellite: multi_update now maintains column sums of
    // squares incrementally across removal steps instead of
    // rescanning W. Deep removal chains (leave only 1..3 columns)
    // over wider instances maximize accumulated drift; the fast path
    // must still match the reference clone-based loop. Pre-validated
    // by a numpy transliteration over these EXACT seeds
    // (DEFAULT_SEED + case): 0 order differences, bit-equal outputs.
    Prop::new(12).check_msg(
        "incremental-colsq multi_update == reference, deep removals",
        |r| {
            let n = 12 + r.below(13); // 12..=24 columns
            let d_row = 4 + r.below(13); // 4..=16 rows
            let w = Tensor::from_vec(&[d_row, n], gen::vec_f32(r, d_row * n, 1.0));
            let h = Tensor::from_vec(&[n, n], gen::spd(r, n, 0.4));
            let hinv = linalg::spd_inverse(&h).unwrap();
            let n_remove = n - 1 - r.below(3); // deep: 1..=3 survivors
            (w, hinv, n, n_remove)
        },
        |(w, hinv, n, n_remove)| {
            let active = vec![1.0f32; *n];
            let mut ops = NativeBackend::new(1);
            let (wf, hf, af, of) =
                ops.multi_update(w, hinv, &active, *n_remove).map_err(|e| e.to_string())?;
            let (wr, hr, ar, or) =
                ops.multi_update_ref(w, hinv, &active, *n_remove).map_err(|e| e.to_string())?;
            if of != or {
                let mut sf = of.clone();
                let mut sr = or.clone();
                sf.sort_unstable();
                sr.sort_unstable();
                if sf != sr {
                    return Err(format!("removed sets differ: {of:?} vs {or:?}"));
                }
            }
            if af != ar {
                return Err("active mask mismatch".into());
            }
            let dw = wf.max_abs_diff(&wr);
            let dh = hf.max_abs_diff(&hr);
            if dw > 1e-4 || dh > 1e-4 {
                return Err(format!("dW {dw} dH {dh}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_fast_spd_inverse_matches_reference() {
    // small instances run the inline path; the occasional 120..168 one
    // crosses the threaded column sweep's chunking gate on multi-core
    // runners (PR-1 follow-up) — both must match the reference loop
    Prop::new(25).check_msg(
        "spd_inverse fast == ref",
        |r| {
            let n = if r.f64() < 0.15 { 120 + r.below(48) } else { 2 + r.below(30) };
            Tensor::from_vec(&[n, n], gen::spd(r, n, 0.5))
        },
        |a| {
            let f = linalg::spd_inverse(a)?;
            let g = linalg::spd_inverse_ref(a)?;
            let d = f.max_abs_diff(&g);
            let tol = 1e-3 * (1.0 + a.rows() as f32 / 32.0);
            if d > tol {
                return Err(format!("diff {d} (tol {tol})"));
            }
            Ok(())
        },
    );
}

// ------------------------------------------------- JSON round-trips

/// Strings with every escape class the writer handles.
fn tricky_string(r: &mut Rng) -> String {
    let pool = [
        "bert-syn-base",
        "m/with\\slash",
        "quote\"inside",
        "tab\ttab",
        "newline\nend",
        "unicode-\u{e9}\u{4e2d}",
        "",
    ];
    pool[r.below(pool.len())].to_string()
}

fn random_latency_table(r: &mut Rng) -> LatencyTable {
    let heads = 1 + r.below(12);
    let per_head = 1e-5 + r.f64() * 1e-3;
    let attn: Vec<f64> = (0..=heads).map(|h| h as f64 * per_head).collect();
    let n_widths = 1 + r.below(6);
    let mut widths: Vec<usize> = (0..n_widths).map(|_| 1 + r.below(4096)).collect();
    widths.sort_unstable();
    widths.dedup();
    widths.reverse();
    let mut mlp: Vec<(usize, f64)> =
        widths.into_iter().map(|w| (w, w as f64 * (1e-8 + r.f64() * 1e-5))).collect();
    mlp.push((0, 0.0));
    LatencyTable {
        model: tricky_string(r),
        device: tricky_string(r),
        regime: if r.f64() < 0.5 { "throughput".into() } else { "latency".into() },
        attn,
        mlp,
        overhead: r.f64() * 1e-3,
    }
}

#[test]
fn prop_latency_table_json_roundtrip_identity() {
    // to_json/from_json identity on randomized instances, both via the
    // in-memory Json value and through the text writer+parser (the
    // on-disk path). f64 Display is shortest-roundtrip, so exact
    // equality must hold.
    Prop::new(60).check_msg(
        "LatencyTable to_json/from_json identity",
        |r| random_latency_table(r),
        |t| {
            let j = t.to_json();
            for (tag, t2) in [
                ("value", LatencyTable::from_json(&j).map_err(|e| e.to_string())?),
                (
                    "text",
                    LatencyTable::from_json(
                        &Json::parse(&j.to_pretty()).map_err(|e| format!("parse: {e}"))?,
                    )
                    .map_err(|e| e.to_string())?,
                ),
            ] {
                if t2.model != t.model
                    || t2.device != t.device
                    || t2.regime != t.regime
                    || t2.attn != t.attn
                    || t2.mlp != t.mlp
                    || t2.overhead != t.overhead
                {
                    return Err(format!("{tag} roundtrip mismatch"));
                }
            }
            Ok(())
        },
    );
}

fn random_env(r: &mut Rng) -> InferenceEnv {
    let mut t = random_latency_table(r);
    // InferenceEnv requires a parseable regime and non-empty blocks;
    // random_latency_table guarantees both, but its model/device are
    // tricky strings — exactly what the env JSON embedding must carry.
    t.regime = if r.f64() < 0.5 { "throughput".into() } else { "latency".into() };
    let mut env = InferenceEnv::measured(t).unwrap();
    if r.f64() < 0.5 {
        env = env.with_batch_shape(1 + r.below(256), 1 + r.below(4096));
    }
    // half the envs carry a seq-length sweep (shape-specialized
    // serving); with_seq_sweep normalizes, so the JSON round-trip must
    // preserve the normalized rows exactly
    if r.f64() < 0.5 {
        let sweep: Vec<(usize, f64)> =
            (0..1 + r.below(5)).map(|_| (1 + r.below(4096), 0.05 + r.f64() * 4.0)).collect();
        env = env.with_seq_sweep(sweep);
    }
    env
}

fn random_manifest(r: &mut Rng) -> FamilyManifest {
    let mut fam = FamilyManifest::new(
        &tricky_string(r),
        &tricky_string(r),
        if r.f64() < 0.5 { "throughput" } else { "latency" },
    );
    // half the manifests embed their certification env (the multi-env
    // sessions PR); absent env must round-trip as None
    if r.f64() < 0.5 {
        fam.env = Some(random_env(r));
    }
    // half record a serving bucket ladder; absent → empty (pre-§9 files)
    if r.f64() < 0.5 {
        fam.buckets =
            (0..1 + r.below(4)).map(|_| (1 + r.below(64), 1 + r.below(512))).collect();
    }
    for i in 0..r.below(6) {
        let n_layers = 1 + r.below(4);
        let profile: Vec<(usize, usize)> =
            (0..n_layers).map(|_| (r.below(16), r.below(3072))).collect();
        let est = 1.0 + r.f64() * 9.0;
        // a third of the members record manifest-v2 typed choices
        // (mixed-axis); the rest stay v1 (choices absent → None)
        let choices = if r.below(3) == 0 { Some(random_choices(r, &profile)) } else { None };
        fam.push(FamilyMember {
            tag: format!("member-{i}-{}", tricky_string(r)),
            ckpt: format!("{i}.zlm"),
            target: 1.0 + r.f64() * 9.0,
            est_speedup: est,
            profile,
            choices,
            calib_loss: if r.below(2) == 0 { Some(r.f64()) } else { None },
        });
    }
    fam
}

/// A random mixed-axis typed profile consistent with a layer anatomy:
/// prune modules record their remaining units; quant/low-rank modules
/// keep the dense shape.
fn random_choices(r: &mut Rng, profile: &[(usize, usize)]) -> CompressionProfile {
    let mut modules = Vec::new();
    for (layer, &(heads, cols)) in profile.iter().enumerate() {
        for is_attn in [true, false] {
            let remaining = if is_attn { heads } else { cols };
            let choice = match r.below(if is_attn { 3 } else { 4 }) {
                0 => LayerChoice::Prune { remaining },
                1 => LayerChoice::Quant { scheme: QuantScheme::Int8 },
                2 => LayerChoice::PruneQuant { remaining, scheme: QuantScheme::Int8 },
                _ => LayerChoice::LowRank { rank: 1 + r.below(256) },
            };
            modules.push(ModuleChoice { layer, is_attn, choice });
        }
    }
    CompressionProfile { modules }
}

#[test]
fn prop_family_manifest_json_roundtrip_identity() {
    // `push` keeps members est_speedup-sorted and `from_json` re-sorts
    // defensively, so a manifest built through the public API must
    // round-trip to an equal value (PartialEq covers member order).
    Prop::new(60).check_msg(
        "FamilyManifest to_json/from_json identity",
        |r| random_manifest(r),
        |f| {
            let j = f.to_json();
            let back = FamilyManifest::from_json(&j).map_err(|e| e.to_string())?;
            if &back != f {
                return Err("value roundtrip mismatch".into());
            }
            let text = FamilyManifest::from_json(
                &Json::parse(&j.to_pretty()).map_err(|e| format!("parse: {e}"))?,
            )
            .map_err(|e| e.to_string())?;
            if &text != f {
                return Err("text roundtrip mismatch".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_per_env_solve_keys_isolate_envs_and_resume() {
    // Engine-free half of the retarget acceptance: the per-env solve
    // checkpoint scheme (env_fingerprint folded into solve_key AND
    // solve_fingerprint, driven through the real StageStore + profile
    // codecs) must (a) resume an env's own solve, (b) never hand one
    // env's certification to another, (c) let both coexist in one
    // directory — the exact mechanics CompressionSession::retarget
    // and emit_families rely on.
    Prop::new(30).check_msg(
        "per-env solve artifacts: same env resumes, other env recomputes",
        |r| {
            let e1 = random_env(r);
            let mut e2 = random_env(r);
            if e1 == e2 {
                e2 = e2.with_batch_shape(7, 9999);
            }
            let stage_fp = format!("{:016x}", r.next_u64());
            let target = 1.0 + r.f64() * 9.0;
            let profile: Vec<usize> = (0..1 + r.below(8)).map(|_| r.below(5)).collect();
            (e1, e2, stage_fp, target, profile)
        },
        |(e1, e2, stage_fp, target, profile)| {
            use ziplm::session::store::{load_profile, save_profile};
            let (f1, f2) = (env_fingerprint(e1), env_fingerprint(e2));
            if env_fingerprint(e1) != f1 {
                return Err("env fingerprint unstable".into());
            }
            if f1 == f2 {
                return Err("distinct envs share a fingerprint".into());
            }
            if solve_key(0, &f1, *target) == solve_key(0, &f2, *target) {
                return Err("distinct envs share a solve key".into());
            }
            let (sf1, sf2) = (solve_fingerprint(stage_fp, &f1), solve_fingerprint(stage_fp, &f2));
            let dir = std::env::temp_dir().join(format!("ziplm_prop_env_{stage_fp}"));
            let _ = std::fs::remove_dir_all(&dir);
            // env1 solves and checkpoints
            let store = StageStore::new(Some(dir.clone()));
            let (p1, loaded1) = store
                .load_or_compute(
                    &solve_key(0, &f1, *target),
                    |p| load_profile(p, &sf1, *target),
                    |p, v: &(Vec<usize>, f64)| save_profile(p, &sf1, *target, &v.0, v.1),
                    || Ok((profile.clone(), 0.5)),
                )
                .map_err(|e| e.to_string())?;
            if loaded1 || &p1.0 != profile {
                return Err("first solve did not compute".into());
            }
            // a re-opened store resumes env1's solve without computing
            let store2 = StageStore::new(Some(dir.clone()));
            let (p2, loaded2) = store2
                .load_or_compute(
                    &solve_key(0, &f1, *target),
                    |p| load_profile(p, &sf1, *target),
                    |p, v: &(Vec<usize>, f64)| save_profile(p, &sf1, *target, &v.0, v.1),
                    || Ok((vec![usize::MAX], f64::NAN)),
                )
                .map_err(|e| e.to_string())?;
            if !loaded2 || &p2.0 != profile {
                return Err("env1 resume failed to load its own solve".into());
            }
            // env2 over the same directory must compute afresh
            let other: Vec<usize> = profile.iter().map(|&x| x + 1).collect();
            let (p3, loaded3) = store2
                .load_or_compute(
                    &solve_key(0, &f2, *target),
                    |p| load_profile(p, &sf2, *target),
                    |p, v: &(Vec<usize>, f64)| save_profile(p, &sf2, *target, &v.0, v.1),
                    || Ok((other.clone(), 1.5)),
                )
                .map_err(|e| e.to_string())?;
            if loaded3 || p3.0 != other {
                return Err("env2 cross-loaded env1's certification".into());
            }
            if store2.counters() != (1, 1) {
                return Err(format!("counters {:?} != (1, 1)", store2.counters()));
            }
            // even at the same path, the fingerprint alone gates
            let env1_path = dir.join(solve_key(0, &f1, *target));
            if load_profile(&env1_path, &sf2, *target).is_some() {
                return Err("env2 fingerprint accepted env1's artifact".into());
            }
            if load_profile(&env1_path, &sf1, *target).is_none() {
                return Err("env1 fingerprint rejected its own artifact".into());
            }
            let _ = std::fs::remove_dir_all(&dir);
            Ok(())
        },
    );
}

#[test]
fn prop_latency_table_speedup_bounds() {
    // 1 ≤ speedup(profile) ≤ dense/overhead for any profile
    Prop::new(40).check_msg(
        "speedup bounds",
        |r| {
            let heads = 2 + r.below(6);
            let f = 8 + r.below(500);
            let per_head = 1e-4 + r.f64() * 1e-3; // one rate: tables are monotone
            let attn: Vec<f64> = (0..=heads).map(|h| h as f64 * per_head).collect();
            let t_dense = 1e-3 + r.f64() * 1e-2;
            let mlp = vec![(f, t_dense), (f / 2, t_dense * (0.3 + 0.5 * r.f64())), (0, 0.0)];
            let n_layers = 1 + r.below(6);
            let profile: Vec<(usize, usize)> =
                (0..n_layers).map(|_| (r.below(heads + 1), r.below(f + 1))).collect();
            (
                LatencyTable {
                    model: "p".into(),
                    device: "t".into(),
                    regime: "throughput".into(),
                    attn,
                    mlp,
                    overhead: 1e-4 + r.f64() * 1e-3,
                },
                profile,
            )
        },
        |(t, profile)| {
            // sanitize: mlp interpolation needs sorted desc — it is.
            let s = t.speedup(profile);
            let cap = t.dense_time(profile.len()) / t.overhead;
            if s >= 1.0 - 1e-6 && s <= cap + 1e-6 {
                Ok(())
            } else {
                Err(format!("speedup {s} outside [1, {cap}]"))
            }
        },
    );
}

#[test]
fn prop_checkpoint_roundtrip_random_masks() {
    use ziplm::models::{Masks, ModelState};
    Prop::new(15).check_msg(
        "ckpt roundtrip",
        |r| {
            let n_layers = 1 + r.below(3);
            let n_heads = 1 + r.below(4);
            let d_ff = 4 + r.below(16);
            let head: Vec<f32> = (0..n_layers * n_heads).map(|_| if r.f64() < 0.3 { 0.0 } else { 1.0 }).collect();
            let ffn: Vec<f32> = (0..n_layers * d_ff).map(|_| if r.f64() < 0.3 { 0.0 } else { 1.0 }).collect();
            let n_params = 64 + r.below(512);
            let params = gen::vec_f32(r, n_params, 1.0);
            (n_layers, n_heads, d_ff, head, ffn, params)
        },
        |(n_layers, n_heads, d_ff, head, ffn, params)| {
            let st = ModelState {
                model: "m".into(),
                task: "t".into(),
                params: params.clone(),
                masks: Masks {
                    n_layers: *n_layers,
                    n_heads: *n_heads,
                    d_ff: *d_ff,
                    head: head.clone(),
                    ffn: ffn.clone(),
                },
            };
            let dir = std::env::temp_dir().join(format!("ziplm_prop_{}", params.len()));
            let path = dir.join("x.zlm");
            st.save(&path).map_err(|e| e.to_string())?;
            let st2 = ModelState::load(&path).map_err(|e| e.to_string())?;
            let _ = std::fs::remove_dir_all(dir);
            if st2.params == st.params && st2.masks == st.masks {
                Ok(())
            } else {
                Err("mismatch".into())
            }
        },
    );
}

// ---------------------------------------------------------------------
// Shape-specialized serving (DESIGN.md §9): cache-key injectivity and
// the route_batch coalescing policy.
// ---------------------------------------------------------------------

/// Artifact names that try to collide with the `@b<batch>s<seq>` shape
/// suffix of `ArtifactKey::encode` — including names that already end
/// in a fake suffix.
fn tricky_artifact(r: &mut Rng) -> String {
    let pool = ["fwd", "m__t__fwd", "spec_m_t_2x", "a@b1s2", "x@b", "s1@b0s0", "@", ""];
    let mut s = pool[r.below(pool.len())].to_string();
    if r.f64() < 0.5 {
        s.push_str(&format!("@b{}s{}", r.below(40), r.below(40)));
    }
    s
}

#[test]
fn prop_artifact_key_encoding_injective() {
    // Distinct (artifact, batch, seq) triples must encode to distinct
    // cache keys even when the artifact id itself contains `@b…s…`
    // fragments — a collision would silently hand one (member, bucket)
    // pair another pair's compiled executable.
    Prop::new(400).check_msg(
        "ArtifactKey::encode injective",
        |r| {
            let k1 = ArtifactKey::new(tricky_artifact(r), r.below(40), r.below(40));
            let k2 = if r.f64() < 0.2 {
                k1.clone()
            } else {
                ArtifactKey::new(tricky_artifact(r), r.below(40), r.below(40))
            };
            (k1, k2)
        },
        |(k1, k2)| {
            if (k1 == k2) != (k1.encode() == k2.encode()) {
                return Err(format!("`{}` vs `{}`", k1.encode(), k2.encode()));
            }
            Ok(())
        },
    );
}

fn random_routing(r: &mut Rng) -> (Vec<MemberRoute>, BucketLadder, Vec<usize>) {
    let n = 1 + r.below(4);
    let mut speeds: Vec<f64> = (0..n).map(|_| 1.0 + r.f64() * 9.0).collect();
    speeds.sort_by(|a, b| a.total_cmp(b));
    let ladder = BucketLadder::new(
        (0..r.below(4)).map(|_| (1 + r.below(16), 8 * (1 + r.below(64)))).collect(),
    );
    let members: Vec<MemberRoute> = speeds
        .iter()
        .enumerate()
        .map(|(i, &sp)| {
            let t = 0.2 / sp;
            MemberRoute {
                tag: format!("m{i}"),
                est_speedup: sp,
                est_batch_time: t,
                bucket_times: ladder
                    .buckets()
                    .iter()
                    .map(|&(b, s)| ((b, s), t * (0.1 + r.f64())))
                    .collect(),
            }
        })
        .collect();
    let depths: Vec<usize> = (0..n).map(|_| r.below(20)).collect();
    (members, ladder, depths)
}

fn random_sla(r: &mut Rng) -> Option<Sla> {
    if r.f64() < 0.3 {
        return None;
    }
    Some(Sla {
        class: "c".into(),
        max_latency: (r.f64() < 0.7).then(|| Duration::from_millis(r.below(400) as u64)),
        min_speedup: (r.f64() < 0.7).then(|| 1.0 + r.f64() * 9.0),
    })
}

#[test]
fn prop_route_batch_singleton_degenerates_to_route() {
    // A one-request "merge" must pick exactly the member the
    // per-request policy picks (and is never refused), plus the bucket
    // its own shape selects — the coalescing layer cannot change
    // single-request semantics.
    Prop::new(300).check_msg(
        "route_batch singleton == route",
        |r| {
            let (members, ladder, depths) = random_routing(r);
            let sla = random_sla(r);
            let len = 1 + r.below(600);
            let max_batch = 1 + r.below(16);
            let pressure = if r.f64() < 0.5 { 0 } else { 1 + r.below(40) };
            (members, ladder, depths, sla, len, max_batch, pressure)
        },
        |(members, ladder, depths, sla, len, max_batch, pressure)| {
            let expect = route(sla.as_ref(), members, depths, *max_batch, *pressure);
            let req = BatchReq { sla: sla.as_ref(), len: *len, waited: Duration::ZERO };
            match route_batch(&[req], members, depths, ladder, *max_batch, *pressure) {
                Some(br) => {
                    if br.member != expect {
                        return Err(format!("member {} != route's {expect}", br.member));
                    }
                    if br.bucket != ladder.bucket_for(1, *len) {
                        return Err(format!("bucket {:?} mismatch", br.bucket));
                    }
                    Ok(())
                }
                None => Err("singleton refused".into()),
            }
        },
    );
}

#[test]
fn prop_route_batch_merge_honors_every_constituent() {
    // Whenever route_batch accepts a multi-request merge (pressure
    // off), the chosen member must satisfy EVERY request: speedup
    // floors hold, and pending backlog + the member's bucket-priced
    // execution fits inside every remaining deadline. This re-derives
    // the §9 decision rule independently of the implementation's loop.
    Prop::new(300).check_msg(
        "accepted merge satisfies all requests",
        |r| {
            let (members, ladder, depths) = random_routing(r);
            let n_reqs = 2 + r.below(7);
            let reqs: Vec<(Option<Sla>, usize, u64)> = (0..n_reqs)
                .map(|_| (random_sla(r), 1 + r.below(600), r.below(50) as u64))
                .collect();
            (members, ladder, depths, reqs)
        },
        |(members, ladder, depths, reqs)| {
            let breqs: Vec<BatchReq> = reqs
                .iter()
                .map(|(sla, len, waited_ms)| BatchReq {
                    sla: sla.as_ref(),
                    len: *len,
                    waited: Duration::from_millis(*waited_ms),
                })
                .collect();
            let max_batch = 8usize.max(breqs.len());
            let Some(br) = route_batch(&breqs, members, depths, ladder, max_batch, 0) else {
                return Ok(()); // refusals are always allowed
            };
            let m = &members[br.member];
            let pending: f64 = members
                .iter()
                .zip(depths)
                .map(|(mm, &d)| d.div_ceil(max_batch) as f64 * mm.est_batch_time)
                .sum();
            let exec = m.time_at(br.bucket);
            for (sla, _, waited_ms) in reqs {
                let Some(sla) = sla else { continue };
                if let Some(min_s) = sla.min_speedup {
                    if m.est_speedup + 1e-9 < min_s {
                        return Err(format!("speedup floor {min_s} broken by {}", m.tag));
                    }
                }
                if let Some(max_l) = sla.max_latency {
                    let remaining =
                        max_l.saturating_sub(Duration::from_millis(*waited_ms)).as_secs_f64();
                    if pending + exec > remaining + 1e-12 {
                        return Err(format!(
                            "deadline broken: pending {pending} + exec {exec} > {remaining}"
                        ));
                    }
                }
            }
            // and the bucket, when chosen, really covers the batch
            if let Some((bb, bs)) = br.bucket {
                let max_len = breqs.iter().map(|q| q.len).max().unwrap();
                if bb < breqs.len() || bs < max_len {
                    return Err(format!("bucket ({bb},{bs}) does not cover the batch"));
                }
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------
// reproduction matrix (exp::repro, DESIGN.md §11)
// ---------------------------------------------------------------------

/// Totality + injectivity of the scenario-matrix enumeration: for any
/// seed, the engine-free cell sweep visits every {model, regime, env,
/// target} key exactly once. The precomputed dir is deliberately
/// nonexistent, so every cpu-measured cell FAILS — those cells must
/// appear with a recorded error, never be dropped from the matrix.
#[test]
fn prop_repro_matrix_total_and_injective() {
    Prop::new(8).check_msg(
        "repro matrix total+injective, errors recorded",
        |r| r.next_u64() >> 12,
        |&seed| {
            let cells = scenario_cells(seed, Path::new("/nonexistent/ziplm_proptest"));
            let want = matrix_keys();
            if cells.len() != want.len() {
                return Err(format!("{} cells, want {}", cells.len(), want.len()));
            }
            let mut seen = std::collections::HashSet::new();
            for c in &cells {
                let key =
                    (c.model.clone(), c.regime.clone(), c.env.clone(), c.target.to_bits());
                if !seen.insert(key) {
                    return Err(format!(
                        "duplicate cell {}/{}/{}/{}",
                        c.model, c.regime, c.env, c.target
                    ));
                }
            }
            for (m, regime, env, t) in &want {
                if !seen.contains(&(m.clone(), regime.clone(), env.clone(), t.to_bits())) {
                    return Err(format!("missing cell {m}/{regime}/{env}/{t}"));
                }
            }
            let errs: Vec<&ScenarioCell> =
                cells.iter().filter(|c| c.status == CellStatus::Error).collect();
            let want_errs = want.iter().filter(|(_, _, env, _)| env == "cpu-measured").count();
            if errs.len() != want_errs {
                return Err(format!("{} error cells, want {want_errs}", errs.len()));
            }
            for c in &errs {
                if c.env != "cpu-measured" {
                    return Err(format!("unexpected error on env {}", c.env));
                }
                if c.error.is_empty() {
                    return Err("error cell with empty reason".into());
                }
            }
            Ok(())
        },
    );
}

fn random_scenario_cell(r: &mut Rng) -> ScenarioCell {
    let status = match r.below(3) {
        0 => CellStatus::Ran,
        1 => CellStatus::Cached,
        _ => CellStatus::Error,
    };
    ScenarioCell {
        model: tricky_string(r),
        regime: if r.below(2) == 0 { "oneshot".into() } else { "gradual".into() },
        env: tricky_string(r),
        target: 1.0 + r.f64() * 4.0,
        status,
        certified: r.f64() * 5.0,
        proxy_error: r.f64() * 3.0,
        profile: (0..r.below(5)).map(|_| (r.below(12), r.below(4096))).collect(),
        error: if status == CellStatus::Error { tricky_string(r) } else { String::new() },
    }
}

fn random_family_block(r: &mut Rng) -> FamilyBlock {
    FamilyBlock {
        model: tricky_string(r),
        env: tricky_string(r),
        members: (0..r.below(4))
            .map(|_| MemberSummary {
                tag: tricky_string(r),
                est_speedup: r.f64() * 4.0,
                est_batch_time_ms: r.f64() * 50.0,
            })
            .collect(),
        buckets: (0..r.below(4)).map(|_| (1 + r.below(64), 1 + r.below(512))).collect(),
        per_bucket: (0..r.below(4))
            .map(|_| BucketRow {
                member: tricky_string(r),
                batch: r.below(64),
                seq: r.below(512),
                specialized: r.below(2) == 0,
                batches: r.below(40),
                requests: r.below(200),
                certified_ms: r.f64() * 50.0,
                realized_p50_ms: r.f64() * 50.0,
                realized_p99_ms: r.f64() * 80.0,
                gap: r.f64() * 2.0,
            })
            .collect(),
        chaos: ChaosSummary {
            submitted: r.below(200),
            lost: r.below(3),
            balanced: r.below(2) == 0,
        },
    }
}

fn random_compound_block(r: &mut Rng) -> CompoundBlock {
    CompoundBlock {
        model: tricky_string(r),
        env: tricky_string(r),
        target: 1.0 + r.f64() * 4.0,
        prune_equiv: r.below(2) == 0,
        members: (0..r.below(6))
            .map(|_| CompoundMember {
                tag: tricky_string(r),
                axis: tricky_string(r),
                certified: r.f64() * 5.0,
                loss: r.f64() * 3.0,
            })
            .collect(),
        axes: (0..r.below(4)).map(|_| (tricky_string(r), r.below(16))).collect(),
    }
}

fn random_adapt_block(r: &mut Rng) -> AdaptBlock {
    AdaptBlock {
        model: tricky_string(r),
        env: tricky_string(r),
        requests: r.below(200),
        latency_drift: r.f64(),
        mass_shift: r.f64(),
        overrun_rate: r.f64(),
        drifted: r.below(2) == 0,
        fitted_batch: 1 + r.below(64),
        fitted_seq: 1 + r.below(512),
        fitted_skew: r.f64() * 2.0,
        fitted_sweep: (0..r.below(4)).map(|_| (1 + r.below(512), r.f64() * 2.0)).collect(),
        knee: r.f64() * 4.0,
        targets: (0..r.below(5)).map(|_| 1.0 + r.f64() * 4.0).collect(),
    }
}

/// ReproReport text round-trip: serialize → parse → deserialize →
/// serialize must reproduce the bytes. f64 Display is shortest
/// round-trip and the parser is correctly rounded, so exact equality
/// must hold on arbitrary (not just q4'd) values; the report schema's
/// error/success field exclusivity also normalizes on the first
/// serialize, so the second pass can't differ.
#[test]
fn prop_repro_report_json_roundtrip_identity() {
    Prop::new(40).check_msg(
        "ReproReport JSON text round-trip",
        |r| ReproReport {
            mode: if r.below(2) == 0 { "kick-tires".into() } else { "full".into() },
            seed: r.below(1 << 31) as u64,
            cells: (0..r.below(6)).map(|_| random_scenario_cell(r)).collect(),
            families: (0..r.below(4)).map(|_| random_family_block(r)).collect(),
            adapt: (0..r.below(3)).map(|_| random_adapt_block(r)).collect(),
            compound: (0..r.below(3)).map(|_| random_compound_block(r)).collect(),
        },
        |rep| {
            let text = rep.to_json().to_pretty();
            let parsed = Json::parse(&text).map_err(|e| format!("parse: {e}"))?;
            let back = ReproReport::from_json(&parsed).map_err(|e| e.to_string())?;
            let text2 = back.to_json().to_pretty();
            if text != text2 {
                let line = text
                    .lines()
                    .zip(text2.lines())
                    .position(|(a, b)| a != b)
                    .map(|i| i + 1)
                    .unwrap_or(0);
                return Err(format!("round-trip drifted at line {line}"));
            }
            if back.seed != rep.seed || back.cells.len() != rep.cells.len() {
                return Err("structural fields drifted".into());
            }
            Ok(())
        },
    );
}

// --------------------------------------------------------- adapt drift

/// Measured env with a pinned anchor shape for the drift properties.
fn drift_env(batch: usize, seq: usize) -> InferenceEnv {
    let table = LatencyTable {
        model: "m".into(),
        device: "sim".into(),
        regime: "throughput".into(),
        attn: vec![0.0, 1e-3],
        mlp: vec![(64, 1e-3), (0, 0.0)],
        overhead: 1e-3,
    };
    InferenceEnv::measured(table).unwrap().with_batch_shape(batch, seq)
}

/// Sample whose realized time EXACTLY equals its certified estimate
/// (built from integer nanos so `exec.as_secs_f64()` is lossless).
fn exact_sample(batch: usize, seq: usize, requests: usize, nanos: u64) -> BucketSample {
    let exec = Duration::from_nanos(nanos);
    BucketSample {
        member: "dense".into(),
        batch,
        seq,
        specialized: true,
        exec,
        requests,
        certified: exec.as_secs_f64(),
    }
}

#[test]
fn prop_drift_silent_on_anchor_shaped_traffic() {
    // traffic shaped exactly like the certified anchor, executing at
    // exactly the certified price, must never flag — for any volume,
    // anchor shape, or per-sample pricing
    Prop::new(60).check_msg(
        "no drift on anchor-shaped traffic",
        |r| {
            let batch = 1 + r.below(64);
            let seq = 1 + r.below(1024);
            let samples: Vec<BucketSample> = (0..1 + r.below(40))
                .map(|_| {
                    exact_sample(batch, seq, 1 + r.below(8), 1_000 + r.below(1 << 30) as u64)
                })
                .collect();
            (batch, seq, samples)
        },
        |(batch, seq, samples)| {
            let env = drift_env(*batch, *seq);
            let rep = detect_drift(samples, &env, &DriftCfg::default());
            if rep.drifted {
                return Err("flagged anchor-shaped traffic".into());
            }
            if rep.latency_drift != 0.0 || rep.mass_shift != 0.0 || rep.overrun_rate != 0.0 {
                return Err(format!("nonzero drift statistics: {rep:?}"));
            }
            if rep.per_bucket.len() != 1 || rep.per_bucket[0].share != 1.0 {
                return Err(format!("per-bucket accounting broke: {:?}", rep.per_bucket));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_drift_mass_shift_monotone_in_injected_shift() {
    // displacing MORE requests, or the same requests FARTHER from the
    // anchor, must strictly grow the mass-shift statistic; and for a
    // single displaced bucket the statistic matches its closed form
    // moved/total * 0.5 * d/seq exactly (integer-valued f64 ops)
    Prop::new(60).check_msg(
        "mass shift monotone in injected shift",
        |r| {
            let batch = 1 + r.below(32);
            let seq = 64 + r.below(512);
            let total = 16 + r.below(32);
            let moved = 1 + r.below(total);
            let d1 = 1 + r.below(seq / 2);
            let d2 = d1 + 1 + r.below(seq / 2);
            (batch, seq, total, moved, d1, d2)
        },
        |&(batch, seq, total, moved, d1, d2)| {
            let env = drift_env(batch, seq);
            let build = |n_moved: usize, d: usize| -> Vec<BucketSample> {
                (0..total)
                    .map(|i| {
                        let s = if i < n_moved { seq - d } else { seq };
                        exact_sample(batch, s, 1, 1_000_000)
                    })
                    .collect()
            };
            let cfg = DriftCfg::default();
            let near = detect_drift(&build(moved, d1), &env, &cfg);
            let far = detect_drift(&build(moved, d2), &env, &cfg);
            if far.mass_shift <= near.mass_shift {
                return Err(format!(
                    "farther displacement did not grow mass shift: {} vs {}",
                    far.mass_shift, near.mass_shift
                ));
            }
            if moved < total {
                let more = detect_drift(&build(moved + 1, d1), &env, &cfg);
                if more.mass_shift <= near.mass_shift {
                    return Err("more displaced requests did not grow mass shift".into());
                }
            }
            let want = moved as f64 / total as f64 * 0.5 * (d1 as f64 / seq as f64);
            if (near.mass_shift - want).abs() > 1e-9 {
                return Err(format!("mass shift {} != closed form {want}", near.mass_shift));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_drift_detect_and_fit_pure_with_json_roundtrip() {
    // same samples in, bit-identical verdict and fitted env out — and
    // the DriftReport survives its JSON text round-trip exactly
    Prop::new(60).check_msg(
        "detect_drift/fit_env purity + DriftReport JSON roundtrip",
        |r| {
            let batch = 1 + r.below(32);
            let seq = 1 + r.below(512);
            let samples: Vec<BucketSample> = (0..1 + r.below(30))
                .map(|_| BucketSample {
                    member: tricky_string(r),
                    batch: 1 + r.below(64),
                    seq: 1 + r.below(1024),
                    specialized: r.below(2) == 0,
                    exec: Duration::from_nanos(1 + r.below(1 << 30) as u64),
                    requests: 1 + r.below(8),
                    certified: 1e-6 + r.f64() * 1e-2,
                })
                .collect();
            (batch, seq, samples)
        },
        |(batch, seq, samples)| {
            let env = drift_env(*batch, *seq);
            let cfg = DriftCfg::default();
            let a = detect_drift(samples, &env, &cfg);
            let b = detect_drift(samples, &env, &cfg);
            if a != b {
                return Err("same samples, different drift reports".into());
            }
            let f1 = fit_env(samples, &env).map_err(|e| e.to_string())?;
            let f2 = fit_env(samples, &env).map_err(|e| e.to_string())?;
            if f1 != f2 {
                return Err("same samples, different fitted envs".into());
            }
            let text = a.to_json().to_pretty();
            let parsed = Json::parse(&text).map_err(|e| format!("parse: {e}"))?;
            let back = DriftReport::from_json(&parsed).map_err(|e| e.to_string())?;
            if back != a {
                return Err("DriftReport JSON roundtrip drifted".into());
            }
            if back.to_json().to_pretty() != text {
                return Err("DriftReport re-serialize drifted".into());
            }
            Ok(())
        },
    );
}
