//! Golden-file suite for the kick-tires reproduction report
//! (DESIGN.md §11) — fully engine-free.
//!
//! Two layers of guarantee:
//!   1. determinism: two seeded `run_kick_tires` invocations produce
//!      bit-identical report JSON and rendered markdown;
//!   2. pinned claims: the rendered tables match the goldens committed
//!      under `tests/golden/` byte-for-byte, so any change to solver,
//!      pricing, routing, replay, or formatting shows up as a reviewed
//!      golden diff, never as silent drift.
//!
//! Refresh after an intentional harness change with
//! `UPDATE_GOLDEN=1 cargo test --test repro_golden` (or
//! `tools/repro/gen_golden.py`, which must agree — CI checks both).

#![allow(clippy::disallowed_methods)] // test code: unwrap-on-failure is fine

use std::path::PathBuf;

use ziplm::exp::repro::{render_markdown, run_kick_tires, ReproReport, DEFAULT_SEED};

fn golden_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests").join("golden")
}

fn precomputed_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("..")
        .join("tools")
        .join("repro")
        .join("precomputed")
}

/// First differing line rendered with context, so a golden mismatch in
/// CI reads as "this claim changed", not as a wall of bytes.
fn diff_lines(name: &str, want: &str, got: &str) -> Option<String> {
    if want == got {
        return None;
    }
    let (w, g): (Vec<&str>, Vec<&str>) = (want.lines().collect(), got.lines().collect());
    for i in 0..w.len().max(g.len()) {
        let a = w.get(i).copied();
        let b = g.get(i).copied();
        if a != b {
            return Some(format!(
                "{name}: first difference at line {}:\n  golden: {}\n  actual: {}",
                i + 1,
                a.unwrap_or("<absent>"),
                b.unwrap_or("<absent>"),
            ));
        }
    }
    Some(format!("{name}: differs in trailing whitespace or length"))
}

#[test]
fn kick_tires_is_bit_identical_across_runs() {
    let pre = precomputed_dir();
    let a = run_kick_tires(DEFAULT_SEED, &pre).unwrap();
    let b = run_kick_tires(DEFAULT_SEED, &pre).unwrap();
    let (ja, jb) = (a.to_json().to_pretty(), b.to_json().to_pretty());
    assert_eq!(ja, jb, "two seeded runs must serialize identically");
    assert_eq!(
        render_markdown(&a),
        render_markdown(&b),
        "two seeded runs must render identically"
    );
    // and a different seed really is a different report (the seed is
    // load-bearing, not decorative)
    let c = run_kick_tires(DEFAULT_SEED ^ 0xDEAD, &pre).unwrap();
    assert_ne!(ja, c.to_json().to_pretty(), "seed change must change the report");
}

#[test]
fn report_round_trips_through_json() {
    let report = run_kick_tires(DEFAULT_SEED, &precomputed_dir()).unwrap();
    let text = report.to_json().to_pretty();
    let parsed = ziplm::util::json::Json::parse(&text).unwrap();
    let back = ReproReport::from_json(&parsed).unwrap();
    assert_eq!(text, back.to_json().to_pretty(), "JSON round-trip must be lossless");
}

#[test]
fn kick_tires_matches_committed_goldens() {
    let report = run_kick_tires(DEFAULT_SEED, &precomputed_dir()).unwrap();
    let json = report.to_json().to_pretty() + "\n";
    let md = render_markdown(&report);

    let dir = golden_dir();
    let json_path = dir.join("repro_kick_tires.json");
    let md_path = dir.join("REPORT.md");

    if std::env::var_os("UPDATE_GOLDEN").is_some() {
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(&json_path, &json).unwrap();
        std::fs::write(&md_path, &md).unwrap();
        eprintln!("updated goldens under {}", dir.display());
        return;
    }

    let missing = |p: &std::path::Path, e: std::io::Error| -> String {
        panic!("missing golden {} ({e}); run with UPDATE_GOLDEN=1", p.display())
    };
    let want_json = std::fs::read_to_string(&json_path).unwrap_or_else(|e| missing(&json_path, e));
    let want_md = std::fs::read_to_string(&md_path).unwrap_or_else(|e| missing(&md_path, e));

    let mut problems = Vec::new();
    problems.extend(diff_lines("repro_kick_tires.json", &want_json, &json));
    problems.extend(diff_lines("REPORT.md", &want_md, &md));
    assert!(
        problems.is_empty(),
        "report drifted from committed goldens (UPDATE_GOLDEN=1 refreshes after an intentional \
         change):\n{}",
        problems.join("\n")
    );
}

#[test]
fn kick_tires_covers_every_cell_without_an_engine() {
    let report = run_kick_tires(DEFAULT_SEED, &precomputed_dir()).unwrap();
    assert_eq!(report.mode, "kick-tires");
    assert_eq!(report.cells.len(), 36, "2 models x 2 regimes x 3 envs x 3 targets");
    assert_eq!(report.families.len(), 6, "one family per (model, env)");
    // the measured-CPU axis has no engine here, so it must degrade to
    // the precomputed artifact (`cached`) — never to a dropped cell
    let cached = report.cells.iter().filter(|c| c.status.name() == "cached").count();
    let ran = report.cells.iter().filter(|c| c.status.name() == "ran").count();
    assert_eq!(cached, 12, "all cpu-measured cells ride the precomputed tables");
    assert_eq!(ran, 24, "analytic envs run live");
    // every family ledger is balanced and lossless by construction
    for fam in &report.families {
        assert_eq!(fam.chaos.submitted, 48);
        assert_eq!(fam.chaos.lost, 0);
        assert!(fam.chaos.balanced);
    }
    // the adapt loop runs once per gpu-sweep family, flags the seeded
    // drifted trace, and recommends targets — all without an engine
    assert_eq!(report.adapt.len(), 2, "one adapt section per model's gpu-sweep family");
    for a in &report.adapt {
        assert_eq!(a.env, "gpu-sweep");
        assert_eq!(a.requests, 48);
        assert!(a.drifted, "the short-seq trace must flag: {a:?}");
        assert!(a.mass_shift > 0.25, "drift is mass-driven: {a:?}");
        assert!(!a.targets.is_empty() && a.knee > 0.0, "frontier must recommend");
    }
    // the compound lattice runs once per model against the analytic
    // gpu-sweep env — engine-free like everything above (DESIGN.md §13)
    assert_eq!(report.compound.len(), 2, "one compound section per model");
    for b in &report.compound {
        assert_eq!(b.env, "gpu-sweep");
        assert!(b.prune_equiv, "prune-only lattice must reproduce the legacy DP: {b:?}");
        let tags: Vec<&str> = b.members.iter().map(|m| m.tag.as_str()).collect();
        assert_eq!(tags, ["dense", "prune", "int8", "lowrank", "compound"]);
        let get = |t: &str| b.members.iter().find(|m| m.tag == t).unwrap();
        assert_eq!(get("dense").certified, 1.0);
        assert_eq!(get("dense").loss, 0.0);
        assert!(get("int8").axis.contains("quant="), "int8 member: {b:?}");
        assert!(get("lowrank").axis.contains("lowrank="), "lowrank member: {b:?}");
        for t in ["prune", "compound"] {
            assert!(get(t).certified >= b.target - 1e-9, "{t} must certify {b:?}");
        }
        assert!(
            get("compound").loss <= get("prune").loss + 1e-9,
            "widening the lattice must never cost loss: {b:?}"
        );
        assert!(b.axes.len() >= 2, "the mixed solve must actually mix axes: {b:?}");
    }
}

#[test]
fn missing_precomputed_tables_record_errors_not_absences() {
    let report = run_kick_tires(DEFAULT_SEED, &PathBuf::from("/nonexistent/ziplm")).unwrap();
    assert_eq!(report.cells.len(), 36, "failed cells must still appear");
    let errors: Vec<_> =
        report.cells.iter().filter(|c| c.status.name() == "error").collect();
    assert_eq!(errors.len(), 12, "exactly the cpu-measured cells fail");
    for c in &errors {
        assert_eq!(c.env, "cpu-measured");
        assert!(
            c.error.contains("precomputed latency table"),
            "error must say why: {}",
            c.error
        );
    }
    // the analytic axes are unaffected
    assert_eq!(report.families.len(), 4, "one family per (model, analytic env)");
    // ... and so is the compound lattice (priced by the analytic
    // gpu-sweep model, it never reads the precomputed tables)
    assert_eq!(report.compound.len(), 2, "compound sections survive missing tables");
    assert!(report.compound.iter().all(|b| b.prune_equiv));
}
