//! End-to-end pipeline integration: teacher → Hessians → databases →
//! SPDY → apply → evaluate, and the serving coordinator. Skipped when
//! artifacts/ is absent.

#![allow(clippy::disallowed_methods)] // test code: unwrap-on-failure IS the assertion

mod support;

use std::path::Path;

use support::{engine, toy_env};
use ziplm::data;
use ziplm::eval;
use ziplm::models::ModelState;
use ziplm::pruner::{PruneCfg, SpdyCfgLite, TargetMode};
use ziplm::session::CompressionSession;
use ziplm::train::{TrainCfg, Trainer};

#[test]
fn oneshot_prune_meets_speedup_and_keeps_signal() {
    let Some(engine) = engine() else { return };
    let model = "bert-syn-base";
    let task = "sst2-syn";
    let minfo = engine.manifest.model(model).clone();
    let tinfo = engine.manifest.task(model, task).clone();
    let ds = data::load_sized(&minfo, task, 128, 64);
    // brief teacher training so the model has structure worth keeping
    let mut st = ModelState::init(&minfo, task, &tinfo, 3);
    let mut tr = Trainer::new(&engine, tinfo.n_params, None);
    tr.train(
        &mut st,
        &ds,
        &TrainCfg { lr: 1e-3, epochs: 2.0, lambdas: [1.0, 0.0, 0.0], weight_decay: 0.0, seed: 0, log_every: 0 },
    )
    .unwrap();
    let dense_eval = eval::evaluate(&engine, &st, &ds, "dev").unwrap();

    let env = toy_env(&engine, model);
    let cfg = PruneCfg {
        calib_samples: 32,
        spdy: SpdyCfgLite { iters: 10, seed: 1 },
        ..Default::default()
    };
    let target = 2.0;
    let mut pruned = st.clone();
    let report = CompressionSession::for_model(&engine, model, task)
        .with_env(env)
        .with_prune_cfg(cfg)
        .open()
        .unwrap()
        .oneshot(&mut pruned, &ds, target)
        .unwrap();
    // speedup guarantee (the paper's headline property)
    assert!(report.est_speedup >= target * 0.999, "est {}", report.est_speedup);
    // masks consistent with profile
    for (l, &(h, f)) in report.layer_profile.iter().enumerate() {
        assert_eq!(pruned.masks.heads_alive(l), h);
        assert_eq!(pruned.masks.ffn_alive(l), f);
    }
    // pruned weights zeroed
    for l in 0..minfo.n_layers {
        let w = pruned.fc_w_paper(&tinfo, l).unwrap();
        for c in 0..minfo.d_ff {
            if pruned.masks.ffn_row(l)[c] == 0.0 {
                for r in 0..w.rows() {
                    assert_eq!(w.at2(r, c), 0.0, "layer {l} col {c}");
                }
            }
        }
    }
    // one-shot 2x should retain most of the dense quality
    let pruned_eval = eval::evaluate(&engine, &pruned, &ds, "dev").unwrap();
    assert!(
        pruned_eval.metric >= dense_eval.metric - 0.25,
        "dense {} pruned {}",
        dense_eval.metric,
        pruned_eval.metric
    );
}

#[test]
fn sparsity_mode_also_runs() {
    let Some(engine) = engine() else { return };
    let model = "bert-syn-base";
    let task = "qnli-syn";
    let minfo = engine.manifest.model(model).clone();
    let tinfo = engine.manifest.task(model, task).clone();
    let ds = data::load_sized(&minfo, task, 64, 32);
    let mut st = ModelState::init(&minfo, task, &tinfo, 4);
    let env = toy_env(&engine, model);
    let mut cfg = PruneCfg {
        calib_samples: 16,
        spdy: SpdyCfgLite { iters: 4, seed: 2 },
        ..Default::default()
    };
    cfg.target_mode = TargetMode::Sparsity;
    // in parameter mode the session anchors on the dense parameter
    // count; drive the explicit stage chain with a custom budget
    let dense_params: f64 = 2.0 * minfo.n_layers as f64
        * (minfo.d_model * minfo.d_attn()) as f64
        + 2.0 * minfo.n_layers as f64 * (minfo.d_model * minfo.d_ff) as f64;
    let sess = CompressionSession::for_model(&engine, model, task)
        .with_env(env)
        .with_prune_cfg(cfg)
        .open()
        .unwrap();
    let variant = sess
        .capture(&st, &ds)
        .unwrap()
        .build_dbs()
        .unwrap()
        .solve_with_dense_cost(&ds, 2.0, dense_params)
        .unwrap()
        .apply();
    assert!(variant.is_ok());
    st = variant.unwrap().state;
    assert!(st.masks.density() < 1.0, "sparsity mode pruned nothing");
}

#[test]
fn gradual_two_targets_monotone_masks() {
    let Some(engine) = engine() else { return };
    let model = "bert-syn-base";
    let task = "sst2-syn";
    let minfo = engine.manifest.model(model).clone();
    let tinfo = engine.manifest.task(model, task).clone();
    let ds = data::load_sized(&minfo, task, 64, 32);
    let st = ModelState::init(&minfo, task, &tinfo, 6);
    let env = toy_env(&engine, model);
    let cfg = PruneCfg {
        calib_samples: 16,
        spdy: SpdyCfgLite { iters: 4, seed: 3 },
        ..Default::default()
    };
    let tcfg = TrainCfg { lr: 5e-4, epochs: 0.5, lambdas: [1.0, 0.0, 0.0], weight_decay: 0.0, seed: 0, log_every: 0 };
    let stages = CompressionSession::for_model(&engine, model, task)
        .with_env(env)
        .with_targets(&[1.5, 2.5])
        .with_prune_cfg(cfg)
        .with_train_cfg(tcfg)
        .open()
        .unwrap()
        .run(st, &ds)
        .unwrap();
    assert_eq!(stages.len(), 2);
    // gradual: stage 2 masks are a subset of stage 1 masks (monotone pruning)
    let m1 = &stages[0].state.masks;
    let m2 = &stages[1].state.masks;
    for (a, b) in m1.head.iter().zip(&m2.head) {
        assert!(!(*a == 0.0 && *b == 1.0), "head resurrected");
    }
    for (a, b) in m1.ffn.iter().zip(&m2.ffn) {
        assert!(!(*a == 0.0 && *b == 1.0), "ffn col resurrected");
    }
    assert!(stages[1].report.est_speedup >= 2.5 * 0.999);
}

#[test]
fn serving_coordinator_batches_and_replies() {
    let Some(engine) = engine() else { return };
    let model = "bert-syn-base";
    let task = "sst2-syn";
    let minfo = engine.manifest.model(model).clone();
    let tinfo = engine.manifest.task(model, task).clone();
    let st = ModelState::init(&minfo, task, &tinfo, 9);
    drop(engine);
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let handle = ziplm::coordinator::start(
        ziplm::coordinator::ServerCfg {
            artifacts: dir,
            max_batch: 8,
            max_wait: std::time::Duration::from_millis(3),
        },
        st,
    )
    .unwrap();
    // concurrent submissions to exercise the batcher
    let mut receivers = Vec::new();
    for i in 0..20 {
        receivers.push(handle.submit(vec![(i % 7) as i32; minfo.seq_len]).unwrap());
    }
    let mut batched = false;
    for rx in receivers {
        let r = rx.recv_timeout(std::time::Duration::from_secs(60)).unwrap();
        assert_eq!(r.logits.len(), tinfo.n_classes);
        assert!(r.logits.iter().all(|x| x.is_finite()));
        if r.batch_size > 1 {
            batched = true;
        }
    }
    let stats = handle.shutdown().unwrap();
    assert_eq!(stats.requests, 20);
    assert!(stats.batches <= 20);
    assert!(batched || stats.batches < 20, "dynamic batching never engaged");
}
