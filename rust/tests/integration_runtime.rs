//! Integration: PJRT runtime × AOT artifacts × the HLO pruning kernels.
//!
//! These tests need `artifacts/` (run `make artifacts` first); they are
//! skipped gracefully when it is absent so `cargo test` stays green on
//! a fresh clone.

#![allow(clippy::disallowed_methods)] // test/bench/example code: unwrap-on-failure is fine

mod support;

use support::engine;
use ziplm::models::ModelState;
use ziplm::runtime::{lit_f32_shaped, lit_i32, lit_to_f32};
use ziplm::tensor::{linalg, Tensor};
use ziplm::util::prop::gen;
use ziplm::util::rng::Rng;
use ziplm::ziplm::{HloBackend, NativeBackend, ObsOps};

#[test]
fn fwd_artifact_runs_and_shapes_match() {
    let Some(engine) = engine() else { return };
    let model = "bert-syn-base";
    let task = "sst2-syn";
    let minfo = engine.manifest.model(model).clone();
    let tinfo = engine.manifest.task(model, task).clone();
    let st = ModelState::init(&minfo, task, &tinfo, 0);
    let b = engine.manifest.batch_eval;
    let ids = vec![3i32; b * minfo.seq_len];
    let out = engine
        .run(
            &format!("{model}__{task}__fwd"),
            &[
                lit_f32_shaped(&[tinfo.n_params], &st.params).unwrap(),
                lit_i32(&[b, minfo.seq_len], &ids).unwrap(),
                lit_f32_shaped(&[minfo.n_layers, minfo.n_heads], &st.masks.head).unwrap(),
                lit_f32_shaped(&[minfo.n_layers, minfo.d_ff], &st.masks.ffn).unwrap(),
            ],
        )
        .expect("fwd");
    let logits = lit_to_f32(&out[0]).unwrap();
    assert_eq!(logits.len(), b * 2);
    assert!(logits.iter().all(|x| x.is_finite()));
}

#[test]
fn hlo_obs_backend_matches_native_mirror_fc() {
    let Some(engine) = engine() else { return };
    let model = "bert-syn-base";
    let minfo = engine.manifest.model(model).clone();
    let mut rng = Rng::new(99);
    let d = minfo.d_model;
    let f = minfo.d_ff;
    let w = Tensor::from_vec(&[d, f], gen::vec_f32(&mut rng, d * f, 0.5));
    let h = Tensor::from_vec(&[f, f], gen::spd(&mut rng, f, 0.2));
    let hinv = linalg::spd_inverse(&h).unwrap();
    let active = vec![1.0f32; f];

    let mut hlo = HloBackend::fc(&engine, model).unwrap();
    let mut native = NativeBackend::new(1);

    let s_h = hlo.scores(&w, &hinv, &active).unwrap();
    let s_n = native.scores(&w, &hinv, &active).unwrap();
    let mut max_rel = 0f64;
    for (a, b) in s_h.iter().zip(&s_n) {
        let rel = ((a - b).abs() / b.abs().max(1e-3)) as f64;
        max_rel = max_rel.max(rel);
    }
    assert!(max_rel < 5e-2, "score mismatch {max_rel}");

    let j = ziplm::ziplm::argmin(&s_h);
    let (w_h, hinv_h) = hlo.update(&w, &hinv, j).unwrap();
    let (w_n, hinv_n) = native.update(&w, &hinv, j).unwrap();
    assert!(w_h.max_abs_diff(&w_n) < 1e-2, "update W mismatch {}", w_h.max_abs_diff(&w_n));
    assert!(hinv_h.max_abs_diff(&hinv_n) < 1e-2);
}

#[test]
fn hlo_obs_backend_matches_native_mirror_attn() {
    let Some(engine) = engine() else { return };
    let model = "bert-syn-base";
    let minfo = engine.manifest.model(model).clone();
    let mut rng = Rng::new(7);
    let d = minfo.d_model;
    let a = minfo.d_attn();
    let w = Tensor::from_vec(&[d, a], gen::vec_f32(&mut rng, d * a, 0.5));
    let h = Tensor::from_vec(&[a, a], gen::spd(&mut rng, a, 0.3));
    let hinv = linalg::spd_inverse(&h).unwrap();
    let active = vec![1.0f32; minfo.n_heads];

    let mut hlo = HloBackend::attn(&engine, model).unwrap();
    let mut native = NativeBackend::new(minfo.d_head);
    let s_h = hlo.scores(&w, &hinv, &active).unwrap();
    let s_n = native.scores(&w, &hinv, &active).unwrap();
    for (x, y) in s_h.iter().zip(&s_n) {
        assert!((x - y).abs() / y.abs().max(1e-3) < 5e-2, "{s_h:?} vs {s_n:?}");
    }
    let j = ziplm::ziplm::argmin(&s_h);
    let (w_h, _) = hlo.update(&w, &hinv, j).unwrap();
    let (w_n, _) = native.update(&w, &hinv, j).unwrap();
    assert!(w_h.max_abs_diff(&w_n) < 2e-2);
}

#[test]
fn hlo_multi_update_matches_native_sequence() {
    let Some(engine) = engine() else { return };
    let model = "bert-syn-base";
    let minfo = engine.manifest.model(model).clone();
    let mut rng = Rng::new(13);
    let d = minfo.d_model;
    let f = minfo.d_ff;
    let w = Tensor::from_vec(&[d, f], gen::vec_f32(&mut rng, d * f, 0.5));
    let h = Tensor::from_vec(&[f, f], gen::spd(&mut rng, f, 0.2));
    let hinv = linalg::spd_inverse(&h).unwrap();
    let active = vec![1.0f32; f];
    let n = 12;
    let mut hlo = HloBackend::fc(&engine, model).unwrap();
    let (w_h, _, act_h, order_h) = hlo.multi_update(&w, &hinv, &active, n).unwrap();
    let mut native = NativeBackend::new(1);
    let (w_n, _, act_n, order_n) = native.multi_update(&w, &hinv, &active, n).unwrap();
    assert_eq!(order_h, order_n, "removal order differs");
    assert_eq!(act_h, act_n);
    assert!(w_h.max_abs_diff(&w_n) < 2e-2, "{}", w_h.max_abs_diff(&w_n));
}

#[test]
fn train_step_decreases_loss_through_pjrt() {
    let Some(engine) = engine() else { return };
    let model = "bert-syn-base";
    let task = "sst2-syn";
    let minfo = engine.manifest.model(model).clone();
    let tinfo = engine.manifest.task(model, task).clone();
    let mut st = ModelState::init(&minfo, task, &tinfo, 1);
    let ds = ziplm::data::load_sized(&minfo, task, 64, 32);
    let mut tr = ziplm::train::Trainer::new(&engine, tinfo.n_params, None);
    let cfg = ziplm::train::TrainCfg {
        lr: 1e-3,
        epochs: 3.0,
        lambdas: [1.0, 0.0, 0.0],
        weight_decay: 0.0,
        seed: 0,
        log_every: 0,
    };
    let final_loss = tr.train(&mut st, &ds, &cfg).unwrap();
    assert!(final_loss < 0.6, "training did not learn: {final_loss}");
}

#[test]
fn masked_fwd_ignores_dead_structures() {
    let Some(engine) = engine() else { return };
    let model = "bert-syn-base";
    let task = "sst2-syn";
    let minfo = engine.manifest.model(model).clone();
    let tinfo = engine.manifest.task(model, task).clone();
    let mut st = ModelState::init(&minfo, task, &tinfo, 5);
    st.masks.kill_head(1, 2);
    for c in 0..minfo.d_ff / 2 {
        st.masks.kill_ffn_col(2, c);
    }
    let ds = ziplm::data::load_sized(&minfo, task, 64, 32);
    let base = ziplm::eval::calib_loss(&engine, &st, &ds, 32).unwrap();
    // perturb exactly the dead head's q-columns; loss must not change
    let mut st3 = st.clone();
    let mut wq = st3.get2(&tinfo, "layer1.wq").unwrap();
    let cols = wq.cols();
    for r in 0..wq.rows() {
        for c in 2 * minfo.d_head..3 * minfo.d_head {
            wq.data[r * cols + c] += 55.0;
        }
    }
    let data = wq.data.clone();
    st3.set_flat(&tinfo, "layer1.wq", &data).unwrap();
    let l3 = ziplm::eval::calib_loss(&engine, &st3, &ds, 32).unwrap();
    assert!((base - l3).abs() < 1e-4, "dead head leaked: {base} vs {l3}");
}

#[test]
fn measured_latency_table_is_monotone() {
    let Some(engine) = engine() else { return };
    let t = ziplm::latency::measure_cpu(&engine, "bert-syn-base", "latency", 15).unwrap();
    // Sub-ms blocks on a shared single core are noisy; require
    // monotonicity only above the noise floor and with generous slack.
    const FLOOR: f64 = 0.4e-3;
    for h in 1..t.attn.len() - 1 {
        if t.attn[h] < FLOOR && t.attn[h + 1] < FLOOR {
            continue;
        }
        assert!(t.attn[h] <= t.attn[h + 1] * 2.0, "attn not ~monotone at {h}: {:?}", t.attn);
    }
    let widths: Vec<usize> = t.mlp.iter().map(|&(w, _)| w).collect();
    for pair in widths.windows(2) {
        let (a, b) = (t.mlp_time(pair[0]), t.mlp_time(pair[1]));
        if a < FLOOR && b < FLOOR {
            continue;
        }
        assert!(a * 2.0 >= b, "mlp not ~monotone: {:?}", t.mlp);
    }
    // dense entries must dominate the tail regardless of noise
    assert!(t.mlp_time(widths[0]) > t.mlp_time(*widths.iter().rev().nth(1).unwrap()));
    assert!(t.attn[t.attn.len() - 1] > t.attn[0]);
}
