//! CompressionSession integration: straight-line-pipeline vs session
//! equivalence, crash-resume behavior, and the multi-env axis
//! (retarget + emit_families). Skipped when artifacts/ is absent, like
//! the other integration suites; the engine-free resume/fingerprint
//! mechanics are covered by `session::store` unit tests and
//! tests/proptests.rs.

#![allow(clippy::disallowed_methods)] // test/bench/example code: unwrap-on-failure is fine

mod support;

use support::{cfg, engine, other_env, tcfg, temp_dir, toy_env};
use ziplm::data;
use ziplm::env::InferenceEnv;
use ziplm::models::ModelState;
use ziplm::session::{env_slug, CompressionSession};

/// Acceptance: a small seeded model driven through BOTH the
/// straight-line free-function pipeline (`session::pipeline`) and the
/// CompressionSession stage API must produce identical chosen
/// profiles, certified speedups, and emitted family manifests.
#[test]
fn pipeline_free_functions_and_session_agree_exactly() {
    let Some(engine) = engine() else { return };
    let model = "bert-syn-base";
    let task = "sst2-syn";
    let minfo = engine.manifest.model(model).clone();
    let tinfo = engine.manifest.task(model, task).clone();
    let ds = data::load_sized(&minfo, task, 64, 32);
    let teacher = ModelState::init(&minfo, task, &tinfo, 12);
    let env = toy_env(&engine, model);
    let targets = [1.5, 2.5];

    // straight-line: the checkpoint-free pipeline free functions
    let straight = ziplm::session::pipeline::gradual(
        &engine,
        teacher.clone(),
        &ds,
        &env,
        &targets,
        &cfg(),
        &tcfg(),
        None,
    )
    .unwrap();
    let straight_dir = temp_dir("straight_family");
    let straight_fam =
        ziplm::session::pipeline::emit_family(&env, &teacher, &straight, &straight_dir).unwrap();

    // session: typed stage API (checkpointing off → pure compute path)
    let sess = CompressionSession::for_model(&engine, model, task)
        .with_env(env.clone())
        .with_targets(&targets)
        .with_prune_cfg(cfg())
        .with_train_cfg(tcfg())
        .open()
        .unwrap();
    let staged = sess.run(teacher.clone(), &ds).unwrap();
    let session_dir = temp_dir("session_family");
    let session_fam = sess.emit_family(&teacher, &staged, &session_dir).unwrap();

    assert_eq!(straight.len(), staged.len());
    for (l, s) in straight.iter().zip(&staged) {
        assert_eq!(l.report.layer_profile, s.report.layer_profile, "chosen profiles differ");
        assert_eq!(l.report.est_speedup, s.report.est_speedup, "certified speedups differ");
        assert_eq!(l.state.masks, s.state.masks, "masks differ");
        assert_eq!(l.state.params, s.state.params, "weights differ");
    }
    // identical manifests, byte for byte (ckpt names are relative),
    // both embedding the certification env
    assert_eq!(
        straight_fam.to_json().to_pretty(),
        session_fam.to_json().to_pretty(),
        "family manifests differ"
    );
    assert_eq!(session_fam.env.as_ref(), Some(&env), "manifest must embed its env");
    let _ = std::fs::remove_dir_all(straight_dir);
    let _ = std::fs::remove_dir_all(session_dir);
}

/// A re-opened session over the same checkpoint directory must load
/// every completed stage instead of recomputing — asserted through the
/// session's (computed, loaded) counters and by output equality.
#[test]
fn session_resume_loads_checkpointed_stages() {
    let Some(engine) = engine() else { return };
    let model = "bert-syn-base";
    let task = "sst2-syn";
    let minfo = engine.manifest.model(model).clone();
    let tinfo = engine.manifest.task(model, task).clone();
    let ds = data::load_sized(&minfo, task, 64, 32);
    let teacher = ModelState::init(&minfo, task, &tinfo, 13);
    let env = toy_env(&engine, model);
    let dir = temp_dir("session_resume");

    let open = || {
        CompressionSession::for_model(&engine, model, task)
            .with_env(env.clone())
            .with_targets(&[1.5, 2.5])
            .with_prune_cfg(cfg())
            .with_train_cfg(tcfg())
            .checkpoint_to(&dir)
            .open()
            .unwrap()
    };

    let first = open();
    let stages1 = first.run(teacher.clone(), &ds).unwrap();
    let (computed1, loaded1) = first.counters();
    assert!(computed1 > 0, "first run computed nothing");
    assert_eq!(loaded1, 0, "first run on an empty dir loaded something");

    // "crash" and re-open: everything must come back from checkpoints
    drop(first);
    let second = open();
    let stages2 = second.run(teacher.clone(), &ds).unwrap();
    let (computed2, loaded2) = second.counters();
    assert_eq!(computed2, 0, "resume recomputed {computed2} stage(s)");
    assert!(loaded2 > 0, "resume loaded nothing");
    assert_eq!(stages1.len(), stages2.len());
    for (a, b) in stages1.iter().zip(&stages2) {
        assert_eq!(a.report.layer_profile, b.report.layer_profile);
        assert_eq!(a.report.est_speedup, b.report.est_speedup);
        assert_eq!(a.state.params, b.state.params);
        assert_eq!(a.state.masks, b.state.masks);
    }

    // a session dir records the envs it has certified against: opening
    // with an env it has never seen must be refused (retarget() is the
    // sanctioned way to introduce one), not silently re-certified
    let refused = CompressionSession::for_model(&engine, model, task)
        .with_env(other_env(&env))
        .with_targets(&[1.5, 2.5])
        .with_prune_cfg(cfg())
        .checkpoint_to(&dir)
        .open();
    assert!(refused.is_err(), "resume against an unrecorded env was not refused");
    let _ = std::fs::remove_dir_all(dir);
}

/// Satellite acceptance: `retarget(env2)` on a checkpointed session
/// produces profiles identical to a fresh capture+solve against env2,
/// while the store counters prove the Hessians and databases were
/// LOADED, not recomputed — and env1's certification stays intact.
#[test]
fn retarget_reuses_databases_and_matches_fresh_solve() {
    let Some(engine) = engine() else { return };
    let model = "bert-syn-base";
    let task = "sst2-syn";
    let minfo = engine.manifest.model(model).clone();
    let tinfo = engine.manifest.task(model, task).clone();
    let ds = data::load_sized(&minfo, task, 64, 32);
    let teacher = ModelState::init(&minfo, task, &tinfo, 21);
    let env1 = toy_env(&engine, model);
    let env2 = other_env(&env1);
    let target = 1.5;
    let dir = temp_dir("retarget");

    let open = |env: &InferenceEnv| {
        CompressionSession::for_model(&engine, model, task)
            .with_env(env.clone())
            .with_prune_cfg(cfg())
            .checkpoint_to(&dir)
            .open()
            .unwrap()
    };

    // 1. certify against env1, checkpointed
    let sess1 = open(&env1);
    let mut s1 = teacher.clone();
    let rep1 = sess1.oneshot(&mut s1, &ds, target).unwrap();
    drop(sess1);

    // 2. re-open with env1, retarget to env2: capture + databases must
    //    load; ONLY the env2 profile is computed
    let mut sess2 = open(&env1);
    sess2.retarget(env2.clone()).unwrap();
    assert_eq!(sess2.env(), &env2);
    let mut s2 = teacher.clone();
    let rep2 = sess2.oneshot(&mut s2, &ds, target).unwrap();
    let (c2, l2) = sess2.counters();
    assert_eq!(c2, 1, "retarget must compute exactly the env2 profile, computed {c2}");
    assert_eq!(l2, 2, "retarget must load hessians + databases, loaded {l2}");

    // 3. fresh, checkpoint-free session against env2: ground truth
    let fresh = CompressionSession::for_model(&engine, model, task)
        .with_env(env2.clone())
        .with_prune_cfg(cfg())
        .open()
        .unwrap();
    let mut s3 = teacher.clone();
    let rep3 = fresh.oneshot(&mut s3, &ds, target).unwrap();
    assert_eq!(rep2.layer_profile, rep3.layer_profile, "retargeted profile != fresh env2 profile");
    assert_eq!(rep2.est_speedup, rep3.est_speedup);
    assert_eq!(s2.params, s3.params);
    assert_eq!(s2.masks, s3.masks);

    // 4. env1's certification is untouched AND env2 is now a recorded
    //    env: opening with either resumes fully (computed == 0)
    for (env, rep_expect) in [(&env1, &rep1), (&env2, &rep2)] {
        let sess = open(env);
        let mut st = teacher.clone();
        let rep = sess.oneshot(&mut st, &ds, target).unwrap();
        let (c, l) = sess.counters();
        assert_eq!(c, 0, "resume against {} recomputed {c}", env.describe());
        assert_eq!(l, 3, "resume against {} loaded {l}", env.describe());
        assert_eq!(rep.layer_profile, rep_expect.layer_profile);
    }
    let _ = std::fs::remove_dir_all(dir);
}

/// Tentpole acceptance: emit_families produces one certified family
/// per env from ONE capture, each manifest embedding its env; a fresh
/// session pinned to the second env then resumes every stage with
/// zero recomputation.
#[test]
fn emit_families_one_capture_many_envs() {
    let Some(engine) = engine() else { return };
    let model = "bert-syn-base";
    let task = "sst2-syn";
    let minfo = engine.manifest.model(model).clone();
    let tinfo = engine.manifest.task(model, task).clone();
    let ds = data::load_sized(&minfo, task, 64, 32);
    let teacher = ModelState::init(&minfo, task, &tinfo, 22);
    let env1 = toy_env(&engine, model);
    let env2 = other_env(&env1);
    let targets = [1.5, 2.5];
    let dir = temp_dir("families_session");
    let base = temp_dir("families_out");

    let sess = CompressionSession::for_model(&engine, model, task)
        .with_env(env1.clone())
        .with_targets(&targets)
        .with_prune_cfg(cfg())
        .checkpoint_to(&dir)
        .open()
        .unwrap();
    let envs = [env1.clone(), env2.clone()];
    let fams = sess.emit_families(&teacher, &ds, &envs, &base).unwrap();
    assert_eq!(fams.len(), 2);
    let (computed, _loaded) = sess.counters();
    // one capture + one database build + one profile per (env, target)
    assert_eq!(computed, 2 + envs.len() * targets.len(), "capture or databases ran twice");
    for (env, fam) in envs.iter().zip(&fams) {
        assert_eq!(fam.env.as_ref(), Some(env), "manifest embeds the wrong env");
        assert_eq!(fam.members.len(), 1 + targets.len());
        // manifest + member checkpoints landed under the env's slug dir
        let fdir = base.join(env_slug(env));
        let loaded = ziplm::models::family::FamilyManifest::load(&fdir.join("family.json"))
            .expect("family.json written");
        assert_eq!(&loaded, fam, "on-disk manifest differs (env JSON round-trip?)");
        assert!(loaded.load_states(&fdir).is_ok(), "member checkpoints missing");
    }
    drop(sess);

    // the proof of "one capture, N envs": a session pinned to env2
    // resumes capture, databases AND its first-target solve without
    // computing anything
    let sess2 = CompressionSession::for_model(&engine, model, task)
        .with_env(env2.clone())
        .with_targets(&targets)
        .with_prune_cfg(cfg())
        .checkpoint_to(&dir)
        .open()
        .unwrap();
    let solved = sess2.capture(&teacher, &ds).unwrap().build_dbs().unwrap();
    let solved = solved.solve(&ds, targets[0]).unwrap();
    let (c2, l2) = sess2.counters();
    assert_eq!(c2, 0, "second env recomputed {c2} artifact(s); expected zero");
    assert_eq!(l2, 3);
    // and its profile equals the family's certified member profile
    let fam2_member = &fams[1].members[1]; // dense is members[0]
    let layer_profile = &fam2_member.profile;
    let applied = solved.apply().unwrap();
    assert_eq!(&applied.report.layer_profile, layer_profile);
    let _ = std::fs::remove_dir_all(dir);
    let _ = std::fs::remove_dir_all(base);
}
