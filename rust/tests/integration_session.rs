//! CompressionSession integration: legacy-shim vs session equivalence
//! (the api_redesign acceptance test) and crash-resume behavior.
//! Skipped when artifacts/ is absent, like the other integration
//! suites; the engine-free resume mechanics are covered by the
//! `session::store` unit tests.

mod support;

use std::path::PathBuf;

use support::{engine, toy_env};
use ziplm::data;
use ziplm::env::InferenceEnv;
use ziplm::models::ModelState;
use ziplm::pruner::{PruneCfg, SpdyCfgLite};
use ziplm::session::CompressionSession;
use ziplm::train::TrainCfg;

fn temp_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("ziplm_itest_{tag}"));
    let _ = std::fs::remove_dir_all(&d);
    d
}

fn cfg() -> PruneCfg {
    PruneCfg { calib_samples: 16, spdy: SpdyCfgLite { iters: 4, seed: 5 }, ..Default::default() }
}

fn tcfg() -> TrainCfg {
    TrainCfg {
        lr: 5e-4,
        epochs: 0.25,
        lambdas: [1.0, 0.0, 0.0],
        weight_decay: 0.0,
        seed: 0,
        log_every: 0,
    }
}

/// Acceptance: a small seeded model driven through BOTH the legacy
/// free-function path (via the deprecated shims) and the
/// CompressionSession stage API must produce identical chosen
/// profiles, certified speedups, and emitted family manifests.
#[test]
#[allow(deprecated)]
fn legacy_shim_path_and_session_agree_exactly() {
    let Some(engine) = engine() else { return };
    let model = "bert-syn-base";
    let task = "sst2-syn";
    let minfo = engine.manifest.model(model).clone();
    let tinfo = engine.manifest.task(model, task).clone();
    let ds = data::load_sized(&minfo, task, 64, 32);
    let teacher = ModelState::init(&minfo, task, &tinfo, 12);
    let env = toy_env(&engine, model);
    let targets = [1.5, 2.5];

    // legacy: deprecated free-function shims
    let legacy = ziplm::pruner::gradual(
        &engine,
        teacher.clone(),
        &ds,
        &env,
        &targets,
        &cfg(),
        &tcfg(),
        None,
    )
    .unwrap();
    let legacy_dir = temp_dir("legacy_family");
    let legacy_fam =
        ziplm::session::pipeline::emit_family(&env, &teacher, &legacy, &legacy_dir).unwrap();

    // session: typed stage API (checkpointing off → pure compute path)
    let sess = CompressionSession::for_model(&engine, model, task)
        .with_env(env.clone())
        .with_targets(&targets)
        .with_prune_cfg(cfg())
        .with_train_cfg(tcfg())
        .open()
        .unwrap();
    let staged = sess.run(teacher.clone(), &ds).unwrap();
    let session_dir = temp_dir("session_family");
    let session_fam = sess.emit_family(&teacher, &staged, &session_dir).unwrap();

    assert_eq!(legacy.len(), staged.len());
    for (l, s) in legacy.iter().zip(&staged) {
        assert_eq!(l.report.layer_profile, s.report.layer_profile, "chosen profiles differ");
        assert_eq!(l.report.est_speedup, s.report.est_speedup, "certified speedups differ");
        assert_eq!(l.state.masks, s.state.masks, "masks differ");
        assert_eq!(l.state.params, s.state.params, "weights differ");
    }
    // identical manifests, byte for byte (ckpt names are relative)
    assert_eq!(
        legacy_fam.to_json().to_pretty(),
        session_fam.to_json().to_pretty(),
        "family manifests differ"
    );
    let _ = std::fs::remove_dir_all(legacy_dir);
    let _ = std::fs::remove_dir_all(session_dir);
}

/// A re-opened session over the same checkpoint directory must load
/// every completed stage instead of recomputing — asserted through the
/// session's (computed, loaded) counters and by output equality.
#[test]
fn session_resume_loads_checkpointed_stages() {
    let Some(engine) = engine() else { return };
    let model = "bert-syn-base";
    let task = "sst2-syn";
    let minfo = engine.manifest.model(model).clone();
    let tinfo = engine.manifest.task(model, task).clone();
    let ds = data::load_sized(&minfo, task, 64, 32);
    let teacher = ModelState::init(&minfo, task, &tinfo, 13);
    let env = toy_env(&engine, model);
    let dir = temp_dir("session_resume");

    let open = || {
        CompressionSession::for_model(&engine, model, task)
            .with_env(env.clone())
            .with_targets(&[1.5, 2.5])
            .with_prune_cfg(cfg())
            .with_train_cfg(tcfg())
            .checkpoint_to(&dir)
            .open()
            .unwrap()
    };

    let first = open();
    let stages1 = first.run(teacher.clone(), &ds).unwrap();
    let (computed1, loaded1) = first.counters();
    assert!(computed1 > 0, "first run computed nothing");
    assert_eq!(loaded1, 0, "first run on an empty dir loaded something");

    // "crash" and re-open: everything must come back from checkpoints
    drop(first);
    let second = open();
    let stages2 = second.run(teacher.clone(), &ds).unwrap();
    let (computed2, loaded2) = second.counters();
    assert_eq!(computed2, 0, "resume recomputed {computed2} stage(s)");
    assert!(loaded2 > 0, "resume loaded nothing");
    assert_eq!(stages1.len(), stages2.len());
    for (a, b) in stages1.iter().zip(&stages2) {
        assert_eq!(a.report.layer_profile, b.report.layer_profile);
        assert_eq!(a.report.est_speedup, b.report.est_speedup);
        assert_eq!(a.state.params, b.state.params);
        assert_eq!(a.state.masks, b.state.masks);
    }

    // a session dir is pinned to its env: resuming with a different
    // environment must be refused, not silently re-certified
    let mut t2 = env.table().clone();
    t2.overhead *= 2.0;
    let other = InferenceEnv::measured(t2).unwrap();
    let refused = CompressionSession::for_model(&engine, model, task)
        .with_env(other)
        .with_targets(&[1.5, 2.5])
        .with_prune_cfg(cfg())
        .checkpoint_to(&dir)
        .open();
    assert!(refused.is_err(), "resume against a different env was not refused");
    let _ = std::fs::remove_dir_all(dir);
}
