//! Differential-testing wall for the SIMD dispatch layer (DESIGN.md
//! §14): every (SIMD, scalar, reference) kernel triple over randomized
//! shapes, with explicit remainder-lane lengths, g=1 vs g>1, the exact
//! committed seeds from prior PRs' proptests re-run through the
//! dispatch layer, alive-set poison/bookkeeping invariants, and
//! thread-budget bit-identity for every threaded kernel.
//!
//! The contract under test is strict: a dispatch level or a thread
//! budget may change throughput, never bits. Comparisons here are
//! `to_bits` equality, not tolerances — tolerances are reserved for
//! the genuinely different reference formulations (`scores_ref`,
//! `update_ref`, `multi_update_ref`, `spd_inverse_ref`, naive GEMM).

#![allow(clippy::disallowed_methods)] // test code: unwrap-on-failure is fine

use ziplm::kernel::{use_compact_pass, with_level, AliveSet, Dispatch, Level};
use ziplm::spdy::{self, LevelOpt, ModuleLevels, SpdyProblem};
use ziplm::tensor::{linalg, Tensor};
use ziplm::util::prop::{gen, Prop};
use ziplm::util::rng::Rng;
use ziplm::util::threadpool::{parallel_tasks, with_thread_budget};
use ziplm::ziplm::{NativeBackend, ObsOps, BIG};

fn bits32(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

fn bits64(v: &[f64]) -> Vec<u64> {
    v.iter().map(|x| x.to_bits()).collect()
}

fn tbits(t: &Tensor) -> Vec<u32> {
    bits32(&t.data)
}

fn rel_close(a: f32, b: f32, tol: f32) -> bool {
    (a - b).abs() <= tol * a.abs().max(b.abs()).max(1.0)
}

/// Inject exact `+0.0` and `-0.0` entries: the dead-column case the
/// OBS passes lean on (and the one spot where a wrong negation idiom —
/// subtract-from-zero instead of XOR — would flip bits).
fn sprinkle_zeros(mut v: Vec<f32>) -> Vec<f32> {
    for i in (0..v.len()).step_by(5) {
        v[i] = 0.0;
    }
    for i in (2..v.len()).step_by(7) {
        v[i] = -0.0;
    }
    v
}

// ------------------------------------------------- primitive triples

/// Lengths covering every residue mod 4 (SSE2) and mod 8 (AVX2), the
/// empty slice, exact multiples, and one long vector.
const LENS: &[usize] = &[0, 1, 2, 3, 4, 5, 6, 7, 8, 9, 11, 12, 13, 15, 16, 17, 31, 32, 33, 100];

#[test]
fn primitives_bit_identical_across_levels_at_all_remainder_lengths() {
    let scalar = Dispatch::at(Level::Scalar);
    let mut r = Rng::new(0x5a1b_c0de);
    for &len in LENS {
        let x = sprinkle_zeros(gen::vec_f32(&mut r, len, 1.0));
        let d0 = sprinkle_zeros(gen::vec_f32(&mut r, len, 1.0));
        let b0 = gen::vec_f32(&mut r, len, 1.0);
        let b1 = sprinkle_zeros(gen::vec_f32(&mut r, len, 1.0));
        let b2 = gen::vec_f32(&mut r, len, 1.0);
        let b3 = gen::vec_f32(&mut r, len, 1.0);
        let c0: Vec<f64> = (0..len).map(|_| r.normal_f32(1.0) as f64).collect();
        let a = r.normal_f32(1.0);
        let q = [r.normal_f32(1.0), -0.0, r.normal_f32(1.0), 0.0];
        for &lvl in Level::available().iter().skip(1) {
            let kd = Dispatch::at(lvl);
            let mut want = d0.clone();
            let mut got = d0.clone();
            scalar.axpy(&mut want, a, &x);
            kd.axpy(&mut got, a, &x);
            assert_eq!(bits32(&got), bits32(&want), "axpy {lvl:?} len {len}");

            let mut want = d0.clone();
            let mut got = d0.clone();
            scalar.axpy_minus(&mut want, a, &x);
            kd.axpy_minus(&mut got, a, &x);
            assert_eq!(bits32(&got), bits32(&want), "axpy_minus {lvl:?} len {len}");

            let mut want = d0.clone();
            let mut got = d0.clone();
            scalar.scale(&mut want, a);
            kd.scale(&mut got, a);
            assert_eq!(bits32(&got), bits32(&want), "scale {lvl:?} len {len}");

            let mut want = c0.clone();
            let mut got = c0.clone();
            scalar.colsq_accum(&mut want, &x);
            kd.colsq_accum(&mut got, &x);
            assert_eq!(bits64(&got), bits64(&want), "colsq_accum {lvl:?} len {len}");

            let mut want = d0.clone();
            let mut wantc = c0.clone();
            let mut got = d0.clone();
            let mut gotc = c0.clone();
            scalar.axpy_minus_colsq(&mut want, a, &x, &mut wantc);
            kd.axpy_minus_colsq(&mut got, a, &x, &mut gotc);
            assert_eq!(bits32(&got), bits32(&want), "axpy_minus_colsq dst {lvl:?} len {len}");
            assert_eq!(bits64(&gotc), bits64(&wantc), "axpy_minus_colsq acc {lvl:?} len {len}");

            let mut want = d0.clone();
            let mut got = d0.clone();
            scalar.quad_axpy(&mut want, q, &b0, &b1, &b2, &b3);
            kd.quad_axpy(&mut got, q, &b0, &b1, &b2, &b3);
            assert_eq!(bits32(&got), bits32(&want), "quad_axpy {lvl:?} len {len}");
        }
    }
}

// -------------------------------------------------- SPD inverse triple

#[test]
fn spd_inverse_bit_identical_across_levels_incl_remainder_dims() {
    // Dims deliberately straddle the lane widths: n < lanes exercises
    // the padding lanes of a single remainder group, n ≡ 1..lane−1
    // (mod lanes) exercises the final partial group, and the larger
    // dims cover multiple full lane-blocks.
    let mut r = Rng::new(0x5a1b_c0de);
    for &n in &[1usize, 2, 3, 4, 5, 7, 8, 9, 12, 15, 16, 17, 25, 33, 40] {
        let a = Tensor::from_vec(&[n, n], gen::spd(&mut r, n, 0.5));
        let base = with_level(Level::Scalar, || linalg::spd_inverse(&a).unwrap());
        for lvl in Level::available() {
            let got = with_level(lvl, || linalg::spd_inverse(&a).unwrap());
            assert_eq!(tbits(&got), tbits(&base), "spd_inverse {lvl:?} n {n}");
        }
        let rf = linalg::spd_inverse_ref(&a).unwrap();
        let d = base.max_abs_diff(&rf);
        let tol = 1e-3 * (1.0 + n as f32 / 32.0);
        assert!(d <= tol, "spd_inverse vs ref n {n}: diff {d} tol {tol}");
    }
}

// ------------------------------------------------------- OBS triples

/// Random structured-OBS problem — the committed generator from the
/// proptests suite, reproduced verbatim so the DEFAULT_SEED + case
/// seeds regenerate the exact instances prior PRs certified.
fn random_obs_problem(r: &mut Rng, g: usize) -> (Tensor, Tensor, Vec<f32>) {
    let n = 3 + r.below(6);
    let d_row = 2 + r.below(8);
    let d_col = n * g;
    let w = Tensor::from_vec(&[d_row, d_col], gen::vec_f32(r, d_row * d_col, 1.0));
    let h = Tensor::from_vec(&[d_col, d_col], gen::spd(r, d_col, 0.4));
    let hinv = linalg::spd_inverse(&h).unwrap();
    let mut active = vec![1.0f32; n];
    for j in 0..n {
        if r.f64() < 0.2 {
            active[j] = 0.0;
        }
    }
    if !active.iter().any(|&a| a > 0.0) {
        active[r.below(n)] = 1.0;
    }
    (w, hinv, active)
}

#[test]
fn scores_triple_levels_bit_identical_and_match_ref_g1_g8() {
    for &g in &[1usize, 8] {
        Prop::new(20).check_msg(
            "dispatched scores: levels bit-identical, ref within 1e-4",
            |r| random_obs_problem(r, g),
            |(w, hinv, active)| {
                let mut ops = NativeBackend::new(g);
                let base = with_level(Level::Scalar, || ops.scores(w, hinv, active))
                    .map_err(|e| e.to_string())?;
                for lvl in Level::available() {
                    let got = with_level(lvl, || ops.scores(w, hinv, active))
                        .map_err(|e| e.to_string())?;
                    if bits32(&got) != bits32(&base) {
                        return Err(format!("g={g} level {lvl:?} diverged from scalar"));
                    }
                }
                let slow = ops.scores_ref(w, hinv, active).map_err(|e| e.to_string())?;
                for (j, (&f, &s)) in base.iter().zip(&slow).enumerate() {
                    if active[j] <= 0.0 {
                        if f < 1e29 || s < 1e29 {
                            return Err(format!("g={g} j={j}: inactive not BIG ({f} vs {s})"));
                        }
                    } else if !rel_close(f, s, 1e-4) {
                        return Err(format!("g={g} j={j}: fast {f} vs ref {s}"));
                    }
                }
                Ok(())
            },
        );
    }
}

#[test]
fn update_triple_levels_bit_identical_and_match_ref_g1_g8() {
    for &g in &[1usize, 8] {
        Prop::new(15).check_msg(
            "dispatched update: levels bit-identical, ref within 1e-4",
            |r| {
                let (w, hinv, active) = random_obs_problem(r, g);
                let n = active.len();
                let alive: Vec<usize> = (0..n).filter(|&j| active[j] > 0.0).collect();
                let idx = alive[r.below(alive.len())];
                (w, hinv, idx)
            },
            |(w, hinv, idx)| {
                let mut ops = NativeBackend::new(g);
                let (bw, bh) = with_level(Level::Scalar, || ops.update(w, hinv, *idx))
                    .map_err(|e| e.to_string())?;
                for lvl in Level::available() {
                    let (gw, gh) = with_level(lvl, || ops.update(w, hinv, *idx))
                        .map_err(|e| e.to_string())?;
                    if tbits(&gw) != tbits(&bw) || tbits(&gh) != tbits(&bh) {
                        return Err(format!("g={g} idx={idx} level {lvl:?} diverged"));
                    }
                }
                let (rw, rh) = ops.update_ref(w, hinv, *idx).map_err(|e| e.to_string())?;
                let (dw, dh) = (bw.max_abs_diff(&rw), bh.max_abs_diff(&rh));
                if dw > 1e-4 || dh > 1e-4 {
                    return Err(format!("g={g} idx={idx}: dW {dw} dH {dh}"));
                }
                Ok(())
            },
        );
    }
}

#[test]
fn multi_update_deep_ladder_triple_committed_seeds() {
    // The EXACT generator and seeds (DEFAULT_SEED + case) of PR 4's
    // committed deep-removal proptest, re-run through the dispatch
    // layer. The deep ladder starts dense and crosses the
    // use_compact_pass threshold mid-run, so one instance exercises
    // the dense SIMD pass, the compact alive-list pass, AND the
    // handoff between them — all of which must be invisible in bits.
    Prop::new(12).check_msg(
        "deep multi_update: levels bit-identical, ref within 1e-4",
        |r| {
            let n = 12 + r.below(13); // 12..=24 columns
            let d_row = 4 + r.below(13); // 4..=16 rows
            let w = Tensor::from_vec(&[d_row, n], gen::vec_f32(r, d_row * n, 1.0));
            let h = Tensor::from_vec(&[n, n], gen::spd(r, n, 0.4));
            let hinv = linalg::spd_inverse(&h).unwrap();
            let n_remove = n - 1 - r.below(3); // deep: 1..=3 survivors
            (w, hinv, n, n_remove)
        },
        |(w, hinv, _n, n_remove)| {
            let active = vec![1.0f32; w.cols()];
            let mut ops = NativeBackend::new(1);
            let (bw, bh, ba, bo) =
                with_level(Level::Scalar, || ops.multi_update(w, hinv, &active, *n_remove))
                    .map_err(|e| e.to_string())?;
            for lvl in Level::available() {
                let (gw, gh, ga, go) =
                    with_level(lvl, || ops.multi_update(w, hinv, &active, *n_remove))
                        .map_err(|e| e.to_string())?;
                if go != bo || ga != ba || tbits(&gw) != tbits(&bw) || tbits(&gh) != tbits(&bh) {
                    return Err(format!("level {lvl:?} diverged from scalar"));
                }
            }
            let (rw, rh, ra, ro) =
                ops.multi_update_ref(w, hinv, &active, *n_remove).map_err(|e| e.to_string())?;
            if bo != ro {
                let mut sf = bo.clone();
                let mut sr = ro.clone();
                sf.sort_unstable();
                sr.sort_unstable();
                if sf != sr {
                    return Err(format!("removed sets differ: {bo:?} vs {ro:?}"));
                }
            }
            if ba != ra {
                return Err("active mask mismatch".into());
            }
            let (dw, dh) = (bw.max_abs_diff(&rw), bh.max_abs_diff(&rh));
            if dw > 1e-4 || dh > 1e-4 {
                return Err(format!("dW {dw} dH {dh}"));
            }
            Ok(())
        },
    );
}

// ------------------------------------------------------- GEMM triple

fn matmul_naive(a: &Tensor, b: &Tensor) -> Tensor {
    let (m, k, n) = (a.rows(), a.cols(), b.cols());
    let mut c = Tensor::zeros(&[m, n]);
    for i in 0..m {
        for t in 0..k {
            let av = a.at2(i, t);
            for j in 0..n {
                c.data[i * n + j] += av * b.at2(t, j);
            }
        }
    }
    c
}

#[test]
fn matmul_bit_identical_across_levels_and_close_to_naive_ref() {
    let mut r = Rng::new(0x5a1b_c0de);
    let shapes = [(1usize, 1usize, 1usize), (3, 5, 7), (9, 17, 23), (33, 12, 65), (80, 70, 66)];
    for &(m, k, n) in &shapes {
        // zero quads exercise the structural-sparsity skip identically
        // at every level (the skip sits above the dispatch layer)
        let a = Tensor::from_vec(&[m, k], sprinkle_zeros(gen::vec_f32(&mut r, m * k, 1.0)));
        let b = Tensor::from_vec(&[k, n], gen::vec_f32(&mut r, k * n, 1.0));
        let base = with_level(Level::Scalar, || a.matmul(&b));
        for lvl in Level::available() {
            let got = with_level(lvl, || a.matmul(&b));
            assert_eq!(tbits(&got), tbits(&base), "matmul {lvl:?} {m}x{k}x{n}");
        }
        let naive = matmul_naive(&a, &b);
        let scale = naive.data.iter().fold(1.0f32, |mx, &v| mx.max(v.abs()));
        let d = base.max_abs_diff(&naive);
        assert!(d <= 1e-3 * scale, "matmul vs naive {m}x{k}x{n}: diff {d} scale {scale}");
    }
}

// ------------------------------------------- alive-set invariants

/// Poisoned clones of a clean (scrubbed) OBS instance: dead W columns
/// and dead Hinv rows/cols hold loud sentinels instead of the zeros a
/// real removal leaves behind. A pass that never reads dead entries
/// produces bit-identical alive outputs; one that never writes them
/// leaves every sentinel untouched.
const W_POISON: f32 = 7777.5;
const H_POISON: f32 = -3333.25;

struct PoisonCase {
    alive_idx: Vec<usize>,
    d_row: usize,
    d_col: usize,
    active: Vec<f32>,
    w_clean: Tensor,
    h_clean: Tensor,
    w_poison: Tensor,
    h_poison: Tensor,
}

fn poison_case(alive_idx: Vec<usize>, d_row: usize, d_col: usize) -> PoisonCase {
    let mut r = Rng::new(0x5a1b_c0de);
    let mut w_clean = Tensor::from_vec(&[d_row, d_col], gen::vec_f32(&mut r, d_row * d_col, 1.0));
    let h0 = Tensor::from_vec(&[d_col, d_col], gen::spd(&mut r, d_col, 0.4));
    let mut h_clean = linalg::spd_inverse(&h0).unwrap();
    let mut active = vec![0.0f32; d_col];
    for &j in &alive_idx {
        active[j] = 1.0;
    }
    // scrub dead structures exactly as a real removal would
    for j in 0..d_col {
        if active[j] > 0.0 {
            continue;
        }
        for i in 0..d_row {
            w_clean.data[i * d_col + j] = 0.0;
        }
        for k in 0..d_col {
            h_clean.data[j * d_col + k] = 0.0;
            h_clean.data[k * d_col + j] = 0.0;
        }
        h_clean.data[j * d_col + j] = 1.0;
    }
    let mut w_poison = w_clean.clone();
    let mut h_poison = h_clean.clone();
    for j in 0..d_col {
        if active[j] > 0.0 {
            continue;
        }
        for i in 0..d_row {
            w_poison.data[i * d_col + j] = W_POISON;
        }
        for k in 0..d_col {
            h_poison.data[j * d_col + k] = H_POISON;
            h_poison.data[k * d_col + j] = H_POISON;
        }
    }
    PoisonCase { alive_idx, d_row, d_col, active, w_clean, h_clean, w_poison, h_poison }
}

#[test]
fn scores_compact_pass_never_reads_poisoned_dead_columns() {
    let pc = poison_case(vec![0, 3, 5, 9, 12, 17, 21, 25, 28, 31], 9, 32);
    assert!(use_compact_pass(pc.alive_idx.len(), pc.d_col));
    let mut ops = NativeBackend::new(1);
    let base =
        with_level(Level::Scalar, || ops.scores(&pc.w_clean, &pc.h_clean, &pc.active)).unwrap();
    for lvl in Level::available() {
        for (tag, wv, hv) in
            [("clean", &pc.w_clean, &pc.h_clean), ("poisoned", &pc.w_poison, &pc.h_poison)]
        {
            let got = with_level(lvl, || ops.scores(wv, hv, &pc.active)).unwrap();
            assert_eq!(bits32(&got), bits32(&base), "scores {tag} {lvl:?}");
        }
    }
    for j in 0..pc.d_col {
        if !pc.alive_idx.contains(&j) {
            assert!(base[j] >= BIG, "dead structure {j} not BIG");
        }
    }
}

#[test]
fn multi_update_compact_ladder_never_touches_poisoned_dead_structures() {
    let pc = poison_case(vec![1, 2, 4, 7, 9, 13, 16, 18, 22, 25, 27, 30], 10, 32);
    // below half density from step 0, and the alive set only shrinks,
    // so the ENTIRE removal ladder runs the compact passes
    assert!(use_compact_pass(pc.alive_idx.len(), pc.d_col));
    let n_remove = pc.alive_idx.len() - 2;
    let is_alive = |j: usize| pc.alive_idx.contains(&j);
    let mut ops = NativeBackend::new(1);
    let (bw, bh, ba, bo) = with_level(Level::Scalar, || {
        ops.multi_update(&pc.w_clean, &pc.h_clean, &pc.active, n_remove)
    })
    .unwrap();
    for lvl in Level::available() {
        let (cw, ch, ca, co) =
            with_level(lvl, || ops.multi_update(&pc.w_clean, &pc.h_clean, &pc.active, n_remove))
                .unwrap();
        assert_eq!(co, bo, "clean order {lvl:?}");
        assert_eq!(ca, ba, "clean mask {lvl:?}");
        assert_eq!(tbits(&cw), tbits(&bw), "clean W {lvl:?}");
        assert_eq!(tbits(&ch), tbits(&bh), "clean H {lvl:?}");
        let (pw, ph, pa, po) =
            with_level(lvl, || ops.multi_update(&pc.w_poison, &pc.h_poison, &pc.active, n_remove))
                .unwrap();
        assert_eq!(po, bo, "poisoned order {lvl:?}");
        assert_eq!(pa, ba, "poisoned mask {lvl:?}");
        for i in 0..pc.d_row {
            for j in 0..pc.d_col {
                let got = pw.at2(i, j);
                if is_alive(j) {
                    let want = bw.at2(i, j);
                    assert_eq!(got.to_bits(), want.to_bits(), "W[{i},{j}] {lvl:?}");
                } else {
                    assert_eq!(got, W_POISON, "W sentinel overwritten at [{i},{j}] {lvl:?}");
                }
            }
        }
        for rr in 0..pc.d_col {
            for cc in 0..pc.d_col {
                let got = ph.at2(rr, cc);
                if is_alive(rr) && is_alive(cc) {
                    let want = bh.at2(rr, cc);
                    assert_eq!(got.to_bits(), want.to_bits(), "H[{rr},{cc}] {lvl:?}");
                } else {
                    assert_eq!(got, H_POISON, "H sentinel overwritten at [{rr},{cc}] {lvl:?}");
                }
            }
        }
    }
}

#[test]
fn alive_set_matches_set_difference_model() {
    // Compaction bookkeeping: after ANY removal sequence (including
    // misses and repeats) the alive list must equal the ascending
    // set-difference of the initial indices and the removed ones, and
    // contains/len/is_empty must agree with the model at every step.
    Prop::new(150).check_msg(
        "AliveSet ≡ ascending set difference",
        |r| {
            let n = 1 + r.below(64);
            let mask: Vec<f32> =
                (0..n).map(|_| if r.f64() < 0.3 { 0.0 } else { 1.0 }).collect();
            let ops: Vec<usize> = (0..r.below(2 * n)).map(|_| r.below(n + 4)).collect();
            (mask, ops)
        },
        |(mask, ops)| {
            let n = mask.len();
            let mut set = AliveSet::from_active(mask);
            let mut model: Vec<usize> = (0..n).filter(|&j| mask[j] > 0.0).collect();
            if set.as_slice() != &model[..] {
                return Err(format!("init: {:?} vs {model:?}", set.as_slice()));
            }
            for &j in ops {
                let pos = model.iter().position(|&x| x == j);
                if set.remove(j) != pos.is_some() {
                    return Err(format!("remove({j}) presence mismatch"));
                }
                if let Some(p) = pos {
                    model.remove(p);
                }
                if set.as_slice() != &model[..] {
                    return Err(format!("after remove({j}): {:?} vs {model:?}", set.as_slice()));
                }
                if set.len() != model.len() || set.is_empty() != model.is_empty() {
                    return Err("len/is_empty disagree with model".into());
                }
                for probe in 0..n + 4 {
                    if set.contains(probe) != model.contains(&probe) {
                        return Err(format!("contains({probe}) disagrees"));
                    }
                }
            }
            Ok(())
        },
    );
}

// --------------------------------------------- thread determinism

/// Random SPDY problem with up to ~40 levels per module: enough levels
/// drop `solve_dp`'s per-chunk target below the 769-bucket row, so the
/// bucket sweep genuinely spawns at budget ≥ 2 (few-level toys stay
/// inline — both shapes are covered).
fn random_dp_problem(r: &mut Rng) -> (SpdyProblem, Vec<f64>, f64) {
    let nm = 1 + r.below(4);
    let mut modules = Vec::new();
    for l in 0..nm {
        let n_levels = 2 + r.below(40);
        let dense_cost = 0.5 + r.f64() * 9.5;
        let mut options = Vec::new();
        for k in 0..n_levels {
            let frac = 1.0 - k as f64 / (n_levels - 1) as f64;
            options.push(LevelOpt {
                remaining: (frac * 8.0) as usize,
                cost: dense_cost * frac * (0.5 + r.f64()),
                prior: (1.0 - frac) * (0.5 + r.f64()),
            });
        }
        options[0].cost = dense_cost;
        options[0].prior = 0.0;
        modules.push(ModuleLevels { layer: l, is_attn: l % 2 == 0, options });
    }
    let p = SpdyProblem { modules, overhead: r.f64() };
    let budget = p.overhead + (p.dense_cost() - p.overhead) * (0.1 + 0.9 * r.f64());
    let coeffs: Vec<f64> = (0..nm).map(|_| 0.1 + 2.0 * r.f64()).collect();
    (p, coeffs, budget)
}

#[test]
fn solve_dp_bit_identical_across_thread_budgets_and_nested() {
    Prop::new(30).check_msg(
        "solve_dp invariant under thread budget",
        random_dp_problem,
        |(p, coeffs, budget)| {
            let base = with_thread_budget(1, || spdy::solve_dp(p, coeffs, *budget));
            for b in [2usize, 8] {
                let got = with_thread_budget(b, || spdy::solve_dp(p, coeffs, *budget));
                if got != base {
                    return Err(format!("budget {b}: {got:?} vs {base:?}"));
                }
            }
            // inside an already-parallel region the sweep must
            // degenerate to the inline loop — with identical output
            let nested =
                with_thread_budget(2, || parallel_tasks(2, |_| spdy::solve_dp(p, coeffs, *budget)));
            for got in nested {
                if got != base {
                    return Err(format!("nested: {got:?} vs {base:?}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn threaded_kernels_bit_identical_across_budgets_and_nested() {
    let mut r = Rng::new(0x5a1b_c0de);
    // GEMM above its 64³ parallel gate
    let a = Tensor::from_vec(&[80, 70], gen::vec_f32(&mut r, 80 * 70, 1.0));
    let b = Tensor::from_vec(&[70, 66], gen::vec_f32(&mut r, 70 * 66, 1.0));
    let base_mm = with_thread_budget(1, || a.matmul(&b));
    // SPD inverse above its column-sweep chunk gate
    let h = Tensor::from_vec(&[120, 120], gen::spd(&mut r, 120, 0.5));
    let base_spd = with_thread_budget(1, || linalg::spd_inverse(&h).unwrap());
    // g>1 score sweep above its ~64k-flop chunk gate
    let (g, n, d_row) = (8usize, 16usize, 96usize);
    let d_col = n * g;
    let w = Tensor::from_vec(&[d_row, d_col], gen::vec_f32(&mut r, d_row * d_col, 1.0));
    let hs = Tensor::from_vec(&[d_col, d_col], gen::spd(&mut r, d_col, 0.4));
    let hinv = linalg::spd_inverse(&hs).unwrap();
    let active = vec![1.0f32; n];
    let mut ops = NativeBackend::new(g);
    let base_sc = with_thread_budget(1, || ops.scores(&w, &hinv, &active).unwrap());

    for budget in [2usize, 8] {
        let mm = with_thread_budget(budget, || a.matmul(&b));
        assert_eq!(tbits(&mm), tbits(&base_mm), "matmul budget {budget}");
        let spd = with_thread_budget(budget, || linalg::spd_inverse(&h).unwrap());
        assert_eq!(tbits(&spd), tbits(&base_spd), "spd_inverse budget {budget}");
        let sc = with_thread_budget(budget, || ops.scores(&w, &hinv, &active).unwrap());
        assert_eq!(bits32(&sc), bits32(&base_sc), "scores budget {budget}");
    }
    // dispatch level × thread budget: the forced level must reach the
    // workers (kernels capture their Dispatch before spawning), and
    // every (level, budget) cell must reproduce the scalar/serial bits
    for lvl in Level::available() {
        let mm = with_level(lvl, || with_thread_budget(2, || a.matmul(&b)));
        assert_eq!(tbits(&mm), tbits(&base_mm), "matmul {lvl:?} budget 2");
        let spd = with_level(lvl, || with_thread_budget(2, || linalg::spd_inverse(&h).unwrap()));
        assert_eq!(tbits(&spd), tbits(&base_spd), "spd_inverse {lvl:?} budget 2");
    }
    // inside an already-parallel region: leaf workers run the kernels
    // inline, and the bits still cannot move
    let nested_mm = with_thread_budget(2, || parallel_tasks(2, |_| a.matmul(&b)));
    for mm in nested_mm {
        assert_eq!(tbits(&mm), tbits(&base_mm), "nested matmul");
    }
    let nested_spd =
        with_thread_budget(2, || parallel_tasks(2, |_| linalg::spd_inverse(&h).unwrap()));
    for spd in nested_spd {
        assert_eq!(tbits(&spd), tbits(&base_spd), "nested spd_inverse");
    }
    let nested_sc = with_thread_budget(2, || {
        parallel_tasks(2, |_| {
            let mut o = NativeBackend::new(g);
            o.scores(&w, &hinv, &active).unwrap()
        })
    });
    for sc in nested_sc {
        assert_eq!(bits32(&sc), bits32(&base_sc), "nested scores");
    }
}
