//! End-to-end adapt loop (DESIGN.md §12): seeded replay telemetry →
//! drift verdict → fitted env → frontier targets — fully engine-free —
//! plus an engine-gated retarget leg proving the fitted env re-prices
//! the session's CHECKPOINTED databases (zero Hessian recomputation,
//! asserted through the session's computed/loaded counters).

#![allow(clippy::disallowed_methods)] // test code: unwrap-on-failure is fine

mod support;

use std::time::Duration;

use support::{cfg, engine, fleet_env, temp_dir, toy_env};
use ziplm::adapt::{AdaptController, AdaptPlan, DriftCfg};
use ziplm::coordinator::chaos::TraceItem;
use ziplm::coordinator::family::{BucketLadder, BucketSample, MemberRoute};
use ziplm::coordinator::replay::{replay_samples, ReplayCfg};
use ziplm::data;
use ziplm::env::{CostModel, InferenceEnv};
use ziplm::models::family::{FamilyManifest, FamilyMember};
use ziplm::models::ModelState;
use ziplm::session::CompressionSession;
use ziplm::util::json::Json;

/// Price a three-member ladder against `env` exactly like the serving
/// path does at startup (`est_speedup` from the table, per-bucket
/// batch estimates from [`InferenceEnv::batch_time`]).
fn member_routes(env: &InferenceEnv, n_layers: usize) -> Vec<MemberRoute> {
    let profiles: [(&str, Vec<(usize, usize)>); 3] = [
        ("dense", vec![(4, 512); n_layers]),
        ("2x", vec![(2, 256); n_layers]),
        ("4x", vec![(1, 64); n_layers]),
    ];
    let dense = env.model_time(&profiles[0].1);
    let ladder = env.bucket_ladder();
    let mut routes: Vec<MemberRoute> = profiles
        .iter()
        .map(|(tag, p)| MemberRoute {
            tag: (*tag).into(),
            est_speedup: dense / env.model_time(p),
            est_batch_time: env.model_time(p),
            bucket_times: ladder.iter().map(|&(b, s)| ((b, s), env.batch_time(p, b, s))).collect(),
        })
        .collect();
    routes.sort_by(|a, b| a.est_speedup.total_cmp(&b.est_speedup));
    routes
}

/// A certified manifest over `routes` with a monotone loss ladder —
/// the frontier input `emit_families` would have written.
fn manifest(env: &InferenceEnv, routes: &[MemberRoute]) -> FamilyManifest {
    let mut fam = FamilyManifest::new("m", "t", "throughput");
    fam.env = Some(env.clone());
    fam.members = routes
        .iter()
        .map(|r| FamilyMember {
            tag: r.tag.clone(),
            ckpt: String::new(),
            target: 1.0,
            est_speedup: r.est_speedup,
            profile: vec![],
            choices: None,
            calib_loss: Some(0.3 * (r.est_speedup - 1.0).max(0.0)),
        })
        .collect();
    fam
}

/// Tentpole acceptance, engine-free: replaying short-sequence traffic
/// through a certified family must flag mass-driven drift, fit an env
/// anchored on the observed shape, and recommend frontier targets —
/// bit-identically across runs.
#[test]
fn replayed_drift_fits_env_and_recommends_targets() {
    let env = fleet_env(); // anchor (8, 64), seq sweep 16/32/64
    let n_layers = 2;
    let routes = member_routes(&env, n_layers);
    let ladder = BucketLadder::new(env.bucket_ladder());
    let fam = manifest(&env, &routes);

    // 48 requests, every one at a quarter of the certified anchor seq
    let trace: Vec<TraceItem> =
        (0..48).map(|_| TraceItem { ids: vec![1; 12], sla: None }).collect();
    let rcfg = ReplayCfg { max_batch: 4, jitter: 0.1, seed: 7, fallback_shape: env.batch_shape() };

    let run = || {
        let samples = replay_samples(&trace, &routes, &ladder, &rcfg);
        let plan =
            AdaptController::default().plan(&samples, &env, std::slice::from_ref(&fam)).unwrap();
        (samples, plan)
    };
    let (samples, plan) = run();

    assert!(!samples.is_empty());
    assert!(samples.iter().all(|s| s.seq == 16), "short traffic must bucket at (8, 16)");
    let tol = DriftCfg::default();
    let drift = &plan.drift;
    assert_eq!(drift.requests, 48);
    assert!(drift.mass_shift > tol.mass_shift_tol, "mass shift: {}", drift.mass_shift);
    assert!(
        drift.latency_drift < tol.latency_ratio_tol,
        "jitter alone must not flag latency: {}",
        drift.latency_drift
    );
    assert!(drift.drifted);

    let fitted = plan.fitted.as_ref().expect("drifted plan fits an env");
    assert_eq!(fitted.batch_shape(), (8, 16), "fitted anchor must follow the observed mass");
    assert!(
        fitted.dense_time(n_layers) < env.dense_time(n_layers),
        "a quarter-seq anchor must price cheaper than the certified one"
    );

    assert_eq!(plan.action(), "retarget");
    assert!(plan.knee.is_some(), "a 3-member frontier has a knee");
    assert!(!plan.targets.is_empty());
    assert!(plan.targets.windows(2).all(|w| w[0] < w[1]), "targets sorted + deduped");

    // pure: a second run from the same inputs is bit-identical
    let (samples2, plan2) = run();
    assert_eq!(samples, samples2);
    assert_eq!(plan, plan2);

    // and the full plan round-trips through its JSON form (the file
    // `ziplm adapt` hands to `prune-gradual --retarget`)
    let text = plan.to_json().to_pretty();
    let back = AdaptPlan::from_json(&Json::parse(&text).unwrap()).unwrap();
    assert_eq!(back, plan);
}

/// Engine-gated acceptance: applying an [`AdaptPlan`] to a
/// checkpointed session swaps it onto the fitted env and the next
/// solve computes exactly ONE artifact (the new profile) — the
/// capture and Hessian databases are LOADED, never recomputed.
#[test]
fn adapt_plan_retargets_session_without_hessian_recompute() {
    let Some(engine) = engine() else { return };
    let model = "bert-syn-base";
    let task = "sst2-syn";
    let minfo = engine.manifest.model(model).clone();
    let tinfo = engine.manifest.task(model, task).clone();
    let ds = data::load_sized(&minfo, task, 64, 32);
    let teacher = ModelState::init(&minfo, task, &tinfo, 31);
    let env1 = toy_env(&engine, model);
    let target = 1.5;
    let dir = temp_dir("adapt_loop");

    let open = |env: &InferenceEnv| {
        CompressionSession::for_model(&engine, model, task)
            .with_env(env.clone())
            .with_prune_cfg(cfg())
            .checkpoint_to(&dir)
            .open()
            .unwrap()
    };

    // 1. certify against env1 (capture + databases land on disk)
    let sess1 = open(&env1);
    let mut s1 = teacher.clone();
    sess1.oneshot(&mut s1, &ds, target).unwrap();
    drop(sess1);

    // 2. telemetry says the device runs 40% hotter than certified:
    //    uniform latency drift, no shape shift (the toy env is
    //    anchorless, so only the ratio test can fire)
    let certified = 8e-3;
    let samples: Vec<BucketSample> = (0..8)
        .map(|_| BucketSample {
            member: "dense".into(),
            batch: 4,
            seq: 32,
            specialized: false,
            exec: Duration::from_secs_f64(certified * 1.4),
            requests: 4,
            certified,
        })
        .collect();

    let mut sess = open(&env1);
    let ctl = AdaptController::default();
    let plan = ctl.plan(&samples, sess.env(), &[]).unwrap();
    assert!(plan.drift.drifted, "a 40% overrun must flag: {:?}", plan.drift);
    assert!(plan.drift.latency_drift > DriftCfg::default().latency_ratio_tol);
    assert!(plan.fitted.is_some(), "a drifted plan must carry a fitted env");
    assert_eq!(plan.action(), "retarget");

    // 3. applying the plan swaps the session onto the fitted env ...
    assert!(ctl.apply(&plan, &mut sess).unwrap(), "plan must retarget");
    assert_eq!(Some(sess.env()), plan.fitted.as_ref());

    // 4. ... and the next solve re-prices the checkpointed databases:
    //    exactly one artifact computed, zero Hessian recomputation
    let mut s2 = teacher.clone();
    let rep = sess.oneshot(&mut s2, &ds, target).unwrap();
    let (computed, loaded) = sess.counters();
    assert_eq!(computed, 1, "retarget recomputed {computed} artifact(s); want 1 (the profile)");
    assert_eq!(loaded, 2, "capture + hessian databases must LOAD, loaded {loaded}");
    assert!(rep.est_speedup > 1.0, "fitted-env solve produced no speedup");
    let _ = std::fs::remove_dir_all(dir);
}
