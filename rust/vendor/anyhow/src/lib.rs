//! Vendored, minimal `anyhow` stand-in so the workspace builds fully
//! offline (crates.io is unreachable in the build environment).
//!
//! Implements exactly the surface the ziplm crate uses: [`Error`],
//! [`Result`], the [`anyhow!`] macro, and [`Context::with_context`] /
//! [`Context::context`] on `Result`. Like the real crate, `Error`
//! deliberately does NOT implement `std::error::Error`, which is what
//! makes the blanket `From<E: std::error::Error>` impl possible.

use std::fmt;

/// A string-backed error chain: context frames are joined with ": ".
pub struct Error {
    msg: String,
}

impl Error {
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { msg: m.to_string() }
    }

    /// Prepend a context frame (mirrors anyhow's `Context` output).
    pub fn context<C: fmt::Display>(self, c: C) -> Error {
        Error { msg: format!("{c}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error { msg: e.to_string() }
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach lazy or eager context to a failing `Result`.
pub trait Context<T> {
    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display,
        F: FnOnce() -> C;
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display,
        F: FnOnce() -> C,
    {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }

    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{c}: {e}")))
    }
}

/// `anyhow!("...")` with full `format!` syntax; a single non-literal
/// expression is taken by `Display` (e.g. `anyhow!(err_string)`).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// `bail!(...)` = `return Err(anyhow!(...))`.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<String> {
        let s = std::fs::read_to_string("/definitely/not/a/file")
            .with_context(|| "reading config".to_string())?;
        Ok(s)
    }

    #[test]
    fn question_mark_converts_std_errors() {
        let e = io_fail().unwrap_err();
        assert!(e.to_string().starts_with("reading config: "));
    }

    #[test]
    fn anyhow_macro_formats() {
        let e: Error = anyhow!("bad value {} at {}", 3, "layer");
        assert_eq!(e.to_string(), "bad value 3 at layer");
        let inline = 7;
        assert_eq!(anyhow!("x={inline}").to_string(), "x=7");
        let s = String::from("plain");
        assert_eq!(anyhow!(s).to_string(), "plain");
    }
}
