//! Vendored host-side stub of the `xla-rs` subset the ziplm runtime
//! uses, so the crate builds and tests offline without the real PJRT
//! C-API bindings.
//!
//! The [`Literal`] half is fully functional (shape/dtype-checked host
//! tensors): literal construction, reshape and readback behave like the
//! real crate, which keeps the runtime's literal round-trip helpers
//! testable. The device half ([`PjRtClient`], [`PjRtLoadedExecutable`])
//! is a stub: `PjRtClient::cpu()` returns an error, so any path that
//! needs compiled artifacts fails with a clear message instead of
//! crashing — and all artifact-dependent tests/benches already skip
//! when `artifacts/` is absent. Swap this path dependency for the real
//! `xla` bindings to run the compiled HLO paths.

use std::fmt;
use std::path::Path;

// ------------------------------------------------------------------ error

#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla: {}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

const STUB_MSG: &str =
    "PJRT unavailable: built with the vendored host-only xla stub (rust/vendor/xla); \
     point the `xla` dependency at the real bindings to execute artifacts";

// ---------------------------------------------------------------- literal

/// Element types the coordinator exchanges with artifacts.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

#[derive(Debug, Clone, PartialEq)]
enum Payload {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

/// Host tensor: row-major payload + logical dims (empty dims = scalar).
#[derive(Debug, Clone, PartialEq)]
pub struct Literal {
    dims: Vec<i64>,
    payload: Payload,
}

/// Sealed-ish element trait for [`Literal::vec1`] / [`Literal::to_vec`].
pub trait NativeType: Copy {
    const DTYPE: DType;
    fn wrap(v: Vec<Self>) -> Payload;
    fn unwrap(p: &Payload) -> Option<Vec<Self>>;
}

impl NativeType for f32 {
    const DTYPE: DType = DType::F32;
    fn wrap(v: Vec<Self>) -> Payload {
        Payload::F32(v)
    }
    fn unwrap(p: &Payload) -> Option<Vec<Self>> {
        match p {
            Payload::F32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

impl NativeType for i32 {
    const DTYPE: DType = DType::I32;
    fn wrap(v: Vec<Self>) -> Payload {
        Payload::I32(v)
    }
    fn unwrap(p: &Payload) -> Option<Vec<Self>> {
        match p {
            Payload::I32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

impl Literal {
    /// 1-D literal from a host slice.
    pub fn vec1<T: NativeType>(v: &[T]) -> Literal {
        Literal { dims: vec![v.len() as i64], payload: T::wrap(v.to_vec()) }
    }

    pub fn dtype(&self) -> DType {
        match self.payload {
            Payload::F32(_) => DType::F32,
            Payload::I32(_) => DType::I32,
        }
    }

    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    pub fn element_count(&self) -> usize {
        match &self.payload {
            Payload::F32(v) => v.len(),
            Payload::I32(v) => v.len(),
        }
    }

    /// Reinterpret the payload under new dims (size-checked; `&[]` is a
    /// scalar of one element, matching the real crate).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let want: i64 = dims.iter().product();
        if want as usize != self.element_count() {
            return Err(Error(format!(
                "reshape {:?} -> {dims:?}: element count {} != {want}",
                self.dims,
                self.element_count()
            )));
        }
        Ok(Literal { dims: dims.to_vec(), payload: self.payload.clone() })
    }

    /// Read the payload back as a host vector (dtype-checked).
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::unwrap(&self.payload)
            .ok_or_else(|| Error(format!("to_vec: literal is {:?}, not {:?}", self.dtype(), T::DTYPE)))
    }

    /// Decompose a tuple literal. The stub never produces tuples (they
    /// only come back from device execution), so this always errors.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(Error(STUB_MSG.into()))
    }
}

// ------------------------------------------------------------------ hlo

/// Parsed HLO module handle. The stub only records the source path.
#[derive(Debug, Clone)]
pub struct HloModuleProto {
    pub path: String,
}

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(path: P) -> Result<HloModuleProto> {
        let p = path.as_ref();
        if !p.exists() {
            return Err(Error(format!("no such HLO file {p:?}")));
        }
        Ok(HloModuleProto { path: p.display().to_string() })
    }
}

#[derive(Debug, Clone)]
pub struct XlaComputation {
    pub source: String,
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { source: proto.path.clone() }
    }
}

// ----------------------------------------------------------------- pjrt

/// Device client stub: construction fails so callers degrade cleanly.
#[derive(Debug)]
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error(STUB_MSG.into()))
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error(STUB_MSG.into()))
    }
}

#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<T: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[T],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error(STUB_MSG.into()))
    }
}

#[derive(Debug)]
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error(STUB_MSG.into()))
    }
}

#[cfg(test)]
#[allow(clippy::disallowed_methods)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        let m = l.reshape(&[2, 2]).unwrap();
        assert_eq!(m.dims(), &[2, 2]);
        assert_eq!(m.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn literal_scalar_and_dtype_checks() {
        let s = Literal::vec1(&[7i32]).reshape(&[]).unwrap();
        assert_eq!(s.dims(), &[] as &[i64]);
        assert!(s.to_vec::<f32>().is_err());
        assert_eq!(s.to_vec::<i32>().unwrap(), vec![7]);
        assert!(Literal::vec1(&[1.0f32]).reshape(&[3]).is_err());
    }

    #[test]
    fn device_paths_fail_loudly() {
        let e = PjRtClient::cpu().unwrap_err();
        assert!(e.to_string().contains("stub"));
    }
}
