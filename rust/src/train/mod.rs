//! Fine-tuning driver: runs the fused `train_step` HLO (fwd+bwd+AdamW in
//! one executable) with the paper's distillation objective (Eq. 5):
//!
//!   L = λ1·L_task + λ2·KL(teacher‖student logits) + λ3·L_token
//!
//! The teacher is the dense checkpoint; its logits + per-layer hidden
//! states are produced by the `teacher_fwd` artifact per batch and fed
//! into the student step. λ = (1,0,0) routes through the `_nokd`
//! executable so the teacher terms are absent from the graph entirely
//! (GPT setting, App. I; ablations, Table 5).

use anyhow::Result;

use crate::data::{Batcher, Dataset};
use crate::eval::mask_literals;
use crate::models::ModelState;
use crate::runtime::{lit_f32_shaped, lit_i32, lit_scalar_f32, lit_to_f32, Engine};

#[derive(Clone, Debug)]
pub struct TrainCfg {
    pub lr: f64,
    pub weight_decay: f64,
    /// (λ_task, λ_logit, λ_token) — Eq. 5
    pub lambdas: [f32; 3],
    pub epochs: f64,
    pub seed: u64,
    pub log_every: usize,
}

impl Default for TrainCfg {
    fn default() -> Self {
        TrainCfg {
            lr: 1e-3,
            weight_decay: 0.01,
            lambdas: [1.0, 0.5, 0.5],
            epochs: 1.0,
            seed: 0,
            log_every: 0,
        }
    }
}

impl TrainCfg {
    pub fn kd_enabled(&self) -> bool {
        self.lambdas[1] > 0.0 || self.lambdas[2] > 0.0
    }
}

/// Adam state + step counter, persisted across pruning stages.
pub struct Trainer<'e> {
    engine: &'e Engine,
    pub m: Vec<f32>,
    pub v: Vec<f32>,
    pub step: usize,
    /// dense teacher parameters (packed), if distillation is used
    pub teacher: Option<Vec<f32>>,
}

impl<'e> Trainer<'e> {
    pub fn new(engine: &'e Engine, n_params: usize, teacher: Option<Vec<f32>>) -> Trainer<'e> {
        Trainer { engine, m: vec![0.0; n_params], v: vec![0.0; n_params], step: 0, teacher }
    }

    pub fn reset_moments(&mut self) {
        self.m.iter_mut().for_each(|x| *x = 0.0);
        self.v.iter_mut().for_each(|x| *x = 0.0);
        self.step = 0;
    }

    /// Train for cfg.epochs over `data.train`; linear LR decay across
    /// the whole run. Returns mean task loss of the final 10 steps.
    pub fn train(&mut self, state: &mut ModelState, data: &Dataset, cfg: &TrainCfg) -> Result<f64> {
        let b = self.engine.manifest.batch_train;
        let tinfo = self.engine.manifest.task(&state.model, &state.task).clone();
        let minfo = self.engine.manifest.model(&state.model).clone();
        let kd = cfg.kd_enabled() && self.teacher.is_some();
        let art = if kd {
            format!("{}__{}__train_step", state.model, state.task)
        } else {
            format!("{}__{}__train_step_nokd", state.model, state.task)
        };
        let teach_art = format!("{}__{}__teacher_fwd", state.model, state.task);
        let total_steps = ((data.train.len() as f64 * cfg.epochs) / b as f64).ceil() as usize;
        let mut batcher = Batcher::new(data.train.len(), b, cfg.seed);
        let (hm, fm) = mask_literals(state)?;
        let pad = lit_f32_shaped(&[b, data.seq_len], &vec![1.0f32; b * data.seq_len])?;
        let lam = lit_f32_shaped(&[3], &cfg.lambdas)?;
        let teacher_params = match (&self.teacher, kd) {
            (Some(t), true) => Some(lit_f32_shaped(&[tinfo.n_params], t)?),
            _ => None,
        };
        let mut tail_losses = Vec::new();
        for s in 0..total_steps {
            self.step += 1;
            let lr_now = cfg.lr * (1.0 - s as f64 / total_steps.max(1) as f64).max(0.05);
            let idxs = batcher.next();
            let (ids, labels) = data.batch(&idxs);
            let ids_l = lit_i32(&[b, data.seq_len], &ids)?;
            let labels_l = if data.kind == "lm" {
                lit_i32(&[b, data.seq_len], &labels)?
            } else {
                lit_i32(&[b], &labels)?
            };
            let params_l = lit_f32_shaped(&[tinfo.n_params], &state.params)?;
            let m_l = lit_f32_shaped(&[tinfo.n_params], &self.m)?;
            let v_l = lit_f32_shaped(&[tinfo.n_params], &self.v)?;
            let t_l = lit_scalar_f32(self.step as f32)?;
            let lr_l = lit_scalar_f32(lr_now as f32)?;
            let wd_l = lit_scalar_f32(cfg.weight_decay as f32)?;
            let out = if kd {
                let tp = teacher_params.as_ref().unwrap();
                let tout = self.engine.run(&teach_art, &[tp.clone(), ids_l.clone()])?;
                // tout = (logits, hiddens)
                self.engine.run(
                    &art,
                    &[
                        params_l, m_l, v_l, t_l, lr_l, ids_l, labels_l,
                        hm.clone(), fm.clone(),
                        tout[0].clone(), tout[1].clone(), pad.clone(), lam.clone(), wd_l,
                    ],
                )?
            } else {
                self.engine.run(
                    &art,
                    &[params_l, m_l, v_l, t_l, lr_l, ids_l, labels_l, hm.clone(), fm.clone(), wd_l],
                )?
            };
            state.params = lit_to_f32(&out[0])?;
            self.m = lit_to_f32(&out[1])?;
            self.v = lit_to_f32(&out[2])?;
            let task_loss = lit_to_f32(&out[3])?[0];
            if tail_losses.len() >= 10 {
                tail_losses.remove(0);
            }
            tail_losses.push(task_loss as f64);
            if cfg.log_every > 0 && s % cfg.log_every == 0 {
                crate::zlog!(
                    "info",
                    "train[{}/{}] step={} lr={:.2e} task_loss={:.4}",
                    s,
                    total_steps,
                    self.step,
                    lr_now,
                    task_loss
                );
            }
        }
        // Masked structures must stay dead: the optimizer nudges them
        // via weight decay/moments only when masks are 1, and the graph
        // multiplies activations by the mask — but we re-zero weights of
        // dead structures for checkpoint hygiene.
        rezero_dead(state, &tinfo, &minfo);
        Ok(tail_losses.iter().sum::<f64>() / tail_losses.len().max(1) as f64)
    }
}

/// Zero out parameters of pruned structures (they receive no gradient
/// through the masked graph, but Adam moments / weight decay could
/// still drift them).
pub fn rezero_dead(
    state: &mut ModelState,
    tinfo: &crate::runtime::TaskInfo,
    minfo: &crate::runtime::ModelInfo,
) {
    let masks = state.masks.clone();
    for l in 0..masks.n_layers {
        let dead_heads: Vec<usize> = (0..masks.n_heads)
            .filter(|&h| masks.head_row(l)[h] == 0.0)
            .collect();
        if !dead_heads.is_empty() {
            if let Ok(mut w) = state.attn_w_paper(tinfo, l) {
                let cols = w.cols();
                for &h in &dead_heads {
                    for r in 0..w.rows() {
                        for c in h * minfo.d_head..(h + 1) * minfo.d_head {
                            w.data[r * cols + c] = 0.0;
                        }
                    }
                }
                let _ = state.set_attn_w_paper(tinfo, l, &w, &dead_heads, minfo.d_head);
            }
        }
        let dead_cols: Vec<usize> = (0..masks.d_ff)
            .filter(|&c| masks.ffn_row(l)[c] == 0.0)
            .collect();
        if !dead_cols.is_empty() {
            if let Ok(mut w) = state.fc_w_paper(tinfo, l) {
                let cols = w.cols();
                for &c in &dead_cols {
                    for r in 0..w.rows() {
                        w.data[r * cols + c] = 0.0;
                    }
                }
                let _ = state.set_fc_w_paper(tinfo, l, &w, &dead_cols);
            }
        }
    }
}
