//! Evaluation: accuracy (cls), exact-match (span, the squad-syn "F1"),
//! and perplexity (lm), all computed from the masked `fwd` / `eval_loss`
//! artifacts. Argmax/aggregation happen here in Rust — no sort/top-k
//! ops exist in the lowered graphs.

use anyhow::Result;

use crate::data::{Dataset, Example};
use crate::models::ModelState;
use crate::runtime::{lit_f32_shaped, lit_i32, lit_to_f32, Engine};

#[derive(Clone, Debug)]
pub struct EvalResult {
    /// accuracy / EM in [0,1] for cls+span; for lm this is exp(-loss)
    /// (inverse perplexity) so "higher = better" holds everywhere.
    pub metric: f64,
    pub loss: f64,
    pub perplexity: Option<f64>,
    pub n: usize,
}

pub fn mask_literals(state: &ModelState) -> Result<(xla::Literal, xla::Literal)> {
    let m = &state.masks;
    Ok((
        lit_f32_shaped(&[m.n_layers, m.n_heads], &m.head)?,
        lit_f32_shaped(&[m.n_layers, m.d_ff], &m.ffn)?,
    ))
}

/// Evaluate on a split ("dev" or "test").
pub fn evaluate(engine: &Engine, state: &ModelState, data: &Dataset, split: &str) -> Result<EvalResult> {
    let examples: &[Example] = match split {
        "test" => &data.test,
        _ => &data.dev,
    };
    match data.kind.as_str() {
        "lm" => eval_lm(engine, state, examples, data),
        _ => eval_argmax(engine, state, examples, data),
    }
}

fn eval_argmax(
    engine: &Engine,
    state: &ModelState,
    examples: &[Example],
    data: &Dataset,
) -> Result<EvalResult> {
    let b = engine.manifest.batch_eval;
    let art = format!("{}__{}__fwd", state.model, state.task);
    let tinfo = engine.manifest.task(&state.model, &state.task);
    let (hm, fm) = mask_literals(state)?;
    let params = lit_f32_shaped(&[tinfo.n_params], &state.params)?;
    let n_out = if data.kind == "span" { data.seq_len } else { data.n_classes };
    let mut correct = 0usize;
    let mut total = 0usize;
    let mut i = 0;
    while i < examples.len() {
        let idxs: Vec<usize> = (i..i + b).collect();
        let (ids, labels) = Dataset::batch_from(examples, &data.kind, data.seq_len, &idxs);
        let out = engine.run(
            &art,
            &[
                params.clone(),
                lit_i32(&[b, data.seq_len], &ids)?,
                hm.clone(),
                fm.clone(),
            ],
        )?;
        let logits = lit_to_f32(&out[0])?;
        let valid = (examples.len() - i).min(b);
        for (k, &label) in labels.iter().enumerate().take(valid) {
            let row = &logits[k * n_out..(k + 1) * n_out];
            let pred = row
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.total_cmp(b.1))
                .map(|(j, _)| j)
                .unwrap_or(0);
            if pred == label as usize {
                correct += 1;
            }
            total += 1;
        }
        i += b;
    }
    Ok(EvalResult { metric: correct as f64 / total.max(1) as f64, loss: 0.0, perplexity: None, n: total })
}

fn eval_lm(
    engine: &Engine,
    state: &ModelState,
    examples: &[Example],
    data: &Dataset,
) -> Result<EvalResult> {
    let b = engine.manifest.batch_eval;
    let art = format!("{}__{}__eval_loss", state.model, state.task);
    let tinfo = engine.manifest.task(&state.model, &state.task);
    let (hm, fm) = mask_literals(state)?;
    let params = lit_f32_shaped(&[tinfo.n_params], &state.params)?;
    let mut loss_sum = 0f64;
    let mut batches = 0usize;
    let mut i = 0;
    while i + b <= examples.len().max(b) {
        let idxs: Vec<usize> = (i..i + b).collect();
        let (ids, labels) = Dataset::batch_from(examples, "lm", data.seq_len, &idxs);
        let out = engine.run(
            &art,
            &[
                params.clone(),
                lit_i32(&[b, data.seq_len], &ids)?,
                lit_i32(&[b, data.seq_len], &labels)?,
                hm.clone(),
                fm.clone(),
            ],
        )?;
        loss_sum += lit_to_f32(&out[0])?[0] as f64;
        batches += 1;
        i += b;
        if i >= examples.len() {
            break;
        }
    }
    let loss = loss_sum / batches.max(1) as f64;
    Ok(EvalResult {
        metric: (-loss).exp(),
        loss,
        perplexity: Some(loss.exp()),
        n: batches * b,
    })
}

/// Mean task loss over calibration batches — the SPDY candidate score.
pub fn calib_loss(
    engine: &Engine,
    state: &ModelState,
    data: &Dataset,
    n_samples: usize,
) -> Result<f64> {
    let b = engine.manifest.batch_eval;
    let art = format!("{}__{}__eval_loss", state.model, state.task);
    let tinfo = engine.manifest.task(&state.model, &state.task);
    let (hm, fm) = mask_literals(state)?;
    let params = lit_f32_shaped(&[tinfo.n_params], &state.params)?;
    let mut loss_sum = 0f64;
    let mut batches = 0usize;
    let mut i = 0;
    while i < n_samples {
        let idxs: Vec<usize> = (i..i + b).collect();
        let (ids, labels) = data.batch(&idxs);
        let out = engine.run(
            &art,
            &[
                params.clone(),
                lit_i32(&[b, data.seq_len], &ids)?,
                if data.kind == "lm" {
                    lit_i32(&[b, data.seq_len], &labels)?
                } else {
                    lit_i32(&[b], &labels)?
                },
                hm.clone(),
                fm.clone(),
            ],
        )?;
        loss_sum += lit_to_f32(&out[0])?[0] as f64;
        batches += 1;
        i += b;
    }
    Ok(loss_sum / batches.max(1) as f64)
}
