//! The ZipLM structured-OBS core (paper §3.1, Algorithm 1).
//!
//! Two interchangeable backends implement the per-step math:
//!
//! * [`HloBackend`] — the production path: executes the AOT-compiled
//!   score/update graphs (whose hot loops are the L1 Pallas kernels)
//!   through PJRT;
//! * [`NativeBackend`] — a pure-Rust mirror used for unit/property
//!   tests and for cross-checking the HLO path bit-for-bit(ish).
//!
//! The native mirror itself has two tiers. The trait methods are the
//! **fast path**: closed-form g=1 scoring (one column-sum-of-squares
//! pass divided by diag(Hinv)), batched block extraction + inversion
//! for g>1, and in-place rank-g downdates — `multi_update` clones W
//! and Hinv once and then streams every removal step in place with an
//! incrementally-maintained alive list AND incrementally-maintained
//! column sums of squares (the scores for the next step are updated
//! inside the same axpy pass that rewrites W, so no per-step rescan
//! of the whole weight matrix remains). The original gather+matmul
//! formulation survives as `scores_ref`/`update_ref`/
//! `multi_update_ref`: the equivalence oracle for property tests
//! (rust/tests/proptests.rs) and the "before" half of the hot-path
//! benches (benches/bench_hotpath.rs → BENCH_hotpath.json).
//!
//! On top of either backend, [`build_module_db`] produces the paper's
//! per-layer *database*: weight snapshots + error priors at every
//! sparsity level of the head/FFN ladders, which the structured SPDY
//! search (spdy/) consumes. Selection inside a database build is pure
//! saliency (Algorithm 1); *inference-awareness* enters at the SPDY
//! level where levels are traded off against latency-table entries.

use anyhow::{anyhow, Result};

use crate::kernel::{use_compact_pass, AliveSet, Dispatch};
use crate::runtime::{lit_f32_shaped, lit_scalar_i32, lit_to_f32, lit_to_i32, Engine};
use crate::tensor::{linalg, Tensor};
use crate::util::threadpool::parallel_for_slices_mut;

pub const BIG: f32 = 1e30;

/// The damped Hessian H = 2·XX^T + λI from an accumulated XX^T.
/// `damp_frac` follows the OBC convention: λ = damp_frac · mean(diag).
/// Split out of [`assemble_hessian`] for callers that only score
/// reconstruction errors (the compound choice lattice) and can skip
/// the O(n³) inversion.
pub fn damped_hessian(acc_xxt: &Tensor, damp_frac: f32) -> Tensor {
    let n = acc_xxt.rows();
    let mut h = acc_xxt.clone();
    h.scale(2.0);
    let mean_diag = (0..n).map(|i| h.at2(i, i) as f64).sum::<f64>() / n as f64;
    let lambda = (damp_frac as f64 * mean_diag).max(1e-8) as f32;
    h.add_diag(lambda);
    h
}

/// Assemble H = 2·XX^T + λI and H^{-1} from an accumulated XX^T.
pub fn assemble_hessian(acc_xxt: &Tensor, damp_frac: f32) -> Result<(Tensor, Tensor)> {
    let h = damped_hessian(acc_xxt, damp_frac);
    let hinv = linalg::spd_inverse(&h).map_err(|e| anyhow!("hessian inverse: {e}"))?;
    Ok((h, hinv))
}

/// One structured-OBS problem: W [d_row, n·g] with column-group
/// structures of width g, inverse Hessian [n·g, n·g].
pub trait ObsOps {
    /// Eq. 2 saliencies for all structures (BIG for inactive).
    fn scores(&mut self, w: &Tensor, hinv: &Tensor, active: &[f32]) -> Result<Vec<f32>>;
    /// Eqs. 3–4: remove structure `idx`, return (W', Hinv').
    fn update(&mut self, w: &Tensor, hinv: &Tensor, idx: usize) -> Result<(Tensor, Tensor)>;
    /// Fused n-step one-at-a-time removal (g = 1 only). Returns
    /// (W', Hinv', active', removal order).
    fn multi_update(
        &mut self,
        w: &Tensor,
        hinv: &Tensor,
        active: &[f32],
        n: usize,
    ) -> Result<(Tensor, Tensor, Vec<f32>, Vec<usize>)>;
    fn group(&self) -> usize;
}

// ---------------------------------------------------------------- native

/// Pure-Rust mirror of the L1/L2 pruning math.
///
/// Each [`ObsOps`] method has two implementations:
///
/// * the **fast path** (the trait methods) — closed-form g=1 scoring
///   (`score_j = Σ_i w_ij² / Hinv_jj` in one column-sum-of-squares
///   pass), batched g×g block extraction/inversion for g>1 with the
///   per-structure quadratic forms fanned out across the thread pool
///   (nesting-aware: inline inside a database-build fan-out), and
///   in-place rank-g downdates that never clone the full W/Hinv per
///   removal step;
/// * the **reference path** (`scores_ref` / `update_ref` /
///   `multi_update_ref`) — the original paper-faithful gather+matmul
///   formulation, kept as the equivalence oracle for property tests
///   and as the "before" entries in the hot-path benches.
pub struct NativeBackend {
    pub g: usize,
}

impl NativeBackend {
    pub fn new(g: usize) -> Self {
        NativeBackend { g }
    }

    fn block_inv(&self, hinv: &Tensor, j: usize) -> Result<Tensor> {
        let g = self.g;
        let idx: Vec<usize> = (j * g..(j + 1) * g).collect();
        let block = hinv.gather_rows(&idx).gather_cols(&idx);
        linalg::gj_inverse(&block).map_err(|e| anyhow!(e))
    }

    /// Gather every active g×g diagonal block of Hinv in one streaming
    /// pass and invert them in place. Returns the flat `[n][g*g]`
    /// inverse-block array (inactive blocks are left as garbage and
    /// must not be read).
    fn batch_block_inverses(&self, hinv: &Tensor, active: &[f32]) -> Result<Vec<f32>> {
        let g = self.g;
        let d_col = hinv.cols();
        let n = d_col / g;
        let mut blocks = vec![0f32; n * g * g];
        for r in 0..d_col {
            let j = r / g;
            if active[j] <= 0.0 {
                continue;
            }
            let src = &hinv.data[r * d_col + j * g..r * d_col + (j + 1) * g];
            blocks[j * g * g + (r - j * g) * g..j * g * g + (r - j * g + 1) * g]
                .copy_from_slice(src);
        }
        let mut scratch = vec![0f32; g * g];
        let mut ident = vec![0f32; g * g];
        for j in 0..n {
            if active[j] <= 0.0 {
                continue;
            }
            let blk = &mut blocks[j * g * g..(j + 1) * g * g];
            scratch.copy_from_slice(blk);
            ident.fill(0.0);
            for t in 0..g {
                ident[t * g + t] = 1.0;
            }
            linalg::gj_inverse_flat(&mut scratch, &mut ident, g).map_err(|e| anyhow!(e))?;
            blk.copy_from_slice(&ident);
        }
        Ok(blocks)
    }

    /// Reference Eq. 2 scoring: per-structure gather + g×g inverse +
    /// per-row matvec. O(n) temporary tensors per call.
    pub fn scores_ref(&self, w: &Tensor, hinv: &Tensor, active: &[f32]) -> Result<Vec<f32>> {
        let g = self.g;
        let n = w.cols() / g;
        let mut out = vec![BIG; n];
        for j in 0..n {
            if active[j] <= 0.0 {
                continue;
            }
            let binv = self.block_inv(hinv, j)?;
            // score_j = Σ_i w_i,Sj Binv w_i,Sj^T
            let mut s = 0f64;
            for i in 0..w.rows() {
                let wi = &w.row(i)[j * g..(j + 1) * g];
                let bw = binv.matvec(wi);
                for (a, b) in wi.iter().zip(&bw) {
                    s += (*a as f64) * (*b as f64);
                }
            }
            out[j] = s as f32;
        }
        Ok(out)
    }

    /// Reference Eqs. 3–4 update: gathers + dense matmuls over cloned
    /// W/Hinv (two full-matrix clones + four temporaries per call).
    pub fn update_ref(&self, w: &Tensor, hinv: &Tensor, idx: usize) -> Result<(Tensor, Tensor)> {
        let g = self.g;
        let d_col = w.cols();
        let cols: Vec<usize> = (idx * g..(idx + 1) * g).collect();
        let binv = self.block_inv(hinv, idx)?;
        // P = Binv @ Hinv[S, :]
        let rows = hinv.gather_rows(&cols);
        let p = binv.matmul(&rows); // [g, d_col]
        // W' = W - W[:, S] @ P ; Hinv' = Hinv - Hinv[:, S] @ P
        let wc = w.gather_cols(&cols);
        let hc = hinv.gather_cols(&cols);
        let mut w2 = w.clone();
        let dw = wc.matmul(&p);
        for i in 0..w2.len() {
            w2.data[i] -= dw.data[i];
        }
        let mut h2 = hinv.clone();
        let dh = hc.matmul(&p);
        for i in 0..h2.len() {
            h2.data[i] -= dh.data[i];
        }
        // scrub: zero removed cols of W, zero rows/cols of Hinv, unit diag
        for i in 0..w2.rows() {
            for &c in &cols {
                w2.data[i * d_col + c] = 0.0;
            }
        }
        for &c in &cols {
            for k in 0..d_col {
                h2.data[c * d_col + k] = 0.0;
                h2.data[k * d_col + c] = 0.0;
            }
            h2.data[c * d_col + c] = 1.0;
        }
        Ok((w2, h2))
    }

    /// Reference fused removal: one clone-based `update_ref` per step.
    pub fn multi_update_ref(
        &self,
        w: &Tensor,
        hinv: &Tensor,
        active: &[f32],
        n: usize,
    ) -> Result<(Tensor, Tensor, Vec<f32>, Vec<usize>)> {
        assert_eq!(self.g, 1, "multi_update is a g=1 path");
        let mut w = w.clone();
        let mut h = hinv.clone();
        let mut act = active.to_vec();
        let mut order = Vec::with_capacity(n);
        for _ in 0..n {
            let scores = self.scores_ref(&w, &h, &act)?;
            let j = argmin(&scores);
            if scores[j] >= BIG {
                return Err(anyhow!("multi_update: no active structure left"));
            }
            let (w2, h2) = self.update_ref(&w, &h, j)?;
            w = w2;
            h = h2;
            act[j] = 0.0;
            order.push(j);
        }
        Ok((w, h, act, order))
    }
}

/// Eqs. 3–4 as an in-place rank-g downdate of (W, Hinv), streamed
/// row-major: `g` axpy passes per row instead of clone + gather +
/// dense matmul. Removed rows/cols are scrubbed to the same exact
/// zeros/unit-diagonal the reference path produces.
fn obs_update_inplace(
    w: &mut Tensor,
    hinv: &mut Tensor,
    idx: usize,
    g: usize,
    binv: &[f32],      // [g, g] inverse of Hinv[S, S]
    p: &mut Vec<f32>,  // scratch, resized to [g, d_col]
    cbuf: &mut Vec<f32>, // scratch, resized to [d_col, g] (Hinv[:, S] copy)
) {
    let d_col = w.cols();
    let s0 = idx * g;
    let kd = Dispatch::get();
    // P = Binv @ Hinv[S, :], built from the still-unmodified rows.
    p.clear();
    p.resize(g * d_col, 0.0);
    for r in 0..g {
        let prow = &mut p[r * d_col..(r + 1) * d_col];
        for t in 0..g {
            let f = binv[r * g + t];
            if f == 0.0 {
                continue;
            }
            let hrow = &hinv.data[(s0 + t) * d_col..(s0 + t + 1) * d_col];
            kd.axpy(prow, f, hrow);
        }
    }
    // W rows: w_i -= Σ_t w_i,S[t] · P[t, :], then exact-zero the block.
    let mut wseg = vec![0f32; g];
    for i in 0..w.rows() {
        let row = w.row_mut(i);
        wseg[..g].copy_from_slice(&row[s0..s0 + g]);
        for (t, &wt) in wseg[..g].iter().enumerate() {
            if wt == 0.0 {
                continue;
            }
            let prow = &p[t * d_col..(t + 1) * d_col];
            kd.axpy_minus(row, wt, prow);
        }
        row[s0..s0 + g].fill(0.0);
    }
    // Hinv: copy the S column block first (it is modified mid-pass),
    // then h_r -= Σ_t Hinv[r, S[t]] · P[t, :] for every row r.
    cbuf.clear();
    cbuf.resize(d_col * g, 0.0);
    for r in 0..d_col {
        cbuf[r * g..(r + 1) * g].copy_from_slice(&hinv.data[r * d_col + s0..r * d_col + s0 + g]);
    }
    for r in 0..d_col {
        for t in 0..g {
            let c = cbuf[r * g + t];
            if c == 0.0 {
                continue;
            }
            let prow = &p[t * d_col..(t + 1) * d_col];
            let hrow = &mut hinv.data[r * d_col..(r + 1) * d_col];
            kd.axpy_minus(hrow, c, prow);
        }
    }
    // scrub removed rows/cols, unit diagonal
    for c in s0..s0 + g {
        hinv.data[c * d_col..(c + 1) * d_col].fill(0.0);
        for r in 0..d_col {
            hinv.data[r * d_col + c] = 0.0;
        }
        hinv.data[c * d_col + c] = 1.0;
    }
}

impl ObsOps for NativeBackend {
    fn scores(&mut self, w: &Tensor, hinv: &Tensor, active: &[f32]) -> Result<Vec<f32>> {
        let g = self.g;
        let d_col = w.cols();
        let n = d_col / g;
        let mut out = vec![BIG; n];
        if g == 1 {
            // Closed form: Binv is the scalar 1/Hinv_jj, so
            // score_j = Σ_i w_ij² / Hinv_jj — one vectorized
            // column-sum-of-squares pass over W, no temporaries.
            // Below half density the pass walks the alive list instead
            // of full rows: dead columns are never scored, so skipping
            // them changes nothing (and never reads or writes them —
            // the poison-sentinel invariant); at high density the
            // full-width pass runs through the SIMD dispatch.
            let kd = Dispatch::get();
            let alive = AliveSet::from_active(&active[..n.min(active.len())]);
            let mut colsq = vec![0f64; d_col];
            if use_compact_pass(alive.len(), d_col) {
                for i in 0..w.rows() {
                    let row = w.row(i);
                    for &c in alive.as_slice() {
                        colsq[c] += (row[c] as f64) * (row[c] as f64);
                    }
                }
            } else {
                for i in 0..w.rows() {
                    kd.colsq_accum(&mut colsq, w.row(i));
                }
            }
            for j in 0..n {
                if active[j] > 0.0 {
                    let hjj = hinv.at2(j, j);
                    // mirror the reference path's gj_inverse guard
                    if hjj.abs() < 1e-20 {
                        return Err(anyhow!("scores: singular Hinv diagonal at {j}"));
                    }
                    out[j] = (colsq[j] / hjj as f64) as f32;
                }
            }
            return Ok(out);
        }
        // g > 1: one batched gather+invert of all active blocks, then
        // per-structure quadratic forms. Structure-outer loop order
        // keeps the g×g inverse block L1-resident across all W rows.
        // Structures are independent given `binvs`, so the sweep fans
        // out across the pool in disjoint chunks of `out` — but only
        // when a chunk carries enough arithmetic (~64k flops) to
        // amortize the scoped spawn/join; tiny sweeps run inline, and
        // inside a database-build fan-out the thread budget is
        // already spent so this also degenerates to the inline loop.
        let binvs = self.batch_block_inverses(hinv, active)?;
        let min_chunk = 65_536usize.div_ceil((w.rows() * g * g).max(1)).max(1);
        parallel_for_slices_mut(&mut out, min_chunk, |start, chunk| {
            for (off, o) in chunk.iter_mut().enumerate() {
                let j = start + off;
                if active[j] <= 0.0 {
                    continue;
                }
                let b = &binvs[j * g * g..(j + 1) * g * g];
                let mut s = 0f64;
                for i in 0..w.rows() {
                    let wseg = &w.row(i)[j * g..(j + 1) * g];
                    for (r, &wr) in wseg.iter().enumerate() {
                        let brow = &b[r * g..(r + 1) * g];
                        let mut t = 0f32;
                        for (bv, wv) in brow.iter().zip(wseg) {
                            t += bv * wv;
                        }
                        s += (wr as f64) * (t as f64);
                    }
                }
                *o = s as f32;
            }
        });
        Ok(out)
    }

    fn update(&mut self, w: &Tensor, hinv: &Tensor, idx: usize) -> Result<(Tensor, Tensor)> {
        let g = self.g;
        let binv = self.block_inv(hinv, idx)?;
        let mut w2 = w.clone();
        let mut h2 = hinv.clone();
        let (mut p, mut cbuf) = (Vec::new(), Vec::new());
        obs_update_inplace(&mut w2, &mut h2, idx, g, &binv.data, &mut p, &mut cbuf);
        Ok((w2, h2))
    }

    fn multi_update(
        &mut self,
        w: &Tensor,
        hinv: &Tensor,
        active: &[f32],
        n: usize,
    ) -> Result<(Tensor, Tensor, Vec<f32>, Vec<usize>)> {
        assert_eq!(self.g, 1, "multi_update is a g=1 path");
        let d_col = w.cols();
        let d_row = w.rows();
        let kd = Dispatch::get();
        // One clone up front; every removal step then works in place
        // (the reference path re-cloned both matrices per step:
        // O(n·(d_col² + d_row·d_col)) copied floats).
        let mut w = w.clone();
        let mut h = hinv.clone();
        let mut act = active.to_vec();
        // Incremental bookkeeping: compacted ascending alive-column
        // list ([`AliveSet`]), shrunk as structures are removed.
        let mut alive = AliveSet::from_active(&act[..d_col.min(act.len())]);
        let mut order = Vec::with_capacity(n);
        // Column sums of squares, computed ONCE and then maintained
        // incrementally inside the per-step W axpy pass (the pass
        // already touches every element it changes, so the separate
        // whole-matrix rescan per step is pure overhead). Accumulation
        // stays in f64; a column the downdates cancel to ~0 can drift
        // a few ulps negative, so scores clamp at 0 when read.
        //
        // Every per-step sweep has two variants picked by
        // [`use_compact_pass`]: a dense full-width pass (SIMD through
        // the dispatch layer) and a compact one that walks the alive
        // list. Dead entries hold exact zeros, so both are
        // bit-identical — the compact variant just skips the
        // multiply-by-zero work, and never reads or writes dead
        // entries at all (the poison-sentinel invariant the alive-set
        // tests pin down).
        let mut colsq = vec![0f64; d_col];
        if use_compact_pass(alive.len(), d_col) {
            for i in 0..d_row {
                let row = w.row(i);
                for &c in alive.as_slice() {
                    colsq[c] += (row[c] as f64) * (row[c] as f64);
                }
            }
        } else {
            for i in 0..d_row {
                kd.colsq_accum(&mut colsq, w.row(i));
            }
        }
        let mut p = vec![0f32; d_col];
        let mut cbuf = vec![0f32; d_col];
        for _step in 0..n {
            if alive.is_empty() {
                return Err(anyhow!("multi_update: no active structure left"));
            }
            // Closed-form g=1 scores over the alive set; the argmin
            // mirrors `argmin(&scores)` exactly (ascending scan,
            // strict <, f32 compare) so removal order is identical to
            // the step-by-step path up to f64 accumulation order.
            let mut best = alive.as_slice()[0];
            let mut best_s = f32::INFINITY;
            for &j in alive.as_slice() {
                let s = (colsq[j].max(0.0) / h.at2(j, j) as f64) as f32;
                if s < best_s {
                    best_s = s;
                    best = j;
                }
            }
            let j = best;
            // g=1 downdate: p = Hinv[j, :] / Hinv_jj, one axpy per row.
            // Guard the pivot like the reference path's gj_inverse does
            // (repeated downdates can cancel H_jj toward 0 on an
            // ill-conditioned Hessian near full removal).
            let hjj = h.at2(j, j);
            if hjj.abs() < 1e-20 {
                return Err(anyhow!("multi_update: singular pivot at {j}"));
            }
            let hjj_inv = 1.0 / hjj;
            if use_compact_pass(alive.len(), d_col) {
                // Compact passes: gather p at alive positions, update
                // only alive entries, scrub only alive entries of
                // row/col j (the dead ones are exact zeros already).
                let idx = alive.as_slice();
                let na = idx.len();
                for (t, &c) in idx.iter().enumerate() {
                    p[t] = h.at2(j, c) * hjj_inv;
                }
                let pc = &p[..na];
                for i in 0..d_row {
                    let row = w.row_mut(i);
                    let wij = row[j];
                    if wij != 0.0 {
                        for (t, &c) in idx.iter().enumerate() {
                            let old = row[c] as f64;
                            row[c] -= wij * pc[t];
                            colsq[c] += (row[c] as f64) * (row[c] as f64) - old * old;
                        }
                    }
                    row[j] = 0.0;
                }
                colsq[j] = 0.0;
                // Reading h[r, j] inside the loop matches the dense
                // path's pre-gathered cbuf: each row update only writes
                // its own row, so every h[r, j] read is still pristine.
                for &r in idx {
                    if r == j {
                        continue; // row j is scrubbed below either way
                    }
                    let c = h.at2(r, j);
                    if c == 0.0 {
                        continue;
                    }
                    for (t, &col) in idx.iter().enumerate() {
                        h.data[r * d_col + col] -= c * pc[t];
                    }
                }
                for &c in idx {
                    h.data[j * d_col + c] = 0.0;
                    h.data[c * d_col + j] = 0.0;
                }
                h.data[j * d_col + j] = 1.0;
            } else {
                p.copy_from_slice(h.row(j));
                kd.scale(&mut p, hjj_inv);
                for i in 0..d_row {
                    let row = w.row_mut(i);
                    let wij = row[j];
                    if wij != 0.0 {
                        kd.axpy_minus_colsq(row, wij, &p, &mut colsq);
                    }
                    row[j] = 0.0;
                }
                colsq[j] = 0.0;
                for (r, c) in cbuf.iter_mut().enumerate() {
                    *c = h.at2(r, j);
                }
                for r in 0..d_col {
                    let c = cbuf[r];
                    if c == 0.0 {
                        continue; // dead rows stay untouched — alive-set bookkeeping
                    }
                    let hrow = h.row_mut(r);
                    kd.axpy_minus(hrow, c, &p);
                }
                h.row_mut(j).fill(0.0);
                for r in 0..d_col {
                    h.data[r * d_col + j] = 0.0;
                }
                h.data[j * d_col + j] = 1.0;
            }
            act[j] = 0.0;
            alive.remove(j);
            order.push(j);
        }
        Ok((w, h, act, order))
    }

    fn group(&self) -> usize {
        self.g
    }
}

/// Index of the smallest score; the first occurrence wins ties.
///
/// When every structure is inactive all entries are the [`BIG`]
/// sentinel and there is no meaningful choice: the function returns 0.
/// Callers that remove structures must therefore never request more
/// removals than there are active structures (the multi-step paths
/// check `scores[argmin] < BIG` and error out instead).
pub fn argmin(scores: &[f32]) -> usize {
    debug_assert!(!scores.is_empty(), "argmin over empty scores");
    let mut best = 0;
    for (i, &s) in scores.iter().enumerate() {
        if s < scores[best] {
            best = i;
        }
    }
    best
}

// ------------------------------------------------------------------ hlo

/// Production backend: drives the AOT score/update executables (L1
/// Pallas kernels inside) through PJRT.
pub struct HloBackend<'e> {
    engine: &'e Engine,
    score_art: String,
    update_art: String,
    multi_art: Option<String>,
    g: usize,
    d_row: usize,
    d_col: usize,
    /// PJRT dispatch counter (perf accounting, EXPERIMENTS.md §Perf).
    pub dispatches: usize,
}

impl<'e> HloBackend<'e> {
    pub fn attn(engine: &'e Engine, model: &str) -> Result<Self> {
        let info = engine.manifest.model(model);
        Ok(HloBackend {
            engine,
            score_art: format!("{model}__score_attn"),
            update_art: format!("{model}__update_attn"),
            multi_art: None,
            g: info.d_head,
            d_row: info.d_model,
            d_col: info.d_attn(),
            dispatches: 0,
        })
    }

    pub fn fc(engine: &'e Engine, model: &str) -> Result<Self> {
        let info = engine.manifest.model(model);
        Ok(HloBackend {
            engine,
            score_art: format!("{model}__score_fc"),
            update_art: format!("{model}__update_fc"),
            multi_art: Some(format!("{model}__update_fc_multi")),
            g: 1,
            d_row: info.d_model,
            d_col: info.d_ff,
            dispatches: 0,
        })
    }
}

impl<'e> ObsOps for HloBackend<'e> {
    fn scores(&mut self, w: &Tensor, hinv: &Tensor, active: &[f32]) -> Result<Vec<f32>> {
        let n = self.d_col / self.g;
        let out = self.engine.run(
            &self.score_art,
            &[
                lit_f32_shaped(&[self.d_row, self.d_col], &w.data)?,
                lit_f32_shaped(&[self.d_col, self.d_col], &hinv.data)?,
                lit_f32_shaped(&[n], active)?,
            ],
        )?;
        self.dispatches += 1;
        lit_to_f32(&out[0])
    }

    fn update(&mut self, w: &Tensor, hinv: &Tensor, idx: usize) -> Result<(Tensor, Tensor)> {
        let out = self.engine.run(
            &self.update_art,
            &[
                lit_f32_shaped(&[self.d_row, self.d_col], &w.data)?,
                lit_f32_shaped(&[self.d_col, self.d_col], &hinv.data)?,
                lit_scalar_i32(idx as i32)?,
            ],
        )?;
        self.dispatches += 1;
        Ok((
            Tensor::from_vec(&[self.d_row, self.d_col], lit_to_f32(&out[0])?),
            Tensor::from_vec(&[self.d_col, self.d_col], lit_to_f32(&out[1])?),
        ))
    }

    fn multi_update(
        &mut self,
        w: &Tensor,
        hinv: &Tensor,
        active: &[f32],
        n: usize,
    ) -> Result<(Tensor, Tensor, Vec<f32>, Vec<usize>)> {
        let art = self
            .multi_art
            .clone()
            .ok_or_else(|| anyhow!("multi_update only lowered for FC (g=1)"))?;
        let out = self.engine.run(
            &art,
            &[
                lit_f32_shaped(&[self.d_row, self.d_col], &w.data)?,
                lit_f32_shaped(&[self.d_col, self.d_col], &hinv.data)?,
                lit_f32_shaped(&[self.d_col], active)?,
                lit_scalar_i32(n as i32)?,
            ],
        )?;
        self.dispatches += 1;
        let w2 = Tensor::from_vec(&[self.d_row, self.d_col], lit_to_f32(&out[0])?);
        let h2 = Tensor::from_vec(&[self.d_col, self.d_col], lit_to_f32(&out[1])?);
        let act2 = lit_to_f32(&out[2])?;
        let order: Vec<usize> = lit_to_i32(&out[3])?
            .into_iter()
            .take(n)
            .map(|x| x as usize)
            .collect();
        Ok((w2, h2, act2, order))
    }

    fn group(&self) -> usize {
        self.g
    }
}

// ------------------------------------------------------------- database

/// One sparsity level of a module: snapshot + SPDY prior.
#[derive(Clone, Debug)]
pub struct LevelSnapshot {
    /// remaining structures (heads or FFN columns)
    pub remaining: usize,
    /// cumulative removed structure indices, in removal order
    pub dead: Vec<usize>,
    /// W_paper at this level ([d_row, d_col], removed columns zeroed)
    pub w: Tensor,
    /// p_s = ||Ŵ_s X − W X|| / ||W X|| (paper §3.2); 1.0 for full drop
    pub prior: f64,
}

/// Per-module database: all ladder levels of one layer's attn or FC2.
#[derive(Clone, Debug)]
pub struct ModuleDb {
    pub layer: usize,
    pub is_attn: bool,
    pub levels: Vec<LevelSnapshot>,
}

impl ModuleDb {
    /// Find the level with exactly `remaining` structures.
    pub fn level(&self, remaining: usize) -> Option<&LevelSnapshot> {
        self.levels.iter().find(|l| l.remaining == remaining)
    }
}

/// Relative reconstruction error ||(Ŵ−W)X|| / ||WX|| via the trace
/// identity with the ORIGINAL (undamped-ish) Hessian.
pub fn relative_error(w0: &Tensor, w_s: &Tensor, h: &Tensor) -> f64 {
    let mut diff = w_s.clone();
    for i in 0..diff.len() {
        diff.data[i] -= w0.data[i];
    }
    let num = linalg::trace_whwt(&diff, h).max(0.0);
    let den = linalg::trace_whwt(w0, h).max(1e-12);
    (num / den).sqrt().min(1.0)
}

/// Build the database for one module by one-at-a-time structured OBS.
///
/// `levels` lists the remaining-structure counts to snapshot, in
/// decreasing order, starting with the dense count (e.g. heads
/// [4,3,2,1,0] or the FFN 0.9^i ladder). The final level 0 is the
/// module-drop level with prior 1.0 (paper §3.2's structured prior).
pub fn build_module_db(
    ops: &mut dyn ObsOps,
    layer: usize,
    is_attn: bool,
    w0: &Tensor,
    hinv0: &Tensor,
    h: &Tensor,
    levels: &[usize],
) -> Result<ModuleDb> {
    build_module_db_masked(ops, layer, is_attn, w0, hinv0, h, levels, &[])
}

/// [`build_module_db`] continuing from an existing structural mask:
/// structures in `already_dead` start inactive (gradual pruning
/// re-anchors on the currently-alive set, so `levels[0]` must equal
/// the alive count). Returned `dead` lists contain only structures
/// removed by THIS build — callers that need absolute lists prepend
/// `already_dead` themselves.
#[allow(clippy::too_many_arguments)]
pub fn build_module_db_masked(
    ops: &mut dyn ObsOps,
    layer: usize,
    is_attn: bool,
    w0: &Tensor,
    hinv0: &Tensor,
    h: &Tensor,
    levels: &[usize],
    already_dead: &[usize],
) -> Result<ModuleDb> {
    let g = ops.group();
    let n_structs = w0.cols() / g;
    let mut active = vec![1.0f32; n_structs];
    for &j in already_dead {
        active[j] = 0.0;
    }
    let alive = n_structs - already_dead.len();
    assert_eq!(levels[0], alive, "levels must start at the current alive count");
    let mut out = Vec::with_capacity(levels.len());
    out.push(LevelSnapshot { remaining: alive, dead: vec![], w: w0.clone(), prior: 0.0 });

    let mut w = w0.clone();
    let mut hinv = hinv0.clone();
    let mut dead: Vec<usize> = Vec::new();

    for &target in &levels[1..] {
        let cur = alive - dead.len();
        if target >= cur {
            continue;
        }
        let n_remove = cur - target;
        if target == 0 {
            // full module drop: W = 0, prior = 1 by definition
            let wz = Tensor::zeros(&w0.shape);
            let mut all_dead = dead.clone();
            for j in 0..n_structs {
                if active[j] > 0.0 {
                    all_dead.push(j);
                }
            }
            out.push(LevelSnapshot { remaining: 0, dead: all_dead, w: wz, prior: 1.0 });
            continue;
        }
        if g == 1 && n_remove > 1 {
            let (w2, h2, act2, order) = ops.multi_update(&w, &hinv, &active, n_remove)?;
            w = w2;
            hinv = h2;
            active = act2;
            dead.extend(order);
        } else {
            for _ in 0..n_remove {
                let scores = ops.scores(&w, &hinv, &active)?;
                let j = argmin(&scores);
                let (w2, h2) = ops.update(&w, &hinv, j)?;
                w = w2;
                hinv = h2;
                active[j] = 0.0;
                dead.push(j);
            }
        }
        let prior = relative_error(w0, &w, h);
        out.push(LevelSnapshot { remaining: target, dead: dead.clone(), w: w.clone(), prior });
    }
    Ok(ModuleDb { layer, is_attn, levels: out })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::gen;
    use crate::util::rng::Rng;

    fn setup(rng: &mut Rng, d_row: usize, n: usize, g: usize) -> (Tensor, Tensor, Tensor) {
        let d_col = n * g;
        let w = Tensor::from_vec(&[d_row, d_col], gen::vec_f32(rng, d_row * d_col, 1.0));
        let h = Tensor::from_vec(&[d_col, d_col], gen::spd(rng, d_col, 0.3));
        let hinv = linalg::spd_inverse(&h).unwrap();
        (w, h, hinv)
    }

    #[test]
    fn native_update_reduces_output_error_vs_plain_zeroing() {
        // The OBS update must beat naive column-zeroing in ||ΔW X||.
        let mut rng = Rng::new(21);
        let (w, h, hinv) = setup(&mut rng, 12, 8, 1);
        let mut ops = NativeBackend::new(1);
        let scores = ops.scores(&w, &hinv, &vec![1.0; 8]).unwrap();
        let j = argmin(&scores);
        let (w_obs, _) = ops.update(&w, &hinv, j).unwrap();
        let mut w_naive = w.clone();
        for i in 0..w.rows() {
            w_naive.data[i * 8 + j] = 0.0;
        }
        let err_obs = relative_error(&w, &w_obs, &h);
        let err_naive = relative_error(&w, &w_naive, &h);
        assert!(err_obs <= err_naive + 1e-9, "obs {err_obs} naive {err_naive}");
    }

    #[test]
    fn native_score_equals_error_increase_g1() {
        // For g=1 the OBS score equals the exact increase in squared
        // error: score_j = ||(W' - W) X||^2 when H is the data Gram.
        let mut rng = Rng::new(22);
        let (w, h, hinv) = setup(&mut rng, 6, 5, 1);
        let mut ops = NativeBackend::new(1);
        let scores = ops.scores(&w, &hinv, &vec![1.0; 5]).unwrap();
        for j in 0..5 {
            let (wj, _) = ops.update(&w, &hinv, j).unwrap();
            let mut diff = wj.clone();
            for i in 0..diff.len() {
                diff.data[i] -= w.data[i];
            }
            let err = linalg::trace_whwt(&diff, &h);
            assert!(
                (err - scores[j] as f64).abs() / err.max(1e-6) < 5e-2,
                "j={j}: score {} vs err {err}",
                scores[j]
            );
        }
    }

    #[test]
    fn native_multi_matches_sequential() {
        let mut rng = Rng::new(23);
        let (w, _h, hinv) = setup(&mut rng, 8, 10, 1);
        let act = vec![1.0f32; 10];
        let mut a = NativeBackend::new(1);
        let (wm, _, actm, order) = a.multi_update(&w, &hinv, &act, 4).unwrap();
        // sequential
        let mut ws = w.clone();
        let mut hs = hinv.clone();
        let mut acts = act.clone();
        let mut order_s = Vec::new();
        for _ in 0..4 {
            let sc = a.scores(&ws, &hs, &acts).unwrap();
            let j = argmin(&sc);
            let (w2, h2) = a.update(&ws, &hs, j).unwrap();
            ws = w2;
            hs = h2;
            acts[j] = 0.0;
            order_s.push(j);
        }
        assert_eq!(order, order_s);
        assert!(wm.max_abs_diff(&ws) < 1e-4);
        assert_eq!(actm, acts);
    }

    #[test]
    fn db_priors_monotone_and_bounded() {
        let mut rng = Rng::new(24);
        let (w, h, hinv) = setup(&mut rng, 8, 12, 1);
        let mut ops = NativeBackend::new(1);
        let levels = vec![12, 9, 6, 3, 1, 0];
        let db = build_module_db(&mut ops, 0, false, &w, &hinv, &h, &levels).unwrap();
        assert_eq!(db.levels.len(), levels.len());
        for pair in db.levels.windows(2) {
            assert!(pair[1].prior >= pair[0].prior - 1e-6, "{:?}", pair.iter().map(|l| l.prior).collect::<Vec<_>>());
        }
        assert_eq!(db.levels.last().unwrap().prior, 1.0);
        assert_eq!(db.levels.last().unwrap().remaining, 0);
        // dead lists grow and stay consistent with `remaining`
        for l in &db.levels {
            assert_eq!(l.dead.len(), 12 - l.remaining);
            // snapshot has removed columns zeroed
            for &c in &l.dead {
                for r in 0..l.w.rows() {
                    assert_eq!(l.w.at2(r, c), 0.0);
                }
            }
        }
    }

    #[test]
    fn grouped_update_zeroes_whole_structure() {
        let mut rng = Rng::new(25);
        let (w, _h, hinv) = setup(&mut rng, 6, 4, 4);
        let mut ops = NativeBackend::new(4);
        let (w2, h2) = ops.update(&w, &hinv, 2).unwrap();
        for r in 0..6 {
            for c in 8..12 {
                assert_eq!(w2.at2(r, c), 0.0);
            }
        }
        // scrubbed hinv has unit diag on removed block
        for c in 8..12 {
            assert_eq!(h2.at2(c, c), 1.0);
        }
    }

    #[test]
    fn argmin_first_min_wins_ties() {
        assert_eq!(argmin(&[3.0, 1.0, 1.0, 2.0]), 1);
        assert_eq!(argmin(&[0.5]), 0);
        assert_eq!(argmin(&[2.0, -1.0, -1.0]), 1);
    }

    #[test]
    fn argmin_all_inactive_returns_zero() {
        // every structure masked → all scores are the BIG sentinel;
        // argmin degenerates to index 0 (documented), and the
        // multi-step paths reject this case instead of removing.
        assert_eq!(argmin(&[BIG, BIG, BIG]), 0);
        let mut rng = Rng::new(27);
        let (w, _h, hinv) = setup(&mut rng, 4, 6, 1);
        let mut ops = NativeBackend::new(1);
        let all_dead = vec![0.0f32; 6];
        assert!(ops.multi_update(&w, &hinv, &all_dead, 1).is_err());
        assert!(ops.multi_update_ref(&w, &hinv, &all_dead, 1).is_err());
    }

    #[test]
    fn fast_scores_match_reference_g1_and_g4() {
        let mut rng = Rng::new(28);
        for &(d_row, n, g) in &[(10, 12, 1), (6, 5, 4)] {
            let (w, _h, hinv) = setup(&mut rng, d_row, n, g);
            let mut act = vec![1.0f32; n];
            act[n / 2] = 0.0;
            let mut ops = NativeBackend::new(g);
            let fast = ops.scores(&w, &hinv, &act).unwrap();
            let slow = ops.scores_ref(&w, &hinv, &act).unwrap();
            for j in 0..n {
                if act[j] <= 0.0 {
                    assert!(fast[j] >= BIG && slow[j] >= BIG);
                } else {
                    let denom = slow[j].abs().max(1e-6);
                    assert!(
                        (fast[j] - slow[j]).abs() / denom < 1e-4,
                        "g={g} j={j}: fast {} ref {}",
                        fast[j],
                        slow[j]
                    );
                }
            }
        }
    }

    #[test]
    fn fast_update_matches_reference_g4() {
        let mut rng = Rng::new(29);
        let (w, _h, hinv) = setup(&mut rng, 7, 5, 4);
        let mut ops = NativeBackend::new(4);
        let (wf, hf) = ops.update(&w, &hinv, 2).unwrap();
        let (wr, hr) = ops.update_ref(&w, &hinv, 2).unwrap();
        assert!(wf.max_abs_diff(&wr) < 1e-4, "W diff {}", wf.max_abs_diff(&wr));
        assert!(hf.max_abs_diff(&hr) < 1e-4, "H diff {}", hf.max_abs_diff(&hr));
    }

    #[test]
    fn assemble_hessian_sane() {
        let mut rng = Rng::new(26);
        let x = Tensor::from_vec(&[6, 40], gen::vec_f32(&mut rng, 240, 1.0));
        let acc = x.matmul(&x.transpose2());
        let (h, hinv) = assemble_hessian(&acc, 0.01).unwrap();
        let prod = h.matmul(&hinv);
        assert!(prod.max_abs_diff(&Tensor::eye(6)) < 1e-2);
    }
}
