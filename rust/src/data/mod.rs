//! Synthetic task suite standing in for SQuAD / GLUE / OpenWebText
//! (none of which are reachable offline — see DESIGN.md §3).
//!
//! Design goals, matching what the paper's curves actually measure:
//!   * learnable but not trivial (teacher reaches high-but-<100% dev
//!     accuracy),
//!   * graded difficulty across tasks (sst2-syn easiest … mnli-syn
//!     hardest 3-class),
//!   * smooth degradation under capacity loss, so accuracy-vs-speedup
//!     curves are informative,
//!   * fully seeded: every experiment in EXPERIMENTS.md regenerates
//!     bit-identical data.
//!
//! Mechanisms: class-conditional unigram bias + class-conditional
//! bigram successors (cls), position-retrieval with a content-keyed
//! trigger (span / squad-syn), and a Zipf+successor stochastic grammar
//! (lm / corpus-syn).

use crate::runtime::ModelInfo;
use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct Example {
    pub ids: Vec<i32>,
    pub label: i32, // cls: class; span: position; lm: unused (-1)
}

#[derive(Clone, Debug)]
pub struct Dataset {
    pub task: String,
    pub kind: String, // "cls" | "span" | "lm"
    pub n_classes: usize,
    pub seq_len: usize,
    pub vocab: usize,
    pub train: Vec<Example>,
    pub dev: Vec<Example>,
    pub test: Vec<Example>,
}

/// Per-task difficulty knobs (unigram signal, bigram signal).
fn task_knobs(task: &str) -> (f64, f64, usize) {
    // (p_unigram_signal, p_bigram_signal, n_classes)
    match task {
        "sst2-syn" => (0.22, 0.25, 2),
        "qqp-syn" => (0.18, 0.22, 2),
        "qnli-syn" => (0.14, 0.18, 2),
        "mnli-syn" => (0.10, 0.15, 3),
        other => panic!("not a cls task: {other}"),
    }
}

pub fn task_seed(task: &str) -> u64 {
    // stable per-task seed
    let mut h = 0xcbf29ce484222325u64;
    for b in task.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Zipf sampler over [0, vocab) with exponent ~1 (precomputed weights).
struct Zipf {
    cdf: Vec<f64>,
}

impl Zipf {
    fn new(vocab: usize) -> Zipf {
        let mut cdf = Vec::with_capacity(vocab);
        let mut acc = 0.0;
        for i in 0..vocab {
            acc += 1.0 / ((i + 2) as f64).powf(1.05);
            cdf.push(acc);
        }
        Zipf { cdf }
    }

    fn sample(&self, rng: &mut Rng) -> usize {
        let t = rng.f64() * self.cdf.last().unwrap();
        match self.cdf.binary_search_by(|x| x.total_cmp(&t)) {
            Ok(i) | Err(i) => i.min(self.cdf.len() - 1),
        }
    }
}

fn gen_cls(info: &ModelInfo, task: &str, n_train: usize, n_eval: usize) -> Dataset {
    let (p_uni, p_bi, n_classes) = task_knobs(task);
    let mut rng = Rng::new(task_seed(task));
    let vocab = info.vocab;
    let zipf = Zipf::new(vocab);
    // class-specific unigram pools + bigram successor permutations
    let pools: Vec<Vec<usize>> = (0..n_classes)
        .map(|_| rng.choose(vocab, vocab / 16))
        .collect();
    let succs: Vec<Vec<usize>> = (0..n_classes)
        .map(|_| {
            let mut p: Vec<usize> = (0..vocab).collect();
            rng.shuffle(&mut p);
            p
        })
        .collect();
    let mut gen_split = |n: usize, rng: &mut Rng| -> Vec<Example> {
        (0..n)
            .map(|_| {
                let c = rng.below(n_classes);
                let mut ids = Vec::with_capacity(info.seq_len);
                let mut prev = zipf.sample(rng);
                ids.push(prev as i32);
                for _ in 1..info.seq_len {
                    let r = rng.f64();
                    let tok = if r < p_uni {
                        pools[c][rng.below(pools[c].len())]
                    } else if r < p_uni + p_bi {
                        succs[c][prev]
                    } else {
                        zipf.sample(rng)
                    };
                    ids.push(tok as i32);
                    prev = tok;
                }
                Example { ids, label: c as i32 }
            })
            .collect()
    };
    let mut tr_rng = rng.split(1);
    let mut dv_rng = rng.split(2);
    let mut te_rng = rng.split(3);
    Dataset {
        task: task.to_string(),
        kind: "cls".into(),
        n_classes,
        seq_len: info.seq_len,
        vocab,
        train: gen_split(n_train, &mut tr_rng),
        dev: gen_split(n_eval, &mut dv_rng),
        test: gen_split(n_eval, &mut te_rng),
    }
}

fn gen_span(info: &ModelInfo, n_train: usize, n_eval: usize) -> Dataset {
    let mut rng = Rng::new(task_seed("squad-syn"));
    let vocab = info.vocab;
    let zipf = Zipf::new(vocab);
    // content-keyed trigger: the "question" token at position 0 determines
    // which token marks the answer position (hash map via permutation)
    let mut trig: Vec<usize> = (0..vocab).collect();
    rng.shuffle(&mut trig);
    let mut gen_split = |n: usize, rng: &mut Rng| -> Vec<Example> {
        (0..n)
            .map(|_| {
                let q = zipf.sample(rng);
                let t = trig[q];
                let pos = 2 + rng.below(info.seq_len - 2);
                let mut ids = Vec::with_capacity(info.seq_len);
                ids.push(q as i32);
                for i in 1..info.seq_len {
                    if i == pos {
                        ids.push(t as i32);
                    } else {
                        // avoid accidental trigger occurrences
                        let mut tok = zipf.sample(rng);
                        while tok == t {
                            tok = zipf.sample(rng);
                        }
                        ids.push(tok as i32);
                    }
                }
                Example { ids, label: pos as i32 }
            })
            .collect()
    };
    let mut tr = rng.split(1);
    let mut dv = rng.split(2);
    let mut te = rng.split(3);
    Dataset {
        task: "squad-syn".into(),
        kind: "span".into(),
        n_classes: 0,
        seq_len: info.seq_len,
        vocab,
        train: gen_split(n_train, &mut tr),
        dev: gen_split(n_eval, &mut dv),
        test: gen_split(n_eval, &mut te),
    }
}

fn gen_lm(info: &ModelInfo, n_train: usize, n_eval: usize) -> Dataset {
    let mut rng = Rng::new(task_seed("corpus-syn"));
    let vocab = info.vocab;
    let zipf = Zipf::new(vocab);
    // stochastic grammar: deterministic successor chains + Zipf restarts
    let mut succ: Vec<usize> = (0..vocab).collect();
    rng.shuffle(&mut succ);
    let mut succ2: Vec<usize> = (0..vocab).collect();
    rng.shuffle(&mut succ2);
    let mut gen_split = |n: usize, rng: &mut Rng| -> Vec<Example> {
        (0..n)
            .map(|_| {
                let mut ids = Vec::with_capacity(info.seq_len);
                let mut prev = zipf.sample(rng);
                ids.push(prev as i32);
                for _ in 1..info.seq_len {
                    let r = rng.f64();
                    let tok = if r < 0.45 {
                        succ[prev]
                    } else if r < 0.60 {
                        succ2[prev]
                    } else {
                        zipf.sample(rng)
                    };
                    ids.push(tok as i32);
                    prev = tok;
                }
                Example { ids, label: -1 }
            })
            .collect()
    };
    let mut tr = rng.split(1);
    let mut dv = rng.split(2);
    let mut te = rng.split(3);
    Dataset {
        task: "corpus-syn".into(),
        kind: "lm".into(),
        n_classes: 0,
        seq_len: info.seq_len,
        vocab,
        train: gen_split(n_train, &mut tr),
        dev: gen_split(n_eval, &mut dv),
        test: gen_split(n_eval, &mut te),
    }
}

/// Standard sizes; experiment drivers may override.
pub fn load(info: &ModelInfo, task: &str) -> Dataset {
    load_sized(info, task, 2048, 512)
}

pub fn load_sized(info: &ModelInfo, task: &str, n_train: usize, n_eval: usize) -> Dataset {
    match task {
        "squad-syn" => gen_span(info, n_train, n_eval),
        "corpus-syn" => gen_lm(info, n_train, n_eval),
        t => gen_cls(info, t, n_train, n_eval),
    }
}

impl Dataset {
    /// Pack `examples[range]` into (ids, labels) batch vectors, padding by
    /// cycling (datasets here are always ≥ batch).
    pub fn batch(&self, idxs: &[usize]) -> (Vec<i32>, Vec<i32>) {
        let mut ids = Vec::with_capacity(idxs.len() * self.seq_len);
        let mut labels = Vec::new();
        for &i in idxs {
            let ex = &self.train[i % self.train.len()];
            ids.extend_from_slice(&ex.ids);
            if self.kind == "lm" {
                labels.extend_from_slice(&ex.ids);
            } else {
                labels.push(ex.label);
            }
        }
        (ids, labels)
    }

    /// Batch from an explicit split.
    pub fn batch_from(split: &[Example], kind: &str, seq_len: usize, idxs: &[usize]) -> (Vec<i32>, Vec<i32>) {
        let mut ids = Vec::with_capacity(idxs.len() * seq_len);
        let mut labels = Vec::new();
        for &i in idxs {
            let ex = &split[i % split.len()];
            ids.extend_from_slice(&ex.ids);
            if kind == "lm" {
                labels.extend_from_slice(&ex.ids);
            } else {
                labels.push(ex.label);
            }
        }
        (ids, labels)
    }

    /// Calibration set: the first n train examples (paper: 2048 default).
    pub fn calib_ids(&self, n: usize, batch: usize) -> Vec<Vec<i32>> {
        let mut out = Vec::new();
        let mut i = 0;
        while i < n {
            let idxs: Vec<usize> = (i..i + batch).collect();
            let (ids, _) = self.batch(&idxs);
            out.push(ids);
            i += batch;
        }
        out
    }
}

/// Shuffled epoch index stream.
pub struct Batcher {
    order: Vec<usize>,
    pos: usize,
    batch: usize,
    rng: Rng,
}

impl Batcher {
    pub fn new(n: usize, batch: usize, seed: u64) -> Batcher {
        let mut rng = Rng::new(seed);
        let mut order: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut order);
        Batcher { order, pos: 0, batch, rng }
    }

    /// Next batch of indices; reshuffles at epoch end.
    pub fn next(&mut self) -> Vec<usize> {
        if self.pos + self.batch > self.order.len() {
            self.rng.shuffle(&mut self.order);
            self.pos = 0;
        }
        let out = self.order[self.pos..self.pos + self.batch].to_vec();
        self.pos += self.batch;
        out
    }

    pub fn batches_per_epoch(&self) -> usize {
        self.order.len() / self.batch
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::ModelInfo;
    use std::collections::BTreeMap;

    fn info() -> ModelInfo {
        ModelInfo {
            name: "t".into(),
            n_layers: 2,
            d_model: 16,
            n_heads: 2,
            d_head: 8,
            d_ff: 32,
            vocab: 256,
            seq_len: 16,
            causal: false,
            ffn_ladder: vec![],
            head_ladder: vec![],
            measured_ffn: vec![],
            tasks: BTreeMap::new(),
        }
    }

    #[test]
    fn deterministic_generation() {
        let a = load_sized(&info(), "sst2-syn", 64, 32);
        let b = load_sized(&info(), "sst2-syn", 64, 32);
        assert_eq!(a.train[0].ids, b.train[0].ids);
        assert_eq!(a.dev[5].label, b.dev[5].label);
    }

    #[test]
    fn splits_are_disjoint_streams() {
        let d = load_sized(&info(), "qnli-syn", 64, 64);
        assert_ne!(d.train[0].ids, d.dev[0].ids);
        assert_ne!(d.dev[0].ids, d.test[0].ids);
    }

    #[test]
    fn cls_labels_in_range_and_balanced() {
        let d = load_sized(&info(), "mnli-syn", 600, 60);
        assert_eq!(d.n_classes, 3);
        let mut counts = [0usize; 3];
        for e in &d.train {
            assert!((0..3).contains(&(e.label as usize)));
            counts[e.label as usize] += 1;
        }
        for c in counts {
            assert!(c > 120, "{counts:?}");
        }
    }

    #[test]
    fn span_label_points_at_trigger() {
        let d = load_sized(&info(), "squad-syn", 64, 16);
        for e in &d.train {
            let pos = e.label as usize;
            assert!(pos >= 2 && pos < d.seq_len);
            let t = e.ids[pos];
            // trigger occurs exactly once outside position 0
            let occurrences = e.ids[1..].iter().filter(|&&x| x == t).count();
            assert_eq!(occurrences, 1);
        }
    }

    #[test]
    fn lm_has_predictable_structure() {
        // successor bigrams should appear far more often than chance
        let d = load_sized(&info(), "corpus-syn", 64, 16);
        let mut best = std::collections::HashMap::new();
        for e in &d.train {
            for w in e.ids.windows(2) {
                *best.entry((w[0], w[1])).or_insert(0usize) += 1;
            }
        }
        let max = best.values().max().copied().unwrap_or(0);
        assert!(max >= 5, "bigram structure too weak: {max}");
    }

    #[test]
    fn batch_shapes() {
        let d = load_sized(&info(), "sst2-syn", 64, 16);
        let (ids, labels) = d.batch(&[0, 1, 2, 3]);
        assert_eq!(ids.len(), 4 * d.seq_len);
        assert_eq!(labels.len(), 4);
        let lm = load_sized(&info(), "corpus-syn", 64, 16);
        let (ids, labels) = lm.batch(&[0, 1]);
        assert_eq!(labels.len(), ids.len());
    }

    #[test]
    fn batcher_covers_epoch() {
        let mut b = Batcher::new(100, 10, 0);
        let mut seen = vec![false; 100];
        for _ in 0..10 {
            for i in b.next() {
                seen[i] = true;
            }
        }
        assert!(seen.iter().all(|&x| x));
    }
}
