//! Inference environments: the typed deployment target every pipeline
//! stage prices against (paper §3.2 — "inference-aware" means the SAME
//! algorithm retargets any (device, regime, batch-shape) environment).
//!
//! Before this module, the environment was a loose `(model, regime)`
//! string pair plus a bare [`LatencyTable`] threaded through ~6 free
//! functions; the pruner, the experiment drivers, and the family
//! coordinator could each end up pricing against a *different* table
//! without anything noticing. [`InferenceEnv`] bundles device, regime,
//! batch shape, and a cost model into one value that is constructed
//! once and handed to every consumer — the SPDY search certifies a
//! speedup against exactly the environment the router later admits
//! requests with.
//!
//! Two cost-model sources exist, mirroring DESIGN.md §3:
//!
//! * [`InferenceEnv::measured`] — wraps a table measured through the
//!   PJRT runtime ([`crate::latency::measure_cpu`]), the paper's real
//!   methodology;
//! * [`InferenceEnv::analytic`] — derives a table from a roofline
//!   [`Device`] model at arbitrary [`ArchDims`] (V100/A100 are
//!   unavailable hardware; paper Tables 3 & 7).
//!
//! The pricing surface itself is the [`CostModel`] trait, implemented
//! by both [`InferenceEnv`] and the underlying [`LatencyTable`], so
//! code that only prices profiles never needs to know which source
//! produced the numbers.
//!
//! Beyond the anchor batch shape, an env can carry a **seq-length
//! sweep** ([`InferenceEnv::with_seq_sweep`], DESIGN.md §9): one
//! `(padded seq, relative cost scale)` row per serving shape bucket,
//! normalized to 1.0 at the anchor seq. The sweep is what lets the
//! family coordinator price a *shaped* batch — [`InferenceEnv::batch_time`]
//! scales the anchor estimate by the request bucket — and what
//! [`InferenceEnv::bucket_ladder`] derives the coordinator's default
//! shape-bucket ladder from. Sources: [`crate::latency::analytic_seq_sweep`]
//! for roofline envs (the latency-regime seq dependence is analytic)
//! and [`crate::latency::regime_sweep`] for measured ones (one row per
//! lowered block-artifact shape).

use std::path::Path;

use anyhow::{anyhow, Result};

use crate::latency::{self, ArchDims, Device, LatencyTable};
use crate::util::json::Json;

/// Batch regime of an environment: which static shapes the latency
/// numbers were taken at (paper §4.2 — the regimes prune differently).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Regime {
    /// large-batch serving (throughput-bound)
    Throughput,
    /// batch-1 short prompts (latency-bound)
    Latency,
}

impl Regime {
    /// Parse the canonical regime name.
    pub fn parse(s: &str) -> Result<Regime> {
        match s {
            "throughput" => Ok(Regime::Throughput),
            "latency" => Ok(Regime::Latency),
            other => Err(anyhow!("unknown regime `{other}` (throughput|latency)")),
        }
    }

    /// Canonical name (inverse of [`Regime::parse`]); also the table /
    /// artifact naming component.
    pub fn name(&self) -> &'static str {
        match self {
            Regime::Throughput => "throughput",
            Regime::Latency => "latency",
        }
    }
}

/// Where an environment's numbers came from.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CostSource {
    /// timed through the PJRT runtime on real hardware
    Measured,
    /// derived from a roofline device model
    Analytic,
}

impl CostSource {
    fn name(&self) -> &'static str {
        match self {
            CostSource::Measured => "measured",
            CostSource::Analytic => "analytic",
        }
    }

    fn parse(s: &str) -> Result<CostSource> {
        match s {
            "measured" => Ok(CostSource::Measured),
            "analytic" => Ok(CostSource::Analytic),
            other => Err(anyhow!("unknown cost source `{other}`")),
        }
    }
}

/// The pricing surface every inference environment exposes: per-block
/// times plus the derived whole-model quantities the SPDY search, the
/// baselines, and the family router all consume.
pub trait CostModel {
    /// Attention-block time with `heads` heads remaining.
    fn attn_time(&self, heads: usize) -> f64;
    /// FFN-block time at `width` intermediate columns remaining.
    fn mlp_time(&self, width: usize) -> f64;
    /// Fixed per-model time outside the prunable blocks.
    fn overhead(&self) -> f64;
    /// Dense per-layer anatomy `(heads, ffn width)` the times anchor to.
    fn dense_profile(&self) -> (usize, usize);

    /// End-to-end model time for a per-layer `(heads, ffn)` profile.
    fn model_time(&self, profile: &[(usize, usize)]) -> f64 {
        self.overhead()
            + profile.iter().map(|&(h, f)| self.attn_time(h) + self.mlp_time(f)).sum::<f64>()
    }

    /// End-to-end time of the dense model at `n_layers` layers.
    fn dense_time(&self, n_layers: usize) -> f64 {
        let (h, f) = self.dense_profile();
        self.model_time(&vec![(h, f); n_layers])
    }

    /// Estimated speedup of a per-layer profile over the dense model.
    fn speedup(&self, profile: &[(usize, usize)]) -> f64 {
        self.dense_time(profile.len()) / self.model_time(profile)
    }

    // ---- compound-compression pricing (DESIGN.md §13) -----------------
    //
    // Quantized and low-rank variants are priced through the SAME cost
    // model the pruner certifies against — the free-standing
    // `quant::CpuEngineModel` pricer is retired; its engine constants
    // (int8 factor 2.5×, sub-linear sparsity exponent 0.75) fold in
    // here so every axis shares one certification surface.

    /// INT8-over-f32 compute speedup factor of the execution engine
    /// (DeepSparse-like; folded from `quant::CpuEngineModel::int8_factor`).
    fn quant_factor(&self) -> f64 {
        2.5
    }

    /// Attention-block time at `heads` heads with int8 weights.
    fn attn_time_quant(&self, heads: usize) -> f64 {
        self.attn_time(heads) / self.quant_factor()
    }

    /// FFN-block time at `width` intermediate columns with int8 weights.
    fn mlp_time_quant(&self, width: usize) -> f64 {
        self.mlp_time(width) / self.quant_factor()
    }

    /// Whole-model compound-engine time: dense time scaled by the
    /// structurally-remaining density, the engine's sub-linear
    /// unstructured-sparsity law `(1 − s)^0.75`, and (optionally) the
    /// int8 factor. Replaces `quant::CpuEngineModel::latency`.
    fn compound_time(&self, n_layers: usize, struct_density: f64, sparsity: f64, int8: bool) -> f64 {
        let mut t = (self.dense_time(n_layers) - self.overhead()) * struct_density;
        t *= (1.0 - sparsity).powf(0.75);
        if int8 {
            t /= self.quant_factor();
        }
        self.overhead() + t
    }

    /// Speedup companion of [`CostModel::compound_time`]. Replaces
    /// `quant::CpuEngineModel::speedup`.
    fn compound_speedup(
        &self,
        n_layers: usize,
        struct_density: f64,
        sparsity: f64,
        int8: bool,
    ) -> f64 {
        self.dense_time(n_layers) / self.compound_time(n_layers, struct_density, sparsity, int8)
    }
}

impl CostModel for LatencyTable {
    fn attn_time(&self, heads: usize) -> f64 {
        LatencyTable::attn_time(self, heads)
    }

    fn mlp_time(&self, width: usize) -> f64 {
        LatencyTable::mlp_time(self, width)
    }

    fn overhead(&self) -> f64 {
        self.overhead
    }

    fn dense_profile(&self) -> (usize, usize) {
        (self.attn.len() - 1, self.mlp[0].0)
    }
}

/// A fully-specified inference environment: device + regime + batch
/// shape + cost model. This is the ONE value that travels from Hessian
/// capture through SPDY certification to family-serving admission; no
/// raw latency table crosses a module boundary outside `env/` and
/// `latency/` themselves.
#[derive(Clone, Debug, PartialEq)]
pub struct InferenceEnv {
    device: String,
    regime: Regime,
    batch: usize,
    seq: usize,
    source: CostSource,
    table: LatencyTable,
    /// per-seq-bucket relative cost scale `(padded seq, scale)`,
    /// ascending in seq, `1.0` at the anchor seq; empty = no sweep
    sweep: Vec<(usize, f64)>,
}

impl InferenceEnv {
    /// Environment from a measured [`LatencyTable`]. Device and regime
    /// are taken from the table; the batch shape starts unknown `(0,
    /// 0)` — attach it with [`InferenceEnv::with_batch_shape`] when the
    /// measuring artifacts' shapes are available.
    pub fn measured(table: LatencyTable) -> Result<InferenceEnv> {
        let regime = Regime::parse(&table.regime)?;
        if table.attn.is_empty() || table.mlp.is_empty() {
            return Err(anyhow!("latency table for `{}` has empty blocks", table.model));
        }
        Ok(InferenceEnv {
            device: table.device.clone(),
            regime,
            batch: 0,
            seq: 0,
            source: CostSource::Measured,
            table,
            sweep: Vec::new(),
        })
    }

    /// Environment from a roofline [`Device`] model at `dims`,
    /// pricing the FFN ladder `mlp_widths` (paper Tables 3 & 7).
    pub fn analytic(
        dev: Device,
        dims: &ArchDims,
        regime: Regime,
        mlp_widths: &[usize],
    ) -> InferenceEnv {
        let table = latency::analytic(dev, dims, regime.name(), mlp_widths);
        InferenceEnv {
            device: dev.name().to_string(),
            regime,
            batch: dims.batch,
            seq: dims.seq,
            source: CostSource::Analytic,
            table,
            sweep: Vec::new(),
        }
    }

    /// [`InferenceEnv::analytic`] with a seq-length sweep attached:
    /// one relative-cost row per padded seq in `seqs`, derived from the
    /// same roofline model ([`crate::latency::analytic_seq_sweep`]).
    /// This is the batch-shape-aware constructor for the latency
    /// regime, where cost depends strongly on the padded seq.
    pub fn analytic_swept(
        dev: Device,
        dims: &ArchDims,
        regime: Regime,
        mlp_widths: &[usize],
        seqs: &[usize],
    ) -> InferenceEnv {
        InferenceEnv::analytic(dev, dims, regime, mlp_widths)
            .with_seq_sweep(latency::analytic_seq_sweep(dev, dims, seqs))
    }

    /// Record the static `(batch, seq)` shape the numbers were taken at.
    pub fn with_batch_shape(mut self, batch: usize, seq: usize) -> InferenceEnv {
        self.batch = batch;
        self.seq = seq;
        self
    }

    /// A copy of this env with every priced time scaled by `skew` —
    /// the per-device latency skew of one fleet worker (DESIGN.md §10):
    /// `skew > 1.0` is a slower device, `< 1.0` a faster one. Relative
    /// pricing (speedups, routing order) is unchanged because attn,
    /// mlp and overhead all scale together; only absolute batch-time
    /// estimates move. Non-finite or non-positive skews are ignored
    /// (returns an unmodified copy) so a corrupt fleet spec degrades
    /// to homogeneous pricing instead of poisoning admission.
    pub fn with_device_skew(&self, skew: f64) -> InferenceEnv {
        let mut env = self.clone();
        if !skew.is_finite() || skew <= 0.0 || skew == 1.0 {
            return env;
        }
        for t in &mut env.table.attn {
            *t *= skew;
        }
        for (_, t) in &mut env.table.mlp {
            *t *= skew;
        }
        env.table.overhead *= skew;
        env
    }

    /// Attach a seq-length sweep: `(padded seq, relative cost scale)`
    /// rows, scale `1.0` meaning "costs exactly like the anchor seq".
    /// Rows are sorted ascending and non-positive seqs dropped; an
    /// empty sweep leaves the env shape-agnostic (scale always 1.0).
    pub fn with_seq_sweep(mut self, mut sweep: Vec<(usize, f64)>) -> InferenceEnv {
        sweep.retain(|&(s, scale)| s > 0 && scale.is_finite() && scale > 0.0);
        sweep.sort_by_key(|&(s, _)| s);
        sweep.dedup_by_key(|p| p.0);
        self.sweep = sweep;
        self
    }

    /// The attached seq sweep (empty when none was recorded).
    pub fn seq_sweep(&self) -> &[(usize, f64)] {
        &self.sweep
    }

    /// Relative cost scale at padded length `seq`: linear interpolation
    /// between sweep rows, clamped at the ends. Without a sweep (or
    /// with `seq == 0`, "unknown") the scale is `1.0` — the anchor
    /// estimate is all the env knows.
    pub fn seq_scale(&self, seq: usize) -> f64 {
        if seq == 0 || self.sweep.is_empty() {
            return 1.0;
        }
        let first = self.sweep[0];
        let last = self.sweep[self.sweep.len() - 1];
        if seq <= first.0 {
            return first.1;
        }
        if seq >= last.0 {
            return last.1;
        }
        for pair in self.sweep.windows(2) {
            let (lo, hi) = (pair[0], pair[1]);
            if seq >= lo.0 && seq <= hi.0 {
                let frac = (seq - lo.0) as f64 / (hi.0 - lo.0) as f64;
                return lo.1 + frac * (hi.1 - lo.1);
            }
        }
        1.0
    }

    /// Estimate of ONE batched forward of `profile` at shape
    /// `(batch, seq)`: the anchor [`CostModel::model_time`] scaled
    /// linearly in batch (relative to the anchor batch, when both are
    /// known) and by [`InferenceEnv::seq_scale`]. This is the pricing
    /// behind the coordinator's shaped-batch admission estimates
    /// (DESIGN.md §9); at the anchor shape it equals `model_time`.
    pub fn batch_time(&self, profile: &[(usize, usize)], batch: usize, seq: usize) -> f64 {
        let batch_factor = if self.batch > 0 && batch > 0 {
            batch as f64 / self.batch as f64
        } else {
            1.0
        };
        self.model_time(profile) * batch_factor * self.seq_scale(seq)
    }

    /// Default shape-bucket ladder for serving against this env: one
    /// `(anchor batch, seq)` bucket per sweep row, or the single anchor
    /// shape when no sweep is recorded, or empty when the shape is
    /// unknown — the coordinator then serves only the generic graph.
    pub fn bucket_ladder(&self) -> Vec<(usize, usize)> {
        if !self.sweep.is_empty() {
            let b = self.batch.max(1);
            return self.sweep.iter().map(|&(s, _)| (b, s)).collect();
        }
        if self.batch > 0 && self.seq > 0 {
            return vec![(self.batch, self.seq)];
        }
        Vec::new()
    }

    /// Device name (canonical for analytic devices; as-measured otherwise).
    pub fn device_name(&self) -> &str {
        &self.device
    }

    /// Batch regime.
    pub fn regime(&self) -> Regime {
        self.regime
    }

    /// Static `(batch, seq)` shape; `(0, 0)` when unrecorded.
    pub fn batch_shape(&self) -> (usize, usize) {
        (self.batch, self.seq)
    }

    /// Whether the numbers were measured or derived.
    pub fn source(&self) -> CostSource {
        self.source
    }

    /// The underlying priced table (rendering, ladder inspection). The
    /// table never needs to leave the env: consumers price through
    /// [`CostModel`].
    pub fn table(&self) -> &LatencyTable {
        &self.table
    }

    /// One-line human description for logs and progress hooks.
    pub fn describe(&self) -> String {
        format!(
            "{} on {} ({} regime, {} cost)",
            self.table.model,
            self.device,
            self.regime.name(),
            self.source.name()
        )
    }

    // ----------------------------------------------------------- persist

    /// Serialize to the on-disk JSON form (session checkpoints). The
    /// `sweep` key is present only when a seq sweep is attached, so
    /// pre-sweep readers and files stay compatible both ways.
    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("device", Json::Str(self.device.clone())),
            ("regime", Json::Str(self.regime.name().to_string())),
            ("batch", Json::Num(self.batch as f64)),
            ("seq", Json::Num(self.seq as f64)),
            ("source", Json::Str(self.source.name().to_string())),
            ("table", self.table.to_json()),
        ];
        if !self.sweep.is_empty() {
            pairs.push((
                "sweep",
                Json::Arr(
                    self.sweep
                        .iter()
                        .map(|&(s, scale)| {
                            Json::Arr(vec![Json::Num(s as f64), Json::Num(scale)])
                        })
                        .collect(),
                ),
            ));
        }
        Json::obj(pairs)
    }

    /// Parse the on-disk JSON form. A `sweep` read from disk goes
    /// through [`InferenceEnv::with_seq_sweep`]'s normalization (sort,
    /// dedup, drop non-positive rows), so [`InferenceEnv::seq_scale`]'s
    /// ordering invariants hold even for hand-edited files.
    pub fn from_json(j: &Json) -> Result<InferenceEnv> {
        let table =
            LatencyTable::from_json(j.get("table").ok_or_else(|| anyhow!("env: no table"))?)?;
        let sweep = j
            .get("sweep")
            .and_then(Json::as_arr)
            .unwrap_or(&[])
            .iter()
            .filter_map(|e| Some((e.idx(0)?.as_usize()?, e.idx(1)?.as_f64()?)))
            .collect();
        Ok(InferenceEnv {
            device: j.req_str("device").to_string(),
            regime: Regime::parse(j.req_str("regime"))?,
            batch: j.get("batch").and_then(Json::as_usize).unwrap_or(0),
            seq: j.get("seq").and_then(Json::as_usize).unwrap_or(0),
            source: CostSource::parse(j.req_str("source"))?,
            table,
            sweep: Vec::new(),
        }
        .with_seq_sweep(sweep))
    }

    /// Write the env as pretty JSON, creating parent directories.
    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(d) = path.parent() {
            std::fs::create_dir_all(d)?;
        }
        std::fs::write(path, self.to_json().to_pretty())?;
        Ok(())
    }

    /// Load an env from disk.
    pub fn load(path: &Path) -> Result<InferenceEnv> {
        let text = std::fs::read_to_string(path)?;
        InferenceEnv::from_json(&Json::parse(&text).map_err(|e| anyhow!(e))?)
    }
}

impl CostModel for InferenceEnv {
    fn attn_time(&self, heads: usize) -> f64 {
        self.table.attn_time(heads)
    }

    fn mlp_time(&self, width: usize) -> f64 {
        self.table.mlp_time(width)
    }

    fn overhead(&self) -> f64 {
        self.table.overhead
    }

    fn dense_profile(&self) -> (usize, usize) {
        (self.table.attn.len() - 1, self.table.mlp[0].0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn table() -> LatencyTable {
        LatencyTable {
            model: "m".into(),
            device: "test".into(),
            regime: "throughput".into(),
            attn: vec![0.0, 1.0e-3, 1.8e-3, 2.5e-3, 3.1e-3],
            mlp: vec![(512, 8e-3), (256, 4.2e-3), (64, 1.5e-3), (0, 0.0)],
            overhead: 1e-3,
        }
    }

    #[test]
    fn measured_env_prices_like_its_table() {
        let t = table();
        let env = InferenceEnv::measured(t.clone()).unwrap();
        assert_eq!(env.regime(), Regime::Throughput);
        assert_eq!(env.source(), CostSource::Measured);
        assert_eq!(env.dense_profile(), (4, 512));
        for h in 0..=4 {
            assert_eq!(CostModel::attn_time(&env, h), t.attn_time(h));
        }
        for w in [0usize, 33, 256, 384, 512] {
            assert_eq!(CostModel::mlp_time(&env, w), t.mlp_time(w));
        }
        let profile = vec![(2usize, 256usize), (4, 512)];
        assert_eq!(env.model_time(&profile), t.model_time(&profile));
        assert_eq!(env.speedup(&profile), t.speedup(&profile));
        assert_eq!(CostModel::dense_time(&env, 3), t.dense_time(3));
    }

    #[test]
    fn measured_rejects_unknown_regime() {
        let mut t = table();
        t.regime = "weird".into();
        assert!(InferenceEnv::measured(t).is_err());
    }

    #[test]
    fn analytic_env_records_device_and_shape() {
        let dims = ArchDims::bert_base_paper();
        let env =
            InferenceEnv::analytic(Device::V100Sim, &dims, Regime::Throughput, &[3072, 302, 33]);
        assert_eq!(env.device_name(), "v100-sim");
        assert_eq!(env.source(), CostSource::Analytic);
        assert_eq!(env.batch_shape(), (128, 128));
        // shrinking the MLP must speed the block up
        assert!(CostModel::mlp_time(&env, 33) < CostModel::mlp_time(&env, 3072));
    }

    #[test]
    fn device_skew_scales_absolute_times_not_speedups() {
        let env = InferenceEnv::measured(table()).unwrap().with_batch_shape(8, 128);
        let slow = env.with_device_skew(1.5);
        let profile = vec![(2usize, 256usize), (4, 512)];
        let t = env.model_time(&profile);
        assert!((slow.model_time(&profile) - 1.5 * t).abs() < 1e-12);
        assert!((slow.batch_time(&profile, 16, 128) - 1.5 * env.batch_time(&profile, 16, 128))
            .abs()
            < 1e-12);
        // relative pricing unchanged: routing order survives skew
        assert!((slow.speedup(&profile) - env.speedup(&profile)).abs() < 1e-12);
        // degenerate skews are ignored
        for bad in [f64::NAN, f64::INFINITY, 0.0, -2.0] {
            assert_eq!(env.with_device_skew(bad), env);
        }
        assert_eq!(env.with_device_skew(1.0), env);
    }

    #[test]
    fn json_roundtrip_preserves_everything() {
        let env = InferenceEnv::measured(table()).unwrap().with_batch_shape(8, 128);
        let j = env.to_json();
        let back = InferenceEnv::from_json(&j).unwrap();
        assert_eq!(env, back);
        // through text as well (checkpoint files go through the parser)
        let back2 =
            InferenceEnv::from_json(&Json::parse(&j.to_pretty()).unwrap()).unwrap();
        assert_eq!(env, back2);
    }

    #[test]
    fn seq_scale_interpolates_and_clamps() {
        let env = InferenceEnv::measured(table())
            .unwrap()
            .with_batch_shape(8, 128)
            .with_seq_sweep(vec![(128, 1.0), (32, 0.3), (64, 0.55), (0, 9.0), (64, 42.0)]);
        // non-positive seqs dropped, duplicates deduped, rows sorted
        assert_eq!(env.seq_sweep(), &[(32, 0.3), (64, 0.55), (128, 1.0)]);
        assert_eq!(env.seq_scale(32), 0.3);
        assert_eq!(env.seq_scale(128), 1.0);
        // clamped outside the sweep, interpolated inside
        assert_eq!(env.seq_scale(8), 0.3);
        assert_eq!(env.seq_scale(512), 1.0);
        let mid = env.seq_scale(48);
        assert!((mid - 0.425).abs() < 1e-12, "{mid}");
        // unknown seq or no sweep → anchor scale
        assert_eq!(env.seq_scale(0), 1.0);
        assert_eq!(InferenceEnv::measured(table()).unwrap().seq_scale(64), 1.0);
    }

    #[test]
    fn batch_time_scales_anchor_estimate() {
        let env = InferenceEnv::measured(table())
            .unwrap()
            .with_batch_shape(8, 128)
            .with_seq_sweep(vec![(32, 0.25), (128, 1.0)]);
        let profile = vec![(2usize, 256usize); 2];
        let anchor = env.model_time(&profile);
        // at the anchor shape, batch_time == model_time
        assert!((env.batch_time(&profile, 8, 128) - anchor).abs() < 1e-15);
        // half the batch at a quarter-cost seq bucket
        let t = env.batch_time(&profile, 4, 32);
        assert!((t - anchor * 0.5 * 0.25).abs() < 1e-15, "{t} vs {anchor}");
        // unknown anchor batch → no batch scaling
        let flat = InferenceEnv::measured(table()).unwrap();
        assert_eq!(flat.batch_time(&profile, 4, 32), flat.model_time(&profile));
    }

    #[test]
    fn bucket_ladder_follows_sweep_then_anchor() {
        let base = InferenceEnv::measured(table()).unwrap();
        assert!(base.bucket_ladder().is_empty());
        let anchored = base.clone().with_batch_shape(8, 128);
        assert_eq!(anchored.bucket_ladder(), vec![(8, 128)]);
        let swept = anchored.with_seq_sweep(vec![(128, 1.0), (32, 0.3)]);
        assert_eq!(swept.bucket_ladder(), vec![(8, 32), (8, 128)]);
    }

    #[test]
    fn json_roundtrip_preserves_sweep() {
        let env = InferenceEnv::measured(table())
            .unwrap()
            .with_batch_shape(8, 128)
            .with_seq_sweep(vec![(32, 0.25), (64, 0.5), (128, 1.0)]);
        let back = InferenceEnv::from_json(&env.to_json()).unwrap();
        assert_eq!(env, back);
        let back2 =
            InferenceEnv::from_json(&Json::parse(&env.to_json().to_pretty()).unwrap()).unwrap();
        assert_eq!(env, back2);
        // sweepless envs keep their pre-sweep JSON shape (no key)
        let plain = InferenceEnv::measured(table()).unwrap();
        assert!(plain.to_json().get("sweep").is_none());
    }

    #[test]
    fn from_json_normalizes_hand_written_sweeps() {
        // a sweep written out of order / with a zero seq by hand or by
        // another tool must come back normalized, or seq_scale's
        // clamp-and-interpolate invariants silently break
        let j = Json::obj(vec![
            ("device", Json::Str("test".into())),
            ("regime", Json::Str("throughput".into())),
            ("batch", Json::Num(8.0)),
            ("seq", Json::Num(128.0)),
            ("source", Json::Str("measured".into())),
            ("table", table().to_json()),
            (
                "sweep",
                Json::Arr(vec![
                    Json::Arr(vec![Json::Num(128.0), Json::Num(1.0)]),
                    Json::Arr(vec![Json::Num(0.0), Json::Num(5.0)]),
                    Json::Arr(vec![Json::Num(32.0), Json::Num(0.3)]),
                ]),
            ),
        ]);
        let env = InferenceEnv::from_json(&j).unwrap();
        assert_eq!(env.seq_sweep(), &[(32, 0.3), (128, 1.0)]);
        assert_eq!(env.seq_scale(64), 0.3 + (64.0 - 32.0) / 96.0 * 0.7);
    }

    #[test]
    fn regime_parse_name_inverse() {
        for r in [Regime::Throughput, Regime::Latency] {
            assert_eq!(Regime::parse(r.name()).unwrap(), r);
        }
        assert!(Regime::parse("batchy").is_err());
    }
}
