//! Typed per-layer compression choices — the compound lattice the
//! inference-aware DP ranges over (DESIGN.md §13).
//!
//! ZipLM's SPDY solve originally chose one structured-pruning *level*
//! per module. This module widens the per-module choice set to int8
//! quantization and low-rank FFN factorization (plus lawful
//! compositions like prune-then-quant) behind one typed lattice:
//!
//! * [`LayerChoice`] — what is done to one module (the axis + its knob);
//! * [`Choice`] — a lattice entry: a [`LayerChoice`] with an env-priced
//!   runtime `cost` and an OBS-style reconstruction `loss`;
//! * [`ChoiceSet`] — all candidate choices for one module
//!   (`choices[0]` is always the dense prune level, mirroring
//!   `ModuleLevels::options[0]`);
//! * [`ChoiceProblem`] — the whole-model lattice; [`ChoiceProblem::lower`]
//!   maps it onto the unchanged `spdy::solve_dp`, carrying each
//!   choice's `(cost, loss)` into a `LevelOpt`'s `(cost, prior)`
//!   verbatim. A prune-only lattice therefore lowers to the exact
//!   `SpdyProblem` the legacy path built, so restricting the lattice
//!   to pruning reproduces the old DP bit-identically
//!   (equivalence-tested below and in `tests/proptests.rs`);
//! * [`CompressionProfile`] — a solved assignment, one
//!   [`ModuleChoice`] per module; the typed replacement for the raw
//!   `Vec<usize>` / `Vec<(usize, usize)>` profile surfaces that used
//!   to leak out of `spdy/`.

use anyhow::{anyhow, bail, Result};

use crate::spdy::{self, LevelOpt, ModuleLevels, SearchCfg, SpdyProblem};
use crate::util::json::Json;

/// Weight-quantization scheme. One engine is seeded today; the enum
/// keeps the manifest schema ready for more without a version bump.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum QuantScheme {
    /// symmetric per-row int8 (see `quant::int8_tensor`)
    Int8,
}

impl QuantScheme {
    /// Stable name used in manifests and reports.
    pub fn name(self) -> &'static str {
        match self {
            QuantScheme::Int8 => "int8",
        }
    }

    /// Inverse of [`QuantScheme::name`].
    pub fn parse(s: &str) -> Result<QuantScheme> {
        match s {
            "int8" => Ok(QuantScheme::Int8),
            other => Err(anyhow!("unknown quant scheme {other:?} (expected \"int8\")")),
        }
    }
}

/// One module's compression choice: the axis plus its knob. `Prune`
/// with the dense remaining count is the identity choice.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LayerChoice {
    /// structured pruning to `remaining` heads (attn) / columns (ffn)
    Prune { remaining: usize },
    /// weight quantization at dense shape
    Quant { scheme: QuantScheme },
    /// rank-`rank` factorization of the FFN pair (FFN modules only)
    LowRank { rank: usize },
    /// prune to `remaining`, then quantize the surviving weights
    PruneQuant { remaining: usize, scheme: QuantScheme },
}

impl LayerChoice {
    /// Axis label used in manifests, reports, and mix summaries.
    pub fn axis(&self) -> &'static str {
        match self {
            LayerChoice::Prune { .. } => "prune",
            LayerChoice::Quant { .. } => "quant",
            LayerChoice::LowRank { .. } => "lowrank",
            LayerChoice::PruneQuant { .. } => "prune+quant",
        }
    }

    /// Structural remaining units (heads / FFN columns) after this
    /// choice; quantized and low-rank variants keep the dense shape.
    pub fn remaining(&self, dense: usize) -> usize {
        match *self {
            LayerChoice::Prune { remaining } | LayerChoice::PruneQuant { remaining, .. } => {
                remaining
            }
            LayerChoice::Quant { .. } | LayerChoice::LowRank { .. } => dense,
        }
    }

    /// JSON fields describing this choice (merged into the module
    /// object by [`ModuleChoice::to_json`]).
    fn json_pairs(&self) -> Vec<(&'static str, Json)> {
        let mut out = vec![("axis", Json::Str(self.axis().into()))];
        match *self {
            LayerChoice::Prune { remaining } => {
                out.push(("remaining", Json::Num(remaining as f64)));
            }
            LayerChoice::Quant { scheme } => {
                out.push(("scheme", Json::Str(scheme.name().into())));
            }
            LayerChoice::LowRank { rank } => out.push(("rank", Json::Num(rank as f64))),
            LayerChoice::PruneQuant { remaining, scheme } => {
                out.push(("remaining", Json::Num(remaining as f64)));
                out.push(("scheme", Json::Str(scheme.name().into())));
            }
        }
        out
    }

    fn from_json(j: &Json) -> Result<LayerChoice> {
        let axis = j
            .get("axis")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("choice missing \"axis\""))?;
        let remaining = || {
            j.get("remaining")
                .and_then(Json::as_usize)
                .ok_or_else(|| anyhow!("{axis} choice missing \"remaining\""))
        };
        let scheme = || -> Result<QuantScheme> {
            QuantScheme::parse(
                j.get("scheme")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow!("{axis} choice missing \"scheme\""))?,
            )
        };
        match axis {
            "prune" => Ok(LayerChoice::Prune { remaining: remaining()? }),
            "quant" => Ok(LayerChoice::Quant { scheme: scheme()? }),
            "lowrank" => Ok(LayerChoice::LowRank {
                rank: j
                    .get("rank")
                    .and_then(Json::as_usize)
                    .ok_or_else(|| anyhow!("lowrank choice missing \"rank\""))?,
            }),
            "prune+quant" => {
                Ok(LayerChoice::PruneQuant { remaining: remaining()?, scheme: scheme()? })
            }
            other => Err(anyhow!("unknown choice axis {other:?}")),
        }
    }
}

/// One lattice entry: a choice priced by the environment's cost model
/// and scored by its calibration-set reconstruction loss.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Choice {
    /// what the entry does to the module
    pub choice: LayerChoice,
    /// env-priced runtime of the module under this choice (same units
    /// as `CostModel::attn_time`/`mlp_time`)
    pub cost: f64,
    /// OBS-style loss score (prune: level prior; quant: calibration
    /// error of int8; low-rank: truncated-SVD residual) — carried into
    /// the DP as the `prior`
    pub loss: f64,
}

/// All candidate choices for one module. Invariant: `choices[0]` is
/// the dense `Prune` level (cost of the uncompressed module, loss 0),
/// mirroring `ModuleLevels::options[0]`.
#[derive(Clone, Debug, PartialEq)]
pub struct ChoiceSet {
    /// transformer layer index
    pub layer: usize,
    /// true = attention module, false = FFN module
    pub is_attn: bool,
    /// the lattice entries, dense first
    pub choices: Vec<Choice>,
}

impl ChoiceSet {
    /// Structural units of the dense (first) choice.
    pub fn dense_remaining(&self) -> usize {
        match self.choices.first() {
            Some(c) => match c.choice {
                LayerChoice::Prune { remaining } => remaining,
                _ => 0,
            },
            None => 0,
        }
    }

    /// Index of the first choice on `axis`, if any.
    pub fn find_axis(&self, axis: &str) -> Option<usize> {
        self.choices.iter().position(|c| c.choice.axis() == axis)
    }
}

/// The whole-model choice lattice the widened DP solves over. Same
/// shape as `SpdyProblem` (one set per module, layer-major with attn
/// before ffn) so solutions index both identically.
#[derive(Clone, Debug, PartialEq)]
pub struct ChoiceProblem {
    /// per-module choice sets
    pub modules: Vec<ChoiceSet>,
    /// profile-independent cost floor (embeddings, head, …)
    pub overhead: f64,
}

impl ChoiceProblem {
    /// Lift a legacy prune-only problem into the lattice: every
    /// `LevelOpt` becomes a `Prune` choice carrying the same
    /// `(cost, prior)` f64s, so [`ChoiceProblem::lower`] of the result
    /// is the identity on the numbers the DP reads.
    pub fn from_spdy(p: &SpdyProblem) -> ChoiceProblem {
        ChoiceProblem {
            modules: p
                .modules
                .iter()
                .map(|m| ChoiceSet {
                    layer: m.layer,
                    is_attn: m.is_attn,
                    choices: m
                        .options
                        .iter()
                        .map(|o| Choice {
                            choice: LayerChoice::Prune { remaining: o.remaining },
                            cost: o.cost,
                            loss: o.prior,
                        })
                        .collect(),
                })
                .collect(),
            overhead: p.overhead,
        }
    }

    /// Lower the lattice onto the unchanged level-index DP: each
    /// choice's `(cost, loss)` becomes a `LevelOpt`'s `(cost, prior)`
    /// verbatim; the `remaining` field records the structural shape
    /// (dense for quant/low-rank) and is not read by `solve_dp`.
    pub fn lower(&self) -> SpdyProblem {
        SpdyProblem {
            modules: self
                .modules
                .iter()
                .map(|s| ModuleLevels {
                    layer: s.layer,
                    is_attn: s.is_attn,
                    options: s
                        .choices
                        .iter()
                        .map(|c| LevelOpt {
                            remaining: c.choice.remaining(s.dense_remaining()),
                            cost: c.cost,
                            prior: c.loss,
                        })
                        .collect(),
                })
                .collect(),
            overhead: self.overhead,
        }
    }

    /// Total cost with every module at its dense choice.
    pub fn dense_cost(&self) -> f64 {
        self.overhead + self.modules.iter().map(|s| s.choices[0].cost).sum::<f64>()
    }

    /// Cheapest achievable total cost.
    pub fn min_cost(&self) -> f64 {
        self.overhead
            + self
                .modules
                .iter()
                .map(|s| s.choices.iter().map(|c| c.cost).fold(f64::INFINITY, f64::min))
                .sum::<f64>()
    }

    /// Total cost of a choice-index assignment.
    pub fn profile_cost(&self, profile: &[usize]) -> f64 {
        self.overhead
            + self
                .modules
                .iter()
                .zip(profile)
                .map(|(s, &ci)| s.choices[ci].cost)
                .sum::<f64>()
    }

    /// Sum of squared losses of an assignment — the DP's objective at
    /// unit coefficients (the `proxy_error` convention of `exp::repro`).
    pub fn loss_sq(&self, profile: &[usize]) -> f64 {
        self.modules
            .iter()
            .zip(profile)
            .map(|(s, &ci)| s.choices[ci].loss * s.choices[ci].loss)
            .sum()
    }

    /// The widened DP: choice indices over the lattice, via
    /// [`ChoiceProblem::lower`] + the unchanged `spdy::solve_dp`.
    pub fn solve_dp(&self, coeffs: &[f64], budget: f64) -> Option<Vec<usize>> {
        spdy::solve_dp(&self.lower(), coeffs, budget)
    }

    /// The widened SPDY coefficient search (same mechanics as
    /// `spdy::search`, ranging over choice indices).
    pub fn search<F: FnMut(&[usize]) -> f64>(
        &self,
        budget: f64,
        cfg: &SearchCfg,
        eval: F,
    ) -> Option<(Vec<usize>, f64)> {
        spdy::search(&self.lower(), budget, cfg, eval)
    }

    /// Structural per-layer anatomy `(heads, ffn_cols)` of an
    /// assignment (quant/low-rank keep the dense shape).
    pub fn as_layer_profile(&self, profile: &[usize]) -> Vec<(usize, usize)> {
        self.lower().as_layer_profile(profile)
    }

    /// Typed view of a solved choice-index assignment.
    pub fn profile_choices(&self, profile: &[usize]) -> CompressionProfile {
        CompressionProfile {
            modules: self
                .modules
                .iter()
                .zip(profile)
                .map(|(s, &ci)| ModuleChoice {
                    layer: s.layer,
                    is_attn: s.is_attn,
                    choice: s.choices[ci].choice,
                })
                .collect(),
        }
    }
}

/// One module's solved choice inside a [`CompressionProfile`].
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ModuleChoice {
    /// transformer layer index
    pub layer: usize,
    /// true = attention module, false = FFN module
    pub is_attn: bool,
    /// the chosen compression
    pub choice: LayerChoice,
}

impl ModuleChoice {
    fn to_json(self) -> Json {
        let mut pairs = vec![
            ("layer", Json::Num(self.layer as f64)),
            ("module", Json::Str(if self.is_attn { "attn" } else { "ffn" }.into())),
        ];
        pairs.extend(self.choice.json_pairs());
        Json::obj(pairs)
    }

    fn from_json(j: &Json) -> Result<ModuleChoice> {
        let layer = j
            .get("layer")
            .and_then(Json::as_usize)
            .ok_or_else(|| anyhow!("module choice missing \"layer\""))?;
        let is_attn = match j.get("module").and_then(Json::as_str) {
            Some("attn") => true,
            Some("ffn") => false,
            other => bail!("module choice has bad \"module\" {other:?}"),
        };
        Ok(ModuleChoice { layer, is_attn, choice: LayerChoice::from_json(j)? })
    }
}

/// A solved per-module choice assignment — the typed profile that
/// replaces raw `Vec<usize>` level indices and `Vec<(usize, usize)>`
/// layer anatomies outside `spdy/` (manifest schema v2 records it).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct CompressionProfile {
    /// one choice per module, layer-major with attn before ffn
    pub modules: Vec<ModuleChoice>,
}

impl CompressionProfile {
    /// Lift a legacy pruning anatomy `(heads, ffn_cols)` per layer:
    /// every module becomes a `Prune` choice. Backward-compat path for
    /// v1 manifests and raw layer profiles.
    pub fn from_layer_profile(lp: &[(usize, usize)]) -> CompressionProfile {
        let mut modules = Vec::with_capacity(lp.len() * 2);
        for (layer, &(heads, cols)) in lp.iter().enumerate() {
            modules.push(ModuleChoice {
                layer,
                is_attn: true,
                choice: LayerChoice::Prune { remaining: heads },
            });
            modules.push(ModuleChoice {
                layer,
                is_attn: false,
                choice: LayerChoice::Prune { remaining: cols },
            });
        }
        CompressionProfile { modules }
    }

    /// Structural anatomy `(heads, ffn_cols)` per layer; modules not
    /// present (or non-pruning choices) report the dense shape passed
    /// in.
    pub fn as_layer_profile(&self, dense_heads: usize, dense_cols: usize) -> Vec<(usize, usize)> {
        let n_layers = self.modules.iter().map(|m| m.layer).max().map_or(0, |l| l + 1);
        let mut out = vec![(dense_heads, dense_cols); n_layers];
        for m in &self.modules {
            if m.is_attn {
                out[m.layer].0 = m.choice.remaining(dense_heads);
            } else {
                out[m.layer].1 = m.choice.remaining(dense_cols);
            }
        }
        out
    }

    /// True iff every module's choice is on the prune axis — the
    /// restriction under which the widened DP must reproduce the
    /// legacy solve bit-identically.
    pub fn is_prune_only(&self) -> bool {
        self.modules.iter().all(|m| matches!(m.choice, LayerChoice::Prune { .. }))
    }

    /// Module count per axis, sorted by axis name (for mix summaries).
    pub fn axis_counts(&self) -> Vec<(String, usize)> {
        let mut counts = std::collections::BTreeMap::new();
        for m in &self.modules {
            *counts.entry(m.choice.axis().to_string()).or_insert(0usize) += 1;
        }
        counts.into_iter().collect()
    }

    /// Manifest-v2 JSON form: an array of flat module objects.
    pub fn to_json(&self) -> Json {
        Json::Arr(self.modules.iter().map(|m| m.to_json()).collect())
    }

    /// Inverse of [`CompressionProfile::to_json`].
    pub fn from_json(j: &Json) -> Result<CompressionProfile> {
        let arr = j.as_arr().ok_or_else(|| anyhow!("compression profile must be an array"))?;
        Ok(CompressionProfile {
            modules: arr.iter().map(ModuleChoice::from_json).collect::<Result<Vec<_>>>()?,
        })
    }
}

#[cfg(test)]
#[allow(clippy::disallowed_methods)]
mod tests {
    use super::*;

    fn toy_spdy() -> SpdyProblem {
        let mk = |layer: usize, is_attn: bool, costs: [f64; 3], priors: [f64; 3]| ModuleLevels {
            layer,
            is_attn,
            options: (0..3)
                .map(|i| LevelOpt { remaining: 8 - 2 * i, cost: costs[i], prior: priors[i] })
                .collect(),
        };
        SpdyProblem {
            modules: vec![
                mk(0, true, [4.0, 2.5, 1.0], [0.0, 0.3, 0.9]),
                mk(0, false, [6.0, 3.0, 1.5], [0.0, 0.2, 0.7]),
            ],
            overhead: 2.0,
        }
    }

    #[test]
    fn prune_only_lowering_is_bit_identical() {
        let p = toy_spdy();
        let cp = ChoiceProblem::from_spdy(&p);
        let lowered = cp.lower();
        // the lowered problem carries the exact same f64s …
        assert_eq!(lowered.overhead.to_bits(), p.overhead.to_bits());
        for (a, b) in lowered.modules.iter().zip(&p.modules) {
            assert_eq!(a.layer, b.layer);
            assert_eq!(a.is_attn, b.is_attn);
            for (x, y) in a.options.iter().zip(&b.options) {
                assert_eq!(x.remaining, y.remaining);
                assert_eq!(x.cost.to_bits(), y.cost.to_bits());
                assert_eq!(x.prior.to_bits(), y.prior.to_bits());
            }
        }
        // … so every DP answer matches the legacy solve exactly
        for f in [0.3, 0.5, 0.8, 1.0] {
            let budget = p.overhead + (p.dense_cost() - p.overhead) * f;
            assert_eq!(cp.solve_dp(&[], budget), spdy::solve_dp(&p, &[], budget), "f={f}");
        }
        assert_eq!(cp.dense_cost().to_bits(), p.dense_cost().to_bits());
        assert_eq!(cp.min_cost().to_bits(), p.min_cost().to_bits());
        assert_eq!(cp.profile_cost(&[1, 2]).to_bits(), p.profile_cost(&[1, 2]).to_bits());
    }

    #[test]
    fn widened_dp_prefers_cheap_mixed_choices() {
        let p = toy_spdy();
        let mut cp = ChoiceProblem::from_spdy(&p);
        // a quant choice on module 0: much cheaper than dense, tiny loss
        cp.modules[0].choices.push(Choice {
            choice: LayerChoice::Quant { scheme: QuantScheme::Int8 },
            cost: 1.6,
            loss: 0.05,
        });
        // a low-rank choice on the FFN: between prune levels on both axes
        cp.modules[1].choices.push(Choice {
            choice: LayerChoice::LowRank { rank: 4 },
            cost: 2.0,
            loss: 0.1,
        });
        let budget = cp.dense_cost() / 2.0;
        let prune_sol = ChoiceProblem::from_spdy(&p).solve_dp(&[], budget).expect("prune dp");
        let mixed_sol = cp.solve_dp(&[], budget).expect("mixed dp");
        // superset of choices at the same budget → no worse objective
        let prune_loss = ChoiceProblem::from_spdy(&p).loss_sq(&prune_sol);
        assert!(cp.loss_sq(&mixed_sol) <= prune_loss + 1e-12);
        // and on this instance strictly better, by picking quant + lowrank
        let typed = cp.profile_choices(&mixed_sol);
        assert!(!typed.is_prune_only());
        assert!(cp.profile_cost(&mixed_sol) <= budget + 1e-12);
    }

    #[test]
    fn layer_profile_lifts_roundtrip() {
        let lp = vec![(4, 512), (2, 256), (0, 64)];
        let p = CompressionProfile::from_layer_profile(&lp);
        assert!(p.is_prune_only());
        assert_eq!(p.modules.len(), 6);
        assert_eq!(p.as_layer_profile(4, 512), lp);
        assert_eq!(p.axis_counts(), vec![("prune".to_string(), 6)]);
    }

    #[test]
    fn mixed_profile_json_roundtrip_and_anatomy() {
        let p = CompressionProfile {
            modules: vec![
                ModuleChoice {
                    layer: 0,
                    is_attn: true,
                    choice: LayerChoice::PruneQuant { remaining: 3, scheme: QuantScheme::Int8 },
                },
                ModuleChoice {
                    layer: 0,
                    is_attn: false,
                    choice: LayerChoice::LowRank { rank: 64 },
                },
                ModuleChoice {
                    layer: 1,
                    is_attn: true,
                    choice: LayerChoice::Quant { scheme: QuantScheme::Int8 },
                },
                ModuleChoice {
                    layer: 1,
                    is_attn: false,
                    choice: LayerChoice::Prune { remaining: 128 },
                },
            ],
        };
        let back = CompressionProfile::from_json(&p.to_json()).expect("roundtrip");
        assert_eq!(back, p);
        let text = Json::parse(&p.to_json().to_pretty()).expect("parse");
        assert_eq!(CompressionProfile::from_json(&text).expect("text"), p);
        // quant/low-rank keep the dense anatomy; prune records remaining
        assert_eq!(p.as_layer_profile(4, 512), vec![(3, 512), (4, 128)]);
        assert!(!p.is_prune_only());
        let counts = p.axis_counts();
        assert_eq!(
            counts,
            vec![
                ("lowrank".to_string(), 1),
                ("prune".to_string(), 1),
                ("prune+quant".to_string(), 1),
                ("quant".to_string(), 1),
            ]
        );
    }

    #[test]
    fn bad_json_is_rejected_with_context() {
        for bad in [
            r#"{"layer": 0}"#,
            r#"[{"layer": 0, "module": "attn", "axis": "prune"}]"#,
            r#"[{"layer": 0, "module": "attn", "axis": "melt"}]"#,
            r#"[{"layer": 0, "module": "gate", "axis": "prune", "remaining": 2}]"#,
            r#"[{"layer": 0, "module": "attn", "axis": "quant", "scheme": "int3"}]"#,
        ] {
            let j = Json::parse(bad).expect("parse");
            assert!(CompressionProfile::from_json(&j).is_err(), "accepted {bad}");
        }
    }
}
