//! Compound compression for edge deployment (paper §5 + Appendix A):
//! on top of a ZipLM structurally-pruned model, apply
//!
//!   1. unstructured magnitude pruning of the remaining weights
//!      (oBERT's role in the paper's pipeline), and
//!   2. symmetric per-row INT8 weight quantization (QAT's role —
//!      post-training here),
//!
//! and estimate single-core CPU latency with a DeepSparse-like analytic
//! engine model: compute scales with effective nonzeros (sub-linearly —
//! sparse kernels have overheads) and INT8 gives a ~2.5x dense-compute
//! boost. This reproduces the *shape* of Fig. 6 (speedup-vs-accuracy on
//! CPU); see DESIGN.md §3 for the substitution rationale.

use anyhow::Result;

use crate::models::ModelState;
use crate::runtime::TaskInfo;

/// Symmetric per-row INT8 quantize→dequantize of all 2-D weights.
/// Returns mean absolute quantization error (diagnostic).
pub fn int8_quantize(state: &mut ModelState, tinfo: &TaskInfo) -> Result<f64> {
    let mut err_sum = 0f64;
    let mut n = 0usize;
    let entries: Vec<_> = tinfo
        .layout
        .iter()
        .filter(|e| e.shape.len() == 2 && !e.name.contains("emb"))
        .cloned()
        .collect();
    for e in entries {
        let rows = e.shape[0];
        let cols = e.shape[1];
        let base = e.offset;
        for r in 0..rows {
            let row = &mut state.params[base + r * cols..base + (r + 1) * cols];
            let maxabs = row.iter().fold(0f32, |m, &x| m.max(x.abs()));
            if maxabs == 0.0 {
                continue;
            }
            let scale = maxabs / 127.0;
            for x in row.iter_mut() {
                let q = (*x / scale).round().clamp(-127.0, 127.0);
                let dq = q * scale;
                err_sum += (dq - *x).abs() as f64;
                *x = dq;
                n += 1;
            }
        }
    }
    Ok(err_sum / n.max(1) as f64)
}

/// Unstructured global magnitude pruning of 2-D weights to `sparsity`
/// (fraction of remaining nonzero weights to remove). Returns achieved
/// overall sparsity among those tensors.
pub fn unstructured_magnitude(state: &mut ModelState, tinfo: &TaskInfo, sparsity: f64) -> Result<f64> {
    let mut idx: Vec<(usize, f32)> = Vec::new();
    for e in tinfo.layout.iter().filter(|e| e.shape.len() == 2 && !e.name.contains("emb")) {
        for i in e.offset..e.offset + e.numel() {
            let v = state.params[i];
            if v != 0.0 {
                idx.push((i, v.abs()));
            }
        }
    }
    let kill = ((idx.len() as f64) * sparsity) as usize;
    idx.sort_by(|a, b| a.1.total_cmp(&b.1));
    for &(i, _) in idx.iter().take(kill) {
        state.params[i] = 0.0;
    }
    Ok(kill as f64 / idx.len().max(1) as f64)
}

/// DeepSparse-like single-core latency model.
#[derive(Clone, Copy, Debug)]
pub struct CpuEngineModel {
    /// dense f32 GFLOP/s on one core
    pub dense_gflops: f64,
    /// INT8 speedup factor over f32
    pub int8_factor: f64,
    /// sparse kernels scale sub-linearly: t ∝ (1-s)^alpha
    pub sparse_alpha: f64,
    /// fixed per-inference overhead (s)
    pub overhead: f64,
}

impl Default for CpuEngineModel {
    fn default() -> Self {
        CpuEngineModel { dense_gflops: 40.0, int8_factor: 2.5, sparse_alpha: 0.75, overhead: 1e-3 }
    }
}

impl CpuEngineModel {
    /// Latency for a model with `dense_flops` per inference, structural
    /// density `struct_density` (fraction of dense compute left after
    /// structured pruning), unstructured sparsity `s`, INT8 on/off.
    pub fn latency(&self, dense_flops: f64, struct_density: f64, s: f64, int8: bool) -> f64 {
        let mut compute = dense_flops * struct_density / (self.dense_gflops * 1e9);
        compute *= (1.0 - s).powf(self.sparse_alpha);
        if int8 {
            compute /= self.int8_factor;
        }
        self.overhead + compute
    }

    pub fn speedup(&self, dense_flops: f64, struct_density: f64, s: f64, int8: bool) -> f64 {
        self.latency(dense_flops, 1.0, 0.0, false)
            / self.latency(dense_flops, struct_density, s, int8)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::tests_support::mini_state;

    #[test]
    fn int8_small_error_and_idempotent_zero() {
        let (_mi, ti, mut st) = mini_state();
        let before = st.params.clone();
        let err = int8_quantize(&mut st, &ti).unwrap();
        assert!(err < 1e-3, "mean err {err}");
        // zeros stay zero
        for (a, b) in before.iter().zip(&st.params) {
            if *a == 0.0 {
                assert_eq!(*b, 0.0);
            }
        }
    }

    #[test]
    fn unstructured_hits_requested_sparsity() {
        let (_mi, ti, mut st) = mini_state();
        let got = unstructured_magnitude(&mut st, &ti, 0.8).unwrap();
        assert!((got - 0.8).abs() < 0.02, "{got}");
        // embeddings untouched
        let emb = st.get1(&ti, "tok_emb").unwrap();
        assert!(emb.iter().all(|&x| x != 0.0));
    }

    #[test]
    fn engine_model_monotone() {
        let m = CpuEngineModel::default();
        let f = 1e9;
        assert!(m.speedup(f, 1.0, 0.0, false) == 1.0);
        let s1 = m.speedup(f, 0.5, 0.0, false);
        let s2 = m.speedup(f, 0.5, 0.8, false);
        let s3 = m.speedup(f, 0.5, 0.8, true);
        assert!(s1 > 1.0 && s2 > s1 && s3 > s2, "{s1} {s2} {s3}");
        // overhead caps speedup
        let extreme = m.speedup(f, 0.01, 0.99, true);
        assert!(extreme < 1000.0);
    }
}
