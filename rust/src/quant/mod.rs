//! Compound compression for edge deployment (paper §5 + Appendix A):
//! on top of a ZipLM structurally-pruned model, apply
//!
//!   1. unstructured magnitude pruning of the remaining weights
//!      (oBERT's role in the paper's pipeline), and
//!   2. symmetric per-row INT8 weight quantization (QAT's role —
//!      post-training here),
//!
//! and estimate single-core CPU latency with a DeepSparse-like analytic
//! engine model: compute scales with effective nonzeros (sub-linearly —
//! sparse kernels have overheads) and INT8 gives a ~2.5x dense-compute
//! boost. This reproduces the *shape* of Fig. 6 (speedup-vs-accuracy on
//! CPU); see DESIGN.md §3 for the substitution rationale.

use anyhow::Result;

use crate::models::ModelState;
use crate::runtime::TaskInfo;
use crate::tensor::Tensor;

/// Symmetric per-row INT8 quantize→dequantize of one weight matrix —
/// the pure tensor-level core of [`int8_quantize`], used by the
/// compound choice lattice to score and apply the quant axis on a
/// module snapshot without touching model state (DESIGN.md §13).
pub fn int8_tensor(w: &Tensor) -> Tensor {
    let (rows, cols) = (w.rows(), w.cols());
    let mut out = w.clone();
    for r in 0..rows {
        let row = &mut out.data[r * cols..(r + 1) * cols];
        let maxabs = row.iter().fold(0f32, |m, &x| m.max(x.abs()));
        if maxabs == 0.0 {
            continue;
        }
        let scale = maxabs / 127.0;
        for x in row.iter_mut() {
            *x = (*x / scale).round().clamp(-127.0, 127.0) * scale;
        }
    }
    out
}

/// Symmetric per-row INT8 quantize→dequantize of all 2-D weights.
/// Returns mean absolute quantization error (diagnostic).
pub fn int8_quantize(state: &mut ModelState, tinfo: &TaskInfo) -> Result<f64> {
    let mut err_sum = 0f64;
    let mut n = 0usize;
    let entries: Vec<_> = tinfo
        .layout
        .iter()
        .filter(|e| e.shape.len() == 2 && !e.name.contains("emb"))
        .cloned()
        .collect();
    for e in entries {
        let rows = e.shape[0];
        let cols = e.shape[1];
        let base = e.offset;
        for r in 0..rows {
            let row = &mut state.params[base + r * cols..base + (r + 1) * cols];
            let maxabs = row.iter().fold(0f32, |m, &x| m.max(x.abs()));
            if maxabs == 0.0 {
                continue;
            }
            let scale = maxabs / 127.0;
            for x in row.iter_mut() {
                let q = (*x / scale).round().clamp(-127.0, 127.0);
                let dq = q * scale;
                err_sum += (dq - *x).abs() as f64;
                *x = dq;
                n += 1;
            }
        }
    }
    Ok(err_sum / n.max(1) as f64)
}

/// Unstructured global magnitude pruning of 2-D weights to `sparsity`
/// (fraction of remaining nonzero weights to remove). Returns achieved
/// overall sparsity among those tensors.
pub fn unstructured_magnitude(state: &mut ModelState, tinfo: &TaskInfo, sparsity: f64) -> Result<f64> {
    let mut idx: Vec<(usize, f32)> = Vec::new();
    for e in tinfo.layout.iter().filter(|e| e.shape.len() == 2 && !e.name.contains("emb")) {
        for i in e.offset..e.offset + e.numel() {
            let v = state.params[i];
            if v != 0.0 {
                idx.push((i, v.abs()));
            }
        }
    }
    let kill = ((idx.len() as f64) * sparsity) as usize;
    idx.sort_by(|a, b| a.1.total_cmp(&b.1));
    for &(i, _) in idx.iter().take(kill) {
        state.params[i] = 0.0;
    }
    Ok(kill as f64 / idx.len().max(1) as f64)
}

/// DeepSparse-like single-core latency model.
#[derive(Clone, Copy, Debug)]
pub struct CpuEngineModel {
    /// dense f32 GFLOP/s on one core
    pub dense_gflops: f64,
    /// INT8 speedup factor over f32
    pub int8_factor: f64,
    /// sparse kernels scale sub-linearly: t ∝ (1-s)^alpha
    pub sparse_alpha: f64,
    /// fixed per-inference overhead (s)
    pub overhead: f64,
}

impl Default for CpuEngineModel {
    fn default() -> Self {
        CpuEngineModel { dense_gflops: 40.0, int8_factor: 2.5, sparse_alpha: 0.75, overhead: 1e-3 }
    }
}

impl CpuEngineModel {
    /// Latency for a model with `dense_flops` per inference, structural
    /// density `struct_density` (fraction of dense compute left after
    /// structured pruning), unstructured sparsity `s`, INT8 on/off.
    #[deprecated(
        note = "free-standing pricer retired: quantized variants are priced through the \
                same cost model the pruner certifies against — use \
                `env::CostModel::compound_time` (DESIGN.md §13)"
    )]
    pub fn latency(&self, dense_flops: f64, struct_density: f64, s: f64, int8: bool) -> f64 {
        let mut compute = dense_flops * struct_density / (self.dense_gflops * 1e9);
        compute *= (1.0 - s).powf(self.sparse_alpha);
        if int8 {
            compute /= self.int8_factor;
        }
        self.overhead + compute
    }

    #[deprecated(
        note = "free-standing pricer retired: quantized variants are priced through the \
                same cost model the pruner certifies against — use \
                `env::CostModel::compound_speedup` (DESIGN.md §13)"
    )]
    #[allow(deprecated)]
    pub fn speedup(&self, dense_flops: f64, struct_density: f64, s: f64, int8: bool) -> f64 {
        self.latency(dense_flops, 1.0, 0.0, false)
            / self.latency(dense_flops, struct_density, s, int8)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::tests_support::mini_state;

    #[test]
    fn int8_small_error_and_idempotent_zero() {
        let (_mi, ti, mut st) = mini_state();
        let before = st.params.clone();
        let err = int8_quantize(&mut st, &ti).unwrap();
        assert!(err < 1e-3, "mean err {err}");
        // zeros stay zero
        for (a, b) in before.iter().zip(&st.params) {
            if *a == 0.0 {
                assert_eq!(*b, 0.0);
            }
        }
    }

    #[test]
    fn unstructured_hits_requested_sparsity() {
        let (_mi, ti, mut st) = mini_state();
        let got = unstructured_magnitude(&mut st, &ti, 0.8).unwrap();
        assert!((got - 0.8).abs() < 0.02, "{got}");
        // embeddings untouched
        let emb = st.get1(&ti, "tok_emb").unwrap();
        assert!(emb.iter().all(|&x| x != 0.0));
    }

    #[test]
    fn int8_tensor_matches_statewise_quantizer() {
        // the pure tensor helper must apply the exact rule
        // int8_quantize applies to each 2-D weight row
        let (_mi, ti, mut st) = mini_state();
        let e = ti
            .layout
            .iter()
            .find(|e| e.shape.len() == 2 && !e.name.contains("emb"))
            .cloned()
            .unwrap();
        let w = Tensor::from_vec(
            &[e.shape[0], e.shape[1]],
            st.params[e.offset..e.offset + e.numel()].to_vec(),
        );
        let q = int8_tensor(&w);
        int8_quantize(&mut st, &ti).unwrap();
        let after = &st.params[e.offset..e.offset + e.numel()];
        assert_eq!(q.data, after, "tensor path diverged from state path");
        // idempotent: re-quantizing a quantized matrix is a no-op
        assert_eq!(int8_tensor(&q).data, q.data);
    }

    #[test]
    #[allow(deprecated)] // exercising the retired pricer's shim until removal
    fn engine_model_monotone() {
        let m = CpuEngineModel::default();
        let f = 1e9;
        assert!(m.speedup(f, 1.0, 0.0, false) == 1.0);
        let s1 = m.speedup(f, 0.5, 0.0, false);
        let s2 = m.speedup(f, 0.5, 0.8, false);
        let s3 = m.speedup(f, 0.5, 0.8, true);
        assert!(s1 > 1.0 && s2 > s1 && s3 > s2, "{s1} {s2} {s3}");
        // overhead caps speedup
        let extreme = m.speedup(f, 0.01, 0.99, true);
        assert!(extreme < 1000.0);
    }

    #[test]
    #[allow(deprecated)] // comparing the retired pricer against its replacement
    fn env_cost_model_subsumes_cpu_engine_pricer() {
        // An env whose dense blocks carry the engine's compute budget
        // must price compound variants like the retired CpuEngineModel:
        // the 2.5× int8 factor and (1−s)^0.75 law now live on the SAME
        // CostModel surface the pruner certifies against.
        use crate::env::CostModel;
        use crate::latency::LatencyTable;
        let m = CpuEngineModel::default();
        let dense_flops = 1e9;
        let compute = dense_flops / (m.dense_gflops * 1e9);
        let table = LatencyTable {
            model: "m".into(),
            device: "cpu".into(),
            regime: "throughput".into(),
            attn: vec![0.0, compute * 0.4],
            mlp: vec![(64, compute * 0.6), (0, 0.0)],
            overhead: m.overhead,
        };
        for &(sd, s, int8) in &[
            (1.0, 0.0, false),
            (0.5, 0.0, false),
            (0.5, 0.8, false),
            (0.5, 0.8, true),
            (0.25, 0.9, true),
        ] {
            let legacy = m.latency(dense_flops, sd, s, int8);
            let new = table.compound_time(1, sd, s, int8);
            assert!((legacy - new).abs() <= 1e-12 * legacy, "time {legacy} vs {new}");
            let ls = m.speedup(dense_flops, sd, s, int8);
            let ns = table.compound_speedup(1, sd, s, int8);
            assert!((ls - ns).abs() <= 1e-9 * ls, "speedup {ls} vs {ns}");
        }
        // and the per-block quant pricing divides by the same factor
        assert_eq!(table.quant_factor(), m.int8_factor);
        assert_eq!(table.attn_time_quant(1), table.attn_time(1) / 2.5);
        assert_eq!(table.mlp_time_quant(64), table.mlp_time(64) / 2.5);
    }
}
