//! Structured SPDY search (paper §3.2, "Finding the optimal sparsity
//! configuration").
//!
//! Given the per-module databases (ziplm/) and a latency table
//! (latency/), find a per-layer level assignment that meets a target
//! *speedup* while minimizing accuracy loss:
//!
//!  1. a knapsack-style DP solves  min Σ_m c_m · p²_{m,ℓ}  subject to
//!     Σ_m t_{m,ℓ} ≤ budget, for given sensitivity coefficients c;
//!  2. an outer random-mutation search perturbs ~10% of the c_m per
//!     step (paper: fixed 1000 steps replacing SPDY's shrinking
//!     neighborhood) and scores each DP solution by REAL calibration
//!     loss — every candidate already satisfies the speedup target, the
//!     property the paper highlights (§3.2, App. F).
//!
//! The same machinery runs the paper's Fig. 4 ablation: swapping the
//! time column for parameter counts turns "pruning for speedup" into
//! "pruning for sparsity".

use crate::util::rng::Rng;
use crate::util::threadpool::parallel_for_slices_mut;

/// One choosable level of a module: time (or params) + error prior.
#[derive(Clone, Debug)]
pub struct LevelOpt {
    /// structures (heads or FFN columns) remaining at this level
    pub remaining: usize,
    /// seconds (speedup mode) or parameter count (sparsity mode)
    pub cost: f64,
    /// p_s error prior from the database (0 = dense, 1 = full drop)
    pub prior: f64,
}

/// All levels for one prunable module (a layer's attn or FC).
#[derive(Clone, Debug)]
pub struct ModuleLevels {
    /// transformer layer index
    pub layer: usize,
    /// true for the attention module, false for the FFN
    pub is_attn: bool,
    /// choosable levels; `options[0]` is the dense level
    pub options: Vec<LevelOpt>,
}

/// A full SPDY instance: all prunable modules plus fixed overhead.
#[derive(Clone, Debug)]
pub struct SpdyProblem {
    /// all 2L prunable modules, in (attn, fc) per-layer order
    pub modules: Vec<ModuleLevels>,
    /// fixed cost outside prunable modules (embeddings/head)
    pub overhead: f64,
}

impl SpdyProblem {
    /// Total cost with every module at its dense level.
    pub fn dense_cost(&self) -> f64 {
        self.overhead + self.modules.iter().map(|m| m.options[0].cost).sum::<f64>()
    }

    /// Cheapest achievable total cost (every module at its cheapest level).
    pub fn min_cost(&self) -> f64 {
        self.overhead
            + self
                .modules
                .iter()
                .map(|m| m.options.iter().map(|o| o.cost).fold(f64::INFINITY, f64::min))
                .sum::<f64>()
    }

    /// Total cost of a per-module level assignment.
    pub fn profile_cost(&self, profile: &[usize]) -> f64 {
        self.overhead
            + self
                .modules
                .iter()
                .zip(profile)
                .map(|(m, &l)| m.options[l].cost)
                .sum::<f64>()
    }

    /// Per-layer (heads, ffn) profile for the latency table / masks.
    pub fn as_layer_profile(&self, profile: &[usize]) -> Vec<(usize, usize)> {
        let n_layers = self.modules.iter().map(|m| m.layer).max().unwrap_or(0) + 1;
        let mut out = vec![(0usize, 0usize); n_layers];
        for (m, &l) in self.modules.iter().zip(profile) {
            let rem = m.options[l].remaining;
            if m.is_attn {
                out[m.layer].0 = rem;
            } else {
                out[m.layer].1 = rem;
            }
        }
        out
    }
}

const BUCKETS: usize = 768;

/// DP knapsack: min Σ c_m prior² s.t. Σ cost ≤ budget.
/// Costs are rounded UP to buckets, so any returned profile genuinely
/// meets the budget. Returns level indices per module, or None if even
/// the cheapest assignment exceeds the budget.
///
/// Per module, every bucket of the next DP row depends only on the
/// previous row, so the bucket axis fans out across the thread pool
/// (nesting-aware like every other threaded kernel here). Each bucket
/// scans the module's levels in declaration order with strict-<
/// first-wins — exactly the legacy level-outer loop's tie-breaking —
/// so dp values, choices, and therefore profiles are bit-identical to
/// the serial formulation at every thread budget.
pub fn solve_dp(problem: &SpdyProblem, coeffs: &[f64], budget: f64) -> Option<Vec<usize>> {
    let avail = budget - problem.overhead;
    if avail <= 0.0 {
        return None;
    }
    let unit = avail / BUCKETS as f64;
    let nm = problem.modules.len();
    const INF: f64 = f64::INFINITY;
    // dp[b] = min cost using budget ≤ b buckets, with backtracking table
    let mut dp = vec![INF; BUCKETS + 1];
    dp[0] = 0.0;
    // choice[m][b] = level picked at module m to land on bucket b
    let mut choice = vec![vec![usize::MAX; BUCKETS + 1]; nm];
    // (next dp value, picked level) per bucket, reused across modules;
    // the sweep overwrites every cell, so no re-init is needed.
    let mut row: Vec<(f64, usize)> = vec![(INF, usize::MAX); BUCKETS + 1];
    for (mi, m) in problem.modules.iter().enumerate() {
        let c = coeffs.get(mi).copied().unwrap_or(1.0);
        // (bucket weight, DP cost, level index), declaration order.
        let mut lvl: Vec<(usize, f64, usize)> = Vec::with_capacity(m.options.len());
        for (li, opt) in m.options.iter().enumerate() {
            let w = (opt.cost / unit).ceil() as usize;
            if w <= BUCKETS {
                lvl.push((w, c * opt.prior * opt.prior, li));
            }
        }
        // ~16k level-scans per chunk; toy problems stay inline.
        let min_chunk = (16_384 / lvl.len().max(1)).max(1);
        parallel_for_slices_mut(&mut row, min_chunk, |start, chunk| {
            for (off, cell) in chunk.iter_mut().enumerate() {
                let b = start + off;
                let mut best = INF;
                let mut pick = usize::MAX;
                for &(w, cost, li) in &lvl {
                    if w > b {
                        continue;
                    }
                    let base = dp[b - w];
                    if base.is_finite() && base + cost < best {
                        best = base + cost;
                        pick = li;
                    }
                }
                *cell = (best, pick);
            }
        });
        for (b, &(v, pick)) in row.iter().enumerate() {
            dp[b] = v;
            choice[mi][b] = pick;
        }
        // prefix-min so dp[b] = best using ≤ b (keep bucket position of
        // best): make dp monotone while keeping choice consistent — we
        // track the actual bucket used during backtracking instead.
        for b in 1..=BUCKETS {
            if dp[b - 1] < dp[b] {
                dp[b] = dp[b - 1];
                choice[mi][b] = usize::MAX; // marker: look left
            }
        }
    }
    if !dp[BUCKETS].is_finite() {
        return None;
    }
    // backtrack
    let mut profile = vec![0usize; nm];
    let mut b = BUCKETS;
    for mi in (0..nm).rev() {
        while choice[mi][b] == usize::MAX {
            if b == 0 {
                return None; // inconsistent (shouldn't happen)
            }
            b -= 1;
        }
        let li = choice[mi][b];
        profile[mi] = li;
        let unit_w = (problem.modules[mi].options[li].cost / unit).ceil() as usize;
        b -= unit_w.min(b);
    }
    Some(profile)
}

/// Outer mutation-search configuration (paper §3.2's SPDY variant).
pub struct SearchCfg {
    /// search steps (paper: fixed 1000)
    pub iters: usize,
    /// fraction of coefficients mutated per step (~0.1)
    pub mutate_frac: f64,
    /// log-normal mutation scale
    pub sigma: f64,
    /// RNG seed (search is fully deterministic given the seed)
    pub seed: u64,
}

impl Default for SearchCfg {
    fn default() -> Self {
        // paper: fixed 1000 steps, ~10% of coefficients mutated per step
        SearchCfg { iters: 1000, mutate_frac: 0.1, sigma: 0.4, seed: 7 }
    }
}

/// Outer mutation search. `eval` maps a level profile to calibration
/// loss (lower = better); it is only called on NEW profiles (cached).
pub fn search<F: FnMut(&[usize]) -> f64>(
    problem: &SpdyProblem,
    budget: f64,
    cfg: &SearchCfg,
    mut eval: F,
) -> Option<(Vec<usize>, f64)> {
    let nm = problem.modules.len();
    let mut rng = Rng::new(cfg.seed);
    let mut coeffs = vec![1.0f64; nm];
    let mut cache: std::collections::HashMap<Vec<usize>, f64> = std::collections::HashMap::new();
    let mut best_profile = solve_dp(problem, &coeffs, budget)?;
    let mut best_loss = eval(&best_profile);
    cache.insert(best_profile.clone(), best_loss);
    let mut best_coeffs = coeffs.clone();
    for _ in 0..cfg.iters {
        coeffs = best_coeffs.clone();
        for c in coeffs.iter_mut() {
            if rng.f64() < cfg.mutate_frac {
                *c *= (rng.normal() * cfg.sigma).exp();
            }
        }
        let Some(profile) = solve_dp(problem, &coeffs, budget) else { continue };
        let loss = if let Some(&l) = cache.get(&profile) {
            l
        } else {
            let l = eval(&profile);
            cache.insert(profile.clone(), l);
            l
        };
        if loss < best_loss {
            best_loss = loss;
            best_profile = profile;
            best_coeffs = coeffs.clone();
        }
    }
    Some((best_profile, best_loss))
}

#[cfg(test)]
mod tests {
    use super::*;

    /// 2 modules, 3 levels each, convenient numbers.
    fn toy() -> SpdyProblem {
        let mk = |layer, is_attn| ModuleLevels {
            layer,
            is_attn,
            options: vec![
                LevelOpt { remaining: 4, cost: 10.0, prior: 0.0 },
                LevelOpt { remaining: 2, cost: 5.0, prior: 0.3 },
                LevelOpt { remaining: 0, cost: 0.0, prior: 1.0 },
            ],
        };
        SpdyProblem { modules: vec![mk(0, true), mk(0, false)], overhead: 2.0 }
    }

    #[test]
    fn dp_respects_budget_exactly() {
        let p = toy();
        for budget in [22.0, 17.0, 12.0, 7.0, 2.5] {
            if let Some(prof) = solve_dp(&p, &[1.0, 1.0], budget) {
                let t = p.profile_cost(&prof);
                assert!(t <= budget + 1e-9, "budget {budget} got {t} prof {prof:?}");
            }
        }
    }

    #[test]
    fn dp_dense_when_budget_allows() {
        let p = toy();
        let prof = solve_dp(&p, &[1.0, 1.0], 100.0).unwrap();
        assert_eq!(prof, vec![0, 0]);
    }

    #[test]
    fn dp_infeasible_when_budget_below_overhead() {
        let p = toy();
        assert!(solve_dp(&p, &[1.0, 1.0], 1.0).is_none());
    }

    #[test]
    fn dp_picks_cheapest_error_combo() {
        let p = toy();
        // budget 17: options are (10+5)=15 cost err 0.09, or (5+10) same,
        // or (10+0)=10 err 1, ... best is one module at level 1.
        let prof = solve_dp(&p, &[1.0, 1.0], 17.0).unwrap();
        let err: f64 = prof
            .iter()
            .zip(&p.modules)
            .map(|(&l, m)| m.options[l].prior.powi(2))
            .sum();
        assert!((err - 0.09).abs() < 1e-9, "prof {prof:?}");
    }

    #[test]
    fn coefficients_steer_dp() {
        let p = toy();
        // huge coefficient on module 0 error: prune module 1 instead
        let prof = solve_dp(&p, &[100.0, 1.0], 17.0).unwrap();
        assert_eq!(prof[0], 0, "{prof:?}");
        assert_eq!(prof[1], 1);
        let prof2 = solve_dp(&p, &[1.0, 100.0], 17.0).unwrap();
        assert_eq!(prof2[1], 0, "{prof2:?}");
    }

    #[test]
    fn search_improves_or_matches_initial() {
        let p = toy();
        // rig the eval to prefer pruning module 1
        let eval = |prof: &[usize]| -> f64 {
            prof[0] as f64 * 10.0 + prof[1] as f64
        };
        let (best, loss) =
            search(&p, 17.0, &SearchCfg { iters: 200, ..Default::default() }, eval).unwrap();
        assert_eq!(best[0], 0, "search should discover module-0 sensitivity");
        assert!(loss <= 1.0 + 1e-9);
        assert!(p.profile_cost(&best) <= 17.0);
    }

    #[test]
    fn layer_profile_mapping() {
        let p = toy();
        let lp = p.as_layer_profile(&[1, 2]);
        assert_eq!(lp, vec![(2, 0)]);
    }

    #[test]
    fn sparsity_mode_works_via_param_costs() {
        // same machinery with params as cost: ensures fig4's ablation path
        let mk = |layer, is_attn| ModuleLevels {
            layer,
            is_attn,
            options: vec![
                LevelOpt { remaining: 4, cost: 1000.0, prior: 0.0 },
                LevelOpt { remaining: 2, cost: 500.0, prior: 0.4 },
                LevelOpt { remaining: 0, cost: 0.0, prior: 1.0 },
            ],
        };
        let p = SpdyProblem { modules: vec![mk(0, true), mk(1, false)], overhead: 0.0 };
        let prof = solve_dp(&p, &[1.0, 1.0], 1500.0).unwrap();
        assert!(p.profile_cost(&prof) <= 1500.0);
    }
}
