//! Experiment drivers: one function per paper table/figure (DESIGN.md §5).
//!
//! Every driver is seeded, prints the paper-shaped rows to stdout, and
//! writes a JSON record under `results/`.
//! Sizes are scaled to the 1-core testbed; pass `--fast` for CI-sized
//! runs (the benches use the same entry points).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Result};

use crate::baselines;
use crate::coordinator::family as famserve;
use crate::data::{self, Dataset};
use crate::env::{CostModel, InferenceEnv, Regime};
use crate::eval::{self, EvalResult};
use crate::latency::{self, ArchDims, Device, LatencyTable};
use crate::models::ModelState;
use crate::pruner::{PruneCfg, SpdyCfgLite, TargetMode};
use crate::quant;
use crate::runtime::Engine;
use crate::session::CompressionSession;
use crate::train::{TrainCfg, Trainer};
use crate::util::json::Json;

pub mod repro;

pub struct ExpCtx {
    pub engine: Engine,
    pub runs: PathBuf,
    pub results: PathBuf,
    pub fast: bool,
    pub seed: u64,
}

impl ExpCtx {
    pub fn new(artifacts: &Path, fast: bool) -> Result<ExpCtx> {
        Ok(ExpCtx {
            engine: Engine::open(artifacts)?,
            runs: PathBuf::from("runs"),
            results: PathBuf::from("results"),
            fast,
            seed: 1234,
        })
    }

    pub fn write_result(&self, name: &str, j: &Json) -> Result<()> {
        std::fs::create_dir_all(&self.results)?;
        let path = self.results.join(format!("{name}.json"));
        std::fs::write(&path, j.to_pretty())?;
        println!("[result] wrote {}", path.display());
        Ok(())
    }

    pub fn dataset(&self, model: &str, task: &str) -> Dataset {
        let info = self.engine.manifest.model(model);
        let (ntr, nev) = if self.fast { (256, 64) } else { (1024, 256) };
        data::load_sized(info, task, ntr, nev)
    }

    /// Train (or load a cached) dense teacher for (model, task).
    pub fn teacher(&self, model: &str, task: &str, data: &Dataset) -> Result<ModelState> {
        let path = self.runs.join(format!("teacher_{model}_{task}.zlm"));
        if let Ok(st) = ModelState::load(&path) {
            if st.params.len() == self.engine.manifest.task(model, task).n_params {
                return Ok(st);
            }
        }
        let minfo = self.engine.manifest.model(model).clone();
        let tinfo = self.engine.manifest.task(model, task).clone();
        let mut st = ModelState::init(&minfo, task, &tinfo, self.seed);
        let mut tr = Trainer::new(&self.engine, tinfo.n_params, None);
        let cfg = TrainCfg {
            lr: 1e-3,
            weight_decay: 0.0,
            lambdas: [1.0, 0.0, 0.0],
            epochs: if self.fast { 2.0 } else { 4.0 },
            seed: self.seed,
            log_every: 50,
        };
        let loss = tr.train(&mut st, data, &cfg)?;
        let ev = eval::evaluate(&self.engine, &st, data, "dev")?;
        println!("[teacher] {model}/{task}: train_loss={loss:.4} dev={:.4}", ev.metric);
        st.save(&path)?;
        Ok(st)
    }

    /// Measured (or disk-cached) inference environment for (model,
    /// regime): the ONE value the pruning session certifies against
    /// and the family coordinator later admits requests with.
    pub fn env(&self, model: &str, regime: Regime) -> Result<InferenceEnv> {
        let path = self.runs.join(format!("latency_{model}_{}.json", regime.name()));
        let table = match LatencyTable::load(&path) {
            Ok(t) => t,
            Err(_) => {
                let t = latency::measure_cpu(&self.engine, model, regime.name(), 30)?;
                t.save(&path)?;
                t
            }
        };
        let (b, sq) = latency::regime_shape(&self.engine, model, regime.name()).unwrap_or((0, 0));
        Ok(InferenceEnv::measured(table)?.with_batch_shape(b, sq))
    }

    fn prune_cfg(&self) -> PruneCfg {
        PruneCfg {
            calib_samples: if self.fast { 64 } else { 256 },
            spdy: SpdyCfgLite { iters: if self.fast { 25 } else { 120 }, seed: 7 },
            ..Default::default()
        }
    }

    /// Checkpoint-free gradual session for (model, task) against `env`.
    #[allow(clippy::too_many_arguments)]
    fn gradual_session(
        &self,
        model: &str,
        task: &str,
        env: &InferenceEnv,
        targets: &[f64],
        pcfg: PruneCfg,
        tcfg: TrainCfg,
        teacher: Option<Vec<f32>>,
    ) -> Result<CompressionSession<'_>> {
        let mut b = CompressionSession::for_model(&self.engine, model, task)
            .with_env(env.clone())
            .with_targets(targets)
            .with_prune_cfg(pcfg)
            .with_train_cfg(tcfg);
        if let Some(t) = teacher {
            b = b.with_teacher(t);
        }
        b.open()
    }

    /// One-shot session (no fine-tune stage) for (model, task).
    fn oneshot_session(
        &self,
        model: &str,
        task: &str,
        env: &InferenceEnv,
        pcfg: PruneCfg,
    ) -> Result<CompressionSession<'_>> {
        CompressionSession::for_model(&self.engine, model, task)
            .with_env(env.clone())
            .with_prune_cfg(pcfg)
            .open()
    }

    fn ft_cfg(&self, kd: bool) -> TrainCfg {
        TrainCfg {
            lr: 5e-4,
            weight_decay: 0.0,
            lambdas: if kd { [1.0, 0.5, 0.5] } else { [1.0, 0.0, 0.0] },
            epochs: if self.fast { 0.5 } else { 2.0 },
            seed: self.seed + 1,
            log_every: 0,
        }
    }
}

fn metric_name(kind: &str) -> &'static str {
    match kind {
        "span" => "EM(F1-proxy)",
        "lm" => "PPL",
        _ => "acc",
    }
}

fn eval_value(kind: &str, ev: &EvalResult) -> f64 {
    if kind == "lm" {
        ev.perplexity.unwrap_or(f64::NAN)
    } else {
        ev.metric
    }
}

// ===================================================================
// fig2 / fig3 / fig7: accuracy-vs-speedup curves, ZipLM vs baselines
// ===================================================================

pub fn fig_curves(ctx: &ExpCtx, model: &str, task: &str, targets: &[f64]) -> Result<Json> {
    let ds = ctx.dataset(model, task);
    let teacher = ctx.teacher(model, task, &ds)?;
    let env = ctx.env(model, Regime::Throughput)?;
    let minfo = ctx.engine.manifest.model(model).clone();
    let tinfo = ctx.engine.manifest.task(model, task).clone();
    let kind = ds.kind.clone();
    let dense_eval = eval::evaluate(&ctx.engine, &teacher, &ds, "dev")?;
    println!(
        "== {model}/{task} dense {} = {:.4} ==",
        metric_name(&kind),
        eval_value(&kind, &dense_eval)
    );
    let mut rows: Vec<Json> = Vec::new();

    // --- ZipLM gradual (one run → whole family)
    let stages = ctx
        .gradual_session(
            model,
            task,
            &env,
            targets,
            ctx.prune_cfg(),
            ctx.ft_cfg(kind != "lm"),
            Some(teacher.params.clone()),
        )?
        .run(teacher.clone(), &ds)?;
    for s in &stages {
        let ev = eval::evaluate(&ctx.engine, &s.state, &ds, "dev")?;
        let anatomy = s.state.masks.summary();
        println!(
            "  ziplm {:>4.1}x  {}={:.4}  profile={:?}",
            s.report.target,
            metric_name(&kind),
            eval_value(&kind, &ev),
            anatomy
        );
        rows.push(Json::obj(vec![
            ("method", Json::Str("ziplm".into())),
            ("target", Json::Num(s.report.target)),
            ("est_speedup", Json::Num(s.report.est_speedup)),
            ("metric", Json::Num(eval_value(&kind, &ev))),
            (
                "profile",
                Json::Arr(
                    anatomy
                        .iter()
                        .map(|&(h, f)| Json::Arr(vec![Json::Num(h as f64), Json::Num(f as f64)]))
                        .collect(),
                ),
            ),
        ]));
        let _ = s
            .state
            .save(&ctx.runs.join(format!("ziplm_{model}_{task}_{:.0}x.zlm", s.report.target)));
    }

    // --- baselines: magnitude + layer-drop (+ finetune with same budget)
    for (bname, which) in [("magnitude", 0), ("layerdrop", 1)] {
        for &t in targets {
            let mut st = teacher.clone();
            let r = match which {
                0 => baselines::magnitude_for_speedup(&mut st, &minfo, &tinfo, &env, t),
                _ => baselines::layer_drop_for_speedup(&mut st, &minfo, &tinfo, &env, t),
            };
            if r.is_err() {
                continue;
            }
            let mut tr = Trainer::new(&ctx.engine, tinfo.n_params, Some(teacher.params.clone()));
            let _ = tr.train(&mut st, &ds, &ctx.ft_cfg(kind != "lm"))?;
            let ev = eval::evaluate(&ctx.engine, &st, &ds, "dev")?;
            let sp = env.speedup(&r.unwrap());
            println!("  {bname} {t:>4.1}x (real {sp:.1}x)  {}={:.4}", metric_name(&kind), eval_value(&kind, &ev));
            rows.push(Json::obj(vec![
                ("method", Json::Str(bname.into())),
                ("target", Json::Num(t)),
                ("est_speedup", Json::Num(sp)),
                ("metric", Json::Num(eval_value(&kind, &ev))),
            ]));
        }
    }

    Ok(Json::obj(vec![
        ("model", Json::Str(model.into())),
        ("task", Json::Str(task.into())),
        ("dense_metric", Json::Num(eval_value(&kind, &dense_eval))),
        ("rows", Json::Arr(rows)),
    ]))
}

pub fn fig2(ctx: &ExpCtx) -> Result<()> {
    let targets: Vec<f64> = if ctx.fast { vec![2.0, 4.0] } else { vec![2.0, 3.0, 4.0, 6.0, 8.0, 12.0] };
    let base = fig_curves(ctx, "bert-syn-base", "squad-syn", &targets)?;
    let large = fig_curves(ctx, "bert-syn-large", "squad-syn", &targets)?;
    ctx.write_result("fig2", &Json::obj(vec![("base", base), ("large", large)]))
}

pub fn fig3(ctx: &ExpCtx) -> Result<()> {
    let targets: Vec<f64> = if ctx.fast { vec![2.0, 4.0] } else { vec![2.0, 4.0, 6.0, 10.0] };
    let mut parts = Vec::new();
    for task in ["sst2-syn", "qnli-syn", "mnli-syn", "qqp-syn"] {
        parts.push((task, fig_curves(ctx, "bert-syn-base", task, &targets)?));
    }
    ctx.write_result(
        "fig3",
        &Json::Obj(parts.into_iter().map(|(k, v)| (k.to_string(), v)).collect::<BTreeMap<_, _>>()),
    )
}

// ===================================================================
// table1: GPT2 throughput vs latency regimes, zero-shot PPL
// ===================================================================

pub fn table1(ctx: &ExpCtx) -> Result<()> {
    let model = "gpt-syn";
    let task = "corpus-syn";
    let ds = ctx.dataset(model, task);
    let teacher = ctx.teacher(model, task, &ds)?;
    let tinfo = ctx.engine.manifest.task(model, task).clone();
    let minfo = ctx.engine.manifest.model(model).clone();
    let dense_ppl = eval::evaluate(&ctx.engine, &teacher, &ds, "test")?.perplexity.unwrap();
    println!("== table1: dense PPL {dense_ppl:.2} ==");
    let targets: Vec<f64> = if ctx.fast { vec![1.5, 2.0] } else { vec![1.5, 2.0, 2.5, 3.0] };
    let mut rows = Vec::new();
    for regime in [Regime::Throughput, Regime::Latency] {
        let env = ctx.env(model, regime)?;
        // no KD for GPT (paper App. I)
        let stages = ctx
            .gradual_session(model, task, &env, &targets, ctx.prune_cfg(), ctx.ft_cfg(false), None)?
            .run(teacher.clone(), &ds)?;
        for s in &stages {
            let ppl = eval::evaluate(&ctx.engine, &s.state, &ds, "test")?.perplexity.unwrap();
            let anatomy = s.state.masks.summary();
            let density = s.state.masks.density();
            println!(
                "  zipgpt [{}] {:>3.1}x  PPL={ppl:.2}  density={density:.2}  {:?}",
                regime.name(),
                s.report.target,
                anatomy
            );
            rows.push(Json::obj(vec![
                ("method", Json::Str("zipgpt".into())),
                ("regime", Json::Str(regime.name().into())),
                ("target", Json::Num(s.report.target)),
                ("ppl", Json::Num(ppl)),
                ("density", Json::Num(density)),
                (
                    "profile",
                    Json::Arr(anatomy.iter().map(|&(h, f)| Json::Arr(vec![Json::Num(h as f64), Json::Num(f as f64)])).collect()),
                ),
            ]));
        }
    }
    // DistilGPT-style half-depth student with task-only training
    let mut student = teacher.clone();
    baselines::half_depth_masks(&mut student, &minfo);
    crate::train::rezero_dead(&mut student, &tinfo, &minfo);
    let mut tr = Trainer::new(&ctx.engine, tinfo.n_params, None);
    tr.train(&mut student, &ds, &ctx.ft_cfg(false))?;
    let ppl = eval::evaluate(&ctx.engine, &student, &ds, "test")?.perplexity.unwrap();
    let env = ctx.env(model, Regime::Throughput)?;
    let sp = env.speedup(&student.masks.summary());
    println!("  distilgpt-style  {sp:.1}x  PPL={ppl:.2}");
    rows.push(Json::obj(vec![
        ("method", Json::Str("distilgpt-style".into())),
        ("regime", Json::Str("throughput".into())),
        ("target", Json::Num(sp)),
        ("ppl", Json::Num(ppl)),
        ("density", Json::Num(student.masks.density())),
    ]));
    ctx.write_result(
        "table1",
        &Json::obj(vec![("dense_ppl", Json::Num(dense_ppl)), ("rows", Json::Arr(rows))]),
    )
}

// ===================================================================
// table2 + table4: one-shot vs Kwon-style; calibration sensitivity
// ===================================================================

pub fn table2(ctx: &ExpCtx) -> Result<()> {
    let mut rows = Vec::new();
    for task in ["squad-syn", "qqp-syn", "mnli-syn"] {
        let model = "bert-syn-base";
        let ds = ctx.dataset(model, task);
        let teacher = ctx.teacher(model, task, &ds)?;
        let env = ctx.env(model, Regime::Throughput)?;
        let minfo = ctx.engine.manifest.model(model).clone();
        let tinfo = ctx.engine.manifest.task(model, task).clone();
        let kind = ds.kind.clone();
        let sess = ctx.oneshot_session(model, task, &env, ctx.prune_cfg())?;
        for &t in &[1.5, 2.0] {
            // ZipLM one-shot
            let mut zs = teacher.clone();
            sess.oneshot(&mut zs, &ds, t)?;
            let zev = eval::evaluate(&ctx.engine, &zs, &ds, "dev")?;
            // Kwon-style: same captured Hessians, diagonal saliencies
            let mut ks = teacher.clone();
            let hs = sess.capture(&ks, &ds)?.hessians;
            baselines::fisher_oneshot(&mut ks, &minfo, &tinfo, &env, &hs, t)?;
            let kev = eval::evaluate(&ctx.engine, &ks, &ds, "dev")?;
            println!(
                "  table2 {task} {t}x: ziplm={:.4} kwon-style={:.4}",
                eval_value(&kind, &zev),
                eval_value(&kind, &kev)
            );
            rows.push(Json::obj(vec![
                ("task", Json::Str(task.into())),
                ("target", Json::Num(t)),
                ("ziplm", Json::Num(eval_value(&kind, &zev))),
                ("kwon_style", Json::Num(eval_value(&kind, &kev))),
            ]));
        }
    }
    ctx.write_result("table2", &Json::obj(vec![("rows", Json::Arr(rows))]))
}

pub fn table4(ctx: &ExpCtx) -> Result<()> {
    let model = "bert-syn-base";
    let task = "squad-syn";
    let ds = ctx.dataset(model, task);
    let teacher = ctx.teacher(model, task, &ds)?;
    let env = ctx.env(model, Regime::Throughput)?;
    let samples: Vec<usize> = if ctx.fast { vec![4, 32, 128] } else { vec![4, 32, 128, 512, 1024] };
    let mut rows = Vec::new();
    for &n in &samples {
        let mut row = vec![("samples", Json::Num(n as f64))];
        for &t in &[1.5, 2.0] {
            let mut st = teacher.clone();
            let mut cfg = ctx.prune_cfg();
            cfg.calib_samples = n;
            ctx.oneshot_session(model, task, &env, cfg)?.oneshot(&mut st, &ds, t)?;
            let ev = eval::evaluate(&ctx.engine, &st, &ds, "dev")?;
            println!("  table4 n={n} {t}x EM={:.4}", ev.metric);
            row.push(if t == 1.5 { ("em_1_5x", Json::Num(ev.metric)) } else { ("em_2x", Json::Num(ev.metric)) });
        }
        rows.push(Json::obj(row));
    }
    ctx.write_result("table4", &Json::obj(vec![("rows", Json::Arr(rows))]))
}

// ===================================================================
// table3: MLP-shrink speedups, V100-sim vs A100-sim (+ measured CPU)
// ===================================================================

pub fn table3(ctx: &ExpCtx) -> Result<()> {
    let dims = ArchDims::bert_base_paper();
    let widths = [3072usize, 1814, 1322, 302, 130, 76, 33];
    let v = InferenceEnv::analytic(Device::V100Sim, &dims, Regime::Throughput, &widths);
    let a = InferenceEnv::analytic(Device::A100Sim, &dims, Regime::Throughput, &widths);
    let cpu = ctx.env("bert-syn-base", Regime::Throughput)?;
    println!("== table3: MLP size | V100-sim | A100-sim | cpu-pjrt(scaled) ==");
    let mut rows = Vec::new();
    for &w in &widths {
        let sv = v.mlp_time(3072) / v.mlp_time(w);
        let sa = a.mlp_time(3072) / a.mlp_time(w);
        // scale paper widths onto our measured model's ladder
        let dense_w = cpu.table().mlp[0].0;
        let scaled = (w as f64 / 3072.0 * dense_w as f64).round() as usize;
        let sc = cpu.mlp_time(dense_w) / cpu.mlp_time(scaled.max(1));
        println!("  {w:>5}  {sv:>6.1}x  {sa:>6.1}x  {sc:>6.1}x");
        rows.push(Json::obj(vec![
            ("mlp", Json::Num(w as f64)),
            ("v100_sim", Json::Num(sv)),
            ("a100_sim", Json::Num(sa)),
            ("cpu_pjrt", Json::Num(sc)),
        ]));
    }
    ctx.write_result("table3", &Json::obj(vec![("rows", Json::Arr(rows))]))
}

// ===================================================================
// table5: distillation ablation (±L_token)
// ===================================================================

pub fn table5(ctx: &ExpCtx) -> Result<()> {
    let model = "bert-syn-base";
    let target = [4.0];
    let mut rows = Vec::new();
    for task in ["sst2-syn", "qnli-syn", "mnli-syn", "squad-syn"] {
        let ds = ctx.dataset(model, task);
        let teacher = ctx.teacher(model, task, &ds)?;
        let env = ctx.env(model, Regime::Throughput)?;
        let kind = ds.kind.clone();
        let mut vals = Vec::new();
        for with_token in [true, false] {
            let mut cfg = ctx.ft_cfg(true);
            if !with_token {
                cfg.lambdas = [1.0, 0.5, 0.0];
            }
            let stages = ctx
                .gradual_session(
                    model,
                    task,
                    &env,
                    &target,
                    ctx.prune_cfg(),
                    cfg,
                    Some(teacher.params.clone()),
                )?
                .run(teacher.clone(), &ds)?;
            let ev = eval::evaluate(&ctx.engine, &stages[0].state, &ds, "dev")?;
            vals.push(eval_value(&kind, &ev));
        }
        println!("  table5 {task}: with_Ltoken={:.4} without={:.4}", vals[0], vals[1]);
        rows.push(Json::obj(vec![
            ("task", Json::Str(task.into())),
            ("with_token", Json::Num(vals[0])),
            ("without_token", Json::Num(vals[1])),
        ]));
    }
    ctx.write_result("table5", &Json::obj(vec![("rows", Json::Arr(rows))]))
}

// ===================================================================
// table7 / table8: latency table dump; target vs achieved speedup
// ===================================================================

pub fn table7(ctx: &ExpCtx) -> Result<()> {
    for regime in [Regime::Throughput, Regime::Latency] {
        let env = ctx.env("bert-syn-base", regime)?;
        println!("{}", env.table().render());
        std::fs::create_dir_all(&ctx.results)?;
        std::fs::write(
            ctx.results.join(format!("table7_{}.txt", regime.name())),
            env.table().render(),
        )?;
    }
    Ok(())
}

pub fn table8(ctx: &ExpCtx) -> Result<()> {
    // target vs achieved speedup, via shape-specialized exports measured
    // end-to-end (see specialize + measure_specialized)
    let model = "bert-syn-base";
    let task = "squad-syn";
    let ds = ctx.dataset(model, task);
    let teacher = ctx.teacher(model, task, &ds)?;
    let env = ctx.env(model, Regime::Throughput)?;
    let targets: Vec<f64> = if ctx.fast { vec![2.0, 4.0] } else { vec![2.0, 4.0, 6.0, 8.0] };
    let dense_t = measure_specialized(ctx, &teacher, "dense")?;
    let sess = ctx.oneshot_session(model, task, &env, ctx.prune_cfg())?;
    let mut rows = Vec::new();
    for &t in &targets {
        let mut st = teacher.clone();
        let rep = sess.oneshot(&mut st, &ds, t)?;
        let pruned_t = measure_specialized(ctx, &st, &format!("t{t:.0}x"))?;
        let achieved = dense_t / pruned_t;
        let dev = (achieved - t) / t * 100.0;
        println!("  table8 target={t:.1}x est={:.2}x achieved={achieved:.2}x dev={dev:+.2}%", rep.est_speedup);
        rows.push(Json::obj(vec![
            ("target", Json::Num(t)),
            ("estimated", Json::Num(rep.est_speedup)),
            ("achieved", Json::Num(achieved)),
            ("deviation_pct", Json::Num(dev)),
        ]));
    }
    ctx.write_result("table8", &Json::obj(vec![("rows", Json::Arr(rows))]))
}

/// Export a masked checkpoint as a shape-materialized HLO via
/// `aot.py --specialize` (compile path), then measure median fwd time.
pub fn measure_specialized(ctx: &ExpCtx, state: &ModelState, tag: &str) -> Result<f64> {
    let minfo = ctx.engine.manifest.model(&state.model).clone();
    let tinfo = ctx.engine.manifest.task(&state.model, &state.task).clone();
    let dir = ctx.runs.join("specialized");
    std::fs::create_dir_all(&dir)?;
    let name = format!("spec_{}_{}_{tag}", state.model, state.task);
    // gather surviving weights in specialized layout order
    let (flat, heads, inters) = gather_specialized(state, &minfo, &tinfo)?;
    let spec = Json::obj(vec![
        ("model", Json::Str(state.model.clone())),
        ("task", Json::Str(state.task.clone())),
        ("name", Json::Str(name.clone())),
        ("heads", Json::arr_usize(&heads)),
        ("inters", Json::arr_usize(&inters)),
        ("batch", Json::Num(8.0)),
        ("seq", Json::Num(minfo.seq_len as f64)),
    ]);
    let spec_path = dir.join(format!("{name}.spec.json"));
    std::fs::write(&spec_path, spec.to_pretty())?;
    let hlo_path = dir.join(format!("{name}.hlo.txt"));
    if !hlo_path.exists() {
        let status = std::process::Command::new("python")
            .args(["-m", "compile.aot", "--specialize"])
            .arg(&spec_path)
            .arg("--out")
            .arg(&dir)
            .current_dir("python")
            .status()?;
        if !status.success() {
            return Err(anyhow!("specialize failed for {name}"));
        }
    }
    let exe = ctx.engine.compile_file(&hlo_path)?;
    let ids = vec![1i32; 8 * minfo.seq_len];
    let lits = vec![
        crate::runtime::lit_f32_shaped(&[flat.len()], &flat)?,
        crate::runtime::lit_i32(&[8, minfo.seq_len], &ids)?,
    ];
    let bench = crate::util::bench::Bench::quick();
    let stats = bench.run(&name, || Engine::run_exe(&exe, &lits).expect("spec exec"));
    Ok(stats.median_ns / 1e9)
}

pub use crate::models::gather_specialized;

// ===================================================================
// fig4: pruning for speedup vs pruning for sparsity
// ===================================================================

pub fn fig4(ctx: &ExpCtx) -> Result<()> {
    let model = "bert-syn-base";
    let task = "sst2-syn";
    let ds = ctx.dataset(model, task);
    let teacher = ctx.teacher(model, task, &ds)?;
    let env = ctx.env(model, Regime::Throughput)?;
    let targets: Vec<f64> = if ctx.fast { vec![2.0, 6.0] } else { vec![2.0, 4.0, 6.0, 10.0] };
    let mut rows = Vec::new();
    for mode in [TargetMode::Speedup, TargetMode::Sparsity] {
        let mut cfg = ctx.prune_cfg();
        cfg.target_mode = mode;
        let stages = ctx
            .gradual_session(
                model,
                task,
                &env,
                &targets,
                cfg,
                ctx.ft_cfg(true),
                Some(teacher.params.clone()),
            )?
            .run(teacher.clone(), &ds)?;
        for s in &stages {
            let ev = eval::evaluate(&ctx.engine, &s.state, &ds, "dev")?;
            let real = env.speedup(&s.report.layer_profile);
            println!(
                "  fig4 {:?} target={:.0}x real={:.2}x acc={:.4}",
                mode, s.report.target, real, ev.metric
            );
            rows.push(Json::obj(vec![
                ("mode", Json::Str(format!("{mode:?}"))),
                ("target", Json::Num(s.report.target)),
                ("real_speedup", Json::Num(real)),
                ("acc", Json::Num(ev.metric)),
            ]));
        }
    }
    ctx.write_result("fig4", &Json::obj(vec![("rows", Json::Arr(rows))]))
}

// ===================================================================
// fig5: scaling laws (extreme speedups, linear fit)
// ===================================================================

pub fn fig5(ctx: &ExpCtx) -> Result<()> {
    let mut out = Vec::new();
    for model in ["bert-syn-base", "bert-syn-large"] {
        let task = "squad-syn";
        let ds = ctx.dataset(model, task);
        let teacher = ctx.teacher(model, task, &ds)?;
        let env = ctx.env(model, Regime::Throughput)?;
        let targets: Vec<f64> =
            if ctx.fast { vec![2.0, 6.0, 12.0] } else { vec![2.0, 4.0, 8.0, 12.0, 16.0, 24.0] };
        let stages = ctx
            .gradual_session(
                model,
                task,
                &env,
                &targets,
                ctx.prune_cfg(),
                ctx.ft_cfg(true),
                Some(teacher.params.clone()),
            )?
            .run(teacher.clone(), &ds)?;
        let mut pts = Vec::new();
        for s in &stages {
            let ev = eval::evaluate(&ctx.engine, &s.state, &ds, "dev")?;
            pts.push((s.report.target, ev.metric));
        }
        // least-squares line acc ≈ a - b * speedup
        let n = pts.len() as f64;
        let sx: f64 = pts.iter().map(|p| p.0).sum();
        let sy: f64 = pts.iter().map(|p| p.1).sum();
        let sxx: f64 = pts.iter().map(|p| p.0 * p.0).sum();
        let sxy: f64 = pts.iter().map(|p| p.0 * p.1).sum();
        let b = (n * sxy - sx * sy) / (n * sxx - sx * sx);
        let a = (sy - b * sx) / n;
        println!("  fig5 {model}: acc ≈ {a:.3} + {b:.4} × speedup  pts={pts:?}");
        out.push(Json::obj(vec![
            ("model", Json::Str(model.into())),
            ("intercept", Json::Num(a)),
            ("slope", Json::Num(b)),
            (
                "points",
                Json::Arr(pts.iter().map(|&(x, y)| Json::Arr(vec![Json::Num(x), Json::Num(y)])).collect()),
            ),
        ]));
    }
    ctx.write_result("fig5", &Json::obj(vec![("models", Json::Arr(out))]))
}

// ===================================================================
// fig6: compound compression for CPU edge deployment
// ===================================================================

pub fn fig6(ctx: &ExpCtx) -> Result<()> {
    let model = "bert-syn-base";
    let task = "squad-syn";
    let ds = ctx.dataset(model, task);
    let teacher = ctx.teacher(model, task, &ds)?;
    let env = ctx.env(model, Regime::Throughput)?;
    let minfo = ctx.engine.manifest.model(model).clone();
    let tinfo = ctx.engine.manifest.task(model, task).clone();
    let targets: Vec<f64> = if ctx.fast { vec![2.0] } else { vec![2.0, 4.0] };
    let mut rows = Vec::new();
    // baseline: layer-drop compound pipeline (paper's comparator, App. A)
    for (method, use_ziplm) in [("ziplm+80%+int8", true), ("layerdrop+80%+int8", false)] {
        for &t in &targets {
            let mut st = teacher.clone();
            if use_ziplm {
                ctx.oneshot_session(model, task, &env, ctx.prune_cfg())?
                    .oneshot(&mut st, &ds, t)?;
            } else {
                baselines::layer_drop_for_speedup(&mut st, &minfo, &tinfo, &env, t)?;
            }
            let mut tr = Trainer::new(&ctx.engine, tinfo.n_params, Some(teacher.params.clone()));
            tr.train(&mut st, &ds, &ctx.ft_cfg(true))?;
            quant::unstructured_magnitude(&mut st, &tinfo, 0.8)?;
            quant::int8_quantize(&mut st, &tinfo)?;
            let ev = eval::evaluate(&ctx.engine, &st, &ds, "dev")?;
            // priced through the SAME env the pruner certified against
            // (DESIGN.md §13) — the free-standing CpuEngineModel pricer
            // is retired
            let sp = env.compound_speedup(minfo.n_layers, st.masks.density(), 0.8, true);
            println!("  fig6 {method} struct={t}x → cpu-sim {sp:.1}x EM={:.4}", ev.metric);
            rows.push(Json::obj(vec![
                ("method", Json::Str(method.into())),
                ("struct_target", Json::Num(t)),
                ("cpu_speedup", Json::Num(sp)),
                ("metric", Json::Num(ev.metric)),
            ]));
        }
    }
    ctx.write_result("fig6", &Json::obj(vec![("rows", Json::Arr(rows))]))
}

// ===================================================================
// compound: one inference-aware DP over pruning × quantization ×
// low-rank (DESIGN.md §13) — a mixed-axis certified family from ONE
// lattice, with the prune-only restriction checked against the legacy
// DP on the way
// ===================================================================

pub fn compound(ctx: &ExpCtx) -> Result<()> {
    use crate::compress::ChoiceProblem;
    use crate::eval::calib_loss;
    use crate::models::family::{FamilyManifest, FamilyMember};
    use crate::pruner::CompoundCfg;
    use crate::session::pipeline;
    use crate::spdy;

    let model = "bert-syn-base";
    let task = "sst2-syn";
    let ds = ctx.dataset(model, task);
    let teacher = ctx.teacher(model, task, &ds)?;
    let env = ctx.env(model, Regime::Throughput)?;
    let minfo = ctx.engine.manifest.model(model).clone();
    let tinfo = ctx.engine.manifest.task(model, task).clone();
    let pcfg = ctx.prune_cfg();
    let ccfg = CompoundCfg::default();
    let target = 2.0;

    // ONE capture serves every axis: the lattice scores int8 and
    // low-rank candidates against the same damped calibration Hessians
    // the pruning priors use
    let hs = pipeline::capture_hessians(&ctx.engine, &teacher, &ds, pcfg.calib_samples)?;
    let dbs = pipeline::build_databases(&ctx.engine, &teacher, &hs, &pcfg)?;
    let problem = pipeline::choice_problem(&dbs, &hs, &env, &minfo, &pcfg, &ccfg)?;
    let legacy = pipeline::spdy_problem(&dbs, &env, &minfo, TargetMode::Speedup);
    let dense = pipeline::dense_cost(&env, &minfo, TargetMode::Speedup);
    let budget = dense / target;

    // acceptance gate: restricting the lattice to the prune axis must
    // reproduce the legacy DP exactly (same choice indices)
    let legacy_sol = spdy::solve_dp(&legacy, &[], budget)
        .ok_or_else(|| anyhow!("legacy DP found no profile at {target}x"))?;
    let lifted_sol = ChoiceProblem::from_spdy(&legacy)
        .solve_dp(&[], budget)
        .ok_or_else(|| anyhow!("lifted prune-only DP found no profile at {target}x"))?;
    if legacy_sol != lifted_sol {
        return Err(anyhow!(
            "prune-only restriction diverged from the legacy DP: {legacy_sol:?} vs {lifted_sol:?}"
        ));
    }
    println!("  compound: prune-only lattice ≡ legacy DP at {target}x");

    // fixed single-axis profiles (the per-axis members), then the full
    // widened search over the whole lattice (the compound member)
    let quant_profile: Vec<usize> =
        problem.modules.iter().map(|s| s.find_axis("quant").unwrap_or(0)).collect();
    let lowrank_profile: Vec<usize> = problem
        .modules
        .iter()
        .map(|s| {
            let lr: Vec<usize> = (0..s.choices.len())
                .filter(|&i| s.choices[i].choice.axis() == "lowrank")
                .collect();
            lr.get(lr.len() / 2).copied().unwrap_or(0)
        })
        .collect();
    let search_cfg =
        spdy::SearchCfg { iters: pcfg.spdy.iters, seed: pcfg.spdy.seed, ..Default::default() };
    let lowered = problem.lower();
    let (mixed_sol, _) = spdy::search(&lowered, budget, &search_cfg, |prof| {
        let mut cand = teacher.clone();
        if pipeline::apply_choices(&mut cand, &dbs, &problem, prof, &minfo, &tinfo).is_err() {
            return f64::INFINITY;
        }
        calib_loss(&ctx.engine, &cand, &ds, pcfg.calib_samples.min(128)).unwrap_or(f64::INFINITY)
    })
    .ok_or_else(|| anyhow!("compound SPDY found no feasible profile at {target}x"))?;

    let dir = ctx.runs.join(format!("compound_{model}_{task}"));
    std::fs::create_dir_all(&dir)?;
    let mut fam = FamilyManifest::new(model, task, env.regime().name());
    fam.env = Some(env.clone());
    fam.buckets = env.bucket_ladder();
    let mut rows = Vec::new();
    let variants: Vec<(&str, Vec<usize>)> = vec![
        ("dense", vec![0; problem.modules.len()]),
        ("prune", lifted_sol),
        ("int8", quant_profile),
        ("lowrank", lowrank_profile),
        ("compound", mixed_sol),
    ];
    for (tag, prof) in variants {
        let mut st = teacher.clone();
        pipeline::apply_choices(&mut st, &dbs, &problem, &prof, &minfo, &tinfo)?;
        // real calibration loss for EVERY non-dense member — quant and
        // low-rank members record it too, not just pruned ones
        let loss = if tag == "dense" {
            0.0
        } else {
            calib_loss(&ctx.engine, &st, &ds, pcfg.calib_samples.min(128))?
        };
        let est = dense / problem.profile_cost(&prof);
        let ev = eval::evaluate(&ctx.engine, &st, &ds, "dev")?;
        let choices = problem.profile_choices(&prof);
        let ckpt = format!("{tag}.zlm");
        st.save(&dir.join(&ckpt))?;
        println!(
            "  compound {tag:>8}: est={est:.2}x calib={loss:.4} acc={:.4} mix={:?}",
            ev.metric,
            choices.axis_counts()
        );
        rows.push(Json::obj(vec![
            ("tag", Json::Str(tag.into())),
            ("est_speedup", Json::Num(est)),
            ("calib_loss", Json::Num(loss)),
            ("metric", Json::Num(ev.metric)),
            (
                "mix",
                Json::Arr(
                    choices
                        .axis_counts()
                        .into_iter()
                        .map(|(a, n)| Json::Arr(vec![Json::Str(a), Json::Num(n as f64)]))
                        .collect(),
                ),
            ),
        ]));
        fam.push(FamilyMember {
            tag: tag.into(),
            ckpt,
            target: if tag == "dense" { 1.0 } else { target },
            est_speedup: est,
            profile: problem.as_layer_profile(&prof),
            choices: Some(choices),
            calib_loss: Some(loss),
        });
    }
    let path = dir.join("family.json");
    fam.save(&path)?;
    println!("[family] wrote {} ({} members)", path.display(), fam.members.len());
    ctx.write_result(
        "compound",
        &Json::obj(vec![
            ("target", Json::Num(target)),
            ("prune_equiv", Json::Bool(true)),
            ("rows", Json::Arr(rows)),
        ]),
    )
}

// ===================================================================
// fig8/9: anatomy of pruned models (from saved gradual checkpoints)
// ===================================================================

pub fn fig8(ctx: &ExpCtx) -> Result<()> {
    let mut rows = Vec::new();
    let dir = std::fs::read_dir(&ctx.runs).map_err(|e| anyhow!("runs/: {e} (run fig2/fig3 first)"))?;
    for entry in dir.flatten() {
        let name = entry.file_name().to_string_lossy().to_string();
        if !name.starts_with("ziplm_") || !name.ends_with(".zlm") {
            continue;
        }
        let st = ModelState::load(&entry.path())?;
        let m = &st.masks;
        let heads: usize = (0..m.n_layers).map(|l| m.heads_alive(l)).sum();
        let ffn: usize = (0..m.n_layers).map(|l| m.ffn_alive(l)).sum();
        let hfrac = heads as f64 / (m.n_layers * m.n_heads) as f64;
        let ffrac = ffn as f64 / (m.n_layers * m.d_ff) as f64;
        println!("  fig8 {name}: heads={:.0}% ffn={:.0}%", hfrac * 100.0, ffrac * 100.0);
        rows.push(Json::obj(vec![
            ("checkpoint", Json::Str(name)),
            ("head_frac", Json::Num(hfrac)),
            ("ffn_frac", Json::Num(ffrac)),
            (
                "per_layer",
                Json::Arr(
                    m.summary()
                        .iter()
                        .map(|&(h, f)| Json::Arr(vec![Json::Num(h as f64), Json::Num(f as f64)]))
                        .collect(),
                ),
            ),
        ]));
    }
    ctx.write_result("fig8", &Json::obj(vec![("rows", Json::Arr(rows))]))
}

// ===================================================================
// family: App. F — emit a model family, serve it behind one SLA-aware
// coordinator, report per-class latency percentiles + SLA-hit rate
// ===================================================================

/// Fire a mixed-SLA workload at a running family coordinator: a
/// round-robin of best-effort (no SLA), `interactive` (latency-bound),
/// and `cheap` (min-speedup) classes, all submitted up front so the
/// queues see real pressure. A request counts as an SLA hit only if
/// its observed latency met the bound AND the member that served it
/// certified the requested speedup. Returns per-request
/// [`famserve::WorkRow`]s — class, latency, hit, and the shape bucket
/// the serving batch executed at — for [`famserve::summarize`].
pub fn mixed_workload(
    handle: &famserve::FamilyHandle,
    ds: &Dataset,
    n: usize,
    interactive_bound: std::time::Duration,
    cheap_speedup: f64,
) -> Result<Vec<famserve::WorkRow>> {
    let mut pending = Vec::with_capacity(n);
    for i in 0..n {
        let ex = &ds.dev[i % ds.dev.len()];
        let sla = match i % 3 {
            0 => None,
            1 => Some(famserve::Sla {
                class: "interactive".into(),
                max_latency: Some(interactive_bound),
                min_speedup: None,
            }),
            _ => Some(famserve::Sla {
                class: "cheap".into(),
                max_latency: None,
                min_speedup: Some(cheap_speedup),
            }),
        };
        let class = sla.as_ref().map(|s| s.class.clone()).unwrap_or_else(|| "best-effort".into());
        let bound = sla.as_ref().and_then(|s| s.max_latency);
        let min_s = sla.as_ref().and_then(|s| s.min_speedup);
        pending.push((class, bound, min_s, handle.submit(ex.ids.clone(), sla)?));
    }
    let mut rows = Vec::with_capacity(n);
    for (class, bound, min_s, rx) in pending {
        let reply = rx.recv()?;
        let latency_ok = bound.map(|b| reply.latency <= b).unwrap_or(true);
        let speedup_ok = min_s.map(|m| reply.member_speedup + 1e-9 >= m).unwrap_or(true);
        rows.push(famserve::WorkRow {
            class,
            latency: reply.latency,
            sla_hit: latency_ok && speedup_ok,
            bucket: reply.bucket,
        });
    }
    Ok(rows)
}

/// Family-serving experiment: gradual-prune a ≥2-member family, emit
/// its manifest, serve it behind the SLA-aware coordinator, and write
/// per-class latency/SLA results.
pub fn family(ctx: &ExpCtx) -> Result<()> {
    let (model, task) = ("bert-syn-base", "sst2-syn");
    let ds = ctx.dataset(model, task);
    let teacher = ctx.teacher(model, task, &ds)?;
    let env = ctx.env(model, Regime::Throughput)?;
    let targets: Vec<f64> = if ctx.fast { vec![2.0] } else { vec![1.5, 3.0] };
    let sess = ctx.gradual_session(
        model,
        task,
        &env,
        &targets,
        ctx.prune_cfg(),
        ctx.ft_cfg(true),
        Some(teacher.params.clone()),
    )?;
    let stages = sess.run(teacher.clone(), &ds)?;
    let base = ctx.runs.join(format!("family_{model}_{task}"));
    let fam = sess.emit_family(&teacher, &stages, &base)?;
    let members: Vec<(String, ModelState)> =
        fam.load_states(&base)?.into_iter().map(|(m, st)| (m.tag, st)).collect();
    let minfo = ctx.engine.manifest.model(model).clone();
    // serve at the bucket ladder the manifest was certified under
    // (DESIGN.md §9): shaped batches + lazy per-(member, bucket)
    // specialized executables, generic fallback while cold
    let handle = famserve::start(
        famserve::FamilyCfg {
            artifacts: ctx.engine.art_dir().to_path_buf(),
            max_batch: 8,
            max_wait: std::time::Duration::from_millis(2),
            pressure: 64,
            buckets: famserve::BucketLadder::new(fam.buckets.clone()),
            specialized: None,
        },
        members,
        &env,
    )?;
    let n = if ctx.fast { 48 } else { 120 };
    // interactive bound: a bit under one dense batched fwd, so latency-
    // sensitive requests must spill to a pruned member under load
    let bound = std::time::Duration::from_secs_f64(env.dense_time(minfo.n_layers) * 0.8);
    let rows = mixed_workload(&handle, &ds, n, bound, targets[0].min(2.0))?;
    let stats = handle.shutdown()?;
    let mut out_rows = Vec::new();
    for r in famserve::summarize(&rows) {
        println!(
            "  family [{:<12}] n={:<4} p50={:.1}ms p99={:.1}ms sla-hit={:.0}%",
            r.class,
            r.n,
            r.p50.as_secs_f64() * 1e3,
            r.p99.as_secs_f64() * 1e3,
            r.hit_rate * 100.0
        );
        out_rows.push(Json::obj(vec![
            ("class", Json::Str(r.class.clone())),
            ("n", Json::Num(r.n as f64)),
            ("p50_ms", Json::Num(r.p50.as_secs_f64() * 1e3)),
            ("p99_ms", Json::Num(r.p99.as_secs_f64() * 1e3)),
            ("sla_hit_rate", Json::Num(r.hit_rate)),
        ]));
    }
    // the §9 deliverable: realized per-bucket execution latency NEXT TO
    // the certified estimate, so the certify-vs-realize gap is a number
    let mut bucket_rows = Vec::new();
    for bkt in &stats.per_bucket {
        let (p50, cert) = (bkt.realized_p50.as_secs_f64(), bkt.certified.as_secs_f64());
        println!(
            "  family [bucket] {:>6} @ {}x{}{}: batches={:<3} realized p50={:.1}ms p99={:.1}ms certified={:.1}ms (gap {:+.0}%)",
            bkt.member,
            bkt.batch,
            bkt.seq,
            if bkt.specialized { " (specialized)" } else { " (generic)" },
            bkt.batches,
            p50 * 1e3,
            bkt.realized_p99.as_secs_f64() * 1e3,
            cert * 1e3,
            (p50 / cert.max(1e-12) - 1.0) * 100.0
        );
        bucket_rows.push(Json::obj(vec![
            ("member", Json::Str(bkt.member.clone())),
            ("batch", Json::Num(bkt.batch as f64)),
            ("seq", Json::Num(bkt.seq as f64)),
            ("specialized", Json::Bool(bkt.specialized)),
            ("batches", Json::Num(bkt.batches as f64)),
            ("requests", Json::Num(bkt.requests as f64)),
            ("share", Json::Num(bkt.share)),
            ("realized_p50_ms", Json::Num(p50 * 1e3)),
            ("realized_p99_ms", Json::Num(bkt.realized_p99.as_secs_f64() * 1e3)),
            ("certified_ms", Json::Num(cert * 1e3)),
        ]));
    }
    // realized sample stream: the offline input `ziplm adapt` consumes
    let samples_path = ctx.results.join("family_samples.json");
    std::fs::write(
        &samples_path,
        famserve::samples_to_json(&stats.samples).to_pretty() + "\n",
    )?;
    println!(
        "  family wrote {} realized sample(s) to {}",
        stats.samples.len(),
        samples_path.display()
    );
    println!(
        "  family served {} reqs / {} batches ({} coalesced), {} compile(s), {} cache hit(s), per-member {:?}",
        stats.requests,
        stats.batches,
        stats.coalesced_batches,
        stats.cache_builds,
        stats.cache_hits,
        stats.per_member
    );
    ctx.write_result(
        "family",
        &Json::obj(vec![
            ("classes", Json::Arr(out_rows)),
            ("buckets", Json::Arr(bucket_rows)),
            ("requests", Json::Num(stats.requests as f64)),
            ("batches", Json::Num(stats.batches as f64)),
            ("coalesced_batches", Json::Num(stats.coalesced_batches as f64)),
            ("cache_builds", Json::Num(stats.cache_builds as f64)),
            ("cache_hits", Json::Num(stats.cache_hits as f64)),
            ("pressure_reroutes", Json::Num(stats.pressure_reroutes as f64)),
            (
                "per_member",
                Json::Arr(
                    stats
                        .per_member
                        .iter()
                        .map(|(t, n)| {
                            Json::obj(vec![
                                ("member", Json::Str(t.clone())),
                                ("requests", Json::Num(*n as f64)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ]),
    )
}

// ===================================================================
// multienv: one capture, N inference environments → N certified
// families (paper §3.2 "any given inference environment"; DESIGN §8)
// ===================================================================

/// Analytic GPU environment at THIS model's architecture dims (the
/// paper's V100 roofline), priced over the model's own FFN ladder —
/// the "unavailable hardware" half of a multi-env run. Ctx-free so
/// `examples/multi_env.rs` builds the exact same env the `multienv`
/// driver certifies against. Being analytic, the env also carries a
/// principled seq-length sweep (quarter / half / full anchor seq,
/// [`crate::latency::analytic_seq_sweep`]), so families certified
/// against it record a multi-bucket serving ladder (DESIGN.md §9).
pub fn analytic_gpu_env(m: &crate::runtime::ModelInfo, regime: Regime) -> InferenceEnv {
    let dims = ArchDims {
        d_model: m.d_model,
        n_heads: m.n_heads,
        d_head: m.d_head,
        d_ff: m.d_ff,
        vocab: m.vocab,
        n_layers: m.n_layers,
        batch: 128,
        seq: m.seq_len,
    };
    // price the model's own ladder, anchored at its dense width
    let mut widths: Vec<usize> = vec![m.d_ff];
    widths.extend(m.ffn_ladder.iter().copied().filter(|&w| w < m.d_ff));
    let seqs = [m.seq_len / 4, m.seq_len / 2, m.seq_len];
    InferenceEnv::analytic_swept(Device::V100Sim, &dims, regime, &widths, &seqs)
}

/// Multi-env experiment: ONE Hessian capture + database build, then
/// certified families for a CPU-measured env AND an analytic-GPU env,
/// solved in parallel. A second session pinned to the GPU env then
/// resumes from the same directory and must compute NOTHING — the
/// store counters are the proof that retargeting is free of Hessian
/// recomputation.
pub fn multienv(ctx: &ExpCtx) -> Result<()> {
    let (model, task) = ("bert-syn-base", "sst2-syn");
    let ds = ctx.dataset(model, task);
    let teacher = ctx.teacher(model, task, &ds)?;
    let env_cpu = ctx.env(model, Regime::Throughput)?;
    let env_gpu = analytic_gpu_env(ctx.engine.manifest.model(model), Regime::Throughput);
    let targets: Vec<f64> = if ctx.fast { vec![1.5, 2.5] } else { vec![1.5, 2.0, 3.0] };
    let sdir = ctx.runs.join(format!("session_multienv_{model}_{task}"));
    let base = ctx.runs.join(format!("families_{model}_{task}"));
    let sess = CompressionSession::for_model(&ctx.engine, model, task)
        .with_env(env_cpu.clone())
        .with_targets(&targets)
        .with_prune_cfg(ctx.prune_cfg())
        .checkpoint_to(&sdir)
        .on_progress(crate::session::stdout_progress())
        .open()?;
    let envs = [env_cpu.clone(), env_gpu.clone()];
    let fams = sess.emit_families(&teacher, &ds, &envs, &base)?;
    let (computed, loaded) = sess.counters();
    println!("[multienv] emit_families: {computed} artifact(s) computed, {loaded} loaded");
    let mut rows = Vec::new();
    for (env, fam) in envs.iter().zip(&fams) {
        println!("  family on {}:", env.describe());
        for m in &fam.members {
            let (tag, t, est) = (&m.tag, m.target, m.est_speedup);
            println!("    {tag:>6}  target {t:>4.1}x  certified {est:>5.2}x");
        }
        rows.push(Json::obj(vec![
            ("env", Json::Str(env.describe())),
            ("dir", Json::Str(crate::session::env_slug(env))),
            ("env_embedded", Json::Bool(fam.env.is_some())),
            (
                "members",
                Json::Arr(
                    fam.members
                        .iter()
                        .map(|m| {
                            Json::obj(vec![
                                ("tag", Json::Str(m.tag.clone())),
                                ("target", Json::Num(m.target)),
                                ("est_speedup", Json::Num(m.est_speedup)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ]));
    }
    // proof of zero recomputation: a fresh session pinned to the GPU
    // env resumes every stage — capture, databases, AND its solve —
    // straight from the shared directory
    let sess2 = CompressionSession::for_model(&ctx.engine, model, task)
        .with_env(env_gpu.clone())
        .with_targets(&targets)
        .with_prune_cfg(ctx.prune_cfg())
        .checkpoint_to(&sdir)
        .open()?;
    let _ = sess2.capture(&teacher, &ds)?.build_dbs()?.solve(&ds, targets[0])?;
    let (c2, l2) = sess2.counters();
    println!("[multienv] gpu-env resume: {c2} computed, {l2} loaded (0 computed = no recapture)");
    if c2 != 0 {
        return Err(anyhow!("gpu-env resume recomputed {c2} artifact(s); expected 0"));
    }
    ctx.write_result(
        "multienv",
        &Json::obj(vec![
            ("families", Json::Arr(rows)),
            ("first_run_computed", Json::Num(computed as f64)),
            ("gpu_resume_computed", Json::Num(c2 as f64)),
            ("gpu_resume_loaded", Json::Num(l2 as f64)),
        ]),
    )
}

// ===================================================================
// chaos: supervised-fleet serving under injected faults (DESIGN §10)
// ===================================================================

/// Chaos experiment: run the seeded fault-injection campaign against a
/// simulated fleet serving a synthetic family derived from the model's
/// own anatomy, and record the outcome audit. Engine-light — pricing
/// comes from the analytic GPU env, no PJRT execution happens — so the
/// request-lifecycle invariant (`lost == 0`, replied + shed +
/// abandoned == submitted) is checked exactly, not sampled.
pub fn chaos(ctx: &ExpCtx) -> Result<()> {
    use crate::coordinator::chaos::{run_chaos_checked, TraceCfg, TraceClass};
    use crate::coordinator::family::BucketLadder;
    use crate::coordinator::fleet::{FleetCfg, FleetMember};
    use crate::runtime::{FaultPlan, FaultRates};

    let model = "bert-syn-base";
    let m = ctx.engine.manifest.model(model);
    let env = analytic_gpu_env(m, Regime::Throughput);
    // synthetic family anatomy from the model's own dims: dense plus
    // progressively narrower members down the FFN ladder
    let (dh, df) = env.dense_profile();
    let members: Vec<FleetMember> = [(1usize, 1usize), (2, 2), (4, 4)]
        .iter()
        .enumerate()
        .map(|(i, &(hdiv, fdiv))| FleetMember {
            tag: if i == 0 { "dense".into() } else { format!("{}x", 1 << i) },
            profile: vec![((dh / hdiv).max(1), (df / fdiv).max(1)); m.n_layers],
        })
        .collect();
    let requests = if ctx.fast { 96 } else { 256 };
    let cfg = FleetCfg {
        workers: 3,
        skews: vec![1.0, 1.25, 0.9],
        buckets: BucketLadder::new(env.bucket_ladder()),
        ..FleetCfg::default()
    };
    let rates = FaultRates {
        crash: 0.05,
        compile_fail: 0.1,
        slowdown: 0.1,
        slowdown_factor: 3.0,
        nan_latency: 0.02,
    };
    let trace = TraceCfg {
        requests,
        seed: 0xC0FFEE,
        arrival_gap: std::time::Duration::from_micros(50),
        len_range: (4, 32),
        classes: vec![
            TraceClass::best_effort(2.0),
            TraceClass {
                class: "realtime".into(),
                weight: 1.0,
                max_latency: Some(std::time::Duration::from_secs_f64(
                    env.dense_time(m.n_layers) * 0.8,
                )),
                min_speedup: None,
            },
            TraceClass {
                class: "throughput".into(),
                weight: 1.0,
                max_latency: None,
                min_speedup: Some(2.0),
            },
        ],
    };
    // faulty campaign + a fault-free control at the same trace seed
    let faulty = run_chaos_checked(
        cfg.clone(),
        members.clone(),
        &env,
        FaultPlan::seeded(0xDECAF, rates),
        &trace,
    )?;
    let control = run_chaos_checked(cfg, members, &env, FaultPlan::none(), &trace)?;
    println!("[chaos] faulty:\n{}", crate::coordinator::chaos::render_report(&faulty));
    println!("[chaos] control:\n{}", crate::coordinator::chaos::render_report(&control));
    // the control must show zero failure-path activity; admission may
    // still shed under transient backlog (that is admission control
    // working, not a fault), so shed stays a reported, legal outcome
    if control.stats.crashes != 0 || control.retried_replies != 0 {
        return Err(anyhow!(
            "fault-free control hit the failure path: {} crashes, {} retried replies",
            control.stats.crashes,
            control.retried_replies
        ));
    }
    let audit = |r: &crate::coordinator::chaos::ChaosReport| {
        Json::obj(vec![
            ("submitted", Json::Num(r.submitted as f64)),
            ("replied", Json::Num(r.replied as f64)),
            ("shed", Json::Num(r.shed as f64)),
            ("abandoned", Json::Num(r.abandoned as f64)),
            ("lost", Json::Num(r.lost as f64)),
            ("retried_replies", Json::Num(r.retried_replies as f64)),
            ("degraded_replies", Json::Num(r.degraded_replies as f64)),
            ("crashes", Json::Num(r.stats.crashes as f64)),
            ("restarts", Json::Num(r.stats.restarts as f64)),
            ("compile_failures", Json::Num(r.stats.compile_failures as f64)),
            ("normal_p50", Json::Num(r.stats.tails.normal_p50)),
            ("normal_p99", Json::Num(r.stats.tails.normal_p99)),
            ("degraded_p50", Json::Num(r.stats.tails.degraded_p50)),
            ("degraded_p99", Json::Num(r.stats.tails.degraded_p99)),
        ])
    };
    ctx.write_result(
        "chaos",
        &Json::obj(vec![("faulty", audit(&faulty)), ("control", audit(&control))]),
    )
}

/// One experiment driver.
pub type Driver = fn(&ExpCtx) -> Result<()>;

/// The single experiment registry: drives [`run`]'s dispatch, the
/// valid-id list in [`UnknownExperiment`], AND the `all` meta-id
/// (which executes the table in THIS order — cheap table dumps before
/// the long gradual runs). Adding an experiment means adding exactly
/// one row here.
pub const EXPERIMENTS: &[(&str, Driver)] = &[
    ("table7", table7),
    ("table3", table3),
    ("table2", table2),
    ("table4", table4),
    ("fig2", fig2),
    ("fig3", fig3),
    ("table5", table5),
    ("fig4", fig4),
    ("fig5", fig5),
    ("fig6", fig6),
    ("compound", compound),
    ("table1", table1),
    ("table8", table8),
    ("fig8", fig8),
    ("family", family),
    ("multienv", multienv),
    ("chaos", chaos),
];

/// Every experiment id [`run`] accepts, besides the `all` meta-id.
pub fn experiment_ids() -> Vec<&'static str> {
    EXPERIMENTS.iter().map(|&(id, _)| id).collect()
}

/// Structured "no such experiment" error: carries the offending id and
/// the full valid set, so callers (CLI, scripts) can render an
/// actionable message or match on it as the id set grows.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct UnknownExperiment {
    /// the id that failed to resolve
    pub id: String,
    /// every accepted id (see [`EXPERIMENTS`])
    pub valid: Vec<&'static str>,
}

impl std::fmt::Display for UnknownExperiment {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "unknown experiment `{}`; valid ids: {}, or `all`",
            self.id,
            self.valid.join(", ")
        )
    }
}

impl std::error::Error for UnknownExperiment {}

/// Dispatch by experiment id (`all` runs the whole registry in order).
pub fn run(ctx: &ExpCtx, id: &str) -> Result<()> {
    if id == "all" {
        for (eid, f) in EXPERIMENTS {
            println!("=== experiment {eid} ===");
            f(ctx)?;
        }
        return Ok(());
    }
    match EXPERIMENTS.iter().find(|&&(eid, _)| eid == id) {
        Some((_, f)) => f(ctx),
        None => Err(UnknownExperiment { id: id.to_string(), valid: experiment_ids() }.into()),
    }
}

#[cfg(test)]
mod tests {
    use super::UnknownExperiment;

    #[test]
    fn unknown_experiment_error_lists_valid_ids() {
        let e = UnknownExperiment { id: "fig99".into(), valid: super::experiment_ids() };
        let msg = e.to_string();
        assert!(msg.contains("`fig99`"), "{msg}");
        for (id, _) in super::EXPERIMENTS {
            assert!(msg.contains(id), "missing {id} in {msg}");
        }
        assert!(msg.contains("`all`"), "{msg}");
        // converts into the crate error type via std::error::Error,
        // preserving the rendered id list (the vendored anyhow is
        // string-backed, so Display is the contract here)
        let any: anyhow::Error = e.clone().into();
        assert_eq!(any.to_string(), e.to_string());
    }
}
