//! One-command reproduction harness: the scenario-matrix runner behind
//! `ziplm repro` (DESIGN.md §11).
//!
//! The paper's central claim is that ONE pipeline produces certified
//! accuracy-vs-speedup families across all settings: encoder and
//! decoder, one-shot and gradual, per inference environment. This
//! module turns that claim into a checkable surface — a full scenario
//! matrix {model} × {env} × {regime} × {speedup target} whose every
//! cell lands in a structured [`ReproReport`] (JSON + rendered
//! `REPORT.md`) with an explicit status:
//!
//! * `ran`    — computed live in this process;
//! * `cached` — backed by a precomputed ruler-style artifact (the
//!   measured-CPU latency tables, which need a real engine to
//!   re-measure);
//! * `error`  — the cell failed, and says why. A cell is NEVER
//!   silently dropped: the matrix enumeration is total.
//!
//! The kick-tires subset ([`run_kick_tires`]) is engine-free and
//! avoids every transcendental-function code path (no `exp`/`ln`
//! calls whose libm results could differ across machines), so its
//! report is bit-identical across runs AND across hosts. CI commits
//! the rendered tables as goldens (`rust/tests/repro_golden.rs`) —
//! any PR that shifts a certified speedup, drops a matrix cell, or
//! breaks determinism fails with a readable table diff. The full run
//! ([`run_full`]) drives the same matrix through the real
//! `CompressionSession`/`emit_families` API against live artifacts.

use std::path::{Path, PathBuf};
use std::time::Duration;

use anyhow::{anyhow, Result};

use crate::adapt::{detect_drift, fit_env, frontier_points, knee_point, propose_targets, DriftCfg};
use crate::compress::{Choice, ChoiceProblem, CompressionProfile, LayerChoice, QuantScheme};
use crate::coordinator::chaos::{gen_trace, run_chaos, TraceCfg, TraceClass};
use crate::coordinator::family::{BucketLadder, MemberRoute};
use crate::coordinator::fleet::{FleetCfg, FleetMember, RetryPolicy};
use crate::coordinator::replay::{replay, replay_samples, ReplayCfg};
use crate::env::{CostModel, InferenceEnv, Regime};
use crate::latency::{low_rank_ffn_width, ArchDims, Device, LatencyTable};
use crate::models::family::{FamilyManifest, FamilyMember};
use crate::runtime::{FaultPlan, FaultRates};
use crate::spdy::{solve_dp, LevelOpt, ModuleLevels, SpdyProblem};
use crate::util::json::Json;
use crate::util::rng::Rng;

use super::ExpCtx;

/// Default pinned seed for reproduction runs.
pub const DEFAULT_SEED: u64 = 7;
/// Speedup-target ladder (the matrix's fourth axis).
pub const TARGETS: [f64; 3] = [1.5, 2.0, 3.0];
/// Inference-environment axis.
pub const ENVS: [&str; 3] = ["cpu-measured", "gpu-sweep", "edge"];
/// Pruning-regime axis.
pub const REGIMES: [&str; 2] = ["oneshot", "gradual"];

/// Attention-head levels per module (dense first).
const HEAD_LADDER: [usize; 5] = [4, 3, 2, 1, 0];
/// FFN-width levels per module (dense first; exact multiples of 32 so
/// no level needs transcendental math to derive).
const FFN_LADDER: [usize; 8] = [512, 384, 256, 192, 128, 64, 32, 0];

const GAMMA: u64 = 0x9E37_79B9_7F4A_7C15;

// ------------------------------------------------------------- models

/// One model axis entry: the synthetic-architecture dims the repo's
/// compile pipeline bakes (python/compile/configs.py).
#[derive(Clone, Copy, Debug)]
pub struct ReproModel {
    /// manifest model name
    pub name: &'static str,
    /// manifest task name (used by the full, engine-backed run)
    pub task: &'static str,
    /// transformer layers
    pub n_layers: usize,
    /// hidden size
    pub d_model: usize,
    /// attention heads
    pub n_heads: usize,
    /// per-head dim
    pub d_head: usize,
    /// FFN intermediate width
    pub d_ff: usize,
    /// vocab size
    pub vocab: usize,
    /// padded sequence length
    pub seq: usize,
    /// causal (decoder) vs bidirectional (encoder)
    pub causal: bool,
}

/// The {encoder, decoder} model axis.
pub fn models() -> [ReproModel; 2] {
    [
        ReproModel {
            name: "bert-syn-base",
            task: "sst2-syn",
            n_layers: 4,
            d_model: 128,
            n_heads: 4,
            d_head: 32,
            d_ff: 512,
            vocab: 2048,
            seq: 64,
            causal: false,
        },
        ReproModel {
            name: "gpt-syn",
            task: "corpus-syn",
            n_layers: 4,
            d_model: 128,
            n_heads: 4,
            d_head: 32,
            d_ff: 512,
            vocab: 2048,
            seq: 128,
            causal: true,
        },
    ]
}

fn dims(m: &ReproModel, batch: usize) -> ArchDims {
    ArchDims {
        d_model: m.d_model,
        n_heads: m.n_heads,
        d_head: m.d_head,
        d_ff: m.d_ff,
        vocab: m.vocab,
        n_layers: m.n_layers,
        batch,
        seq: m.seq,
    }
}

// ------------------------------------------------------ numeric rules

/// Quantize to 4 decimal places, half away from zero — every float in
/// the report goes through this so the JSON and the rendered tables
/// are stable under last-bit arithmetic drift.
pub fn q4(x: f64) -> f64 {
    (x * 10000.0).round() / 10000.0
}

/// Render a report number exactly like the JSON writer does (integers
/// lose the `.0`, everything else is shortest-roundtrip), so the
/// markdown tables and the JSON agree byte-for-byte on every value.
pub fn fmt_num(n: f64) -> String {
    if n.fract() == 0.0 && n.abs() < 1e15 {
        format!("{}", n as i64)
    } else {
        format!("{n}")
    }
}

/// Derive an independent sub-seed per matrix coordinate (SplitMix-style
/// golden-ratio mix, matching the repo's other seed derivations).
fn sub_seed(seed: u64, idx: u64) -> u64 {
    seed ^ idx.wrapping_add(1).wrapping_mul(GAMMA)
}

// ------------------------------------------------------ report schema

/// Outcome status of one matrix cell.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CellStatus {
    /// computed live in this run
    Ran,
    /// backed by a precomputed ruler-style artifact
    Cached,
    /// failed; the cell records why instead of disappearing
    Error,
}

impl CellStatus {
    /// Wire name (lands in the JSON and the rendered tables).
    pub fn name(self) -> &'static str {
        match self {
            CellStatus::Ran => "ran",
            CellStatus::Cached => "cached",
            CellStatus::Error => "error",
        }
    }

    /// Parse a wire name back.
    pub fn parse(s: &str) -> Option<CellStatus> {
        match s {
            "ran" => Some(CellStatus::Ran),
            "cached" => Some(CellStatus::Cached),
            "error" => Some(CellStatus::Error),
            _ => None,
        }
    }
}

/// One {model, regime, env, target} matrix cell.
#[derive(Clone, Debug)]
pub struct ScenarioCell {
    /// model-axis name
    pub model: String,
    /// regime-axis name (`oneshot` | `gradual`)
    pub regime: String,
    /// env-axis name
    pub env: String,
    /// requested speedup target
    pub target: f64,
    /// outcome status
    pub status: CellStatus,
    /// certified speedup actually achieved (q4; 0 on error)
    pub certified: f64,
    /// solver proxy error paid (sum of squared priors, q4; 0 on error)
    pub proxy_error: f64,
    /// per-layer (heads, ffn) profile (empty on error)
    pub profile: Vec<(usize, usize)>,
    /// failure description (empty unless status is `error`)
    pub error: String,
}

impl ScenarioCell {
    /// JSON form (error cells omit the result fields, success cells
    /// omit `error` — so a cell can never look half-succeeded).
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("model", Json::Str(self.model.clone())),
            ("regime", Json::Str(self.regime.clone())),
            ("env", Json::Str(self.env.clone())),
            ("target", Json::Num(self.target)),
            ("status", Json::Str(self.status.name().to_string())),
        ];
        if self.status == CellStatus::Error {
            fields.push(("error", Json::Str(self.error.clone())));
        } else {
            fields.push(("certified", Json::Num(self.certified)));
            fields.push(("proxy_error", Json::Num(self.proxy_error)));
            fields.push((
                "profile",
                Json::Arr(
                    self.profile
                        .iter()
                        .map(|&(h, f)| Json::Arr(vec![Json::Num(h as f64), Json::Num(f as f64)]))
                        .collect(),
                ),
            ));
        }
        Json::obj(fields)
    }

    /// Parse the JSON form back.
    pub fn from_json(j: &Json) -> Result<ScenarioCell> {
        let field = |k: &str| -> Result<String> {
            j.get(k)
                .and_then(Json::as_str)
                .map(str::to_string)
                .ok_or_else(|| anyhow!("cell: missing `{k}`"))
        };
        let status = CellStatus::parse(&field("status")?)
            .ok_or_else(|| anyhow!("cell: bad status"))?;
        let num = |k: &str| j.get(k).and_then(Json::as_f64).unwrap_or(0.0);
        let profile = j
            .get("profile")
            .and_then(Json::as_arr)
            .map(|a| {
                a.iter()
                    .map(|e| {
                        (
                            e.idx(0).and_then(Json::as_usize).unwrap_or(0),
                            e.idx(1).and_then(Json::as_usize).unwrap_or(0),
                        )
                    })
                    .collect()
            })
            .unwrap_or_default();
        Ok(ScenarioCell {
            model: field("model")?,
            regime: field("regime")?,
            env: field("env")?,
            target: j
                .get("target")
                .and_then(Json::as_f64)
                .ok_or_else(|| anyhow!("cell: missing `target`"))?,
            status,
            certified: num("certified"),
            proxy_error: num("proxy_error"),
            profile,
            error: j.get("error").and_then(Json::as_str).unwrap_or("").to_string(),
        })
    }
}

/// Chaos-ledger balance for one family's fault-injection campaign.
/// Only scheduling-independent fields are recorded: the outcome MIX
/// depends on thread timing, the LEDGER BALANCE must not.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ChaosSummary {
    /// requests submitted from the seeded trace
    pub submitted: usize,
    /// requests with no terminal outcome (the invariant says 0)
    pub lost: usize,
    /// whether Replied + Shed + Abandoned == submitted held
    pub balanced: bool,
}

/// Certified summary of one family member.
#[derive(Clone, Debug)]
pub struct MemberSummary {
    /// member tag (`dense`, `1.5x`, …)
    pub tag: String,
    /// certified speedup (q4)
    pub est_speedup: f64,
    /// certified one-batch time at the anchor shape, ms (q4)
    pub est_batch_time_ms: f64,
}

/// One certified-vs-realized row: a (member, bucket, specialized?)
/// serving cell from the deterministic replay.
#[derive(Clone, Debug)]
pub struct BucketRow {
    /// member tag
    pub member: String,
    /// executed batch dimension
    pub batch: usize,
    /// executed padded seq
    pub seq: usize,
    /// bucket-specialized (vs generic) execution
    pub specialized: bool,
    /// executed batches
    pub batches: usize,
    /// requests served
    pub requests: usize,
    /// certified one-batch estimate, ms (q4)
    pub certified_ms: f64,
    /// realized median, ms (q4)
    pub realized_p50_ms: f64,
    /// realized 99th percentile, ms (q4)
    pub realized_p99_ms: f64,
    /// realized p50 over certified (q4)
    pub gap: f64,
}

/// Per-(model, env) family section: members, replayed realized stats,
/// and the chaos-ledger balance.
#[derive(Clone, Debug)]
pub struct FamilyBlock {
    /// model-axis name
    pub model: String,
    /// env-axis name
    pub env: String,
    /// members, ascending certified speedup (dense first)
    pub members: Vec<MemberSummary>,
    /// serving-bucket ladder the stats are keyed by
    pub buckets: Vec<(usize, usize)>,
    /// certified-vs-realized rows
    pub per_bucket: Vec<BucketRow>,
    /// fault-injection ledger balance
    pub chaos: ChaosSummary,
}

impl FamilyBlock {
    /// JSON form.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("model", Json::Str(self.model.clone())),
            ("env", Json::Str(self.env.clone())),
            (
                "members",
                Json::Arr(
                    self.members
                        .iter()
                        .map(|m| {
                            Json::obj(vec![
                                ("tag", Json::Str(m.tag.clone())),
                                ("est_speedup", Json::Num(m.est_speedup)),
                                ("est_batch_time_ms", Json::Num(m.est_batch_time_ms)),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "buckets",
                Json::Arr(
                    self.buckets
                        .iter()
                        .map(|&(b, s)| Json::Arr(vec![Json::Num(b as f64), Json::Num(s as f64)]))
                        .collect(),
                ),
            ),
            (
                "per_bucket",
                Json::Arr(
                    self.per_bucket
                        .iter()
                        .map(|r| {
                            Json::obj(vec![
                                ("member", Json::Str(r.member.clone())),
                                ("batch", Json::Num(r.batch as f64)),
                                ("seq", Json::Num(r.seq as f64)),
                                ("specialized", Json::Bool(r.specialized)),
                                ("batches", Json::Num(r.batches as f64)),
                                ("requests", Json::Num(r.requests as f64)),
                                ("certified_ms", Json::Num(r.certified_ms)),
                                ("realized_p50_ms", Json::Num(r.realized_p50_ms)),
                                ("realized_p99_ms", Json::Num(r.realized_p99_ms)),
                                ("gap", Json::Num(r.gap)),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "chaos",
                Json::obj(vec![
                    ("submitted", Json::Num(self.chaos.submitted as f64)),
                    ("lost", Json::Num(self.chaos.lost as f64)),
                    ("balanced", Json::Bool(self.chaos.balanced)),
                ]),
            ),
        ])
    }

    /// Parse the JSON form back.
    pub fn from_json(j: &Json) -> Result<FamilyBlock> {
        let str_of = |v: &Json, k: &str| -> Result<String> {
            v.get(k)
                .and_then(Json::as_str)
                .map(str::to_string)
                .ok_or_else(|| anyhow!("family: missing `{k}`"))
        };
        let members = j
            .get("members")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("family: missing `members`"))?
            .iter()
            .map(|m| {
                Ok(MemberSummary {
                    tag: str_of(m, "tag")?,
                    est_speedup: m.get("est_speedup").and_then(Json::as_f64).unwrap_or(0.0),
                    est_batch_time_ms: m
                        .get("est_batch_time_ms")
                        .and_then(Json::as_f64)
                        .unwrap_or(0.0),
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let buckets = j
            .get("buckets")
            .and_then(Json::as_arr)
            .map(|a| {
                a.iter()
                    .map(|e| {
                        (
                            e.idx(0).and_then(Json::as_usize).unwrap_or(0),
                            e.idx(1).and_then(Json::as_usize).unwrap_or(0),
                        )
                    })
                    .collect()
            })
            .unwrap_or_default();
        let per_bucket = j
            .get("per_bucket")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("family: missing `per_bucket`"))?
            .iter()
            .map(|r| {
                let num = |k: &str| r.get(k).and_then(Json::as_f64).unwrap_or(0.0);
                let int = |k: &str| r.get(k).and_then(Json::as_usize).unwrap_or(0);
                Ok(BucketRow {
                    member: str_of(r, "member")?,
                    batch: int("batch"),
                    seq: int("seq"),
                    specialized: r.get("specialized").and_then(Json::as_bool).unwrap_or(false),
                    batches: int("batches"),
                    requests: int("requests"),
                    certified_ms: num("certified_ms"),
                    realized_p50_ms: num("realized_p50_ms"),
                    realized_p99_ms: num("realized_p99_ms"),
                    gap: num("gap"),
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let chaos = j.get("chaos").ok_or_else(|| anyhow!("family: missing `chaos`"))?;
        Ok(FamilyBlock {
            model: str_of(j, "model")?,
            env: str_of(j, "env")?,
            members,
            buckets,
            per_bucket,
            chaos: ChaosSummary {
                submitted: chaos.get("submitted").and_then(Json::as_usize).unwrap_or(0),
                lost: chaos.get("lost").and_then(Json::as_usize).unwrap_or(0),
                balanced: chaos.get("balanced").and_then(Json::as_bool).unwrap_or(false),
            },
        })
    }
}

/// One adapt-loop section (DESIGN.md §12): a seeded DRIFTED trace is
/// replayed against the family's serving routes, and the pure `adapt`
/// pipeline (`detect_drift` → `fit_env` → frontier proposal) runs on
/// the realized samples. Engine-free end to end — no weights, no
/// Hessian recomputes — so every number here is bit-stable under the
/// pinned seed, exactly like the matrix cells.
#[derive(Clone, Debug, PartialEq)]
pub struct AdaptBlock {
    /// model-axis name
    pub model: String,
    /// env-axis name the family was certified against
    pub env: String,
    /// requests replayed in the drifted trace
    pub requests: usize,
    /// request-weighted mean |realized/certified − 1| (q4)
    pub latency_drift: f64,
    /// request-weighted mean relative shape deviation from the anchor (q4)
    pub mass_shift: f64,
    /// fraction of requests whose batch overran its certified estimate (q4)
    pub overrun_rate: f64,
    /// detector verdict under the default thresholds
    pub drifted: bool,
    /// fitted env anchor batch
    pub fitted_batch: usize,
    /// fitted env anchor seq
    pub fitted_seq: usize,
    /// fitted-over-certified dense-time ratio (q4) — the device skew
    /// the fitted env applies so its anchor prices at the realized rate
    pub fitted_skew: f64,
    /// fitted seq sweep on the observed support, `(seq, scale q4)` rows
    pub fitted_sweep: Vec<(usize, f64)>,
    /// frontier knee speedup (q4; 0 when the frontier is too small)
    pub knee: f64,
    /// recommended next targets (q4, ascending, deduplicated)
    pub targets: Vec<f64>,
}

impl AdaptBlock {
    /// JSON form.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("model", Json::Str(self.model.clone())),
            ("env", Json::Str(self.env.clone())),
            ("requests", Json::Num(self.requests as f64)),
            ("latency_drift", Json::Num(self.latency_drift)),
            ("mass_shift", Json::Num(self.mass_shift)),
            ("overrun_rate", Json::Num(self.overrun_rate)),
            ("drifted", Json::Bool(self.drifted)),
            (
                "fitted",
                Json::obj(vec![
                    ("batch", Json::Num(self.fitted_batch as f64)),
                    ("seq", Json::Num(self.fitted_seq as f64)),
                    ("skew", Json::Num(self.fitted_skew)),
                    (
                        "sweep",
                        Json::Arr(
                            self.fitted_sweep
                                .iter()
                                .map(|&(s, sc)| {
                                    Json::Arr(vec![Json::Num(s as f64), Json::Num(sc)])
                                })
                                .collect(),
                        ),
                    ),
                ]),
            ),
            ("knee", Json::Num(self.knee)),
            ("targets", Json::arr_f64(&self.targets)),
        ])
    }

    /// Parse the JSON form back.
    pub fn from_json(j: &Json) -> Result<AdaptBlock> {
        let field = |k: &str| -> Result<String> {
            j.get(k)
                .and_then(Json::as_str)
                .map(str::to_string)
                .ok_or_else(|| anyhow!("adapt: missing `{k}`"))
        };
        let num = |k: &str| j.get(k).and_then(Json::as_f64).unwrap_or(0.0);
        let fitted = j.get("fitted").ok_or_else(|| anyhow!("adapt: missing `fitted`"))?;
        let fitted_sweep = fitted
            .get("sweep")
            .and_then(Json::as_arr)
            .map(|a| {
                a.iter()
                    .map(|e| {
                        (
                            e.idx(0).and_then(Json::as_usize).unwrap_or(0),
                            e.idx(1).and_then(Json::as_f64).unwrap_or(0.0),
                        )
                    })
                    .collect()
            })
            .unwrap_or_default();
        Ok(AdaptBlock {
            model: field("model")?,
            env: field("env")?,
            requests: j.get("requests").and_then(Json::as_usize).unwrap_or(0),
            latency_drift: num("latency_drift"),
            mass_shift: num("mass_shift"),
            overrun_rate: num("overrun_rate"),
            drifted: j.get("drifted").and_then(Json::as_bool).unwrap_or(false),
            fitted_batch: fitted.get("batch").and_then(Json::as_usize).unwrap_or(0),
            fitted_seq: fitted.get("seq").and_then(Json::as_usize).unwrap_or(0),
            fitted_skew: fitted.get("skew").and_then(Json::as_f64).unwrap_or(0.0),
            fitted_sweep,
            knee: num("knee"),
            targets: j
                .get("targets")
                .and_then(Json::as_arr)
                .map(|a| a.iter().filter_map(Json::as_f64).collect())
                .unwrap_or_default(),
        })
    }
}

/// One member row of a compound-lattice section: a single-axis
/// restriction (or the full mixed solve) of the widened DP.
#[derive(Clone, Debug, PartialEq)]
pub struct CompoundMember {
    /// member tag (`dense`, `prune`, `int8`, `lowrank`, `compound`)
    pub tag: String,
    /// per-axis module mix of the member's profile (`axis=n`, space-joined)
    pub axis: String,
    /// certified speedup under the env's cost model (q4)
    pub certified: f64,
    /// solver objective paid: Σ loss² over chosen lattice entries (q4)
    pub loss: f64,
}

/// Per-model compound-compression section (DESIGN.md §13): the typed
/// choice lattice — pruning levels plus env-priced int8 and low-rank
/// FFN entries with exact-arithmetic synthetic losses — solved by the
/// SAME widened DP the session pipeline uses. Engine-free and
/// transcendental-free, so bit-stable like the matrix cells.
#[derive(Clone, Debug, PartialEq)]
pub struct CompoundBlock {
    /// model-axis name
    pub model: String,
    /// env-axis name the lattice was priced against
    pub env: String,
    /// speedup target every non-dense member solved for
    pub target: f64,
    /// whether the prune-only lattice restriction reproduced the
    /// legacy DP's exact choice indices (the tentpole invariant)
    pub prune_equiv: bool,
    /// member rows, fixed order: dense, prune, int8, lowrank, compound
    pub members: Vec<CompoundMember>,
    /// module count per axis in the full-lattice solve, axis-sorted
    pub axes: Vec<(String, usize)>,
}

impl CompoundBlock {
    /// JSON form.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("model", Json::Str(self.model.clone())),
            ("env", Json::Str(self.env.clone())),
            ("target", Json::Num(self.target)),
            ("prune_equiv", Json::Bool(self.prune_equiv)),
            (
                "members",
                Json::Arr(
                    self.members
                        .iter()
                        .map(|m| {
                            Json::obj(vec![
                                ("tag", Json::Str(m.tag.clone())),
                                ("axis", Json::Str(m.axis.clone())),
                                ("certified", Json::Num(m.certified)),
                                ("loss", Json::Num(m.loss)),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "axes",
                Json::Arr(
                    self.axes
                        .iter()
                        .map(|(a, n)| {
                            Json::Arr(vec![Json::Str(a.clone()), Json::Num(*n as f64)])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    /// Parse the JSON form back.
    pub fn from_json(j: &Json) -> Result<CompoundBlock> {
        let field = |k: &str| -> Result<String> {
            j.get(k)
                .and_then(Json::as_str)
                .map(str::to_string)
                .ok_or_else(|| anyhow!("compound: missing `{k}`"))
        };
        let members = j
            .get("members")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("compound: missing `members`"))?
            .iter()
            .map(|m| {
                Ok(CompoundMember {
                    tag: m
                        .get("tag")
                        .and_then(Json::as_str)
                        .map(str::to_string)
                        .ok_or_else(|| anyhow!("compound member: missing `tag`"))?,
                    axis: m.get("axis").and_then(Json::as_str).unwrap_or("").to_string(),
                    certified: m.get("certified").and_then(Json::as_f64).unwrap_or(0.0),
                    loss: m.get("loss").and_then(Json::as_f64).unwrap_or(0.0),
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let axes = j
            .get("axes")
            .and_then(Json::as_arr)
            .map(|a| {
                a.iter()
                    .map(|e| {
                        (
                            e.idx(0).and_then(Json::as_str).unwrap_or("").to_string(),
                            e.idx(1).and_then(Json::as_usize).unwrap_or(0),
                        )
                    })
                    .collect()
            })
            .unwrap_or_default();
        Ok(CompoundBlock {
            model: field("model")?,
            env: field("env")?,
            target: j
                .get("target")
                .and_then(Json::as_f64)
                .ok_or_else(|| anyhow!("compound: missing `target`"))?,
            prune_equiv: j.get("prune_equiv").and_then(Json::as_bool).unwrap_or(false),
            members,
            axes,
        })
    }
}

/// The structured reproduction report: every matrix cell plus the
/// per-(model, env) family sections.
#[derive(Clone, Debug)]
pub struct ReproReport {
    /// `kick-tires` or `full`
    pub mode: String,
    /// pinned seed the run derived everything from
    pub seed: u64,
    /// all matrix cells, enumeration order (model → env → regime →
    /// target); total by construction
    pub cells: Vec<ScenarioCell>,
    /// family sections for every (model, env) whose env constructed
    pub families: Vec<FamilyBlock>,
    /// adapt-loop sections (one per `gpu-sweep` family; DESIGN.md §12)
    pub adapt: Vec<AdaptBlock>,
    /// compound-lattice sections (one per model; DESIGN.md §13)
    pub compound: Vec<CompoundBlock>,
}

impl ReproReport {
    /// JSON form (schema version 1; `adapt` and `compound` are
    /// additive — readers of older reports see an absent key, not a
    /// version bump).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("version", Json::Num(1.0)),
            ("mode", Json::Str(self.mode.clone())),
            ("seed", Json::Num(self.seed as f64)),
            ("cells", Json::Arr(self.cells.iter().map(ScenarioCell::to_json).collect())),
            ("families", Json::Arr(self.families.iter().map(FamilyBlock::to_json).collect())),
            ("adapt", Json::Arr(self.adapt.iter().map(AdaptBlock::to_json).collect())),
            ("compound", Json::Arr(self.compound.iter().map(CompoundBlock::to_json).collect())),
        ])
    }

    /// Parse the JSON form back.
    pub fn from_json(j: &Json) -> Result<ReproReport> {
        let cells = j
            .get("cells")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("report: missing `cells`"))?
            .iter()
            .map(ScenarioCell::from_json)
            .collect::<Result<Vec<_>>>()?;
        let families = j
            .get("families")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("report: missing `families`"))?
            .iter()
            .map(FamilyBlock::from_json)
            .collect::<Result<Vec<_>>>()?;
        let adapt = match j.get("adapt").and_then(Json::as_arr) {
            Some(a) => a.iter().map(AdaptBlock::from_json).collect::<Result<Vec<_>>>()?,
            None => Vec::new(),
        };
        let compound = match j.get("compound").and_then(Json::as_arr) {
            Some(a) => a.iter().map(CompoundBlock::from_json).collect::<Result<Vec<_>>>()?,
            None => Vec::new(),
        };
        Ok(ReproReport {
            mode: j.req_str("mode").to_string(),
            seed: j.get("seed").and_then(Json::as_usize).unwrap_or(0) as u64,
            cells,
            families,
            adapt,
            compound,
        })
    }
}

// -------------------------------------------------- matrix enumeration

/// The full cell key space in enumeration order — the ground truth the
/// totality/injectivity property tests compare reports against.
pub fn matrix_keys() -> Vec<(String, String, String, f64)> {
    let mut out = Vec::new();
    for m in models() {
        for env in ENVS {
            for regime in REGIMES {
                for t in TARGETS {
                    out.push((m.name.to_string(), regime.to_string(), env.to_string(), t));
                }
            }
        }
    }
    out
}

// ------------------------------------------------------- environments

/// Construct the env for one (model, env-axis) coordinate of the
/// engine-free subset. `cpu-measured` loads a precomputed table (the
/// ruler fallback — re-measuring needs a real engine) and is `cached`;
/// the analytic envs are computed live and are `ran`.
fn kick_env(
    m: &ReproModel,
    env_name: &str,
    precomputed: &Path,
) -> Result<(InferenceEnv, CellStatus)> {
    match env_name {
        "cpu-measured" => {
            let path = precomputed.join(format!("latency_{}_throughput.json", m.name));
            let table = LatencyTable::load(&path)
                .map_err(|e| anyhow!("precomputed latency table {}: {e}", path.display()))?;
            Ok((InferenceEnv::measured(table)?.with_batch_shape(8, m.seq), CellStatus::Cached))
        }
        "gpu-sweep" => Ok((
            InferenceEnv::analytic_swept(
                Device::V100Sim,
                &dims(m, 32),
                Regime::Throughput,
                &FFN_LADDER,
                &[m.seq / 4, m.seq / 2, m.seq],
            ),
            CellStatus::Ran,
        )),
        "edge" => Ok((
            InferenceEnv::analytic(Device::CpuPjrt, &dims(m, 1), Regime::Latency, &FFN_LADDER),
            CellStatus::Ran,
        )),
        other => Err(anyhow!("unknown env axis `{other}`")),
    }
}

/// Synthetic per-module sensitivity weights, pure in (seed, model):
/// stand-ins for the calibration-derived error priors of the full run,
/// drawn from the deterministic [`Rng`] (no transcendentals).
fn sensitivity_weights(seed: u64, model_idx: usize, n_modules: usize) -> Vec<f64> {
    let mut rng = Rng::new(sub_seed(seed, model_idx as u64));
    (0..n_modules).map(|_| 0.55 + 0.45 * rng.f64()).collect()
}

/// Build the SPDY instance for one (model, env): per layer an attn
/// module over [`HEAD_LADDER`] and an FFN module over [`FFN_LADDER`],
/// each level priced by the env's own cost model and carrying a
/// `(1 - remaining/dense) * weight` error prior.
fn build_problem(m: &ReproModel, env: &InferenceEnv, weights: &[f64]) -> SpdyProblem {
    let table = env.table();
    let mut modules = Vec::with_capacity(m.n_layers * 2);
    for layer in 0..m.n_layers {
        let wa = weights[layer * 2];
        modules.push(ModuleLevels {
            layer,
            is_attn: true,
            options: HEAD_LADDER
                .iter()
                .map(|&h| LevelOpt {
                    remaining: h,
                    cost: table.attn_time(h),
                    prior: (1.0 - h as f64 / m.n_heads as f64) * wa,
                })
                .collect(),
        });
        let wm = weights[layer * 2 + 1];
        modules.push(ModuleLevels {
            layer,
            is_attn: false,
            options: FFN_LADDER
                .iter()
                .map(|&w| LevelOpt {
                    remaining: w,
                    cost: table.mlp_time(w),
                    prior: (1.0 - w as f64 / m.d_ff as f64) * wm,
                })
                .collect(),
        });
    }
    SpdyProblem { modules, overhead: table.overhead }
}

/// Solver objective actually paid by a solution: Σ prior² over the
/// chosen levels (unit coefficients, like the kick-tires solve).
fn proxy_error(problem: &SpdyProblem, sol: &[usize]) -> f64 {
    let mut e = 0.0;
    for (module, &l) in problem.modules.iter().zip(sol) {
        let p = module.options[l].prior;
        e += p * p;
    }
    e
}

// ------------------------------------------------------- cell solving

struct EnvSolve {
    cells: Vec<ScenarioCell>,
    /// per target: the gradual stage's layer profile (None = failed)
    gradual: Vec<Option<Vec<(usize, usize)>>>,
}

fn success_cell(
    m: &ReproModel,
    regime: &str,
    env_name: &str,
    target: f64,
    status: CellStatus,
    problem: &SpdyProblem,
    sol: &[usize],
    dense: f64,
) -> ScenarioCell {
    ScenarioCell {
        model: m.name.to_string(),
        regime: regime.to_string(),
        env: env_name.to_string(),
        target,
        status,
        certified: q4(dense / problem.profile_cost(sol)),
        proxy_error: q4(proxy_error(problem, sol)),
        profile: problem.as_layer_profile(sol),
        error: String::new(),
    }
}

fn error_cell(
    m: &ReproModel,
    regime: &str,
    env_name: &str,
    target: f64,
    msg: &str,
) -> ScenarioCell {
    ScenarioCell {
        model: m.name.to_string(),
        regime: regime.to_string(),
        env: env_name.to_string(),
        target,
        status: CellStatus::Error,
        certified: 0.0,
        proxy_error: 0.0,
        profile: Vec::new(),
        error: msg.to_string(),
    }
}

/// Error cells for EVERY (regime, target) of one failed (model, env) —
/// an env that fails to construct still occupies all its cells.
fn error_cells(m: &ReproModel, env_name: &str, msg: &str) -> Vec<ScenarioCell> {
    let mut out = Vec::new();
    for regime in REGIMES {
        for t in TARGETS {
            out.push(error_cell(m, regime, env_name, t, msg));
        }
    }
    out
}

/// Solve every (regime, target) cell of one (model, env): one-shot
/// solves from dense each time; gradual re-solves from the previous
/// stage's levels (monotone — structures only ever shrink), matching
/// the paper's stage semantics. A failed stage records an error cell
/// and later stages continue from the last successful one.
fn solve_env(
    m: &ReproModel,
    env_name: &str,
    status: CellStatus,
    problem: &SpdyProblem,
) -> EnvSolve {
    let dense = problem.dense_cost();
    let mut cells = Vec::new();
    for &t in &TARGETS {
        match solve_dp(problem, &[], dense / t) {
            Some(sol) => {
                cells.push(success_cell(m, "oneshot", env_name, t, status, problem, &sol, dense));
            }
            None => cells.push(error_cell(
                m,
                "oneshot",
                env_name,
                t,
                "infeasible: target exceeds the env's achievable speedup",
            )),
        }
    }
    let mut gradual = Vec::new();
    let mut prev: Vec<usize> = vec![0; problem.modules.len()];
    for &t in &TARGETS {
        let restricted = SpdyProblem {
            modules: problem
                .modules
                .iter()
                .zip(&prev)
                .map(|(module, &p)| ModuleLevels {
                    layer: module.layer,
                    is_attn: module.is_attn,
                    options: module.options[p..].to_vec(),
                })
                .collect(),
            overhead: problem.overhead,
        };
        match solve_dp(&restricted, &[], dense / t) {
            Some(rel) => {
                let sol: Vec<usize> = rel.iter().zip(&prev).map(|(&l, &p)| p + l).collect();
                prev.clone_from(&sol);
                cells.push(success_cell(m, "gradual", env_name, t, status, problem, &sol, dense));
                gradual.push(Some(problem.as_layer_profile(&sol)));
            }
            None => {
                cells.push(error_cell(
                    m,
                    "gradual",
                    env_name,
                    t,
                    "infeasible: stage budget below the reachable cost from the previous stage",
                ));
                gradual.push(None);
            }
        }
    }
    EnvSolve { cells, gradual }
}

/// Enumerate and solve EVERY matrix cell of the engine-free subset —
/// total by construction (env failures degrade to error cells). This
/// is the pure core the totality/injectivity property tests drive.
pub fn scenario_cells(seed: u64, precomputed: &Path) -> Vec<ScenarioCell> {
    let mut cells = Vec::new();
    for (mi, m) in models().iter().enumerate() {
        let weights = sensitivity_weights(seed, mi, m.n_layers * 2);
        for env_name in ENVS {
            match kick_env(m, env_name, precomputed) {
                Err(e) => cells.extend(error_cells(m, env_name, &format!("{e}"))),
                Ok((env, status)) => {
                    let problem = build_problem(m, &env, &weights);
                    cells.extend(solve_env(m, env_name, status, &problem).cells);
                }
            }
        }
    }
    cells
}

// ----------------------------------------------------- family replay

struct BuiltMember {
    tag: String,
    est_speedup: f64,
    profile: Vec<(usize, usize)>,
}

/// Serving-side artifacts of one family build, reused by the adapt
/// loop: the routing table and the bucket ladder the replay ran under.
struct FamilyServing {
    routes: Vec<MemberRoute>,
    ladder: BucketLadder,
}

/// The three-class SLA mix every replayed trace draws from:
/// best-effort, realtime under 0.8× dense, throughput at the fastest
/// member (capped at 2×).
fn trace_classes(m: &ReproModel, env: &InferenceEnv, fastest: f64) -> Vec<TraceClass> {
    vec![
        TraceClass::best_effort(2.0),
        TraceClass {
            class: "realtime".to_string(),
            weight: 1.0,
            max_latency: Some(Duration::from_secs_f64(env.dense_time(m.n_layers) * 0.8)),
            min_speedup: None,
        },
        TraceClass {
            class: "throughput".to_string(),
            weight: 1.0,
            max_latency: None,
            min_speedup: Some(fastest.min(2.0)),
        },
    ]
}

/// Build one (model, env) family section: members from the gradual
/// stages, realized per-bucket stats from the deterministic replay
/// (`coordinator::replay`), and a real fault-injection campaign for
/// the chaos-ledger balance.
fn family_block(
    m: &ReproModel,
    block_idx: usize,
    env_name: &str,
    env: &InferenceEnv,
    gradual: &[Option<Vec<(usize, usize)>>],
    seed: u64,
) -> Result<(FamilyBlock, FamilyServing)> {
    let dense_profile = vec![(m.n_heads, m.d_ff); m.n_layers];
    let mut built = vec![BuiltMember {
        tag: "dense".to_string(),
        est_speedup: env.speedup(&dense_profile),
        profile: dense_profile,
    }];
    for (k, stage) in gradual.iter().enumerate() {
        if let Some(profile) = stage {
            built.push(BuiltMember {
                tag: format!("{}x", fmt_num(TARGETS[k])),
                est_speedup: env.speedup(profile),
                profile: profile.clone(),
            });
        }
    }
    built.sort_by(|a, b| a.est_speedup.total_cmp(&b.est_speedup));

    let ladder = BucketLadder::new(env.bucket_ladder());
    let bucket_list = ladder.buckets().to_vec();
    let routes: Vec<MemberRoute> = built
        .iter()
        .map(|mb| MemberRoute {
            tag: mb.tag.clone(),
            est_speedup: mb.est_speedup,
            est_batch_time: env.model_time(&mb.profile),
            bucket_times: bucket_list
                .iter()
                .map(|&(b, s)| ((b, s), env.batch_time(&mb.profile, b, s)))
                .collect(),
        })
        .collect();

    let block_seed = sub_seed(seed, 0x100 + block_idx as u64);
    let fastest = built.iter().fold(1.0f64, |a, mb| a.max(mb.est_speedup));
    let tcfg = TraceCfg {
        requests: 48,
        seed: block_seed,
        arrival_gap: Duration::ZERO,
        len_range: (4, 32),
        classes: trace_classes(m, env, fastest),
    };
    let trace = gen_trace(&tcfg);
    let stats = replay(
        &trace,
        &routes,
        &ladder,
        &ReplayCfg {
            max_batch: 4,
            jitter: 0.1,
            seed: block_seed,
            fallback_shape: env.batch_shape(),
        },
    );
    let per_bucket = stats
        .iter()
        .map(|s| {
            let cert = s.certified.as_secs_f64();
            let p50 = s.realized_p50.as_secs_f64();
            let p99 = s.realized_p99.as_secs_f64();
            BucketRow {
                member: s.member.clone(),
                batch: s.batch,
                seq: s.seq,
                specialized: s.specialized,
                batches: s.batches,
                requests: s.requests,
                certified_ms: q4(cert * 1e3),
                realized_p50_ms: q4(p50 * 1e3),
                realized_p99_ms: q4(p99 * 1e3),
                gap: if cert > 0.0 { q4(p50 / cert) } else { 0.0 },
            }
        })
        .collect();

    let fleet_members: Vec<FleetMember> = built
        .iter()
        .map(|mb| FleetMember { tag: mb.tag.clone(), profile: mb.profile.clone() })
        .collect();
    let fcfg = FleetCfg {
        workers: 2,
        skews: vec![1.0, 1.15],
        max_batch: 4,
        max_wait: Duration::from_micros(200),
        queue_cap: 64,
        retry: RetryPolicy { max_retries: 3, base: Duration::from_micros(150), factor: 2.0 },
        quarantine_after: 50,
        restart_delay: Duration::from_micros(400),
        buckets: ladder.clone(),
        time_scale: 0.0,
    };
    let rates = FaultRates {
        crash: 0.05,
        compile_fail: 0.1,
        slowdown: 0.1,
        slowdown_factor: 3.0,
        nan_latency: 0.0,
    };
    let chaos_rep = run_chaos(
        fcfg,
        fleet_members,
        env,
        FaultPlan::seeded(block_seed ^ 0xFA, rates),
        &tcfg,
    )?;

    Ok((
        FamilyBlock {
            model: m.name.to_string(),
            env: env_name.to_string(),
            members: built
                .iter()
                .map(|mb| MemberSummary {
                    tag: mb.tag.clone(),
                    est_speedup: q4(mb.est_speedup),
                    est_batch_time_ms: q4(env.model_time(&mb.profile) * 1e3),
                })
                .collect(),
            buckets: bucket_list,
            per_bucket,
            chaos: ChaosSummary {
                submitted: chaos_rep.submitted,
                lost: chaos_rep.lost,
                balanced: chaos_rep.balanced(),
            },
        },
        FamilyServing { routes, ladder },
    ))
}

// -------------------------------------------------------- adapt loop

/// Frontier input for one kick-tires family: the serving routes paired
/// with the gradual cells' proxy errors as calibration losses (the
/// dense member anchors at zero, like `session::pipeline` records).
fn kick_manifest(
    m: &ReproModel,
    env: &InferenceEnv,
    routes: &[MemberRoute],
    cells: &[ScenarioCell],
) -> FamilyManifest {
    let members = routes
        .iter()
        .map(|r| FamilyMember {
            tag: r.tag.clone(),
            ckpt: String::new(),
            target: 1.0,
            est_speedup: r.est_speedup,
            profile: Vec::new(),
            choices: None,
            calib_loss: if r.tag == "dense" {
                Some(0.0)
            } else {
                cells
                    .iter()
                    .find(|c| {
                        c.regime == "gradual"
                            && c.status != CellStatus::Error
                            && format!("{}x", fmt_num(c.target)) == r.tag
                    })
                    .map(|c| c.proxy_error)
            },
        })
        .collect();
    FamilyManifest {
        model: m.name.to_string(),
        task: m.task.to_string(),
        regime: env.table().regime.clone(),
        env: Some(env.clone()),
        buckets: Vec::new(),
        fleet: None,
        members,
    }
}

/// Build one adapt-loop section: replay a seeded DRIFTED trace — all
/// sequences at or under a quarter of the certified anchor — against
/// the family's routes, then run the pure `adapt` pipeline on the
/// realized samples. The knee and target proposals come from the
/// family's own loss-vs-certified-speedup frontier.
fn adapt_block(
    m: &ReproModel,
    block_idx: usize,
    env_name: &str,
    env: &InferenceEnv,
    serving: &FamilyServing,
    manifest: &FamilyManifest,
    seed: u64,
) -> Result<AdaptBlock> {
    let drift_seed = sub_seed(seed, 0x300 + block_idx as u64);
    let fastest = serving.routes.iter().fold(1.0f64, |a, r| a.max(r.est_speedup));
    let tcfg = TraceCfg {
        requests: 48,
        seed: drift_seed,
        arrival_gap: Duration::ZERO,
        len_range: (4, (m.seq / 4).max(5)),
        classes: trace_classes(m, env, fastest),
    };
    let trace = gen_trace(&tcfg);
    let samples = replay_samples(
        &trace,
        &serving.routes,
        &serving.ladder,
        &ReplayCfg {
            max_batch: 4,
            jitter: 0.1,
            seed: drift_seed,
            fallback_shape: env.batch_shape(),
        },
    );
    let drift = detect_drift(&samples, env, &DriftCfg::default());
    let fitted = fit_env(&samples, env)?;
    let (fitted_batch, fitted_seq) = fitted.batch_shape();
    let base_dense = env.dense_time(m.n_layers);
    let skew = if base_dense > 0.0 { fitted.dense_time(m.n_layers) / base_dense } else { 0.0 };
    let frontier = frontier_points(std::slice::from_ref(manifest));
    let knee = knee_point(&frontier).unwrap_or(0.0);
    let mut targets: Vec<f64> =
        propose_targets(&frontier, TARGETS.len()).into_iter().map(q4).collect();
    targets.dedup();
    Ok(AdaptBlock {
        model: m.name.to_string(),
        env: env_name.to_string(),
        requests: drift.requests,
        latency_drift: q4(drift.latency_drift),
        mass_shift: q4(drift.mass_shift),
        overrun_rate: q4(drift.overrun_rate),
        drifted: drift.drifted,
        fitted_batch,
        fitted_seq,
        fitted_skew: q4(skew),
        fitted_sweep: fitted.seq_sweep().iter().map(|&(s, sc)| (s, q4(sc))).collect(),
        knee: q4(knee),
        targets,
    })
}

// -------------------------------------------------- compound lattice

/// Low-rank FFN ranks the kick-tires lattice offers. With d_model 128
/// and d_ff 512 the equal-GEMM-work widths are exactly 5·rank (480,
/// 320, 160) — integer arithmetic, no transcendentals.
const LOWRANK_RANKS: [usize; 3] = [96, 64, 32];

/// Per-axis module mix of a typed profile, `axis=n` space-joined.
fn mix_string(p: &CompressionProfile) -> String {
    p.axis_counts()
        .into_iter()
        .map(|(a, n)| format!("{a}={n}"))
        .collect::<Vec<_>>()
        .join(" ")
}

/// Widen one kick-tires SPDY instance into the typed choice lattice
/// (DESIGN.md §13): the pruning levels verbatim (so the prune-only
/// restriction lowers bit-identically), plus int8 entries at the
/// exact-binary `cost/2.5` engine factor and low-rank FFN entries at
/// equal-GEMM-work widths. Synthetic losses mirror the sensitivity
/// priors with only exact-binary scalings (`w/64` per quant step,
/// `(1 − rank/d_model)·w` per low-rank step) — no libm anywhere.
fn compound_choices(
    m: &ReproModel,
    env: &InferenceEnv,
    base: &SpdyProblem,
    weights: &[f64],
) -> ChoiceProblem {
    let table = env.table();
    let mut problem = ChoiceProblem::from_spdy(base);
    for (module, set) in base.modules.iter().zip(&mut problem.modules) {
        let w = weights[module.layer * 2 + usize::from(!module.is_attn)];
        let mut extra = Vec::new();
        for (li, opt) in module.options.iter().enumerate() {
            if opt.remaining == 0 {
                continue; // a dropped module has nothing to quantize
            }
            let cost = if module.is_attn {
                table.attn_time(opt.remaining) / 2.5
            } else {
                table.mlp_time(opt.remaining) / 2.5
            };
            let choice = if li == 0 {
                LayerChoice::Quant { scheme: QuantScheme::Int8 }
            } else {
                LayerChoice::PruneQuant { remaining: opt.remaining, scheme: QuantScheme::Int8 }
            };
            extra.push(Choice { choice, cost, loss: opt.prior + w / 64.0 });
        }
        if !module.is_attn {
            for rank in LOWRANK_RANKS {
                let w_eff = low_rank_ffn_width(m.d_model, m.d_ff, rank);
                if w_eff >= m.d_ff {
                    continue; // prices no cheaper than dense
                }
                extra.push(Choice {
                    choice: LayerChoice::LowRank { rank },
                    cost: table.mlp_time(w_eff),
                    loss: (1.0 - rank as f64 / m.d_model as f64) * w,
                });
            }
        }
        set.choices.extend(extra);
    }
    problem
}

/// Build one model's compound section: the widened lattice on the
/// `gpu-sweep` env, solved at one target as dense / per-axis
/// restrictions / the full mixed lattice, with the prune-only
/// restriction checked against the legacy DP's exact indices. Pure in
/// `(seed, model)` — the analytic env never touches `precomputed`.
fn compound_block(
    m: &ReproModel,
    model_idx: usize,
    seed: u64,
    precomputed: &Path,
) -> Result<CompoundBlock> {
    let env_name = "gpu-sweep";
    let (env, _) = kick_env(m, env_name, precomputed)?;
    let weights = sensitivity_weights(seed, model_idx, m.n_layers * 2);
    let base = build_problem(m, &env, &weights);
    let problem = compound_choices(m, &env, &base, &weights);
    // 2.5x sits past the all-int8 point (compute/2.5 still pays the
    // dense overhead), so the solver is forced to genuinely mix axes
    let target = 2.5;
    let dense = base.dense_cost();
    let budget = dense / target;

    // the tentpole invariant, checked live on every run: restricting
    // the lattice to the prune axis reproduces the legacy DP exactly
    let legacy_sol = solve_dp(&base, &[], budget)
        .ok_or_else(|| anyhow!("legacy DP infeasible at {target}x"))?;
    let lifted_sol = ChoiceProblem::from_spdy(&base)
        .solve_dp(&[], budget)
        .ok_or_else(|| anyhow!("lifted prune-only DP infeasible at {target}x"))?;
    let prune_equiv = legacy_sol == lifted_sol;

    // single-axis restrictions, then the full-lattice mixed solve
    let dense_prof = vec![0usize; problem.modules.len()];
    let quant_prof: Vec<usize> =
        problem.modules.iter().map(|s| s.find_axis("quant").unwrap_or(0)).collect();
    let lowrank_prof: Vec<usize> = problem
        .modules
        .iter()
        .map(|s| {
            let lr: Vec<usize> = (0..s.choices.len())
                .filter(|&i| s.choices[i].choice.axis() == "lowrank")
                .collect();
            lr.get(lr.len() / 2).copied().unwrap_or(0)
        })
        .collect();
    let mixed_sol = problem
        .solve_dp(&[], budget)
        .ok_or_else(|| anyhow!("widened DP infeasible at {target}x"))?;

    let member = |tag: &str, prof: &[usize]| CompoundMember {
        tag: tag.to_string(),
        axis: mix_string(&problem.profile_choices(prof)),
        certified: q4(dense / problem.profile_cost(prof)),
        loss: q4(problem.loss_sq(prof)),
    };
    let members = vec![
        member("dense", &dense_prof),
        member("prune", &lifted_sol),
        member("int8", &quant_prof),
        member("lowrank", &lowrank_prof),
        member("compound", &mixed_sol),
    ];
    let axes = problem.profile_choices(&mixed_sol).axis_counts();
    Ok(CompoundBlock {
        model: m.name.to_string(),
        env: env_name.to_string(),
        target,
        prune_equiv,
        members,
        axes,
    })
}

/// One compound section per model — the engine-free sections both
/// entrypoints append.
fn compound_blocks(seed: u64, precomputed: &Path) -> Result<Vec<CompoundBlock>> {
    models()
        .iter()
        .enumerate()
        .map(|(mi, m)| compound_block(m, mi, seed, precomputed))
        .collect()
}

// --------------------------------------------------------- entrypoints

/// The engine-free kick-tires run: every matrix cell plus a family
/// section (replayed realized stats + chaos ledger) per (model, env).
/// Pure in `(seed, precomputed)` — two runs are bit-identical.
pub fn run_kick_tires(seed: u64, precomputed: &Path) -> Result<ReproReport> {
    let mut cells = Vec::new();
    let mut families = Vec::new();
    let mut adapt = Vec::new();
    for (mi, m) in models().iter().enumerate() {
        let weights = sensitivity_weights(seed, mi, m.n_layers * 2);
        for (ei, env_name) in ENVS.iter().enumerate() {
            match kick_env(m, env_name, precomputed) {
                Err(e) => cells.extend(error_cells(m, env_name, &format!("{e}"))),
                Ok((env, status)) => {
                    let problem = build_problem(m, &env, &weights);
                    let solved = solve_env(m, env_name, status, &problem);
                    let fi = mi * ENVS.len() + ei;
                    let (fam, serving) =
                        family_block(m, fi, env_name, &env, &solved.gradual, seed)?;
                    if *env_name == "gpu-sweep" {
                        let manifest = kick_manifest(m, &env, &serving.routes, &solved.cells);
                        adapt.push(adapt_block(m, fi, env_name, &env, &serving, &manifest, seed)?);
                    }
                    cells.extend(solved.cells);
                    families.push(fam);
                }
            }
        }
    }
    let compound = compound_blocks(seed, precomputed)?;
    Ok(ReproReport { mode: "kick-tires".to_string(), seed, cells, families, adapt, compound })
}

/// The full engine-backed run: the same matrix driven through the real
/// `CompressionSession` API — one-shot cells via [`CompressionSession::oneshot`]
/// per target, gradual cells via a staged `run`, and family sections
/// emitted through `emit_families` then replayed exactly like the
/// kick-tires subset. Envs degrade per the ruler idiom: a measured CPU
/// table that cannot be captured live falls back to the precomputed
/// artifact (`cached`), and any cell whose stage fails records an
/// error cell instead of vanishing.
pub fn run_full(ctx: &ExpCtx, seed: u64, precomputed: &Path) -> Result<ReproReport> {
    let mut cells = Vec::new();
    let mut families = Vec::new();
    let mut adapt = Vec::new();
    for (mi, m) in models().iter().enumerate() {
        let data = ctx.dataset(m.name, m.task);
        let teacher = ctx.teacher(m.name, m.task, &data)?;
        let mut live_envs: Vec<(usize, String, InferenceEnv)> = Vec::new();
        for (ei, env_name) in ENVS.iter().enumerate() {
            let built = match env_name {
                "cpu-measured" => match ctx.env(m.name, Regime::Throughput) {
                    Ok(env) => Ok((env, CellStatus::Ran)),
                    Err(_) => kick_env(m, env_name, precomputed),
                },
                _ => kick_env(m, env_name, precomputed),
            };
            match built {
                Err(e) => cells.extend(error_cells(m, env_name, &format!("{e}"))),
                Ok((env, status)) => {
                    cells.extend(full_env_cells(ctx, m, env_name, &env, status, &teacher, &data));
                    live_envs.push((mi * ENVS.len() + ei, env_name.to_string(), env));
                }
            }
        }
        if live_envs.is_empty() {
            continue;
        }
        // one capture, N envs: emit the families through the session
        // API, then replay each family's members deterministically
        let sess = ctx.gradual_session(
            m.name,
            m.task,
            &live_envs[0].2,
            &TARGETS,
            ctx.prune_cfg(),
            ctx.ft_cfg(!m.causal),
            None,
        )?;
        let base = ctx.runs.join(format!("repro_{}_{}", m.name, m.task));
        let envs: Vec<InferenceEnv> = live_envs.iter().map(|(_, _, e)| e.clone()).collect();
        let manifests = sess.emit_families(&teacher, &data, &envs, &base)?;
        for ((block_idx, env_name, env), fam) in live_envs.iter().zip(&manifests) {
            let stages: Vec<Option<Vec<(usize, usize)>>> = TARGETS
                .iter()
                .map(|&t| {
                    fam.members
                        .iter()
                        .find(|mb| mb.tag != "dense" && mb.target == t)
                        .map(|mb| mb.profile.clone())
                })
                .collect();
            let (block, serving) = family_block(m, *block_idx, env_name, env, &stages, seed)?;
            if env_name.as_str() == "gpu-sweep" {
                // the real manifest carries recorded calibration losses,
                // so the frontier here is the genuine article
                adapt.push(adapt_block(m, *block_idx, env_name, env, &serving, fam, seed)?);
            }
            families.push(block);
        }
    }
    // the compound lattice sections are engine-free by design; the
    // engine-backed compound family lives in `ziplm compound`
    let compound = compound_blocks(seed, precomputed)?;
    Ok(ReproReport { mode: "full".to_string(), seed, cells, families, adapt, compound })
}

/// Solve the full-mode cells of one (model, env) through the session
/// API; per-target failures degrade to error cells.
fn full_env_cells(
    ctx: &ExpCtx,
    m: &ReproModel,
    env_name: &str,
    env: &InferenceEnv,
    status: CellStatus,
    teacher: &crate::models::ModelState,
    data: &crate::data::Dataset,
) -> Vec<ScenarioCell> {
    let mut cells = Vec::new();
    match ctx.oneshot_session(m.name, m.task, env, ctx.prune_cfg()) {
        Ok(sess) => {
            for &t in &TARGETS {
                let mut state = teacher.clone();
                match sess.oneshot(&mut state, data, t) {
                    Ok(rep) => cells.push(ScenarioCell {
                        model: m.name.to_string(),
                        regime: "oneshot".to_string(),
                        env: env_name.to_string(),
                        target: t,
                        status,
                        certified: q4(rep.est_speedup),
                        proxy_error: q4(rep.calib_loss),
                        profile: rep.layer_profile,
                        error: String::new(),
                    }),
                    Err(e) => cells.push(error_cell(m, "oneshot", env_name, t, &format!("{e}"))),
                }
            }
        }
        Err(e) => {
            let msg = format!("{e}");
            for t in TARGETS {
                cells.push(error_cell(m, "oneshot", env_name, t, &msg));
            }
        }
    }
    let staged = ctx
        .gradual_session(
            m.name,
            m.task,
            env,
            &TARGETS,
            ctx.prune_cfg(),
            ctx.ft_cfg(!m.causal),
            None,
        )
        .and_then(|sess| sess.run(teacher.clone(), data));
    match staged {
        Ok(stages) => {
            for (k, &t) in TARGETS.iter().enumerate() {
                match stages.get(k) {
                    Some(st) => cells.push(ScenarioCell {
                        model: m.name.to_string(),
                        regime: "gradual".to_string(),
                        env: env_name.to_string(),
                        target: t,
                        status,
                        certified: q4(st.report.est_speedup),
                        proxy_error: q4(st.report.calib_loss),
                        profile: st.report.layer_profile.clone(),
                        error: String::new(),
                    }),
                    None => cells.push(error_cell(m, "gradual", env_name, t, "stage missing")),
                }
            }
        }
        Err(e) => {
            let msg = format!("{e}");
            for t in TARGETS {
                cells.push(error_cell(m, "gradual", env_name, t, &msg));
            }
        }
    }
    cells
}

// ----------------------------------------------------------- rendering

fn push_row(out: &mut String, cols: &[String]) {
    out.push('|');
    for c in cols {
        out.push(' ');
        out.push_str(c);
        out.push_str(" |");
    }
    out.push('\n');
}

fn yesno(b: bool) -> &'static str {
    if b {
        "yes"
    } else {
        "no"
    }
}

/// Render the paper-style tables. Every number is the same
/// `fmt_num(q4(...))` string the JSON carries, so the markdown goldens
/// and the JSON goldens can never disagree on a value.
pub fn render_markdown(report: &ReproReport) -> String {
    let mut out = String::new();
    out.push_str("# ZipLM reproduction report\n\n");
    out.push_str(&format!(
        "Mode: `{}` · seed {} · {} cells · {} families.\n\n",
        report.mode,
        report.seed,
        report.cells.len(),
        report.families.len()
    ));
    out.push_str(
        "Generated by `ziplm repro`; regenerate with `tools/repro/kick_tires.sh` \
         (see DESIGN.md §11 for the matrix axes, report schema, and golden-refresh \
         workflow). Statuses: `ran` = computed live, `cached` = precomputed \
         ruler-style artifact, `error` = recorded failure — a matrix cell is never \
         silently dropped.\n\n",
    );

    out.push_str("## Accuracy-vs-speedup (certified)\n\n");
    out.push_str(
        "Each cell: certified speedup achieved at the target, and the proxy error \
         the SPDY solver paid for it (sum of squared priors; lower = closer to \
         dense).\n",
    );
    for m in models() {
        for regime in REGIMES {
            out.push_str(&format!("\n### {} · {regime}\n\n", m.name));
            let mut header = vec!["target".to_string()];
            header.extend(ENVS.iter().map(|e| e.to_string()));
            push_row(&mut out, &header);
            push_row(&mut out, &vec!["---".to_string(); header.len()]);
            for t in TARGETS {
                let mut row = vec![format!("{}x", fmt_num(t))];
                for env in ENVS {
                    let cell = report.cells.iter().find(|c| {
                        c.model == m.name && c.regime == regime && c.env == env && c.target == t
                    });
                    row.push(match cell {
                        Some(c) if c.status != CellStatus::Error => format!(
                            "{}x / e={} ({})",
                            fmt_num(c.certified),
                            fmt_num(c.proxy_error),
                            c.status.name()
                        ),
                        Some(_) => "error".to_string(),
                        None => "MISSING".to_string(),
                    });
                }
                push_row(&mut out, &row);
            }
        }
    }

    out.push_str("\n## Certified vs realized (per bucket)\n\n");
    out.push_str(
        "Realized p50/p99 come from a deterministic replay of a seeded trace \
         through the live routing layer (DESIGN.md §11); `gap` is realized p50 \
         over the certified estimate.\n",
    );
    for fam in &report.families {
        out.push_str(&format!("\n### {} · {}\n\n", fam.model, fam.env));
        let members = fam
            .members
            .iter()
            .map(|mb| format!("{} {}x", mb.tag, fmt_num(mb.est_speedup)))
            .collect::<Vec<_>>()
            .join(", ");
        out.push_str(&format!("Members (certified): {members}.\n\n"));
        push_row(
            &mut out,
            &[
                "member", "batch", "seq", "spec", "batches", "requests", "certified ms",
                "p50 ms", "p99 ms", "gap",
            ]
            .iter()
            .map(|s| s.to_string())
            .collect::<Vec<_>>(),
        );
        push_row(&mut out, &vec!["---".to_string(); 10]);
        for r in &fam.per_bucket {
            push_row(
                &mut out,
                &[
                    r.member.clone(),
                    r.batch.to_string(),
                    r.seq.to_string(),
                    yesno(r.specialized).to_string(),
                    r.batches.to_string(),
                    r.requests.to_string(),
                    fmt_num(r.certified_ms),
                    fmt_num(r.realized_p50_ms),
                    fmt_num(r.realized_p99_ms),
                    fmt_num(r.gap),
                ],
            );
        }
    }

    out.push_str("\n## Chaos ledger\n\n");
    out.push_str(
        "Each family served one seeded fault-injection campaign (crashes, compile \
         failures, slowdowns); `balanced` asserts the Replied/Shed/Abandoned \
         ledger accounts for every submitted request (DESIGN.md §10).\n\n",
    );
    push_row(
        &mut out,
        &["family", "submitted", "lost", "balanced"]
            .iter()
            .map(|s| s.to_string())
            .collect::<Vec<_>>(),
    );
    push_row(&mut out, &vec!["---".to_string(); 4]);
    for fam in &report.families {
        push_row(
            &mut out,
            &[
                format!("{} · {}", fam.model, fam.env),
                fam.chaos.submitted.to_string(),
                fam.chaos.lost.to_string(),
                yesno(fam.chaos.balanced).to_string(),
            ],
        );
    }

    if !report.adapt.is_empty() {
        out.push_str("\n## Adapt loop\n\n");
        out.push_str(
            "Each `gpu-sweep` family replays a seeded DRIFTED trace (sequences at \
             or under a quarter of the certified anchor), then runs the pure \
             drift → fit → frontier pipeline (DESIGN.md §12). Engine-free: the \
             verdict, the fitted anchor and the recommended targets are \
             bit-stable under the pinned seed.\n\n",
        );
        push_row(
            &mut out,
            &[
                "family", "requests", "latency drift", "mass shift", "overrun", "drifted",
                "fitted anchor", "skew", "knee", "targets",
            ]
            .iter()
            .map(|s| s.to_string())
            .collect::<Vec<_>>(),
        );
        push_row(&mut out, &vec!["---".to_string(); 10]);
        for a in &report.adapt {
            push_row(
                &mut out,
                &[
                    format!("{} · {}", a.model, a.env),
                    a.requests.to_string(),
                    fmt_num(a.latency_drift),
                    fmt_num(a.mass_shift),
                    fmt_num(a.overrun_rate),
                    yesno(a.drifted).to_string(),
                    format!("{}x{}", a.fitted_batch, a.fitted_seq),
                    fmt_num(a.fitted_skew),
                    fmt_num(a.knee),
                    a.targets
                        .iter()
                        .map(|&t| format!("{}x", fmt_num(t)))
                        .collect::<Vec<_>>()
                        .join(" "),
                ],
            );
        }
    }

    if !report.compound.is_empty() {
        out.push_str("\n## Compound compression\n\n");
        out.push_str(
            "One inference-aware DP over pruning × int8 × low-rank (DESIGN.md §13): \
             per model, each single-axis restriction and the full-lattice `compound` \
             solve at one target, all priced by the `gpu-sweep` cost model. \
             Engine-free and bit-stable like the matrix cells; `mix` counts modules \
             per axis.\n",
        );
        for b in &report.compound {
            out.push_str(&format!(
                "\n### {} · {} · target {}x\n\n",
                b.model,
                b.env,
                fmt_num(b.target)
            ));
            push_row(
                &mut out,
                &["member", "mix", "certified", "loss"]
                    .iter()
                    .map(|s| s.to_string())
                    .collect::<Vec<_>>(),
            );
            push_row(&mut out, &vec!["---".to_string(); 4]);
            for mb in &b.members {
                push_row(
                    &mut out,
                    &[
                        mb.tag.clone(),
                        mb.axis.clone(),
                        format!("{}x", fmt_num(mb.certified)),
                        fmt_num(mb.loss),
                    ],
                );
            }
            out.push_str(&format!(
                "\nCompound mix: {} · prune-only DP ≡ legacy DP: {}\n",
                b.axes
                    .iter()
                    .map(|(a, n)| format!("{a}={n}"))
                    .collect::<Vec<_>>()
                    .join(", "),
                yesno(b.prune_equiv)
            ));
        }
    }
    out
}

/// Write `repro_report.json` + `REPORT.md` under `out`; returns both
/// paths.
pub fn write_report(report: &ReproReport, out: &Path) -> Result<(PathBuf, PathBuf)> {
    std::fs::create_dir_all(out)?;
    let json_path = out.join("repro_report.json");
    let md_path = out.join("REPORT.md");
    std::fs::write(&json_path, report.to_json().to_pretty() + "\n")?;
    std::fs::write(&md_path, render_markdown(report))?;
    Ok((json_path, md_path))
}

#[cfg(test)]
#[allow(clippy::disallowed_methods)]
mod tests {
    use super::*;

    #[test]
    fn fmt_num_matches_json_writer() {
        assert_eq!(fmt_num(2.0), "2");
        assert_eq!(fmt_num(1.5), "1.5");
        assert_eq!(fmt_num(0.0213), "0.0213");
        assert_eq!(fmt_num(-3.0), "-3");
        for x in [2.0, 1.5, 0.0213, 123.4567] {
            assert_eq!(fmt_num(x), Json::Num(x).to_string());
        }
    }

    #[test]
    fn q4_rounds_half_away_from_zero() {
        assert_eq!(q4(0.00005), 0.0001);
        assert_eq!(q4(-0.00005), -0.0001);
        assert_eq!(q4(1.23456), 1.2346);
        assert_eq!(q4(2.0), 2.0);
    }

    #[test]
    fn missing_precomputed_degrades_to_error_cells_never_drops() {
        let cells = scenario_cells(DEFAULT_SEED, Path::new("/nonexistent/repro"));
        let keys = matrix_keys();
        assert_eq!(cells.len(), keys.len(), "matrix must be total");
        for (c, k) in cells.iter().zip(&keys) {
            assert_eq!(
                (c.model.clone(), c.regime.clone(), c.env.clone(), c.target),
                k.clone(),
                "enumeration order is pinned"
            );
        }
        for c in &cells {
            if c.env == "cpu-measured" {
                assert_eq!(c.status, CellStatus::Error);
                assert!(c.error.contains("precomputed latency table"));
            } else {
                assert_ne!(c.status, CellStatus::Error, "{}/{}: {}", c.env, c.regime, c.error);
                assert!(c.certified >= 1.0, "certified {} ≥ 1", c.certified);
            }
        }
    }

    #[test]
    fn gradual_stages_are_monotone() {
        let m = models()[0];
        let weights = sensitivity_weights(DEFAULT_SEED, 0, m.n_layers * 2);
        let (env, _) = kick_env(&m, "gpu-sweep", Path::new("/nonexistent")).unwrap();
        let problem = build_problem(&m, &env, &weights);
        let solved = solve_env(&m, "gpu-sweep", CellStatus::Ran, &problem);
        let stages: Vec<_> = solved.gradual.iter().flatten().collect();
        assert!(stages.len() >= 2, "want ≥ 2 successful stages");
        for w in stages.windows(2) {
            for (a, b) in w[0].iter().zip(w[1].iter()) {
                assert!(b.0 <= a.0 && b.1 <= a.1, "structures only shrink: {a:?} → {b:?}");
            }
        }
    }

    #[test]
    fn certified_meets_target_on_success() {
        let m = models()[1];
        let weights = sensitivity_weights(DEFAULT_SEED, 1, m.n_layers * 2);
        let (env, _) = kick_env(&m, "edge", Path::new("/nonexistent")).unwrap();
        let problem = build_problem(&m, &env, &weights);
        for c in solve_env(&m, "edge", CellStatus::Ran, &problem).cells {
            if c.status != CellStatus::Error {
                assert!(
                    c.certified + 1e-9 >= c.target,
                    "certified {} must meet target {}",
                    c.certified,
                    c.target
                );
            }
        }
    }

    #[test]
    fn report_json_roundtrip() {
        let cells = scenario_cells(11, Path::new("/nonexistent/repro"));
        let report = ReproReport {
            mode: "kick-tires".into(),
            seed: 11,
            cells,
            families: vec![],
            adapt: vec![],
            compound: compound_blocks(11, Path::new("/nonexistent/repro")).unwrap(),
        };
        let j = report.to_json();
        let back = ReproReport::from_json(&Json::parse(&j.to_string()).unwrap()).unwrap();
        assert_eq!(back.to_json().to_string(), j.to_string());
    }

    #[test]
    fn adapt_block_flags_drift_and_is_deterministic() {
        let m = models()[0];
        let weights = sensitivity_weights(DEFAULT_SEED, 0, m.n_layers * 2);
        let (env, _) = kick_env(&m, "gpu-sweep", Path::new("/nonexistent")).unwrap();
        let problem = build_problem(&m, &env, &weights);
        let solved = solve_env(&m, "gpu-sweep", CellStatus::Ran, &problem);
        let build = || {
            let (_, serving) =
                family_block(&m, 1, "gpu-sweep", &env, &solved.gradual, DEFAULT_SEED).unwrap();
            let manifest = kick_manifest(&m, &env, &serving.routes, &solved.cells);
            adapt_block(&m, 1, "gpu-sweep", &env, &serving, &manifest, DEFAULT_SEED).unwrap()
        };
        let a = build();
        assert_eq!(a.requests, 48);
        assert!(a.mass_shift > 0.25, "short-seq traffic must shift mass: {a:?}");
        assert!(a.drifted, "detector must flag the drifted trace");
        assert!(a.fitted_seq < m.seq, "fitted anchor follows the observed traffic");
        assert!(a.knee > 0.0 && !a.targets.is_empty(), "frontier must recommend");
        assert_eq!(a, build(), "bit-deterministic under the pinned seed");
        let j = a.to_json();
        let back = AdaptBlock::from_json(&Json::parse(&j.to_string()).unwrap()).unwrap();
        assert_eq!(back, a, "adapt block JSON round-trips");
    }

    #[test]
    fn markdown_covers_every_cell_and_family() {
        let cells = scenario_cells(DEFAULT_SEED, Path::new("/nonexistent/repro"));
        let report = ReproReport {
            mode: "kick-tires".into(),
            seed: 7,
            cells,
            families: vec![],
            adapt: vec![],
            compound: compound_blocks(DEFAULT_SEED, Path::new("/nonexistent/repro")).unwrap(),
        };
        let md = render_markdown(&report);
        assert!(!md.contains("MISSING"), "every cell must render");
        for m in models() {
            for regime in REGIMES {
                assert!(md.contains(&format!("### {} · {regime}", m.name)));
            }
        }
        assert!(md.contains("## Chaos ledger"));
        assert!(md.contains("## Compound compression"));
        for m in models() {
            assert!(md.contains(&format!("### {} · gpu-sweep · target 2.5x", m.name)));
        }
    }

    #[test]
    fn compound_blocks_mix_axes_and_match_legacy_dp() {
        // the compound sections never touch `precomputed` (analytic
        // gpu-sweep env only), so the error-path report carries them too
        let blocks = compound_blocks(DEFAULT_SEED, Path::new("/nonexistent/repro")).unwrap();
        assert_eq!(blocks.len(), models().len());
        for b in &blocks {
            assert_eq!(b.env, "gpu-sweep");
            assert!(b.prune_equiv, "{}: prune-only lattice must equal the legacy DP", b.model);
            let tags: Vec<&str> = b.members.iter().map(|m| m.tag.as_str()).collect();
            assert_eq!(tags, ["dense", "prune", "int8", "lowrank", "compound"]);
            let by_tag = |t: &str| {
                b.members
                    .iter()
                    .find(|m| m.tag == t)
                    .unwrap_or_else(|| panic!("missing member {t}"))
            };
            assert_eq!(by_tag("dense").certified, 1.0);
            assert_eq!(by_tag("dense").loss, 0.0);
            // single-axis members actually live on their axis
            assert!(by_tag("int8").axis.contains("quant="), "{:?}", by_tag("int8"));
            assert!(by_tag("lowrank").axis.contains("lowrank="), "{:?}", by_tag("lowrank"));
            // prune and compound both certify the target…
            for t in ["prune", "compound"] {
                assert!(
                    by_tag(t).certified + 1e-9 >= b.target,
                    "{}: {t} certified {} < target {}",
                    b.model,
                    by_tag(t).certified,
                    b.target
                );
            }
            // …and the wider lattice never pays MORE loss than pruning
            assert!(
                by_tag("compound").loss <= by_tag("prune").loss + 1e-12,
                "{}: compound {} > prune {}",
                b.model,
                by_tag("compound").loss,
                by_tag("prune").loss
            );
            // the mixed solve uses ≥ 2 axes (it is genuinely compound)
            assert!(b.axes.len() >= 2, "{}: mixed solve stayed single-axis: {:?}", b.model, b.axes);
        }
        // bit-deterministic, and JSON round-trips value-exactly
        assert_eq!(blocks, compound_blocks(DEFAULT_SEED, Path::new("/nonexistent/repro")).unwrap());
        for b in &blocks {
            let j = b.to_json();
            let back = CompoundBlock::from_json(&Json::parse(&j.to_string()).unwrap()).unwrap();
            assert_eq!(&back, b);
        }
    }
}
