//! Latency tables — the inference-awareness substrate (paper §3.2, App. E).
//!
//! A table records the runtime of one transformer layer's attention
//! block with 0..N_heads heads remaining and of its FFN block at every
//! measured intermediate width, for a given (device, batch regime).
//! ZipLM consumes tables, never devices, so swapping a measured CPU
//! table for an analytic V100/A100 model (unavailable hardware,
//! DESIGN.md §3) changes nothing downstream.
//!
//! * [`measure_cpu`] — the real path: times the AOT block artifacts
//!   (python/compile/blocks.py) through the same PJRT runtime the
//!   deployed model uses, exactly the paper's methodology.
//! * [`analytic`] — roofline-style device models calibrated to the
//!   paper's Tables 3 & 7: V100 is near-linear in width; A100 saturates
//!   (~4.4x) because small matrices underutilize it.

use std::path::Path;

use anyhow::{anyhow, Result};

use crate::runtime::{lit_f32_shaped, lit_i32, Engine};
use crate::util::bench::Bench;
use crate::util::json::Json;

/// Which device a latency table describes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Device {
    /// the real path: measured through the CPU PJRT runtime
    CpuPjrt,
    /// analytic V100 model (near-linear in width, paper Tables 3 & 7)
    V100Sim,
    /// analytic A100 model (saturates around 4.4x, paper Table 3)
    A100Sim,
}

impl Device {
    /// Parse a CLI device name (`cpu`, `v100`, `a100`, or `*-sim`/`-pjrt` forms).
    pub fn parse(s: &str) -> Result<Device> {
        match s {
            "cpu" | "cpu-pjrt" => Ok(Device::CpuPjrt),
            "v100" | "v100-sim" => Ok(Device::V100Sim),
            "a100" | "a100-sim" => Ok(Device::A100Sim),
            other => Err(anyhow!("unknown device `{other}`")),
        }
    }

    /// Canonical table/device name (inverse of [`Device::parse`]).
    pub fn name(&self) -> &'static str {
        match self {
            Device::CpuPjrt => "cpu-pjrt",
            Device::V100Sim => "v100-sim",
            Device::A100Sim => "a100-sim",
        }
    }
}

/// Latency table for one (model, device, regime).
#[derive(Clone, Debug, PartialEq)]
pub struct LatencyTable {
    /// model the table was measured/derived for
    pub model: String,
    /// device name (see [`Device::name`])
    pub device: String,
    /// `"throughput"` (large batch) or `"latency"` (batch 1)
    pub regime: String,
    /// `attn[h]` = seconds with h heads remaining; `attn[0]` == 0 (dropped)
    pub attn: Vec<f64>,
    /// (intermediate width, seconds), decreasing width, plus (0, 0.0)
    pub mlp: Vec<(usize, f64)>,
    /// fixed per-model time (embeddings + task/LM head) — what caps the
    /// maximum achievable speedup (paper: GPT2 ≤ ~3.5x from the vocab)
    pub overhead: f64,
}

impl LatencyTable {
    /// Attention-block time with `heads` heads remaining.
    pub fn attn_time(&self, heads: usize) -> f64 {
        self.attn[heads.min(self.attn.len() - 1)]
    }

    /// Linear interpolation between measured widths.
    pub fn mlp_time(&self, width: usize) -> f64 {
        if width == 0 {
            return 0.0;
        }
        let mut upper = self.mlp[0];
        for &(w, t) in &self.mlp {
            if w >= width {
                upper = (w, t);
            }
            if w <= width {
                let lower = (w, t);
                if upper.0 == lower.0 {
                    return lower.1;
                }
                let frac = (width - lower.0) as f64 / (upper.0 - lower.0) as f64;
                return lower.1 + frac * (upper.1 - lower.1);
            }
        }
        // below smallest nonzero measurement: scale towards 0
        let (w, t) = *self.mlp.iter().rev().find(|&&(w, _)| w > 0).unwrap();
        t * width as f64 / w as f64
    }

    /// End-to-end model time for per-layer (heads, ffn width) profile.
    pub fn model_time(&self, profile: &[(usize, usize)]) -> f64 {
        self.overhead
            + profile
                .iter()
                .map(|&(h, f)| self.attn_time(h) + self.mlp_time(f))
                .sum::<f64>()
    }

    /// End-to-end time of the dense model at `n_layers` layers.
    pub fn dense_time(&self, n_layers: usize) -> f64 {
        let dense_h = self.attn.len() - 1;
        let dense_f = self.mlp[0].0;
        self.model_time(&vec![(dense_h, dense_f); n_layers])
    }

    /// Estimated speedup of a per-layer profile over the dense model.
    pub fn speedup(&self, profile: &[(usize, usize)]) -> f64 {
        self.dense_time(profile.len()) / self.model_time(profile)
    }

    // ----------------------------------------------------------- persist

    /// Serialize to the on-disk JSON form.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("model", Json::Str(self.model.clone())),
            ("device", Json::Str(self.device.clone())),
            ("regime", Json::Str(self.regime.clone())),
            ("attn", Json::arr_f64(&self.attn)),
            (
                "mlp",
                Json::Arr(
                    self.mlp
                        .iter()
                        .map(|&(w, t)| Json::Arr(vec![Json::Num(w as f64), Json::Num(t)]))
                        .collect(),
                ),
            ),
            ("overhead", Json::Num(self.overhead)),
        ])
    }

    /// Parse the on-disk JSON form.
    pub fn from_json(j: &Json) -> Result<LatencyTable> {
        let attn = j
            .get("attn")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("no attn"))?
            .iter()
            .filter_map(Json::as_f64)
            .collect();
        let mlp = j
            .get("mlp")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("no mlp"))?
            .iter()
            .map(|e| {
                (
                    e.idx(0).and_then(Json::as_usize).unwrap_or(0),
                    e.idx(1).and_then(Json::as_f64).unwrap_or(0.0),
                )
            })
            .collect();
        Ok(LatencyTable {
            model: j.req_str("model").to_string(),
            device: j.req_str("device").to_string(),
            regime: j.req_str("regime").to_string(),
            attn,
            mlp,
            overhead: j.get("overhead").and_then(Json::as_f64).unwrap_or(0.0),
        })
    }

    /// Write the table as pretty JSON, creating parent directories.
    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(d) = path.parent() {
            std::fs::create_dir_all(d)?;
        }
        std::fs::write(path, self.to_json().to_pretty())?;
        Ok(())
    }

    /// Load a table from disk.
    pub fn load(path: &Path) -> Result<LatencyTable> {
        let text = std::fs::read_to_string(path)?;
        LatencyTable::from_json(&Json::parse(&text).map_err(|e| anyhow!(e))?)
    }

    /// Pretty print (paper App. E, Table 7 format).
    pub fn render(&self) -> String {
        let mut s = format!(
            "# latency table: {} on {} ({} regime)\n{:<20}{:>12}\n",
            self.model, self.device, self.regime, "intermediate", "latency(ms)"
        );
        for &(w, t) in &self.mlp {
            s += &format!("{:<20}{:>12.3}\n", w, t * 1e3);
        }
        s += &format!("{:<20}{:>12}\n", "heads", "latency(ms)");
        for (h, t) in self.attn.iter().enumerate().rev() {
            s += &format!("{:<20}{:>12.3}\n", h, t * 1e3);
        }
        s += &format!("{:<20}{:>12.3}\n", "overhead", self.overhead * 1e3);
        s
    }
}

// --------------------------------------------------------------- measure

/// Build a table by timing the AOT block artifacts on the CPU PJRT
/// runtime (median over repetitions). `reps` trades precision for time.
pub fn measure_cpu(engine: &Engine, model: &str, regime: &str, reps: usize) -> Result<LatencyTable> {
    let info = engine.manifest.model(model).clone();
    let bench = Bench { warmup: std::time::Duration::from_millis(30), budget: std::time::Duration::from_millis(400), max_iters: reps.max(5) };
    let mut attn = vec![0.0f64; info.n_heads + 1];
    for h in 1..=info.n_heads {
        let name = format!("{model}__block_attn_h{h}__{regime}");
        attn[h] = time_artifact(engine, &name, &bench)?;
        engine.evict(&name);
    }
    let mut mlp: Vec<(usize, f64)> = Vec::new();
    for &f in &info.measured_ffn {
        let name = format!("{model}__block_mlp_f{f}__{regime}");
        mlp.push((f, time_artifact(engine, &name, &bench)?));
        engine.evict(&name);
    }
    mlp.sort_by(|a, b| b.0.cmp(&a.0));
    mlp.push((0, 0.0));
    // Fixed overhead: embeddings + task head, estimated from flops
    // relative to one dense layer (measured), since the fwd artifact's
    // batch differs per regime.
    let (b, s) = regime_shape(engine, model, regime)?;
    let dense_layer = attn[info.n_heads] + mlp[0].1;
    let layer_flops = flops_attn(&info, info.n_heads, b, s) + flops_mlp(&info, info.d_ff, b, s);
    let head_flops = flops_overhead(&info, b, s);
    let overhead = dense_layer * head_flops / layer_flops;
    Ok(LatencyTable {
        model: model.to_string(),
        device: "cpu-pjrt".into(),
        regime: regime.into(),
        attn,
        mlp,
        overhead,
    })
}

/// Static `(batch, seq)` shape of the measured block artifacts for
/// `(model, regime)` — the anchor shape an [`crate::env::InferenceEnv`]
/// records alongside a measured table. For the full per-bucket set,
/// see [`regime_sweep`].
pub fn regime_shape(engine: &Engine, model: &str, regime: &str) -> Result<(usize, usize)> {
    let info = engine.manifest.model(model);
    let name = format!("{model}__block_attn_h{}__{regime}", info.n_heads);
    let a = engine
        .manifest
        .artifacts
        .get(&name)
        .ok_or_else(|| anyhow!("missing block artifact {name}"))?;
    Ok((a.batch.unwrap_or(1), a.seq.unwrap_or(info.seq_len)))
}

/// Every distinct `(batch, seq)` shape the dense-attention block
/// artifacts for `(model, regime)` were lowered at, ascending in seq —
/// one row per serving shape bucket (DESIGN.md §9). With today's
/// single-shape artifact sets this returns exactly the
/// [`regime_shape`] anchor; when `aot.py` emits per-seq block variants
/// (names extending `{model}__block_attn_h{H}__{regime}`), each
/// lowered shape becomes a bucket, giving a measured-env seq sweep the
/// same shape the analytic one ([`analytic_seq_sweep`]) has.
pub fn regime_sweep(engine: &Engine, model: &str, regime: &str) -> Result<Vec<(usize, usize)>> {
    let info = engine.manifest.model(model);
    let prefix = format!("{model}__block_attn_h{}__{regime}", info.n_heads);
    let mut shapes: Vec<(usize, usize)> = engine
        .manifest
        .artifacts
        .iter()
        .filter(|(name, _)| name.starts_with(&prefix))
        .map(|(_, a)| (a.batch.unwrap_or(1), a.seq.unwrap_or(info.seq_len)))
        .collect();
    if shapes.is_empty() {
        return Err(anyhow!("no block artifacts matching {prefix}*"));
    }
    shapes.sort_by_key(|&(b, s)| (s, b));
    shapes.dedup();
    Ok(shapes)
}

fn time_artifact(engine: &Engine, name: &str, bench: &Bench) -> Result<f64> {
    let info = engine
        .manifest
        .artifacts
        .get(name)
        .ok_or_else(|| anyhow!("unknown artifact {name}"))?
        .clone();
    // random-ish inputs of the right shapes
    let mut lits = Vec::new();
    for (i, sig) in info.inputs.iter().enumerate() {
        let n: usize = sig.shape.iter().product();
        if sig.dtype == "i32" {
            lits.push(lit_i32(&sig.shape, &vec![1i32; n])?);
        } else {
            let data: Vec<f32> = (0..n).map(|k| ((k + i) % 13) as f32 * 0.01).collect();
            lits.push(lit_f32_shaped(&sig.shape, &data)?);
        }
    }
    let exe = engine.executable(name)?;
    let stats = bench.run(name, || Engine::run_exe(&exe, &lits).expect("block exec"));
    Ok(stats.median_ns / 1e9)
}

// --------------------------------------------------------------- analytic

/// Architectural dims for analytic tables (decoupled from our synthetic
/// models so Table 3 can be reproduced at the paper's BERT-base scale).
#[derive(Clone, Copy, Debug)]
pub struct ArchDims {
    /// hidden size
    pub d_model: usize,
    /// attention heads
    pub n_heads: usize,
    /// per-head dimension
    pub d_head: usize,
    /// FFN intermediate width
    pub d_ff: usize,
    /// vocabulary size (drives the un-prunable head overhead)
    pub vocab: usize,
    /// transformer layers
    pub n_layers: usize,
    /// batch size of the regime being modeled
    pub batch: usize,
    /// sequence length of the regime being modeled
    pub seq: usize,
}

impl ArchDims {
    /// BERT-base at the paper's measurement scale (Tables 3 & 7).
    pub fn bert_base_paper() -> ArchDims {
        ArchDims { d_model: 768, n_heads: 12, d_head: 64, d_ff: 3072, vocab: 30522, n_layers: 12, batch: 128, seq: 128 }
    }
}

fn flops_attn(info: &crate::runtime::ModelInfo, heads: usize, b: usize, s: usize) -> f64 {
    let dims = ArchDims {
        d_model: info.d_model,
        n_heads: info.n_heads,
        d_head: info.d_head,
        d_ff: info.d_ff,
        vocab: info.vocab,
        n_layers: info.n_layers,
        batch: b,
        seq: s,
    };
    flops_attn_d(&dims, heads)
}

fn flops_mlp(info: &crate::runtime::ModelInfo, width: usize, b: usize, s: usize) -> f64 {
    (b * s) as f64 * 4.0 * info.d_model as f64 * width as f64
}

fn flops_overhead(info: &crate::runtime::ModelInfo, b: usize, s: usize) -> f64 {
    // embedding gather is cheap; the head matmul dominates: 2*d*V per tok
    (b * s) as f64 * 2.0 * info.d_model as f64 * info.vocab as f64 * 0.25
}

fn flops_attn_d(d: &ArchDims, heads: usize) -> f64 {
    let a = heads * d.d_head;
    let toks = (d.batch * d.seq) as f64;
    // q,k,v,out projections + score/context matmuls
    toks * (8.0 * d.d_model as f64 * a as f64) + toks * (4.0 * d.seq as f64 * a as f64)
}

fn flops_mlp_d(d: &ArchDims, width: usize) -> f64 {
    (d.batch * d.seq) as f64 * 4.0 * d.d_model as f64 * width as f64
}

/// Device model: t(work) = max(floor, t_fix + work / peak).
/// Calibrated against the paper:
///  * V100 (Tables 3 & 7): near-linear in width with a small intercept
///    (fit of Table 7 gives intercept ≈ 4.9% of the dense block);
///  * A100 (Table 3): much higher peak but saturates — speedup capped
///    at ≈ 4.4x regardless of how small the matrices get.
struct DeviceModel {
    peak_flops: f64,
    t_fix: f64,
    floor_frac: f64, // min block time as fraction of dense block (0 = none)
}

fn device_model(dev: Device, dense_flops: f64) -> DeviceModel {
    match dev {
        Device::V100Sim => {
            // dense FFN block 11.9ms at paper scale => derive peak
            let t_dense = 11.9e-3 * dense_flops / flops_mlp_d(&ArchDims::bert_base_paper(), 3072);
            DeviceModel { peak_flops: dense_flops / (t_dense * 0.951), t_fix: t_dense * 0.049, floor_frac: 0.0 }
        }
        Device::A100Sim => {
            let t_dense = 4.1e-3 * dense_flops / flops_mlp_d(&ArchDims::bert_base_paper(), 3072);
            DeviceModel { peak_flops: dense_flops / (t_dense * 0.90), t_fix: t_dense * 0.10, floor_frac: 1.0 / 4.4 }
        }
        Device::CpuPjrt => DeviceModel { peak_flops: 5e9, t_fix: 20e-6, floor_frac: 0.0 },
    }
}

/// Build an analytic table for arbitrary architecture dims.
pub fn analytic(dev: Device, dims: &ArchDims, regime: &str, mlp_widths: &[usize]) -> LatencyTable {
    let dense_mlp = flops_mlp_d(dims, dims.d_ff);
    let m = device_model(dev, dense_mlp);
    let block_time = |flops: f64, dense: f64| -> f64 {
        let t = m.t_fix + flops / m.peak_flops;
        let floor = m.floor_frac * (m.t_fix + dense / m.peak_flops);
        t.max(floor)
    };
    let dense_attn = flops_attn_d(dims, dims.n_heads);
    let mut attn = vec![0.0f64];
    for h in 1..=dims.n_heads {
        attn.push(block_time(flops_attn_d(dims, h), dense_attn));
    }
    let mut mlp: Vec<(usize, f64)> = mlp_widths
        .iter()
        .filter(|&&w| w > 0)
        .map(|&w| (w, block_time(flops_mlp_d(dims, w), dense_mlp)))
        .collect();
    mlp.sort_by(|a, b| b.0.cmp(&a.0));
    mlp.push((0, 0.0));
    let head_flops = (dims.batch * dims.seq) as f64 * 2.0 * dims.d_model as f64 * dims.vocab as f64 * 0.25;
    let overhead = block_time(head_flops, dense_mlp);
    LatencyTable {
        model: format!("analytic-d{}", dims.d_model),
        device: dev.name().into(),
        regime: regime.into(),
        attn,
        mlp,
        overhead,
    }
}

/// Relative per-seq cost scale of one dense transformer layer on an
/// analytic device: layer time at each padded seq in `seqs`, normalized
/// to the time at the anchor `dims.seq` (scale 1.0). The attention
/// score/context term is quadratic in seq while the projections and the
/// FFN are linear, so the sweep is convex rather than proportional —
/// exactly the shape dependence the latency regime's shaped batches
/// need priced (DESIGN.md §9). Feed the result to
/// [`crate::env::InferenceEnv::with_seq_sweep`].
pub fn analytic_seq_sweep(dev: Device, dims: &ArchDims, seqs: &[usize]) -> Vec<(usize, f64)> {
    // one device model, calibrated at the anchor dims, shared by every
    // seq so only the workload varies across rows
    let m = device_model(dev, flops_mlp_d(dims, dims.d_ff));
    let layer_time = |seq: usize| -> f64 {
        let d = ArchDims { seq, ..*dims };
        // dense blocks: the saturation floor (a fraction of the dense
        // block's own time) never binds, so the roofline term is exact
        let block = |flops: f64| m.t_fix + flops / m.peak_flops;
        block(flops_attn_d(&d, d.n_heads)) + block(flops_mlp_d(&d, d.d_ff))
    };
    let anchor = layer_time(dims.seq);
    let mut out: Vec<(usize, f64)> = seqs
        .iter()
        .filter(|&&s| s > 0)
        .map(|&s| (s, layer_time(s) / anchor))
        .collect();
    out.sort_by_key(|&(s, _)| s);
    out.dedup_by_key(|p| p.0);
    out
}

/// Dense FFN width whose GEMM work matches a rank-`rank` factorization
/// of the `d_model`×`width` FFN pair: both projections drop from
/// `O(d_model·width)` to `O(rank·(d_model + width))` multiply-adds, so
/// the factorized pair prices like a dense pair of width
/// `⌈rank·(d_model + width)/d_model⌉`. This is how low-rank choices
/// reuse the SAME `CostModel::mlp_time` ladder the pruner is certified
/// against (DESIGN.md §13) — integer-only, clamped to the dense width
/// so a non-compressing rank never prices below dense.
pub fn low_rank_ffn_width(d_model: usize, width: usize, rank: usize) -> usize {
    if d_model == 0 {
        return width;
    }
    (rank * (d_model + width)).div_ceil(d_model).min(width)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn table() -> LatencyTable {
        LatencyTable {
            model: "m".into(),
            device: "test".into(),
            regime: "throughput".into(),
            attn: vec![0.0, 1.0e-3, 1.8e-3, 2.5e-3, 3.1e-3],
            mlp: vec![(512, 8e-3), (256, 4.2e-3), (64, 1.5e-3), (0, 0.0)],
            overhead: 1e-3,
        }
    }

    #[test]
    fn mlp_interpolation_monotone() {
        let t = table();
        assert!((t.mlp_time(512) - 8e-3).abs() < 1e-12);
        let mid = t.mlp_time(384);
        assert!(mid > 4.2e-3 && mid < 8e-3);
        assert!(t.mlp_time(32) < 1.5e-3);
        assert_eq!(t.mlp_time(0), 0.0);
        // monotone over a sweep
        let mut prev = f64::INFINITY;
        for w in (0..=512).rev().step_by(16) {
            let v = t.mlp_time(w);
            assert!(v <= prev + 1e-12, "w={w}");
            prev = v;
        }
    }

    #[test]
    fn model_time_and_speedup() {
        let t = table();
        let dense = t.dense_time(2);
        assert!((dense - (1e-3 + 2.0 * (3.1e-3 + 8e-3))).abs() < 1e-9);
        let s = t.speedup(&[(2, 256), (0, 0)]);
        assert!(s > 1.0);
        assert!((t.speedup(&vec![(4, 512); 2]) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn json_roundtrip() {
        let t = table();
        let j = t.to_json();
        let t2 = LatencyTable::from_json(&j).unwrap();
        assert_eq!(t.attn, t2.attn);
        assert_eq!(t.mlp, t2.mlp);
        assert_eq!(t.overhead, t2.overhead);
    }

    #[test]
    fn analytic_v100_vs_a100_saturation() {
        // Reproduces the *shape* of paper Table 3: V100 keeps speeding
        // up as the MLP shrinks; A100 saturates around 4.4x.
        let dims = ArchDims::bert_base_paper();
        let widths = [3072usize, 1814, 1322, 302, 130, 76, 33];
        let v = analytic(Device::V100Sim, &dims, "throughput", &widths);
        let a = analytic(Device::A100Sim, &dims, "throughput", &widths);
        let sp = |t: &LatencyTable, w: usize| t.mlp_time(3072) / t.mlp_time(w);
        assert!(sp(&v, 33) > 10.0, "V100 33: {}", sp(&v, 33));
        assert!(sp(&a, 33) < 5.0, "A100 33: {}", sp(&a, 33));
        assert!((sp(&a, 33) - sp(&a, 76)).abs() < 0.2, "A100 saturated");
        assert!(sp(&v, 302) > 2.0 * sp(&a, 302) / 2.0); // V100 ahead at mid sizes
        // dense ratio ≈ 1 for both
        assert!((sp(&v, 3072) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn attn_time_zero_when_dropped() {
        let t = table();
        assert_eq!(t.attn_time(0), 0.0);
    }

    #[test]
    fn analytic_seq_sweep_anchored_monotone_superlinear() {
        let dims = ArchDims::bert_base_paper(); // anchor seq 128
        let sweep = analytic_seq_sweep(Device::V100Sim, &dims, &[512, 32, 64, 128, 0, 64]);
        // non-positive dropped, dups deduped, ascending
        let seqs: Vec<usize> = sweep.iter().map(|&(s, _)| s).collect();
        assert_eq!(seqs, vec![32, 64, 128, 512]);
        // scale 1.0 at the anchor, monotone in seq
        let at = |s: usize| sweep.iter().find(|&&(q, _)| q == s).unwrap().1;
        assert!((at(128) - 1.0).abs() < 1e-12);
        assert!(at(32) < at(64) && at(64) < at(128) && at(128) < at(512));
        // attention's seq² term makes the sweep superlinear: 4x the
        // anchor seq costs MORE than 4x the anchor layer time
        assert!(at(512) > 4.0, "seq² term missing: {}", at(512));
        // and shorter-than-anchor seqs cost less than proportionally
        assert!(at(32) > 32.0 / 128.0 * 0.5, "sub-anchor scale collapsed: {}", at(32));
    }

    #[test]
    fn low_rank_width_matches_gemm_work_and_clamps() {
        // kick-tires dims: d_model 128, d_ff 512 → d_model + d_ff is
        // 5·d_model, so the equivalent width is exactly 5·rank
        for (rank, want) in [(96, 480), (64, 320), (32, 160)] {
            assert_eq!(low_rank_ffn_width(128, 512, rank), want);
        }
        // a non-compressing rank clamps to dense, never above
        assert_eq!(low_rank_ffn_width(128, 512, 128), 512);
        assert_eq!(low_rank_ffn_width(128, 512, 4096), 512);
        // ceil on non-divisible shapes, zero-rank prices as dropped
        assert_eq!(low_rank_ffn_width(100, 300, 7), 28);
        assert_eq!(low_rank_ffn_width(128, 512, 0), 0);
        assert_eq!(low_rank_ffn_width(0, 512, 3), 512);
    }
}
