//! Deterministic fault injection for the serving fleet (DESIGN.md §10).
//!
//! A [`FaultPlan`] is a *seeded schedule* of faults — worker crashes,
//! compile failures, exec slowdowns, NaN latency samples — that the
//! simulated devices behind the fleet coordinator consult instead of
//! real hardware failing. Two properties make it a test substrate
//! rather than a chaos monkey:
//!
//! * **Replayable.** Every decision comes from a [`FaultStream`] whose
//!   generator state is a pure function of `(plan seed, worker,
//!   incarnation)` plus a worker-local draw counter. Thread
//!   interleaving cannot change what the k-th exec of worker w's n-th
//!   incarnation does, so a seeded chaos run injects the *same* fault
//!   schedule on every replay — the bit-identical-replay test in
//!   `runtime::cache` and the fleet property tests in
//!   `tests/fleet_chaos.rs` both lean on this.
//! * **Engine-free.** Nothing here touches PJRT; the plan prices
//!   nothing and owns nothing. [`FaultPlan::none`] is the production
//!   value: every query answers "no fault" without consuming entropy,
//!   so a fault-free fleet run is byte-identical to one built before
//!   this module existed.
//!
//! The draw order inside a stream is part of its contract:
//! [`FaultStream::exec_fault`] consumes exactly three uniform draws
//! (crash, slowdown, NaN) and [`FaultStream::compile_fault`] exactly
//! one, so interleaved exec/compile queries replay identically as long
//! as the caller issues them in the same worker-local order — which a
//! single-threaded worker loop does by construction.

use crate::util::rng::Rng;

/// Per-event fault probabilities (all in `[0, 1]`; `0` disables).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct FaultRates {
    /// P\[the worker crashes while executing a batch\]
    pub crash: f64,
    /// P\[a cold compile fails\] (per `compile_fault` query)
    pub compile_fail: f64,
    /// P\[a batch execution is slowed by `slowdown_factor`\]
    pub slowdown: f64,
    /// multiplier applied to exec time when a slowdown fires (≥ 1.0
    /// is meaningful; non-finite or < 1.0 values are clamped to 1.0)
    pub slowdown_factor: f64,
    /// P\[the reported exec-latency sample is NaN\] (the sample is
    /// poisoned, the reply itself is still correct — exercises the
    /// NaN-tolerant stats paths)
    pub nan_latency: f64,
}

impl FaultRates {
    /// Whether every rate is zero (the no-fault fast path).
    pub fn is_none(&self) -> bool {
        self.crash == 0.0 && self.compile_fail == 0.0 && self.slowdown == 0.0
            && self.nan_latency == 0.0
    }
}

/// A seeded, replayable schedule of injected faults.
///
/// The plan itself is tiny and copyable: streams are derived on demand
/// with [`FaultPlan::stream`], one per (worker, incarnation), so a
/// restarted worker draws from a fresh-but-deterministic sequence
/// instead of replaying its predecessor's.
#[derive(Clone, Copy, Debug)]
pub struct FaultPlan {
    seed: u64,
    rates: FaultRates,
}

impl FaultPlan {
    /// The production plan: no faults, ever.
    pub fn none() -> FaultPlan {
        FaultPlan { seed: 0, rates: FaultRates::default() }
    }

    /// A seeded plan injecting faults at `rates`.
    pub fn seeded(seed: u64, rates: FaultRates) -> FaultPlan {
        FaultPlan { seed, rates }
    }

    /// The plan's rates.
    pub fn rates(&self) -> FaultRates {
        self.rates
    }

    /// The fault stream for one worker incarnation. Pure in
    /// `(self.seed, worker, incarnation)` — see the module docs for
    /// why that makes chaos runs replayable.
    pub fn stream(&self, worker: usize, incarnation: u32) -> FaultStream {
        // mix the coordinates through SplitMix-style odd constants so
        // (w=1, inc=0) and (w=0, inc=1) land on unrelated streams
        let tag = (worker as u64)
            .wrapping_mul(0x9E3779B97F4A7C15)
            .wrapping_add((incarnation as u64).wrapping_mul(0xBF58476D1CE4E5B9));
        FaultStream { rng: Rng::new(self.seed ^ tag), rates: self.rates }
    }
}

/// Outcome of one exec-fault query: at most one crash, plus an exec
/// time multiplier and whether the latency *sample* is poisoned.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ExecFault {
    /// the worker dies mid-batch (no reply is produced)
    pub crash: bool,
    /// exec-time multiplier (1.0 = nominal)
    pub slowdown: f64,
    /// the recorded latency sample is NaN (reply still correct)
    pub nan_latency: bool,
}

impl ExecFault {
    /// The no-fault value.
    pub fn nominal() -> ExecFault {
        ExecFault { crash: false, slowdown: 1.0, nan_latency: false }
    }
}

/// One worker incarnation's deterministic fault sequence (derive via
/// [`FaultPlan::stream`]).
#[derive(Clone, Debug)]
pub struct FaultStream {
    rng: Rng,
    rates: FaultRates,
}

impl FaultStream {
    /// Draw the fault verdict for the next executed batch. Always
    /// consumes exactly three uniform draws, even when every rate is
    /// zero, so mixed-rate plans replay identically.
    pub fn exec_fault(&mut self) -> ExecFault {
        let (c, s, n) = (self.rng.f64(), self.rng.f64(), self.rng.f64());
        let factor = if self.rates.slowdown_factor.is_finite() {
            self.rates.slowdown_factor.max(1.0)
        } else {
            1.0
        };
        ExecFault {
            crash: c < self.rates.crash,
            slowdown: if s < self.rates.slowdown { factor } else { 1.0 },
            nan_latency: n < self.rates.nan_latency,
        }
    }

    /// Draw whether the next cold compile fails (one uniform draw).
    pub fn compile_fault(&mut self) -> bool {
        self.rng.f64() < self.rates.compile_fail
    }
}

#[cfg(test)]
#[allow(clippy::disallowed_methods)]
mod tests {
    use super::*;

    fn rates() -> FaultRates {
        FaultRates {
            crash: 0.3,
            compile_fail: 0.4,
            slowdown: 0.5,
            slowdown_factor: 4.0,
            nan_latency: 0.2,
        }
    }

    #[test]
    fn replay_is_bit_identical() {
        let plan = FaultPlan::seeded(0xC0FFEE, rates());
        let (mut a, mut b) = (plan.stream(2, 1), plan.stream(2, 1));
        for _ in 0..200 {
            assert_eq!(a.exec_fault(), b.exec_fault());
            assert_eq!(a.compile_fault(), b.compile_fault());
        }
    }

    #[test]
    fn streams_differ_across_workers_and_incarnations() {
        let plan = FaultPlan::seeded(7, rates());
        let seq = |mut s: FaultStream| -> Vec<ExecFault> {
            (0..64).map(|_| s.exec_fault()).collect()
        };
        let base = seq(plan.stream(0, 0));
        assert_ne!(base, seq(plan.stream(1, 0)), "workers share a stream");
        assert_ne!(base, seq(plan.stream(0, 1)), "incarnations share a stream");
        // and the swapped coordinates don't collide either
        assert_ne!(seq(plan.stream(1, 0)), seq(plan.stream(0, 1)));
    }

    #[test]
    fn none_never_faults() {
        let mut s = FaultPlan::none().stream(3, 9);
        for _ in 0..100 {
            assert_eq!(s.exec_fault(), ExecFault::nominal());
            assert!(!s.compile_fault());
        }
        assert!(FaultPlan::none().rates().is_none());
    }

    #[test]
    fn rates_are_roughly_honored() {
        let mut s = FaultPlan::seeded(42, rates()).stream(0, 0);
        let n = 20_000;
        let (mut crashes, mut slows, mut nans, mut cfails) = (0, 0, 0, 0);
        for _ in 0..n {
            let f = s.exec_fault();
            crashes += f.crash as usize;
            slows += (f.slowdown > 1.0) as usize;
            nans += f.nan_latency as usize;
            cfails += s.compile_fault() as usize;
        }
        let close = |got: usize, p: f64| {
            let f = got as f64 / n as f64;
            assert!((f - p).abs() < 0.02, "rate {f} vs {p}");
        };
        close(crashes, 0.3);
        close(slows, 0.5);
        close(nans, 0.2);
        close(cfails, 0.4);
    }

    #[test]
    fn slowdown_factor_sanitized() {
        let mut s = FaultPlan::seeded(
            1,
            FaultRates { slowdown: 1.0, slowdown_factor: f64::NAN, ..Default::default() },
        )
        .stream(0, 0);
        let f = s.exec_fault();
        assert_eq!(f.slowdown, 1.0, "NaN factor must clamp to nominal");
        let mut s2 = FaultPlan::seeded(
            1,
            FaultRates { slowdown: 1.0, slowdown_factor: 0.25, ..Default::default() },
        )
        .stream(0, 0);
        assert_eq!(s2.exec_fault().slowdown, 1.0, "sub-1 factor must clamp up");
    }
}
