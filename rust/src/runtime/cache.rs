//! Shared compiled-artifact cache (DESIGN.md §6; shape-specialized
//! keys in §9).
//!
//! Compiling an HLO artifact is the most expensive control-plane
//! operation in the coordinator (hundreds of ms per graph), so every
//! executable is built exactly once per process and shared from then
//! on. The cache is keyed by [`ArtifactKey`] — *(artifact variant,
//! batch shape)* — rather than by file path:
//!
//! * family members that share a graph (the masked `fwd` artifact is
//!   identical for every pruned variant of one model — masks are
//!   runtime inputs) collapse to ONE key and therefore one compile,
//!   no matter how many variants the family coordinator serves;
//! * shape-specialized exports (one materialized graph per variant,
//!   table 8 / production serving) get distinct keys per variant and
//!   batch shape, so they coexist without eviction fights. The family
//!   coordinator's per-(member, bucket) executables (DESIGN.md §9)
//!   live behind exactly these keys: the member tag goes into the
//!   artifact id, the bucket into `batch`/`seq`, so "builds == distinct
//!   (member, bucket) pairs exercised" is the cache-counter invariant
//!   the coordinator tests assert.
//!
//! Concurrency follows PR 1's per-artifact compile gate: a per-key
//! mutex makes check-then-compile atomic, so racing callers (the
//! parallel database builds, multiple family queues) serialize per
//! key while distinct keys still compile in parallel. Build and hit
//! counters are exposed for the coordinator's serving stats and for
//! the "each artifact compiled at most once across the family"
//! acceptance test.

use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::Result;

/// Cache key for a compiled executable: which graph, at which shape.
///
/// `artifact` identifies the model variant's graph (for masked graphs
/// that is one shared id per (model, task); for specialized exports it
/// embeds the variant tag). `batch`/`seq` record the static input
/// shape the graph was lowered at; use 0 when the dimension is baked
/// into the artifact id.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct ArtifactKey {
    /// artifact id (manifest name or specialized-export name)
    pub artifact: String,
    /// static batch dimension of the lowered graph (0 = unspecified)
    pub batch: usize,
    /// static sequence length of the lowered graph (0 = unspecified)
    pub seq: usize,
}

impl ArtifactKey {
    /// Build a key from its parts.
    pub fn new(artifact: impl Into<String>, batch: usize, seq: usize) -> ArtifactKey {
        ArtifactKey { artifact: artifact.into(), batch, seq }
    }

    /// Canonical string form used as the cache map key. Injective:
    /// the shape suffix after the final `@` is all digits, so two
    /// distinct `(artifact, batch, seq)` triples can never encode to
    /// one string even when the artifact id itself contains `@b…s…`
    /// (property-tested in `tests/proptests.rs`).
    pub fn encode(&self) -> String {
        format!("{}@b{}s{}", self.artifact, self.batch, self.seq)
    }
}

/// A build-once map from [`ArtifactKey`] strings to shared values.
///
/// `get_or_build` is the only write path: the first caller for a key
/// runs the builder under that key's gate while other keys proceed
/// concurrently; every later caller gets the cached `Arc`. Builder
/// errors are propagated and nothing is cached, so a failed compile
/// can be retried.
pub struct CompileCache<V> {
    entries: Mutex<HashMap<String, Arc<V>>>,
    /// Per-key compile gates (PR 1): serialize per name so a value is
    /// built exactly once while different keys build in parallel. The
    /// map only grows, bounded by the number of distinct keys.
    inflight: Mutex<HashMap<String, Arc<Mutex<()>>>>,
    builds: AtomicUsize,
    hits: AtomicUsize,
}

impl<V> CompileCache<V> {
    /// Empty cache with zeroed counters.
    pub fn new() -> CompileCache<V> {
        CompileCache {
            entries: Mutex::new(HashMap::new()),
            inflight: Mutex::new(HashMap::new()),
            builds: AtomicUsize::new(0),
            hits: AtomicUsize::new(0),
        }
    }

    /// Fetch the value for `key`, building it (exactly once per key,
    /// across threads) if absent.
    pub fn get_or_build<F>(&self, key: &str, build: F) -> Result<Arc<V>>
    where
        F: FnOnce() -> Result<V>,
    {
        if let Some(v) = self.entries.lock().unwrap().get(key) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return Ok(Arc::clone(v));
        }
        let gate = {
            let mut inflight = self.inflight.lock().unwrap();
            Arc::clone(inflight.entry(key.to_string()).or_default())
        };
        let _building = gate.lock().unwrap();
        // re-check under the gate: a racing caller may have finished
        if let Some(v) = self.entries.lock().unwrap().get(key) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return Ok(Arc::clone(v));
        }
        let v = Arc::new(build()?);
        self.builds.fetch_add(1, Ordering::Relaxed);
        self.entries.lock().unwrap().insert(key.to_string(), Arc::clone(&v));
        Ok(v)
    }

    /// Cached value for `key`, if present (counts as a hit).
    pub fn get(&self, key: &str) -> Option<Arc<V>> {
        let v = self.entries.lock().unwrap().get(key).map(Arc::clone);
        if v.is_some() {
            self.hits.fetch_add(1, Ordering::Relaxed);
        }
        v
    }

    /// Whether `key` is cached, WITHOUT counting a hit. The family
    /// coordinator uses this to decide between serving a batch on an
    /// already-specialized executable and falling back to the generic
    /// one while the specialization is still cold (DESIGN.md §9), so
    /// probing must not distort the build/hit counters the serving
    /// stats report.
    pub fn contains(&self, key: &str) -> bool {
        self.entries.lock().unwrap().contains_key(key)
    }

    /// Drop a cached value (memory control for block sweeps). Returns
    /// whether an entry was removed. Outstanding `Arc`s stay valid.
    pub fn evict(&self, key: &str) -> bool {
        self.entries.lock().unwrap().remove(key).is_some()
    }

    /// Number of cached values.
    pub fn len(&self) -> usize {
        self.entries.lock().unwrap().len()
    }

    /// Whether the cache holds no values.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// How many times a builder ran to completion.
    pub fn builds(&self) -> usize {
        self.builds.load(Ordering::Relaxed)
    }

    /// How many lookups were served from cache.
    pub fn hits(&self) -> usize {
        self.hits.load(Ordering::Relaxed)
    }
}

impl<V> Default for CompileCache<V> {
    fn default() -> Self {
        CompileCache::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn key_encoding_distinguishes_shape_and_variant() {
        let a = ArtifactKey::new("m__t__fwd", 8, 128);
        let b = ArtifactKey::new("m__t__fwd", 16, 128);
        let c = ArtifactKey::new("spec_m_t_2x", 8, 128);
        assert_ne!(a.encode(), b.encode());
        assert_ne!(a.encode(), c.encode());
        assert_eq!(a.encode(), ArtifactKey::new("m__t__fwd", 8, 128).encode());
    }

    #[test]
    fn builds_once_then_hits() {
        let cache: CompileCache<usize> = CompileCache::new();
        let k = ArtifactKey::new("art", 4, 16).encode();
        let v1 = cache.get_or_build(&k, || Ok(7usize)).unwrap();
        let v2 = cache.get_or_build(&k, || panic!("must not rebuild")).unwrap();
        assert_eq!(*v1, 7);
        assert_eq!(*v2, 7);
        assert_eq!(cache.builds(), 1);
        assert_eq!(cache.hits(), 1);
    }

    #[test]
    fn failed_build_is_retryable_and_uncounted() {
        let cache: CompileCache<usize> = CompileCache::new();
        assert!(cache.get_or_build("k", || Err(anyhow::anyhow!("boom"))).is_err());
        assert_eq!(cache.builds(), 0);
        let v = cache.get_or_build("k", || Ok(3usize)).unwrap();
        assert_eq!(*v, 3);
        assert_eq!(cache.builds(), 1);
    }

    #[test]
    fn concurrent_same_key_builds_exactly_once() {
        let cache: CompileCache<u64> = CompileCache::new();
        let attempts = AtomicUsize::new(0);
        std::thread::scope(|s| {
            for _ in 0..8 {
                s.spawn(|| {
                    let v = cache
                        .get_or_build("shared", || {
                            attempts.fetch_add(1, Ordering::SeqCst);
                            // widen the race window
                            std::thread::sleep(std::time::Duration::from_millis(5));
                            Ok(42u64)
                        })
                        .unwrap();
                    assert_eq!(*v, 42);
                });
            }
        });
        assert_eq!(attempts.load(Ordering::SeqCst), 1, "builder raced");
        assert_eq!(cache.builds(), 1);
        assert_eq!(cache.hits(), 7);
    }

    #[test]
    fn contains_probes_without_counting_hits() {
        let cache: CompileCache<usize> = CompileCache::new();
        assert!(!cache.contains("k"));
        cache.get_or_build("k", || Ok(1usize)).unwrap();
        assert!(cache.contains("k"));
        assert!(cache.contains("k"));
        assert_eq!(cache.hits(), 0, "contains() must not count hits");
        assert_eq!(cache.builds(), 1);
    }

    #[test]
    fn eviction_under_contention_stays_consistent() {
        // Readers hammer get_or_build while an evictor repeatedly drops
        // the entry: every reader must still observe a valid value,
        // outstanding Arcs stay usable, and the counters must balance —
        // every lookup is exactly one build or one hit, with at least
        // one rebuild forced by the evictions.
        let cache: CompileCache<u64> = CompileCache::new();
        const READERS: usize = 4;
        const ROUNDS: usize = 200;
        std::thread::scope(|s| {
            for _ in 0..READERS {
                s.spawn(|| {
                    for _ in 0..ROUNDS {
                        let v = cache.get_or_build("hot", || Ok(7u64)).unwrap();
                        assert_eq!(*v, 7);
                    }
                });
            }
            s.spawn(|| {
                for _ in 0..ROUNDS / 4 {
                    cache.evict("hot");
                    std::thread::yield_now();
                }
            });
        });
        let (builds, hits) = (cache.builds(), cache.hits());
        assert_eq!(builds + hits, READERS * ROUNDS, "lookup neither built nor hit");
        assert!(builds >= 1, "never built");
        assert!(hits > 0, "never hit");
        // the survivor (if any) is still the same value
        if let Some(v) = cache.get("hot") {
            assert_eq!(*v, 7);
        }
    }

    #[test]
    fn distinct_keys_build_independently_and_evict() {
        let cache: CompileCache<usize> = CompileCache::new();
        for (i, k) in ["a", "b", "c"].iter().enumerate() {
            cache.get_or_build(k, || Ok(i)).unwrap();
        }
        assert_eq!(cache.builds(), 3);
        assert_eq!(cache.len(), 3);
        assert!(cache.evict("b"));
        assert!(!cache.evict("b"));
        assert_eq!(cache.len(), 2);
        // rebuilt after eviction
        cache.get_or_build("b", || Ok(9)).unwrap();
        assert_eq!(cache.builds(), 4);
    }
}
