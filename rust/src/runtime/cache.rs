//! Shared compiled-artifact cache (DESIGN.md §6; shape-specialized
//! keys in §9).
//!
//! Compiling an HLO artifact is the most expensive control-plane
//! operation in the coordinator (hundreds of ms per graph), so every
//! executable is built exactly once per process and shared from then
//! on. The cache is keyed by [`ArtifactKey`] — *(artifact variant,
//! batch shape)* — rather than by file path:
//!
//! * family members that share a graph (the masked `fwd` artifact is
//!   identical for every pruned variant of one model — masks are
//!   runtime inputs) collapse to ONE key and therefore one compile,
//!   no matter how many variants the family coordinator serves;
//! * shape-specialized exports (one materialized graph per variant,
//!   table 8 / production serving) get distinct keys per variant and
//!   batch shape, so they coexist without eviction fights. The family
//!   coordinator's per-(member, bucket) executables (DESIGN.md §9)
//!   live behind exactly these keys: the member tag goes into the
//!   artifact id, the bucket into `batch`/`seq`, so "builds == distinct
//!   (member, bucket) pairs exercised" is the cache-counter invariant
//!   the coordinator tests assert.
//!
//! Concurrency follows PR 1's per-artifact compile gate: a per-key
//! mutex makes check-then-compile atomic, so racing callers (the
//! parallel database builds, multiple family queues) serialize per
//! key while distinct keys still compile in parallel. Build and hit
//! counters are exposed for the coordinator's serving stats and for
//! the "each artifact compiled at most once across the family"
//! acceptance test.

use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, MutexGuard, PoisonError};

use anyhow::Result;

/// Lock a cache-internal mutex, recovering from poisoning. A builder
/// closure that panics unwinds *between* map operations (HashMap
/// lookups/inserts are not left half-applied), so the data under a
/// poisoned lock is still consistent — and the fleet supervisor
/// (DESIGN.md §10) requires that one crashed worker can never wedge
/// the shard a sibling or its own restarted incarnation still probes.
fn relock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// Cache key for a compiled executable: which graph, at which shape.
///
/// `artifact` identifies the model variant's graph (for masked graphs
/// that is one shared id per (model, task); for specialized exports it
/// embeds the variant tag). `batch`/`seq` record the static input
/// shape the graph was lowered at; use 0 when the dimension is baked
/// into the artifact id.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct ArtifactKey {
    /// artifact id (manifest name or specialized-export name)
    pub artifact: String,
    /// static batch dimension of the lowered graph (0 = unspecified)
    pub batch: usize,
    /// static sequence length of the lowered graph (0 = unspecified)
    pub seq: usize,
}

impl ArtifactKey {
    /// Build a key from its parts.
    pub fn new(artifact: impl Into<String>, batch: usize, seq: usize) -> ArtifactKey {
        ArtifactKey { artifact: artifact.into(), batch, seq }
    }

    /// Canonical string form used as the cache map key. Injective:
    /// the shape suffix after the final `@` is all digits, so two
    /// distinct `(artifact, batch, seq)` triples can never encode to
    /// one string even when the artifact id itself contains `@b…s…`
    /// (property-tested in `tests/proptests.rs`).
    pub fn encode(&self) -> String {
        format!("{}@b{}s{}", self.artifact, self.batch, self.seq)
    }
}

/// A build-once map from [`ArtifactKey`] strings to shared values.
///
/// `get_or_build` is the only write path: the first caller for a key
/// runs the builder under that key's gate while other keys proceed
/// concurrently; every later caller gets the cached `Arc`. Builder
/// errors are propagated and nothing is cached, so a failed compile
/// can be retried.
pub struct CompileCache<V> {
    entries: Mutex<HashMap<String, Arc<V>>>,
    /// Per-key compile gates (PR 1): serialize per name so a value is
    /// built exactly once while different keys build in parallel. The
    /// map only grows, bounded by the number of distinct keys.
    inflight: Mutex<HashMap<String, Arc<Mutex<()>>>>,
    builds: AtomicUsize,
    hits: AtomicUsize,
}

impl<V> CompileCache<V> {
    /// Empty cache with zeroed counters.
    pub fn new() -> CompileCache<V> {
        CompileCache {
            entries: Mutex::new(HashMap::new()),
            inflight: Mutex::new(HashMap::new()),
            builds: AtomicUsize::new(0),
            hits: AtomicUsize::new(0),
        }
    }

    /// Fetch the value for `key`, building it (exactly once per key,
    /// across threads) if absent.
    pub fn get_or_build<F>(&self, key: &str, build: F) -> Result<Arc<V>>
    where
        F: FnOnce() -> Result<V>,
    {
        if let Some(v) = relock(&self.entries).get(key) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return Ok(Arc::clone(v));
        }
        let gate = {
            let mut inflight = relock(&self.inflight);
            Arc::clone(inflight.entry(key.to_string()).or_default())
        };
        let _building = relock(&gate);
        // re-check under the gate: a racing caller may have finished
        if let Some(v) = relock(&self.entries).get(key) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return Ok(Arc::clone(v));
        }
        let v = Arc::new(build()?);
        self.builds.fetch_add(1, Ordering::Relaxed);
        relock(&self.entries).insert(key.to_string(), Arc::clone(&v));
        Ok(v)
    }

    /// Cached value for `key`, if present (counts as a hit).
    pub fn get(&self, key: &str) -> Option<Arc<V>> {
        let v = relock(&self.entries).get(key).map(Arc::clone);
        if v.is_some() {
            self.hits.fetch_add(1, Ordering::Relaxed);
        }
        v
    }

    /// Whether `key` is cached, WITHOUT counting a hit. The family
    /// coordinator uses this to decide between serving a batch on an
    /// already-specialized executable and falling back to the generic
    /// one while the specialization is still cold (DESIGN.md §9), so
    /// probing must not distort the build/hit counters the serving
    /// stats report.
    pub fn contains(&self, key: &str) -> bool {
        relock(&self.entries).contains_key(key)
    }

    /// Drop a cached value (memory control for block sweeps). Returns
    /// whether an entry was removed. Outstanding `Arc`s stay valid.
    pub fn evict(&self, key: &str) -> bool {
        relock(&self.entries).remove(key).is_some()
    }

    /// Number of cached values.
    pub fn len(&self) -> usize {
        relock(&self.entries).len()
    }

    /// Whether the cache holds no values.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// How many times a builder ran to completion.
    pub fn builds(&self) -> usize {
        self.builds.load(Ordering::Relaxed)
    }

    /// How many lookups were served from cache.
    pub fn hits(&self) -> usize {
        self.hits.load(Ordering::Relaxed)
    }
}

impl<V> Default for CompileCache<V> {
    fn default() -> Self {
        CompileCache::new()
    }
}

/// Per-worker [`CompileCache`] shards for the serving fleet
/// (DESIGN.md §10).
///
/// Each fleet worker owns one shard: the executables a (simulated)
/// device process compiled live and die with that process, so a worker
/// crash retires its shard wholesale — [`CacheShards::replace`] swaps
/// in a fresh one for the restarted incarnation and returns the
/// retired shard for post-mortem counter inspection. Shards are
/// handed to workers as `Arc`s; the supervisor keeps this registry so
/// fleet-wide build/hit totals stay one call away.
///
/// The per-incarnation invariant the fleet tests assert lives here:
/// a fresh shard's `builds()` equals the number of distinct
/// (member, bucket) pairs the restarted worker re-serves, because
/// demand re-warming compiles each pair exactly once.
pub struct CacheShards<V> {
    shards: Vec<Arc<CompileCache<V>>>,
}

impl<V> CacheShards<V> {
    /// `n` empty shards (at least one).
    pub fn new(n: usize) -> CacheShards<V> {
        CacheShards { shards: (0..n.max(1)).map(|_| Arc::new(CompileCache::new())).collect() }
    }

    /// Number of shards.
    pub fn len(&self) -> usize {
        self.shards.len()
    }

    /// Always false: `new` guarantees ≥ 1 shard.
    pub fn is_empty(&self) -> bool {
        self.shards.is_empty()
    }

    /// Shard `i` (indices wrap, so a worker id is always a valid
    /// shard id and lookup can never panic).
    pub fn shard(&self, i: usize) -> Arc<CompileCache<V>> {
        Arc::clone(&self.shards[i % self.shards.len()])
    }

    /// Retire shard `i` (crashed worker) and install a fresh, empty
    /// one for the next incarnation. Returns the retired shard;
    /// outstanding `Arc`s into it stay valid but no new work lands
    /// there.
    pub fn replace(&mut self, i: usize) -> Arc<CompileCache<V>> {
        let n = self.shards.len();
        std::mem::replace(&mut self.shards[i % n], Arc::new(CompileCache::new()))
    }

    /// Fleet-wide builder completions (sum over live shards).
    pub fn builds(&self) -> usize {
        self.shards.iter().map(|s| s.builds()).sum()
    }

    /// Fleet-wide cache hits (sum over live shards).
    pub fn hits(&self) -> usize {
        self.shards.iter().map(|s| s.hits()).sum()
    }
}

#[cfg(test)]
#[allow(clippy::disallowed_methods)]
mod tests {
    use super::*;

    #[test]
    fn key_encoding_distinguishes_shape_and_variant() {
        let a = ArtifactKey::new("m__t__fwd", 8, 128);
        let b = ArtifactKey::new("m__t__fwd", 16, 128);
        let c = ArtifactKey::new("spec_m_t_2x", 8, 128);
        assert_ne!(a.encode(), b.encode());
        assert_ne!(a.encode(), c.encode());
        assert_eq!(a.encode(), ArtifactKey::new("m__t__fwd", 8, 128).encode());
    }

    #[test]
    fn builds_once_then_hits() {
        let cache: CompileCache<usize> = CompileCache::new();
        let k = ArtifactKey::new("art", 4, 16).encode();
        let v1 = cache.get_or_build(&k, || Ok(7usize)).unwrap();
        let v2 = cache.get_or_build(&k, || panic!("must not rebuild")).unwrap();
        assert_eq!(*v1, 7);
        assert_eq!(*v2, 7);
        assert_eq!(cache.builds(), 1);
        assert_eq!(cache.hits(), 1);
    }

    #[test]
    fn failed_build_is_retryable_and_uncounted() {
        let cache: CompileCache<usize> = CompileCache::new();
        assert!(cache.get_or_build("k", || Err(anyhow::anyhow!("boom"))).is_err());
        assert_eq!(cache.builds(), 0);
        let v = cache.get_or_build("k", || Ok(3usize)).unwrap();
        assert_eq!(*v, 3);
        assert_eq!(cache.builds(), 1);
    }

    #[test]
    fn concurrent_same_key_builds_exactly_once() {
        let cache: CompileCache<u64> = CompileCache::new();
        let attempts = AtomicUsize::new(0);
        std::thread::scope(|s| {
            for _ in 0..8 {
                s.spawn(|| {
                    let v = cache
                        .get_or_build("shared", || {
                            attempts.fetch_add(1, Ordering::SeqCst);
                            // widen the race window
                            std::thread::sleep(std::time::Duration::from_millis(5));
                            Ok(42u64)
                        })
                        .unwrap();
                    assert_eq!(*v, 42);
                });
            }
        });
        assert_eq!(attempts.load(Ordering::SeqCst), 1, "builder raced");
        assert_eq!(cache.builds(), 1);
        assert_eq!(cache.hits(), 7);
    }

    #[test]
    fn contains_probes_without_counting_hits() {
        let cache: CompileCache<usize> = CompileCache::new();
        assert!(!cache.contains("k"));
        cache.get_or_build("k", || Ok(1usize)).unwrap();
        assert!(cache.contains("k"));
        assert!(cache.contains("k"));
        assert_eq!(cache.hits(), 0, "contains() must not count hits");
        assert_eq!(cache.builds(), 1);
    }

    #[test]
    fn eviction_under_contention_stays_consistent() {
        // Readers hammer get_or_build while an evictor repeatedly drops
        // the entry: every reader must still observe a valid value,
        // outstanding Arcs stay usable, and the counters must balance —
        // every lookup is exactly one build or one hit, with at least
        // one rebuild forced by the evictions.
        let cache: CompileCache<u64> = CompileCache::new();
        const READERS: usize = 4;
        const ROUNDS: usize = 200;
        std::thread::scope(|s| {
            for _ in 0..READERS {
                s.spawn(|| {
                    for _ in 0..ROUNDS {
                        let v = cache.get_or_build("hot", || Ok(7u64)).unwrap();
                        assert_eq!(*v, 7);
                    }
                });
            }
            s.spawn(|| {
                for _ in 0..ROUNDS / 4 {
                    cache.evict("hot");
                    std::thread::yield_now();
                }
            });
        });
        let (builds, hits) = (cache.builds(), cache.hits());
        assert_eq!(builds + hits, READERS * ROUNDS, "lookup neither built nor hit");
        assert!(builds >= 1, "never built");
        assert!(hits > 0, "never hit");
        // the survivor (if any) is still the same value
        if let Some(v) = cache.get("hot") {
            assert_eq!(*v, 7);
        }
    }

    #[test]
    fn distinct_keys_build_independently_and_evict() {
        let cache: CompileCache<usize> = CompileCache::new();
        for (i, k) in ["a", "b", "c"].iter().enumerate() {
            cache.get_or_build(k, || Ok(i)).unwrap();
        }
        assert_eq!(cache.builds(), 3);
        assert_eq!(cache.len(), 3);
        assert!(cache.evict("b"));
        assert!(!cache.evict("b"));
        assert_eq!(cache.len(), 2);
        // rebuilt after eviction
        cache.get_or_build("b", || Ok(9)).unwrap();
        assert_eq!(cache.builds(), 4);
    }

    #[test]
    fn panicking_builder_does_not_poison_the_cache() {
        // A worker that dies mid-compile must not wedge the shard for
        // siblings or its restarted incarnation (DESIGN.md §10): the
        // next caller recovers the lock and builds normally.
        let cache = Arc::new(CompileCache::<u32>::new());
        let c2 = Arc::clone(&cache);
        let died = std::thread::spawn(move || {
            let _ = c2.get_or_build("k", || -> Result<u32> { panic!("compile crashed") });
        })
        .join();
        assert!(died.is_err(), "builder panic must surface in its own thread");
        let v = cache.get_or_build("k", || Ok(5)).unwrap();
        assert_eq!(*v, 5);
        assert_eq!(cache.builds(), 1);
        assert!(cache.contains("k"));
    }

    // ---- fleet-shard coverage (ISSUE 6 satellite): CompileCache under
    // injected compile failures, contention across shards, and seeded
    // FaultPlan replay

    use crate::runtime::fault::{FaultPlan, FaultRates};

    fn faulty_rates() -> FaultRates {
        FaultRates { compile_fail: 0.5, ..Default::default() }
    }

    /// Outcome alphabet for the replay test: what one scripted cache
    /// query did, including the per-pair quarantine the coordinator
    /// escalates from (PR 5 → DESIGN.md §10).
    #[derive(Debug, PartialEq, Eq, Clone)]
    enum Seen {
        Built,
        Hit,
        Failed,
        Quarantined,
    }

    /// Drive one shard through a scripted key sequence, compile
    /// failures injected from a [`FaultPlan`] stream; failed keys are
    /// quarantined exactly like the coordinator quarantines a
    /// (member, bucket) pair.
    fn drive(shard: &CompileCache<u8>, plan: &FaultPlan, keys: &[&str]) -> Vec<Seen> {
        let mut stream = plan.stream(0, 0);
        let mut quarantined: std::collections::HashSet<String> = Default::default();
        let mut out = Vec::new();
        for &k in keys {
            if quarantined.contains(k) {
                out.push(Seen::Quarantined);
                continue;
            }
            let cold = !shard.contains(k);
            let fail = cold && stream.compile_fault();
            let r = shard.get_or_build(k, || {
                if fail {
                    Err(anyhow::anyhow!("injected compile failure"))
                } else {
                    Ok(1u8)
                }
            });
            out.push(match (r.is_ok(), cold) {
                (true, true) => Seen::Built,
                (true, false) => Seen::Hit,
                (false, _) => {
                    quarantined.insert(k.to_string());
                    Seen::Failed
                }
            });
        }
        out
    }

    #[test]
    fn seeded_fault_replay_is_bit_identical() {
        // Same seed, same key script → the exact same outcome sequence,
        // run after run: the property that makes chaos runs debuggable.
        let keys =
            ["a@b1s32", "b@b1s32", "a@b1s32", "c@b8s128", "b@b1s32", "c@b8s128", "d@b4s64"];
        let plan = FaultPlan::seeded(0xFA17, faulty_rates());
        let first = drive(&CompileCache::new(), &plan, &keys);
        for _ in 0..3 {
            assert_eq!(drive(&CompileCache::new(), &plan, &keys), first);
        }
        // a different seed genuinely reschedules the failures
        let other = drive(&CompileCache::new(), &FaultPlan::seeded(0x5EED, faulty_rates()), &keys);
        assert_eq!(other.len(), first.len());
        // and a failed pair is never retried once quarantined
        for seq in [&first, &other] {
            let mut dead = false;
            for (s, k) in seq.iter().zip(keys) {
                if k == "a@b1s32" {
                    match s {
                        Seen::Failed => dead = true,
                        Seen::Quarantined => assert!(dead),
                        _ => assert!(!dead),
                    }
                }
            }
        }
    }

    #[test]
    fn quarantine_reprobe_after_replace_rebuilds() {
        // Per-pair quarantine is per-incarnation: replacing a crashed
        // worker's shard clears it, and the re-probe on the fresh shard
        // (no injected failure this time) builds exactly once.
        let mut shards: CacheShards<u8> = CacheShards::new(2);
        let plan = FaultPlan::seeded(3, FaultRates { compile_fail: 1.0, ..Default::default() });
        let seq = drive(&shards.shard(1), &plan, &["x@b1s32", "x@b1s32"]);
        assert_eq!(seq, vec![Seen::Failed, Seen::Quarantined]);
        assert_eq!(shards.shard(1).builds(), 0);
        let retired = shards.replace(1);
        assert_eq!(retired.builds(), 0);
        // fresh incarnation, fault-free probe: builds == distinct pairs re-served
        let seq2 = drive(&shards.shard(1), &FaultPlan::none(), &["x@b1s32", "x@b1s32"]);
        assert_eq!(seq2, vec![Seen::Built, Seen::Hit]);
        assert_eq!(shards.shard(1).builds(), 1);
        // the sibling shard never saw any of this
        assert_eq!(shards.shard(0).builds() + shards.shard(0).hits(), 0);
    }

    #[test]
    fn shards_isolate_eviction_contention() {
        // Readers hammer their own shard while an evictor attacks shard
        // 0 only: shard 1's counters stay perfectly build-once while
        // shard 0 absorbs the rebuilds — contention cannot leak across
        // the shard boundary.
        let shards: Arc<CacheShards<u64>> = Arc::new(CacheShards::new(2));
        const ROUNDS: usize = 200;
        std::thread::scope(|s| {
            for w in 0..2usize {
                let shards = Arc::clone(&shards);
                s.spawn(move || {
                    let shard = shards.shard(w);
                    for _ in 0..ROUNDS {
                        let v = shard.get_or_build("hot", || Ok(w as u64)).unwrap();
                        assert_eq!(*v, w as u64, "value leaked across shards");
                    }
                });
            }
            let shards = Arc::clone(&shards);
            s.spawn(move || {
                for _ in 0..ROUNDS / 4 {
                    shards.shard(0).evict("hot");
                    std::thread::yield_now();
                }
            });
        });
        let (s0, s1) = (shards.shard(0), shards.shard(1));
        assert_eq!(s1.builds(), 1, "uncontended shard must build exactly once");
        assert_eq!(s1.hits(), ROUNDS - 1);
        assert!(s0.builds() >= 1);
        assert_eq!(s0.builds() + s0.hits(), ROUNDS);
        assert_eq!(shards.builds(), s0.builds() + s1.builds());
        assert_eq!(shards.hits(), s0.hits() + s1.hits());
    }
}
