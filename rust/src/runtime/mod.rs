//! PJRT runtime: loads AOT artifacts (HLO text) and executes them.
//!
//! This is the ONLY bridge between the Rust coordinator and the
//! compiled L1/L2 graphs. The flow (see /opt/xla-example):
//!
//! ```text
//! artifacts/<name>.hlo.txt --HloModuleProto::from_text_file-->
//!   XlaComputation --PjRtClient::compile--> PjRtLoadedExecutable
//!   --execute(&[Literal])--> tuple Literal --decompose--> outputs
//! ```
//!
//! Executables are compiled once and cached; Python never runs here.

pub mod cache;
pub mod fault;
pub mod manifest;

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use anyhow::{anyhow, Context, Result};

pub use cache::{ArtifactKey, CacheShards, CompileCache};
pub use fault::{ExecFault, FaultPlan, FaultRates, FaultStream};
pub use manifest::{ArtifactInfo, Manifest, ModelInfo, TaskInfo};

/// A host-side tensor paired with its logical shape (row-major f32).
#[derive(Clone, Debug)]
pub struct HostF32 {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl HostF32 {
    pub fn new(shape: &[usize], data: Vec<f32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        HostF32 { shape: shape.to_vec(), data }
    }

    pub fn zeros(shape: &[usize]) -> Self {
        HostF32 { shape: shape.to_vec(), data: vec![0.0; shape.iter().product()] }
    }

    pub fn scalar(v: f32) -> Self {
        HostF32 { shape: vec![], data: vec![v] }
    }
}

/// Literal constructors.
pub fn lit_f32(t: &HostF32) -> Result<xla::Literal> {
    let v = xla::Literal::vec1(&t.data);
    let dims: Vec<i64> = t.shape.iter().map(|&d| d as i64).collect();
    Ok(v.reshape(&dims)?)
}

pub fn lit_f32_shaped(shape: &[usize], data: &[f32]) -> Result<xla::Literal> {
    assert_eq!(shape.iter().product::<usize>(), data.len());
    let v = xla::Literal::vec1(data);
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    Ok(v.reshape(&dims)?)
}

pub fn lit_i32(shape: &[usize], data: &[i32]) -> Result<xla::Literal> {
    assert_eq!(shape.iter().product::<usize>(), data.len());
    let v = xla::Literal::vec1(data);
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    Ok(v.reshape(&dims)?)
}

pub fn lit_scalar_f32(v: f32) -> Result<xla::Literal> {
    Ok(xla::Literal::vec1(&[v]).reshape(&[])?)
}

pub fn lit_scalar_i32(v: i32) -> Result<xla::Literal> {
    Ok(xla::Literal::vec1(&[v]).reshape(&[])?)
}

pub fn lit_to_f32(l: &xla::Literal) -> Result<Vec<f32>> {
    Ok(l.to_vec::<f32>()?)
}

pub fn lit_to_i32(l: &xla::Literal) -> Result<Vec<i32>> {
    Ok(l.to_vec::<i32>()?)
}

/// The PJRT engine: one CPU client + a shared compiled-executable
/// cache keyed by (artifact variant, batch shape).
pub struct Engine {
    client: xla::PjRtClient,
    art_dir: PathBuf,
    /// Typed view of `artifacts/manifest.json` — the single source of
    /// truth for model configs, layouts, and artifact I/O signatures.
    pub manifest: Manifest,
    /// Build-once executable cache (see [`cache`]): family members
    /// that share a masked graph dedupe to one compile; per-key gates
    /// keep concurrent database builds from compiling twice.
    exe_cache: CompileCache<xla::PjRtLoadedExecutable>,
    compile_count: AtomicUsize,
}

impl Engine {
    /// Open the artifact directory (expects manifest.json inside).
    pub fn open(art_dir: &Path) -> Result<Engine> {
        let man_path = art_dir.join("manifest.json");
        let text = std::fs::read_to_string(&man_path)
            .with_context(|| format!("reading {man_path:?} — run `make artifacts` first"))?;
        let manifest = Manifest::parse(&text).map_err(|e| anyhow!("manifest: {e}"))?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Engine {
            client,
            art_dir: art_dir.to_path_buf(),
            manifest,
            exe_cache: CompileCache::new(),
            compile_count: AtomicUsize::new(0),
        })
    }

    /// Default artifact dir: $ZIPLM_ARTIFACTS or ./artifacts.
    pub fn open_default() -> Result<Engine> {
        let dir = std::env::var("ZIPLM_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
        Engine::open(Path::new(&dir))
    }

    pub fn client(&self) -> &xla::PjRtClient {
        &self.client
    }

    pub fn art_dir(&self) -> &Path {
        &self.art_dir
    }

    /// Cache key for a manifest artifact: the recorded batch/seq of
    /// the lowered graph (0 when the manifest does not record them —
    /// the shape is then baked into the artifact id alone).
    fn manifest_key(&self, name: &str) -> ArtifactKey {
        let (b, s) = self
            .manifest
            .artifacts
            .get(name)
            .map(|a| (a.batch.unwrap_or(0), a.seq.unwrap_or(0)))
            .unwrap_or((0, 0));
        ArtifactKey::new(name, b, s)
    }

    /// Compile-or-fetch an executable by artifact name. Thread-safe:
    /// the cache's per-key gate makes check-then-compile atomic, so
    /// concurrent module builds never compile the same artifact twice.
    pub fn executable(&self, name: &str) -> Result<Arc<xla::PjRtLoadedExecutable>> {
        self.executable_keyed(&self.manifest_key(name))
    }

    /// Compile-or-fetch by explicit [`ArtifactKey`] (variant + batch
    /// shape). Family members whose keys coincide — every masked
    /// variant of one (model, task) shares the same `fwd` graph —
    /// resolve to a single compiled executable.
    pub fn executable_keyed(&self, key: &ArtifactKey) -> Result<Arc<xla::PjRtLoadedExecutable>> {
        self.exe_cache.get_or_build(&key.encode(), || {
            let info = self
                .manifest
                .artifacts
                .get(&key.artifact)
                .ok_or_else(|| anyhow!("unknown artifact `{}`", key.artifact))?;
            self.compile_file(&self.art_dir.join(&info.file))
        })
    }

    /// Compile-or-fetch a shape-specialized export that lives OUTSIDE
    /// the manifest: `key` carries the member tag + bucket shape
    /// (DESIGN.md §9), `path` the materialized HLO file that
    /// `aot.py --specialize` wrote for exactly that shape. A missing
    /// file is an error and caches nothing, so the family coordinator
    /// can fall back to the generic executable and retry once the
    /// export appears.
    pub fn executable_file_keyed(
        &self,
        key: &ArtifactKey,
        path: &Path,
    ) -> Result<Arc<xla::PjRtLoadedExecutable>> {
        self.exe_cache.get_or_build(&key.encode(), || {
            if !path.exists() {
                return Err(anyhow!("no specialized export at {path:?} for `{}`", key.encode()));
            }
            self.compile_file(path)
        })
    }

    /// Whether the executable for `key` is already compiled and cached
    /// (no hit is counted — see [`CompileCache::contains`]).
    pub fn cached_keyed(&self, key: &ArtifactKey) -> bool {
        self.exe_cache.contains(&key.encode())
    }

    /// Compile an HLO-text file outside the manifest (specialized exports).
    pub fn compile_file(&self, path: &Path) -> Result<xla::PjRtLoadedExecutable> {
        let proto = xla::HloModuleProto::from_text_file(path)
            .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.compile_count.fetch_add(1, Ordering::Relaxed);
        Ok(self.client.compile(&comp)?)
    }

    /// Execute an artifact with literal inputs; returns the flattened
    /// tuple outputs (aot.py lowers with return_tuple=True).
    pub fn run(&self, name: &str, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let exe = self.executable(name)?;
        Self::run_exe(&exe, inputs)
    }

    pub fn run_exe(
        exe: &xla::PjRtLoadedExecutable,
        inputs: &[xla::Literal],
    ) -> Result<Vec<xla::Literal>> {
        let out = exe.execute::<xla::Literal>(inputs)?;
        let lit = out[0][0].to_literal_sync()?;
        Ok(lit.to_tuple()?)
    }

    /// Number of PJRT compilations so far (perf accounting).
    pub fn compiles(&self) -> usize {
        self.compile_count.load(Ordering::Relaxed)
    }

    /// Executable-cache counters `(builds, hits)` — the family
    /// coordinator reports these in its serving stats.
    pub fn cache_stats(&self) -> (usize, usize) {
        (self.exe_cache.builds(), self.exe_cache.hits())
    }

    /// Drop a cached executable (memory control for block sweeps).
    pub fn evict(&self, name: &str) {
        self.exe_cache.evict(&self.manifest_key(name).encode());
    }
}
