//! Typed view of artifacts/manifest.json (written by python/compile/aot.py).
//!
//! The manifest is the single source of truth for shapes: model configs,
//! packed-parameter layouts, ladders, and per-artifact I/O signatures.
//! Nothing about shapes is hard-coded on the Rust side.

use std::collections::BTreeMap;

use crate::util::json::Json;

#[derive(Clone, Debug, PartialEq)]
pub struct SigEntry {
    pub shape: Vec<usize>,
    pub dtype: String, // "f32" | "i32"
}

#[derive(Clone, Debug)]
pub struct ArtifactInfo {
    pub file: String,
    pub inputs: Vec<SigEntry>,
    pub outputs: Vec<SigEntry>,
    /// block metadata (latency sweep artifacts only)
    pub kind: Option<String>,
    pub heads: Option<usize>,
    pub inter: Option<usize>,
    pub regime: Option<String>,
    pub batch: Option<usize>,
    pub seq: Option<usize>,
}

#[derive(Clone, Debug)]
pub struct LayoutEntry {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset: usize,
}

impl LayoutEntry {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

#[derive(Clone, Debug)]
pub struct TaskInfo {
    pub n_params: usize,
    pub kind: String, // "cls" | "span" | "lm"
    pub n_classes: usize,
    pub layout: Vec<LayoutEntry>,
}

impl TaskInfo {
    pub fn entry(&self, name: &str) -> Option<&LayoutEntry> {
        self.layout.iter().find(|e| e.name == name)
    }
}

#[derive(Clone, Debug)]
pub struct ModelInfo {
    pub name: String,
    pub n_layers: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub d_head: usize,
    pub d_ff: usize,
    pub vocab: usize,
    pub seq_len: usize,
    pub causal: bool,
    pub ffn_ladder: Vec<usize>,
    pub head_ladder: Vec<usize>,
    pub measured_ffn: Vec<usize>,
    pub tasks: BTreeMap<String, TaskInfo>,
}

impl ModelInfo {
    pub fn d_attn(&self) -> usize {
        self.n_heads * self.d_head
    }
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub batch_train: usize,
    pub batch_eval: usize,
    pub batch_calib: usize,
    pub models: BTreeMap<String, ModelInfo>,
    pub artifacts: BTreeMap<String, ArtifactInfo>,
}

fn sig(j: &Json) -> Vec<SigEntry> {
    j.as_arr()
        .unwrap_or(&[])
        .iter()
        .map(|e| SigEntry {
            shape: e.get("shape").map(|s| s.usize_array()).unwrap_or_default(),
            dtype: e.get("dtype").and_then(Json::as_str).unwrap_or("f32").to_string(),
        })
        .collect()
}

impl Manifest {
    pub fn parse(text: &str) -> Result<Manifest, String> {
        let j = Json::parse(text)?;
        let batch = j.get("batch").ok_or("missing batch")?;
        let mut models = BTreeMap::new();
        for (name, m) in j.get("models").and_then(Json::as_obj).ok_or("missing models")? {
            let mut tasks = BTreeMap::new();
            if let Some(ts) = m.get("tasks").and_then(Json::as_obj) {
                for (tname, t) in ts {
                    let layout = t
                        .get("layout")
                        .and_then(Json::as_arr)
                        .unwrap_or(&[])
                        .iter()
                        .map(|e| LayoutEntry {
                            name: e.req_str("name").to_string(),
                            shape: e.get("shape").map(|s| s.usize_array()).unwrap_or_default(),
                            offset: e.req_usize("offset"),
                        })
                        .collect();
                    tasks.insert(
                        tname.clone(),
                        TaskInfo {
                            n_params: t.req_usize("n_params"),
                            kind: t.req_str("kind").to_string(),
                            n_classes: t.get("n_classes").and_then(Json::as_usize).unwrap_or(0),
                            layout,
                        },
                    );
                }
            }
            models.insert(
                name.clone(),
                ModelInfo {
                    name: name.clone(),
                    n_layers: m.req_usize("n_layers"),
                    d_model: m.req_usize("d_model"),
                    n_heads: m.req_usize("n_heads"),
                    d_head: m.req_usize("d_head"),
                    d_ff: m.req_usize("d_ff"),
                    vocab: m.req_usize("vocab"),
                    seq_len: m.req_usize("seq_len"),
                    causal: m.get("causal").and_then(Json::as_bool).unwrap_or(false),
                    ffn_ladder: m.get("ffn_ladder").map(|v| v.usize_array()).unwrap_or_default(),
                    head_ladder: m.get("head_ladder").map(|v| v.usize_array()).unwrap_or_default(),
                    measured_ffn: m.get("measured_ffn").map(|v| v.usize_array()).unwrap_or_default(),
                    tasks,
                },
            );
        }
        let mut artifacts = BTreeMap::new();
        for (name, a) in j.get("artifacts").and_then(Json::as_obj).ok_or("missing artifacts")? {
            artifacts.insert(
                name.clone(),
                ArtifactInfo {
                    file: a.req_str("file").to_string(),
                    inputs: a.get("inputs").map(sig).unwrap_or_default(),
                    outputs: a.get("outputs").map(sig).unwrap_or_default(),
                    kind: a.get("kind").and_then(Json::as_str).map(String::from),
                    heads: a.get("heads").and_then(Json::as_usize),
                    inter: a.get("inter").and_then(Json::as_usize),
                    regime: a.get("regime").and_then(Json::as_str).map(String::from),
                    batch: a.get("batch").and_then(Json::as_usize),
                    seq: a.get("seq").and_then(Json::as_usize),
                },
            );
        }
        Ok(Manifest {
            batch_train: batch.req_usize("train"),
            batch_eval: batch.req_usize("eval"),
            batch_calib: batch.req_usize("calib"),
            models,
            artifacts,
        })
    }

    pub fn model(&self, name: &str) -> &ModelInfo {
        self.models.get(name).unwrap_or_else(|| panic!("unknown model `{name}`"))
    }

    pub fn task(&self, model: &str, task: &str) -> &TaskInfo {
        self.model(model)
            .tasks
            .get(task)
            .unwrap_or_else(|| panic!("unknown task `{task}` for model `{model}`"))
    }
}

#[cfg(test)]
#[allow(clippy::disallowed_methods)]
mod tests {
    use super::*;

    const MINI: &str = r#"{
      "batch": {"train": 16, "eval": 32, "calib": 16},
      "models": {
        "m": {"n_layers": 2, "d_model": 8, "n_heads": 2, "d_head": 4,
               "d_ff": 16, "vocab": 32, "seq_len": 4, "causal": false,
               "ffn_ladder": [16, 8, 0], "head_ladder": [2, 1, 0],
               "measured_ffn": [16, 8],
               "tasks": {"t": {"n_params": 10, "kind": "cls", "n_classes": 2,
                 "layout": [{"name": "w", "shape": [2, 5], "offset": 0}]}}}
      },
      "artifacts": {
        "m__t__fwd": {"file": "f.hlo.txt",
          "inputs": [{"shape": [10], "dtype": "f32"}],
          "outputs": [{"shape": [32, 2], "dtype": "f32"}]}
      }
    }"#;

    #[test]
    fn parses_mini() {
        let m = Manifest::parse(MINI).unwrap();
        assert_eq!(m.batch_train, 16);
        let mi = m.model("m");
        assert_eq!(mi.d_attn(), 8);
        let t = m.task("m", "t");
        assert_eq!(t.layout[0].numel(), 10);
        let a = &m.artifacts["m__t__fwd"];
        assert_eq!(a.inputs[0].shape, vec![10]);
        assert_eq!(a.outputs[0].dtype, "f32");
    }

    #[test]
    fn parses_real_manifest_if_present() {
        let p = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/manifest.json");
        if let Ok(txt) = std::fs::read_to_string(p) {
            let m = Manifest::parse(&txt).unwrap();
            assert!(m.models.contains_key("bert-syn-base"));
            assert!(m.artifacts.contains_key("bert-syn-base__sst2-syn__train_step"));
            let t = m.task("bert-syn-base", "sst2-syn");
            // layout must be contiguous
            let mut cur = 0;
            for e in &t.layout {
                assert_eq!(e.offset, cur, "{}", e.name);
                cur += e.numel();
            }
            assert_eq!(cur, t.n_params);
        }
    }
}
