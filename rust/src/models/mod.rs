//! Model state on the coordinator side: packed parameters, structural
//! masks, initialization, checkpoints, and the masked↔materialized
//! weight plumbing the pruner needs.
//!
//! Shapes all come from the manifest (runtime/manifest.rs); this module
//! never hard-codes a layout. The [`family`] submodule records whole
//! SPDY-produced model families (checkpoint + certified speedup per
//! member) for the family-serving coordinator.

pub mod family;

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{anyhow, Context, Result};

use crate::runtime::{ModelInfo, TaskInfo};
use crate::tensor::Tensor;
use crate::util::json::Json;
use crate::util::rng::Rng;

/// Structural masks: 1.0 = structure present. Row-major [L, H] / [L, F].
#[derive(Clone, Debug, PartialEq)]
pub struct Masks {
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub head: Vec<f32>, // [L * H]
    pub ffn: Vec<f32>,  // [L * F]
}

impl Masks {
    pub fn dense(info: &ModelInfo) -> Masks {
        Masks {
            n_layers: info.n_layers,
            n_heads: info.n_heads,
            d_ff: info.d_ff,
            head: vec![1.0; info.n_layers * info.n_heads],
            ffn: vec![1.0; info.n_layers * info.d_ff],
        }
    }

    pub fn heads_alive(&self, layer: usize) -> usize {
        self.head[layer * self.n_heads..(layer + 1) * self.n_heads]
            .iter()
            .filter(|&&m| m > 0.0)
            .count()
    }

    pub fn ffn_alive(&self, layer: usize) -> usize {
        self.ffn[layer * self.d_ff..(layer + 1) * self.d_ff]
            .iter()
            .filter(|&&m| m > 0.0)
            .count()
    }

    pub fn head_row(&self, layer: usize) -> &[f32] {
        &self.head[layer * self.n_heads..(layer + 1) * self.n_heads]
    }

    pub fn ffn_row(&self, layer: usize) -> &[f32] {
        &self.ffn[layer * self.d_ff..(layer + 1) * self.d_ff]
    }

    pub fn kill_head(&mut self, layer: usize, h: usize) {
        self.head[layer * self.n_heads + h] = 0.0;
    }

    pub fn kill_ffn_col(&mut self, layer: usize, c: usize) {
        self.ffn[layer * self.d_ff + c] = 0.0;
    }

    /// Remaining-structure summary per layer: (heads, ffn cols).
    pub fn summary(&self) -> Vec<(usize, usize)> {
        (0..self.n_layers).map(|l| (self.heads_alive(l), self.ffn_alive(l))).collect()
    }

    /// Fraction of prunable encoder weight remaining.
    pub fn density(&self) -> f64 {
        let h: f64 =
            self.head.iter().map(|&x| x as f64).sum::<f64>() / self.head.len() as f64;
        let f: f64 = self.ffn.iter().map(|&x| x as f64).sum::<f64>() / self.ffn.len() as f64;
        0.5 * h + 0.5 * f
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("n_layers", Json::Num(self.n_layers as f64)),
            ("n_heads", Json::Num(self.n_heads as f64)),
            ("d_ff", Json::Num(self.d_ff as f64)),
            ("head", Json::Arr(self.head.iter().map(|&x| Json::Num(x as f64)).collect())),
            ("ffn", Json::Arr(self.ffn.iter().map(|&x| Json::Num(x as f64)).collect())),
        ])
    }

    pub fn from_json(j: &Json) -> Result<Masks> {
        let getf = |k: &str| -> Vec<f32> {
            j.get(k)
                .and_then(Json::as_arr)
                .map(|a| a.iter().filter_map(Json::as_f64).map(|x| x as f32).collect())
                .unwrap_or_default()
        };
        Ok(Masks {
            n_layers: j.req_usize("n_layers"),
            n_heads: j.req_usize("n_heads"),
            d_ff: j.req_usize("d_ff"),
            head: getf("head"),
            ffn: getf("ffn"),
        })
    }
}

/// Full coordinator-side model state.
#[derive(Clone, Debug)]
pub struct ModelState {
    pub model: String,
    pub task: String,
    pub params: Vec<f32>,
    pub masks: Masks,
}

impl ModelState {
    /// BERT-style init: N(0, 0.02) weights, zero biases, unit LN gains.
    pub fn init(info: &ModelInfo, task_name: &str, tinfo: &TaskInfo, seed: u64) -> ModelState {
        let mut rng = Rng::new(seed);
        let mut params = vec![0f32; tinfo.n_params];
        for e in &tinfo.layout {
            let slice = &mut params[e.offset..e.offset + e.numel()];
            let base = e.name.rsplit('.').next().unwrap_or(&e.name);
            if base.ends_with("_g") {
                slice.fill(1.0);
            } else if base.starts_with('b') || base.ends_with("_b") {
                slice.fill(0.0);
            } else {
                for x in slice.iter_mut() {
                    *x = rng.normal_f32(0.02);
                }
            }
        }
        ModelState {
            model: info.name.clone(),
            task: task_name.to_string(),
            params,
            masks: Masks::dense(info),
        }
    }

    /// View a layout entry as a 2-D tensor (copies).
    pub fn get2(&self, tinfo: &TaskInfo, name: &str) -> Result<Tensor> {
        let e = tinfo.entry(name).ok_or_else(|| anyhow!("no param `{name}`"))?;
        if e.shape.len() != 2 {
            return Err(anyhow!("`{name}` is not 2-D"));
        }
        Ok(Tensor::from_vec(&e.shape, self.params[e.offset..e.offset + e.numel()].to_vec()))
    }

    pub fn get1(&self, tinfo: &TaskInfo, name: &str) -> Result<Vec<f32>> {
        let e = tinfo.entry(name).ok_or_else(|| anyhow!("no param `{name}`"))?;
        Ok(self.params[e.offset..e.offset + e.numel()].to_vec())
    }

    pub fn set_flat(&mut self, tinfo: &TaskInfo, name: &str, data: &[f32]) -> Result<()> {
        let e = tinfo.entry(name).ok_or_else(|| anyhow!("no param `{name}`"))?;
        if data.len() != e.numel() {
            return Err(anyhow!("size mismatch for `{name}`"));
        }
        self.params[e.offset..e.offset + e.numel()].copy_from_slice(data);
        Ok(())
    }

    /// OBS orientation for the attention out-projection of `layer`:
    /// W_paper = wo^T with shape [d_model, d_attn] (structures = head
    /// column groups). Returns a copy.
    pub fn attn_w_paper(&self, tinfo: &TaskInfo, layer: usize) -> Result<Tensor> {
        Ok(self.get2(tinfo, &format!("layer{layer}.wo"))?.transpose2())
    }

    /// Write back an updated W_paper for the attention out-projection,
    /// zeroing q/k/v columns + biases of pruned heads for hygiene.
    pub fn set_attn_w_paper(
        &mut self,
        tinfo: &TaskInfo,
        layer: usize,
        w_paper: &Tensor,
        dead_heads: &[usize],
        d_head: usize,
    ) -> Result<()> {
        let wo = w_paper.transpose2();
        self.set_flat(tinfo, &format!("layer{layer}.wo"), &wo.data)?;
        for name in ["wq", "wk", "wv"] {
            let full = format!("layer{layer}.{name}");
            let mut t = self.get2(tinfo, &full)?;
            let cols = t.cols();
            for &h in dead_heads {
                for r in 0..t.rows() {
                    for c in h * d_head..(h + 1) * d_head {
                        t.data[r * cols + c] = 0.0;
                    }
                }
            }
            self.set_flat(tinfo, &full, &t.data)?;
        }
        for name in ["bq", "bk", "bv"] {
            let full = format!("layer{layer}.{name}");
            let mut b = self.get1(tinfo, &full)?;
            for &h in dead_heads {
                for c in h * d_head..(h + 1) * d_head {
                    b[c] = 0.0;
                }
            }
            self.set_flat(tinfo, &full, &b)?;
        }
        Ok(())
    }

    /// OBS orientation for FC2 of `layer`: W_paper = w2^T, [d_model, d_ff].
    pub fn fc_w_paper(&self, tinfo: &TaskInfo, layer: usize) -> Result<Tensor> {
        Ok(self.get2(tinfo, &format!("layer{layer}.w2"))?.transpose2())
    }

    /// Write back FC2 and zero pruned intermediate columns in w1/b1.
    pub fn set_fc_w_paper(
        &mut self,
        tinfo: &TaskInfo,
        layer: usize,
        w_paper: &Tensor,
        dead_cols: &[usize],
    ) -> Result<()> {
        let w2 = w_paper.transpose2();
        self.set_flat(tinfo, &format!("layer{layer}.w2"), &w2.data)?;
        let full = format!("layer{layer}.w1");
        let mut w1 = self.get2(tinfo, &full)?;
        let cols = w1.cols();
        for &c in dead_cols {
            for r in 0..w1.rows() {
                w1.data[r * cols + c] = 0.0;
            }
        }
        self.set_flat(tinfo, &full, &w1.data)?;
        let bfull = format!("layer{layer}.b1");
        let mut b1 = self.get1(tinfo, &bfull)?;
        for &c in dead_cols {
            b1[c] = 0.0;
        }
        self.set_flat(tinfo, &bfull, &b1)?;
        Ok(())
    }

    // ------------------------------------------------------- checkpoints

    /// Binary checkpoint: magic, JSON header (model/task/masks), f32 LE params.
    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let header = Json::obj(vec![
            ("model", Json::Str(self.model.clone())),
            ("task", Json::Str(self.task.clone())),
            ("n_params", Json::Num(self.params.len() as f64)),
            ("masks", self.masks.to_json()),
        ])
        .to_string();
        let mut f = std::fs::File::create(path)?;
        f.write_all(b"ZLM1")?;
        f.write_all(&(header.len() as u64).to_le_bytes())?;
        f.write_all(header.as_bytes())?;
        let mut buf = Vec::with_capacity(self.params.len() * 4);
        for &x in &self.params {
            buf.extend_from_slice(&x.to_le_bytes());
        }
        f.write_all(&buf)?;
        Ok(())
    }

    pub fn load(path: &Path) -> Result<ModelState> {
        let mut f = std::fs::File::open(path).with_context(|| format!("open {path:?}"))?;
        let mut magic = [0u8; 4];
        f.read_exact(&mut magic)?;
        if &magic != b"ZLM1" {
            return Err(anyhow!("bad checkpoint magic"));
        }
        let mut len8 = [0u8; 8];
        f.read_exact(&mut len8)?;
        let hlen = u64::from_le_bytes(len8) as usize;
        let mut hbuf = vec![0u8; hlen];
        f.read_exact(&mut hbuf)?;
        let header = Json::parse(std::str::from_utf8(&hbuf)?).map_err(|e| anyhow!(e))?;
        let n = header.req_usize("n_params");
        let mut raw = Vec::new();
        f.read_to_end(&mut raw)?;
        if raw.len() != n * 4 {
            return Err(anyhow!("checkpoint truncated: {} vs {}", raw.len(), n * 4));
        }
        let params = raw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        Ok(ModelState {
            model: header.req_str("model").to_string(),
            task: header.req_str("task").to_string(),
            params,
            masks: Masks::from_json(header.get("masks").ok_or_else(|| anyhow!("no masks"))?)?,
        })
    }
}

/// Mirror of python `specialized_layout`: gather the surviving
/// rows/cols of a masked checkpoint into the packed parameter order a
/// shape-specialized export (`aot.py --specialize`) expects. Returns
/// `(flat params, heads alive per layer, FFN columns alive per layer)`.
/// Used by `exp::measure_specialized` (paper Table 8) and by the family
/// coordinator's per-(member, bucket) specialized executables
/// (DESIGN.md §9), which is why it lives model-side rather than with
/// the experiment drivers.
pub fn gather_specialized(
    state: &ModelState,
    minfo: &ModelInfo,
    tinfo: &TaskInfo,
) -> Result<(Vec<f32>, Vec<usize>, Vec<usize>)> {
    let mut heads = Vec::new();
    let mut inters = Vec::new();
    let mut head_keep: Vec<Vec<usize>> = Vec::new();
    let mut ffn_keep: Vec<Vec<usize>> = Vec::new();
    for l in 0..minfo.n_layers {
        let hk: Vec<usize> =
            (0..minfo.n_heads).filter(|&h| state.masks.head_row(l)[h] > 0.0).collect();
        let fk: Vec<usize> = (0..minfo.d_ff).filter(|&c| state.masks.ffn_row(l)[c] > 0.0).collect();
        heads.push(hk.len());
        inters.push(fk.len());
        head_keep.push(hk);
        ffn_keep.push(fk);
    }
    let mut out: Vec<f32> = Vec::new();
    let mut push_full = |state: &ModelState, name: &str, out: &mut Vec<f32>| {
        if let Some(e) = tinfo.entry(name) {
            out.extend_from_slice(&state.params[e.offset..e.offset + e.numel()]);
        }
    };
    push_full(state, "tok_emb", &mut out);
    push_full(state, "pos_emb", &mut out);
    if !minfo.causal {
        push_full(state, "emb_ln_g", &mut out);
        push_full(state, "emb_ln_b", &mut out);
    }
    for l in 0..minfo.n_layers {
        let hk = &head_keep[l];
        let fk = &ffn_keep[l];
        let cols_a: Vec<usize> =
            hk.iter().flat_map(|&h| (h * minfo.d_head..(h + 1) * minfo.d_head)).collect();
        if !hk.is_empty() {
            for name in ["wq", "wk", "wv"] {
                let t = state.get2(tinfo, &format!("layer{l}.{name}"))?;
                let g = t.gather_cols(&cols_a);
                out.extend_from_slice(&g.data);
                let b = state.get1(tinfo, &format!("layer{l}.{}", name.replace('w', "b")))?;
                for &c in &cols_a {
                    out.push(b[c]);
                }
            }
            let wo = state.get2(tinfo, &format!("layer{l}.wo"))?;
            let g = wo.gather_rows(&cols_a);
            out.extend_from_slice(&g.data);
            out.extend_from_slice(&state.get1(tinfo, &format!("layer{l}.bo"))?);
        }
        out.extend_from_slice(&state.get1(tinfo, &format!("layer{l}.ln1_g"))?);
        out.extend_from_slice(&state.get1(tinfo, &format!("layer{l}.ln1_b"))?);
        if !fk.is_empty() {
            let w1 = state.get2(tinfo, &format!("layer{l}.w1"))?;
            out.extend_from_slice(&w1.gather_cols(fk).data);
            let b1 = state.get1(tinfo, &format!("layer{l}.b1"))?;
            for &c in fk {
                out.push(b1[c]);
            }
            let w2 = state.get2(tinfo, &format!("layer{l}.w2"))?;
            out.extend_from_slice(&w2.gather_rows(fk).data);
            out.extend_from_slice(&state.get1(tinfo, &format!("layer{l}.b2"))?);
        }
        out.extend_from_slice(&state.get1(tinfo, &format!("layer{l}.ln2_g"))?);
        out.extend_from_slice(&state.get1(tinfo, &format!("layer{l}.ln2_b"))?);
    }
    match tinfo.kind.as_str() {
        "cls" => {
            push_full(state, "cls_w", &mut out);
            push_full(state, "cls_b", &mut out);
        }
        "span" => {
            push_full(state, "span_w", &mut out);
            push_full(state, "span_b", &mut out);
        }
        _ => {
            push_full(state, "lnf_g", &mut out);
            push_full(state, "lnf_b", &mut out);
        }
    }
    Ok((out, heads, inters))
}

/// Shared fixtures for unit tests across modules (only in test builds).
#[cfg(test)]
pub mod tests_support {
    use super::*;
    use crate::runtime::manifest::LayoutEntry;
    use std::collections::BTreeMap;

    /// A 2-layer toy model with a full BERT-style layout.
    pub fn mini_state() -> (ModelInfo, TaskInfo, ModelState) {
        let (d, a, f, v, s) = (8usize, 8usize, 8usize, 16usize, 4usize);
        let mut names: Vec<(String, Vec<usize>)> = vec![
            ("tok_emb".into(), vec![v, d]),
            ("pos_emb".into(), vec![s, d]),
            ("emb_ln_g".into(), vec![d]),
            ("emb_ln_b".into(), vec![d]),
        ];
        for l in 0..2 {
            for (n, shape) in [
                ("wq", vec![d, a]), ("bq", vec![a]),
                ("wk", vec![d, a]), ("bk", vec![a]),
                ("wv", vec![d, a]), ("bv", vec![a]),
                ("wo", vec![a, d]), ("bo", vec![d]),
                ("ln1_g", vec![d]), ("ln1_b", vec![d]),
                ("w1", vec![d, f]), ("b1", vec![f]),
                ("w2", vec![f, d]), ("b2", vec![d]),
                ("ln2_g", vec![d]), ("ln2_b", vec![d]),
            ] {
                names.push((format!("layer{l}.{n}"), shape));
            }
        }
        names.push(("cls_w".into(), vec![d, 2]));
        names.push(("cls_b".into(), vec![2]));
        let mut layout = Vec::new();
        let mut off = 0usize;
        for (name, shape) in names {
            let numel: usize = shape.iter().product();
            layout.push(LayoutEntry { name, shape, offset: off });
            off += numel;
        }
        let tinfo = TaskInfo { n_params: off, kind: "cls".into(), n_classes: 2, layout };
        let minfo = ModelInfo {
            name: "mini2".into(),
            n_layers: 2,
            d_model: d,
            n_heads: 2,
            d_head: 4,
            d_ff: f,
            vocab: v,
            seq_len: s,
            causal: false,
            ffn_ladder: vec![f, 6, 4, 2, 1, 0],
            head_ladder: vec![2, 1, 0],
            measured_ffn: vec![f, 4, 1],
            tasks: BTreeMap::new(),
        };
        let st = ModelState::init(&minfo, "sst2-syn", &tinfo, 42);
        (minfo, tinfo, st)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::LayoutEntry;
    use std::collections::BTreeMap;

    pub(crate) fn mini_info() -> (ModelInfo, TaskInfo) {
        let names: Vec<(&str, Vec<usize>)> = vec![
            ("tok_emb", vec![8, 4]),
            ("layer0.wo", vec![4, 4]),
            ("layer0.bo", vec![4]),
            ("layer0.ln1_g", vec![4]),
            ("layer0.wq", vec![4, 4]),
            ("layer0.wk", vec![4, 4]),
            ("layer0.wv", vec![4, 4]),
            ("layer0.bq", vec![4]),
            ("layer0.bk", vec![4]),
            ("layer0.bv", vec![4]),
            ("layer0.w1", vec![4, 4]),
            ("layer0.b1", vec![4]),
            ("layer0.w2", vec![4, 4]),
            ("layer0.b2", vec![4]),
        ];
        let mut layout = Vec::new();
        let mut off = 0;
        for (n, shape) in names {
            let numel: usize = shape.iter().product();
            layout.push(LayoutEntry { name: n.into(), shape, offset: off });
            off += numel;
        }
        let tinfo = TaskInfo { n_params: off, kind: "cls".into(), n_classes: 2, layout };
        let minfo = ModelInfo {
            name: "mini".into(),
            n_layers: 1,
            d_model: 4,
            n_heads: 2,
            d_head: 2,
            d_ff: 4,
            vocab: 8,
            seq_len: 4,
            causal: false,
            ffn_ladder: vec![4, 2, 0],
            head_ladder: vec![2, 1, 0],
            measured_ffn: vec![4, 2],
            tasks: BTreeMap::new(),
        };
        (minfo, tinfo)
    }

    #[test]
    fn init_respects_layout_conventions() {
        let (mi, ti) = mini_info();
        let st = ModelState::init(&mi, "t", &ti, 0);
        assert_eq!(st.params.len(), ti.n_params);
        let g = st.get1(&ti, "layer0.ln1_g").unwrap();
        assert!(g.iter().all(|&x| x == 1.0));
        let b = st.get1(&ti, "layer0.bo").unwrap();
        assert!(b.iter().all(|&x| x == 0.0));
        let w = st.get2(&ti, "layer0.wo").unwrap();
        assert!(w.data.iter().any(|&x| x != 0.0));
    }

    #[test]
    fn attn_w_paper_roundtrip_and_qkv_zeroing() {
        let (mi, ti) = mini_info();
        let mut st = ModelState::init(&mi, "t", &ti, 1);
        let w = st.attn_w_paper(&ti, 0).unwrap();
        assert_eq!(w.shape, vec![4, 4]);
        let mut w2 = w.clone();
        w2.data[0] = 9.0;
        st.set_attn_w_paper(&ti, 0, &w2, &[1], 2).unwrap();
        let back = st.attn_w_paper(&ti, 0).unwrap();
        assert_eq!(back.data[0], 9.0);
        let wq = st.get2(&ti, "layer0.wq").unwrap();
        for r in 0..4 {
            assert_eq!(wq.at2(r, 2), 0.0);
            assert_eq!(wq.at2(r, 3), 0.0);
        }
        let bq = st.get1(&ti, "layer0.bq").unwrap();
        assert_eq!(&bq[2..4], &[0.0, 0.0]);
    }

    #[test]
    fn fc_w_paper_zeroes_w1_cols() {
        let (mi, ti) = mini_info();
        let mut st = ModelState::init(&mi, "t", &ti, 2);
        let w = st.fc_w_paper(&ti, 0).unwrap();
        st.set_fc_w_paper(&ti, 0, &w, &[1, 3]).unwrap();
        let w1 = st.get2(&ti, "layer0.w1").unwrap();
        for r in 0..4 {
            assert_eq!(w1.at2(r, 1), 0.0);
            assert_eq!(w1.at2(r, 3), 0.0);
        }
        let b1 = st.get1(&ti, "layer0.b1").unwrap();
        assert_eq!(b1[1], 0.0);
        assert_eq!(b1[3], 0.0);
    }

    #[test]
    fn masks_accounting() {
        let (mi, _) = mini_info();
        let mut m = Masks::dense(&mi);
        assert_eq!(m.heads_alive(0), 2);
        m.kill_head(0, 0);
        m.kill_ffn_col(0, 3);
        assert_eq!(m.heads_alive(0), 1);
        assert_eq!(m.ffn_alive(0), 3);
        assert_eq!(m.summary(), vec![(1, 3)]);
        let j = m.to_json();
        let m2 = Masks::from_json(&j).unwrap();
        assert_eq!(m, m2);
    }

    #[test]
    fn checkpoint_roundtrip() {
        let (mi, ti) = mini_info();
        let mut st = ModelState::init(&mi, "sst2-syn", &ti, 5);
        st.masks.kill_head(0, 1);
        let dir = std::env::temp_dir().join("ziplm_test_ckpt");
        let path = dir.join("m.zlm");
        st.save(&path).unwrap();
        let st2 = ModelState::load(&path).unwrap();
        assert_eq!(st.params, st2.params);
        assert_eq!(st.masks, st2.masks);
        assert_eq!(st2.task, "sst2-syn");
        let _ = std::fs::remove_dir_all(dir);
    }
}
