//! Family manifest: the deliverable of a gradual ZipLM run.
//!
//! The paper's headline property (§3.2, App. F) is that one gradual
//! run emits an *entire family* of compressed models, each guaranteed
//! to meet its inference target. The manifest is the on-disk record of
//! that family: which checkpoints exist, what speedup each was pruned
//! for, what the latency table estimated, and the per-layer anatomy
//! the SPDY search settled on. It is emitted by the experiment drivers
//! (`exp/`) and the `prune-gradual` CLI after the SPDY stages finish,
//! and consumed here on the `models/` side to load the member
//! checkpoints behind the family coordinator (`coordinator/family`).

use std::path::Path;

use anyhow::{anyhow, Result};

use super::ModelState;
use crate::compress::CompressionProfile;
use crate::env::InferenceEnv;
use crate::util::json::Json;

/// One member of a served model family.
#[derive(Clone, Debug, PartialEq)]
pub struct FamilyMember {
    /// display/routing tag, e.g. `"dense"` or `"3x"`
    pub tag: String,
    /// checkpoint file, relative to the manifest's directory
    pub ckpt: String,
    /// requested speedup target (1.0 for the dense member)
    pub target: f64,
    /// latency-table speedup estimate the SPDY search certified
    pub est_speedup: f64,
    /// per-layer (heads alive, FFN columns alive) structural profile
    pub profile: Vec<(usize, usize)>,
    /// typed per-module compression choices (manifest schema v2,
    /// DESIGN.md §13) — records which axis produced each module
    /// (prune level / int8 / low-rank rank / compositions). `None` for
    /// v1 pruning-only manifests, which load unchanged; readers that
    /// need choices lift `profile` via
    /// [`CompressionProfile::from_layer_profile`].
    pub choices: Option<CompressionProfile>,
    /// calibration loss recorded when the member was solved — the y
    /// axis of the adapt frontier (`adapt::frontier_points`). `None`
    /// for manifests written before losses were recorded; the frontier
    /// substitutes a deterministic speedup-based proxy. Quant and
    /// low-rank members record their axis's calibration loss here so
    /// the frontier sees the full mixed-axis family.
    pub calib_loss: Option<f64>,
}

/// Optional fleet topology a family was certified to serve under
/// (DESIGN.md §10): worker count and per-worker device-latency skews
/// for `coordinator::fleet`. Absent for single-worker manifests.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FleetSpec {
    /// number of fleet workers (simulated devices)
    pub workers: usize,
    /// per-worker latency skew (missing entries default to 1.0)
    pub skews: Vec<f64>,
}

/// The full family for one (model, task, latency regime).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FamilyManifest {
    /// manifest model name (all members share it)
    pub model: String,
    /// task name (all members share it)
    pub task: String,
    /// latency-table regime the targets were certified against
    pub regime: String,
    /// the full inference environment the members were certified
    /// against. Serving tools (`serve-family`, the family
    /// coordinator) price admission with THIS value instead of
    /// re-measuring, closing the certify-vs-admit gap. `None` only
    /// for manifests written before env embedding existed.
    pub env: Option<InferenceEnv>,
    /// `(batch, padded seq)` shape-bucket ladder the family was
    /// certified under (DESIGN.md §9) — the default
    /// `coordinator::family::BucketLadder` serving tools shape batches
    /// and specialized executables with. Empty for manifests written
    /// before shape-specialized serving existed (generic-only).
    pub buckets: Vec<(usize, usize)>,
    /// fleet topology to serve the family under (`serve-fleet`);
    /// `None` = classic single-worker serving
    pub fleet: Option<FleetSpec>,
    /// members ordered by ascending `est_speedup` (dense first)
    pub members: Vec<FamilyMember>,
}

impl FamilyManifest {
    /// Empty family for (model, task, regime).
    pub fn new(model: &str, task: &str, regime: &str) -> FamilyManifest {
        FamilyManifest {
            model: model.to_string(),
            task: task.to_string(),
            regime: regime.to_string(),
            env: None,
            buckets: Vec::new(),
            fleet: None,
            members: Vec::new(),
        }
    }

    /// Insert a member, keeping `members` sorted by ascending
    /// `est_speedup` (the router relies on this order).
    pub fn push(&mut self, member: FamilyMember) {
        let at = self
            .members
            .iter()
            .position(|m| m.est_speedup > member.est_speedup)
            .unwrap_or(self.members.len());
        self.members.insert(at, member);
    }

    /// The fastest member (queue-pressure fallback target).
    pub fn fastest(&self) -> Option<&FamilyMember> {
        self.members.last()
    }

    /// The most accurate (slowest) member whose certified speedup is at
    /// least `min_speedup`; `None` when no member qualifies.
    pub fn best_for_speedup(&self, min_speedup: f64) -> Option<&FamilyMember> {
        self.members.iter().find(|m| m.est_speedup + 1e-9 >= min_speedup)
    }

    /// Serialize to the on-disk JSON form (the `env` and `buckets`
    /// keys are present only when a certification env / bucket ladder
    /// is recorded, so older readers and files stay compatible).
    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("model", Json::Str(self.model.clone())),
            ("task", Json::Str(self.task.clone())),
            ("regime", Json::Str(self.regime.clone())),
        ];
        if let Some(env) = &self.env {
            pairs.push(("env", env.to_json()));
        }
        if !self.buckets.is_empty() {
            pairs.push((
                "buckets",
                Json::Arr(
                    self.buckets
                        .iter()
                        .map(|&(b, s)| {
                            Json::Arr(vec![Json::Num(b as f64), Json::Num(s as f64)])
                        })
                        .collect(),
                ),
            ));
        }
        if let Some(fl) = &self.fleet {
            pairs.push((
                "fleet",
                Json::obj(vec![
                    ("workers", Json::Num(fl.workers as f64)),
                    (
                        "skews",
                        Json::Arr(fl.skews.iter().map(|&s| Json::Num(s)).collect()),
                    ),
                ]),
            ));
        }
        pairs.push((
                "members",
                Json::Arr(
                    self.members
                        .iter()
                        .map(|m| {
                            let mut mp = vec![
                                ("tag", Json::Str(m.tag.clone())),
                                ("ckpt", Json::Str(m.ckpt.clone())),
                                ("target", Json::Num(m.target)),
                                ("est_speedup", Json::Num(m.est_speedup)),
                            ];
                            if let Some(l) = m.calib_loss {
                                if l.is_finite() {
                                    mp.push(("calib_loss", Json::Num(l)));
                                }
                            }
                            if let Some(c) = &m.choices {
                                mp.push(("choices", c.to_json()));
                            }
                            mp.push((
                                    "profile",
                                    Json::Arr(
                                        m.profile
                                            .iter()
                                            .map(|&(h, f)| {
                                                Json::Arr(vec![
                                                    Json::Num(h as f64),
                                                    Json::Num(f as f64),
                                                ])
                                            })
                                            .collect(),
                                    ),
                            ));
                            Json::obj(mp)
                        })
                        .collect(),
                ),
        ));
        Json::obj(pairs)
    }

    /// Parse the on-disk JSON form (members are re-sorted defensively;
    /// absent `env`/`buckets` keys parse as `None`/empty for files
    /// written before those were recorded).
    pub fn from_json(j: &Json) -> Result<FamilyManifest> {
        let mut out = FamilyManifest::new(
            j.req_str("model"),
            j.req_str("task"),
            j.get("regime").and_then(Json::as_str).unwrap_or("throughput"),
        );
        out.env = j.get("env").map(InferenceEnv::from_json).transpose()?;
        out.buckets = j
            .get("buckets")
            .and_then(Json::as_arr)
            .unwrap_or(&[])
            .iter()
            .filter_map(|e| Some((e.idx(0)?.as_usize()?, e.idx(1)?.as_usize()?)))
            .collect();
        out.fleet = j.get("fleet").map(|f| FleetSpec {
            workers: f.get("workers").and_then(Json::as_usize).unwrap_or(1).max(1),
            skews: f
                .get("skews")
                .and_then(Json::as_arr)
                .unwrap_or(&[])
                .iter()
                .filter_map(Json::as_f64)
                .collect(),
        });
        for m in j.get("members").and_then(Json::as_arr).unwrap_or(&[]) {
            let profile = m
                .get("profile")
                .and_then(Json::as_arr)
                .unwrap_or(&[])
                .iter()
                .map(|e| {
                    (
                        e.idx(0).and_then(Json::as_usize).unwrap_or(0),
                        e.idx(1).and_then(Json::as_usize).unwrap_or(0),
                    )
                })
                .collect();
            out.push(FamilyMember {
                tag: m.req_str("tag").to_string(),
                ckpt: m.req_str("ckpt").to_string(),
                target: m.get("target").and_then(Json::as_f64).unwrap_or(1.0),
                est_speedup: m.get("est_speedup").and_then(Json::as_f64).unwrap_or(1.0),
                profile,
                // schema v2: absent on v1 pruning-only manifests → None
                choices: m.get("choices").map(CompressionProfile::from_json).transpose()?,
                calib_loss: m.get("calib_loss").and_then(Json::as_f64),
            });
        }
        Ok(out)
    }

    /// Write the manifest as pretty JSON, creating parent directories.
    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(d) = path.parent() {
            std::fs::create_dir_all(d)?;
        }
        std::fs::write(path, self.to_json().to_pretty())?;
        Ok(())
    }

    /// Load a manifest from disk.
    pub fn load(path: &Path) -> Result<FamilyManifest> {
        let text = std::fs::read_to_string(path)?;
        FamilyManifest::from_json(&Json::parse(&text).map_err(|e| anyhow!(e))?)
    }

    /// Load every member checkpoint (paths resolved relative to
    /// `base`, normally the manifest's directory) and sanity-check
    /// that each matches the manifest's (model, task).
    pub fn load_states(&self, base: &Path) -> Result<Vec<(FamilyMember, ModelState)>> {
        let mut out = Vec::with_capacity(self.members.len());
        for m in &self.members {
            let st = ModelState::load(&base.join(&m.ckpt))?;
            if st.model != self.model || st.task != self.task {
                return Err(anyhow!(
                    "family member `{}` is {}/{}, manifest says {}/{}",
                    m.tag,
                    st.model,
                    st.task,
                    self.model,
                    self.task
                ));
            }
            out.push((m.clone(), st));
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn member(tag: &str, est: f64) -> FamilyMember {
        FamilyMember {
            tag: tag.into(),
            ckpt: format!("{tag}.zlm"),
            target: est,
            est_speedup: est,
            profile: vec![(2, 8), (1, 4)],
            choices: None,
            calib_loss: if est > 1.0 { Some(0.01 * est) } else { None },
        }
    }

    #[test]
    fn push_keeps_speedup_order() {
        let mut f = FamilyManifest::new("m", "t", "throughput");
        f.push(member("3x", 3.1));
        f.push(member("dense", 1.0));
        f.push(member("2x", 2.2));
        let tags: Vec<&str> = f.members.iter().map(|m| m.tag.as_str()).collect();
        assert_eq!(tags, vec!["dense", "2x", "3x"]);
        assert_eq!(f.fastest().unwrap().tag, "3x");
    }

    #[test]
    fn best_for_speedup_picks_most_accurate_qualifier() {
        let mut f = FamilyManifest::new("m", "t", "throughput");
        for (tag, est) in [("dense", 1.0), ("2x", 2.2), ("3x", 3.1)] {
            f.push(member(tag, est));
        }
        assert_eq!(f.best_for_speedup(2.0).unwrap().tag, "2x");
        assert_eq!(f.best_for_speedup(2.2).unwrap().tag, "2x");
        assert_eq!(f.best_for_speedup(3.0).unwrap().tag, "3x");
        assert!(f.best_for_speedup(5.0).is_none());
    }

    #[test]
    fn json_roundtrip() {
        let mut f = FamilyManifest::new("bert-syn-base", "sst2-syn", "latency");
        f.push(member("dense", 1.0));
        f.push(member("2x", 2.05));
        let j = f.to_json();
        let f2 = FamilyManifest::from_json(&j).unwrap();
        assert_eq!(f, f2);
        // no env/ladder recorded → no keys in the JSON (older readers)
        assert!(j.get("env").is_none());
        assert!(j.get("buckets").is_none());
    }

    #[test]
    fn json_roundtrip_with_bucket_ladder() {
        let mut f = FamilyManifest::new("bert-syn-base", "sst2-syn", "latency");
        f.buckets = vec![(1, 32), (1, 64), (8, 128)];
        f.push(member("dense", 1.0));
        let f2 = FamilyManifest::from_json(&f.to_json()).unwrap();
        assert_eq!(f, f2);
        assert_eq!(f2.buckets, vec![(1, 32), (1, 64), (8, 128)]);
        // through text as well (serving tools go through the parser)
        let f3 = FamilyManifest::from_json(
            &crate::util::json::Json::parse(&f.to_json().to_pretty()).unwrap(),
        )
        .unwrap();
        assert_eq!(f, f3);
    }

    #[test]
    fn json_roundtrip_with_fleet_spec() {
        let mut f = FamilyManifest::new("bert-syn-base", "sst2-syn", "throughput");
        f.fleet = Some(FleetSpec { workers: 3, skews: vec![1.0, 1.3, 0.9] });
        f.push(member("dense", 1.0));
        let j = f.to_json();
        let f2 = FamilyManifest::from_json(&j).unwrap();
        assert_eq!(f, f2);
        // through text too (serve-fleet goes through the parser)
        let f3 = FamilyManifest::from_json(
            &crate::util::json::Json::parse(&j.to_pretty()).unwrap(),
        )
        .unwrap();
        assert_eq!(f, f3);
        // no fleet recorded → no key; absent key parses as None
        let plain = FamilyManifest::new("m", "t", "throughput");
        assert!(plain.to_json().get("fleet").is_none());
        assert!(FamilyManifest::from_json(&plain.to_json()).unwrap().fleet.is_none());
    }

    #[test]
    fn json_roundtrip_with_embedded_env() {
        let env = InferenceEnv::measured(crate::latency::LatencyTable {
            model: "bert-syn-base".into(),
            device: "cpu-pjrt".into(),
            regime: "latency".into(),
            attn: vec![0.0, 1.1e-3, 2.0e-3],
            mlp: vec![(64, 4e-3), (16, 1e-3), (0, 0.0)],
            overhead: 7e-4,
        })
        .unwrap()
        .with_batch_shape(1, 64);
        let mut f = FamilyManifest::new("bert-syn-base", "sst2-syn", "latency");
        f.env = Some(env.clone());
        f.push(member("dense", 1.0));
        f.push(member("3x", 3.0));
        // value and text round-trips both preserve the embedded env
        let f2 = FamilyManifest::from_json(&f.to_json()).unwrap();
        assert_eq!(f, f2);
        assert_eq!(f2.env.as_ref(), Some(&env));
        let f3 = FamilyManifest::from_json(
            &crate::util::json::Json::parse(&f.to_json().to_pretty()).unwrap(),
        )
        .unwrap();
        assert_eq!(f, f3);
    }

    #[test]
    fn save_load_roundtrip_and_state_mismatch_detected() {
        let dir = std::env::temp_dir().join("ziplm_family_test");
        let _ = std::fs::remove_dir_all(&dir);
        let mut f = FamilyManifest::new("mini2", "t", "throughput");
        f.push(member("dense", 1.0));
        let path = dir.join("family.json");
        f.save(&path).unwrap();
        let f2 = FamilyManifest::load(&path).unwrap();
        assert_eq!(f, f2);
        // a checkpoint whose (model, task) disagrees must be rejected
        let (mi, ti, _st) = crate::models::tests_support::mini_state();
        let st = ModelState::init(&mi, "other-task", &ti, 0);
        st.save(&dir.join("dense.zlm")).unwrap();
        assert!(f2.load_states(&dir).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
