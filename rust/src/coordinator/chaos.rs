//! Chaos harness for the supervised fleet (DESIGN.md §10): a seeded
//! trace-driven load generator plus a driver that submits the trace
//! against a fault-injected [`fleet`](super::fleet) and audits the
//! terminal outcomes.
//!
//! The harness closes the loop the fault layer opens: a
//! [`crate::runtime::FaultPlan`] decides *where* faults strike, a
//! [`TraceCfg`] decides *what load* arrives, and [`run_chaos`] checks
//! the contract that must survive both — every submitted request gets
//! exactly one terminal [`Outcome`] (`lost == 0`), and the shed /
//! abandoned / replied counts balance against submissions. The same
//! `(plan seed, trace seed)` pair replays the same campaign, which is
//! what the CI chaos smoke job pins.

use std::time::Duration;

use anyhow::{anyhow, Result};

use super::family::Sla;
use super::fleet::{self, FleetCfg, FleetMember, FleetStats, Outcome, ShedReason};
use crate::env::InferenceEnv;
use crate::runtime::FaultPlan;
use crate::util::rng::Rng;

/// One workload class in a trace: a weight and the SLA its requests
/// carry (`None` bounds = best-effort traffic).
#[derive(Clone, Debug)]
pub struct TraceClass {
    /// class label (lands in [`Sla::class`])
    pub class: String,
    /// sampling weight relative to the other classes
    pub weight: f64,
    /// admission latency bound for this class's requests
    pub max_latency: Option<Duration>,
    /// certified-speedup floor for this class's requests
    pub min_speedup: Option<f64>,
}

impl TraceClass {
    /// A best-effort class with no SLA bounds.
    pub fn best_effort(weight: f64) -> TraceClass {
        TraceClass { class: "best-effort".into(), weight, max_latency: None, min_speedup: None }
    }
}

/// Seeded load-trace configuration.
#[derive(Clone, Debug)]
pub struct TraceCfg {
    /// requests in the trace
    pub requests: usize,
    /// trace seed (independent of the fault-plan seed)
    pub seed: u64,
    /// wall gap between consecutive submissions (0 = burst)
    pub arrival_gap: Duration,
    /// inclusive token-length range of generated requests
    pub len_range: (usize, usize),
    /// workload classes (empty = all requests best-effort, no SLA)
    pub classes: Vec<TraceClass>,
}

impl Default for TraceCfg {
    fn default() -> Self {
        TraceCfg {
            requests: 64,
            seed: 0x7ace,
            arrival_gap: Duration::ZERO,
            len_range: (4, 32),
            classes: Vec::new(),
        }
    }
}

/// One generated request: token ids + the SLA it carries.
#[derive(Clone, Debug)]
pub struct TraceItem {
    /// token ids
    pub ids: Vec<i32>,
    /// SLA (None = best-effort)
    pub sla: Option<Sla>,
}

/// Generate the seeded request trace for `cfg` — pure in `cfg.seed`.
pub fn gen_trace(cfg: &TraceCfg) -> Vec<TraceItem> {
    let mut rng = Rng::new(cfg.seed ^ 0x7_ace_0f_1_0ad);
    let (lo, hi) = cfg.len_range;
    let lo = lo.max(1);
    let hi = hi.max(lo);
    let weights: Vec<f64> = cfg.classes.iter().map(|c| c.weight.max(0.0)).collect();
    let any_weight = weights.iter().any(|&w| w > 0.0);
    (0..cfg.requests)
        .map(|_| {
            let len = lo + rng.below(hi - lo + 1);
            let ids: Vec<i32> = (0..len).map(|_| rng.below(30_000) as i32).collect();
            let sla = if any_weight {
                let c = &cfg.classes[rng.weighted(&weights)];
                Some(Sla {
                    class: c.class.clone(),
                    max_latency: c.max_latency,
                    min_speedup: c.min_speedup,
                })
            } else {
                None
            };
            TraceItem { ids, sla }
        })
        .collect()
}

/// Outcome audit of one chaos campaign.
#[derive(Clone, Debug, Default)]
pub struct ChaosReport {
    /// requests submitted from the trace
    pub submitted: usize,
    /// requests that terminated `Replied`
    pub replied: usize,
    /// requests shed at admission
    pub shed: usize,
    /// requests abandoned (deadline or retry exhaustion)
    pub abandoned: usize,
    /// requests with NO terminal outcome — the invariant says 0;
    /// anything else is a lost request and a bug
    pub lost: usize,
    /// replies that survived at least one re-dispatch
    pub retried_replies: usize,
    /// replies served while the fleet was degraded
    pub degraded_replies: usize,
    /// shed-reason breakdown `(queue_full, no_capacity, deadline)`
    pub shed_reasons: (usize, usize, usize),
    /// fleet stats at shutdown
    pub stats: FleetStats,
}

impl ChaosReport {
    /// Whether every submitted request reached exactly one terminal
    /// outcome and the fleet's own accounting agrees.
    pub fn balanced(&self) -> bool {
        self.lost == 0
            && self.replied + self.shed + self.abandoned == self.submitted
            && self.stats.accounted() == self.stats.submitted
    }
}

/// Run one chaos campaign: start a fleet under `plan`, submit the
/// seeded trace, await a terminal [`Outcome`] for every request, shut
/// down, and audit the books.
pub fn run_chaos(
    cfg: FleetCfg,
    members: Vec<FleetMember>,
    env: &InferenceEnv,
    plan: FaultPlan,
    trace: &TraceCfg,
) -> Result<ChaosReport> {
    let handle = fleet::start(cfg, members, env, plan)?;
    let items = gen_trace(trace);
    let mut receivers = Vec::with_capacity(items.len());
    for item in items {
        receivers.push(handle.submit(item.ids, item.sla)?);
        if trace.arrival_gap > Duration::ZERO {
            std::thread::sleep(trace.arrival_gap);
        }
    }
    let mut report = ChaosReport { submitted: receivers.len(), ..ChaosReport::default() };
    for rx in receivers {
        match rx.recv_timeout(Duration::from_secs(30)) {
            Ok(Outcome::Replied(r)) => {
                report.replied += 1;
                if r.attempts > 0 {
                    report.retried_replies += 1;
                }
                if r.degraded {
                    report.degraded_replies += 1;
                }
            }
            Ok(Outcome::Shed(reason)) => {
                report.shed += 1;
                match reason {
                    ShedReason::QueueFull => report.shed_reasons.0 += 1,
                    ShedReason::NoCapacity => report.shed_reasons.1 += 1,
                    ShedReason::DeadlineUnmeetable => report.shed_reasons.2 += 1,
                }
            }
            Ok(Outcome::Abandoned { .. }) => report.abandoned += 1,
            // a dropped or never-resolved receiver IS the lost-request
            // bug this harness exists to catch
            Err(_) => report.lost += 1,
        }
    }
    report.stats = handle.shutdown()?;
    Ok(report)
}

/// Render a one-screen chaos summary (the fleet example and the `chaos`
/// experiment both print this).
pub fn render_report(r: &ChaosReport) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "chaos: {} submitted → {} replied / {} shed / {} abandoned / {} LOST\n",
        r.submitted, r.replied, r.shed, r.abandoned, r.lost
    ));
    out.push_str(&format!(
        "  shed reasons: queue-full {} / no-capacity {} / deadline {}\n",
        r.shed_reasons.0, r.shed_reasons.1, r.shed_reasons.2
    ));
    out.push_str(&format!(
        "  faults: {} crashes, {} restarts, {} compile failures, {} retries ({} replies survived a retry)\n",
        r.stats.crashes, r.stats.restarts, r.stats.compile_failures, r.stats.retries, r.retried_replies
    ));
    out.push_str(&format!(
        "  tails (priced exec s): normal p50 {:.4} p99 {:.4} (n={}) | degraded p50 {:.4} p99 {:.4} (n={})\n",
        r.stats.tails.normal_p50,
        r.stats.tails.normal_p99,
        r.stats.tails.normal_n,
        r.stats.tails.degraded_p50,
        r.stats.tails.degraded_p99,
        r.stats.tails.degraded_n
    ));
    for w in &r.stats.per_worker {
        out.push_str(&format!(
            "  w{}: inc {} served {} crashes {} restarts {}{} | shard builds {} hits {}\n",
            w.worker,
            w.incarnation,
            w.served,
            w.crashes,
            w.restarts,
            if w.quarantined { " QUARANTINED" } else { "" },
            w.builds,
            w.hits
        ));
    }
    out
}

/// Convenience: assert the no-lost-request invariant, returning the
/// report on success (the chaos smoke job's single call).
pub fn run_chaos_checked(
    cfg: FleetCfg,
    members: Vec<FleetMember>,
    env: &InferenceEnv,
    plan: FaultPlan,
    trace: &TraceCfg,
) -> Result<ChaosReport> {
    let report = run_chaos(cfg, members, env, plan, trace)?;
    if !report.balanced() {
        return Err(anyhow!(
            "chaos invariant violated: submitted {} != replied {} + shed {} + abandoned {} (lost {})",
            report.submitted,
            report.replied,
            report.shed,
            report.abandoned,
            report.lost
        ));
    }
    Ok(report)
}

#[cfg(test)]
#[allow(clippy::disallowed_methods)]
mod tests {
    use super::*;

    #[test]
    fn trace_is_seeded_and_respects_bounds() {
        let cfg = TraceCfg {
            requests: 50,
            seed: 42,
            len_range: (3, 9),
            classes: vec![
                TraceClass::best_effort(1.0),
                TraceClass {
                    class: "rt".into(),
                    weight: 2.0,
                    max_latency: Some(Duration::from_millis(50)),
                    min_speedup: None,
                },
            ],
            ..TraceCfg::default()
        };
        let a = gen_trace(&cfg);
        let b = gen_trace(&cfg);
        assert_eq!(a.len(), 50);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.ids, y.ids, "trace must replay bit-identically");
            assert_eq!(
                x.sla.as_ref().map(|s| s.class.clone()),
                y.sla.as_ref().map(|s| s.class.clone())
            );
            assert!(x.ids.len() >= 3 && x.ids.len() <= 9);
            assert!(x.sla.is_some(), "weighted classes always assign an SLA");
        }
        let mut other = cfg.clone();
        other.seed = 43;
        assert!(
            gen_trace(&other).iter().zip(&a).any(|(x, y)| x.ids != y.ids),
            "different seeds must differ"
        );
    }

    #[test]
    fn empty_classes_mean_best_effort() {
        let cfg = TraceCfg { requests: 8, classes: Vec::new(), ..TraceCfg::default() };
        assert!(gen_trace(&cfg).iter().all(|t| t.sla.is_none()));
    }

    #[test]
    fn report_balance_detects_loss() {
        let mut r = ChaosReport { submitted: 4, replied: 2, shed: 1, abandoned: 1, ..Default::default() };
        r.stats.submitted = 4;
        r.stats.replied = 2;
        r.stats.shed = 1;
        r.stats.abandoned = 1;
        assert!(r.balanced());
        r.lost = 1;
        assert!(!r.balanced());
        r.lost = 0;
        r.replied = 1;
        assert!(!r.balanced());
    }
}
