//! Inference coordinator: a request-serving front end over the PJRT
//! runtime, used to measure the paper's two deployment regimes
//! (§4, "pruning for throughput" vs "pruning for latency") on real
//! executions rather than table estimates.
//!
//! Architecture (vLLM-router-like, scaled to one box):
//!   * clients submit `Request`s over an mpsc channel;
//!   * a dedicated worker thread owns the `Engine` + model state (PJRT
//!     handles are not `Send`, so the engine lives entirely inside the
//!     worker);
//!   * a dynamic batcher collects up to `max_batch` requests or
//!     `max_wait` and executes one padded fwd per batch;
//!   * per-request latency + aggregate throughput come back with each
//!     reply.
//!
//! tokio is unavailable offline; std threads + channels implement the
//! same event loop (DESIGN.md §4).
//!
//! The [`family`] submodule generalizes this single-model loop to a
//! whole SPDY-produced model family behind one front end, with
//! per-request SLA routing and per-variant batch queues (DESIGN.md §6),
//! plus shape-specialized executables and cross-SLA batch coalescing
//! for realized — not just certified — speedups (DESIGN.md §9).
//!
//! The [`fleet`] submodule splits the family loop into a supervised
//! N-worker fleet with an explicit Replied/Shed/Abandoned request
//! lifecycle, bounded retry of work lost to worker crashes, and
//! supervisor-driven restart + cache-shard re-warm (DESIGN.md §10);
//! [`chaos`] is its deterministic fault-injection harness, and
//! [`replay`] re-runs generated traces through the same routing layer
//! with a synthetic clock so the repro harness can golden-test realized
//! per-bucket stats (DESIGN.md §11).

pub mod chaos;
pub mod family;
pub mod fleet;
pub mod replay;

use std::path::PathBuf;
use std::sync::mpsc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::eval::mask_literals;
use crate::models::ModelState;
use crate::runtime::{lit_f32_shaped, lit_i32, lit_to_f32, Engine};

/// One queued inference request (built by [`ServerHandle::submit`]).
pub struct Request {
    /// token ids (padded to the graph's seq_len by the worker)
    pub ids: Vec<i32>,
    /// submission timestamp (queue-time accounting)
    pub submitted: Instant,
    /// reply channel
    pub reply: mpsc::Sender<Reply>,
}

/// Reply for one request.
#[derive(Clone, Debug)]
pub struct Reply {
    /// task logits for this example
    pub logits: Vec<f32>,
    /// time spent queued before the batch launched
    pub queue_time: Duration,
    /// number of real requests in the executed batch
    pub batch_size: usize,
    /// end-to-end latency (submit → reply)
    pub latency: Duration,
}

/// Single-model server configuration.
pub struct ServerCfg {
    /// artifact directory (manifest.json + HLO files)
    pub artifacts: PathBuf,
    /// max requests per executed batch (clamped to the graph batch)
    pub max_batch: usize,
    /// how long a batch waits for stragglers before launching
    pub max_wait: Duration,
}

/// Handle to a running single-model server.
pub struct ServerHandle {
    tx: Option<mpsc::Sender<Request>>,
    worker: Option<JoinHandle<Result<ServerStats>>>,
}

/// Aggregate serving statistics returned by [`ServerHandle::shutdown`].
#[derive(Clone, Debug, Default)]
pub struct ServerStats {
    /// total requests served
    pub requests: usize,
    /// total executed batches
    pub batches: usize,
    /// cumulative execution time
    pub busy_time: Duration,
}

impl ServerHandle {
    /// Enqueue a request; the receiver yields the [`Reply`].
    pub fn submit(&self, ids: Vec<i32>) -> Result<mpsc::Receiver<Reply>> {
        let (rtx, rrx) = mpsc::channel();
        self.tx
            .as_ref()
            .ok_or_else(|| anyhow!("server stopped"))?
            .send(Request { ids, submitted: Instant::now(), reply: rtx })
            .map_err(|_| anyhow!("server gone"))?;
        Ok(rrx)
    }

    /// Submit and wait (convenience).
    pub fn infer(&self, ids: Vec<i32>) -> Result<Reply> {
        let rx = self.submit(ids)?;
        Ok(rx.recv()?)
    }

    /// Stop accepting requests, drain the queue, and return stats.
    pub fn shutdown(mut self) -> Result<ServerStats> {
        drop(self.tx.take());
        self.worker
            .take()
            .ok_or_else(|| anyhow!("already stopped"))?
            .join()
            .map_err(|_| anyhow!("worker panicked"))?
    }
}

/// Start the serving worker for a (masked) checkpoint.
pub fn start(cfg: ServerCfg, state: ModelState) -> Result<ServerHandle> {
    let (tx, rx) = mpsc::channel::<Request>();
    let worker = std::thread::Builder::new()
        .name("ziplm-server".into())
        .spawn(move || serve_loop(cfg, state, rx))
        .map_err(|e| anyhow!("spawn server: {e}"))?;
    Ok(ServerHandle { tx: Some(tx), worker: Some(worker) })
}

/// Pad per-request token ids into one flat `[graph_b, seq_len]` id
/// buffer (XLA shapes are static: short rows pad with id 0, missing
/// batch rows are all-zero).
pub(crate) fn pad_ids<'a, I>(ids: I, graph_b: usize, seq_len: usize) -> Vec<i32>
where
    I: Iterator<Item = &'a [i32]>,
{
    let mut out = Vec::with_capacity(graph_b * seq_len);
    for row in ids {
        let mut v = row.to_vec();
        v.resize(seq_len, 0);
        out.extend_from_slice(&v);
    }
    out.resize(graph_b * seq_len, 0);
    out
}

fn serve_loop(cfg: ServerCfg, state: ModelState, rx: mpsc::Receiver<Request>) -> Result<ServerStats> {
    let engine = Engine::open(&cfg.artifacts)?;
    let minfo = engine.manifest.model(&state.model).clone();
    let tinfo = engine.manifest.task(&state.model, &state.task).clone();
    let b = engine.manifest.batch_eval.min(cfg.max_batch.max(1));
    let art = format!("{}__{}__fwd", state.model, state.task);
    let exe = engine.executable(&art)?;
    let graph_b = engine.manifest.batch_eval;
    let (hm, fm) = mask_literals(&state)?;
    let params = lit_f32_shaped(&[tinfo.n_params], &state.params)?;
    let n_out: usize = {
        let a = engine
            .manifest
            .artifacts
            .get(&art)
            .ok_or_else(|| anyhow!("missing fwd artifact {art}"))?;
        a.outputs[0].shape.iter().product::<usize>() / graph_b
    };
    let mut stats = ServerStats::default();
    // batching loop: block for the first request, then greedily fill
    // the batch up to `b` or until max_wait elapses (dynamic batching)
    'outer: loop {
        let first = match rx.recv() {
            Ok(r) => r,
            Err(_) => break 'outer, // all senders dropped: shutdown
        };
        let mut batch = vec![first];
        let deadline = Instant::now() + cfg.max_wait;
        while batch.len() < b {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(r) => batch.push(r),
                Err(mpsc::RecvTimeoutError::Timeout) => break,
                Err(mpsc::RecvTimeoutError::Disconnected) => break,
            }
        }
        // pad to the graph batch (XLA shapes are static)
        let t0 = Instant::now();
        let ids = pad_ids(batch.iter().map(|r| r.ids.as_slice()), graph_b, minfo.seq_len);
        let out = Engine::run_exe(
            &exe,
            &[params.clone(), lit_i32(&[graph_b, minfo.seq_len], &ids)?, hm.clone(), fm.clone()],
        )?;
        let logits = lit_to_f32(&out[0])?;
        let exec = t0.elapsed();
        stats.busy_time += exec;
        stats.batches += 1;
        for (k, r) in batch.iter().enumerate() {
            stats.requests += 1;
            let _ = r.reply.send(Reply {
                logits: logits[k * n_out..(k + 1) * n_out].to_vec(),
                queue_time: t0.duration_since(r.submitted),
                batch_size: batch.len(),
                latency: r.submitted.elapsed(),
            });
        }
    }
    Ok(stats)
}

#[cfg(test)]
#[allow(clippy::disallowed_methods)]
mod tests {
    // The serving loop needs real artifacts; covered by
    // rust/tests/integration_pipeline.rs. Here we only test pure logic.

    #[test]
    fn pad_ids_static_shape() {
        let a = vec![1, 2, 3];
        let b = vec![4];
        let ids = super::pad_ids([a.as_slice(), b.as_slice()].into_iter(), 3, 4);
        assert_eq!(ids, vec![1, 2, 3, 0, 4, 0, 0, 0, 0, 0, 0, 0]);
    }

    #[test]
    fn server_cfg_defaults_sane() {
        let cfg = super::ServerCfg {
            artifacts: std::path::PathBuf::from("artifacts"),
            max_batch: 8,
            max_wait: std::time::Duration::from_millis(2),
        };
        assert!(cfg.max_batch > 0);
    }
}
