//! Inference coordinator: a request-serving front end over the PJRT
//! runtime, used to measure the paper's two deployment regimes
//! (§4, "pruning for throughput" vs "pruning for latency") on real
//! executions rather than table estimates.
//!
//! Architecture (vLLM-router-like, scaled to one box):
//!   * clients submit `Request`s over an mpsc channel;
//!   * a dedicated worker thread owns the `Engine` + model state (PJRT
//!     handles are not `Send`, so the engine lives entirely inside the
//!     worker);
//!   * a dynamic batcher collects up to `max_batch` requests or
//!     `max_wait` and executes one padded fwd per batch;
//!   * per-request latency + aggregate throughput come back with each
//!     reply.
//!
//! tokio is unavailable offline; std threads + channels implement the
//! same event loop (DESIGN.md §4).

use std::path::PathBuf;
use std::sync::mpsc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::eval::mask_literals;
use crate::models::ModelState;
use crate::runtime::{lit_f32_shaped, lit_i32, lit_to_f32, Engine};

pub struct Request {
    pub ids: Vec<i32>,
    pub submitted: Instant,
    pub reply: mpsc::Sender<Reply>,
}

#[derive(Clone, Debug)]
pub struct Reply {
    /// task logits for this example
    pub logits: Vec<f32>,
    pub queue_time: Duration,
    pub batch_size: usize,
    pub latency: Duration,
}

pub struct ServerCfg {
    pub artifacts: PathBuf,
    pub max_batch: usize,
    pub max_wait: Duration,
}

pub struct ServerHandle {
    tx: Option<mpsc::Sender<Request>>,
    worker: Option<JoinHandle<Result<ServerStats>>>,
}

#[derive(Clone, Debug, Default)]
pub struct ServerStats {
    pub requests: usize,
    pub batches: usize,
    pub busy_time: Duration,
}

impl ServerHandle {
    pub fn submit(&self, ids: Vec<i32>) -> Result<mpsc::Receiver<Reply>> {
        let (rtx, rrx) = mpsc::channel();
        self.tx
            .as_ref()
            .ok_or_else(|| anyhow!("server stopped"))?
            .send(Request { ids, submitted: Instant::now(), reply: rtx })
            .map_err(|_| anyhow!("server gone"))?;
        Ok(rrx)
    }

    /// Submit and wait (convenience).
    pub fn infer(&self, ids: Vec<i32>) -> Result<Reply> {
        let rx = self.submit(ids)?;
        Ok(rx.recv()?)
    }

    pub fn shutdown(mut self) -> Result<ServerStats> {
        drop(self.tx.take());
        self.worker
            .take()
            .ok_or_else(|| anyhow!("already stopped"))?
            .join()
            .map_err(|_| anyhow!("worker panicked"))?
    }
}

/// Start the serving worker for a (masked) checkpoint.
pub fn start(cfg: ServerCfg, state: ModelState) -> ServerHandle {
    let (tx, rx) = mpsc::channel::<Request>();
    let worker = std::thread::Builder::new()
        .name("ziplm-server".into())
        .spawn(move || serve_loop(cfg, state, rx))
        .expect("spawn server");
    ServerHandle { tx: Some(tx), worker: Some(worker) }
}

fn serve_loop(cfg: ServerCfg, state: ModelState, rx: mpsc::Receiver<Request>) -> Result<ServerStats> {
    let engine = Engine::open(&cfg.artifacts)?;
    let minfo = engine.manifest.model(&state.model).clone();
    let tinfo = engine.manifest.task(&state.model, &state.task).clone();
    let b = engine.manifest.batch_eval.min(cfg.max_batch.max(1));
    let art = format!("{}__{}__fwd", state.model, state.task);
    let exe = engine.executable(&art)?;
    let graph_b = engine.manifest.batch_eval;
    let (hm, fm) = mask_literals(&state)?;
    let params = lit_f32_shaped(&[tinfo.n_params], &state.params)?;
    let n_out: usize = {
        let a = engine.manifest.artifacts.get(&art).unwrap();
        a.outputs[0].shape.iter().product::<usize>() / graph_b
    };
    let mut stats = ServerStats::default();
    // batching loop: block for the first request, then greedily fill
    // the batch up to `b` or until max_wait elapses (dynamic batching)
    'outer: loop {
        let first = match rx.recv() {
            Ok(r) => r,
            Err(_) => break 'outer, // all senders dropped: shutdown
        };
        let mut batch = vec![first];
        let deadline = Instant::now() + cfg.max_wait;
        while batch.len() < b {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(r) => batch.push(r),
                Err(mpsc::RecvTimeoutError::Timeout) => break,
                Err(mpsc::RecvTimeoutError::Disconnected) => break,
            }
        }
        // pad to the graph batch (XLA shapes are static)
        let t0 = Instant::now();
        let mut ids = Vec::with_capacity(graph_b * minfo.seq_len);
        for r in &batch {
            let mut v = r.ids.clone();
            v.resize(minfo.seq_len, 0);
            ids.extend_from_slice(&v);
        }
        ids.resize(graph_b * minfo.seq_len, 0);
        let out = Engine::run_exe(
            &exe,
            &[params.clone(), lit_i32(&[graph_b, minfo.seq_len], &ids)?, hm.clone(), fm.clone()],
        )?;
        let logits = lit_to_f32(&out[0])?;
        let exec = t0.elapsed();
        stats.busy_time += exec;
        stats.batches += 1;
        for (k, r) in batch.iter().enumerate() {
            stats.requests += 1;
            let _ = r.reply.send(Reply {
                logits: logits[k * n_out..(k + 1) * n_out].to_vec(),
                queue_time: t0.duration_since(r.submitted),
                batch_size: batch.len(),
                latency: r.submitted.elapsed(),
            });
        }
    }
    Ok(stats)
}

#[cfg(test)]
mod tests {
    // The serving loop needs real artifacts; covered by
    // rust/tests/integration_pipeline.rs. Here we only test pure logic.

    #[test]
    fn server_cfg_defaults_sane() {
        let cfg = super::ServerCfg {
            artifacts: std::path::PathBuf::from("artifacts"),
            max_batch: 8,
            max_wait: std::time::Duration::from_millis(2),
        };
        assert!(cfg.max_batch > 0);
    }
}
