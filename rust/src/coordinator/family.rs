//! Model-family serving: one SLA-aware front end over a whole ZipLM
//! family (paper §3.2, App. F; DESIGN.md §6).
//!
//! ZipLM's gradual run emits a *family* of checkpoints — dense plus
//! one member per speedup target, each certified against a latency
//! table. This module serves the entire family behind a single
//! request front end:
//!
//! * clients submit ids plus an optional per-request [`Sla`];
//! * a router assigns each request to a family member: the most
//!   accurate member whose certified speedup and
//!   [`InferenceEnv`]-priced admission estimate satisfy the SLA, or
//!   the fastest member when
//!   nothing qualifies or total backlog crosses the pressure
//!   threshold. The env is the one the pruning session certified the
//!   family against — since manifests embed it
//!   ([`crate::models::family::FamilyManifest::env`]), `serve-family`
//!   passes the *loaded* value here rather than re-measuring, so
//!   certification and admission cannot diverge even across machines;
//! * each member has its own dynamic-batch queue, drained by the one
//!   worker thread that owns the PJRT engine (handles are not `Send`,
//!   exactly as in the single-model loop, DESIGN.md §4);
//! * every member of one (model, task) shares the masked `fwd` graph,
//!   so the engine's [`crate::runtime::CompileCache`] compiles it once
//!   for the whole family — build/hit counts come back in
//!   [`FamilyStats`].
//!
//! Routing is a pure function ([`route`]) over [`MemberRoute`] data so
//! the policy is unit-testable without artifacts or PJRT.

use std::collections::VecDeque;
use std::path::PathBuf;
use std::sync::mpsc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::env::{CostModel, InferenceEnv};
use crate::eval::mask_literals;
use crate::models::ModelState;
use crate::runtime::{lit_f32_shaped, lit_i32, lit_to_f32, Engine};

/// Per-request service-level agreement. All bounds are optional; an
/// absent bound never excludes a member.
#[derive(Clone, Debug, Default)]
pub struct Sla {
    /// workload-class label used for per-class reporting
    pub class: String,
    /// admission bound on estimated end-to-end latency (queue + exec)
    pub max_latency: Option<Duration>,
    /// minimum certified family-member speedup (cost ceiling)
    pub min_speedup: Option<f64>,
}

/// A queued family request (internal; built by [`FamilyHandle::submit`]).
pub struct FamilyRequest {
    /// token ids (padded to the graph's seq_len by the worker)
    pub ids: Vec<i32>,
    /// optional routing constraints
    pub sla: Option<Sla>,
    /// submission timestamp (queue-time accounting)
    pub submitted: Instant,
    /// reply channel
    pub reply: mpsc::Sender<FamilyReply>,
}

/// Reply for one family request.
#[derive(Clone, Debug)]
pub struct FamilyReply {
    /// task logits for this example
    pub logits: Vec<f32>,
    /// tag of the family member that served the request
    pub member: String,
    /// certified speedup of that member
    pub member_speedup: f64,
    /// time spent queued before the batch launched
    pub queue_time: Duration,
    /// number of real requests in the executed batch
    pub batch_size: usize,
    /// end-to-end latency (submit → reply)
    pub latency: Duration,
}

/// Family-coordinator configuration.
pub struct FamilyCfg {
    /// artifact directory (manifest.json + HLO files)
    pub artifacts: PathBuf,
    /// max requests per executed batch (clamped to the graph batch)
    pub max_batch: usize,
    /// how long a batch waits for stragglers before launching
    pub max_wait: Duration,
    /// total backlog (requests queued across all members) at which
    /// routing falls back to the fastest member; 0 disables
    pub pressure: usize,
}

/// Routing view of one family member: pure data (priced from the
/// family's [`InferenceEnv`] at startup), so the routing policy can be
/// exercised without PJRT.
#[derive(Clone, Debug)]
pub struct MemberRoute {
    /// member tag (diagnostics)
    pub tag: String,
    /// certified speedup from the latency table (dense = 1.0)
    pub est_speedup: f64,
    /// latency-table estimate of one batched forward of this member
    pub est_batch_time: f64,
}

/// Pick the member index for a request.
///
/// `members` must be sorted by ascending `est_speedup` (most accurate
/// first) and `depths[i]` is the current queue length of member `i`.
/// Policy, in order:
///
/// 1. total backlog ≥ `pressure` (and pressure enabled) → fastest
///    member, regardless of SLA — the overload escape hatch;
/// 2. no SLA → most accurate member;
/// 3. otherwise the FIRST (most accurate) member with
///    `est_speedup ≥ min_speedup` whose admission estimate fits
///    inside `max_latency`;
/// 4. no member qualifies → fastest member (best effort).
///
/// The admission estimate models the single engine-owning worker:
/// every batch already queued on ANY member is older than this
/// request and will be served first (oldest-head scheduling), so the
/// estimate is the table-priced sum of all pending batches plus the
/// marginal batch this request adds to member `i`'s queue.
pub fn route(
    sla: Option<&Sla>,
    members: &[MemberRoute],
    depths: &[usize],
    max_batch: usize,
    pressure: usize,
) -> usize {
    debug_assert_eq!(members.len(), depths.len());
    let fastest = members.len() - 1;
    if pressure > 0 && depths.iter().sum::<usize>() >= pressure {
        return fastest;
    }
    let Some(sla) = sla else { return 0 };
    let b = max_batch.max(1);
    // worker time already committed, across ALL queues
    let pending: f64 = members
        .iter()
        .zip(depths)
        .map(|(m, &d)| d.div_ceil(b) as f64 * m.est_batch_time)
        .sum();
    for (i, (m, &depth)) in members.iter().zip(depths).enumerate() {
        if let Some(min_s) = sla.min_speedup {
            if m.est_speedup + 1e-9 < min_s {
                continue;
            }
        }
        if let Some(max_l) = sla.max_latency {
            // batches member i must run that it wouldn't have without us
            let marginal = ((depth + 1).div_ceil(b) - depth.div_ceil(b)) as f64 * m.est_batch_time;
            if pending + marginal > max_l.as_secs_f64() {
                continue;
            }
        }
        return i;
    }
    fastest
}

/// Aggregate serving statistics returned by [`FamilyHandle::shutdown`].
#[derive(Clone, Debug, Default)]
pub struct FamilyStats {
    /// total requests served
    pub requests: usize,
    /// total executed batches
    pub batches: usize,
    /// cumulative execution time
    pub busy_time: Duration,
    /// requests served per member, in router order
    pub per_member: Vec<(String, usize)>,
    /// requests rerouted to the fastest member by queue pressure
    pub pressure_reroutes: usize,
    /// executable-cache builds — at most one per shared graph,
    /// however many members the family has
    pub cache_builds: usize,
    /// executable-cache hits
    pub cache_hits: usize,
}

/// Handle to a running family coordinator.
pub struct FamilyHandle {
    tx: Option<mpsc::Sender<FamilyRequest>>,
    worker: Option<JoinHandle<Result<FamilyStats>>>,
}

impl FamilyHandle {
    /// Enqueue a request; the receiver yields the [`FamilyReply`].
    pub fn submit(&self, ids: Vec<i32>, sla: Option<Sla>) -> Result<mpsc::Receiver<FamilyReply>> {
        let (rtx, rrx) = mpsc::channel();
        self.tx
            .as_ref()
            .ok_or_else(|| anyhow!("family server stopped"))?
            .send(FamilyRequest { ids, sla, submitted: Instant::now(), reply: rtx })
            .map_err(|_| anyhow!("family server gone"))?;
        Ok(rrx)
    }

    /// Submit and wait (convenience).
    pub fn infer(&self, ids: Vec<i32>, sla: Option<Sla>) -> Result<FamilyReply> {
        let rx = self.submit(ids, sla)?;
        Ok(rx.recv()?)
    }

    /// Stop accepting requests, flush all queues, and return stats.
    pub fn shutdown(mut self) -> Result<FamilyStats> {
        drop(self.tx.take());
        self.worker
            .take()
            .ok_or_else(|| anyhow!("already stopped"))?
            .join()
            .map_err(|_| anyhow!("family worker panicked"))?
    }
}

struct MemberSpec {
    tag: String,
    state: ModelState,
    route: MemberRoute,
}

/// Start the family coordinator over `members` (tag, checkpoint).
///
/// All members must share one (model, task); their per-layer profiles
/// are read from the checkpoint masks and priced with `env` — the same
/// [`InferenceEnv`] the pruning session certified the members against,
/// so admission estimates cannot silently diverge from certification.
/// Members are served in ascending-speedup order (index 0 = most
/// accurate).
pub fn start(
    cfg: FamilyCfg,
    members: Vec<(String, ModelState)>,
    env: &InferenceEnv,
) -> Result<FamilyHandle> {
    if members.is_empty() {
        return Err(anyhow!("family must have at least one member"));
    }
    let (model, task) = (members[0].1.model.clone(), members[0].1.task.clone());
    let mut specs: Vec<MemberSpec> = Vec::with_capacity(members.len());
    for (tag, state) in members {
        if state.model != model || state.task != task {
            return Err(anyhow!(
                "family member `{tag}` is {}/{}, expected {model}/{task}",
                state.model,
                state.task
            ));
        }
        let profile = state.masks.summary();
        let route = MemberRoute {
            tag: tag.clone(),
            est_speedup: env.speedup(&profile),
            est_batch_time: env.model_time(&profile),
        };
        specs.push(MemberSpec { tag, state, route });
    }
    specs.sort_by(|a, b| a.route.est_speedup.partial_cmp(&b.route.est_speedup).unwrap());
    let (tx, rx) = mpsc::channel::<FamilyRequest>();
    let worker = std::thread::Builder::new()
        .name("ziplm-family".into())
        .spawn(move || serve_family_loop(cfg, specs, rx))
        .expect("spawn family server");
    Ok(FamilyHandle { tx: Some(tx), worker: Some(worker) })
}

fn serve_family_loop(
    cfg: FamilyCfg,
    specs: Vec<MemberSpec>,
    rx: mpsc::Receiver<FamilyRequest>,
) -> Result<FamilyStats> {
    let engine = Engine::open(&cfg.artifacts)?;
    let (model, task) = (specs[0].state.model.clone(), specs[0].state.task.clone());
    let minfo = engine.manifest.model(&model).clone();
    let b = engine.manifest.batch_eval.min(cfg.max_batch.max(1));
    let graph_b = engine.manifest.batch_eval;
    let art = format!("{model}__{task}__fwd");
    let n_out: usize = {
        let a = engine
            .manifest
            .artifacts
            .get(&art)
            .ok_or_else(|| anyhow!("missing fwd artifact {art}"))?;
        a.outputs[0].shape.iter().product::<usize>() / graph_b
    };
    // Per-member device literals, built once.
    let mut lits = Vec::with_capacity(specs.len());
    for s in &specs {
        let (hm, fm) = mask_literals(&s.state)?;
        let params = lit_f32_shaped(&[s.state.params.len()], &s.state.params)?;
        lits.push((params, hm, fm));
    }
    let routes: Vec<MemberRoute> = specs.iter().map(|s| s.route.clone()).collect();
    let mut queues: Vec<VecDeque<FamilyRequest>> = specs.iter().map(|_| VecDeque::new()).collect();
    let mut served = vec![0usize; specs.len()];
    let mut stats = FamilyStats::default();
    let mut open = true;

    fn enqueue(
        req: FamilyRequest,
        routes: &[MemberRoute],
        queues: &mut [VecDeque<FamilyRequest>],
        max_batch: usize,
        pressure: usize,
        stats: &mut FamilyStats,
    ) {
        let depths: Vec<usize> = queues.iter().map(VecDeque::len).collect();
        let under_pressure = pressure > 0 && depths.iter().sum::<usize>() >= pressure;
        let i = route(req.sla.as_ref(), routes, &depths, max_batch, pressure);
        if under_pressure && i == routes.len() - 1 {
            stats.pressure_reroutes += 1;
        }
        queues[i].push_back(req);
    }

    // Serve until the channel closes AND every queue is flushed.
    while open || queues.iter().any(|q| !q.is_empty()) {
        // drain everything already waiting on the channel
        loop {
            match rx.try_recv() {
                Ok(r) => enqueue(r, &routes, &mut queues, b, cfg.pressure, &mut stats),
                Err(mpsc::TryRecvError::Empty) => break,
                Err(mpsc::TryRecvError::Disconnected) => {
                    open = false;
                    break;
                }
            }
        }
        if queues.iter().all(|q| q.is_empty()) {
            if !open {
                break;
            }
            // idle: block for the next request (or shutdown)
            match rx.recv() {
                Ok(r) => enqueue(r, &routes, &mut queues, b, cfg.pressure, &mut stats),
                Err(_) => open = false,
            }
            continue;
        }
        // serve the member whose head request has waited longest
        let mi = queues
            .iter()
            .enumerate()
            .filter(|(_, q)| !q.is_empty())
            .min_by_key(|(_, q)| q.front().map(|r| r.submitted).unwrap_or_else(Instant::now))
            .map(|(i, _)| i)
            .unwrap_or(0);
        // dynamic batching: let stragglers join this member's batch
        if open {
            let deadline = Instant::now() + cfg.max_wait;
            while queues[mi].len() < b {
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                match rx.recv_timeout(deadline - now) {
                    Ok(r) => enqueue(r, &routes, &mut queues, b, cfg.pressure, &mut stats),
                    Err(mpsc::RecvTimeoutError::Timeout) => break,
                    Err(mpsc::RecvTimeoutError::Disconnected) => {
                        open = false;
                        break;
                    }
                }
            }
        }
        let take = queues[mi].len().min(b);
        let batch: Vec<FamilyRequest> = queues[mi].drain(..take).collect();
        // pad to the static graph batch and execute with this member's
        // params/masks; the compiled fwd executable is shared by every
        // member (one cache key), so only the first batch compiles
        let t0 = Instant::now();
        let ids =
            super::pad_ids(batch.iter().map(|r| r.ids.as_slice()), graph_b, minfo.seq_len);
        let (params, hm, fm) = &lits[mi];
        let exe = engine.executable(&art)?;
        let out = Engine::run_exe(
            &exe,
            &[params.clone(), lit_i32(&[graph_b, minfo.seq_len], &ids)?, hm.clone(), fm.clone()],
        )?;
        let logits = lit_to_f32(&out[0])?;
        stats.busy_time += t0.elapsed();
        stats.batches += 1;
        served[mi] += batch.len();
        for (k, r) in batch.iter().enumerate() {
            stats.requests += 1;
            let _ = r.reply.send(FamilyReply {
                logits: logits[k * n_out..(k + 1) * n_out].to_vec(),
                member: specs[mi].tag.clone(),
                member_speedup: specs[mi].route.est_speedup,
                queue_time: t0.duration_since(r.submitted),
                batch_size: batch.len(),
                latency: r.submitted.elapsed(),
            });
        }
    }
    let (builds, hits) = engine.cache_stats();
    stats.cache_builds = builds;
    stats.cache_hits = hits;
    stats.per_member =
        specs.iter().zip(&served).map(|(s, &n)| (s.tag.clone(), n)).collect();
    Ok(stats)
}

// ------------------------------------------------------------ reporting

/// Per-class latency/SLA report (client-side aggregation).
#[derive(Clone, Debug)]
pub struct ClassReport {
    /// workload-class label
    pub class: String,
    /// requests in the class
    pub n: usize,
    /// median end-to-end latency
    pub p50: Duration,
    /// 99th-percentile end-to-end latency
    pub p99: Duration,
    /// fraction of requests whose latency met their SLA bound
    pub hit_rate: f64,
}

/// Aggregate `(class, latency, sla_hit)` rows into per-class reports,
/// sorted by class name.
pub fn summarize(rows: &[(String, Duration, bool)]) -> Vec<ClassReport> {
    use std::collections::BTreeMap;
    let mut by: BTreeMap<&str, (Vec<f64>, usize)> = BTreeMap::new();
    for (class, lat, hit) in rows {
        let e = by.entry(class.as_str()).or_default();
        e.0.push(lat.as_secs_f64());
        e.1 += usize::from(*hit);
    }
    by.into_iter()
        .map(|(class, (mut lats, hits))| {
            lats.sort_by(|a, b| a.partial_cmp(b).unwrap());
            ClassReport {
                class: class.to_string(),
                n: lats.len(),
                p50: Duration::from_secs_f64(percentile(&lats, 0.50)),
                p99: Duration::from_secs_f64(percentile(&lats, 0.99)),
                hit_rate: hits as f64 / lats.len().max(1) as f64,
            }
        })
        .collect()
}

/// Nearest-rank percentile of an ascending-sorted slice (q in [0, 1]).
fn percentile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((sorted.len() - 1) as f64 * q).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{ArtifactKey, CompileCache};

    fn routes() -> Vec<MemberRoute> {
        // sorted ascending by speedup, as `start` guarantees
        vec![
            MemberRoute { tag: "dense".into(), est_speedup: 1.0, est_batch_time: 80e-3 },
            MemberRoute { tag: "2x".into(), est_speedup: 2.1, est_batch_time: 38e-3 },
            MemberRoute { tag: "4x".into(), est_speedup: 4.3, est_batch_time: 19e-3 },
        ]
    }

    fn sla(max_ms: Option<u64>, min_speedup: Option<f64>) -> Sla {
        Sla {
            class: "t".into(),
            max_latency: max_ms.map(Duration::from_millis),
            min_speedup,
        }
    }

    #[test]
    fn route_no_sla_prefers_most_accurate() {
        assert_eq!(route(None, &routes(), &[0, 0, 0], 8, 0), 0);
    }

    #[test]
    fn route_min_speedup_picks_most_accurate_qualifier() {
        let s = sla(None, Some(2.0));
        assert_eq!(route(Some(&s), &routes(), &[0, 0, 0], 8, 0), 1);
        let s = sla(None, Some(4.0));
        assert_eq!(route(Some(&s), &routes(), &[0, 0, 0], 8, 0), 2);
        // unsatisfiable → fastest (best effort)
        let s = sla(None, Some(9.0));
        assert_eq!(route(Some(&s), &routes(), &[0, 0, 0], 8, 0), 2);
    }

    #[test]
    fn route_max_latency_uses_queue_depth_admission_estimate() {
        // 100ms bound: dense (80ms) fits when idle
        let s = sla(Some(100), None);
        assert_eq!(route(Some(&s), &routes(), &[0, 0, 0], 8, 0), 0);
        // 16 dense requests = 2 pending batches (160ms of worker time):
        // dense adds its own 3rd batch (240ms > 200) but the 2x member
        // rides the backlog at 160 + 38 = 198ms ≤ 200 → spill to 2x
        let s = sla(Some(200), None);
        assert_eq!(route(Some(&s), &routes(), &[16, 0, 0], 8, 0), 1);
        // tighter 185ms bound also excludes 2x (198) → 4x (179)
        let s = sla(Some(185), None);
        assert_eq!(route(Some(&s), &routes(), &[16, 0, 0], 8, 0), 2);
        // a bound nothing meets even idle → fastest
        let s = sla(Some(5), None);
        assert_eq!(route(Some(&s), &routes(), &[0, 0, 0], 8, 0), 2);
    }

    #[test]
    fn route_admission_counts_cross_queue_backlog() {
        // One worker serves every queue oldest-first, so a 16-deep 2x
        // queue (2 × 38ms pending) delays dense too: dense estimates
        // 76 + 80 = 156ms > 100 even though its own queue is empty;
        // joining the 2x backlog adds a whole batch (76 + 38 = 114);
        // only 4x (76 + 19 = 95ms) admits under a 100ms bound.
        let s = sla(Some(100), None);
        assert_eq!(route(Some(&s), &routes(), &[0, 16, 0], 8, 0), 2);
    }

    #[test]
    fn route_pressure_overrides_everything() {
        let s = sla(Some(1_000), Some(1.0)); // dense would qualify
        assert_eq!(route(Some(&s), &routes(), &[4, 4, 4], 8, 12), 2);
        assert_eq!(route(None, &routes(), &[12, 0, 0], 8, 12), 2);
        // pressure disabled (0) → normal policy
        assert_eq!(route(None, &routes(), &[12, 0, 0], 8, 0), 0);
    }

    #[test]
    fn route_combined_speedup_and_latency_constraints() {
        // min_speedup 2 excludes dense; 30ms bound excludes 2x (38ms)
        let s = sla(Some(30), Some(2.0));
        assert_eq!(route(Some(&s), &routes(), &[0, 0, 0], 8, 0), 2);
    }

    #[test]
    fn summarize_percentiles_and_hit_rate() {
        let ms = Duration::from_millis;
        let mut rows = Vec::new();
        for i in 1..=100u64 {
            rows.push(("a".to_string(), ms(i), i <= 90));
        }
        rows.push(("b".to_string(), ms(7), true));
        let reps = summarize(&rows);
        assert_eq!(reps.len(), 2);
        let a = &reps[0];
        assert_eq!(a.class, "a");
        assert_eq!(a.n, 100);
        assert!((a.hit_rate - 0.90).abs() < 1e-9);
        assert!(a.p50 >= ms(49) && a.p50 <= ms(52), "{:?}", a.p50);
        assert!(a.p99 >= ms(98), "{:?}", a.p99);
        let b = &reps[1];
        assert_eq!((b.n, b.p50, b.hit_rate), (1, ms(7), 1.0));
    }

    #[test]
    fn family_members_share_one_compiled_artifact() {
        // Acceptance: each compiled artifact is built at most once
        // across the family. All masked variants of one (model, task)
        // map to the same (artifact, batch-shape) cache key, so N
        // members × M requests produce exactly one build; a
        // shape-specialized variant gets its own key and one build.
        let cache: CompileCache<&'static str> = CompileCache::new();
        let shared = ArtifactKey::new("bert__sst2__fwd", 8, 128);
        for _member in 0..3 {
            for _req in 0..4 {
                let exe = cache.get_or_build(&shared.encode(), || Ok("exe")).unwrap();
                assert_eq!(*exe, "exe");
            }
        }
        assert_eq!(cache.builds(), 1, "shared graph compiled more than once");
        assert_eq!(cache.hits(), 11);
        let spec = ArtifactKey::new("spec_bert_sst2_4x", 8, 128);
        cache.get_or_build(&spec.encode(), || Ok("spec")).unwrap();
        assert_eq!(cache.builds(), 2);
    }

    #[test]
    fn start_rejects_empty_and_mixed_families() {
        let env = InferenceEnv::measured(crate::latency::LatencyTable {
            model: "m".into(),
            device: "test".into(),
            regime: "throughput".into(),
            attn: vec![0.0, 1e-3, 2e-3],
            mlp: vec![(8, 4e-3), (0, 0.0)],
            overhead: 1e-3,
        })
        .unwrap();
        let cfg = || FamilyCfg {
            artifacts: std::path::PathBuf::from("artifacts"),
            max_batch: 8,
            max_wait: Duration::from_millis(1),
            pressure: 0,
        };
        assert!(start(cfg(), vec![], &env).is_err());
        // members disagreeing on (model, task) are rejected up front
        let (mi, ti, _st) = crate::models::tests_support::mini_state();
        let a = crate::models::ModelState::init(&mi, "task-a", &ti, 0);
        let b = crate::models::ModelState::init(&mi, "task-b", &ti, 1);
        assert!(start(cfg(), vec![("a".into(), a), ("b".into(), b)], &env).is_err());
    }
}
