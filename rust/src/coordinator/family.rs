//! Model-family serving: one SLA-aware front end over a whole ZipLM
//! family (paper §3.2, App. F; DESIGN.md §6 and, for the
//! realized-speedup serving path, §9).
//!
//! ZipLM's gradual run emits a *family* of checkpoints — dense plus
//! one member per speedup target, each certified against a latency
//! table. This module serves the entire family behind a single
//! request front end:
//!
//! * clients submit ids plus an optional per-request [`Sla`];
//! * a router assigns each request to a family member: the most
//!   accurate member whose certified speedup and
//!   [`InferenceEnv`]-priced admission estimate satisfy the SLA, or
//!   the fastest member when
//!   nothing qualifies or total backlog crosses the pressure
//!   threshold. The env is the one the pruning session certified the
//!   family against — since manifests embed it
//!   ([`crate::models::family::FamilyManifest::env`]), `serve-family`
//!   passes the *loaded* value here rather than re-measuring, so
//!   certification and admission cannot diverge even across machines;
//! * each member has its own dynamic-batch queue, drained by the one
//!   worker thread that owns the PJRT engine (handles are not `Send`,
//!   exactly as in the single-model loop, DESIGN.md §4);
//! * every member of one (model, task) shares the masked `fwd` graph,
//!   so the engine's [`crate::runtime::CompileCache`] compiles it once
//!   for the whole family — build/hit counts come back in
//!   [`FamilyStats`].
//!
//! Two mechanisms close the certify-vs-realize gap (DESIGN.md §9):
//!
//! * **Shape-specialized executables.** [`FamilyCfg::buckets`] carries
//!   a [`BucketLadder`] of `(batch, padded seq)` serving shapes. Each
//!   executed batch is assigned the smallest covering bucket, and the
//!   worker lazily compiles a per-(member, bucket) specialized export
//!   (gathered weights, materialized shapes — the same files
//!   `aot.py --specialize` writes for Table 8) behind a
//!   [`crate::runtime::ArtifactKey`] in the shared compile cache. The
//!   FIRST batch that hits a cold (member, bucket) pair is served by
//!   the generic masked executable and the specialization compiles
//!   after its replies go out — the triggering batch never pays the
//!   compile, and later-queued requests absorb at most one compile per
//!   (member, bucket) pair (the engine-owning worker is
//!   single-threaded by the PJRT `Send` constraint, DESIGN.md §4, so
//!   warm-up cannot move off-thread). A pair whose export file is
//!   absent is re-probed with one cheap `stat` per batch — exports
//!   generated while serving are picked up — and a pair whose export
//!   fails to compile or execute (e.g. stale against the member's
//!   current masks) is quarantined: that shape serves generic from
//!   then on instead of killing the worker. Every later batch at a
//!   warm shape runs the specialized executable at the speed the
//!   pruner certified.
//! * **Cross-SLA batch coalescing.** [`route_batch`] — pure, like
//!   [`route`] — merges the oldest queued requests ACROSS SLA classes
//!   into one shaped batch when a single member's admission estimate
//!   still meets every merged request's deadline and speedup floor; a
//!   merge that would break any constituent is refused and the worker
//!   falls back to the per-member batch.
//!
//! [`FamilyStats::per_bucket`] reports the *realized* per-bucket
//! execution p50/p99 next to the env's certified estimate, so the
//! certify-vs-realize gap is a number the `family` experiment and
//! `examples/family_serving.rs` print instead of a caveat.
//!
//! Routing stays pure-function territory ([`route`], [`route_batch`],
//! [`aggregate_buckets`]) over [`MemberRoute`]/[`BucketSample`] data so
//! every policy is unit-testable without artifacts or PJRT.

use std::collections::{BTreeMap, HashSet, VecDeque};
use std::path::PathBuf;
use std::sync::mpsc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::env::{CostModel, InferenceEnv};
use crate::eval::mask_literals;
use crate::models::{gather_specialized, ModelState};
use crate::runtime::{lit_f32_shaped, lit_i32, lit_to_f32, ArtifactKey, Engine};
use crate::util::json::Json;

/// Per-request service-level agreement. All bounds are optional; an
/// absent bound never excludes a member.
#[derive(Clone, Debug, Default)]
pub struct Sla {
    /// workload-class label used for per-class reporting
    pub class: String,
    /// admission bound on estimated end-to-end latency (queue + exec)
    pub max_latency: Option<Duration>,
    /// minimum certified family-member speedup (cost ceiling)
    pub min_speedup: Option<f64>,
}

/// A queued family request (internal; built by [`FamilyHandle::submit`]).
pub struct FamilyRequest {
    /// token ids (padded to the executed shape by the worker)
    pub ids: Vec<i32>,
    /// optional routing constraints
    pub sla: Option<Sla>,
    /// submission timestamp (queue-time accounting)
    pub submitted: Instant,
    /// reply channel
    pub reply: mpsc::Sender<FamilyReply>,
}

/// Reply for one family request.
#[derive(Clone, Debug)]
pub struct FamilyReply {
    /// task logits for this example
    pub logits: Vec<f32>,
    /// tag of the family member that served the request
    pub member: String,
    /// certified speedup of that member
    pub member_speedup: f64,
    /// time spent queued before the batch launched
    pub queue_time: Duration,
    /// number of real requests in the executed batch
    pub batch_size: usize,
    /// end-to-end latency (submit → reply)
    pub latency: Duration,
    /// `(batch, seq)` shape the batch executed at (the graph anchor
    /// when no bucket applied)
    pub bucket: (usize, usize),
    /// whether a shape-specialized executable served the batch
    pub specialized: bool,
}

// ------------------------------------------------------------- buckets

/// Ladder of serving shape buckets `(batch, padded seq)` (DESIGN.md §9).
///
/// Buckets are the shapes specialized executables are lowered at; a
/// batch of `n` requests with max raw length `len` executes at the
/// smallest bucket covering `(n, len)` — smallest padded seq first,
/// then smallest batch, so padding waste is minimized. An empty ladder
/// means generic-only serving (every batch pads to the graph anchor),
/// which is exactly the pre-§9 coordinator.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct BucketLadder {
    buckets: Vec<(usize, usize)>,
}

impl BucketLadder {
    /// Build a ladder: zero-dimension buckets are dropped, the rest
    /// sorted ascending by `(seq, batch)` and deduplicated.
    pub fn new(mut buckets: Vec<(usize, usize)>) -> BucketLadder {
        buckets.retain(|&(b, s)| b > 0 && s > 0);
        buckets.sort_by_key(|&(b, s)| (s, b));
        buckets.dedup();
        BucketLadder { buckets }
    }

    /// The sorted bucket list.
    pub fn buckets(&self) -> &[(usize, usize)] {
        &self.buckets
    }

    /// Whether the ladder has no buckets (generic-only serving).
    pub fn is_empty(&self) -> bool {
        self.buckets.is_empty()
    }

    /// Smallest bucket covering a batch of `batch` requests whose
    /// longest row is `seq` tokens; `None` when nothing covers it (the
    /// batch then pads to the generic graph shape).
    pub fn bucket_for(&self, batch: usize, seq: usize) -> Option<(usize, usize)> {
        self.buckets.iter().copied().find(|&(b, s)| b >= batch && s >= seq)
    }
}

/// Artifact id of one member's shape-specialized export — the same
/// `spec_<model>_<task>_<tag>` naming `aot.py --specialize` and
/// `exp::measure_specialized` use, so Table 8's exports and the
/// coordinator's are the same files.
pub fn spec_artifact(model: &str, task: &str, tag: &str) -> String {
    format!("spec_{model}_{task}_{tag}")
}

/// Compile-cache key of a member's specialized executable at `bucket`:
/// member tag in the artifact id, bucket in the shape half, so distinct
/// (member, bucket) pairs can never collide with each other or with the
/// shared generic key (DESIGN.md §9).
pub fn spec_key(model: &str, task: &str, tag: &str, bucket: (usize, usize)) -> ArtifactKey {
    ArtifactKey::new(spec_artifact(model, task, tag), bucket.0, bucket.1)
}

/// File name (inside [`FamilyCfg::specialized`]) holding the HLO text
/// for `key` — one materialized graph per (member, bucket).
pub fn spec_file(key: &ArtifactKey) -> String {
    format!("{}_b{}s{}.hlo.txt", key.artifact, key.batch, key.seq)
}

// ------------------------------------------------------------- config

/// Family-coordinator configuration.
pub struct FamilyCfg {
    /// artifact directory (manifest.json + HLO files)
    pub artifacts: PathBuf,
    /// max requests per executed batch (clamped to the graph batch)
    pub max_batch: usize,
    /// how long a batch waits for stragglers before launching
    pub max_wait: Duration,
    /// total backlog (requests queued across all members) at which
    /// routing falls back to the fastest member; 0 disables
    pub pressure: usize,
    /// serving shape-bucket ladder (normally the ladder the family was
    /// certified under, [`crate::models::family::FamilyManifest::buckets`]);
    /// empty = generic-only serving
    pub buckets: BucketLadder,
    /// directory of shape-specialized HLO exports ([`spec_file`] names);
    /// `None` = `<artifacts>/specialized`
    pub specialized: Option<PathBuf>,
}

/// Routing view of one family member: pure data (priced from the
/// family's [`InferenceEnv`] at startup), so the routing policies can
/// be exercised without PJRT.
#[derive(Clone, Debug)]
pub struct MemberRoute {
    /// member tag (diagnostics)
    pub tag: String,
    /// certified speedup from the latency table (dense = 1.0)
    pub est_speedup: f64,
    /// latency-table estimate of one batched forward of this member at
    /// the anchor shape
    pub est_batch_time: f64,
    /// per-bucket estimates of one batched forward, ladder order
    /// (priced by [`InferenceEnv::batch_time`] at startup); empty when
    /// serving generic-only
    pub bucket_times: Vec<((usize, usize), f64)>,
}

impl MemberRoute {
    /// Admission estimate of one batched forward at `bucket`
    /// (`None`, or a bucket the ladder never priced, falls back to the
    /// anchor estimate).
    pub fn time_at(&self, bucket: Option<(usize, usize)>) -> f64 {
        bucket
            .and_then(|bk| self.bucket_times.iter().find(|&&(b, _)| b == bk))
            .map(|&(_, t)| t)
            .unwrap_or(self.est_batch_time)
    }
}

// ------------------------------------------------------------- routing

/// Pick the member index for a request.
///
/// `members` must be sorted by ascending `est_speedup` (most accurate
/// first) and `depths[i]` is the current queue length of member `i`.
/// Policy, in order:
///
/// 1. total backlog ≥ `pressure` (and pressure enabled) → fastest
///    member, regardless of SLA — the overload escape hatch;
/// 2. no SLA → most accurate member;
/// 3. otherwise the FIRST (most accurate) member with
///    `est_speedup ≥ min_speedup` whose admission estimate fits
///    inside `max_latency`;
/// 4. no member qualifies → fastest member (best effort).
///
/// The admission estimate models the single engine-owning worker:
/// every batch already queued on ANY member is older than this
/// request and will be served first (oldest-head scheduling), so the
/// estimate is the table-priced sum of all pending batches plus the
/// marginal batch this request adds to member `i`'s queue.
pub fn route(
    sla: Option<&Sla>,
    members: &[MemberRoute],
    depths: &[usize],
    max_batch: usize,
    pressure: usize,
) -> usize {
    debug_assert_eq!(members.len(), depths.len());
    let fastest = members.len() - 1;
    if pressure > 0 && depths.iter().sum::<usize>() >= pressure {
        return fastest;
    }
    let Some(sla) = sla else { return 0 };
    let b = max_batch.max(1);
    // worker time already committed, across ALL queues
    let pending: f64 = members
        .iter()
        .zip(depths)
        .map(|(m, &d)| d.div_ceil(b) as f64 * m.est_batch_time)
        .sum();
    for (i, (m, &depth)) in members.iter().zip(depths).enumerate() {
        if let Some(min_s) = sla.min_speedup {
            if m.est_speedup + 1e-9 < min_s {
                continue;
            }
        }
        if let Some(max_l) = sla.max_latency {
            // batches member i must run that it wouldn't have without us
            let marginal = ((depth + 1).div_ceil(b) - depth.div_ceil(b)) as f64 * m.est_batch_time;
            if pending + marginal > max_l.as_secs_f64() {
                continue;
            }
        }
        return i;
    }
    fastest
}

/// One queued request as [`route_batch`] sees it: its SLA (if any),
/// its raw token length (pre-padding, for bucket selection), and how
/// long it has already waited in a queue (spent deadline budget).
#[derive(Clone, Copy, Debug)]
pub struct BatchReq<'a> {
    /// the request's routing constraints
    pub sla: Option<&'a Sla>,
    /// raw token-id length
    pub len: usize,
    /// time already spent queued (0 at submit-time routing)
    pub waited: Duration,
}

/// Decision of [`route_batch`]: serve the merged batch on `member` at
/// `bucket` (`None` = the generic graph shape).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BatchRoute {
    /// index into the member list (ascending-speedup order)
    pub member: usize,
    /// executing shape bucket, when the ladder covers the batch
    pub bucket: Option<(usize, usize)>,
}

/// Coalesce `reqs` — the oldest queued requests, possibly spanning
/// several SLA classes — into ONE shaped batch on one member, if any
/// member can honor every merged request (DESIGN.md §9).
///
/// `depths` are the queue lengths EXCLUDING the candidate requests
/// (the caller is about to pop them), so `pending` prices only the
/// work that genuinely runs before this batch. The decision rule:
///
/// 1. one request degenerates EXACTLY to [`route`] (same member, plus
///    the bucket its shape selects) — never refused;
/// 2. under pressure the merge goes to the fastest member wholesale;
/// 3. otherwise the most accurate member satisfying EVERY request is
///    chosen: each `min_speedup` floor must hold, and the member's
///    bucket-priced execution estimate plus pending backlog must fit
///    inside each request's REMAINING deadline (`max_latency` minus
///    time already waited);
/// 4. no such member → `None`: the merge is refused and the caller
///    falls back to per-member batches. Refusal is the correctness
///    half of the policy — a merge must never convert an admitted
///    request into a deadline miss.
pub fn route_batch(
    reqs: &[BatchReq],
    members: &[MemberRoute],
    depths: &[usize],
    ladder: &BucketLadder,
    max_batch: usize,
    pressure: usize,
) -> Option<BatchRoute> {
    debug_assert_eq!(members.len(), depths.len());
    if reqs.is_empty() || reqs.len() > max_batch.max(1) {
        return None;
    }
    let max_len = reqs.iter().map(|r| r.len).max().unwrap_or(0);
    let bucket = ladder.bucket_for(reqs.len(), max_len);
    if reqs.len() == 1 {
        let member = route(reqs[0].sla, members, depths, max_batch, pressure);
        return Some(BatchRoute { member, bucket });
    }
    let fastest = members.len() - 1;
    // backlog includes the candidates themselves (depths exclude them)
    if pressure > 0 && depths.iter().sum::<usize>() + reqs.len() >= pressure {
        return Some(BatchRoute { member: fastest, bucket });
    }
    let b = max_batch.max(1);
    let pending: f64 = members
        .iter()
        .zip(depths)
        .map(|(m, &d)| d.div_ceil(b) as f64 * m.est_batch_time)
        .sum();
    'member: for (i, m) in members.iter().enumerate() {
        let exec = m.time_at(bucket);
        for r in reqs {
            let Some(sla) = r.sla else { continue };
            if let Some(min_s) = sla.min_speedup {
                if m.est_speedup + 1e-9 < min_s {
                    continue 'member;
                }
            }
            if let Some(max_l) = sla.max_latency {
                let remaining = max_l.saturating_sub(r.waited).as_secs_f64();
                if pending + exec > remaining {
                    continue 'member;
                }
            }
        }
        return Some(BatchRoute { member: i, bucket });
    }
    None
}

// --------------------------------------------------------------- stats

/// Realized-vs-certified serving record for one (member, bucket,
/// specialized?) cell (DESIGN.md §9 "certified vs realized").
#[derive(Clone, Debug, PartialEq)]
pub struct BucketStats {
    /// member tag
    pub member: String,
    /// executed batch dimension
    pub batch: usize,
    /// executed padded seq
    pub seq: usize,
    /// whether a shape-specialized executable served these batches
    pub specialized: bool,
    /// executed batches in this cell
    pub batches: usize,
    /// real requests served in this cell
    pub requests: usize,
    /// fraction of ALL aggregated requests that landed in this cell —
    /// the traffic mass the drift detector weighs latency ratios by
    pub share: f64,
    /// median realized execution time of one batch
    pub realized_p50: Duration,
    /// 99th-percentile realized execution time
    pub realized_p99: Duration,
    /// the env's certified estimate of one batched forward at this
    /// shape — what admission promised; `realized_p50 / certified` is
    /// the certify-vs-realize gap
    pub certified: Duration,
}

/// One executed batch, as the worker records it (input to
/// [`aggregate_buckets`] and to `adapt::detect_drift`).
#[derive(Clone, Debug, PartialEq)]
pub struct BucketSample {
    /// member tag that served the batch
    pub member: String,
    /// executed batch dimension
    pub batch: usize,
    /// executed padded seq
    pub seq: usize,
    /// whether the specialized executable ran
    pub specialized: bool,
    /// measured execution time
    pub exec: Duration,
    /// real requests in the batch
    pub requests: usize,
    /// certified estimate of one batched forward at this shape (secs)
    pub certified: f64,
}

impl BucketSample {
    /// Serialize one sample (stable schema: `--samples-out` files are
    /// the offline interchange format `ziplm adapt` reads back).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("member", Json::Str(self.member.clone())),
            ("batch", Json::Num(self.batch as f64)),
            ("seq", Json::Num(self.seq as f64)),
            ("specialized", Json::Bool(self.specialized)),
            ("exec_secs", Json::Num(self.exec.as_secs_f64())),
            ("requests", Json::Num(self.requests as f64)),
            ("certified", Json::Num(self.certified)),
        ])
    }

    /// Parse the [`BucketSample::to_json`] form.
    pub fn from_json(j: &Json) -> Result<BucketSample> {
        let num = |k: &str| -> Result<f64> {
            j.get(k).and_then(Json::as_f64).ok_or_else(|| anyhow!("bucket sample: no `{k}`"))
        };
        Ok(BucketSample {
            member: j
                .get("member")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("bucket sample: no `member`"))?
                .to_string(),
            batch: num("batch")? as usize,
            seq: num("seq")? as usize,
            specialized: j.get("specialized").and_then(Json::as_bool).unwrap_or(false),
            exec: Duration::from_secs_f64(num("exec_secs")?.max(0.0)),
            requests: num("requests")? as usize,
            certified: num("certified")?,
        })
    }
}

/// Serialize a recorded sample stream (the `--samples-out` payload).
pub fn samples_to_json(samples: &[BucketSample]) -> Json {
    Json::obj(vec![(
        "samples",
        Json::Arr(samples.iter().map(BucketSample::to_json).collect()),
    )])
}

/// Parse a `--samples-out` file back into the sample stream. Accepts
/// either the `{"samples": [...]}` wrapper or a bare array.
pub fn samples_from_json(j: &Json) -> Result<Vec<BucketSample>> {
    let arr = j
        .get("samples")
        .and_then(Json::as_arr)
        .or_else(|| j.as_arr())
        .ok_or_else(|| anyhow!("samples file: expected `samples` array"))?;
    arr.iter().map(BucketSample::from_json).collect()
}

/// Fold per-batch [`BucketSample`]s into per-(member, bucket,
/// specialized?) [`BucketStats`] rows, sorted deterministically. Pure,
/// so the realized-vs-certified reporting is testable without PJRT.
pub fn aggregate_buckets(samples: &[BucketSample]) -> Vec<BucketStats> {
    let total: usize = samples.iter().map(|s| s.requests).sum();
    // (member, batch, seq, specialized) → (exec secs, requests, certified)
    let mut by = BTreeMap::new();
    for s in samples {
        let e = by
            .entry((s.member.clone(), s.batch, s.seq, s.specialized))
            .or_insert((Vec::new(), 0, s.certified));
        e.0.push(s.exec.as_secs_f64());
        e.1 += s.requests;
    }
    by.into_iter()
        .map(|((member, batch, seq, specialized), (mut execs, requests, certified))| {
            // total_cmp: a NaN exec sample sorts to the end instead of
            // panicking the worker (ISSUE 6 — fault-injected NaNs)
            execs.sort_by(|a, b| a.total_cmp(b));
            BucketStats {
                member,
                batch,
                seq,
                specialized,
                batches: execs.len(),
                requests,
                share: if total > 0 { requests as f64 / total as f64 } else { 0.0 },
                realized_p50: Duration::from_secs_f64(percentile(&execs, 0.50)),
                realized_p99: Duration::from_secs_f64(percentile(&execs, 0.99)),
                certified: Duration::from_secs_f64(certified),
            }
        })
        .collect()
}

/// Aggregate serving statistics returned by [`FamilyHandle::shutdown`].
#[derive(Clone, Debug, Default)]
pub struct FamilyStats {
    /// total requests served
    pub requests: usize,
    /// total executed batches
    pub batches: usize,
    /// cumulative execution time
    pub busy_time: Duration,
    /// requests served per member, in router order
    pub per_member: Vec<(String, usize)>,
    /// requests rerouted to the fastest member by queue pressure
    pub pressure_reroutes: usize,
    /// batches that merged requests from ≥ 2 member queues
    /// ([`route_batch`] coalescing)
    pub coalesced_batches: usize,
    /// realized-vs-certified per-bucket serving rows (DESIGN.md §9)
    pub per_bucket: Vec<BucketStats>,
    /// the raw executed-batch stream behind `per_bucket`, in execution
    /// order — exportable via `--samples-out` and consumable by
    /// `adapt::detect_drift` (DESIGN.md §12)
    pub samples: Vec<BucketSample>,
    /// executable-cache builds: one for the shared masked graph plus
    /// one per (member, bucket) specialization that warmed up
    pub cache_builds: usize,
    /// executable-cache hits
    pub cache_hits: usize,
}

/// Handle to a running family coordinator.
pub struct FamilyHandle {
    tx: Option<mpsc::Sender<FamilyRequest>>,
    worker: Option<JoinHandle<Result<FamilyStats>>>,
}

impl FamilyHandle {
    /// Enqueue a request; the receiver yields the [`FamilyReply`].
    pub fn submit(&self, ids: Vec<i32>, sla: Option<Sla>) -> Result<mpsc::Receiver<FamilyReply>> {
        let (rtx, rrx) = mpsc::channel();
        self.tx
            .as_ref()
            .ok_or_else(|| anyhow!("family server stopped"))?
            .send(FamilyRequest { ids, sla, submitted: Instant::now(), reply: rtx })
            .map_err(|_| anyhow!("family server gone"))?;
        Ok(rrx)
    }

    /// Submit and wait (convenience).
    pub fn infer(&self, ids: Vec<i32>, sla: Option<Sla>) -> Result<FamilyReply> {
        let rx = self.submit(ids, sla)?;
        Ok(rx.recv()?)
    }

    /// Stop accepting requests, flush all queues, and return stats.
    pub fn shutdown(mut self) -> Result<FamilyStats> {
        drop(self.tx.take());
        self.worker
            .take()
            .ok_or_else(|| anyhow!("already stopped"))?
            .join()
            .map_err(|_| anyhow!("family worker panicked"))?
    }
}

struct MemberSpec {
    tag: String,
    state: ModelState,
    route: MemberRoute,
}

/// Start the family coordinator over `members` (tag, checkpoint).
///
/// All members must share one (model, task); their per-layer profiles
/// are read from the checkpoint masks and priced with `env` — the same
/// [`InferenceEnv`] the pruning session certified the members against,
/// so admission estimates cannot silently diverge from certification.
/// Each [`FamilyCfg::buckets`] bucket is priced per member through
/// [`InferenceEnv::batch_time`] (seq sweep + batch scaling), giving
/// [`route_batch`] its shaped admission estimates. Members are served
/// in ascending-speedup order (index 0 = most accurate).
pub fn start(
    cfg: FamilyCfg,
    members: Vec<(String, ModelState)>,
    env: &InferenceEnv,
) -> Result<FamilyHandle> {
    if members.is_empty() {
        return Err(anyhow!("family must have at least one member"));
    }
    let (model, task) = (members[0].1.model.clone(), members[0].1.task.clone());
    let mut specs: Vec<MemberSpec> = Vec::with_capacity(members.len());
    for (tag, state) in members {
        if state.model != model || state.task != task {
            return Err(anyhow!(
                "family member `{tag}` is {}/{}, expected {model}/{task}",
                state.model,
                state.task
            ));
        }
        let profile = state.masks.summary();
        let route = MemberRoute {
            tag: tag.clone(),
            est_speedup: env.speedup(&profile),
            est_batch_time: env.model_time(&profile),
            bucket_times: cfg
                .buckets
                .buckets()
                .iter()
                .map(|&(b, s)| ((b, s), env.batch_time(&profile, b, s)))
                .collect(),
        };
        specs.push(MemberSpec { tag, state, route });
    }
    specs.sort_by(|a, b| a.route.est_speedup.total_cmp(&b.route.est_speedup));
    let (tx, rx) = mpsc::channel::<FamilyRequest>();
    let worker = std::thread::Builder::new()
        .name("ziplm-family".into())
        .spawn(move || serve_family_loop(cfg, specs, rx))
        .map_err(|e| anyhow!("spawn family server: {e}"))?;
    Ok(FamilyHandle { tx: Some(tx), worker: Some(worker) })
}

fn serve_family_loop(
    cfg: FamilyCfg,
    specs: Vec<MemberSpec>,
    rx: mpsc::Receiver<FamilyRequest>,
) -> Result<FamilyStats> {
    let engine = Engine::open(&cfg.artifacts)?;
    let (model, task) = (specs[0].state.model.clone(), specs[0].state.task.clone());
    let minfo = engine.manifest.model(&model).clone();
    let tinfo = engine.manifest.task(&model, &task).clone();
    let b = engine.manifest.batch_eval.min(cfg.max_batch.max(1));
    let graph_b = engine.manifest.batch_eval;
    let art = format!("{model}__{task}__fwd");
    engine
        .manifest
        .artifacts
        .get(&art)
        .ok_or_else(|| anyhow!("missing fwd artifact {art}"))?;
    let ladder = cfg.buckets.clone();
    let spec_dir = cfg.specialized.clone().unwrap_or_else(|| cfg.artifacts.join("specialized"));
    // Per-member device literals, built once.
    let mut lits = Vec::with_capacity(specs.len());
    for s in &specs {
        let (hm, fm) = mask_literals(&s.state)?;
        let params = lit_f32_shaped(&[s.state.params.len()], &s.state.params)?;
        lits.push((params, hm, fm));
    }
    let routes: Vec<MemberRoute> = specs.iter().map(|s| s.route.clone()).collect();
    let mut queues: Vec<VecDeque<FamilyRequest>> = specs.iter().map(|_| VecDeque::new()).collect();
    let mut served = vec![0usize; specs.len()];
    let mut stats = FamilyStats::default();
    let mut samples: Vec<BucketSample> = Vec::new();
    // shape-specialization warm-up state: per-member gathered params
    // (built with the first successful compile) and the quarantined
    // (member, bucket) pairs whose export failed to compile or execute
    // (stale against the member's masks, truncated file, …) — those
    // serve generic forever instead of retrying or killing the worker.
    // Warmth itself is probed through the compile cache
    // ([`Engine::cached_keyed`]), and a pair with NO export file is
    // simply not warm yet: the file is re-stat'ed per batch, so
    // exports generated while serving get picked up.
    let mut spec_lits: Vec<Option<xla::Literal>> = specs.iter().map(|_| None).collect();
    let mut bad: HashSet<(usize, (usize, usize))> = HashSet::new();
    let mut open = true;

    fn enqueue(
        req: FamilyRequest,
        routes: &[MemberRoute],
        queues: &mut [VecDeque<FamilyRequest>],
        max_batch: usize,
        pressure: usize,
        stats: &mut FamilyStats,
    ) {
        let depths: Vec<usize> = queues.iter().map(VecDeque::len).collect();
        let under_pressure = pressure > 0 && depths.iter().sum::<usize>() >= pressure;
        let i = route(req.sla.as_ref(), routes, &depths, max_batch, pressure);
        if under_pressure && i == routes.len() - 1 {
            stats.pressure_reroutes += 1;
        }
        queues[i].push_back(req);
    }

    // generic fallback: pad to the static graph batch and execute with
    // the member's params + masks through the SHARED fwd executable
    let run_generic = |member: usize, batch: &[FamilyRequest]| -> Result<Vec<f32>> {
        let ids = super::pad_ids(batch.iter().map(|r| r.ids.as_slice()), graph_b, minfo.seq_len);
        let (params, hm, fm) = &lits[member];
        let exe = engine.executable(&art)?;
        let out = Engine::run_exe(
            &exe,
            &[params.clone(), lit_i32(&[graph_b, minfo.seq_len], &ids)?, hm.clone(), fm.clone()],
        )?;
        lit_to_f32(&out[0])
    };

    // specialized path: the member's gathered weights + the bucket's
    // materialized graph (masks are baked in, so only two inputs)
    let run_specialized = |key: &ArtifactKey,
                           params: &xla::Literal,
                           batch: &[FamilyRequest],
                           bk: (usize, usize)|
     -> Result<Vec<f32>> {
        let exe = engine.executable_file_keyed(key, &spec_dir.join(spec_file(key)))?;
        let ids = super::pad_ids(batch.iter().map(|r| r.ids.as_slice()), bk.0, bk.1);
        let out = Engine::run_exe(&exe, &[params.clone(), lit_i32(&[bk.0, bk.1], &ids)?])?;
        lit_to_f32(&out[0])
    };

    // Serve until the channel closes AND every queue is flushed.
    while open || queues.iter().any(|q| !q.is_empty()) {
        // drain everything already waiting on the channel
        loop {
            match rx.try_recv() {
                Ok(r) => enqueue(r, &routes, &mut queues, b, cfg.pressure, &mut stats),
                Err(mpsc::TryRecvError::Empty) => break,
                Err(mpsc::TryRecvError::Disconnected) => {
                    open = false;
                    break;
                }
            }
        }
        if queues.iter().all(|q| q.is_empty()) {
            if !open {
                break;
            }
            // idle: block for the next request (or shutdown)
            match rx.recv() {
                Ok(r) => enqueue(r, &routes, &mut queues, b, cfg.pressure, &mut stats),
                Err(_) => open = false,
            }
            continue;
        }
        // serve the member whose head request has waited longest
        let mi = queues
            .iter()
            .enumerate()
            .filter(|(_, q)| !q.is_empty())
            .min_by_key(|(_, q)| q.front().map(|r| r.submitted).unwrap_or_else(Instant::now))
            .map(|(i, _)| i)
            .unwrap_or(0);
        // dynamic batching: let stragglers join this member's batch
        if open {
            let deadline = Instant::now() + cfg.max_wait;
            while queues[mi].len() < b {
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                match rx.recv_timeout(deadline - now) {
                    Ok(r) => enqueue(r, &routes, &mut queues, b, cfg.pressure, &mut stats),
                    Err(mpsc::RecvTimeoutError::Timeout) => break,
                    Err(mpsc::RecvTimeoutError::Disconnected) => {
                        open = false;
                        break;
                    }
                }
            }
        }
        // ---- cross-SLA coalescing: offer the globally oldest ≤ b
        // requests (possibly spanning several member queues) to
        // route_batch; a refused merge falls back to member mi's own
        // batch, exactly the pre-coalescing behavior
        let mut cursors = vec![0usize; queues.len()];
        let mut picked: Vec<(usize, usize)> = Vec::new();
        while picked.len() < b {
            let mut best: Option<(usize, Instant)> = None;
            for (qi, q) in queues.iter().enumerate() {
                if let Some(r) = q.get(cursors[qi]) {
                    if best.is_none_or(|(_, t)| r.submitted < t) {
                        best = Some((qi, r.submitted));
                    }
                }
            }
            let Some((qi, _)) = best else { break };
            picked.push((qi, cursors[qi]));
            cursors[qi] += 1;
        }
        let now = Instant::now();
        let breqs: Vec<BatchReq> = picked
            .iter()
            .map(|&(qi, k)| BatchReq {
                sla: queues[qi][k].sla.as_ref(),
                len: queues[qi][k].ids.len(),
                waited: now.duration_since(queues[qi][k].submitted),
            })
            .collect();
        let depths_excl: Vec<usize> =
            queues.iter().zip(&cursors).map(|(q, &c)| q.len() - c).collect();
        let decision = route_batch(&breqs, &routes, &depths_excl, &ladder, b, cfg.pressure);
        drop(breqs);
        let (member, batch, bucket) = match decision {
            Some(br) => {
                let spanned: HashSet<usize> = picked.iter().map(|&(qi, _)| qi).collect();
                if spanned.len() > 1 {
                    stats.coalesced_batches += 1;
                }
                let mut drained: Vec<VecDeque<FamilyRequest>> = queues
                    .iter_mut()
                    .zip(&cursors)
                    .map(|(q, &c)| q.drain(..c).collect())
                    .collect();
                let mut batch = Vec::with_capacity(picked.len());
                for &(qi, _) in &picked {
                    let r = drained[qi]
                        .pop_front()
                        .ok_or_else(|| anyhow!("picked request missing from drained queue"))?;
                    batch.push(r);
                }
                (br.member, batch, br.bucket)
            }
            None => {
                let take = queues[mi].len().min(b);
                let batch: Vec<FamilyRequest> = queues[mi].drain(..take).collect();
                let max_len = batch.iter().map(|r| r.ids.len()).max().unwrap_or(0);
                (mi, batch, ladder.bucket_for(take, max_len))
            }
        };
        // ---- execute: specialized when the (member, bucket) pair is
        // warm (compiled + weights gathered), generic otherwise
        // (cold-start fallback, DESIGN.md §9). A specialized run that
        // FAILS — stale export vs the member's current masks, bad
        // file — quarantines the pair and falls back to the generic
        // graph for this and every later batch, rather than taking the
        // whole worker (and every queued request) down with it.
        let t0 = Instant::now();
        let mut shape = (graph_b, minfo.seq_len);
        let mut used_spec = false;
        let mut logits: Option<Vec<f32>> = None;
        if let Some(bk) = bucket {
            let pair = (member, bk);
            if !bad.contains(&pair) {
                if let Some(params) = spec_lits[member].as_ref() {
                    let key = spec_key(&model, &task, &specs[member].tag, bk);
                    if engine.cached_keyed(&key) {
                        match run_specialized(&key, params, &batch, bk) {
                            Ok(l) => {
                                shape = bk;
                                used_spec = true;
                                logits = Some(l);
                            }
                            Err(_) => {
                                bad.insert(pair);
                            }
                        }
                    }
                }
            }
        }
        let logits = match logits {
            Some(l) => l,
            None => run_generic(member, &batch)?,
        };
        let exec_time = t0.elapsed();
        stats.busy_time += exec_time;
        stats.batches += 1;
        served[member] += batch.len();
        samples.push(BucketSample {
            member: specs[member].tag.clone(),
            batch: shape.0,
            seq: shape.1,
            specialized: used_spec,
            exec: exec_time,
            requests: batch.len(),
            certified: if used_spec {
                routes[member].time_at(Some(shape))
            } else {
                routes[member].est_batch_time
            },
        });
        // per-example output width comes from the EXECUTED shape, not
        // the generic anchor: seq-dependent task outputs (span, lm)
        // shrink with the bucket's padded seq, and slicing them with
        // the anchor width would hand requests each other's rows
        let out_w = logits.len() / shape.0.max(1);
        for (k, r) in batch.iter().enumerate() {
            stats.requests += 1;
            let _ = r.reply.send(FamilyReply {
                logits: logits[k * out_w..(k + 1) * out_w].to_vec(),
                member: specs[member].tag.clone(),
                member_speedup: specs[member].route.est_speedup,
                queue_time: t0.duration_since(r.submitted),
                batch_size: batch.len(),
                latency: r.submitted.elapsed(),
                bucket: shape,
                specialized: used_spec,
            });
        }
        // ---- lazy warm-up AFTER the replies went out: the first hit
        // on a cold (member, bucket) pair compiles its specialized
        // executable (and gathers the member's packed weights) without
        // adding a compile to the triggering batch's latency. A pair
        // with no export file is left cold and re-stat'ed on its next
        // hit (exports generated while serving get picked up, per
        // [`Engine::executable_file_keyed`]'s contract); a compile
        // failure quarantines the pair instead of retrying forever.
        if let Some(bk) = bucket {
            let pair = (member, bk);
            if !used_spec && !bad.contains(&pair) {
                let key = spec_key(&model, &task, &specs[member].tag, bk);
                let path = spec_dir.join(spec_file(&key));
                if !engine.cached_keyed(&key) && path.exists() {
                    match engine.executable_file_keyed(&key, &path) {
                        Ok(_) => {
                            if spec_lits[member].is_none() {
                                let (flat, _, _) =
                                    gather_specialized(&specs[member].state, &minfo, &tinfo)?;
                                spec_lits[member] = Some(lit_f32_shaped(&[flat.len()], &flat)?);
                            }
                        }
                        Err(_) => {
                            bad.insert(pair);
                        }
                    }
                }
            }
        }
    }
    let (builds, hits) = engine.cache_stats();
    stats.cache_builds = builds;
    stats.cache_hits = hits;
    stats.per_bucket = aggregate_buckets(&samples);
    stats.samples = samples;
    stats.per_member =
        specs.iter().zip(&served).map(|(s, &n)| (s.tag.clone(), n)).collect();
    Ok(stats)
}

// ------------------------------------------------------------ reporting

/// Per-(class, bucket) latency line inside a [`ClassReport`]: how one
/// workload class fared at one executed shape.
#[derive(Clone, Debug)]
pub struct ClassBucket {
    /// executed batch dimension
    pub batch: usize,
    /// executed padded seq
    pub seq: usize,
    /// requests of the class served at this shape
    pub n: usize,
    /// median end-to-end latency at this shape
    pub p50: Duration,
    /// 99th-percentile end-to-end latency at this shape
    pub p99: Duration,
}

/// Per-class latency/SLA report (client-side aggregation).
#[derive(Clone, Debug)]
pub struct ClassReport {
    /// workload-class label
    pub class: String,
    /// requests in the class
    pub n: usize,
    /// median end-to-end latency
    pub p50: Duration,
    /// 99th-percentile end-to-end latency
    pub p99: Duration,
    /// fraction of requests whose latency met their SLA bound
    pub hit_rate: f64,
    /// per-executed-shape breakdown (realized client-side latencies;
    /// the worker-side twin is [`FamilyStats::per_bucket`])
    pub per_bucket: Vec<ClassBucket>,
}

/// One served request's client-side row (input to [`summarize`]),
/// normally built from a [`FamilyReply`].
#[derive(Clone, Debug)]
pub struct WorkRow {
    /// workload-class label
    pub class: String,
    /// end-to-end latency
    pub latency: Duration,
    /// whether the request's SLA was honored
    pub sla_hit: bool,
    /// `(batch, seq)` shape the serving batch executed at
    pub bucket: (usize, usize),
}

/// Aggregate per-request [`WorkRow`]s into per-class reports (sorted by
/// class name), each with a per-bucket latency breakdown.
pub fn summarize(rows: &[WorkRow]) -> Vec<ClassReport> {
    let mut by: BTreeMap<&str, Vec<&WorkRow>> = BTreeMap::new();
    for r in rows {
        by.entry(r.class.as_str()).or_default().push(r);
    }
    let pctiles = |lats: &mut Vec<f64>| -> (Duration, Duration) {
        // NaN-tolerant: a poisoned latency sample sorts last, never panics
        lats.sort_by(|a, b| a.total_cmp(b));
        (
            Duration::from_secs_f64(percentile(lats, 0.50)),
            Duration::from_secs_f64(percentile(lats, 0.99)),
        )
    };
    by.into_iter()
        .map(|(class, rs)| {
            let hits = rs.iter().filter(|r| r.sla_hit).count();
            let mut lats: Vec<f64> = rs.iter().map(|r| r.latency.as_secs_f64()).collect();
            let (p50, p99) = pctiles(&mut lats);
            let mut buckets: BTreeMap<(usize, usize), Vec<f64>> = BTreeMap::new();
            for r in &rs {
                buckets.entry(r.bucket).or_default().push(r.latency.as_secs_f64());
            }
            let per_bucket = buckets
                .into_iter()
                .map(|((batch, seq), mut ls)| {
                    let n = ls.len();
                    let (p50, p99) = pctiles(&mut ls);
                    ClassBucket { batch, seq, n, p50, p99 }
                })
                .collect();
            ClassReport {
                class: class.to_string(),
                n: rs.len(),
                p50,
                p99,
                hit_rate: hits as f64 / rs.len().max(1) as f64,
                per_bucket,
            }
        })
        .collect()
}

/// Nearest-rank percentile of an ascending-sorted slice (q in [0, 1]).
/// Shared with the fleet coordinator's tail-latency stats.
pub(crate) fn percentile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((sorted.len() - 1) as f64 * q).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

#[cfg(test)]
#[allow(clippy::disallowed_methods)]
mod tests {
    use super::*;
    use crate::runtime::{ArtifactKey, CompileCache};

    fn routes() -> Vec<MemberRoute> {
        // sorted ascending by speedup, as `start` guarantees
        vec![
            MemberRoute {
                tag: "dense".into(),
                est_speedup: 1.0,
                est_batch_time: 80e-3,
                bucket_times: Vec::new(),
            },
            MemberRoute {
                tag: "2x".into(),
                est_speedup: 2.1,
                est_batch_time: 38e-3,
                bucket_times: Vec::new(),
            },
            MemberRoute {
                tag: "4x".into(),
                est_speedup: 4.3,
                est_batch_time: 19e-3,
                bucket_times: Vec::new(),
            },
        ]
    }

    /// The same family, priced over a two-bucket ladder: the short
    /// bucket costs 30% of the anchor.
    fn routes_with_buckets() -> (Vec<MemberRoute>, BucketLadder) {
        let ladder = BucketLadder::new(vec![(8, 32), (8, 128)]);
        let routes = routes()
            .into_iter()
            .map(|mut m| {
                m.bucket_times =
                    vec![((8, 32), m.est_batch_time * 0.3), ((8, 128), m.est_batch_time)];
                m
            })
            .collect();
        (routes, ladder)
    }

    fn sla(max_ms: Option<u64>, min_speedup: Option<f64>) -> Sla {
        Sla {
            class: "t".into(),
            max_latency: max_ms.map(Duration::from_millis),
            min_speedup,
        }
    }

    fn breq(sla: Option<&Sla>, len: usize) -> BatchReq<'_> {
        BatchReq { sla, len, waited: Duration::ZERO }
    }

    #[test]
    fn route_no_sla_prefers_most_accurate() {
        assert_eq!(route(None, &routes(), &[0, 0, 0], 8, 0), 0);
    }

    #[test]
    fn route_min_speedup_picks_most_accurate_qualifier() {
        let s = sla(None, Some(2.0));
        assert_eq!(route(Some(&s), &routes(), &[0, 0, 0], 8, 0), 1);
        let s = sla(None, Some(4.0));
        assert_eq!(route(Some(&s), &routes(), &[0, 0, 0], 8, 0), 2);
        // unsatisfiable → fastest (best effort)
        let s = sla(None, Some(9.0));
        assert_eq!(route(Some(&s), &routes(), &[0, 0, 0], 8, 0), 2);
    }

    #[test]
    fn route_max_latency_uses_queue_depth_admission_estimate() {
        // 100ms bound: dense (80ms) fits when idle
        let s = sla(Some(100), None);
        assert_eq!(route(Some(&s), &routes(), &[0, 0, 0], 8, 0), 0);
        // 16 dense requests = 2 pending batches (160ms of worker time):
        // dense adds its own 3rd batch (240ms > 200) but the 2x member
        // rides the backlog at 160 + 38 = 198ms ≤ 200 → spill to 2x
        let s = sla(Some(200), None);
        assert_eq!(route(Some(&s), &routes(), &[16, 0, 0], 8, 0), 1);
        // tighter 185ms bound also excludes 2x (198) → 4x (179)
        let s = sla(Some(185), None);
        assert_eq!(route(Some(&s), &routes(), &[16, 0, 0], 8, 0), 2);
        // a bound nothing meets even idle → fastest
        let s = sla(Some(5), None);
        assert_eq!(route(Some(&s), &routes(), &[0, 0, 0], 8, 0), 2);
    }

    #[test]
    fn route_admission_counts_cross_queue_backlog() {
        // One worker serves every queue oldest-first, so a 16-deep 2x
        // queue (2 × 38ms pending) delays dense too: dense estimates
        // 76 + 80 = 156ms > 100 even though its own queue is empty;
        // joining the 2x backlog adds a whole batch (76 + 38 = 114);
        // only 4x (76 + 19 = 95ms) admits under a 100ms bound.
        let s = sla(Some(100), None);
        assert_eq!(route(Some(&s), &routes(), &[0, 16, 0], 8, 0), 2);
    }

    #[test]
    fn route_pressure_overrides_everything() {
        let s = sla(Some(1_000), Some(1.0)); // dense would qualify
        assert_eq!(route(Some(&s), &routes(), &[4, 4, 4], 8, 12), 2);
        assert_eq!(route(None, &routes(), &[12, 0, 0], 8, 12), 2);
        // pressure disabled (0) → normal policy
        assert_eq!(route(None, &routes(), &[12, 0, 0], 8, 0), 0);
    }

    #[test]
    fn route_combined_speedup_and_latency_constraints() {
        // min_speedup 2 excludes dense; 30ms bound excludes 2x (38ms)
        let s = sla(Some(30), Some(2.0));
        assert_eq!(route(Some(&s), &routes(), &[0, 0, 0], 8, 0), 2);
    }

    // ----------------------------------------------------- bucket ladder

    #[test]
    fn bucket_ladder_picks_smallest_cover() {
        let l = BucketLadder::new(vec![(8, 128), (8, 32), (4, 32), (0, 16), (8, 0), (8, 32)]);
        // zero dims dropped, sorted by (seq, batch), deduped
        assert_eq!(l.buckets(), &[(4, 32), (8, 32), (8, 128)]);
        assert_eq!(l.bucket_for(3, 20), Some((4, 32)));
        assert_eq!(l.bucket_for(6, 20), Some((8, 32)));
        assert_eq!(l.bucket_for(2, 60), Some((8, 128)));
        // nothing covers: batch too big, or seq too long
        assert_eq!(l.bucket_for(9, 20), None);
        assert_eq!(l.bucket_for(1, 200), None);
        assert!(BucketLadder::default().bucket_for(1, 1).is_none());
        assert!(BucketLadder::default().is_empty());
    }

    #[test]
    fn spec_keys_separate_members_and_buckets() {
        let a = spec_key("m", "t", "2x", (8, 32));
        let b = spec_key("m", "t", "2x", (8, 128));
        let c = spec_key("m", "t", "4x", (8, 32));
        assert_ne!(a.encode(), b.encode());
        assert_ne!(a.encode(), c.encode());
        // and never the shared generic key
        assert_ne!(a.encode(), ArtifactKey::new("m__t__fwd", 8, 32).encode());
        assert_eq!(spec_file(&a), "spec_m_t_2x_b8s32.hlo.txt");
    }

    // ------------------------------------------------------- route_batch

    #[test]
    fn route_batch_single_request_degenerates_to_route() {
        let (routes, ladder) = routes_with_buckets();
        let cases = [
            (None, [0usize, 0, 0]),
            (Some(sla(Some(100), None)), [0, 0, 0]),
            (Some(sla(Some(200), None)), [16, 0, 0]),
            (Some(sla(None, Some(4.0))), [0, 0, 0]),
            (Some(sla(Some(5), None)), [0, 0, 0]), // unsatisfiable → fastest
        ];
        for (s, depths) in &cases {
            let expect = route(s.as_ref(), &routes, depths, 8, 0);
            let got = route_batch(&[breq(s.as_ref(), 24)], &routes, depths, &ladder, 8, 0)
                .expect("single request is never refused");
            assert_eq!(got.member, expect, "sla {s:?}");
            assert_eq!(got.bucket, Some((8, 32)));
        }
        // pressure path degenerates too
        let got =
            route_batch(&[breq(None, 24)], &routes, &[12, 0, 0], &ladder, 8, 12).unwrap();
        assert_eq!(got.member, 2);
    }

    #[test]
    fn route_batch_coalesces_compatible_sla_classes() {
        let (routes, ladder) = routes_with_buckets();
        // latency-bound (30ms) + min-speedup (2.0) classes, short rows:
        // bucket (8,32); dense fails the speedup floor, 2x fits both
        // (11.4ms ≤ 30ms, 2.1 ≥ 2.0) → most accurate qualifier
        let interactive = sla(Some(30), None);
        let cheap = sla(None, Some(2.0));
        let reqs = [breq(Some(&interactive), 24), breq(Some(&cheap), 30)];
        let br = route_batch(&reqs, &routes, &[0, 0, 0], &ladder, 8, 0).expect("compatible");
        assert_eq!(br, BatchRoute { member: 1, bucket: Some((8, 32)) });
        // a long row in the merge moves the bucket up the ladder, and
        // the anchor-priced 2x (38ms) still fits the 50ms bound
        let relaxed = sla(Some(50), None);
        let reqs = [breq(Some(&relaxed), 120), breq(Some(&cheap), 30)];
        let br = route_batch(&reqs, &routes, &[0, 0, 0], &ladder, 8, 0).expect("compatible");
        assert_eq!(br, BatchRoute { member: 1, bucket: Some((8, 128)) });
        // no-SLA requests merge with anything
        let reqs = [breq(None, 24), breq(Some(&cheap), 24)];
        let br = route_batch(&reqs, &routes, &[0, 0, 0], &ladder, 8, 0).unwrap();
        assert_eq!(br.member, 1);
    }

    #[test]
    fn route_batch_refuses_deadline_violating_merge() {
        let (routes, ladder) = routes_with_buckets();
        // 4ms bound: even 4x at the short bucket (5.7ms) misses → the
        // merge must be REFUSED, not served best-effort (that would
        // convert an admitted request into a guaranteed miss)
        let tight = sla(Some(4), None);
        let cheap = sla(None, Some(2.0));
        let reqs = [breq(Some(&tight), 24), breq(Some(&cheap), 24)];
        assert!(route_batch(&reqs, &routes, &[0, 0, 0], &ladder, 8, 0).is_none());
        // speedup floor vs deadline conflict: one request insists on
        // ≥4x, the other's 5ms bound excludes 4x at the anchor bucket
        // (19ms) — no member satisfies both → refused
        let fast_floor = sla(None, Some(4.0));
        let bound = sla(Some(5), None);
        let reqs = [breq(Some(&fast_floor), 120), breq(Some(&bound), 24)];
        assert!(route_batch(&reqs, &routes, &[0, 0, 0], &ladder, 8, 0).is_none());
        // queued backlog counts: 16 dense requests pending = 160ms, a
        // 100ms bound can no longer be met by anyone
        let bound = sla(Some(100), None);
        let reqs = [breq(Some(&bound), 24), breq(None, 24)];
        assert!(route_batch(&reqs, &routes, &[16, 0, 0], &ladder, 8, 0).is_none());
        // time already waited eats the budget: 30ms bound, 27ms waited
        // → 3ms remaining < 5.7ms short-bucket exec → refused
        let bound = sla(Some(30), None);
        let waited = BatchReq {
            sla: Some(&bound),
            len: 24,
            waited: Duration::from_millis(27),
        };
        assert!(route_batch(&[waited, breq(None, 24)], &routes, &[0, 0, 0], &ladder, 8, 0)
            .is_none());
        // ...but the same merge with fresh requests is fine
        assert!(route_batch(
            &[breq(Some(&bound), 24), breq(None, 24)],
            &routes,
            &[0, 0, 0],
            &ladder,
            8,
            0
        )
        .is_some());
    }

    #[test]
    fn route_batch_pressure_and_size_limits() {
        let (routes, ladder) = routes_with_buckets();
        // pressure coalesces everything to the fastest member
        let s = sla(Some(1_000), Some(1.0));
        let reqs = [breq(Some(&s), 24), breq(None, 24)];
        let br = route_batch(&reqs, &routes, &[5, 5, 0], &ladder, 8, 12).unwrap();
        assert_eq!(br.member, 2);
        // empty and over-sized candidate sets are not batches
        assert!(route_batch(&[], &routes, &[0, 0, 0], &ladder, 8, 0).is_none());
        let many: Vec<BatchReq> = (0..9).map(|_| breq(None, 8)).collect();
        assert!(route_batch(&many, &routes, &[0, 0, 0], &ladder, 8, 0).is_none());
    }

    // ------------------------------------------- acceptance: §9 end-to-end

    #[test]
    fn coalesced_batch_one_specialized_executable_realized_vs_certified() {
        // Acceptance (ISSUE 5): two SLA classes with compatible shapes
        // coalesce into ONE batch served by ONE specialized executable;
        // the compile cache builds exactly one executable per distinct
        // (member, bucket) pair exercised and serves the rest as hits;
        // FamilyStats reports realized per-bucket latency next to the
        // certified estimate; a deadline-incompatible merge is refused.
        let (routes, ladder) = routes_with_buckets();
        let interactive = sla(Some(30), None);
        let cheap = sla(None, Some(2.0));
        let reqs = [breq(Some(&interactive), 24), breq(Some(&cheap), 30)];
        let br = route_batch(&reqs, &routes, &[0, 0, 0], &ladder, 8, 0)
            .expect("compatible classes must coalesce");
        assert_eq!(br, BatchRoute { member: 1, bucket: Some((8, 32)) });

        // resolve executables exactly the way the worker does: one
        // get_or_build per executed batch, keyed by (member, bucket)
        let cache: CompileCache<String> = CompileCache::new();
        let mut samples: Vec<BucketSample> = Vec::new();
        let mut serve = |member: usize, bucket: (usize, usize), n: usize, exec_ms: f64| {
            let key = spec_key("m", "t", &routes[member].tag, bucket);
            cache.get_or_build(&key.encode(), || Ok(key.encode())).unwrap();
            samples.push(BucketSample {
                member: routes[member].tag.clone(),
                batch: bucket.0,
                seq: bucket.1,
                specialized: true,
                exec: Duration::from_secs_f64(exec_ms * 1e-3),
                requests: n,
                certified: routes[member].time_at(Some(bucket)),
            });
        };
        // the coalesced (2x, 8x32) batch, then repeats, then a second
        // distinct pair (4x at the anchor bucket)
        for k in 0..4 {
            serve(br.member, br.bucket.unwrap(), 2, 12.0 + k as f64);
        }
        for _ in 0..2 {
            serve(2, (8, 128), 8, 21.0);
        }
        assert_eq!(cache.builds(), 2, "one build per distinct (member, bucket) pair");
        assert!(cache.hits() > 0, "repeat shapes must be cache hits");

        let stats = FamilyStats {
            coalesced_batches: 1,
            per_bucket: aggregate_buckets(&samples),
            ..FamilyStats::default()
        };
        assert_eq!(stats.per_bucket.len(), 2);
        let row = stats
            .per_bucket
            .iter()
            .find(|r| r.member == "2x" && (r.batch, r.seq) == (8, 32))
            .expect("realized row for the coalesced bucket");
        assert!(row.specialized);
        assert_eq!((row.batches, row.requests), (4, 8));
        // realized p50/p99 sit NEXT TO the certified estimate
        assert!((row.certified.as_secs_f64() - 38e-3 * 0.3).abs() < 1e-12);
        assert!(row.realized_p50 >= Duration::from_millis(12));
        assert!(row.realized_p99 <= Duration::from_millis(16));
        assert!(row.realized_p50 <= row.realized_p99);
        assert!(stats.coalesced_batches > 0);

        // the refusal half: a deadline-incompatible merge stays split
        let tight = sla(Some(4), None);
        let reqs = [breq(Some(&tight), 24), breq(Some(&cheap), 24)];
        assert!(route_batch(&reqs, &routes, &[0, 0, 0], &ladder, 8, 0).is_none());
    }

    // --------------------------------------------------------- reporting

    fn row(class: &str, ms: u64, hit: bool, bucket: (usize, usize)) -> WorkRow {
        WorkRow {
            class: class.to_string(),
            latency: Duration::from_millis(ms),
            sla_hit: hit,
            bucket,
        }
    }

    #[test]
    fn summarize_percentiles_hit_rate_and_buckets() {
        let mut rows = Vec::new();
        for i in 1..=100u64 {
            let bucket = if i % 2 == 0 { (8, 32) } else { (8, 128) };
            rows.push(row("a", i, i <= 90, bucket));
        }
        rows.push(row("b", 7, true, (8, 128)));
        let reps = summarize(&rows);
        assert_eq!(reps.len(), 2);
        let a = &reps[0];
        assert_eq!(a.class, "a");
        assert_eq!(a.n, 100);
        assert!((a.hit_rate - 0.90).abs() < 1e-9);
        let ms = Duration::from_millis;
        assert!(a.p50 >= ms(49) && a.p50 <= ms(52), "{:?}", a.p50);
        assert!(a.p99 >= ms(98), "{:?}", a.p99);
        // per-bucket breakdown: evens at (8,32), odds at (8,128)
        assert_eq!(a.per_bucket.len(), 2);
        let short = a.per_bucket.iter().find(|b| b.seq == 32).unwrap();
        let long = a.per_bucket.iter().find(|b| b.seq == 128).unwrap();
        assert_eq!((short.n, long.n), (50, 50));
        assert!(short.p50 >= ms(48) && short.p50 <= ms(54));
        assert!(long.p99 >= ms(97));
        let b = &reps[1];
        assert_eq!((b.n, b.p50, b.hit_rate), (1, ms(7), 1.0));
        assert_eq!(b.per_bucket.len(), 1);
    }

    #[test]
    fn aggregate_buckets_groups_and_orders_rows() {
        let mk = |member: &str, seq: usize, specialized: bool, exec_ms: f64| BucketSample {
            member: member.into(),
            batch: 8,
            seq,
            specialized,
            exec: Duration::from_secs_f64(exec_ms * 1e-3),
            requests: 3,
            certified: 10e-3,
        };
        let rows = aggregate_buckets(&[
            mk("2x", 32, true, 12.0),
            mk("2x", 32, true, 14.0),
            // generic cold-start batches of the same member land in a
            // SEPARATE row — the gap between the two rows is the
            // specialization win
            mk("2x", 128, false, 40.0),
            mk("dense", 128, false, 80.0),
        ]);
        assert_eq!(rows.len(), 3);
        let spec = rows.iter().find(|r| r.member == "2x" && r.specialized).unwrap();
        assert_eq!((spec.batches, spec.requests, spec.seq), (2, 6, 32));
        assert_eq!(spec.certified, Duration::from_secs_f64(10e-3));
        assert!(spec.realized_p50 >= Duration::from_millis(12));
        assert!(spec.realized_p99 <= Duration::from_millis(14));
        let generic = rows.iter().find(|r| r.member == "2x" && !r.specialized).unwrap();
        assert_eq!(generic.batches, 1);
        assert!(aggregate_buckets(&[]).is_empty());
        // traffic-mass shares: 4 samples × 3 requests, spec row holds 6
        assert!((spec.share - 0.5).abs() < 1e-12);
        assert!((generic.share - 0.25).abs() < 1e-12);
        let mass: f64 = rows.iter().map(|r| r.share).sum();
        assert!((mass - 1.0).abs() < 1e-12, "shares partition the traffic");
    }

    #[test]
    fn bucket_samples_round_trip_through_json() {
        let samples = vec![
            BucketSample {
                member: "2x".into(),
                batch: 8,
                seq: 32,
                specialized: true,
                exec: Duration::from_secs_f64(12e-3),
                requests: 6,
                certified: 10e-3,
            },
            BucketSample {
                member: "dense".into(),
                batch: 1,
                seq: 128,
                specialized: false,
                exec: Duration::from_secs_f64(80e-3),
                requests: 1,
                certified: 75e-3,
            },
        ];
        let j = samples_to_json(&samples);
        let back = samples_from_json(&Json::parse(&j.to_pretty()).unwrap()).unwrap();
        assert_eq!(samples, back);
        // bare-array form parses too (hand-written sample files)
        let bare = Json::Arr(samples.iter().map(BucketSample::to_json).collect());
        assert_eq!(samples, samples_from_json(&bare).unwrap());
        assert!(samples_from_json(&Json::Num(3.0)).is_err());
    }

    #[test]
    fn family_members_share_one_compiled_artifact() {
        // Each compiled artifact is built at most once across the
        // family. All masked variants of one (model, task) map to the
        // same (artifact, batch-shape) cache key, so N members × M
        // requests produce exactly one build; a shape-specialized
        // variant gets its own key and one build.
        let cache: CompileCache<&'static str> = CompileCache::new();
        let shared = ArtifactKey::new("bert__sst2__fwd", 8, 128);
        for _member in 0..3 {
            for _req in 0..4 {
                let exe = cache.get_or_build(&shared.encode(), || Ok("exe")).unwrap();
                assert_eq!(*exe, "exe");
            }
        }
        assert_eq!(cache.builds(), 1, "shared graph compiled more than once");
        assert_eq!(cache.hits(), 11);
        let spec = spec_key("bert", "sst2", "4x", (8, 128));
        cache.get_or_build(&spec.encode(), || Ok("spec")).unwrap();
        assert_eq!(cache.builds(), 2);
    }

    #[test]
    fn start_rejects_empty_and_mixed_families() {
        let env = InferenceEnv::measured(crate::latency::LatencyTable {
            model: "m".into(),
            device: "test".into(),
            regime: "throughput".into(),
            attn: vec![0.0, 1e-3, 2e-3],
            mlp: vec![(8, 4e-3), (0, 0.0)],
            overhead: 1e-3,
        })
        .unwrap();
        let cfg = || FamilyCfg {
            artifacts: std::path::PathBuf::from("artifacts"),
            max_batch: 8,
            max_wait: Duration::from_millis(1),
            pressure: 0,
            buckets: BucketLadder::default(),
            specialized: None,
        };
        assert!(start(cfg(), vec![], &env).is_err());
        // members disagreeing on (model, task) are rejected up front
        let (mi, ti, _st) = crate::models::tests_support::mini_state();
        let a = crate::models::ModelState::init(&mi, "task-a", &ti, 0);
        let b = crate::models::ModelState::init(&mi, "task-b", &ti, 1);
        assert!(start(cfg(), vec![("a".into(), a), ("b".into(), b)], &env).is_err());
    }
}
