//! Supervised serving fleet: one front-end router over N per-device
//! workers, with an explicit request lifecycle and deterministic fault
//! injection (DESIGN.md §10).
//!
//! The [`family`](super::family) coordinator keeps ZipLM's SLA promise
//! only while its single engine-owning worker never fails. This module
//! splits that loop into a *supervised fleet*:
//!
//! * a **supervisor** thread owns every request, queue, and reply
//!   channel — workers only ever receive cloned token ids
//!   ([`BatchOrder`]), so a crashing worker cannot take a request's
//!   reply path down with it (the no-lost-request invariant);
//! * N **workers**, each a simulated device ([`FleetMember`] profiles
//!   priced through a per-worker skewed [`InferenceEnv`] — see
//!   [`InferenceEnv::with_device_skew`]) with its own
//!   [`CompileCache`] shard and its own [`FaultStream`];
//! * every submitted request terminates in **exactly one**
//!   [`Outcome`]: `Replied` (served), `Shed` (admission refused — see
//!   [`ShedReason`]), or `Abandoned` (deadline passed while queued, or
//!   retries exhausted after worker failures).
//!
//! Failure handling, in escalation order (DESIGN.md §10):
//!
//! 1. a worker **panic or injected crash** never crosses the worker
//!    boundary: the worker loop runs orders under `catch_unwind` and a
//!    drop guard converts thread death into a `Down` event;
//! 2. the crashed worker's **in-flight batch is re-dispatched** to a
//!    sibling with bounded exponential backoff ([`RetryPolicy`]);
//!    requests that exhaust retries are `Abandoned`, never dropped;
//! 3. the supervisor **restarts** the dead worker after
//!    [`FleetCfg::restart_delay`] with a FRESH cache shard
//!    ([`CacheShards::replace`]) and the next incarnation's fault
//!    stream; the shard re-warms on demand, so after restart its
//!    `builds()` equals the distinct (member, bucket) pairs it
//!    re-serves — the re-warm acceptance invariant;
//! 4. repeated failures (crashes + compile failures) **quarantine the
//!    whole worker** — the per-worker escalation of the per-export
//!    quarantine the family loop already does per (member, bucket)
//!    pair. Quarantined workers are never restarted and their queues
//!    redistribute to siblings.
//!
//! Everything here is engine-free: no PJRT, no artifacts. Replies
//! carry [`sim_logits`] — a deterministic function of (member, ids) —
//! so integrity tests can verify a retried request was served by a
//! real member and not fabricated. The chaos harness over this module
//! lives in [`super::chaos`].

use std::collections::{HashSet, VecDeque};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use super::family::{percentile, BucketLadder, BucketSample, MemberRoute, Sla};
use crate::env::{CostModel, InferenceEnv};
use crate::runtime::{CacheShards, CompileCache, FaultPlan, FaultStream};
use crate::util::rng::Rng;

/// Logits width every simulated member produces per request.
pub const SIM_WIDTH: usize = 4;

// ------------------------------------------------------------ lifecycle

/// Why a request was refused at admission (terminal, DESIGN.md §10).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ShedReason {
    /// every live worker's queue is at [`FleetCfg::queue_cap`]
    QueueFull,
    /// no worker is alive and unquarantined
    NoCapacity,
    /// live workers have queue space, but no member on any of them can
    /// meet the request's SLA given the backlog already committed
    DeadlineUnmeetable,
}

impl std::fmt::Display for ShedReason {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = match self {
            ShedReason::QueueFull => "queue-full",
            ShedReason::NoCapacity => "no-capacity",
            ShedReason::DeadlineUnmeetable => "deadline-unmeetable",
        };
        f.write_str(s)
    }
}

/// Terminal outcome of one submitted request — exactly one per submit.
#[derive(Clone, Debug)]
pub enum Outcome {
    /// served; the reply carries the logits and serving metadata
    Replied(FleetReply),
    /// refused at (re-)admission
    Shed(ShedReason),
    /// deadline passed while queued, or retries exhausted after
    /// worker failures
    Abandoned {
        /// time from submit to abandonment
        waited: Duration,
        /// dispatch attempts consumed (0 = never dispatched)
        attempts: u32,
    },
}

impl Outcome {
    /// Whether this outcome is `Replied`.
    pub fn is_replied(&self) -> bool {
        matches!(self, Outcome::Replied(_))
    }

    /// Whether this outcome is `Shed`.
    pub fn is_shed(&self) -> bool {
        matches!(self, Outcome::Shed(_))
    }

    /// Whether this outcome is `Abandoned`.
    pub fn is_abandoned(&self) -> bool {
        matches!(self, Outcome::Abandoned { .. })
    }
}

/// Reply for one served request.
#[derive(Clone, Debug)]
pub struct FleetReply {
    /// simulated task logits ([`sim_logits`] of the serving member)
    pub logits: Vec<f32>,
    /// tag of the family member that served the request
    pub member: String,
    /// worker index that executed the batch
    pub worker: usize,
    /// worker incarnation at execution time (0 = never restarted)
    pub incarnation: u32,
    /// certified speedup of the serving member on this worker's device
    pub est_speedup: f64,
    /// time spent queued before the batch launched
    pub queue_time: Duration,
    /// end-to-end wall latency (submit → reply)
    pub latency: Duration,
    /// number of requests in the executed batch
    pub batch_size: usize,
    /// `(batch, seq)` shape bucket the batch executed at (the env
    /// anchor shape when no ladder bucket covered it)
    pub bucket: (usize, usize),
    /// whether a bucket-specialized executable served the batch
    pub specialized: bool,
    /// whether any fleet worker was down or quarantined at exec time
    pub degraded: bool,
    /// dispatch attempts this request consumed (>0 ⇒ it survived at
    /// least one worker failure and was re-dispatched)
    pub attempts: u32,
}

// --------------------------------------------------------------- config

/// Bounded exponential backoff for re-dispatching work lost to a
/// worker failure (DESIGN.md §10).
#[derive(Clone, Copy, Debug)]
pub struct RetryPolicy {
    /// dispatch attempts beyond the first before a request is
    /// `Abandoned` (0 = never retry)
    pub max_retries: u32,
    /// backoff before the first retry
    pub base: Duration,
    /// multiplier per further retry (clamped to ≥ 1.0)
    pub factor: f64,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy { max_retries: 2, base: Duration::from_millis(1), factor: 2.0 }
    }
}

impl RetryPolicy {
    /// Backoff before retry number `attempt` (1-based):
    /// `base * factor^(attempt-1)`, capped at 1s.
    pub fn backoff(&self, attempt: u32) -> Duration {
        let factor = if self.factor.is_finite() { self.factor.max(1.0) } else { 1.0 };
        let exp = attempt.saturating_sub(1).min(16);
        let secs = self.base.as_secs_f64() * factor.powi(exp as i32);
        Duration::from_secs_f64(secs.min(1.0).max(0.0))
    }
}

/// Fleet configuration.
#[derive(Clone, Debug)]
pub struct FleetCfg {
    /// number of workers (simulated devices); ≥ 1
    pub workers: usize,
    /// per-worker latency skew fed to [`InferenceEnv::with_device_skew`]
    /// (missing entries default to 1.0 — a homogeneous fleet)
    pub skews: Vec<f64>,
    /// max requests per executed batch
    pub max_batch: usize,
    /// how long a batch head waits for same-member stragglers
    pub max_wait: Duration,
    /// per-worker queue bound; admission sheds beyond it
    pub queue_cap: usize,
    /// re-dispatch policy for work lost to worker failures
    pub retry: RetryPolicy,
    /// failures (crashes + compile failures) after which a worker is
    /// quarantined instead of restarted
    pub quarantine_after: usize,
    /// delay before a crashed (unquarantined) worker restarts
    pub restart_delay: Duration,
    /// serving shape-bucket ladder (empty = anchor-only serving)
    pub buckets: BucketLadder,
    /// wall-seconds slept per priced second of simulated exec time
    /// (0.0 = no sleeping — virtual time only, the test default)
    pub time_scale: f64,
}

impl Default for FleetCfg {
    fn default() -> Self {
        FleetCfg {
            workers: 2,
            skews: Vec::new(),
            max_batch: 8,
            max_wait: Duration::from_millis(1),
            queue_cap: 64,
            retry: RetryPolicy::default(),
            quarantine_after: 3,
            restart_delay: Duration::from_millis(2),
            buckets: BucketLadder::default(),
            time_scale: 0.0,
        }
    }
}

/// One family member as the fleet serves it: tag + per-layer `(heads,
/// ffn)` profile. Engine-free — the profile is priced through each
/// worker's skewed env, exactly like
/// [`crate::models::family::FamilyMember::profile`] records it.
#[derive(Clone, Debug)]
pub struct FleetMember {
    /// member tag (routing + reply attribution)
    pub tag: String,
    /// per-layer `(heads, ffn width)` profile
    pub profile: Vec<(usize, usize)>,
}

// ------------------------------------------------------------ admission

/// Admission view of one worker, as [`admit`] sees it: pure data so
/// the shed policy is property-testable without threads.
#[derive(Clone, Debug)]
pub struct WorkerView<'a> {
    /// alive and not quarantined
    pub alive: bool,
    /// requests currently queued on this worker
    pub depth: usize,
    /// this worker's queue bound
    pub queue_cap: usize,
    /// priced exec seconds already committed to this worker's queue
    pub queued_time: f64,
    /// this worker's member routes, ascending certified speedup
    pub routes: &'a [MemberRoute],
}

/// Admit a request to `(worker, member)` or shed it (DESIGN.md §10).
///
/// Per live worker with queue space, the candidate member is the most
/// accurate one whose `est_speedup` clears the SLA's `min_speedup`
/// floor and whose admission estimate — the worker's committed
/// `queued_time` plus one batched forward of the member — fits inside
/// `max_latency`. Among workers with a candidate, the one with the
/// least committed time wins (ties → lower index, so routing is
/// deterministic). Shed reasons, in precedence order:
/// [`ShedReason::NoCapacity`] (no live worker at all), then
/// [`ShedReason::QueueFull`] (live workers, all at capacity), then
/// [`ShedReason::DeadlineUnmeetable`].
pub fn admit(sla: Option<&Sla>, workers: &[WorkerView]) -> Result<(usize, usize), ShedReason> {
    let mut any_alive = false;
    let mut any_space = false;
    let mut best: Option<(usize, usize, f64)> = None;
    for (w, v) in workers.iter().enumerate() {
        if !v.alive {
            continue;
        }
        any_alive = true;
        if v.depth >= v.queue_cap.max(1) {
            continue;
        }
        any_space = true;
        for (m, r) in v.routes.iter().enumerate() {
            if let Some(min_s) = sla.and_then(|s| s.min_speedup) {
                if r.est_speedup + 1e-9 < min_s {
                    continue;
                }
            }
            if let Some(max_l) = sla.and_then(|s| s.max_latency) {
                if v.queued_time + r.est_batch_time > max_l.as_secs_f64() {
                    continue;
                }
            }
            // most accurate qualifying member found for this worker
            let better = match best {
                None => true,
                Some((_, _, qt)) => v.queued_time < qt,
            };
            if better {
                best = Some((w, m, v.queued_time));
            }
            break;
        }
    }
    match best {
        Some((w, m, _)) => Ok((w, m)),
        None if !any_alive => Err(ShedReason::NoCapacity),
        None if !any_space => Err(ShedReason::QueueFull),
        None => Err(ShedReason::DeadlineUnmeetable),
    }
}

// ------------------------------------------------------------ simulator

/// Deterministic simulated logits for `(member, ids)`: what a fleet
/// worker replies with, and what integrity tests recompute to verify
/// a re-dispatched request was genuinely served by the claimed member.
pub fn sim_logits(member: &str, ids: &[i32], width: usize) -> Vec<f32> {
    // FNV-1a over (tag, ids) seeds a private stream
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for b in member.bytes() {
        h = (h ^ b as u64).wrapping_mul(0x0000_0100_0000_01b3);
    }
    for &i in ids {
        h = (h ^ i as u32 as u64).wrapping_mul(0x0000_0100_0000_01b3);
    }
    let mut rng = Rng::new(h);
    (0..width).map(|_| rng.f32()).collect()
}

/// A "compiled executable" on the simulated device: the priced exec
/// time of one batched forward at the key's shape.
#[derive(Clone, Copy, Debug)]
struct SimExe {
    time: f64,
    width: usize,
}

// ---------------------------------------------------------- wire types

/// What a worker receives: cloned ids only, never reply channels — a
/// crashing worker cannot lose a request, only a batch's work.
#[derive(Clone, Debug)]
struct BatchOrder {
    id: u64,
    member: usize,
    bucket: Option<(usize, usize)>,
    ids: Vec<Vec<i32>>,
}

enum Order {
    Run(BatchOrder),
    Stop,
}

enum BatchResult {
    Done { logits: Vec<Vec<f32>>, exec: f64, bucket: (usize, usize), specialized: bool },
    Failed { error: String },
}

enum Event {
    Submit(FleetRequest),
    Done { worker: usize, order: u64, result: BatchResult },
    Down { worker: usize },
    Shutdown,
}

/// One queued fleet request (built by [`FleetHandle::submit`]).
struct FleetRequest {
    ids: Vec<i32>,
    sla: Option<Sla>,
    submitted: Instant,
    reply: mpsc::Sender<Outcome>,
}

// ---------------------------------------------------------------- stats

/// Per-worker serving stats at shutdown.
#[derive(Clone, Debug, Default)]
pub struct WorkerStats {
    /// worker index
    pub worker: usize,
    /// final incarnation (0 = never restarted)
    pub incarnation: u32,
    /// requests served across all incarnations
    pub served: usize,
    /// crashes (injected + real panics)
    pub crashes: usize,
    /// supervisor restarts performed
    pub restarts: u32,
    /// whether the worker ended quarantined
    pub quarantined: bool,
    /// final cache shard's builds — after a restart this equals the
    /// distinct (member, bucket) pairs the re-warmed shard re-served
    pub builds: usize,
    /// final cache shard's hits
    pub hits: usize,
}

/// Normal-mode vs degraded-mode exec-latency tails (priced seconds).
/// A sample is "degraded" when any worker was down or quarantined at
/// execution time. NaN samples (injected poisoned latencies) are
/// counted in [`FleetStats::nan_samples`] and excluded here.
#[derive(Clone, Debug, Default)]
pub struct TailStats {
    /// batches executed with the whole fleet healthy
    pub normal_n: usize,
    /// median exec time, healthy fleet
    pub normal_p50: f64,
    /// p99 exec time, healthy fleet
    pub normal_p99: f64,
    /// batches executed while degraded
    pub degraded_n: usize,
    /// median exec time while degraded
    pub degraded_p50: f64,
    /// p99 exec time while degraded
    pub degraded_p99: f64,
}

/// Aggregate fleet statistics returned by [`FleetHandle::shutdown`].
#[derive(Clone, Debug, Default)]
pub struct FleetStats {
    /// requests submitted
    pub submitted: usize,
    /// requests that terminated `Replied`
    pub replied: usize,
    /// requests that terminated `Shed`
    pub shed: usize,
    /// requests that terminated `Abandoned`
    pub abandoned: usize,
    /// re-dispatch attempts scheduled after worker failures
    pub retries: usize,
    /// worker crashes observed (injected + panics)
    pub crashes: usize,
    /// supervisor-driven worker restarts
    pub restarts: usize,
    /// anchor-graph compile failures escalated to the supervisor
    pub compile_failures: usize,
    /// workers quarantined at shutdown
    pub quarantined_workers: usize,
    /// per-worker breakdown
    pub per_worker: Vec<WorkerStats>,
    /// normal vs degraded exec tails
    pub tails: TailStats,
    /// executable builds across all shards, retired incarnations
    /// included
    pub cache_builds: usize,
    /// executable-cache hits across all shards, retired included
    pub cache_hits: usize,
    /// injected-NaN latency samples (excluded from [`TailStats`])
    pub nan_samples: usize,
    /// the raw executed-batch stream, in completion order — same
    /// telemetry shape the family worker records, exportable via
    /// `--samples-out` and consumable by `adapt::detect_drift`
    /// (NaN-latency batches are excluded, counted in `nan_samples`)
    pub samples: Vec<BucketSample>,
}

impl FleetStats {
    /// Requests with a terminal outcome; equals [`FleetStats::submitted`]
    /// at shutdown — the exactly-one-outcome invariant as a number.
    pub fn accounted(&self) -> usize {
        self.replied + self.shed + self.abandoned
    }

    /// Drift-test the fleet's recorded sample stream against the env
    /// that certified the family it served (DESIGN.md §12). A pure
    /// pass over already-recorded telemetry — it never touches the
    /// supervisor, so surfacing drift cannot block serving.
    pub fn drift_report(
        &self,
        env: &InferenceEnv,
        cfg: &crate::adapt::DriftCfg,
    ) -> crate::adapt::DriftReport {
        crate::adapt::detect_drift(&self.samples, env, cfg)
    }
}

// --------------------------------------------------------------- handle

/// Handle to a running fleet.
pub struct FleetHandle {
    events: mpsc::Sender<Event>,
    supervisor: Option<JoinHandle<FleetStats>>,
}

impl FleetHandle {
    /// Submit a request; the receiver yields its single [`Outcome`].
    pub fn submit(&self, ids: Vec<i32>, sla: Option<Sla>) -> Result<mpsc::Receiver<Outcome>> {
        let (rtx, rrx) = mpsc::channel();
        self.events
            .send(Event::Submit(FleetRequest {
                ids,
                sla,
                submitted: Instant::now(),
                reply: rtx,
            }))
            .map_err(|_| anyhow!("fleet supervisor gone"))?;
        Ok(rrx)
    }

    /// Submit and wait (convenience).
    pub fn infer(&self, ids: Vec<i32>, sla: Option<Sla>) -> Result<Outcome> {
        let rx = self.submit(ids, sla)?;
        rx.recv().map_err(|_| anyhow!("fleet supervisor dropped the request"))
    }

    /// Stop accepting requests, drain every queue to a terminal
    /// outcome, stop the workers, and return the stats.
    pub fn shutdown(mut self) -> Result<FleetStats> {
        self.events.send(Event::Shutdown).map_err(|_| anyhow!("fleet supervisor gone"))?;
        self.supervisor
            .take()
            .ok_or_else(|| anyhow!("already stopped"))?
            .join()
            .map_err(|_| anyhow!("fleet supervisor panicked"))
    }
}

/// Start a fleet of [`FleetCfg::workers`] simulated devices serving
/// `members`, priced against per-worker skews of `env`, with faults
/// injected per `plan` ([`FaultPlan::none`] for production behavior).
///
/// Members are served in ascending base-env speedup order (index 0 =
/// most accurate), the same ordering contract as
/// [`super::family::start`]; uniform skew preserves it per worker.
pub fn start(
    cfg: FleetCfg,
    members: Vec<FleetMember>,
    env: &InferenceEnv,
    plan: FaultPlan,
) -> Result<FleetHandle> {
    if cfg.workers == 0 {
        return Err(anyhow!("fleet must have at least one worker"));
    }
    if members.is_empty() {
        return Err(anyhow!("fleet must serve at least one member"));
    }
    for m in &members {
        if m.profile.is_empty() {
            return Err(anyhow!("fleet member `{}` has an empty profile", m.tag));
        }
    }
    // fixed member order: ascending base-env speedup
    let mut order: Vec<usize> = (0..members.len()).collect();
    let base: Vec<f64> = members.iter().map(|m| env.speedup(&m.profile)).collect();
    order.sort_by(|&a, &b| base[a].total_cmp(&base[b]));
    let mut routes_per_worker = Vec::with_capacity(cfg.workers);
    for w in 0..cfg.workers {
        let skew = cfg.skews.get(w).copied().unwrap_or(1.0);
        let we = env.with_device_skew(skew);
        routes_per_worker.push(
            order
                .iter()
                .map(|&i| MemberRoute {
                    tag: members[i].tag.clone(),
                    est_speedup: we.speedup(&members[i].profile),
                    est_batch_time: we.model_time(&members[i].profile),
                    bucket_times: cfg
                        .buckets
                        .buckets()
                        .iter()
                        .map(|&(b, s)| ((b, s), we.batch_time(&members[i].profile, b, s)))
                        .collect(),
                })
                .collect::<Vec<_>>(),
        );
    }
    let anchor = env.batch_shape();
    let shards: CacheShards<SimExe> = CacheShards::new(cfg.workers);
    let (events_tx, events_rx) = mpsc::channel::<Event>();
    let mut workers = Vec::with_capacity(cfg.workers);
    for w in 0..cfg.workers {
        let (orders, join) = spawn_worker(
            w,
            routes_per_worker[w].clone(),
            anchor,
            shards.shard(w),
            plan.stream(w, 0),
            cfg.time_scale,
            events_tx.clone(),
        )?;
        workers.push(WorkerSlot {
            alive: true,
            quarantined: false,
            orders: Some(orders),
            join: Some(join),
            queue: VecDeque::new(),
            queued_time: 0.0,
            busy: None,
            restart_at: None,
            incarnation: 0,
            failures: 0,
            crashes: 0,
            served: 0,
            restarts: 0,
        });
    }
    let supervisor = Supervisor {
        cfg,
        plan,
        anchor,
        routes_per_worker,
        shards,
        workers,
        events_tx: events_tx.clone(),
        events_rx,
        retries: Vec::new(),
        next_order: 0,
        draining: false,
        submitted: 0,
        replied: 0,
        shed_n: 0,
        abandoned: 0,
        retries_n: 0,
        crashes: 0,
        restarts: 0,
        compile_failures: 0,
        retired_builds: 0,
        retired_hits: 0,
        normal: Vec::new(),
        degraded_samples: Vec::new(),
        nan_samples: 0,
        samples: Vec::new(),
    };
    let join = std::thread::Builder::new()
        .name("ziplm-fleet-supervisor".into())
        .spawn(move || supervisor.run())
        .map_err(|e| anyhow!("spawn fleet supervisor: {e}"))?;
    Ok(FleetHandle { events: events_tx, supervisor: Some(join) })
}

// --------------------------------------------------------------- worker

/// Converts worker-thread death (panic OR injected crash) into a
/// `Down` event; disarmed only on graceful stop, so no exit path can
/// silently strand the supervisor's in-flight record.
struct DownGuard {
    worker: usize,
    events: mpsc::Sender<Event>,
    armed: bool,
}

impl Drop for DownGuard {
    fn drop(&mut self) {
        if self.armed {
            let _ = self.events.send(Event::Down { worker: self.worker });
        }
    }
}

fn spawn_worker(
    worker: usize,
    routes: Vec<MemberRoute>,
    anchor: (usize, usize),
    shard: std::sync::Arc<CompileCache<SimExe>>,
    stream: FaultStream,
    time_scale: f64,
    events: mpsc::Sender<Event>,
) -> Result<(mpsc::Sender<Order>, JoinHandle<()>)> {
    let (otx, orx) = mpsc::channel::<Order>();
    let join = std::thread::Builder::new()
        .name(format!("ziplm-fleet-w{worker}"))
        .spawn(move || {
            let mut guard = DownGuard { worker, events: events.clone(), armed: true };
            let mut stream = stream;
            // per-incarnation quarantines of (member, bucket) pairs and
            // anchor graphs whose compile failed — PR 5's per-export
            // quarantine, now per worker incarnation
            let mut bad: HashSet<(usize, (usize, usize))> = HashSet::new();
            let mut anchor_bad: HashSet<usize> = HashSet::new();
            loop {
                let order = match orx.recv() {
                    Ok(o) => o,
                    Err(_) => {
                        // supervisor gone: graceful exit, not a crash
                        guard.armed = false;
                        return;
                    }
                };
                let o = match order {
                    Order::Stop => {
                        guard.armed = false;
                        return;
                    }
                    Order::Run(o) => o,
                };
                // no panic crosses the worker boundary: a backend panic
                // is downgraded to this worker's crash path
                let res = catch_unwind(AssertUnwindSafe(|| {
                    run_order(&routes, anchor, &shard, &mut stream, &mut bad, &mut anchor_bad, time_scale, &o)
                }));
                match res {
                    Ok(Some(result)) => {
                        if events.send(Event::Done { worker, order: o.id, result }).is_err() {
                            guard.armed = false;
                            return;
                        }
                    }
                    // injected crash or real panic: fall off the loop
                    // with the guard armed → `Down` fires
                    Ok(None) | Err(_) => return,
                }
            }
        })
        .map_err(|e| anyhow!("spawn fleet worker {worker}: {e}"))?;
    Ok((otx, join))
}

/// Execute one batch on the simulated device. `None` = injected crash
/// (the caller dies with its guard armed).
#[allow(clippy::too_many_arguments)]
fn run_order(
    routes: &[MemberRoute],
    anchor: (usize, usize),
    shard: &CompileCache<SimExe>,
    stream: &mut FaultStream,
    bad: &mut HashSet<(usize, (usize, usize))>,
    anchor_bad: &mut HashSet<usize>,
    time_scale: f64,
    o: &BatchOrder,
) -> Option<BatchResult> {
    let fault = stream.exec_fault();
    if fault.crash {
        return None;
    }
    // poison pill: a batch containing i32::MIN panics the simulated
    // backend. The chaos tests submit it to prove a REAL panic (not
    // just an injected crash) never crosses the worker boundary.
    if o.ids.iter().any(|ids| ids.contains(&i32::MIN)) {
        panic!("poison pill executed on the simulated device");
    }
    let Some(route) = routes.get(o.member) else {
        return Some(BatchResult::Failed { error: format!("unknown member index {}", o.member) });
    };
    // bucket-specialized executable first (demand compile against this
    // incarnation's shard), anchor graph as the fallback
    let mut served = anchor;
    let mut specialized = false;
    let mut exe = None;
    if let Some(bk) = o.bucket {
        if !bad.contains(&(o.member, bk)) {
            let key = format!("{}@b{}s{}", route.tag, bk.0, bk.1);
            let cold = !shard.contains(&key);
            let fail = cold && stream.compile_fault();
            match shard.get_or_build(&key, || {
                if fail {
                    Err(anyhow!("injected compile failure: {key}"))
                } else {
                    Ok(SimExe { time: route.time_at(Some(bk)), width: SIM_WIDTH })
                }
            }) {
                Ok(e) => {
                    served = bk;
                    specialized = true;
                    exe = Some(e);
                }
                Err(_) => {
                    bad.insert((o.member, bk));
                }
            }
        }
    }
    let exe = match exe {
        Some(e) => e,
        None => {
            if anchor_bad.contains(&o.member) {
                return Some(BatchResult::Failed {
                    error: format!("anchor graph for `{}` quarantined", route.tag),
                });
            }
            let key = format!("{}@anchor", route.tag);
            let cold = !shard.contains(&key);
            let fail = cold && stream.compile_fault();
            match shard.get_or_build(&key, || {
                if fail {
                    Err(anyhow!("injected compile failure: {key}"))
                } else {
                    Ok(SimExe { time: route.est_batch_time, width: SIM_WIDTH })
                }
            }) {
                Ok(e) => e,
                Err(e) => {
                    anchor_bad.insert(o.member);
                    return Some(BatchResult::Failed { error: e.to_string() });
                }
            }
        }
    };
    let exec = exe.time * fault.slowdown;
    if time_scale > 0.0 {
        let s = exec * time_scale;
        if s.is_finite() && s > 0.0 {
            std::thread::sleep(Duration::from_secs_f64(s.min(1.0)));
        }
    }
    let logits = o.ids.iter().map(|ids| sim_logits(&route.tag, ids, exe.width)).collect();
    Some(BatchResult::Done {
        logits,
        // the reply is correct even when the latency SAMPLE is poisoned
        exec: if fault.nan_latency { f64::NAN } else { exec },
        bucket: served,
        specialized,
    })
}

// ----------------------------------------------------------- supervisor

struct Pending {
    ids: Vec<i32>,
    sla: Option<Sla>,
    submitted: Instant,
    deadline: Option<Instant>,
    enqueued: Instant,
    attempts: u32,
    est: f64,
    member: usize,
    reply: mpsc::Sender<Outcome>,
}

struct InFlight {
    order: u64,
    member: usize,
    reqs: Vec<Pending>,
    launched: Instant,
}

struct RetryItem {
    not_before: Instant,
    req: Pending,
}

struct WorkerSlot {
    alive: bool,
    quarantined: bool,
    orders: Option<mpsc::Sender<Order>>,
    join: Option<JoinHandle<()>>,
    queue: VecDeque<Pending>,
    queued_time: f64,
    busy: Option<InFlight>,
    restart_at: Option<Instant>,
    incarnation: u32,
    failures: usize,
    crashes: usize,
    served: usize,
    restarts: u32,
}

struct Supervisor {
    cfg: FleetCfg,
    plan: FaultPlan,
    anchor: (usize, usize),
    routes_per_worker: Vec<Vec<MemberRoute>>,
    shards: CacheShards<SimExe>,
    workers: Vec<WorkerSlot>,
    events_tx: mpsc::Sender<Event>,
    events_rx: mpsc::Receiver<Event>,
    retries: Vec<RetryItem>,
    next_order: u64,
    draining: bool,
    submitted: usize,
    replied: usize,
    shed_n: usize,
    abandoned: usize,
    retries_n: usize,
    crashes: usize,
    restarts: usize,
    compile_failures: usize,
    retired_builds: usize,
    retired_hits: usize,
    normal: Vec<f64>,
    degraded_samples: Vec<f64>,
    nan_samples: usize,
    samples: Vec<BucketSample>,
}

impl Supervisor {
    fn run(mut self) -> FleetStats {
        loop {
            let timeout = self.next_timeout();
            match self.events_rx.recv_timeout(timeout) {
                Ok(Event::Submit(req)) => self.on_submit(req),
                Ok(Event::Done { worker, order, result }) => self.on_done(worker, order, result),
                Ok(Event::Down { worker }) => self.on_down(worker),
                Ok(Event::Shutdown) | Err(mpsc::RecvTimeoutError::Disconnected) => {
                    self.draining = true;
                }
                Err(mpsc::RecvTimeoutError::Timeout) => {}
            }
            self.pump();
            if self.draining && self.idle() {
                break;
            }
        }
        self.finish()
    }

    /// Sleep until the earliest pending deadline: a batch's max_wait, a
    /// request's abandonment, a retry release, or a worker restart.
    fn next_timeout(&self) -> Duration {
        let now = Instant::now();
        let mut earliest: Option<Instant> = None;
        let mut consider = |t: Instant| match earliest {
            Some(e) if e <= t => {}
            _ => earliest = Some(t),
        };
        for s in &self.workers {
            if let Some(t) = s.restart_at {
                consider(t);
            }
            if let Some(p) = s.queue.front() {
                if s.alive && !s.quarantined && s.busy.is_none() {
                    consider(p.enqueued + self.cfg.max_wait);
                }
            }
            for p in &s.queue {
                if let Some(d) = p.deadline {
                    consider(d);
                }
            }
        }
        for r in &self.retries {
            consider(r.not_before);
            if let Some(d) = r.req.deadline {
                consider(d);
            }
        }
        match earliest {
            Some(t) => t.saturating_duration_since(now).min(Duration::from_millis(25)),
            None => Duration::from_millis(25),
        }
    }

    fn idle(&self) -> bool {
        self.retries.is_empty()
            && self.workers.iter().all(|s| s.queue.is_empty() && s.busy.is_none())
    }

    fn views(&self) -> Vec<WorkerView<'_>> {
        self.workers
            .iter()
            .enumerate()
            .map(|(w, s)| WorkerView {
                alive: s.alive && !s.quarantined,
                depth: s.queue.len(),
                queue_cap: self.cfg.queue_cap,
                queued_time: s.queued_time,
                routes: &self.routes_per_worker[w],
            })
            .collect()
    }

    fn on_submit(&mut self, req: FleetRequest) {
        self.submitted += 1;
        let now = Instant::now();
        // bring due restarts online BEFORE admission: a request must
        // not shed NoCapacity just because the bookkeeping sweep in
        // `pump` had not yet run this loop iteration
        self.do_restarts(now);
        let decision = admit(req.sla.as_ref(), &self.views());
        match decision {
            Ok((w, m)) => {
                let deadline = req
                    .sla
                    .as_ref()
                    .and_then(|s| s.max_latency)
                    .map(|d| req.submitted + d);
                let p = Pending {
                    ids: req.ids,
                    sla: req.sla,
                    submitted: req.submitted,
                    deadline,
                    enqueued: now,
                    attempts: 0,
                    est: 0.0,
                    member: m,
                    reply: req.reply,
                };
                self.enqueue(w, m, p, now);
            }
            Err(reason) => {
                self.shed_n += 1;
                let _ = req.reply.send(Outcome::Shed(reason));
            }
        }
    }

    fn enqueue(&mut self, w: usize, m: usize, mut p: Pending, now: Instant) {
        p.member = m;
        p.est = self.routes_per_worker[w][m].est_batch_time;
        p.enqueued = now;
        let slot = &mut self.workers[w];
        slot.queued_time += p.est;
        slot.queue.push_back(p);
    }

    fn on_done(&mut self, worker: usize, order: u64, result: BatchResult) {
        let degraded = self.workers.iter().any(|s| !s.alive || s.quarantined);
        let matches =
            self.workers[worker].busy.as_ref().is_some_and(|b| b.order == order);
        if !matches {
            return; // stale completion (should not happen; defensive)
        }
        let Some(inflight) = self.workers[worker].busy.take() else { return };
        match result {
            BatchResult::Done { logits, exec, bucket, specialized } => {
                if exec.is_nan() {
                    self.nan_samples += 1;
                } else if degraded {
                    self.degraded_samples.push(exec);
                } else {
                    self.normal.push(exec);
                }
                let route = &self.routes_per_worker[worker][inflight.member];
                let (tag, speedup) = (route.tag.clone(), route.est_speedup);
                let incarnation = self.workers[worker].incarnation;
                let n = inflight.reqs.len();
                if !exec.is_nan() {
                    self.samples.push(BucketSample {
                        member: tag.clone(),
                        batch: bucket.0,
                        seq: bucket.1,
                        specialized,
                        exec: Duration::from_secs_f64(exec.max(0.0)),
                        requests: n,
                        certified: route.time_at(specialized.then_some(bucket)),
                    });
                }
                for (k, p) in inflight.reqs.into_iter().enumerate() {
                    self.replied += 1;
                    self.workers[worker].served += 1;
                    let _ = p.reply.send(Outcome::Replied(FleetReply {
                        logits: logits.get(k).cloned().unwrap_or_default(),
                        member: tag.clone(),
                        worker,
                        incarnation,
                        est_speedup: speedup,
                        queue_time: inflight.launched.duration_since(p.submitted),
                        latency: p.submitted.elapsed(),
                        batch_size: n,
                        bucket,
                        specialized,
                        degraded,
                        attempts: p.attempts,
                    }));
                }
            }
            BatchResult::Failed { .. } => {
                self.compile_failures += 1;
                self.workers[worker].failures += 1;
                if self.workers[worker].failures >= self.cfg.quarantine_after.max(1) {
                    self.quarantine(worker);
                }
                self.requeue_failed(inflight.reqs);
            }
        }
    }

    fn on_down(&mut self, worker: usize) {
        let now = Instant::now();
        self.crashes += 1;
        {
            let slot = &mut self.workers[worker];
            slot.alive = false;
            slot.orders = None;
            slot.crashes += 1;
            slot.failures += 1;
            // reap the dead thread (it has already exited)
            if let Some(h) = slot.join.take() {
                let _ = h.join();
            }
        }
        let quarantine = self.workers[worker].failures >= self.cfg.quarantine_after.max(1);
        if quarantine {
            self.workers[worker].quarantined = true;
            self.workers[worker].restart_at = None;
        } else if !self.draining {
            self.workers[worker].restart_at = Some(now + self.cfg.restart_delay);
        }
        // in-flight work from the crashed worker: bounded retry on a
        // sibling, never silently dropped
        if let Some(inflight) = self.workers[worker].busy.take() {
            self.requeue_failed(inflight.reqs);
        }
        // queued (not yet dispatched) requests re-admit immediately
        let queued: Vec<Pending> = self.workers[worker].queue.drain(..).collect();
        self.workers[worker].queued_time = 0.0;
        for p in queued {
            self.readmit_or_abandon(p, now);
        }
    }

    /// Quarantine a worker: stop routing to it and redistribute its
    /// queue. A quarantined worker is never restarted (DESIGN.md §10).
    fn quarantine(&mut self, worker: usize) {
        if self.workers[worker].quarantined {
            return;
        }
        self.workers[worker].quarantined = true;
        self.workers[worker].restart_at = None;
        let now = Instant::now();
        let queued: Vec<Pending> = self.workers[worker].queue.drain(..).collect();
        self.workers[worker].queued_time = 0.0;
        for p in queued {
            self.readmit_or_abandon(p, now);
        }
    }

    /// Schedule lost batch work for re-dispatch with backoff; requests
    /// beyond [`RetryPolicy::max_retries`] are `Abandoned`.
    fn requeue_failed(&mut self, reqs: Vec<Pending>) {
        let now = Instant::now();
        for mut p in reqs {
            p.attempts += 1;
            if p.attempts > self.cfg.retry.max_retries {
                self.abandoned += 1;
                let _ = p.reply.send(Outcome::Abandoned {
                    waited: now.duration_since(p.submitted),
                    attempts: p.attempts,
                });
            } else {
                self.retries_n += 1;
                let not_before = now + self.cfg.retry.backoff(p.attempts);
                self.retries.push(RetryItem { not_before, req: p });
            }
        }
    }

    /// Re-admit a displaced request; if no sibling can take it, the
    /// request is `Abandoned` (it was admitted once — shedding again
    /// would misreport an admission refusal).
    fn readmit_or_abandon(&mut self, p: Pending, now: Instant) {
        let decision = admit(p.sla.as_ref(), &self.views());
        match decision {
            Ok((w, m)) => self.enqueue(w, m, p, now),
            Err(_) => {
                self.abandoned += 1;
                let _ = p.reply.send(Outcome::Abandoned {
                    waited: now.duration_since(p.submitted),
                    attempts: p.attempts,
                });
            }
        }
    }

    /// Timer-driven work: abandon expired requests, release due
    /// retries, restart due workers, launch ready batches.
    fn pump(&mut self) {
        let now = Instant::now();
        self.sweep_abandons(now);
        // due restarts FIRST: a released retry must see a worker whose
        // restart_delay has already elapsed as alive, not abandon
        // because the bookkeeping had not caught up yet
        self.do_restarts(now);
        // due retries
        let mut due = Vec::new();
        let mut i = 0;
        while i < self.retries.len() {
            if now >= self.retries[i].not_before {
                due.push(self.retries.swap_remove(i));
            } else {
                i += 1;
            }
        }
        for item in due {
            self.readmit_or_abandon(item.req, now);
        }
        for w in 0..self.workers.len() {
            self.try_launch(w, now);
        }
    }

    /// Restart every crashed worker whose `restart_delay` has elapsed.
    fn do_restarts(&mut self, now: Instant) {
        for w in 0..self.workers.len() {
            if self.workers[w].restart_at.is_some_and(|t| now >= t) {
                self.restart(w);
            }
        }
    }

    fn sweep_abandons(&mut self, now: Instant) {
        for w in 0..self.workers.len() {
            let slot = &mut self.workers[w];
            let mut i = 0;
            while i < slot.queue.len() {
                let expired = slot.queue[i].deadline.is_some_and(|d| now >= d);
                if expired {
                    if let Some(p) = slot.queue.remove(i) {
                        slot.queued_time = (slot.queued_time - p.est).max(0.0);
                        self.abandoned += 1;
                        let _ = p.reply.send(Outcome::Abandoned {
                            waited: now.duration_since(p.submitted),
                            attempts: p.attempts,
                        });
                    }
                } else {
                    i += 1;
                }
            }
        }
        let mut i = 0;
        while i < self.retries.len() {
            let expired = self.retries[i].req.deadline.is_some_and(|d| now >= d);
            if expired {
                let item = self.retries.swap_remove(i);
                self.abandoned += 1;
                let _ = item.req.reply.send(Outcome::Abandoned {
                    waited: now.duration_since(item.req.submitted),
                    attempts: item.req.attempts,
                });
            } else {
                i += 1;
            }
        }
    }

    /// Restart a crashed worker: next incarnation, fresh cache shard
    /// (executables died with the device process), fresh fault stream.
    fn restart(&mut self, w: usize) {
        let inc = self.workers[w].incarnation + 1;
        let retired = self.shards.replace(w);
        self.retired_builds += retired.builds();
        self.retired_hits += retired.hits();
        let spawned = spawn_worker(
            w,
            self.routes_per_worker[w].clone(),
            self.anchor,
            self.shards.shard(w),
            self.plan.stream(w, inc),
            self.cfg.time_scale,
            self.events_tx.clone(),
        );
        let slot = &mut self.workers[w];
        slot.restart_at = None;
        match spawned {
            Ok((orders, join)) => {
                slot.orders = Some(orders);
                slot.join = Some(join);
                slot.alive = true;
                slot.busy = None;
                slot.incarnation = inc;
                slot.restarts += 1;
                self.restarts += 1;
            }
            Err(_) => {
                // the OS refused a thread: treat like terminal failure
                slot.quarantined = true;
            }
        }
    }

    /// Launch the head batch on an idle worker: a contiguous
    /// same-member prefix, once it reaches `max_batch` or the head has
    /// waited `max_wait` (immediately while draining).
    fn try_launch(&mut self, w: usize, now: Instant) {
        let b = self.cfg.max_batch.max(1);
        let slot = &mut self.workers[w];
        if !slot.alive || slot.quarantined || slot.busy.is_some() || slot.queue.is_empty() {
            return;
        }
        let head_member = slot.queue[0].member;
        let prefix = slot
            .queue
            .iter()
            .take_while(|p| p.member == head_member)
            .take(b)
            .count();
        let due = now >= slot.queue[0].enqueued + self.cfg.max_wait;
        if prefix < b && !due && !self.draining {
            return;
        }
        let mut reqs = Vec::with_capacity(prefix);
        for _ in 0..prefix {
            if let Some(p) = slot.queue.pop_front() {
                slot.queued_time = (slot.queued_time - p.est).max(0.0);
                reqs.push(p);
            }
        }
        let max_len = reqs.iter().map(|p| p.ids.len()).max().unwrap_or(0);
        let bucket = self.cfg.buckets.bucket_for(reqs.len(), max_len);
        self.next_order += 1;
        let id = self.next_order;
        let order = BatchOrder {
            id,
            member: head_member,
            bucket,
            ids: reqs.iter().map(|p| p.ids.clone()).collect(),
        };
        let sent = slot
            .orders
            .as_ref()
            .map(|tx| tx.send(Order::Run(order)).is_ok())
            .unwrap_or(false);
        if sent {
            slot.busy = Some(InFlight { order: id, member: head_member, reqs, launched: now });
        } else {
            // worker died between Down being sent and processed: put
            // the requests back; the pending Down event redistributes
            for p in reqs.into_iter().rev() {
                slot.queued_time += p.est;
                slot.queue.push_front(p);
            }
        }
    }

    fn finish(mut self) -> FleetStats {
        for s in &mut self.workers {
            if let Some(tx) = s.orders.take() {
                let _ = tx.send(Order::Stop);
            }
        }
        for s in &mut self.workers {
            if let Some(h) = s.join.take() {
                let _ = h.join();
            }
        }
        let mut tails = TailStats::default();
        let fill = |samples: &mut Vec<f64>| -> (usize, f64, f64) {
            samples.sort_by(|a, b| a.total_cmp(b));
            (samples.len(), percentile(samples, 0.50), percentile(samples, 0.99))
        };
        let (n, p50, p99) = fill(&mut self.normal);
        (tails.normal_n, tails.normal_p50, tails.normal_p99) = (n, p50, p99);
        let (n, p50, p99) = fill(&mut self.degraded_samples);
        (tails.degraded_n, tails.degraded_p50, tails.degraded_p99) = (n, p50, p99);
        let per_worker: Vec<WorkerStats> = self
            .workers
            .iter()
            .enumerate()
            .map(|(w, s)| WorkerStats {
                worker: w,
                incarnation: s.incarnation,
                served: s.served,
                crashes: s.crashes,
                restarts: s.restarts,
                quarantined: s.quarantined,
                builds: self.shards.shard(w).builds(),
                hits: self.shards.shard(w).hits(),
            })
            .collect();
        FleetStats {
            submitted: self.submitted,
            replied: self.replied,
            shed: self.shed_n,
            abandoned: self.abandoned,
            retries: self.retries_n,
            crashes: self.crashes,
            restarts: self.restarts,
            compile_failures: self.compile_failures,
            quarantined_workers: self.workers.iter().filter(|s| s.quarantined).count(),
            per_worker,
            tails,
            cache_builds: self.shards.builds() + self.retired_builds,
            cache_hits: self.shards.hits() + self.retired_hits,
            nan_samples: self.nan_samples,
            samples: self.samples,
        }
    }
}

#[cfg(test)]
#[allow(clippy::disallowed_methods)]
mod tests {
    use super::*;
    use crate::env::Regime;
    use crate::latency::LatencyTable;
    use crate::runtime::FaultRates;

    fn env() -> InferenceEnv {
        let table = LatencyTable {
            model: "m".into(),
            device: "sim".into(),
            regime: "throughput".into(),
            attn: vec![0.0, 1.0e-3, 1.8e-3, 2.5e-3, 3.1e-3],
            mlp: vec![(512, 8e-3), (256, 4.2e-3), (64, 1.5e-3), (0, 0.0)],
            overhead: 1e-3,
        };
        InferenceEnv::measured(table).unwrap().with_batch_shape(8, 128)
    }

    fn members() -> Vec<FleetMember> {
        vec![
            FleetMember { tag: "dense".into(), profile: vec![(4, 512); 2] },
            FleetMember { tag: "2x".into(), profile: vec![(2, 256); 2] },
            FleetMember { tag: "4x".into(), profile: vec![(1, 64); 2] },
        ]
    }

    fn quick_cfg(workers: usize) -> FleetCfg {
        FleetCfg {
            workers,
            max_wait: Duration::from_micros(200),
            restart_delay: Duration::from_micros(500),
            retry: RetryPolicy {
                max_retries: 3,
                base: Duration::from_micros(200),
                factor: 2.0,
            },
            ..FleetCfg::default()
        }
    }

    #[test]
    fn env_regime_is_parsed() {
        assert_eq!(env().regime(), Regime::Throughput);
    }

    #[test]
    fn backoff_grows_and_caps() {
        let r = RetryPolicy { max_retries: 5, base: Duration::from_millis(2), factor: 2.0 };
        assert_eq!(r.backoff(1), Duration::from_millis(2));
        assert_eq!(r.backoff(2), Duration::from_millis(4));
        assert_eq!(r.backoff(3), Duration::from_millis(8));
        assert!(r.backoff(40) <= Duration::from_secs(1));
        // degenerate factors clamp instead of exploding
        let bad = RetryPolicy { max_retries: 1, base: Duration::from_millis(2), factor: f64::NAN };
        assert_eq!(bad.backoff(3), Duration::from_millis(2));
        let shrink = RetryPolicy { max_retries: 1, base: Duration::from_millis(2), factor: 0.1 };
        assert_eq!(shrink.backoff(3), Duration::from_millis(2));
    }

    fn mk_routes(times: &[f64]) -> Vec<MemberRoute> {
        times
            .iter()
            .enumerate()
            .map(|(i, &t)| MemberRoute {
                tag: format!("m{i}"),
                est_speedup: 1.0 + i as f64,
                est_batch_time: t,
                bucket_times: Vec::new(),
            })
            .collect()
    }

    #[test]
    fn admit_prefers_least_loaded_live_worker() {
        let routes = mk_routes(&[40e-3, 20e-3]);
        let views = vec![
            WorkerView { alive: true, depth: 3, queue_cap: 8, queued_time: 0.12, routes: &routes },
            WorkerView { alive: true, depth: 1, queue_cap: 8, queued_time: 0.04, routes: &routes },
        ];
        assert_eq!(admit(None, &views), Ok((1, 0)));
        // dead workers are skipped even when emptier
        let views = vec![
            WorkerView { alive: false, depth: 0, queue_cap: 8, queued_time: 0.0, routes: &routes },
            WorkerView { alive: true, depth: 5, queue_cap: 8, queued_time: 0.2, routes: &routes },
        ];
        assert_eq!(admit(None, &views), Ok((1, 0)));
    }

    #[test]
    fn admit_sheds_with_the_right_reason() {
        let routes = mk_routes(&[40e-3, 20e-3]);
        // nobody alive
        let views = vec![WorkerView {
            alive: false,
            depth: 0,
            queue_cap: 8,
            queued_time: 0.0,
            routes: &routes,
        }];
        assert_eq!(admit(None, &views), Err(ShedReason::NoCapacity));
        // alive but full
        let views = vec![WorkerView {
            alive: true,
            depth: 8,
            queue_cap: 8,
            queued_time: 0.3,
            routes: &routes,
        }];
        assert_eq!(admit(None, &views), Err(ShedReason::QueueFull));
        // space, but the backlog exceeds every member's deadline fit
        let views = vec![WorkerView {
            alive: true,
            depth: 2,
            queue_cap: 8,
            queued_time: 0.5,
            routes: &routes,
        }];
        let sla = Sla {
            class: "rt".into(),
            max_latency: Some(Duration::from_millis(10)),
            min_speedup: None,
        };
        assert_eq!(admit(Some(&sla), &views), Err(ShedReason::DeadlineUnmeetable));
    }

    #[test]
    fn admit_honors_min_speedup_and_deadline_member_choice() {
        let routes = mk_routes(&[40e-3, 20e-3, 5e-3]); // speedups 1.0, 2.0, 3.0
        let views = vec![WorkerView {
            alive: true,
            depth: 0,
            queue_cap: 8,
            queued_time: 0.0,
            routes: &routes,
        }];
        // min_speedup pushes past the most accurate member
        let sla = Sla { class: "c".into(), max_latency: None, min_speedup: Some(1.5) };
        assert_eq!(admit(Some(&sla), &views), Ok((0, 1)));
        // a tight deadline pushes to the fastest member
        let sla = Sla {
            class: "rt".into(),
            max_latency: Some(Duration::from_millis(10)),
            min_speedup: None,
        };
        assert_eq!(admit(Some(&sla), &views), Ok((0, 2)));
    }

    #[test]
    fn sim_logits_deterministic_and_member_dependent() {
        let a = sim_logits("2x", &[1, 2, 3], SIM_WIDTH);
        assert_eq!(a.len(), SIM_WIDTH);
        assert_eq!(a, sim_logits("2x", &[1, 2, 3], SIM_WIDTH));
        assert_ne!(a, sim_logits("4x", &[1, 2, 3], SIM_WIDTH));
        assert_ne!(a, sim_logits("2x", &[1, 2, 4], SIM_WIDTH));
    }

    #[test]
    fn fault_free_fleet_replies_to_everything() {
        let fleet = start(quick_cfg(2), members(), &env(), FaultPlan::none()).unwrap();
        let mut rxs = Vec::new();
        for i in 0..40i32 {
            rxs.push(fleet.submit(vec![i; 8], None).unwrap());
        }
        for (i, rx) in rxs.into_iter().enumerate() {
            let out = rx.recv_timeout(Duration::from_secs(20)).unwrap();
            match out {
                Outcome::Replied(r) => {
                    // logits must be the serving member's genuine output
                    assert_eq!(r.logits, sim_logits(&r.member, &vec![i as i32; 8], SIM_WIDTH));
                    assert_eq!(r.attempts, 0);
                    assert!(!r.degraded);
                }
                other => panic!("fault-free fleet must reply, got {other:?}"),
            }
        }
        let stats = fleet.shutdown().unwrap();
        assert_eq!(stats.submitted, 40);
        assert_eq!(stats.replied, 40);
        assert_eq!(stats.accounted(), stats.submitted);
        assert_eq!(stats.crashes, 0);
        assert_eq!(stats.nan_samples, 0);
    }

    #[test]
    fn replies_carry_genuine_member_logits() {
        let fleet = start(quick_cfg(1), members(), &env(), FaultPlan::none()).unwrap();
        let ids = vec![5, 6, 7, 8];
        let out = fleet.infer(ids.clone(), None).unwrap();
        let Outcome::Replied(r) = out else { panic!("expected reply") };
        assert_eq!(r.logits, sim_logits(&r.member, &ids, SIM_WIDTH));
        assert_eq!(r.member, "dense"); // no SLA → most accurate
        let _ = fleet.shutdown().unwrap();
    }

    #[test]
    fn shed_is_terminal_and_counted() {
        // one worker, capacity 1, slow device pace so the queue backs up
        let mut cfg = quick_cfg(1);
        cfg.queue_cap = 1;
        cfg.max_wait = Duration::from_millis(20);
        let fleet = start(cfg, members(), &env(), FaultPlan::none()).unwrap();
        let mut rxs = Vec::new();
        for i in 0..10 {
            rxs.push(fleet.submit(vec![i; 4], None).unwrap());
        }
        let mut shed = 0;
        for rx in rxs {
            match rx.recv_timeout(Duration::from_secs(20)).unwrap() {
                Outcome::Shed(ShedReason::QueueFull) => shed += 1,
                Outcome::Replied(_) => {}
                other => panic!("unexpected outcome {other:?}"),
            }
        }
        let stats = fleet.shutdown().unwrap();
        assert!(shed > 0, "queue_cap 1 must shed under a burst");
        assert_eq!(stats.shed, shed);
        assert_eq!(stats.accounted(), stats.submitted);
    }

    #[test]
    fn expired_deadline_abandons_queued_requests() {
        // batches wait far longer than the SLA allows, so the sweep
        // must abandon the queued request rather than serve it late
        let mut cfg = quick_cfg(1);
        cfg.max_wait = Duration::from_millis(200);
        cfg.max_batch = 64;
        let fleet = start(cfg, members(), &env(), FaultPlan::none()).unwrap();
        let sla = Sla {
            class: "rt".into(),
            max_latency: Some(Duration::from_millis(8)),
            min_speedup: None,
        };
        // admission passes on the fastest member (est ≈ 6ms ≤ 8ms),
        // then the long
        // max_wait lets the 8ms deadline expire while the request is
        // still queued — the sweep must abandon it, not serve it late
        let rx = fleet.submit(vec![1; 4], Some(sla)).unwrap();
        let out = rx.recv_timeout(Duration::from_secs(20)).unwrap();
        match out {
            Outcome::Abandoned { attempts, .. } => assert_eq!(attempts, 0),
            Outcome::Replied(r) => {
                // raced the sweep: acceptable only if it met the bound
                assert!(r.latency <= Duration::from_millis(200));
            }
            other => panic!("unexpected {other:?}"),
        }
        let stats = fleet.shutdown().unwrap();
        assert_eq!(stats.accounted(), stats.submitted);
    }

    #[test]
    fn crash_retries_on_sibling_and_restart_rewarms() {
        // worker 0 crashes on its first exec (crash rate 1 for worker 0
        // incarnation 0 is not expressible per-worker, so use a high
        // global rate and rely on retries to land somewhere)
        let rates = FaultRates { crash: 0.35, ..FaultRates::default() };
        let mut cfg = quick_cfg(3);
        cfg.quarantine_after = 100; // keep restarting, not quarantining
        let fleet = start(cfg, members(), &env(), FaultPlan::seeded(11, rates)).unwrap();
        let mut rxs = Vec::new();
        for i in 0..120 {
            rxs.push(fleet.submit(vec![i; 6], None).unwrap());
        }
        let mut replied = 0;
        let mut abandoned = 0;
        let mut shed = 0;
        for rx in rxs {
            match rx.recv_timeout(Duration::from_secs(30)).unwrap() {
                Outcome::Replied(r) => {
                    replied += 1;
                    assert!(r.logits.len() == SIM_WIDTH);
                }
                Outcome::Abandoned { .. } => abandoned += 1,
                // queues are ample so QueueFull is impossible, but a
                // submit can land in a window where all three workers
                // are simultaneously mid-restart → NoCapacity is legal
                Outcome::Shed(ShedReason::NoCapacity) => shed += 1,
                Outcome::Shed(other) => panic!("capacity is ample, got {other}"),
            }
        }
        let stats = fleet.shutdown().unwrap();
        assert_eq!(stats.accounted(), stats.submitted);
        assert_eq!(stats.replied, replied);
        assert_eq!(stats.abandoned, abandoned);
        assert_eq!(stats.shed, shed);
        assert!(stats.crashes > 0, "crash rate 0.35 over ≥15 batches must crash");
        assert!(stats.restarts > 0, "crashed workers must restart");
        assert!(replied > 0, "retries must land some requests");
    }

    #[test]
    fn all_workers_quarantined_sheds_no_capacity() {
        // certain crash on every exec + quarantine_after 1 → first
        // batch kills and quarantines each worker; once all are gone,
        // later submits shed NoCapacity
        let rates = FaultRates { crash: 1.0, ..FaultRates::default() };
        let mut cfg = quick_cfg(2);
        cfg.quarantine_after = 1;
        cfg.retry = RetryPolicy { max_retries: 1, base: Duration::from_micros(100), factor: 1.0 };
        let fleet = start(cfg, members(), &env(), FaultPlan::seeded(5, rates)).unwrap();
        let mut outs = Vec::new();
        for i in 0..6 {
            let rx = fleet.submit(vec![i; 4], None).unwrap();
            outs.push(rx.recv_timeout(Duration::from_secs(20)).unwrap());
            std::thread::sleep(Duration::from_millis(2));
        }
        assert!(
            outs.iter().all(|o| !o.is_replied()),
            "every exec crashes; nothing can be served: {outs:?}"
        );
        assert!(
            outs.iter().any(|o| matches!(o, Outcome::Shed(ShedReason::NoCapacity))),
            "once both workers are quarantined, submits must shed: {outs:?}"
        );
        let stats = fleet.shutdown().unwrap();
        assert_eq!(stats.accounted(), stats.submitted);
        assert_eq!(stats.quarantined_workers, 2);
        assert_eq!(stats.restarts, 0, "quarantined workers must not restart");
    }
}
