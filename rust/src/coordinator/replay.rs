//! Deterministic single-process serving replay (DESIGN.md §11).
//!
//! The repro harness needs realized per-bucket p50/p99 numbers that are
//! bit-identical across runs and machines, which rules out the threaded
//! fleet: wall-clock scheduling jitter would leak into every percentile.
//! `replay` re-runs a generated trace through the SAME pure routing layer
//! the live coordinator uses ([`route_batch`] with a [`route`] fallback),
//! prices every executed batch with the member's own bucket-priced
//! estimate, and perturbs it with a seeded multiplicative jitter drawn
//! from the deterministic [`Rng`] stream. The result folds through
//! [`aggregate_buckets`] exactly like live worker samples do, so the
//! certified-vs-realized table in the repro report exercises the real
//! stats path — only the clock is synthetic.

use std::time::Duration;

use crate::coordinator::chaos::TraceItem;
use crate::coordinator::family::{
    aggregate_buckets, route, route_batch, BatchReq, BucketLadder, BucketSample, BucketStats,
    MemberRoute,
};
use crate::util::rng::Rng;

/// Configuration for one deterministic replay.
#[derive(Clone, Debug)]
pub struct ReplayCfg {
    /// Largest merged batch handed to [`route_batch`].
    pub max_batch: usize,
    /// Relative half-width of the seeded execution jitter: an executed
    /// batch realizes `certified * f` with `f` uniform in
    /// `[1 - jitter, 1 + jitter]`.
    pub jitter: f64,
    /// Seed for the jitter stream. The replay is pure in
    /// `(trace, members, ladder, cfg)` — same inputs, same stats.
    pub seed: u64,
    /// Executed shape recorded for batches the ladder does not cover
    /// (the generic-graph path); normally the env's anchor batch shape.
    pub fallback_shape: (usize, usize),
}

/// Replay `trace` through the routing layer and fold the executed
/// batches into per-bucket realized stats.
///
/// Requests are taken in arrival order and greedily chunked to
/// `max_batch`; every chunk is offered to [`route_batch`] first, and a
/// refused merge falls back to per-request [`route`] exactly like the
/// live coordinator. Queue depths stay zero throughout — the replay
/// models a drained single worker, so admission decisions depend only
/// on SLAs and bucket-priced execution estimates, never on timing.
pub fn replay(
    trace: &[TraceItem],
    members: &[MemberRoute],
    ladder: &BucketLadder,
    cfg: &ReplayCfg,
) -> Vec<BucketStats> {
    aggregate_buckets(&replay_samples(trace, members, ladder, cfg))
}

/// The raw executed-batch stream behind [`replay`]: one
/// [`BucketSample`] per executed batch, in execution order, before any
/// aggregation. This is the exact input shape the drift detector
/// (`adapt::detect_drift`) and env fitter consume, so a seeded replay
/// doubles as an engine-free telemetry source.
pub fn replay_samples(
    trace: &[TraceItem],
    members: &[MemberRoute],
    ladder: &BucketLadder,
    cfg: &ReplayCfg,
) -> Vec<BucketSample> {
    if members.is_empty() {
        return Vec::new();
    }
    let mut rng = Rng::new(cfg.seed ^ 0x71);
    let depths = vec![0usize; members.len()];
    let mut samples: Vec<BucketSample> = Vec::new();
    for chunk in trace.chunks(cfg.max_batch.max(1)) {
        let reqs: Vec<BatchReq> = chunk
            .iter()
            .map(|it| BatchReq { sla: it.sla.as_ref(), len: it.ids.len(), waited: Duration::ZERO })
            .collect();
        match route_batch(&reqs, members, &depths, ladder, cfg.max_batch, 0) {
            Some(r) => {
                samples.push(sample(&members[r.member], r.bucket, chunk.len(), cfg, &mut rng));
            }
            None => {
                // refused merge: serve each request on its own member
                for it in chunk {
                    let m = route(it.sla.as_ref(), members, &depths, cfg.max_batch, 0);
                    let bucket = ladder.bucket_for(1, it.ids.len());
                    samples.push(sample(&members[m], bucket, 1, cfg, &mut rng));
                }
            }
        }
    }
    samples
}

/// Price one executed batch: certified estimate at its bucket, jittered.
fn sample(
    member: &MemberRoute,
    bucket: Option<(usize, usize)>,
    requests: usize,
    cfg: &ReplayCfg,
    rng: &mut Rng,
) -> BucketSample {
    let certified = member.time_at(bucket);
    let factor = 1.0 - cfg.jitter + 2.0 * cfg.jitter * rng.f64();
    let (batch, seq) = bucket.unwrap_or(cfg.fallback_shape);
    BucketSample {
        member: member.tag.clone(),
        batch,
        seq,
        specialized: bucket.is_some(),
        exec: Duration::from_secs_f64(certified * factor),
        requests,
        certified,
    }
}

#[cfg(test)]
#[allow(clippy::disallowed_methods)]
mod tests {
    use super::*;
    use crate::coordinator::family::Sla;

    fn members() -> Vec<MemberRoute> {
        vec![
            MemberRoute {
                tag: "dense".into(),
                est_speedup: 1.0,
                est_batch_time: 8e-3,
                bucket_times: vec![((4, 32), 8e-3), ((4, 64), 12e-3)],
            },
            MemberRoute {
                tag: "2x".into(),
                est_speedup: 2.0,
                est_batch_time: 4e-3,
                bucket_times: vec![((4, 32), 4e-3), ((4, 64), 6e-3)],
            },
        ]
    }

    fn item(len: usize, sla: Option<Sla>) -> TraceItem {
        TraceItem { ids: vec![1; len], sla }
    }

    fn cfg() -> ReplayCfg {
        ReplayCfg { max_batch: 4, jitter: 0.1, seed: 9, fallback_shape: (4, 64) }
    }

    #[test]
    fn replay_is_deterministic() {
        let ladder = BucketLadder::new(vec![(4, 32), (4, 64)]);
        let trace: Vec<TraceItem> =
            (0..13).map(|i| item(8 + (i % 3) * 20, None)).collect();
        let a = replay(&trace, &members(), &ladder, &cfg());
        let b = replay(&trace, &members(), &ladder, &cfg());
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.member, y.member);
            assert_eq!((x.batch, x.seq, x.specialized), (y.batch, y.seq, y.specialized));
            assert_eq!(x.realized_p50, y.realized_p50);
            assert_eq!(x.realized_p99, y.realized_p99);
        }
        let total: usize = a.iter().map(|s| s.requests).sum();
        assert_eq!(total, trace.len(), "every request accounted");
    }

    #[test]
    fn samples_fold_to_the_replay_stats() {
        let ladder = BucketLadder::new(vec![(4, 32), (4, 64)]);
        let trace: Vec<TraceItem> =
            (0..13).map(|i| item(8 + (i % 3) * 20, None)).collect();
        let samples = replay_samples(&trace, &members(), &ladder, &cfg());
        assert_eq!(
            aggregate_buckets(&samples),
            replay(&trace, &members(), &ladder, &cfg()),
            "replay() must be exactly aggregate_buckets over replay_samples()"
        );
        let total: usize = samples.iter().map(|s| s.requests).sum();
        assert_eq!(total, trace.len(), "every request lands in some sample");
        assert_eq!(samples, replay_samples(&trace, &members(), &ladder, &cfg()));
    }

    #[test]
    fn jitter_stays_inside_band() {
        let ladder = BucketLadder::new(vec![(4, 32), (4, 64)]);
        let trace: Vec<TraceItem> = (0..32).map(|_| item(16, None)).collect();
        for s in replay(&trace, &members(), &ladder, &cfg()) {
            let cert = s.certified.as_secs_f64();
            let p99 = s.realized_p99.as_secs_f64();
            let p50 = s.realized_p50.as_secs_f64();
            assert!(p99 <= cert * 1.1 + 1e-12, "p99 {p99} vs cert {cert}");
            assert!(p50 >= cert * 0.9 - 1e-12, "p50 {p50} vs cert {cert}");
        }
    }

    #[test]
    fn uncovered_shapes_take_generic_path() {
        // ladder covers nothing → every chunk routes generic, recorded
        // at the fallback shape with specialized = false
        let ladder = BucketLadder::new(vec![]);
        let trace: Vec<TraceItem> = (0..8).map(|_| item(16, None)).collect();
        let stats = replay(&trace, &members(), &ladder, &cfg());
        assert!(!stats.is_empty());
        for s in &stats {
            assert!(!s.specialized);
            assert_eq!((s.batch, s.seq), (4, 64));
        }
    }

    #[test]
    fn min_speedup_sla_respected() {
        let ladder = BucketLadder::new(vec![(4, 32), (4, 64)]);
        let sla = Sla {
            class: "throughput".into(),
            max_latency: None,
            min_speedup: Some(2.0),
        };
        let trace: Vec<TraceItem> = (0..8).map(|_| item(16, Some(sla.clone()))).collect();
        let stats = replay(&trace, &members(), &ladder, &cfg());
        for s in &stats {
            assert_eq!(s.member, "2x", "floor of 2.0 must skip dense");
        }
    }
}
